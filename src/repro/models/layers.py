"""Transformer building blocks: norms, RoPE/M-RoPE, GQA/SWA attention, MLPs.

All functions are pure; parameters are plain dicts of arrays.  Attention is a
pure-JAX flash formulation (python-unrolled Q blocks, lax.scan over KV blocks
with online softmax) so 32k/500k contexts compile with bounded live memory and
causal/sliding-window FLOPs are not doubled by full-mask waste — this is what
keeps the §Roofline compute term honest.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------- norms


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dtype)


# ----------------------------------------------------------------- RoPE


def rope_angles(positions: jax.Array, head_dim: int, base: float = 10000.0):
    """positions [...]-> (cos, sin) of shape [..., head_dim//2], f32."""
    half = head_dim // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [B, T, H, D]; cos/sin [B, T, D//2] -> rotated x (split-half layout)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def mrope_angles(
    positions3: jax.Array,  # [3, B, T] (temporal, height, width) positions
    head_dim: int,
    sections: tuple[int, int, int],
    base: float = 10000.0,
):
    """Qwen2-VL M-RoPE: frequency bands split across 3 position streams.

    sections sum to head_dim//2; band j uses positions3[s(j)] where s maps the
    frequency index to its section.  For text tokens all three streams are
    equal, reducing M-RoPE to standard RoPE exactly.
    """
    half = head_dim // 2
    if sum(sections) != half:
        raise ValueError(f"sections {sections} must sum to head_dim//2={half}")
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )  # [half]
    pos = jnp.take(positions3, sec_id, axis=0)  # [half, B, T]
    ang = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs  # [B, T, half]
    return jnp.cos(ang), jnp.sin(ang)


# ----------------------------------------------------------------- attention


def pad_heads(n_heads: int, n_kv_heads: int, multiple: int) -> tuple[int, int]:
    """Pad head counts so q-heads shard over ``multiple`` and divide kv-heads.

    Padded heads are dead weight (zero output-projection rows), the standard
    trick for archs like smollm (9H) / qwen2-vl (28H) on a 16-way tensor axis;
    see DESIGN.md §5.  Returns (padded_q_heads, padded_kv_heads).
    """
    h = n_heads
    if multiple > 1:
        h = ((n_heads + multiple - 1) // multiple) * multiple
    kv = n_kv_heads
    while h % kv != 0:
        kv += 1
    return h, kv


def _block_mask(q_ids, k_ids, s, causal, window):
    mask = (k_ids < s)[None, :]
    if causal:
        mask &= q_ids[:, None] >= k_ids[None, :]
    if window is not None:
        mask &= q_ids[:, None] - k_ids[None, :] < window
    return mask


def _kv_range(q0, q1, s, causal, window, k_block, q_offset):
    """Static KV-block footprint [k_start, k_end) of q rows [q0, q1)."""
    k_end = min(q_offset + q1, s) if causal else s
    k_start = 0
    if window is not None:
        k_start = max(0, q_offset + q0 - window + 1)
    k_start = (k_start // k_block) * k_block
    return k_start, k_end


def _flash_fwd_impl(q, k, v, causal, window, q_offset, q_block, k_block, scale,
                    s_true):
    """Returns (o [B,T,KH,G,D], lse [B,KH,G,T]) — the flash residuals.
    ``s_true`` is the unpadded KV length (padding is mask-neutralized)."""
    b, t, kh, g, d = q.shape
    s = s_true
    out = jnp.zeros((b, t, kh, g, d), q.dtype)
    lse = jnp.zeros((b, kh, g, t), jnp.float32)
    n_q = -(-t // q_block)
    for qi in range(n_q):
        q0, q1 = qi * q_block, min((qi + 1) * q_block, t)
        qb = q1 - q0
        k_start, k_end = _kv_range(q0, q1, s, causal, window, k_block, q_offset)
        if k_end <= k_start:
            continue
        n_k = -(-(k_end - k_start) // k_block)
        q_blk = q[:, q0:q1].astype(jnp.float32) * scale
        q_ids = q_offset + jnp.arange(q0, q1)

        def kv_step(carry, ki):
            acc, m_run, l_run = carry
            ks = k_start + ki * k_block
            k_blk = jax.lax.dynamic_slice_in_dim(k, ks, k_block, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ks, k_block, axis=1)
            k_ids = ks + jnp.arange(k_block)
            scores = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk,
                                k_blk.astype(jnp.float32))
            mask = _block_mask(q_ids, k_ids, s, causal, window)
            scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
            m_new = jnp.maximum(m_run, scores.max(axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.where(mask[None, None, None],
                          jnp.exp(scores - m_safe[..., None]), 0.0)
            alpha = jnp.where(jnp.isfinite(m_run), jnp.exp(m_run - m_safe), 0.0)
            l_new = l_run * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
            return (acc * alpha[..., None] + pv, m_new, l_new), None

        acc0 = jnp.zeros((b, kh, g, qb, d), jnp.float32)
        m0 = jnp.full((b, kh, g, qb), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kh, g, qb), jnp.float32)
        (acc, m_run, l_run), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                              jnp.arange(n_k))
        o_blk = acc / jnp.maximum(l_run, 1e-20)[..., None]
        lse_blk = m_run + jnp.log(jnp.maximum(l_run, 1e-20))
        out = jax.lax.dynamic_update_slice_in_dim(
            out, jnp.moveaxis(o_blk, 3, 1).astype(q.dtype), q0, axis=1
        )
        lse = jax.lax.dynamic_update_slice_in_dim(lse, lse_blk, q0, axis=3)
    return out, lse


def _flash_bwd_impl(q, k, v, o, lse, do, causal, window, q_offset, q_block,
                    k_block, scale, s_true, t_true):
    """Flash backward: outer python loop over KV blocks, inner scan over the
    Q blocks that touch them.  Residuals are O(T*D); dq is a f32 carry.
    dk/dv are written once per KV block (no full-size carry)."""
    b, t, kh, g, d = q.shape  # t is the q_block-padded length
    s_pad = k.shape[1]
    s = s_true
    n_k = -(-s // k_block)  # padded-tail KV blocks are fully masked; skip them
    # delta = rowsum(do * o)  [B, KH, G, T]
    delta = jnp.einsum("bthgd,bthgd->bhgt", do.astype(jnp.float32),
                       o.astype(jnp.float32))
    dq = jnp.zeros((b, t, kh, g, d), jnp.float32)
    # Cotangents match the padded inputs; padded-tail blocks stay zero.
    dk = jnp.zeros((b, s_pad, kh, d), jnp.float32)
    dv = jnp.zeros((b, s_pad, kh, d), jnp.float32)

    for ki in range(n_k):
        ks = ki * k_block
        ke = min(ks + k_block, s_pad)
        kb = ke - ks
        # Q rows that can see this KV block.
        if causal:
            q_lo = max(ks - q_offset, 0)
        else:
            q_lo = 0
        q_hi = t_true
        if window is not None:
            q_hi = min(t_true, ke - 1 + window - q_offset + 1)
        if q_lo >= q_hi:
            continue
        qi0 = q_lo // q_block
        qi1 = -(-q_hi // q_block)
        k_blk = k[:, ks:ke].astype(jnp.float32)
        v_blk = v[:, ks:ke].astype(jnp.float32)
        k_ids = ks + jnp.arange(kb)

        def q_step(carry, qi):
            dk_a, dv_a, dq_run = carry
            q0 = qi * q_block
            q_blk = jax.lax.dynamic_slice_in_dim(q, q0, q_block, axis=1)
            do_blk = jax.lax.dynamic_slice_in_dim(do, q0, q_block, axis=1)
            lse_blk = jax.lax.dynamic_slice_in_dim(lse, q0, q_block, axis=3)
            dlt_blk = jax.lax.dynamic_slice_in_dim(delta, q0, q_block, axis=3)
            q_ids = q_offset + q0 + jnp.arange(q_block)
            qs = q_blk.astype(jnp.float32) * scale
            scores = jnp.einsum("bqhgd,bkhd->bhgqk", qs, k_blk)
            mask = _block_mask(q_ids, k_ids, s, causal, window)
            mask = mask & (q_ids < t_true + q_offset)[:, None]  # tail q pad
            p = jnp.where(mask[None, None, None],
                          jnp.exp(scores - lse_blk[..., None]), 0.0)
            do32 = do_blk.astype(jnp.float32)
            dv_a = dv_a + jnp.einsum("bhgqk,bqhgd->bkhd", p, do32)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", do32, v_blk)
            ds = p * (dp - dlt_blk[..., None])
            dq_blk = jnp.einsum("bhgqk,bkhd->bqhgd", ds, k_blk) * scale
            dk_a = dk_a + jnp.einsum("bhgqk,bqhgd->bkhd", ds, qs)
            dq_run = jax.lax.dynamic_update_slice_in_dim(
                dq_run,
                jax.lax.dynamic_slice_in_dim(dq_run, q0, q_block, 1) + dq_blk,
                q0, axis=1,
            )
            return (dk_a, dv_a, dq_run), None

        dk_a0 = jnp.zeros((b, kb, kh, d), jnp.float32)
        dv_a0 = jnp.zeros((b, kb, kh, d), jnp.float32)
        (dk_a, dv_a, dq), _ = jax.lax.scan(
            q_step, (dk_a0, dv_a0, dq), jnp.arange(qi0, qi1)
        )
        dk = dk.at[:, ks:ke].set(dk_a)
        dv = dv.at[:, ks:ke].set(dv_a)
    # dk includes the *scale on q side already (ds uses qs = q*scale for dk,
    # and dq multiplied by scale) — consistent with scores = (q*scale).k.
    return dq, dk, dv


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10)
)
def _flash_core(q, k, v, causal, window, q_offset, q_block, k_block, scale,
                s_true, t_true):
    o, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset, q_block,
                           k_block, scale, s_true)
    return o


def _flash_core_fwd(q, k, v, causal, window, q_offset, q_block, k_block, scale,
                    s_true, t_true):
    o, lse = _flash_fwd_impl(q, k, v, causal, window, q_offset, q_block,
                             k_block, scale, s_true)
    return o, (q, k, v, o, lse)


def _flash_core_bwd(causal, window, q_offset, q_block, k_block, scale, s_true,
                    t_true, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _flash_bwd_impl(
        q, k, v, o, lse, do, causal, window, q_offset, q_block, k_block, scale,
        s_true, t_true,
    )
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def flash_attention(
    q: jax.Array,  # [B, T, H, D]
    k: jax.Array,  # [B, S, KH, D]
    v: jax.Array,  # [B, S, KH, D]
    *,
    causal: bool = True,
    window: int | None = None,  # sliding-window size (None = full)
    q_offset: int = 0,  # absolute position of q[0] (prefill continuation)
    q_block: int = 512,
    k_block: int = 1024,
    softmax_scale: float | None = None,
) -> jax.Array:
    """Memory-bounded attention with a flash-style custom VJP.

    Forward: python-unrolled Q blocks, online-softmax scan over only the KV
    blocks each Q block's causal/window footprint touches (HLO FLOPs ~= true
    masked FLOPs).  Backward: custom VJP saving only (q, k, v, o, lse) —
    O(T*D) residuals instead of the O(T^2) that autodiff-through-scan keeps.
    """
    b, t, h, d = q.shape
    s = k.shape[1]
    kh = k.shape[2]
    g = h // kh
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    q_block = min(q_block, t)
    k_block = min(k_block, s)
    # Pad so every block is full-size (masks neutralize padding).
    t_pad = -(-t // q_block) * q_block
    s_pad = -(-s // k_block) * k_block
    qg = q.reshape(b, t, kh, g, d)
    if t_pad != t:
        qg = jnp.pad(qg, [(0, 0), (0, t_pad - t), (0, 0), (0, 0), (0, 0)])
    if s_pad != s:
        pad = [(0, 0), (0, s_pad - s), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    o = _flash_core(qg, k, v, causal, window, q_offset, q_block, k_block,
                    scale, s, t)
    return o[:, :t].reshape(b, t, h, d)


def decode_attention(
    q: jax.Array,  # [B, 1, H, D]
    k_cache: jax.Array,  # [B, S, KH, D]
    v_cache: jax.Array,  # [B, S, KH, D]
    cache_len: jax.Array,  # int32 [] or [B] — valid prefix length
    *,
    window: int | None = None,
    softmax_scale: float | None = None,
) -> jax.Array:
    """Single-token attention against a KV cache (the serve_step hot loop)."""
    b, _, h, d = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, kh, g, d).astype(jnp.float32) * scale
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache.astype(jnp.float32))
    k_ids = jnp.arange(s)
    cl = jnp.asarray(cache_len)
    cl = cl[:, None] if cl.ndim == 1 else cl[None, None]
    valid = k_ids[None, :] < cl  # [B or 1, S]
    if window is not None:
        valid &= k_ids[None, :] >= (cl - window)
    valid = jnp.broadcast_to(valid, (b, s))
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, h, d).astype(q.dtype)


# ----------------------------------------------------------------- MLPs


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array):
    """LLaMA-family MLP: down( silu(x @ gate) * (x @ up) )."""
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x: jax.Array, w_in: jax.Array, b_in, w_out: jax.Array, b_out):
    h = jax.nn.gelu(x @ w_in + b_in)
    return h @ w_out + b_out


# ----------------------------------------------------------------- init


def dense_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape) / math.sqrt(fan_in)).astype(dtype)
