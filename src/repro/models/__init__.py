from repro.models import ctr, embedding  # noqa: F401
