"""CTR backbones: DCN (paper §4.1, Wang et al. 2017) and DeepFM (Guo et al. 2017).

Models take the already-looked-up embedding rows [B, F, d] so the same forward
works for every embedding method in models/embedding.py (and so the trainer
can differentiate w.r.t. the rows for LPT/ALPT).

Paper Appendix B architecture: DCN with cross/deep depth 3 (widths
1024/512/256) for Avazu, depth 5 (width 1000) for Criteo; dropout 0.2 on the
MLP for Criteo.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DCNConfig:
    n_fields: int
    emb_dim: int
    cross_depth: int = 3
    mlp_widths: tuple[int, ...] = (1024, 512, 256)
    dropout: float = 0.0

    @property
    def input_dim(self) -> int:
        return self.n_fields * self.emb_dim


def init_dcn(key: jax.Array, cfg: DCNConfig) -> dict[str, Any]:
    d0 = cfg.input_dim
    keys = jax.random.split(key, 2 * cfg.cross_depth + 2 * len(cfg.mlp_widths) + 1)
    ki = iter(keys)
    params: dict[str, Any] = {"cross_w": [], "cross_b": [], "mlp": []}
    for _ in range(cfg.cross_depth):
        params["cross_w"].append(
            jax.random.normal(next(ki), (d0,), jnp.float32) / jnp.sqrt(d0)
        )
        params["cross_b"].append(jnp.zeros((d0,), jnp.float32))
    prev = d0
    for w in cfg.mlp_widths:
        params["mlp"].append(
            {
                "w": jax.random.normal(next(ki), (prev, w), jnp.float32)
                * jnp.sqrt(2.0 / prev),
                "b": jnp.zeros((w,), jnp.float32),
            }
        )
        prev = w
    final_in = d0 + prev
    params["out_w"] = jax.random.normal(next(ki), (final_in,), jnp.float32) / jnp.sqrt(
        final_in
    )
    params["out_b"] = jnp.zeros((), jnp.float32)
    return params


def dcn_forward(
    params: dict[str, Any],
    rows: jax.Array,  # [B, F, d]
    cfg: DCNConfig,
    *,
    dropout_key: jax.Array | None = None,
) -> jax.Array:
    """Returns logits [B]."""
    b = rows.shape[0]
    x0 = rows.reshape(b, -1)
    # Cross network: x_{l+1} = x0 * (x_l . w_l) + b_l + x_l
    x = x0
    for w, bias in zip(params["cross_w"], params["cross_b"]):
        xw = x @ w  # [B]
        x = x0 * xw[:, None] + bias[None, :] + x
    # Deep network.
    h = x0
    key = dropout_key
    for layer in params["mlp"]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
        if cfg.dropout > 0.0 and key is not None:
            key, sub = jax.random.split(key)
            keep = jax.random.bernoulli(sub, 1.0 - cfg.dropout, h.shape)
            h = jnp.where(keep, h / (1.0 - cfg.dropout), 0.0)
    concat = jnp.concatenate([x, h], axis=-1)
    return concat @ params["out_w"] + params["out_b"]


@dataclasses.dataclass(frozen=True)
class DeepFMConfig:
    n_fields: int
    emb_dim: int
    mlp_widths: tuple[int, ...] = (400, 400, 400)
    dropout: float = 0.0

    @property
    def input_dim(self) -> int:
        return self.n_fields * self.emb_dim


def init_deepfm(key: jax.Array, cfg: DeepFMConfig) -> dict[str, Any]:
    keys = jax.random.split(key, len(cfg.mlp_widths) + 2)
    ki = iter(keys)
    params: dict[str, Any] = {"mlp": []}
    prev = cfg.input_dim
    for w in cfg.mlp_widths:
        params["mlp"].append(
            {
                "w": jax.random.normal(next(ki), (prev, w), jnp.float32)
                * jnp.sqrt(2.0 / prev),
                "b": jnp.zeros((w,), jnp.float32),
            }
        )
        prev = w
    params["out_w"] = jax.random.normal(next(ki), (prev,), jnp.float32) / jnp.sqrt(prev)
    params["out_b"] = jnp.zeros((), jnp.float32)
    return params


def deepfm_forward(
    params: dict[str, Any],
    rows: jax.Array,  # [B, F, d] — shared FM/deep embeddings
    first_order: jax.Array,  # [B, F] — per-feature scalar weights (from a d=1 table)
    cfg: DeepFMConfig,
    *,
    dropout_key: jax.Array | None = None,
) -> jax.Array:
    b = rows.shape[0]
    # FM second order: 0.5 * ((sum v)^2 - sum v^2).
    s = rows.sum(axis=1)
    fm2 = 0.5 * ((s * s).sum(axis=-1) - (rows * rows).sum(axis=(1, 2)))
    fm1 = first_order.sum(axis=1)
    h = rows.reshape(b, -1)
    key = dropout_key
    for layer in params["mlp"]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
        if cfg.dropout > 0.0 and key is not None:
            key, sub = jax.random.split(key)
            keep = jax.random.bernoulli(sub, 1.0 - cfg.dropout, h.shape)
            h = jnp.where(keep, h / (1.0 - cfg.dropout), 0.0)
    deep = h @ params["out_w"] + params["out_b"]
    return fm1 + fm2 + deep


def logits_from_rows(
    dense_params: dict[str, Any],
    rows: jax.Array,  # [B, F, d] (DeepFM: d+1 — last column is first-order)
    cfg: DCNConfig | DeepFMConfig,
    *,
    model: str = "dcn",
    dropout_key: jax.Array | None = None,
) -> jax.Array:
    """One entry point from looked-up rows to logits for every CTR backbone.

    Shared by the trainer (which differentiates through it w.r.t. the rows)
    and the serving engine (which feeds it rows read straight off the int8
    codes via ``serving.table.rows``).  DeepFM packs the first-order scalar
    table as the last embedding column, so one [B, F, d+1] lookup serves both
    towers.
    """
    if model == "deepfm":
        r, first = rows[..., :-1], rows[..., -1]
        return deepfm_forward(
            dense_params, r, first, cfg, dropout_key=dropout_key
        )
    return dcn_forward(dense_params, rows, cfg, dropout_key=dropout_key)


def bce_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean binary cross-entropy from logits (numerically stable)."""
    return jnp.mean(
        jnp.maximum(logits, 0.0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
