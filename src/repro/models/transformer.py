"""Unified LM backbone covering all 10 assigned architectures.

One config describes dense (llama-style), GQA/SWA/qk-norm attention, MoE
(routed + shared experts), Mamba2 SSD, hybrid interleaves (Jamba), encoder-
only (HuBERT) and M-RoPE VLM backbones (Qwen2-VL).

Layer stacking: layers are grouped into *periods* (``layer_types`` is the
period pattern, e.g. Jamba's ``(m m m m attn m m m)``); parameters are stacked
[n_groups, ...] per period position and the forward scans over groups — the
HLO is O(period), not O(n_layers), which is what lets deepseek-67b (95 layers)
lower+compile quickly on the 512-device dry-run mesh.

Embedding: the vocab table is a quantized LPT/ALPT table (the paper's
technique, DESIGN.md §4) or fp.  The forward takes the *de-quantized* table as
an explicit argument so trainers can differentiate w.r.t. it and run the
paper's integer-table update (lpt.dense_apply / alpt_dense_step).  The tied
head contracts int8-as-float codes and applies the per-row step AFTER the
matmul (logits[v] = step[v] * <h, codes[v]>), so quantized tying costs no
extra HBM traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.context import hint
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.serving import table as serving_tbl


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    # Period pattern: layer l has type layer_types[l % period].
    layer_types: tuple[str, ...] = ("attn",)  # 'attn' | 'mamba'
    moe_pattern: tuple[bool, ...] = (False,)  # per period position: routed MoE?
    moe: moe_mod.MoEConfig | None = None
    ssm: ssm_mod.SSMConfig | None = None
    # Attention flavor.
    qk_norm: bool = False
    attn_bias: bool = False
    sliding_window: int | None = None
    rope_base: float = 10000.0
    mrope_sections: tuple[int, int, int] | None = None
    causal: bool = True  # False -> encoder-only (hubert)
    mlp_type: str = "swiglu"  # 'swiglu' | 'gelu' (hubert) — d_ff == 0: no MLP
    # Embedding / head (the paper's technique lives here).
    embedding_method: str = "alpt"  # 'fp' | 'lpt' | 'alpt'
    embedding_bits: int = 8
    tie_embeddings: bool = False
    input_mode: str = "tokens"  # 'tokens' | 'embeds' | 'mixed'
    visual_prefix: int = 0  # 'mixed': number of patch-embedding positions
    # Numerics / sharding-shape knobs.
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    head_pad_multiple: int = 1  # pad q-heads to a multiple (16 for TP dry-run)
    ce_chunk: int = 512
    attn_q_block: int = 512
    attn_k_block: int = 1024
    remat: bool = False  # checkpoint each period group in the scan

    @property
    def period(self) -> int:
        return len(self.layer_types)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.period == 0, (self.n_layers, self.period)
        return self.n_layers // self.period

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_heads(self) -> tuple[int, int]:
        return L.pad_heads(self.n_heads, self.n_kv_heads, self.head_pad_multiple)

    def layer_type(self, pos: int) -> str:
        return self.layer_types[pos % self.period]

    def is_moe(self, pos: int) -> bool:
        return self.moe_pattern[pos % self.period] if self.moe is not None else False


# --------------------------------------------------------------------- init


def _init_attn(key, cfg: ModelConfig):
    h, kv = cfg.padded_heads
    hd = cfg.hd
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], (d, h * hd), dtype=cfg.param_dtype),
        "wk": L.dense_init(ks[1], (d, kv * hd), dtype=cfg.param_dtype),
        "wv": L.dense_init(ks[2], (d, kv * hd), dtype=cfg.param_dtype),
        "wo": L.dense_init(ks[3], (h * hd, d), dtype=cfg.param_dtype),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((h * hd,), cfg.param_dtype)
        p["bk"] = jnp.zeros((kv * hd,), cfg.param_dtype)
        p["bv"] = jnp.zeros((kv * hd,), cfg.param_dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.param_dtype)
        p["k_norm"] = jnp.ones((hd,), cfg.param_dtype)
    return p


def _init_mlp(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_type == "gelu":
        return {
            "w_in": L.dense_init(k1, (d, f), dtype=cfg.param_dtype),
            "b_in": jnp.zeros((f,), cfg.param_dtype),
            "w_out": L.dense_init(k2, (f, d), dtype=cfg.param_dtype),
            "b_out": jnp.zeros((d,), cfg.param_dtype),
        }
    return {
        "w_gate": L.dense_init(k1, (d, f), dtype=cfg.param_dtype),
        "w_up": L.dense_init(k2, (d, f), dtype=cfg.param_dtype),
        "w_down": L.dense_init(k3, (f, d), dtype=cfg.param_dtype),
    }


def _init_block(key, cfg: ModelConfig, pos: int):
    """One period-position block (norms + mixer + mlp/moe)."""
    kind = cfg.layer_type(pos)
    k_mix, k_mlp = jax.random.split(key)
    p: dict[str, Any] = {
        "norm1": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "norm2": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if kind == "attn":
        p["attn"] = _init_attn(k_mix, cfg)
    elif kind == "mamba":
        p["mamba"] = ssm_mod.init_ssm(k_mix, cfg.ssm, dtype=cfg.param_dtype)
    else:
        raise ValueError(kind)
    if cfg.is_moe(pos):
        p["moe"] = moe_mod.init_moe(k_mlp, cfg.moe, dtype=cfg.param_dtype)
    elif cfg.d_ff > 0:
        p["mlp"] = _init_mlp(k_mlp, cfg)
    else:
        del p["norm2"]  # pure-mamba blocks (mamba2) have no MLP sub-layer
    return p


def init_params(key: jax.Array, cfg: ModelConfig) -> dict[str, Any]:
    """Returns {'blocks': [period][stacked over groups], 'final_norm', 'head'...}.

    The embedding table is NOT here — it is a quantized LPT table owned by the
    trainer (see repro.training.lm_trainer) and passed to the forward
    de-quantized.  Untied archs get a float 'head' [V, d].
    """
    keys = jax.random.split(key, cfg.period + 2)
    blocks = []
    for pos in range(cfg.period):
        gkeys = jax.random.split(keys[pos], cfg.n_groups)
        stacked = jax.vmap(lambda k: _init_block(k, cfg, pos))(gkeys)
        blocks.append(stacked)
    params: dict[str, Any] = {
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(
            keys[-1], (cfg.vocab_size, cfg.d_model), fan_in=cfg.d_model,
            dtype=cfg.param_dtype,
        )
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# --------------------------------------------------------------------- blocks


def _attn_block(p, x, cfg: ModelConfig, *, positions, cache=None, cache_len=None,
                return_kv=False):
    """Pre-norm attention. cache=None: full-sequence; else single-token decode.
    ``return_kv``: full-sequence prefill returns the rope'd (k, v) for caching.
    """
    b, t, d = x.shape
    h, kv = cfg.padded_heads
    hd = cfg.hd
    a = p["attn"]
    y = L.rms_norm(x, p["norm1"])
    q = y @ a["wq"]
    k = y @ a["wk"]
    v = y @ a["wv"]
    if cfg.attn_bias:
        q, k, v = q + a["bq"], k + a["bk"], v + a["bv"]
    q = hint(q.reshape(b, t, h, hd), "q_heads")
    k = hint(k.reshape(b, t, kv, hd), "kv_heads")
    v = hint(v.reshape(b, t, kv, hd), "kv_heads")
    if cfg.qk_norm:
        q = L.rms_norm(q, a["q_norm"])
        k = L.rms_norm(k, a["k_norm"])
    if cfg.mrope_sections is not None:
        cos, sin = L.mrope_angles(positions, hd, cfg.mrope_sections, cfg.rope_base)
    else:
        cos, sin = L.rope_angles(positions, hd, cfg.rope_base)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)

    if cache is None:
        o = L.flash_attention(
            q, k, v,
            causal=cfg.causal,
            window=cfg.sliding_window,
            q_block=cfg.attn_q_block,
            k_block=cfg.attn_k_block,
        )
        new_cache = (k, v) if return_kv else None
    else:
        # SWA caches are window-sized ring buffers (slot = position % size) —
        # this is what bounds long_500k memory for mixtral/h2o-danube.
        cache_size = cache["k"].shape[1]
        ring = cfg.sliding_window is not None and cache_size <= cfg.sliding_window
        cl = jnp.asarray(cache_len)
        if cl.ndim == 0:
            write_idx = cl % cache_size if ring else cl
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, write_idx, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, write_idx, 1)
        else:
            # Per-slot lengths (the serving Engine's continuous batching):
            # each batch row writes its token at its own cache position.
            write_idx = (
                cl % cache_size if ring else jnp.minimum(cl, cache_size - 1)
            )
            rows = jnp.arange(b)
            k_cache = cache["k"].at[rows, write_idx].set(k[:, 0])
            v_cache = cache["v"].at[rows, write_idx].set(v[:, 0])
        valid_len = jnp.minimum(cl + 1, cache_size) if ring else cl + 1
        o = L.decode_attention(
            q, k_cache, v_cache, valid_len,
            window=None if ring else cfg.sliding_window,
        )
        new_cache = {"k": k_cache, "v": v_cache}
    o = o.reshape(b, t, h * hd) @ a["wo"]
    return x + o, new_cache


def _mamba_block(p, x, cfg: ModelConfig, *, cache=None, return_cache=False):
    y = L.rms_norm(x, p["norm1"])
    if cache is None:
        out, c = ssm_mod.ssm_forward(
            p["mamba"], y, cfg.ssm, return_cache=return_cache
        )
        return x + out, c
    out, new_cache = ssm_mod.ssm_decode_step(p["mamba"], y, cfg.ssm, cache)
    return x + out, new_cache


def _moe_apply(p_moe, y, cfg: ModelConfig):
    """Dense (GSPMD) MoE, or the explicit shard_map EP dispatch when the
    active policy requests it (EXPERIMENTS.md §Perf, deepseek-moe cell)."""
    from repro.dist.context import moe_ep_context

    ctx = moe_ep_context()
    if ctx is None or cfg.moe.n_experts % ctx.policy.model_size != 0:
        return moe_mod.moe_forward(p_moe, y, cfg.moe)
    from jax.sharding import PartitionSpec as P

    pol = ctx.policy
    m = pol.model_axis
    dp = pol.dp_spec
    all_axes = tuple(pol.data_axes) + (m,)
    w_specs = {
        "router": P(None, None),
        "w_gate": P(m, None, None),
        "w_up": P(m, None, None),
        "w_down": P(m, None, None),
    }
    if cfg.moe.n_shared_experts:
        w_specs["shared"] = {
            "w_gate": P(None, None), "w_up": P(None, None),
            "w_down": P(None, None),
        }

    def inner(p_local, y_local):
        out, aux = moe_mod.moe_forward_ep(p_local, y_local, cfg.moe, axis=m)
        return out, jax.lax.pmean(aux, all_axes)

    fn = jax.shard_map(
        inner, mesh=ctx.mesh,
        in_specs=(w_specs, P(dp, None, None)),
        out_specs=(P(dp, None, None), P()),
        check_vma=False,
    )
    return fn(p_moe, y)


def _mlp_block(p, x, cfg: ModelConfig, pos: int):
    if not cfg.is_moe(pos) and cfg.d_ff == 0:
        return x, jnp.zeros((), jnp.float32)
    y = L.rms_norm(x, p["norm2"])
    if cfg.is_moe(pos):
        out, aux = _moe_apply(p["moe"], y, cfg)
        return x + out, aux
    if cfg.mlp_type == "gelu":
        out = L.gelu_mlp(
            y, p["mlp"]["w_in"], p["mlp"]["b_in"], p["mlp"]["w_out"],
            p["mlp"]["b_out"],
        )
    else:
        out = L.swiglu(y, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return x + out, jnp.zeros((), jnp.float32)


def _period_fwd(period_params, x, cfg: ModelConfig, positions):
    """Apply one period (cfg.period consecutive layers). Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    x = hint(x, "carry")
    for pos in range(cfg.period):
        p = period_params[pos]
        if cfg.layer_type(pos) == "attn":
            x, _ = _attn_block(p, x, cfg, positions=positions)
        else:
            x, _ = _mamba_block(p, x, cfg)
        x, a = _mlp_block(p, x, cfg, pos)
        aux = aux + a
    return x, aux


# --------------------------------------------------------------------- fwd


def backbone(
    params: dict[str, Any],
    embeds: jax.Array,  # [B, T, d] (already embedded / modality stub)
    cfg: ModelConfig,
    positions: jax.Array,  # [B, T] or [3, B, T] for M-RoPE
) -> tuple[jax.Array, jax.Array]:
    """Scan over period groups. Returns (hidden [B,T,d], moe_aux scalar)."""
    x = hint(embeds.astype(cfg.dtype), "activation")

    def group_step(carry, group_params):
        x, aux = carry
        fwd = _period_fwd
        if cfg.remat:
            fwd = jax.checkpoint(
                _period_fwd, static_argnums=(2,), prevent_cse=False
            )
        x, a = fwd(group_params, x, cfg, positions)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(
        group_step, (x, jnp.zeros((), jnp.float32)), params["blocks"]
    )
    return L.rms_norm(x, params["final_norm"]), aux


def embed_tokens(table_fp, tokens: jax.Array, cfg: ModelConfig):
    """Token rows from either a float [V, d] table or an int8-resident
    serving table (repro.serving.table) — the latter reads through the fused
    ``ops.dequant_gather`` inside the jitted step, bitwise-equal to gathering
    the de-quantized export."""
    emb = serving_tbl.rows(table_fp, tokens).astype(cfg.dtype)
    # Standard embedding scale keeps quantized-table variance usable.
    return emb


def head_logits(params, table_fp, h, cfg: ModelConfig):
    """Logits [.., V]; tied head contracts the (de-quantized) table.

    The hint reshards the weight to vocab-sharded at the matmul: for untied
    heads it is a no-op / FSDP gather; for the tied quantized table it is the
    d-sharded -> vocab-sharded reshard, paid in cfg.dtype (bf16) bytes.

    A tied int8-resident serving table instead contracts through
    ``ops.dequant_matmul``: weight tiles de-quantize in VMEM right before the
    MXU, 1 byte/weight off HBM, no fp32 table anywhere.  The contraction runs
    in f32, so under ``cfg.dtype == float32`` (every serving config today) it
    is bitwise-equal to the fp-exported einsum; a bf16 config would make the
    quantized head *more* precise than the bf16 float path, not less, and
    parity becomes approximate.  The ``head_weight`` reshard hint is not
    emitted here — single-host serving only; the multi-host follow-up
    (ROADMAP) owns sharding the codes.
    """
    w = table_fp if cfg.tie_embeddings else params["head"]
    if serving_tbl.is_integer_resident(w):
        return serving_tbl.head_logits(w, h)
    if isinstance(w, serving_tbl.FloatTable):
        w = w.table
    w = hint(w.astype(cfg.dtype), "head_weight")
    return jnp.einsum("...d,vd->...v", h, w).astype(jnp.float32)


def chunked_ce_loss(
    params,
    table_fp,
    h: jax.Array,  # [B, T, d]
    labels: jax.Array,  # [B, T] int32; -1 = ignore
    cfg: ModelConfig,
) -> jax.Array:
    """Cross-entropy without materializing [B, T, V]: scan over T chunks."""
    b, t, d = h.shape
    chunk = min(cfg.ce_chunk, t)
    if t % chunk:
        chunk = t  # fall back to single chunk for odd lengths
    nc = t // chunk
    hc = h.reshape(b, nc, chunk, d)
    lc = labels.reshape(b, nc, chunk)

    def piece(h_blk, l_blk):
        logits = head_logits(params, table_fp, h_blk, cfg)  # [B, chunk, V] f32
        logits = hint(logits, "logits")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(l_blk, 0)[..., None], axis=-1
        )[..., 0]
        mask = (l_blk >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    piece = jax.checkpoint(piece)

    def scan_fn(carry, xs):
        tot, cnt = carry
        s, c = piece(xs[0], xs[1])
        return (tot + s, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(
        scan_fn,
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0)),
    )
    return tot / jnp.maximum(cnt, 1.0)


def default_positions(b: int, t: int, cfg: ModelConfig, offset: int = 0):
    pos = jnp.arange(offset, offset + t, dtype=jnp.int32)[None, :].repeat(b, 0)
    if cfg.mrope_sections is not None:
        return jnp.stack([pos, pos, pos], axis=0)  # text: all streams equal
    return pos


def assemble_embeds(table_fp, batch: dict[str, jax.Array], cfg: ModelConfig):
    """Input embedding for every input_mode; returns [B, T, d]."""
    if cfg.input_mode == "embeds":
        return batch["embeds"].astype(cfg.dtype)
    tok_emb = embed_tokens(table_fp, batch["tokens"], cfg)
    if cfg.input_mode == "mixed" and cfg.visual_prefix > 0:
        prefix = batch["prefix_embeds"].astype(cfg.dtype)  # [B, P, d]
        p = cfg.visual_prefix
        return jnp.concatenate([prefix, tok_emb[:, p:]], axis=1)
    return tok_emb


def loss_fn(
    params: dict[str, Any],
    table_fp: jax.Array,  # [V, d] de-quantized embedding table
    batch: dict[str, jax.Array],
    cfg: ModelConfig,
) -> tuple[jax.Array, jax.Array]:
    """Full training loss (CE + MoE aux). Returns (loss, aux_loss)."""
    embeds = assemble_embeds(table_fp, batch, cfg)
    b, t, _ = embeds.shape
    positions = batch.get("positions", default_positions(b, t, cfg))
    h, aux = backbone(params, embeds, cfg, positions)
    ce = chunked_ce_loss(params, table_fp, h, batch["labels"], cfg)
    return ce + aux, aux


# --------------------------------------------------------------------- decode


def cache_len_for(cfg: ModelConfig, max_len: int) -> int:
    """KV slots per attention layer: SWA archs get a window-sized ring buffer —
    this is what bounds long_500k memory for mixtral/h2o-danube."""
    return min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> list:
    """Decode cache: one entry per period position, stacked over groups.

    Matches the scan layout of params['blocks'] so decode_step/prefill scan
    over (params, cache) jointly — the lowered HLO is O(period), not O(depth)
    (95-layer deepseek-67b decode compiles as one scan body).
    """
    _, kv = cfg.padded_heads
    hd = cfg.hd
    kv_len = cache_len_for(cfg, max_len)
    g = cfg.n_groups
    caches = []
    for pos in range(cfg.period):
        if cfg.layer_type(pos) == "attn":
            caches.append(
                {
                    "k": jnp.zeros((g, batch, kv_len, kv, hd), cfg.dtype),
                    "v": jnp.zeros((g, batch, kv_len, kv, hd), cfg.dtype),
                }
            )
        else:
            one = ssm_mod.init_ssm_cache(cfg.ssm, batch, cfg.dtype)
            caches.append(
                jax.tree.map(lambda a: jnp.zeros((g,) + a.shape, a.dtype), one)
            )
    return caches


def decode_step(
    params,
    table_fp,
    token: jax.Array,  # [B] int32 current token
    cache: list,
    cache_len: jax.Array,  # int32 [] or [B] — tokens already in cache per slot
    cfg: ModelConfig,
):
    """One serve_step: returns (logits [B, V], new_cache).

    ``cache_len`` may be a scalar (all slots in lock-step, the historical
    wave path) or a per-slot [B] vector — the serving Engine's slot-based
    continuous batching, where refilled slots carry different lengths.
    """
    b = token.shape[0]
    x = embed_tokens(table_fp, token[:, None], cfg)
    # RoPE positions are the absolute index of each slot's new token.
    cl = jnp.asarray(cache_len)
    offset = cl[:, None] if cl.ndim == 1 else cl
    positions = default_positions(b, 1, cfg, offset=0) + offset

    def group_step(x, xs):
        gparams, gcache = xs
        new_c = []
        for pos in range(cfg.period):
            p = gparams[pos]
            if cfg.layer_type(pos) == "attn":
                x, c = _attn_block(
                    p, x, cfg, positions=positions, cache=gcache[pos],
                    cache_len=cache_len,
                )
            else:
                x, c = _mamba_block(p, x, cfg, cache=gcache[pos])
            x, _ = _mlp_block(p, x, cfg, pos)
            new_c.append(c)
        return x, new_c

    x, new_cache = jax.lax.scan(group_step, x, (params["blocks"], cache))
    h = L.rms_norm(x, params["final_norm"])
    logits = head_logits(params, table_fp, h[:, 0], cfg)
    return logits, new_cache


def prefill(
    params, table_fp, tokens: jax.Array, cfg: ModelConfig, max_len: int,
    lens: jax.Array | None = None,
):
    """Run the full prompt, build the decode cache. Returns (logits_last, cache).

    ``lens`` ([B] int32, optional) marks each row's true prompt length for
    right-padded batches: the returned logits come from position ``lens-1``
    per row.  Causal attention masks the padding *exactly* (pad keys
    contribute zero), so the first ``lens`` cache positions are valid and the
    decoder masks the rest via its per-slot ``cache_len`` — but the padded
    sequence length changes XLA's reduction shapes, so results match an
    exact-length prefill numerically (~1 ulp), not bitwise.  Only meaningful
    for attention-only stacks — an SSM layer's final state would have
    scanned through the padding; the serving Engine therefore prefills at
    exact length (bitwise per-request determinism) and keeps this as the
    future bucketed-prefill path.
    """
    b, t = tokens.shape
    x = embed_tokens(table_fp, tokens, cfg)
    positions = default_positions(b, t, cfg)
    kv_len = cache_len_for(cfg, max_len)
    # Ring layout: position p lives in slot p % kv_len; for t <= kv_len this is
    # the identity. Only the last kv_len positions survive (unique slots).
    n_keep = min(t, kv_len)
    slots = jnp.arange(t - n_keep, t) % kv_len

    def group_step(x, gparams):
        new_c = []
        for pos in range(cfg.period):
            p = gparams[pos]
            if cfg.layer_type(pos) == "attn":
                x, (k, v) = _attn_block(
                    p, x, cfg, positions=positions, return_kv=True
                )
                kc = jnp.zeros((b, kv_len) + k.shape[2:], cfg.dtype)
                vc = jnp.zeros((b, kv_len) + v.shape[2:], cfg.dtype)
                new_c.append(
                    {
                        "k": kc.at[:, slots].set(k[:, -n_keep:]),
                        "v": vc.at[:, slots].set(v[:, -n_keep:]),
                    }
                )
            else:
                x, c = _mamba_block(p, x, cfg, return_cache=True)
                new_c.append(c)
            x, _ = _mlp_block(p, x, cfg, pos)
        return x, new_c

    x, cache = jax.lax.scan(group_step, x, params["blocks"])
    h_final = L.rms_norm(x, params["final_norm"])
    if lens is None:
        h_last = h_final[:, -1]
    else:
        idx = jnp.clip(lens - 1, 0, t - 1)
        h_last = jnp.take_along_axis(h_final, idx[:, None, None], axis=1)[:, 0]
    logits = head_logits(params, table_fp, h_last, cfg)
    return logits, cache
