"""One embedding-table API over all methods in paper Table 1.

Methods: 'fp', 'lpt', 'alpt', 'lsq', 'pact', 'hash', 'prune'.

Lookup/update semantics per method family:
  * float-leaf methods ('fp', 'lsq', 'pact', 'hash', 'prune') — ``params()``
    exposes differentiable leaves, updated by the caller's optimizer.
  * integer-table methods ('lpt', 'alpt') — the table is int8 state, not a
    differentiable leaf.  The trainer differentiates w.r.t. the *looked-up
    rows* and calls ``apply_row_grads`` (Eq. 8 / Algorithm 1).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import alpt, hashing, lpt, pruning, qat


@dataclasses.dataclass(frozen=True)
class EmbeddingSpec:
    method: str  # fp | lpt | alpt | lsq | pact | hash | prune
    n: int
    d: int
    bits: int = 8
    init_scale: float = 1e-2
    # LPT (Xu et al. 2021) fixes Delta via a tuned clip value:
    clip_value: float | None = None
    # ALPT hyper-parameters (paper §4.1):
    alpt: alpt.ALPTConfig = alpt.ALPTConfig()
    row_optimizer: str = "adam"
    hash_compression: float = 2.0
    prune: pruning.PruneConfig = pruning.PruneConfig()

    @property
    def is_integer_table(self) -> bool:
        return self.method in ("lpt", "alpt")


FLOAT_METHODS = ("fp", "lsq", "pact", "hash", "prune")
INT_METHODS = ("lpt", "alpt")


def init_embedding(key: jax.Array, spec: EmbeddingSpec) -> Any:
    if spec.method == "fp":
        return jax.random.normal(key, (spec.n, spec.d), jnp.float32) * spec.init_scale
    if spec.method in ("lpt", "alpt"):
        return lpt.init_table(
            key,
            spec.n,
            spec.d,
            spec.bits,
            init_scale=spec.init_scale,
            clip_value=spec.clip_value if spec.method == "lpt" else None,
            optimizer=spec.row_optimizer,
        )
    if spec.method in ("lsq", "pact"):
        return qat.init_qat(
            key, spec.n, spec.d, spec.bits, method=spec.method,
            init_scale=spec.init_scale,
        )
    if spec.method == "hash":
        return hashing.init_qr(
            key, spec.n, spec.d, compression=spec.hash_compression,
            init_scale=spec.init_scale,
        )
    if spec.method == "prune":
        return pruning.init_prune(key, spec.n, spec.d, init_scale=spec.init_scale)
    raise ValueError(f"unknown embedding method {spec.method!r}")


def lookup(state: Any, ids: jax.Array, spec: EmbeddingSpec,
           grad_scale: float = 1.0) -> jax.Array:
    """De-quantized / fake-quantized / masked rows [..., d]."""
    if spec.method == "fp":
        return jnp.take(state, ids, axis=0)
    if spec.method in ("lpt", "alpt"):
        return lpt.lookup(state, ids)
    if spec.method in ("lsq", "pact"):
        return qat.qat_lookup(state, ids, spec.bits, method=spec.method,
                              grad_scale=grad_scale)
    if spec.method == "hash":
        return hashing.qr_lookup(state, ids)
    if spec.method == "prune":
        return pruning.prune_lookup(state, ids)
    raise ValueError(spec.method)


def trainable_params(state: Any, spec: EmbeddingSpec):
    """Differentiable leaves for float-leaf methods (None for int tables)."""
    if spec.method == "fp":
        return state
    if spec.method in ("lsq", "pact"):
        return {"weights": state.weights, "scale": state.scale}
    if spec.method == "hash":
        return {"remainder": state.remainder, "quotient": state.quotient}
    if spec.method == "prune":
        return {"weights": state.weights}
    return None


def with_params(state: Any, params: Any, spec: EmbeddingSpec):
    """Rebuild state from updated differentiable leaves."""
    if spec.method == "fp":
        return params
    if spec.method in ("lsq", "pact"):
        return qat.QATTable(weights=params["weights"], scale=params["scale"])
    if spec.method == "hash":
        return hashing.QRTable(
            remainder=params["remainder"], quotient=params["quotient"], r=state.r
        )
    if spec.method == "prune":
        return state._replace(weights=params["weights"])
    return state


def memory_bytes(state: Any, spec: EmbeddingSpec, *, training: bool) -> int:
    """Embedding-memory accounting as in paper Table 1's compression columns."""
    n, d = spec.n, spec.d
    fp = n * d * 4
    if spec.method == "fp":
        return fp
    if spec.method in ("lpt", "alpt"):
        return int(n * d * spec.bits / 8) + n * 4
    if spec.method in ("lsq", "pact"):
        # Training keeps the fp master copy; inference ships codes + step.
        return fp + n * 4 if training else int(n * d * spec.bits / 8) + n * 4
    if spec.method == "hash":
        return hashing.qr_memory_bytes(state)
    if spec.method == "prune":
        # Unstructured sparsity: training keeps dense + mask; inference CSR-ish.
        if training:
            return fp + n * d // 8
        keep = float(jnp.mean(state.mask.astype(jnp.float32)))
        return int(fp * keep)
    raise ValueError(spec.method)
