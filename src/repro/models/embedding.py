"""One embedding-table API over all methods — a thin shim over
:mod:`repro.methods`.

The protocol, registry, and per-method implementations live in
``repro/methods/`` (one file per method; ``repro.methods.base`` documents the
full ``EmbeddingMethod`` surface).  This module keeps the historical
function-style entry points — ``init_embedding`` / ``lookup`` /
``trainable_params`` / ``with_params`` / ``memory_bytes`` — as one-line
delegations so existing callers and notebooks keep working; new code should
call ``repro.methods.get(spec.method)`` directly.

Lookup/update semantics per method family:

  * float-leaf methods ('fp', 'lsq', 'pact', 'hash', 'prune') —
    ``trainable_params`` exposes differentiable leaves, updated by the
    caller's optimizer.
  * integer-table methods ('lpt', 'alpt', 'qr_lpt', 'qr_alpt') — the table is int8
    state, not a differentiable leaf.  The trainer differentiates w.r.t. the
    *looked-up rows* and the method applies them (Eq. 8 / Algorithm 1).
"""
from __future__ import annotations

from typing import Any

import jax

from repro.methods import EmbeddingSpec, available, get  # noqa: F401

__all__ = [
    "EmbeddingSpec",
    "available",
    "get",
    "init_embedding",
    "lookup",
    "trainable_params",
    "with_params",
    "memory_bytes",
]


def init_embedding(key: jax.Array, spec: EmbeddingSpec) -> Any:
    return get(spec.method).init(key, spec)


def lookup(state: Any, ids: jax.Array, spec: EmbeddingSpec,
           grad_scale: float = 1.0) -> jax.Array:
    """De-quantized / fake-quantized / masked rows [..., d]."""
    return get(spec.method).lookup(state, ids, spec, grad_scale=grad_scale)


def trainable_params(state: Any, spec: EmbeddingSpec):
    """Differentiable leaves for float-leaf methods (None for int tables)."""
    return get(spec.method).trainable_params(state, spec)


def with_params(state: Any, params: Any, spec: EmbeddingSpec):
    """Rebuild state from updated differentiable leaves."""
    return get(spec.method).with_params(state, params, spec)


def memory_bytes(state: Any, spec: EmbeddingSpec, *, training: bool) -> int:
    """Embedding-memory accounting as in paper Table 1's compression columns."""
    return get(spec.method).memory_bytes(state, spec, training=training)
