"""Mamba2 (state-space duality / SSD) blocks — arXiv:2405.21060.

Chunked SSD for train/prefill (lax.scan over chunks carries the inter-chunk
state, so live memory is one chunk's pairwise decay matrix and the HLO is
O(1) in sequence length), recurrent form for decode (O(1) state per token —
this is what makes ``long_500k`` runnable where full attention is not).

Tensor-parallel layout: the monolithic mamba in_proj is split into per-stream
projections (z, x, B, C, dt) so each can carry its own PartitionSpec —
z/x/dt shard over heads ('model' axis), B/C are head-shared and replicated
(DESIGN.md §5).  Shapes: d_inner = expand * d_model, H = d_inner / headdim
heads, state N, B/C shared across heads (ngroups = 1 as released).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.headdim

    @property
    def conv_dim(self) -> int:  # total conv channels (x | B | C)
        return self.d_inner + 2 * self.d_state

    @property
    def proj_width(self) -> int:  # total input-projection columns
        return 2 * self.d_inner + 2 * self.d_state + self.n_heads


def init_ssm(key: jax.Array, cfg: SSMConfig, dtype=jnp.float32) -> dict[str, Any]:
    kz, kx, kb, kc, kdt, kcv, ko, kdtb = jax.random.split(key, 8)
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    s = 1.0 / jnp.sqrt(d)
    dt_init = jnp.exp(
        jax.random.uniform(kdtb, (h,))
        * (jnp.log(cfg.dt_max) - jnp.log(cfg.dt_min))
        + jnp.log(cfg.dt_min)
    )
    return {
        "wz": (jax.random.normal(kz, (d, di)) * s).astype(dtype),
        "wx": (jax.random.normal(kx, (d, di)) * s).astype(dtype),
        "wB": (jax.random.normal(kb, (d, n)) * s).astype(dtype),
        "wC": (jax.random.normal(kc, (d, n)) * s).astype(dtype),
        "wdt": (jax.random.normal(kdt, (d, h)) * s).astype(dtype),
        # Depthwise causal conv over (x | B | C), stored per stream.
        "conv_x": (jax.random.normal(kcv, (cfg.conv_width, di)) * 0.1).astype(dtype),
        "conv_B": jnp.zeros((cfg.conv_width, n), dtype) + 0.1,
        "conv_C": jnp.zeros((cfg.conv_width, n), dtype) + 0.1,
        "conv_bx": jnp.zeros((di,), dtype),
        "conv_bB": jnp.zeros((n,), dtype),
        "conv_bC": jnp.zeros((n,), dtype),
        # softplus(dt_bias) spans [dt_min, dt_max] (mamba2 init).
        "dt_bias": jnp.log(jnp.expm1(dt_init)).astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": (jax.random.normal(ko, (di, d)) / jnp.sqrt(di)).astype(dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d: out_t = silu(b + sum_i w[i] * x_{t-W+1+i})."""
    wdt = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (wdt - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(wdt):
        out = out + pad[:, i : i + x.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def ssd_chunked(
    x: jax.Array,  # [B, T, H, P]
    dt: jax.Array,  # [B, T, H] (post-softplus)
    A: jax.Array,  # [H] (negative)
    B_: jax.Array,  # [B, T, N]
    C_: jax.Array,  # [B, T, N]
    chunk: int,
    ssm_state: jax.Array | None = None,  # [B, H, P, N]
):
    """Returns (y [B, T, H, P], final_state [B, H, P, N])."""
    b, t, h, p = x.shape
    n = B_.shape[-1]
    if t % chunk:
        raise ValueError(f"seq {t} must divide chunk {chunk}")
    nc = t // chunk
    q = chunk

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    Bc = B_.reshape(b, nc, q, n)
    Cc = C_.reshape(b, nc, q, n)
    dA = dtc * A  # [b, nc, q, h] (negative)
    cs = jnp.cumsum(dA, axis=2)  # within-chunk cumulative log decay

    s0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if ssm_state is None
        else ssm_state.astype(jnp.float32)
    )

    def chunk_step(state, inp):
        xq, dtq, Bq, Cq, csq = inp
        xdt = (xq * dtq[..., None]).astype(jnp.float32)  # [b, q, h, p]
        # Intra: Y[i] = sum_{j<=i} (C_i.B_j) * exp(cs_i - cs_j) * xdt_j
        cb = jnp.einsum("bin,bjn->bij", Cq.astype(jnp.float32), Bq.astype(jnp.float32))
        seg = csq[:, :, None, :] - csq[:, None, :, :]  # [b, i, j, h]
        mask = jnp.tril(jnp.ones((q, q), bool))
        decay = jnp.where(mask[None, :, :, None], jnp.exp(seg), 0.0)
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", cb, decay, xdt)
        # Inter: Y[i] += C_i . state * exp(cs_i)
        y_inter = jnp.einsum(
            "bin,bhpn,bih->bihp", Cq.astype(jnp.float32), state, jnp.exp(csq)
        )
        # State: S' = exp(total) * S + sum_j exp(cs_end - cs_j) B_j (x) xdt_j
        total = csq[:, -1, :]  # [b, h]
        decay_to_end = jnp.exp(total[:, None, :] - csq)  # [b, q, h]
        s_local = jnp.einsum(
            "bjh,bjn,bjhp->bhpn", decay_to_end, Bq.astype(jnp.float32), xdt
        )
        state = jnp.exp(total)[:, :, None, None] * state + s_local
        return state, (y_intra + y_inter).astype(x.dtype)

    xs = tuple(
        jnp.moveaxis(a, 1, 0) for a in (xc, dtc, Bc, Cc, cs)
    )
    final_state, yc = jax.lax.scan(chunk_step, s0, xs)
    y = jnp.moveaxis(yc, 0, 1).reshape(b, t, h, p)
    return y, final_state


def _project(params, u):
    """Split projections. u [B,T,d] -> z, x_raw, B_raw, C_raw, dt_raw."""
    return (
        u @ params["wz"],
        u @ params["wx"],
        u @ params["wB"],
        u @ params["wC"],
        u @ params["wdt"],
    )


def ssm_forward(
    params: dict[str, Any],
    u: jax.Array,  # [B, T, d_model]
    cfg: SSMConfig,
    ssm_state: jax.Array | None = None,
    return_cache: bool = False,
):
    """Full mamba2 mixer. Returns (out, cache|None)."""
    b, t, _ = u.shape
    z, x_raw, B_raw, C_raw, dt_raw = _project(params, u)
    x = _causal_conv(x_raw, params["conv_x"], params["conv_bx"])
    B_ = _causal_conv(B_raw, params["conv_B"], params["conv_bB"])
    C_ = _causal_conv(C_raw, params["conv_C"], params["conv_bC"])
    x = x.reshape(b, t, cfg.n_heads, cfg.headdim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    chunk = min(cfg.chunk, t)
    y, state = ssd_chunked(x, dt, A, B_, C_, chunk, ssm_state)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * x
    y = y.reshape(b, t, cfg.d_inner)
    from repro.models.layers import rms_norm

    y = rms_norm(y * jax.nn.silu(z), params["norm_w"])
    out = y @ params["out_proj"]
    if not return_cache:
        return out, None
    w = cfg.conv_width - 1
    cache = {
        "conv_x": x_raw[:, -w:].astype(u.dtype),
        "conv_B": B_raw[:, -w:].astype(u.dtype),
        "conv_C": C_raw[:, -w:].astype(u.dtype),
        "ssm": state,
    }
    return out, cache


def _conv_step(window: jax.Array, new: jax.Array, w: jax.Array, b: jax.Array):
    """One causal-conv step: window [B, W-1, c] + new [B, c]."""
    full = jnp.concatenate([window, new[:, None, :]], axis=1)  # [B, W, c]
    out = jax.nn.silu(jnp.einsum("bwc,wc->bc", full, w) + b)
    return out, full[:, 1:]


def ssm_decode_step(
    params: dict[str, Any],
    u: jax.Array,  # [B, 1, d_model]
    cfg: SSMConfig,
    cache: dict[str, jax.Array],
):
    """O(1) recurrent step. Returns (out [B,1,d], new_cache)."""
    b = u.shape[0]
    z, x_raw, B_raw, C_raw, dt_raw = _project(params, u)
    x1, conv_x = _conv_step(cache["conv_x"], x_raw[:, 0], params["conv_x"],
                            params["conv_bx"])
    B1, conv_B = _conv_step(cache["conv_B"], B_raw[:, 0], params["conv_B"],
                            params["conv_bB"])
    C1, conv_C = _conv_step(cache["conv_C"], C_raw[:, 0], params["conv_C"],
                            params["conv_bC"])
    x = x1.reshape(b, cfg.n_heads, cfg.headdim)
    dt1 = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt1 * A)  # [B, H]
    xdt = (x * dt1[..., None]).astype(jnp.float32)
    new_state = a[:, :, None, None] * cache["ssm"] + jnp.einsum(
        "bn,bhp->bhpn", B1.astype(jnp.float32), xdt
    )
    y = jnp.einsum("bn,bhpn->bhp", C1.astype(jnp.float32), new_state)
    y = y.astype(u.dtype) + params["D"].astype(u.dtype)[None, :, None] * x
    y = y.reshape(b, 1, cfg.d_inner)
    from repro.models.layers import rms_norm

    y = rms_norm(y * jax.nn.silu(z), params["norm_w"])
    new_cache = {
        "conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C, "ssm": new_state,
    }
    return y @ params["out_proj"], new_cache


def init_ssm_cache(cfg: SSMConfig, batch: int, dtype=jnp.float32):
    w = cfg.conv_width - 1
    return {
        "conv_x": jnp.zeros((batch, w, cfg.d_inner), dtype),
        "conv_B": jnp.zeros((batch, w, cfg.d_state), dtype),
        "conv_C": jnp.zeros((batch, w, cfg.d_state), dtype),
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.headdim, cfg.d_state), jnp.float32),
    }
