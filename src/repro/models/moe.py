"""Mixture-of-Experts: top-k routing with per-sample capacity dispatch.

Design for GSPMD (DESIGN.md §5): the dispatch buffer keeps the batch dim
leading — [B, E, C, d] with C = ceil(S*k/E * capacity_factor) per *sample* —
so every routing op (cumsum, scatter, gather) is local to a data shard under
pjit; the only collective a MoE layer induces is the same all-reduce a dense
Megatron MLP has (contraction over the 'model'-sharded expert inner dim).

Supports shared experts (DeepSeek-MoE: always-on experts added to the routed
output) and the standard load-balance auxiliary loss.  Token order within a
sample decides capacity drops (residual passes dropped tokens through).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.context import hint


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int  # per-expert hidden dim
    n_shared_experts: int = 0
    shared_d_ff: int | None = None  # defaults to d_ff * n_shared
    capacity_factor: float = 1.25
    normalize_gates: bool = True  # renormalize top-k probs (Mixtral-style)
    aux_loss_coef: float = 0.01

    @property
    def shared_hidden(self) -> int:
        if self.n_shared_experts == 0:
            return 0
        return self.shared_d_ff or self.d_ff * self.n_shared_experts


def init_moe(key: jax.Array, cfg: MoEConfig, dtype=jnp.float32) -> dict[str, Any]:
    k_r, k1, k2, k3, s1, s2, s3 = jax.random.split(key, 7)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    scale_in = 1.0 / jnp.sqrt(d)
    scale_out = 1.0 / jnp.sqrt(f)
    params = {
        "router": (jax.random.normal(k_r, (d, e)) * scale_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(k1, (e, d, f)) * scale_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (e, d, f)) * scale_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (e, f, d)) * scale_out).astype(dtype),
    }
    if cfg.n_shared_experts:
        fs = cfg.shared_hidden
        params["shared"] = {
            "w_gate": (jax.random.normal(s1, (d, fs)) * scale_in).astype(dtype),
            "w_up": (jax.random.normal(s2, (d, fs)) * scale_in).astype(dtype),
            "w_down": (jax.random.normal(s3, (fs, d)) * scale_out).astype(dtype),
        }
    return params


def capacity(cfg: MoEConfig, seq_len: int) -> int:
    c = int(seq_len * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(c, 1)


def moe_forward(
    params: dict[str, Any],
    x: jax.Array,  # [B, S, d]
    cfg: MoEConfig,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [B, S, d], aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, s)

    logits = x.astype(jnp.float32) @ params["router"]  # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # [B, S, k]
    if cfg.normalize_gates:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(axis=-1, keepdims=True), 1e-9
        )

    # Position of each (token, slot) within its expert, per sample.
    oh = jax.nn.one_hot(expert_ids, e, dtype=jnp.int32)  # [B, S, k, E]
    oh_flat = oh.reshape(b, s * k, e)
    pos = jnp.cumsum(oh_flat, axis=1) - oh_flat  # exclusive prefix count
    pos_in_expert = (pos * oh_flat).sum(-1).reshape(b, s, k)  # [B, S, k]
    keep = pos_in_expert < c

    # Scatter tokens into [B, E, C, d]; dropped slots scatter out of range.
    flat_e = expert_ids.reshape(b, s * k)
    flat_p = jnp.where(keep.reshape(b, s * k), pos_in_expert.reshape(b, s * k), c)
    x_rep = jnp.repeat(x[:, :, None, :], k, axis=2).reshape(b, s * k, d)
    buf = jnp.zeros((b, e, c, d), x.dtype)
    bidx = jnp.arange(b)[:, None]
    buf = buf.at[bidx, flat_e, flat_p].add(x_rep, mode="drop")
    # Under expert parallelism this constraint makes the scatter above the
    # dispatch all-to-all and the per-expert matmuls fully local.
    buf = hint(buf, "moe_buf")

    # Per-expert SwiGLU (einsum keeps the expert dim explicit for sharding).
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, params["w_gate"]))
    h = h * jnp.einsum("becd,edf->becf", buf, params["w_up"])
    y_buf = jnp.einsum("becf,efd->becd", h, params["w_down"])  # [B, E, C, d]
    y_buf = hint(y_buf, "moe_buf")

    # Gather back + gate-combine.
    y_tok = y_buf[bidx, flat_e, jnp.minimum(flat_p, c - 1)]  # [B, S*k, d]
    y_tok = y_tok * (keep.reshape(b, s * k, 1) * gate_vals.reshape(b, s * k, 1)).astype(
        y_tok.dtype
    )
    y = y_tok.reshape(b, s, k, d).sum(axis=2)

    if cfg.n_shared_experts:
        sh = params["shared"]
        hs = jax.nn.silu(x @ sh["w_gate"]) * (x @ sh["w_up"])
        y = y + hs @ sh["w_down"]

    # Load-balance loss (Switch/Mixtral form): E * sum_e f_e * P_e.
    frac_tokens = jnp.mean(
        (oh.sum(axis=2) > 0).astype(jnp.float32), axis=(0, 1)
    )  # [E]
    mean_probs = jnp.mean(probs, axis=(0, 1))
    aux = cfg.aux_loss_coef * e * jnp.sum(frac_tokens * mean_probs)
    return y.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Expert parallelism (shard_map interior).  EXPERIMENTS.md §Perf measured that
# expressing EP through GSPMD sharding constraints alone triggers involuntary
# full rematerialization (412 s collective term); this explicit dispatch is
# the fix: tokens are sequence-split across the model axis, all-to-all'd to
# their expert owners, processed fully locally, and all-to-all'd back; one
# psum restores the replicated activation layout.
# ---------------------------------------------------------------------------


def moe_forward_ep(
    params: dict[str, Any],  # w_* sharded over experts OUTSIDE; local E/n here
    x: jax.Array,  # [B, S, d] — replicated over the model axis (local view)
    cfg: MoEConfig,
    axis: str,  # model-axis name inside shard_map
) -> tuple[jax.Array, jax.Array]:
    """Runs INSIDE shard_map(mesh, model axis = ``axis``).

    Local views: x [B, S, d] (same on every model rank of a data shard);
    params['w_*'] [E_loc, d, f] (this rank's experts); router replicated.
    Returns the full [B, S, d] output (replicated over ``axis``) + aux loss.
    """
    n = jax.lax.axis_size(axis)
    rank = jax.lax.axis_index(axis)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    e_loc = e // n
    s_loc = s // n
    # 1. Sequence-split the (replicated) tokens across model ranks.
    xs = jax.lax.dynamic_slice_in_dim(x, rank * s_loc, s_loc, axis=1)
    logits = xs.astype(jnp.float32) @ params["router"]  # [B, s_loc, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)
    if cfg.normalize_gates:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9
        )
    # 2. Dispatch into a per-destination-rank buffer [n, E_loc, C, d].
    c = max(int(s_loc * k * cfg.capacity_factor / e) + 1, 1)
    oh = jax.nn.one_hot(expert_ids, e, dtype=jnp.int32)  # [B, s_loc, k, E]
    oh_flat = oh.reshape(b, s_loc * k, e)
    pos = jnp.cumsum(oh_flat, axis=1) - oh_flat
    pos_in_expert = (pos * oh_flat).sum(-1).reshape(b, s_loc * k)
    keep = pos_in_expert < c
    flat_e = expert_ids.reshape(b, s_loc * k)
    flat_p = jnp.where(keep, pos_in_expert, c)
    x_rep = jnp.repeat(xs[:, :, None, :], k, axis=2).reshape(b, s_loc * k, d)
    send = jnp.zeros((n, e_loc, b, c, d), x.dtype)
    dest_rank = flat_e // e_loc
    dest_exp = flat_e % e_loc
    bidx = jnp.arange(b)[:, None]
    send = send.at[dest_rank, dest_exp, bidx, flat_p].add(x_rep, mode="drop")
    # 3. All-to-all: rank r receives every rank's slice for ITS experts.
    recv = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=0,
                              tiled=False)
    # recv [n(source), e_loc, b, c, d] — tokens for this rank's experts.
    h = jax.nn.silu(jnp.einsum("sebcd,edf->sebcf", recv, params["w_gate"]))
    h = h * jnp.einsum("sebcd,edf->sebcf", recv, params["w_up"])
    y_buf = jnp.einsum("sebcf,efd->sebcd", h, params["w_down"])
    # 4. Return trip + combine into this rank's token slice.
    back = jax.lax.all_to_all(y_buf, axis, split_axis=0, concat_axis=0,
                              tiled=False)  # [n(dest->home), e_loc, b, c, d]
    y_tok = back[dest_rank, dest_exp, bidx, jnp.minimum(flat_p, c - 1)]
    y_tok = y_tok * (keep[..., None] * gate_vals.reshape(b, s_loc * k, 1)
                     ).astype(y_tok.dtype)
    ys = y_tok.reshape(b, s_loc, k, d).sum(axis=2)
    if cfg.n_shared_experts:
        sh = params["shared"]
        hs = jax.nn.silu(xs @ sh["w_gate"]) * (xs @ sh["w_up"])
        ys = ys + hs @ sh["w_down"]
    # 5. Restore the replicated [B, S, d] layout with one psum.
    full = jnp.zeros((b, s, d), x.dtype)
    full = jax.lax.dynamic_update_slice_in_dim(full, ys.astype(x.dtype),
                                               rank * s_loc, axis=1)
    y = jax.lax.psum(full, axis)
    frac_tokens = jnp.mean((oh.sum(axis=2) > 0).astype(jnp.float32),
                           axis=(0, 1))
    mean_probs = jnp.mean(probs, axis=(0, 1))
    aux = cfg.aux_loss_coef * e * jnp.sum(frac_tokens * mean_probs)
    aux = jax.lax.pmean(aux, axis)
    return y, aux
