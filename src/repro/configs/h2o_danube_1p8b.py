"""h2o-danube-1.8b [dense]: 24L d=2560 32H (kv=8) d_ff=6912 vocab=32000.

llama+mistral mix with sliding-window attention — arXiv:2401.16818.
head_dim = 80; SWA window 4096 makes long_500k runnable.
"""
from repro.models.transformer import ModelConfig
from repro.configs.common import shrink

SKIP_SHAPES: dict[str, str] = {}  # SWA -> all shapes run


def full_config(**overrides) -> ModelConfig:
    cfg = ModelConfig(
        name="h2o-danube-1.8b",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6912,
        vocab_size=32000,
        sliding_window=4096,
        embedding_method="alpt",
    )
    return shrink(cfg, **overrides)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        sliding_window=32,
        embedding_method="alpt",
        ce_chunk=32,
        attn_q_block=32,
        attn_k_block=32,
    )
