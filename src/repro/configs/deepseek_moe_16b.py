"""deepseek-moe-16b [moe]: 28L d=2048 16H (MHA kv=16) d_ff=1408 vocab=102400.

Fine-grained MoE: 64 routed experts top-6 plus 2 shared (always-on) experts —
arXiv:2401.06066.  Deviation note: the released model's layer 0 is a dense
MLP (d_ff 10944); we route every layer to keep the scan period at 1 (DESIGN.md
§7) — parameter count differs by <1%.
"""
from repro.models.moe import MoEConfig
from repro.models.transformer import ModelConfig
from repro.configs.common import shrink, FULL_ATTN_LONG_SKIP

SKIP_SHAPES = {"long_500k": FULL_ATTN_LONG_SKIP}  # full (non-windowed) attention


def full_config(**overrides) -> ModelConfig:
    cfg = ModelConfig(
        name="deepseek-moe-16b",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=102400,
        layer_types=("attn",),
        moe_pattern=(True,),
        moe=MoEConfig(
            n_experts=64,
            top_k=6,
            d_model=2048,
            d_ff=1408,
            n_shared_experts=2,
            shared_d_ff=2816,
            normalize_gates=False,  # deepseek-moe keeps raw top-k probs
        ),
        embedding_method="alpt",
    )
    return shrink(cfg, **overrides)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=32,
        vocab_size=512,
        layer_types=("attn",),
        moe_pattern=(True,),
        moe=MoEConfig(
            n_experts=8, top_k=3, d_model=64, d_ff=32,
            n_shared_experts=2, shared_d_ff=64, normalize_gates=False,
        ),
        embedding_method="alpt",
        ce_chunk=32,
        attn_q_block=32,
        attn_k_block=32,
    )
