"""qwen3-1.7b [dense]: 28L d=2048 16H (kv=8) d_ff=6144 vocab=151936.

QK-RMSNorm per head, GQA, head_dim=128, tied embeddings [hf:Qwen/Qwen3-8B
family].  The 151936x2048 vocab table is the arch's biggest single tensor —
the strongest LM case for the paper's technique (~19% of params).
"""
from repro.models.transformer import ModelConfig
from repro.configs.common import shrink, FULL_ATTN_LONG_SKIP

SKIP_SHAPES = {"long_500k": FULL_ATTN_LONG_SKIP}


def full_config(**overrides) -> ModelConfig:
    cfg = ModelConfig(
        name="qwen3-1.7b",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=6144,
        vocab_size=151936,
        qk_norm=True,
        rope_base=1_000_000.0,
        tie_embeddings=True,
        embedding_method="alpt",
    )
    return shrink(cfg, **overrides)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        qk_norm=True,
        tie_embeddings=True,
        embedding_method="alpt",
        ce_chunk=32,
        attn_q_block=32,
        attn_k_block=32,
    )
