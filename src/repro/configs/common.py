"""Shared machinery for the assigned architecture configs.

Every config module exposes:
  full_config(**overrides)  -> ModelConfig   (the exact published shape)
  smoke_config()            -> ModelConfig   (reduced same-family config)
  SKIP_SHAPES: dict[shape_name, reason]      (spec-sanctioned skips)

Shapes (LM pool): train/prefill lower ``train_step``-style full-sequence
programs; decode/long lower ``serve_step`` (one token + KV cache).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

FULL_ATTN_LONG_SKIP = (
    "long_500k needs sub-quadratic attention; this arch is pure full attention "
    "(see DESIGN.md §4)"
)
ENCODER_DECODE_SKIP = "encoder-only arch has no autoregressive decode step"


def input_specs(cfg: ModelConfig, shape_name: str) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    For 'train'/'prefill': full-sequence inputs.  For 'decode': one new token
    plus the cache metadata (the cache itself is built by serve_step's init).
    """
    s = SHAPES[shape_name]
    b, t = s["global_batch"], s["seq_len"]
    i32 = jnp.int32
    if s["kind"] in ("train", "prefill"):
        batch: dict[str, jax.ShapeDtypeStruct] = {}
        if cfg.input_mode == "embeds":
            batch["embeds"] = jax.ShapeDtypeStruct((b, t, cfg.d_model), cfg.dtype)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((b, t), i32)
            if cfg.input_mode == "mixed":
                batch["prefix_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.visual_prefix, cfg.d_model), cfg.dtype
                )
                batch["positions"] = jax.ShapeDtypeStruct((3, b, t), i32)
        batch["labels"] = jax.ShapeDtypeStruct((b, t), i32)
        return batch
    # decode: one token per sequence, cache length scalar.
    return {
        "token": jax.ShapeDtypeStruct((b,), i32),
        "cache_len": jax.ShapeDtypeStruct((), i32),
    }


def concrete_batch(cfg: ModelConfig, *, batch: int, seq: int, key=None):
    """Small concrete batch for smoke tests (same structure as input_specs)."""
    key = jax.random.PRNGKey(0) if key is None else key
    k1, k2, k3 = jax.random.split(key, 3)
    out: dict[str, jax.Array] = {}
    if cfg.input_mode == "embeds":
        out["embeds"] = jax.random.normal(k1, (batch, seq, cfg.d_model), cfg.dtype)
    else:
        out["tokens"] = jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size, jnp.int32)
        if cfg.input_mode == "mixed":
            out["prefix_embeds"] = jax.random.normal(
                k2, (batch, cfg.visual_prefix, cfg.d_model), cfg.dtype
            )
            pos = jnp.arange(seq, dtype=jnp.int32)[None].repeat(batch, 0)
            out["positions"] = jnp.stack([pos, pos, pos], 0)
    out["labels"] = jax.random.randint(k3, (batch, seq), 0, cfg.vocab_size, jnp.int32)
    return out


def shrink(cfg: ModelConfig, **overrides) -> ModelConfig:
    return dataclasses.replace(cfg, **overrides)
