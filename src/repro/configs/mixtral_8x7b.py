"""mixtral-8x7b [moe]: 32L d=4096 32H (kv=8) d_ff=14336 vocab=32000.

8 experts, top-2, sliding-window attention (4096) — arXiv:2401.04088.
SWA makes long_500k runnable (window-sized ring KV cache).
"""
from repro.models.moe import MoEConfig
from repro.models.transformer import ModelConfig
from repro.configs.common import shrink

SKIP_SHAPES: dict[str, str] = {}  # SWA -> sub-quadratic decode, all shapes run


def full_config(**overrides) -> ModelConfig:
    cfg = ModelConfig(
        name="mixtral-8x7b",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        layer_types=("attn",),
        moe_pattern=(True,),
        moe=MoEConfig(n_experts=8, top_k=2, d_model=4096, d_ff=14336),
        sliding_window=4096,
        embedding_method="alpt",
    )
    return shrink(cfg, **overrides)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        layer_types=("attn",),
        moe_pattern=(True,),
        moe=MoEConfig(n_experts=4, top_k=2, d_model=64, d_ff=128),
        sliding_window=32,
        embedding_method="alpt",
        ce_chunk=32,
        attn_q_block=32,
        attn_k_block=32,
    )
