"""Architecture registry: ``--arch <id>`` resolves here.

10 assigned LM-pool architectures + the paper's own DCN/CTR setups.
"""
from __future__ import annotations

import importlib

ARCHS = {
    "mamba2-370m": "repro.configs.mamba2_370m",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "smollm-135m": "repro.configs.smollm_135m",
    "qwen3-1.7b": "repro.configs.qwen3_1p7b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1p8b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
}


def get_arch(name: str):
    """Returns the config module for an architecture id."""
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[name])


def full_config(name: str, **overrides):
    return get_arch(name).full_config(**overrides)


def smoke_config(name: str):
    return get_arch(name).smoke_config()


def skip_shapes(name: str) -> dict[str, str]:
    return get_arch(name).SKIP_SHAPES
