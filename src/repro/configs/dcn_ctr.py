"""The paper's own architecture: DCN on (synthetic) Criteo / Avazu.

Appendix B: Avazu — cross/deep depth 3, deep widths 1024/512/256;
Criteo — depth 5, width 1000, dropout 0.2.  Embedding dim 16 (§4.1).
"""
from repro.core.alpt import ALPTConfig
from repro.data import ctr_synth
from repro.models.ctr import DCNConfig
from repro.models.embedding import EmbeddingSpec


def avazu_setup(method: str = "alpt", bits: int = 8, scale: float = 0.01):
    data_cfg = ctr_synth.avazu_like(scale=scale)
    spec = EmbeddingSpec(
        method=method, n=data_cfg.n_features, d=16, bits=bits,
        alpt=ALPTConfig(bits=bits, step_lr=2e-5, weight_decay=5e-8),
    )
    dcn = DCNConfig(
        n_fields=data_cfg.n_fields, emb_dim=16, cross_depth=3,
        mlp_widths=(1024, 512, 256),
    )
    return data_cfg, spec, dcn


def criteo_setup(method: str = "alpt", bits: int = 8, scale: float = 0.01):
    data_cfg = ctr_synth.criteo_like(scale=scale)
    spec = EmbeddingSpec(
        method=method, n=data_cfg.n_features, d=16, bits=bits,
        alpt=ALPTConfig(bits=bits, step_lr=2e-5, weight_decay=1e-5),
    )
    dcn = DCNConfig(
        n_fields=data_cfg.n_fields, emb_dim=16, cross_depth=5,
        mlp_widths=(1000,) * 5, dropout=0.2,
    )
    return data_cfg, spec, dcn
