"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (kv=8) d_ff=14336 vocab=65536.

Mamba + attention at 1:7 (one attention layer per 8-layer period, position 4)
and MoE (16 experts, top-2) on every second layer — arXiv:2403.19887.  Mamba
sub-layers use d_state=16 (Jamba's value; the pool line pins ssm_state only
for mamba2-370m).
"""
from repro.models.moe import MoEConfig
from repro.models.ssm import SSMConfig
from repro.models.transformer import ModelConfig
from repro.configs.common import shrink

SKIP_SHAPES: dict[str, str] = {}  # hybrid: sub-quadratic, all shapes run

_PERIOD = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba")
_MOE = (False, True, False, True, False, True, False, True)


def full_config(**overrides) -> ModelConfig:
    cfg = ModelConfig(
        name="jamba-v0.1-52b",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=65536,
        layer_types=_PERIOD,
        moe_pattern=_MOE,
        moe=MoEConfig(n_experts=16, top_k=2, d_model=4096, d_ff=14336),
        ssm=SSMConfig(d_model=4096, d_state=16, headdim=64, expand=2),
        embedding_method="alpt",
    )
    return shrink(cfg, **overrides)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke",
        n_layers=8,  # one full period
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        layer_types=_PERIOD,
        moe_pattern=_MOE,
        moe=MoEConfig(n_experts=4, top_k=2, d_model=64, d_ff=128),
        ssm=SSMConfig(d_model=64, d_state=16, headdim=16, expand=2, chunk=32),
        embedding_method="alpt",
        ce_chunk=32,
    )
