"""deepseek-67b [dense]: 95L d=8192 64H (kv=8) d_ff=22016 vocab=102400.

llama-arch at 67B — arXiv:2401.02954.  The depth (95 layers) is why the
backbone scans over layer groups: the lowered HLO is O(1) in depth.  Requires
the fsdp_tp sharding policy to fit 16 GB/chip (DESIGN.md §5).
"""
from repro.models.transformer import ModelConfig
from repro.configs.common import shrink, FULL_ATTN_LONG_SKIP

SKIP_SHAPES = {"long_500k": FULL_ATTN_LONG_SKIP}


def full_config(**overrides) -> ModelConfig:
    cfg = ModelConfig(
        name="deepseek-67b",
        n_layers=95,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab_size=102400,
        embedding_method="alpt",
        remat=True,  # activation checkpointing per layer group
    )
    return shrink(cfg, **overrides)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b-smoke",
        n_layers=3,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=512,
        embedding_method="alpt",
        remat=True,
        ce_chunk=32,
        attn_q_block=32,
        attn_k_block=32,
    )
