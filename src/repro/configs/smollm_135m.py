"""smollm-135m [dense]: 30L d=576 9H (kv=3) d_ff=1536 vocab=49152.

llama-arch small model [hf:HuggingFaceTB/SmolLM-135M]; tied embeddings.
9 heads don't divide a 16-way tensor axis — the dry-run policy pads q-heads
to 16 / kv to 4 (layers.pad_heads; DESIGN.md §5).
"""
from repro.models.transformer import ModelConfig
from repro.configs.common import shrink, FULL_ATTN_LONG_SKIP

SKIP_SHAPES = {"long_500k": FULL_ATTN_LONG_SKIP}


def full_config(**overrides) -> ModelConfig:
    cfg = ModelConfig(
        name="smollm-135m",
        n_layers=30,
        d_model=576,
        n_heads=9,
        n_kv_heads=3,
        d_ff=1536,
        vocab_size=49152,
        tie_embeddings=True,
        embedding_method="alpt",
    )
    return shrink(cfg, **overrides)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="smollm-smoke",
        n_layers=3,
        d_model=48,
        n_heads=3,  # keeps the 3:1 GQA ratio of the full model
        n_kv_heads=1,
        d_ff=128,
        vocab_size=512,
        tie_embeddings=True,
        embedding_method="alpt",
        ce_chunk=32,
        attn_q_block=32,
        attn_k_block=32,
    )
