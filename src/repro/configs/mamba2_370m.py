"""mamba2-370m [ssm]: 48L d_model=1024, attention-free, vocab=50280, state=128.

SSD (state-space duality), arXiv:2405.21060.  No MLP sub-layer (d_ff=0); each
layer is a single Mamba2 mixer.  d_inner = 2048, headdim 64 -> 32 SSD heads.
Tied embeddings (as released).  ALPT quantizes the 50280x1024 vocab table.
"""
from repro.models.ssm import SSMConfig
from repro.models.transformer import ModelConfig
from repro.configs.common import shrink

SKIP_SHAPES: dict[str, str] = {}  # SSM: all four shapes run (O(1) decode state)


def full_config(**overrides) -> ModelConfig:
    cfg = ModelConfig(
        name="mamba2-370m",
        n_layers=48,
        d_model=1024,
        n_heads=32,  # SSD heads (d_inner / headdim); no attention layers
        n_kv_heads=32,
        d_ff=0,
        vocab_size=50280,
        layer_types=("mamba",),
        ssm=SSMConfig(d_model=1024, d_state=128, headdim=64, expand=2),
        tie_embeddings=True,
        embedding_method="alpt",
    )
    return shrink(cfg, **overrides)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m-smoke",
        n_layers=4,
        d_model=64,
        n_heads=8,
        n_kv_heads=8,
        d_ff=0,
        vocab_size=512,
        layer_types=("mamba",),
        ssm=SSMConfig(d_model=64, d_state=32, headdim=16, expand=2, chunk=32),
        tie_embeddings=True,
        embedding_method="alpt",
        ce_chunk=32,
    )
