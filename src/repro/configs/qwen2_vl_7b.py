"""qwen2-vl-7b [vlm]: 28L d=3584 28H (kv=4) d_ff=18944 vocab=152064.

M-RoPE (temporal/height/width frequency sections 16/24/24 of head_dim 128)
and QKV bias — arXiv:2409.12191.  Vision frontend is a STUB per the pool
spec: input_specs() provides 256 precomputed patch embeddings that replace
the first 256 token positions ('mixed' input mode) plus 3-stream positions.
28 q-heads pad to 32 on a 16-way tensor axis.
"""
from repro.models.transformer import ModelConfig
from repro.configs.common import shrink, FULL_ATTN_LONG_SKIP

SKIP_SHAPES = {"long_500k": FULL_ATTN_LONG_SKIP}


def full_config(**overrides) -> ModelConfig:
    cfg = ModelConfig(
        name="qwen2-vl-7b",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        attn_bias=True,
        mrope_sections=(16, 24, 24),
        input_mode="mixed",
        visual_prefix=256,
        embedding_method="alpt",
    )
    return shrink(cfg, **overrides)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        attn_bias=True,
        mrope_sections=(2, 3, 3),
        input_mode="mixed",
        visual_prefix=8,
        embedding_method="alpt",
        ce_chunk=32,
        attn_q_block=32,
        attn_k_block=32,
    )
