"""hubert-xlarge [audio]: 48L d=1280 16H (kv=16) d_ff=5120 vocab=504.

Encoder-only transformer (same backbone as wav2vec2) — arXiv:2106.07447.
Per the pool spec the modality frontend is a STUB: input_specs() provides
precomputed frame embeddings [B, S, 1280]; training is frame-level CE over
the 504 cluster targets.  GELU MLP; deviation: RMSNorm instead of LayerNorm
(uniform backbone; DESIGN.md §7).  No decode shapes (encoder-only).
"""
from repro.models.transformer import ModelConfig
from repro.configs.common import shrink, ENCODER_DECODE_SKIP

SKIP_SHAPES = {
    "decode_32k": ENCODER_DECODE_SKIP,
    "long_500k": ENCODER_DECODE_SKIP,
}


def full_config(**overrides) -> ModelConfig:
    cfg = ModelConfig(
        name="hubert-xlarge",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        mlp_type="gelu",
        causal=False,
        input_mode="embeds",
        embedding_method="alpt",  # applies to the (tiny) 504-way output table
    )
    return shrink(cfg, **overrides)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=64,
        mlp_type="gelu",
        causal=False,
        input_mode="embeds",
        embedding_method="alpt",
        ce_chunk=32,
        attn_q_block=32,
        attn_k_block=32,
    )
