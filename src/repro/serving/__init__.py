"""`repro.serving` — the int8-resident serving subsystem.

One :class:`~repro.serving.engine.Engine` API (build from a checkpoint or
trainer state + ``EmbeddingSpec``; submit/poll requests; step the scheduler;
report metrics) with two scenario frontends sharing it:

* :mod:`repro.serving.lm` — slot-based continuous-batch LM prefill/decode;
* :mod:`repro.serving.ctr` — batched CTR request scoring.

For integer-table embedding methods the resident state is
:class:`~repro.serving.table.QuantTable` codes + scales — the fp32 table is
never materialized, in HBM or host memory (``Engine.resident_embedding_bytes``
is the int8 footprint ``benchmarks/serve_bench.py`` asserts).

The engine/frontends import the model and method layers, which themselves
import :mod:`repro.serving.table`; this ``__init__`` therefore loads only the
table types eagerly and resolves the engine classes lazily.
"""
from repro.serving import table  # noqa: F401

_LAZY = {
    "Engine": ("repro.serving.engine", "Engine"),
    "EngineMetrics": ("repro.serving.engine", "EngineMetrics"),
    "LMEngine": ("repro.serving.lm", "LMEngine"),
    "LMRequest": ("repro.serving.lm", "LMRequest"),
    "CTREngine": ("repro.serving.ctr", "CTREngine"),
    "CTRRequest": ("repro.serving.ctr", "CTRRequest"),
}

__all__ = ["table", *_LAZY]


def __getattr__(name: str):
    try:
        module, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(module), attr)
