"""CTR serving frontend: batched request scoring at fixed jit geometry.

The missing half of a CTR reproduction's deployment story: requests (one
[n_fields] categorical id vector each) are admitted in waves of up to
``batch``, padded to the fixed [batch, n_fields] geometry the jitted scorer
was traced at (pad rows repeat the first real request and their outputs are
discarded), and scored through the shared :func:`repro.models.ctr
.logits_from_rows` forward.

Embedding reads go straight off the int8 codes through ``ops.dequant_gather``
inside the jitted step — for integer-table methods the engine's resident
embedding bytes are the code bytes + scale vectors, nothing else.  Scores are
per-row independent, so a request's (logit, prob) is bitwise identical
whatever batch it lands in (the CTR determinism contract, tested in
tests/test_serve.py).

Tiered storage (``repro.storage``):

* ``cache_rows > 0`` composes a device hot-row cache over every cacheable
  sub-table (``serving_tbl.cache_slots``); per wave the policy observes the
  *real* requests' ids and applies admissions before scoring.  Cache-on is
  bitwise-equal to cache-off (serving is read-only, so the hot tier always
  mirrors the backing).
* ``cold_tier=True`` moves the code container to host memory entirely
  (:class:`repro.storage.cold.ColdStore`): the device holds the scale
  vector plus ``cache_rows`` hot rows, per-wave misses ride one
  ``device_put``, and the next wave's host gather is staged ahead (one-deep
  prefetch).  Serves tables larger than ``device_budget_bytes``.
"""
from __future__ import annotations

import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro import methods
from repro.models import ctr as ctr_models
from repro.obs.trace import tracer
from repro.serving import table as serving_tbl
from repro.serving.engine import CacheMetrics, Engine
from repro.storage.cold import ColdStore
from repro.storage.tiered import HotRowCache


@dataclasses.dataclass(frozen=True)
class CTRRequest:
    ids: np.ndarray  # [n_fields] int32 global feature ids
    rid: int | None = None


class CTREngine(Engine):
    scenario = "ctr"
    # _advance re-queues its popped wave on failure (see there), so the
    # engine-level bounded wave retry is safe to apply.
    _wave_retry_safe = True

    def __init__(self, dense_params, serving_table,
                 model_cfg, spec: methods.EmbeddingSpec, *, batch: int,
                 model: str = "dcn", cache_rows: int = 0,
                 cold_tier: bool = False,
                 device_budget_bytes: int | None = None):
        super().__init__(serving_table=serving_table, spec=spec)
        self.dense_params = dense_params
        self.model_cfg = model_cfg
        self.model = model
        self.batch = batch
        self.n_fields = model_cfg.n_fields
        self.cache_budget_bytes = device_budget_bytes
        self._caches: list = []  # [(CacheSlot, HotRowCache)]
        self._cold: ColdStore | None = None

        if cold_tier:
            if not isinstance(serving_table, serving_tbl.QuantTable):
                raise ValueError(
                    "cold_tier serves a plain QuantTable (single code "
                    f"container); got {type(serving_table).__name__}"
                )
            self._cold = ColdStore(
                serving_table.codes, serving_table.step,
                cache_rows=max(1, cache_rows),
            )
            self.prefetch_depth = 1
            d_live = serving_table.d
            n_fields = self.n_fields

            def score_cold(dense, rows_flat):
                rows = rows_flat[:, :d_live].reshape(batch, n_fields, d_live)
                rows = jax.lax.optimization_barrier(rows)
                logits = ctr_models.logits_from_rows(
                    dense, rows, model_cfg, model=model
                )
                return logits, jax.nn.sigmoid(logits)

            self._score_cold = jax.jit(score_cold)
            # The device never holds the code container in cold mode — the
            # ColdStore copied it to host memory above.
            self.table = None
            if device_budget_bytes is not None:
                if self._cold.device_bytes > device_budget_bytes:
                    raise ValueError(
                        f"cold-tier device bytes {self._cold.device_bytes} "
                        f"exceed budget {device_budget_bytes}"
                    )
            return

        if cache_rows > 0:
            table = self.table
            for slot in serving_tbl.cache_slots(table):
                sub = slot.get(table)
                cap = max(1, min(int(cache_rows), slot.rows))
                cache = HotRowCache(
                    cap, int(sub.codes.shape[0]), name=slot.name
                )
                tiered = cache.wrap(sub.codes)
                table = slot.put(
                    table, dataclasses.replace(sub, codes=tiered)
                )
                self._caches.append((slot, cache))
            self.table = table
            if device_budget_bytes is not None:
                hot = sum(
                    slot.get(self.table).codes.hot_bytes
                    + slot.get(self.table).codes.metadata_bytes
                    for slot, _ in self._caches
                )
                if hot > device_budget_bytes:
                    raise ValueError(
                        f"hot-tier bytes {hot} exceed cache budget "
                        f"{device_budget_bytes}"
                    )

        def score(table, dense, ids):
            rows = serving_tbl.rows(table, ids)
            # Materialize the rows interface: the dense forward compiles to
            # the same program whatever produced the rows (fused int8 gather
            # or an fp export), which is what makes the quant-vs-float parity
            # bitwise instead of fusion-dependent.
            rows = jax.lax.optimization_barrier(rows)
            logits = ctr_models.logits_from_rows(
                dense, rows, model_cfg, model=model
            )
            return logits, jax.nn.sigmoid(logits)

        self._score = jax.jit(score)

    # ------------------------------------------------------------ build

    @classmethod
    def from_state(cls, state, cfg, *, batch: int, cache_rows: int = 0,
                   cold_tier: bool = False,
                   device_budget_bytes: int | None = None) -> "CTREngine":
        """Build from a ``ctr_trainer.TrainState`` + its ``TrainerConfig``."""
        model_cfg = cfg.dcn if cfg.model == "dcn" else cfg.deepfm
        table = cls.build_serving_state(state.emb_state, cfg.spec)
        return cls(state.dense_params, table, model_cfg, cfg.spec,
                   batch=batch, model=cfg.model, cache_rows=cache_rows,
                   cold_tier=cold_tier,
                   device_budget_bytes=device_budget_bytes)

    @classmethod
    def from_checkpoint(cls, directory, cfg, dense_template, *,
                        batch: int, step: int | None = None,
                        cache_rows: int = 0, cold_tier: bool = False,
                        device_budget_bytes: int | None = None) -> "CTREngine":
        """Restore dense params + the serving-resident table from a serving
        checkpoint (int8 codes restore as int8, straight into residency)."""
        from repro.checkpoint import manager

        dense, table, _ = manager.restore_serving_checkpoint(
            directory, cfg.spec, dense_template, step=step
        )
        model_cfg = cfg.dcn if cfg.model == "dcn" else cfg.deepfm
        return cls(dense, table, model_cfg, cfg.spec, batch=batch,
                   model=cfg.model, cache_rows=cache_rows,
                   cold_tier=cold_tier,
                   device_budget_bytes=device_budget_bytes)

    # ------------------------------------------------------------ cache

    def warm_start(self, freqs) -> None:
        """Pre-admit the hottest rows from global id frequency counts (e.g.
        training-time statistics shipped alongside a serving checkpoint)."""
        freqs = np.asarray(freqs, np.int64).reshape(-1)
        if self._cold is not None:
            self._cold.warm_start(freqs)
            return
        ids = np.arange(freqs.size)
        for slot, cache in self._caches:
            local = np.asarray(slot.local_ids(ids), np.int64)
            ok = (local >= 0) & (local < cache.n_alloc)
            lf = np.zeros(cache.n_alloc, np.int64)
            np.add.at(lf, local[ok], freqs[ok])
            sub = slot.get(self.table)
            tiered = cache.warm_start(sub.codes, lf)
            self.table = slot.put(
                self.table, dataclasses.replace(sub, codes=tiered)
            )

    def _maintain_caches(self, real_ids: np.ndarray) -> None:
        """Run each slot's policy over the wave's *real* ids (padding repeats
        request 0 and would inflate hit counts) and apply admissions."""
        flat = real_ids.reshape(-1)
        for slot, cache in self._caches:
            moves = cache.observe(slot.local_ids(flat))
            if moves is None:
                continue
            sub = slot.get(self.table)
            tiered = cache.apply(sub.codes, moves)
            self.table = slot.put(
                self.table, dataclasses.replace(sub, codes=tiered)
            )

    def cache_metrics(self) -> tuple[CacheMetrics, ...]:
        if self._cold is not None:
            c = self._cold.cache
            return (CacheMetrics(
                tier="cold", name=c.name, capacity=c.capacity,
                rows_cached=c.rows_cached, hits=c.hits, misses=c.misses,
                evictions=c.evictions, writebacks=c.writebacks,
                hit_rate=c.hit_rate,
                hot_bytes=self._cold.hot_device_bytes,
                metadata_bytes=c.host_metadata_bytes,
                admission_oom=c.admission_oom,
                prefetch_dropped=self._cold.prefetch_dropped,
                corruption_detected=self._cold.corruption_detected,
            ),)
        out = []
        for slot, cache in self._caches:
            tiered = slot.get(self.table).codes
            out.append(CacheMetrics(
                tier="hot", name=cache.name, capacity=cache.capacity,
                rows_cached=cache.rows_cached, hits=cache.hits,
                misses=cache.misses, evictions=cache.evictions,
                writebacks=cache.writebacks, hit_rate=cache.hit_rate,
                hot_bytes=tiered.hot_bytes,
                metadata_bytes=tiered.metadata_bytes
                + cache.host_metadata_bytes,
                admission_oom=cache.admission_oom,
            ))
        return tuple(out)

    def _reset_cache_counters(self) -> None:
        if self._cold is not None:
            self._cold.reset_counters()
        for _, cache in self._caches:
            cache.reset_counters()

    def _tier_retry_stats(self):
        if self._cold is None:
            return []
        return [("cold", self._cold.retry_stats)]

    # ------------------------------------------------------------ metrics

    @property
    def resident_embedding_bytes(self) -> int:
        if self._cold is not None:
            return self._cold.device_bytes
        return super().resident_embedding_bytes

    @property
    def embedding_code_bytes(self) -> int:
        if self._cold is not None:
            return self._cold.hot_device_bytes
        return super().embedding_code_bytes

    @property
    def embedding_scale_bytes(self) -> int:
        if self._cold is not None:
            step = self._cold.step
            return int(step.size) * step.dtype.itemsize
        return super().embedding_scale_bytes

    @property
    def int8_resident(self) -> bool:
        if self._cold is not None:
            return True
        return super().int8_resident

    @property
    def cold_host_bytes(self) -> int:
        """Host bytes of the cold tier's code container (0 when warm)."""
        return self._cold.host_bytes if self._cold is not None else 0

    # ------------------------------------------------------------ scheduler

    def submit(self, request: CTRRequest) -> int:
        if np.shape(request.ids) != (self.n_fields,):
            raise ValueError(
                f"request ids shape {np.shape(request.ids)} != "
                f"({self.n_fields},)"
            )
        return super().submit(request)

    def _padded_wave_ids(self, reqs) -> np.ndarray:
        ids = np.zeros((self.batch, self.n_fields), np.int32)
        for i, req in enumerate(reqs):
            ids[i] = req.ids
        # Pad rows repeat request 0 (always in range); outputs discarded.
        ids[len(reqs):] = ids[0]
        return ids

    def _advance(self) -> None:
        wave = [
            self._queue.popleft()
            for _ in range(min(self.batch, len(self._queue)))
        ]
        try:
            self._score_wave(wave)
        except BaseException:
            # Re-queue the wave at the front so the engine's bounded wave
            # retry (or the caller) sees the same requests again — a
            # transient tier failure must not lose work.
            self._queue.extendleft(reversed(wave))
            raise

    def _score_wave(self, wave) -> None:
        ids = self._padded_wave_ids(wave)
        if self._cold is not None:
            self._cold.admit(ids[: len(wave)].reshape(-1))
            rows_flat = self._cold.rows(ids.reshape(-1))
            with tracer().span("engine.score", wave=len(wave), tier="cold"):
                logits, probs = self._score_cold(self.dense_params, rows_flat)
                tracer().fence(probs)
            # Stage the next wave's host gather while this wave finishes.
            nxt = list(itertools.islice(self._queue, self.batch))
            if nxt:
                self._cold.stage(self._padded_wave_ids(nxt).reshape(-1))
        else:
            self._maintain_caches(ids[: len(wave)])
            with tracer().span("engine.score", wave=len(wave)):
                logits, probs = self._score(
                    self.table, self.dense_params, jnp.asarray(ids)
                )
                tracer().fence(probs)
        logits = np.asarray(logits)
        probs = np.asarray(probs)
        for i, req in enumerate(wave):
            self._finish(
                req.rid, {"logit": float(logits[i]), "prob": float(probs[i])}
            )
