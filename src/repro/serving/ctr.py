"""CTR serving frontend: batched request scoring at fixed jit geometry.

The missing half of a CTR reproduction's deployment story: requests (one
[n_fields] categorical id vector each) are admitted in waves of up to
``batch``, padded to the fixed [batch, n_fields] geometry the jitted scorer
was traced at (pad rows repeat the first real request and their outputs are
discarded), and scored through the shared :func:`repro.models.ctr
.logits_from_rows` forward.

Embedding reads go straight off the int8 codes through ``ops.dequant_gather``
inside the jitted step — for integer-table methods the engine's resident
embedding bytes are the code bytes + scale vectors, nothing else.  Scores are
per-row independent, so a request's (logit, prob) is bitwise identical
whatever batch it lands in (the CTR determinism contract, tested in
tests/test_serve.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import methods
from repro.models import ctr as ctr_models
from repro.serving import table as serving_tbl
from repro.serving.engine import Engine


@dataclasses.dataclass(frozen=True)
class CTRRequest:
    ids: np.ndarray  # [n_fields] int32 global feature ids
    rid: int | None = None


class CTREngine(Engine):
    scenario = "ctr"

    def __init__(self, dense_params, serving_table,
                 model_cfg, spec: methods.EmbeddingSpec, *, batch: int,
                 model: str = "dcn"):
        super().__init__(serving_table=serving_table, spec=spec)
        self.dense_params = dense_params
        self.model_cfg = model_cfg
        self.model = model
        self.batch = batch
        self.n_fields = model_cfg.n_fields

        def score(table, dense, ids):
            rows = serving_tbl.rows(table, ids)
            # Materialize the rows interface: the dense forward compiles to
            # the same program whatever produced the rows (fused int8 gather
            # or an fp export), which is what makes the quant-vs-float parity
            # bitwise instead of fusion-dependent.
            rows = jax.lax.optimization_barrier(rows)
            logits = ctr_models.logits_from_rows(
                dense, rows, model_cfg, model=model
            )
            return logits, jax.nn.sigmoid(logits)

        self._score = jax.jit(score)

    # ------------------------------------------------------------ build

    @classmethod
    def from_state(cls, state, cfg, *, batch: int) -> "CTREngine":
        """Build from a ``ctr_trainer.TrainState`` + its ``TrainerConfig``."""
        model_cfg = cfg.dcn if cfg.model == "dcn" else cfg.deepfm
        table = cls.build_serving_state(state.emb_state, cfg.spec)
        return cls(state.dense_params, table, model_cfg, cfg.spec,
                   batch=batch, model=cfg.model)

    @classmethod
    def from_checkpoint(cls, directory, cfg, dense_template, *,
                        batch: int, step: int | None = None) -> "CTREngine":
        """Restore dense params + the serving-resident table from a serving
        checkpoint (int8 codes restore as int8, straight into residency)."""
        from repro.checkpoint import manager

        dense, table, _ = manager.restore_serving_checkpoint(
            directory, cfg.spec, dense_template, step=step
        )
        model_cfg = cfg.dcn if cfg.model == "dcn" else cfg.deepfm
        return cls(dense, table, model_cfg, cfg.spec, batch=batch,
                   model=cfg.model)

    # ------------------------------------------------------------ scheduler

    def submit(self, request: CTRRequest) -> int:
        if np.shape(request.ids) != (self.n_fields,):
            raise ValueError(
                f"request ids shape {np.shape(request.ids)} != "
                f"({self.n_fields},)"
            )
        return super().submit(request)

    def _advance(self) -> None:
        wave = [
            self._queue.popleft()
            for _ in range(min(self.batch, len(self._queue)))
        ]
        ids = np.zeros((self.batch, self.n_fields), np.int32)
        for i, req in enumerate(wave):
            ids[i] = req.ids
        # Pad rows repeat request 0 (always in range); outputs discarded.
        ids[len(wave):] = ids[0]
        logits, probs = self._score(
            self.table, self.dense_params, jnp.asarray(ids)
        )
        logits = np.asarray(logits)
        probs = np.asarray(probs)
        for i, req in enumerate(wave):
            self._finish(
                req.rid, {"logit": float(logits[i]), "prob": float(probs[i])}
            )
