"""The `Engine`: one serving API for every scenario frontend.

An Engine owns

* the **resident embedding state** — built once from a trainer state or a
  checkpoint via the method's ``serving_state`` capability.  For integer-
  table methods that is :class:`~repro.serving.table.QuantTable` codes +
  scales; the fp32 table is never materialized (``resident_embedding_bytes``
  is the int8 footprint the serve benchmark asserts);
* the **request lifecycle** — ``submit`` enqueues, ``step`` advances the
  scenario's scheduler by one unit of work, ``poll`` returns a finished
  request's result, ``run`` drains everything;
* the **metrics surface** — a typed :class:`EngineMetrics` snapshot
  (request/step/token counters, wall-clock, resident-bytes accounting,
  per-tier :class:`CacheMetrics`, and an accurate per-engine kernel fallback
  report).  ``metrics()`` returns the dataclass; its ``to_json()`` is the
  stable wire schema the serve CLI and benchmarks consume, and the dataclass
  doubles as a read-only mapping so ``m["key"]`` / ``m.get`` / ``{**m}``
  call sites keep working unchanged.

Scenario frontends subclass this: :class:`repro.serving.lm.LMEngine`
(slot-based continuous-batch prefill/decode) and
:class:`repro.serving.ctr.CTREngine` (fixed-geometry batched scoring).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

from repro import methods
from repro.faults import plan as faultplan
from repro.faults.recovery import RetryStats, retry_with_backoff
from repro.kernels import ops as kernel_ops
from repro.obs import counters as obs_counters
from repro.obs import stats as obs_stats
from repro.obs.trace import tracer
from repro.serving import table as serving_tbl

# Engine counters live in the repro.obs registry, labeled by scenario so
# mixed CTR+LM processes keep their tallies apart.  The registry is purely
# observational — nothing jitted reads it (the obs bitwise contract).
_REG = obs_counters.registry()
_MET_SUBMITTED = _REG.counter(
    "engine.requests_submitted", "requests enqueued", labels=("scenario",)
)
_MET_COMPLETED = _REG.counter(
    "engine.requests_completed", "requests finished", labels=("scenario",)
)
_MET_WAVES = _REG.counter(
    "engine.waves", "scheduler steps taken", labels=("scenario",)
)
_MET_DEADLINE = _REG.counter(
    "engine.deadline_misses", "waves over the per-wave deadline",
    labels=("scenario",),
)
_MET_DEGRADED = _REG.counter(
    "engine.served_degraded", "waves served degraded off the warm tier",
    labels=("scenario",),
)


def _publish_cache_metrics(caches) -> None:
    """Mirror per-tier cache snapshots into ``cache.*`` registry gauges."""
    for c in caches:
        for field in ("capacity", "rows_cached", "hits", "misses",
                      "evictions", "writebacks", "hit_rate", "hot_bytes",
                      "metadata_bytes", "admission_oom", "prefetch_dropped",
                      "corruption_detected"):
            _REG.gauge(
                f"cache.{field}", labels=("tier", "name")
            ).set(getattr(c, field), c.tier, c.name)


@dataclasses.dataclass
class _Counters:
    """Mutable per-engine counters (``EngineMetrics`` is the frozen view)."""

    requests_submitted: int = 0
    requests_completed: int = 0
    steps: int = 0
    tokens_generated: int = 0  # LM only
    wall_s: float = 0.0
    served_degraded: int = 0  # waves served off the warm tier (admission OOM)
    deadline_misses: int = 0  # waves exceeding the per-wave deadline


@dataclasses.dataclass(frozen=True)
class CacheMetrics:
    """One cache tier's snapshot (a hot-row cache slot or the cold tier)."""

    tier: str  # 'hot' (device hot-row cache) | 'cold' (host-backed)
    name: str  # slot name ('table', 'remainder', 'group0', ...)
    capacity: int  # rows the tier can hold
    rows_cached: int
    hits: int
    misses: int
    evictions: int
    writebacks: int
    hit_rate: float
    hot_bytes: int  # device bytes of the cached rows
    metadata_bytes: int  # id-map / recency / frequency bookkeeping bytes
    admission_oom: int = 0  # waves the tier refused on admission pressure
    prefetch_dropped: int = 0  # injected prefetch losses (demand re-fetched)
    corruption_detected: int = 0  # staged bytes failing crc verification

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class EngineMetrics:
    """Typed, immutable snapshot of one engine's serving metrics.

    ``to_json()`` is the stable schema: keys present in the pre-redesign
    ad-hoc dict keep their names and conditional presence (``us_per_request``
    only once requests completed; ``tokens_generated``/``us_per_token`` only
    for token-generating scenarios; cache keys only when caching is on).
    """

    scenario: str
    embedding_method: str
    requests_submitted: int
    requests_completed: int
    steps: int
    wall_s: float
    resident_embedding_bytes: int
    embedding_code_bytes: int
    embedding_scale_bytes: int
    int8_resident: bool
    kernel_fallbacks: int
    tokens_generated: int = 0
    caches: tuple[CacheMetrics, ...] = ()
    cache_hit_rate: float | None = None
    cache_budget_bytes: int | None = None
    prefetch_depth: int = 0
    served_degraded: int = 0
    deadline_misses: int = 0
    wave_retries: int = 0
    retry_failures: int = 0
    #: Streaming latency summaries ({"wave": {...}, "request": {...}}, each
    #: a StreamingQuantiles.to_json() with p50/p95/p99 in µs); None until a
    #: measured wave lands, so legacy consumers see no new key on idle
    #: engines.
    latency_us: dict | None = None

    def to_json(self) -> dict:
        out = {
            "scenario": self.scenario,
            "embedding_method": self.embedding_method,
            "requests_submitted": self.requests_submitted,
            "requests_completed": self.requests_completed,
            "steps": self.steps,
            "wall_s": self.wall_s,
            "resident_embedding_bytes": self.resident_embedding_bytes,
            "embedding_code_bytes": self.embedding_code_bytes,
            "embedding_scale_bytes": self.embedding_scale_bytes,
            "int8_resident": self.int8_resident,
            "kernel_fallbacks": self.kernel_fallbacks,
            "served_degraded": self.served_degraded,
            "deadline_misses": self.deadline_misses,
            "wave_retries": self.wave_retries,
            "retry_failures": self.retry_failures,
        }
        if self.requests_completed:
            out["us_per_request"] = (
                self.wall_s / self.requests_completed * 1e6
            )
        if self.tokens_generated:
            out["tokens_generated"] = self.tokens_generated
            out["us_per_token"] = self.wall_s / self.tokens_generated * 1e6
        if self.caches:
            out["caches"] = [c.to_json() for c in self.caches]
            out["cache_hit_rate"] = self.cache_hit_rate
            out["cache_budget_bytes"] = self.cache_budget_bytes
            out["prefetch_depth"] = self.prefetch_depth
        if self.latency_us is not None:
            out["latency_us"] = self.latency_us
        return out

    # --- read-only mapping shim (legacy consumers index / spread / .get) ---

    def keys(self):
        return self.to_json().keys()

    def __getitem__(self, key):
        return self.to_json()[key]

    def __iter__(self):
        return iter(self.to_json())

    def get(self, key, default=None):
        return self.to_json().get(key, default)


class Engine:
    """Shared serving core: resident table + queue + scheduler + metrics."""

    #: Scenario tag frontends set ('lm' | 'ctr'); shows up in metrics.
    scenario: str = "?"

    #: Frontends whose ``_advance`` re-queues its wave on failure (so a
    #: re-run sees the same requests) opt in to wave-level retry here.
    _wave_retry_safe: bool = False

    def __init__(self, *, serving_table, spec: methods.EmbeddingSpec):
        self.table = serving_table
        self.spec = spec
        self._queue: collections.deque = collections.deque()
        self._done: dict[int, Any] = {}
        self._next_rid = 0
        self._metrics = _Counters()
        #: Optional resident-bytes ceiling for the cache tiers (reported in
        #: metrics; frontends that enforce it raise at construction time).
        self.cache_budget_bytes: int | None = None
        #: How many waves ahead the cold tier stages host->device copies.
        self.prefetch_depth: int = 0
        #: Per-wave deadline (seconds): a wave whose wall time exceeds it
        #: ticks ``deadline_misses`` (traced compute cannot be aborted
        #: mid-flight, so the deadline is observed, not enforced).
        self.deadline_s: float | None = None
        #: Bounded retry budget for a wave that dies on a *transient* error
        #: (injected faults, cold-tier retry exhaustion, OS hiccups); the
        #: final failure always propagates loudly.
        self.wave_attempts: int = 2
        #: Wave-level retry counters (the per-tier fetch retries live on the
        #: cold store's own RetryStats).
        self.retry_stats = RetryStats()
        # One scope for the engine's lifetime: every jitted call site below
        # runs under it, so the report covers exactly this engine's dispatch.
        self._fallbacks = kernel_ops.FallbackScope()
        # Streaming latency percentiles (host wall-clock, µs): per scheduler
        # wave and per request submit→finish.  Pure host arithmetic — the
        # estimators never see device values.
        self._wave_latency = obs_stats.StreamingQuantiles()
        self._request_latency = obs_stats.StreamingQuantiles()
        self._submit_ns: dict[int, int] = {}

    # ------------------------------------------------------------ build

    @staticmethod
    def build_serving_state(table_state, spec: methods.EmbeddingSpec):
        """The method's serving-resident export for a trained table state."""
        return methods.get(spec.method).serving_state(table_state, spec)

    # ------------------------------------------------------------ requests

    def submit(self, request) -> int:
        """Enqueue one request; returns its rid (assigned if the request has
        ``rid=None``)."""
        rid = getattr(request, "rid", None)
        if rid is None:
            rid = self._next_rid
            request = dataclasses.replace(request, rid=rid)
        self._next_rid = max(self._next_rid, rid + 1)
        self._queue.append(request)
        self._metrics.requests_submitted += 1
        _MET_SUBMITTED.inc(1, self.scenario)
        self._submit_ns[rid] = time.perf_counter_ns()
        tracer().async_begin("engine.request", rid, scenario=self.scenario)
        return rid

    def poll(self, rid: int):
        """The finished result for ``rid``, or None while still in flight."""
        return self._done.get(rid)

    @property
    def pending(self) -> int:
        """Requests not yet finished (queued + in flight)."""
        return self._metrics.requests_submitted - self._metrics.requests_completed

    def step(self) -> bool:
        """Advance the scheduler by one unit of work.

        Returns True while there is (or was) work; False once idle.  All
        device work runs inside the engine's fallback scope so the metrics
        report every kernel fallback this engine's shapes hit.
        """
        if not self._has_work():
            return False
        # Degraded-wave detection is plan-gated: snapshotting cache metrics
        # per wave costs host work, so zero-fault serving skips it entirely.
        watch_oom = faultplan.lookup("cache.admission") is not None
        oom_before = self._admission_oom_total() if watch_oom else 0
        t0 = time.perf_counter()
        with tracer().span("engine.wave", scenario=self.scenario):
            with kernel_ops.fallback_scope(self._fallbacks):
                if (faultplan.active_plan() is None
                        or not self._wave_retry_safe):
                    self._advance()
                else:
                    # Chaos runs: one bounded retry budget around the wave;
                    # a re-run recomputes from the engine's host-side queues
                    # (the wave's device work is idempotent — outputs
                    # overwrite).
                    retry_with_backoff(
                        self._advance, op=f"{self.scenario}.wave",
                        attempts=self.wave_attempts, base_s=0.002,
                        stats=self.retry_stats,
                    )
        dt = time.perf_counter() - t0
        self._metrics.wall_s += dt
        self._metrics.steps += 1
        self._wave_latency.add(dt * 1e6)
        _MET_WAVES.inc(1, self.scenario)
        if self.deadline_s is not None and dt > self.deadline_s:
            self._metrics.deadline_misses += 1
            _MET_DEADLINE.inc(1, self.scenario)
        if watch_oom and self._admission_oom_total() > oom_before:
            self._metrics.served_degraded += 1
            _MET_DEGRADED.inc(1, self.scenario)
        return True

    def run(self) -> dict[int, Any]:
        """Drain the queue; returns {rid: result} for everything finished."""
        while self.step():
            pass
        return dict(self._done)

    # ------------------------------------------------------------ scenario

    def _has_work(self) -> bool:
        return bool(self._queue)

    def _advance(self) -> None:  # pragma: no cover - abstract
        raise NotImplementedError

    def _finish(self, rid: int, result) -> None:
        self._done[rid] = result
        self._metrics.requests_completed += 1
        _MET_COMPLETED.inc(1, self.scenario)
        t0 = self._submit_ns.pop(rid, None)
        if t0 is not None:
            self._request_latency.add((time.perf_counter_ns() - t0) / 1e3)
        tracer().async_end("engine.request", rid)

    # ------------------------------------------------------------ metrics

    @property
    def resident_embedding_bytes(self) -> int:
        """Bytes of embedding state this engine keeps resident — for
        integer-table methods: int8 code bytes + scale bytes (+ cache rows
        and id maps when a hot tier is composed in)."""
        return serving_tbl.resident_bytes(self.table)

    @property
    def embedding_code_bytes(self) -> int:
        return serving_tbl.code_bytes(self.table)

    @property
    def embedding_scale_bytes(self) -> int:
        return serving_tbl.scale_bytes(self.table)

    @property
    def int8_resident(self) -> bool:
        return serving_tbl.is_integer_resident(self.table)

    def cache_metrics(self) -> tuple[CacheMetrics, ...]:
        """Per-tier cache snapshots; () when no cache is composed in."""
        return ()

    def _admission_oom_total(self) -> int:
        return sum(c.admission_oom for c in self.cache_metrics())

    def _tier_retry_stats(self) -> list[tuple[str, RetryStats]]:
        """(name, RetryStats) per storage tier with a retried fetch path."""
        return []

    def health(self) -> dict:
        """Readiness report: is this engine fit to take traffic, and why.

        ``ready`` stays True through *recovered* degradation (warm-tier
        serving, retried fetches — outputs are still bitwise-correct) and
        drops only on conditions that lose work or violate the residency
        contract: exhausted retries or a blown cache budget.
        """
        retry_failures = self.retry_stats.failures + sum(
            s.failures for _, s in self._tier_retry_stats()
        )
        checks = {
            "int8_resident": self.int8_resident,
            "within_budget": (
                self.cache_budget_bytes is None
                or self.resident_embedding_bytes <= self.cache_budget_bytes
            ),
            "no_retry_exhaustion": retry_failures == 0,
        }
        return {
            "ready": all(checks.values()),
            "checks": checks,
            "queue_depth": self.pending,
            "served_degraded": self._metrics.served_degraded,
            "deadline_misses": self._metrics.deadline_misses,
            "wave_retries": self.retry_stats.retries,
            "kernel_fallbacks": self.fallback_report()["total_fallbacks"],
        }

    def fallback_report(self) -> dict:
        """Kernel-vs-fallback dispatch seen by THIS engine's call sites."""
        return self._fallbacks.stats()

    def _reset_cache_counters(self) -> None:
        """Frontends with cache tiers zero their traffic counters here."""

    def reset_metrics(self) -> None:
        """Zero the counters (benchmarks warm the jit traces, then measure).
        Finished results, cache *membership*, and the fallback report are
        kept; cache traffic counters restart with the measurement window."""
        self._metrics = _Counters()
        self.retry_stats = RetryStats()
        self._wave_latency = obs_stats.StreamingQuantiles()
        self._request_latency = obs_stats.StreamingQuantiles()
        self._reset_cache_counters()

    def metrics(self) -> EngineMetrics:
        m = self._metrics
        caches = self.cache_metrics()
        _publish_cache_metrics(caches)
        hit_rate = None
        if caches:
            hits = sum(c.hits for c in caches)
            total = hits + sum(c.misses for c in caches)
            hit_rate = hits / total if total else 0.0
        latency = None
        if self._wave_latency.count:
            latency = {"wave": self._wave_latency.to_json()}
            if self._request_latency.count:
                latency["request"] = self._request_latency.to_json()
        return EngineMetrics(
            scenario=self.scenario,
            embedding_method=self.spec.method,
            requests_submitted=m.requests_submitted,
            requests_completed=m.requests_completed,
            steps=m.steps,
            wall_s=m.wall_s,
            resident_embedding_bytes=self.resident_embedding_bytes,
            embedding_code_bytes=self.embedding_code_bytes,
            embedding_scale_bytes=self.embedding_scale_bytes,
            int8_resident=self.int8_resident,
            kernel_fallbacks=self.fallback_report()["total_fallbacks"],
            tokens_generated=m.tokens_generated,
            caches=caches,
            cache_hit_rate=hit_rate,
            cache_budget_bytes=self.cache_budget_bytes,
            prefetch_depth=self.prefetch_depth,
            served_degraded=m.served_degraded,
            deadline_misses=m.deadline_misses,
            wave_retries=self.retry_stats.retries,
            retry_failures=self.retry_stats.failures,
            latency_us=latency,
        )
