"""The `Engine`: one serving API for every scenario frontend.

An Engine owns

* the **resident embedding state** — built once from a trainer state or a
  checkpoint via the method's ``serving_state`` capability.  For integer-
  table methods that is :class:`~repro.serving.table.QuantTable` codes +
  scales; the fp32 table is never materialized (``resident_embedding_bytes``
  is the int8 footprint the serve benchmark asserts);
* the **request lifecycle** — ``submit`` enqueues, ``step`` advances the
  scenario's scheduler by one unit of work, ``poll`` returns a finished
  request's result, ``run`` drains everything;
* the **metrics surface** — request/step/token counters, wall-clock split by
  phase, the resident-bytes accounting, and an accurate per-engine kernel
  fallback report (``ops.fallback_scope`` wraps every jitted call site, so
  dispatch decisions are observed even when the process traced the same
  shapes before the engine existed — the bug the old serve CLI's
  reset-then-read dance admitted to).

Scenario frontends subclass this: :class:`repro.serving.lm.LMEngine`
(slot-based continuous-batch prefill/decode) and
:class:`repro.serving.ctr.CTREngine` (fixed-geometry batched scoring).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

from repro import methods
from repro.kernels import ops as kernel_ops
from repro.serving import table as serving_tbl


@dataclasses.dataclass
class EngineMetrics:
    """Mutable per-engine counters; ``Engine.metrics()`` renders the dict."""

    requests_submitted: int = 0
    requests_completed: int = 0
    steps: int = 0
    tokens_generated: int = 0  # LM only
    wall_s: float = 0.0


class Engine:
    """Shared serving core: resident table + queue + scheduler + metrics."""

    #: Scenario tag frontends set ('lm' | 'ctr'); shows up in metrics.
    scenario: str = "?"

    def __init__(self, *, serving_table, spec: methods.EmbeddingSpec):
        self.table = serving_table
        self.spec = spec
        self._queue: collections.deque = collections.deque()
        self._done: dict[int, Any] = {}
        self._next_rid = 0
        self._metrics = EngineMetrics()
        # One scope for the engine's lifetime: every jitted call site below
        # runs under it, so the report covers exactly this engine's dispatch.
        self._fallbacks = kernel_ops.FallbackScope()

    # ------------------------------------------------------------ build

    @staticmethod
    def build_serving_state(table_state, spec: methods.EmbeddingSpec):
        """The method's serving-resident export for a trained table state."""
        return methods.get(spec.method).serving_state(table_state, spec)

    # ------------------------------------------------------------ requests

    def submit(self, request) -> int:
        """Enqueue one request; returns its rid (assigned if the request has
        ``rid=None``)."""
        rid = getattr(request, "rid", None)
        if rid is None:
            rid = self._next_rid
            request = dataclasses.replace(request, rid=rid)
        self._next_rid = max(self._next_rid, rid + 1)
        self._queue.append(request)
        self._metrics.requests_submitted += 1
        return rid

    def poll(self, rid: int):
        """The finished result for ``rid``, or None while still in flight."""
        return self._done.get(rid)

    @property
    def pending(self) -> int:
        """Requests not yet finished (queued + in flight)."""
        return self._metrics.requests_submitted - self._metrics.requests_completed

    def step(self) -> bool:
        """Advance the scheduler by one unit of work.

        Returns True while there is (or was) work; False once idle.  All
        device work runs inside the engine's fallback scope so the metrics
        report every kernel fallback this engine's shapes hit.
        """
        if not self._has_work():
            return False
        t0 = time.perf_counter()
        with kernel_ops.fallback_scope(self._fallbacks):
            self._advance()
        self._metrics.wall_s += time.perf_counter() - t0
        self._metrics.steps += 1
        return True

    def run(self) -> dict[int, Any]:
        """Drain the queue; returns {rid: result} for everything finished."""
        while self.step():
            pass
        return dict(self._done)

    # ------------------------------------------------------------ scenario

    def _has_work(self) -> bool:
        return bool(self._queue)

    def _advance(self) -> None:  # pragma: no cover - abstract
        raise NotImplementedError

    def _finish(self, rid: int, result) -> None:
        self._done[rid] = result
        self._metrics.requests_completed += 1

    # ------------------------------------------------------------ metrics

    @property
    def resident_embedding_bytes(self) -> int:
        """Bytes of embedding state this engine keeps resident — for
        integer-table methods: int8 code bytes + scale bytes, nothing else."""
        return serving_tbl.resident_bytes(self.table)

    @property
    def int8_resident(self) -> bool:
        return serving_tbl.is_integer_resident(self.table)

    def fallback_report(self) -> dict:
        """Kernel-vs-fallback dispatch seen by THIS engine's call sites."""
        return self._fallbacks.stats()

    def reset_metrics(self) -> None:
        """Zero the counters (benchmarks warm the jit traces, then measure).
        Finished results and the fallback report are kept."""
        self._metrics = EngineMetrics()

    def metrics(self) -> dict:
        m = self._metrics
        out = {
            "scenario": self.scenario,
            "embedding_method": self.spec.method,
            "requests_submitted": m.requests_submitted,
            "requests_completed": m.requests_completed,
            "steps": m.steps,
            "wall_s": m.wall_s,
            "resident_embedding_bytes": self.resident_embedding_bytes,
            "embedding_code_bytes": serving_tbl.code_bytes(self.table),
            "embedding_scale_bytes": serving_tbl.scale_bytes(self.table),
            "int8_resident": self.int8_resident,
            "kernel_fallbacks": self.fallback_report()["total_fallbacks"],
        }
        if m.requests_completed:
            out["us_per_request"] = m.wall_s / m.requests_completed * 1e6
        if m.tokens_generated:
            out["tokens_generated"] = m.tokens_generated
            out["us_per_token"] = m.wall_s / m.tokens_generated * 1e6
        return out
