"""LM serving frontend: slot-based continuous-batch prefill/decode.

The production decode shape, re-homed from the old ``launch/serve.py`` loop
and upgraded from wave admission to real slot refill:

* one jitted ``prefill`` per prompt length (batch=1 — exact length, so the
  result is bitwise independent of whatever else is in flight, and recurrent
  (SSM) layers see no padding),
* one jitted ``decode_step`` over the fixed slot batch (cache donated
  in/out) with a **per-slot** ``cache_len`` vector, so a freshly refilled
  slot decodes next to slots deep into generation without recompiling,
* finished slots are refilled from the queue immediately — no wave barrier.

Per-request determinism (the slot-refill contract, tested in
tests/test_serve.py): every per-row op in the decode step is independent of
the other rows, and prefill is per-request, so a request's tokens are
bitwise identical whatever the arrival order or slot assignment.

The embedding table stays int8-resident end-to-end: slot embeds read through
``ops.dequant_gather`` and the tied head contracts through
``ops.dequant_matmul`` inside the jitted steps (see repro.serving.table).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import methods
from repro.models import transformer as tfm
from repro.obs.trace import tracer
from repro.serving.engine import Engine


@dataclasses.dataclass(frozen=True)
class LMRequest:
    prompt: np.ndarray  # [T] int32 token ids
    max_new: int
    rid: int | None = None


class LMEngine(Engine):
    scenario = "lm"

    def __init__(self, params, serving_table, cfg: tfm.ModelConfig,
                 spec: methods.EmbeddingSpec, *, batch: int, max_len: int):
        if cfg.input_mode == "embeds":
            raise ValueError(
                f"{cfg.name}: encoder-only archs have no decode path"
            )
        super().__init__(serving_table=serving_table, spec=spec)
        self.params = params
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self._prefill = jax.jit(
            functools.partial(tfm.prefill, cfg=cfg, max_len=max_len)
        )
        self._decode = jax.jit(
            functools.partial(tfm.decode_step, cfg=cfg), donate_argnums=(3,)
        )
        self._insert = jax.jit(self._insert_fn, donate_argnums=(0,))
        # Device state: the slot cache + per-slot current token / length.
        self._cache = tfm.init_cache(cfg, batch, max_len)
        self._cur = np.zeros((batch,), np.int32)
        self._cache_len = np.zeros((batch,), np.int32)
        # Host state per slot.
        self._slot_rid: list[int | None] = [None] * batch
        self._slot_budget = [0] * batch
        self._slot_out: list[list[int]] = [[] for _ in range(batch)]

    @staticmethod
    def _insert_fn(cache, cache_one, slot):
        """Copy a batch-1 prefilled cache into batch slot ``slot``; every
        cache leaf is laid out [groups, batch, ...]."""
        return jax.tree.map(
            lambda full, one: full.at[:, slot].set(one[:, 0]), cache, cache_one
        )

    # ------------------------------------------------------------ build

    @classmethod
    def from_state(cls, state, cfg: tfm.ModelConfig, tcfg=None, *,
                   batch: int, max_len: int) -> "LMEngine":
        """Build from a live ``lm_trainer.LMTrainState``."""
        from repro.training import lm_trainer

        spec = lm_trainer.embedding_spec_of(cfg, tcfg)
        table = cls.build_serving_state(state.table, spec)
        return cls(state.params, table, cfg, spec, batch=batch, max_len=max_len)

    @classmethod
    def from_checkpoint(cls, directory, cfg: tfm.ModelConfig, tcfg=None, *,
                        batch: int, max_len: int, step: int | None = None
                        ) -> "LMEngine":
        """Restore params + table from a serving checkpoint
        (``checkpoint.manager.save_serving_checkpoint``); the artifact holds
        the serving-resident state itself, so int8 codes restore as int8 and
        go straight into residency — no fp32 detour, no training leaves."""
        from repro.checkpoint import manager
        from repro.training import lm_trainer

        spec = lm_trainer.embedding_spec_of(cfg, tcfg)
        params_template = jax.eval_shape(
            functools.partial(tfm.init_params, cfg=cfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
        params, table, _ = manager.restore_serving_checkpoint(
            directory, spec, params_template, step=step
        )
        return cls(params, table, cfg, spec, batch=batch, max_len=max_len)

    # ------------------------------------------------------------ scheduler

    def submit(self, request: LMRequest) -> int:
        if len(request.prompt) + request.max_new > self.max_len + 1:
            raise ValueError(
                f"prompt {len(request.prompt)} + max_new {request.max_new} "
                f"exceeds engine max_len {self.max_len}"
            )
        return super().submit(request)

    def _has_work(self) -> bool:
        return bool(self._queue) or any(
            rid is not None for rid in self._slot_rid
        )

    def _free_slots(self):
        return [i for i, rid in enumerate(self._slot_rid) if rid is None]

    def _admit(self) -> None:
        """Refill free slots from the queue: per-request exact-length prefill
        (its own jit trace per distinct length), cache spliced into the slot."""
        free = self._free_slots()
        while free and self._queue:
            req = self._queue.popleft()
            if req.max_new <= 0:
                self._finish(req.rid, [])  # zero generation budget
                continue
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            with tracer().span("engine.prefill", rid=req.rid,
                               prompt_len=len(req.prompt)):
                logits, cache_one = self._prefill(
                    self.params, self.table, prompt
                )
                tracer().fence(logits)
            first = int(jnp.argmax(logits[0]))
            self._metrics.tokens_generated += 1
            if req.max_new <= 1:
                self._finish(req.rid, [first])  # done at prefill; no slot used
                continue
            slot = free.pop(0)
            self._cache = self._insert(
                self._cache, cache_one, jnp.asarray(slot, jnp.int32)
            )
            self._slot_rid[slot] = req.rid
            self._slot_budget[slot] = req.max_new
            self._slot_out[slot] = [first]
            self._cur[slot] = first
            self._cache_len[slot] = len(req.prompt)

    def _advance(self) -> None:
        self._admit()
        active = [i for i, rid in enumerate(self._slot_rid) if rid is not None]
        if not active:
            return
        with tracer().span("engine.decode", active=len(active)):
            logits, self._cache = self._decode(
                self.params, self.table, jnp.asarray(self._cur),
                self._cache, jnp.asarray(self._cache_len),
            )
            tracer().fence(logits)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self._cache_len += 1
        for slot in active:
            self._cur[slot] = nxt[slot]
            self._slot_out[slot].append(int(nxt[slot]))
            self._metrics.tokens_generated += 1
            if len(self._slot_out[slot]) >= self._slot_budget[slot]:
                self._finish(self._slot_rid[slot], self._slot_out[slot])
                self._slot_rid[slot] = None
                self._slot_out[slot] = []
