"""Serving-resident embedding tables: the codes+scales the Engine keeps live.

The training side answers "how do embeddings *learn* in low precision"; this
module answers what a serving process actually holds in memory.  Three
resident forms, all registered jax pytrees so they flow through jitted
prefill/decode/score steps:

* :class:`QuantTable` — int8 codes + per-row Delta (LPT/ALPT tables, and the
  int8 export of the QAT baselines).  Row reads run through the fused
  ``ops.dequant_gather`` and the tied LM head through ``ops.dequant_matmul``;
  the fp32 table **never exists** — not in HBM, not in host memory.
* :class:`QRQuantTable` — two :class:`QuantTable` sub-tables composed by the
  quotient-remainder product (qr_lpt / qr_alpt), each with its own learned
  scale vector.
* :class:`FloatTable` — the fp32 export for float-leaf methods (fp, hash,
  prune); also the reference the int8-resident parity tests compare against.

Redesigned surface: each table class implements the protocol methods
``rows`` / ``head_logits`` / ``code_bytes`` / ``scale_bytes`` /
``live_rows`` / ``cache_slots`` itself; the module-level functions of the
same names are now *only* the raw-``jax.Array`` boundary (they reproduce the
historical fp paths bitwise and otherwise delegate to the table).  Adding a
resident form no longer grows an isinstance chain per call site.
``cache_slots`` is the hot-row-cache hook: it names each cacheable
:class:`QuantTable` inside a composed table as a
:class:`repro.storage.base.CacheSlot`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.storage import base as rowstore


def _einsum_head(w: jax.Array, h: jax.Array) -> jax.Array:
    """The reference tied-head contraction over a dense fp table."""
    return jnp.einsum("...d,vd->...v", h.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class FloatTable:
    """fp32-resident [n, d] table (float-leaf methods' serving export)."""

    table: jax.Array

    def rows(self, ids: jax.Array) -> jax.Array:
        return jnp.take(self.table, ids, axis=0)

    def head_logits(self, h: jax.Array) -> jax.Array:
        return _einsum_head(self.table, h)

    def code_bytes(self) -> int:
        return 0

    def scale_bytes(self) -> int:
        return 0

    def live_rows(self) -> int:
        return int(self.table.shape[0])

    def cache_slots(self) -> tuple[rowstore.CacheSlot, ...]:
        return ()


@dataclasses.dataclass(frozen=True)
class QuantTable:
    """Integer-resident table: codes [N, D] + per-row scale [N].

    ``codes`` is a raw int8 array, a
    :class:`repro.core.codestore.CodeStore` — sub-byte widths arrive packed
    (2 or 4 codes per resident byte) and stay packed; the fused kernels
    unpack tiles in VMEM — or a :class:`repro.storage.tiered.TieredCodes`
    overlaying a device-resident hot-row cache on either.  ``n``/``d`` are
    the *live* geometry (``pad_to_tiles`` allocates N >= n, D >= d so real
    tables hit the kernel path); they are static pytree aux data, so jitted
    consumers slice with concrete bounds.
    """

    codes: object  # CodeStore | TieredCodes | jax.Array, [N_alloc, D_alloc]
    step: jax.Array  # f32 [N_alloc]
    n: int  # live id space (ids must be < n)
    d: int  # live embedding width
    use_kernels: bool = True

    def rows(self, ids: jax.Array) -> jax.Array:
        flat = ids.reshape(-1)
        out = ops.dequant_gather(
            self.codes, self.step, flat, use_kernel=self.use_kernels
        )
        out = out.reshape(ids.shape + (self.codes.shape[1],))
        if self.d != out.shape[-1]:
            out = out[..., : self.d]
        return out

    def head_logits(self, h: jax.Array) -> jax.Array:
        lead = h.shape[:-1]
        h2 = h.reshape(-1, h.shape[-1]).astype(jnp.float32)
        d_alloc = self.codes.shape[1]
        if h2.shape[-1] != d_alloc:
            # Padded columns hold codes for dims the model never writes;
            # zero activations there keep the contraction exact.
            h2 = jnp.pad(h2, ((0, 0), (0, d_alloc - h2.shape[-1])))
        logits = ops.dequant_matmul(
            h2, self.codes, self.step, use_kernel=self.use_kernels
        )
        if self.n != logits.shape[-1]:
            logits = logits[:, : self.n]
        return logits.reshape(lead + (self.n,)).astype(jnp.float32)

    def code_bytes(self) -> int:
        return rowstore.resident_bytes_of(self.codes)

    def scale_bytes(self) -> int:
        return int(self.step.size) * self.step.dtype.itemsize

    def live_rows(self) -> int:
        return self.n

    def cache_slots(self) -> tuple[rowstore.CacheSlot, ...]:
        return (rowstore.CacheSlot(
            name="table", rows=self.n,
            get=lambda t: t,
            put=lambda t, sub: sub,
            local_ids=lambda ids: np.asarray(ids),
        ),)


@dataclasses.dataclass(frozen=True)
class QRQuantTable:
    """Quotient-remainder composition of two int8-resident sub-tables.

    Virtual row ``i`` is ``remainder[i % r] * quotient[i // r]`` — each
    sub-table carries its own learned per-row Delta (the qr_alpt serving
    contract: both scale vectors are honored independently)."""

    remainder: QuantTable
    quotient: QuantTable
    r: int  # static remainder modulus
    n: int
    d: int

    def rows(self, ids: jax.Array) -> jax.Array:
        return self.remainder.rows(ids % self.r) * self.quotient.rows(
            ids // self.r
        )

    def head_logits(self, h: jax.Array) -> jax.Array:
        # The QR product head is not a single matmul over codes; the virtual
        # rows are composed from the two fused gathers per step (transient
        # [n, d] — resident state stays int8).  A decomposed contraction
        # (einsum('bd,qd,rd->bqr') over the two small sub-tables) would avoid
        # the transient entirely but re-associates the product and breaks
        # bitwise parity with the fp-exported table — the parity contract
        # wins here; the decomposed head is a ROADMAP follow-up.
        return _einsum_head(self.rows(jnp.arange(self.n)), h)

    def code_bytes(self) -> int:
        return self.remainder.code_bytes() + self.quotient.code_bytes()

    def scale_bytes(self) -> int:
        return self.remainder.scale_bytes() + self.quotient.scale_bytes()

    def live_rows(self) -> int:
        return self.n

    def cache_slots(self) -> tuple[rowstore.CacheSlot, ...]:
        r = self.r
        return (
            rowstore.CacheSlot(
                name="remainder", rows=self.remainder.n,
                get=lambda t: t.remainder,
                put=lambda t, sub: dataclasses.replace(t, remainder=sub),
                local_ids=lambda ids: np.asarray(ids) % r,
            ),
            rowstore.CacheSlot(
                name="quotient", rows=self.quotient.n,
                get=lambda t: t.quotient,
                put=lambda t, sub: dataclasses.replace(t, quotient=sub),
                local_ids=lambda ids: np.asarray(ids) // r,
            ),
        )


@dataclasses.dataclass(frozen=True)
class MixedQuantTable:
    """Per-field mixed-precision composition of integer-resident sub-tables.

    Fields are partitioned into groups by bit width; group ``g`` holds one
    :class:`QuantTable` stacking the rows of every field assigned to it.
    Global id ``i`` belongs to field ``f`` (via the static ``field_offsets``
    fence-posts) and resolves to row ``i - field_offsets[f] +
    field_local[f]`` of sub-table ``field_group[f]``.  The field maps are
    tiny static tuples (one entry per *field*, not per row), so the id→row
    arithmetic constant-folds inside jit.
    """

    subs: tuple[QuantTable, ...]
    field_offsets: tuple[int, ...]  # [F] global start row per field
    field_group: tuple[int, ...]  # [F] sub-table index per field
    field_local: tuple[int, ...]  # [F] local start row inside the sub
    n: int
    d: int

    def rows(self, ids: jax.Array) -> jax.Array:
        offs = jnp.asarray(self.field_offsets, jnp.int32)
        fid = jnp.searchsorted(offs, ids.astype(jnp.int32), side="right") - 1
        local = (
            ids.astype(jnp.int32)
            - jnp.take(offs, fid)
            + jnp.take(jnp.asarray(self.field_local, jnp.int32), fid)
        )
        gid = jnp.take(jnp.asarray(self.field_group, jnp.int32), fid)
        # Masked sum over the sub-tables — identical composition (group
        # order, where/sum placement) to the training-side mixed lookup, so
        # serving reads stay bitwise-parity with training.
        out = jnp.zeros(ids.shape + (self.d,), jnp.float32)
        for g, sub in enumerate(self.subs):
            mask = gid == g
            vals = sub.rows(jnp.where(mask, local, 0))
            out = out + jnp.where(mask[..., None], vals, 0.0)
        return out

    def head_logits(self, h: jax.Array) -> jax.Array:
        # Same trade-off as the QR head: compose the virtual rows through the
        # per-group fused gathers (transient [n, d]; resident state stays
        # packed integer) so the contraction is bitwise-parity with the
        # fp-exported table.
        return _einsum_head(self.rows(jnp.arange(self.n)), h)

    def code_bytes(self) -> int:
        return sum(sub.code_bytes() for sub in self.subs)

    def scale_bytes(self) -> int:
        return sum(sub.scale_bytes() for sub in self.subs)

    def live_rows(self) -> int:
        return self.n

    def cache_slots(self) -> tuple[rowstore.CacheSlot, ...]:
        starts = np.asarray(self.field_offsets, np.int64)
        group = np.asarray(self.field_group, np.int64)
        local = np.asarray(self.field_local, np.int64)

        def make_local(g):
            def f(ids):
                ids = np.asarray(ids, np.int64)
                fid = np.searchsorted(starts, ids, side="right") - 1
                loc = ids - starts[fid] + local[fid]
                return np.where(group[fid] == g, loc, -1)

            return f

        def make_put(g):
            def put(t, sub):
                subs = t.subs[:g] + (sub,) + t.subs[g + 1:]
                return dataclasses.replace(t, subs=subs)

            return put

        return tuple(
            rowstore.CacheSlot(
                name=f"group{g}", rows=sub.n,
                get=(lambda g: lambda t: t.subs[g])(g),
                put=make_put(g),
                local_ids=make_local(g),
            )
            for g, sub in enumerate(self.subs)
        )


jax.tree_util.register_pytree_node(
    FloatTable,
    lambda t: ((t.table,), None),
    lambda aux, ch: FloatTable(*ch),
)
jax.tree_util.register_pytree_node(
    QuantTable,
    lambda t: ((t.codes, t.step), (t.n, t.d, t.use_kernels)),
    lambda aux, ch: QuantTable(ch[0], ch[1], *aux),
)
jax.tree_util.register_pytree_node(
    QRQuantTable,
    lambda t: ((t.remainder, t.quotient), (t.r, t.n, t.d)),
    lambda aux, ch: QRQuantTable(ch[0], ch[1], *aux),
)
jax.tree_util.register_pytree_node(
    MixedQuantTable,
    lambda t: (
        (t.subs,),
        (t.field_offsets, t.field_group, t.field_local, t.n, t.d),
    ),
    lambda aux, ch: MixedQuantTable(ch[0], *aux),
)

ServingTable = FloatTable | QuantTable | QRQuantTable | MixedQuantTable


def is_serving_table(table) -> bool:
    return isinstance(
        table, (FloatTable, QuantTable, QRQuantTable, MixedQuantTable)
    )


def is_integer_resident(table) -> bool:
    """True when the resident bytes are integer codes (+ scales), not fp32."""
    return isinstance(table, (QuantTable, QRQuantTable, MixedQuantTable))


def resident_bytes(table) -> int:
    """Bytes the table keeps resident (the serve_bench int8 assertion).

    Counted over the pytree leaves, so a tiered table's hot rows and id-map
    arrays are included automatically — the cache is resident state, not
    free metadata.
    """
    if isinstance(table, jax.Array):
        return int(table.size) * table.dtype.itemsize
    return int(sum(
        leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(table)
    ))


def code_bytes(table) -> int:
    """The integer-code footprint alone (excludes the scale vectors).

    Container-actual: a packed :class:`~repro.core.codestore.CodeStore`
    counts its resident bytes (``ceil(d * bits / 8)`` per row), not one byte
    per logical code.
    """
    return table.code_bytes() if is_serving_table(table) else 0


def scale_bytes(table) -> int:
    return table.scale_bytes() if is_serving_table(table) else 0


def n_rows(table) -> int:
    """Live id space of the table."""
    if is_serving_table(table):
        return table.live_rows()
    return int(table.shape[0])


def cache_slots(table) -> tuple[rowstore.CacheSlot, ...]:
    """The cacheable :class:`QuantTable` slots inside a serving table."""
    return table.cache_slots() if is_serving_table(table) else ()


def rows(table, ids: jax.Array) -> jax.Array:
    """De-quantized rows for ``ids`` (any leading shape) -> f32 [..., d].

    int8-resident tables read through the fused gather+dequantize kernel
    (1 byte/element off HBM); raw arrays / FloatTable reproduce the
    historical ``jnp.take`` bitwise.
    """
    if is_serving_table(table):
        return table.rows(ids)
    return jnp.take(table, ids, axis=0)


def head_logits(table, h: jax.Array) -> jax.Array:
    """Tied-head contraction ``h [..., d] -> logits [..., n]`` (f32).

    int8-resident tables contract through ``ops.dequant_matmul`` — weight
    tiles are de-quantized in VMEM right before the MXU, so the head costs
    1 byte/weight of HBM traffic and the fp32 table never materializes.
    Bitwise-equal to the einsum over the de-quantized table (the pre-redesign
    fp-exported path).
    """
    if is_serving_table(table):
        return table.head_logits(h)
    return _einsum_head(table, h)
