"""Serving-resident embedding tables: the codes+scales the Engine keeps live.

The training side answers "how do embeddings *learn* in low precision"; this
module answers what a serving process actually holds in memory.  Three
resident forms, all registered jax pytrees so they flow through jitted
prefill/decode/score steps:

* :class:`QuantTable` — int8 codes + per-row Delta (LPT/ALPT tables, and the
  int8 export of the QAT baselines).  Row reads run through the fused
  ``ops.dequant_gather`` and the tied LM head through ``ops.dequant_matmul``;
  the fp32 table **never exists** — not in HBM, not in host memory.
* :class:`QRQuantTable` — two :class:`QuantTable` sub-tables composed by the
  quotient-remainder product (qr_lpt / qr_alpt), each with its own learned
  scale vector.
* :class:`FloatTable` — the fp32 export for float-leaf methods (fp, hash,
  prune); also the reference the int8-resident parity tests compare against.

``rows`` / ``head_logits`` also accept a raw ``jax.Array`` table and then
reproduce the historical fp paths bitwise, so the model code
(:mod:`repro.models.transformer`, :mod:`repro.models.ctr`) calls one function
for training, eval, and serving.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import codestore
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class FloatTable:
    """fp32-resident [n, d] table (float-leaf methods' serving export)."""

    table: jax.Array


@dataclasses.dataclass(frozen=True)
class QuantTable:
    """Integer-resident table: codes [N, D] + per-row scale [N].

    ``codes`` is either a raw int8 array or a
    :class:`repro.core.codestore.CodeStore` — sub-byte widths arrive packed
    (2 or 4 codes per resident byte) and stay packed; the fused kernels
    unpack tiles in VMEM.  ``n``/``d`` are the *live* geometry
    (``pad_to_tiles`` allocates N >= n, D >= d so real tables hit the kernel
    path); they are static pytree aux data, so jitted consumers slice with
    concrete bounds.
    """

    codes: codestore.CodeStore | jax.Array  # [N_alloc, D_alloc] logical
    step: jax.Array  # f32 [N_alloc]
    n: int  # live id space (ids must be < n)
    d: int  # live embedding width
    use_kernels: bool = True


@dataclasses.dataclass(frozen=True)
class QRQuantTable:
    """Quotient-remainder composition of two int8-resident sub-tables.

    Virtual row ``i`` is ``remainder[i % r] * quotient[i // r]`` — each
    sub-table carries its own learned per-row Delta (the qr_alpt serving
    contract: both scale vectors are honored independently)."""

    remainder: QuantTable
    quotient: QuantTable
    r: int  # static remainder modulus
    n: int
    d: int


@dataclasses.dataclass(frozen=True)
class MixedQuantTable:
    """Per-field mixed-precision composition of integer-resident sub-tables.

    Fields are partitioned into groups by bit width; group ``g`` holds one
    :class:`QuantTable` stacking the rows of every field assigned to it.
    Global id ``i`` belongs to field ``f`` (via the static ``field_offsets``
    fence-posts) and resolves to row ``i - field_offsets[f] +
    field_local[f]`` of sub-table ``field_group[f]``.  The field maps are
    tiny static tuples (one entry per *field*, not per row), so the id→row
    arithmetic constant-folds inside jit.
    """

    subs: tuple[QuantTable, ...]
    field_offsets: tuple[int, ...]  # [F] global start row per field
    field_group: tuple[int, ...]  # [F] sub-table index per field
    field_local: tuple[int, ...]  # [F] local start row inside the sub
    n: int
    d: int


jax.tree_util.register_pytree_node(
    FloatTable,
    lambda t: ((t.table,), None),
    lambda aux, ch: FloatTable(*ch),
)
jax.tree_util.register_pytree_node(
    QuantTable,
    lambda t: ((t.codes, t.step), (t.n, t.d, t.use_kernels)),
    lambda aux, ch: QuantTable(ch[0], ch[1], *aux),
)
jax.tree_util.register_pytree_node(
    QRQuantTable,
    lambda t: ((t.remainder, t.quotient), (t.r, t.n, t.d)),
    lambda aux, ch: QRQuantTable(ch[0], ch[1], *aux),
)
jax.tree_util.register_pytree_node(
    MixedQuantTable,
    lambda t: (
        (t.subs,),
        (t.field_offsets, t.field_group, t.field_local, t.n, t.d),
    ),
    lambda aux, ch: MixedQuantTable(ch[0], *aux),
)

ServingTable = FloatTable | QuantTable | QRQuantTable | MixedQuantTable


def is_serving_table(table) -> bool:
    return isinstance(
        table, (FloatTable, QuantTable, QRQuantTable, MixedQuantTable)
    )


def is_integer_resident(table) -> bool:
    """True when the resident bytes are integer codes (+ scales), not fp32."""
    return isinstance(table, (QuantTable, QRQuantTable, MixedQuantTable))


def resident_bytes(table) -> int:
    """Bytes the table keeps resident (the serve_bench int8 assertion)."""
    if isinstance(table, jax.Array):
        return int(table.size) * table.dtype.itemsize
    return int(sum(
        leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(table)
    ))


def code_bytes(table) -> int:
    """The integer-code footprint alone (excludes the scale vectors).

    Container-actual: a packed :class:`~repro.core.codestore.CodeStore`
    counts its resident bytes (``ceil(d * bits / 8)`` per row), not one byte
    per logical code.
    """
    if isinstance(table, QuantTable):
        return codestore.resident_bytes_of(table.codes)
    if isinstance(table, QRQuantTable):
        return code_bytes(table.remainder) + code_bytes(table.quotient)
    if isinstance(table, MixedQuantTable):
        return sum(code_bytes(sub) for sub in table.subs)
    return 0


def scale_bytes(table) -> int:
    if isinstance(table, QuantTable):
        return int(table.step.size) * table.step.dtype.itemsize
    if isinstance(table, QRQuantTable):
        return scale_bytes(table.remainder) + scale_bytes(table.quotient)
    if isinstance(table, MixedQuantTable):
        return sum(scale_bytes(sub) for sub in table.subs)
    return 0


def n_rows(table) -> int:
    """Live id space of the table."""
    if isinstance(table, jax.Array):
        return int(table.shape[0])
    if isinstance(table, FloatTable):
        return int(table.table.shape[0])
    return table.n


def rows(table, ids: jax.Array) -> jax.Array:
    """De-quantized rows for ``ids`` (any leading shape) -> f32 [..., d].

    int8-resident tables read through the fused gather+dequantize kernel
    (1 byte/element off HBM); raw arrays / FloatTable reproduce the
    historical ``jnp.take`` bitwise.
    """
    if isinstance(table, FloatTable):
        return jnp.take(table.table, ids, axis=0)
    if isinstance(table, QuantTable):
        flat = ids.reshape(-1)
        out = ops.dequant_gather(
            table.codes, table.step, flat, use_kernel=table.use_kernels
        )
        out = out.reshape(ids.shape + (table.codes.shape[1],))
        if table.d != out.shape[-1]:
            out = out[..., : table.d]
        return out
    if isinstance(table, QRQuantTable):
        return rows(table.remainder, ids % table.r) * rows(
            table.quotient, ids // table.r
        )
    if isinstance(table, MixedQuantTable):
        offs = jnp.asarray(table.field_offsets, jnp.int32)
        fid = jnp.searchsorted(offs, ids.astype(jnp.int32), side="right") - 1
        local = (
            ids.astype(jnp.int32)
            - jnp.take(offs, fid)
            + jnp.take(jnp.asarray(table.field_local, jnp.int32), fid)
        )
        gid = jnp.take(jnp.asarray(table.field_group, jnp.int32), fid)
        # Masked sum over the sub-tables — identical composition (group
        # order, where/sum placement) to the training-side mixed lookup, so
        # serving reads stay bitwise-parity with training.
        out = jnp.zeros(ids.shape + (table.d,), jnp.float32)
        for g, sub in enumerate(table.subs):
            mask = gid == g
            vals = rows(sub, jnp.where(mask, local, 0))
            out = out + jnp.where(mask[..., None], vals, 0.0)
        return out
    return jnp.take(table, ids, axis=0)


def head_logits(table, h: jax.Array) -> jax.Array:
    """Tied-head contraction ``h [..., d] -> logits [..., n]`` (f32).

    int8-resident tables contract through ``ops.dequant_matmul`` — weight
    tiles are de-quantized in VMEM right before the MXU, so the head costs
    1 byte/weight of HBM traffic and the fp32 table never materializes.
    Bitwise-equal to the einsum over the de-quantized table (the pre-redesign
    fp-exported path).
    """
    if isinstance(table, QuantTable):
        lead = h.shape[:-1]
        h2 = h.reshape(-1, h.shape[-1]).astype(jnp.float32)
        d_alloc = table.codes.shape[1]
        if h2.shape[-1] != d_alloc:
            # Padded columns hold codes for dims the model never writes;
            # zero activations there keep the contraction exact.
            h2 = jnp.pad(h2, ((0, 0), (0, d_alloc - h2.shape[-1])))
        logits = ops.dequant_matmul(
            h2, table.codes, table.step, use_kernel=table.use_kernels
        )
        if table.n != logits.shape[-1]:
            logits = logits[:, : table.n]
        return logits.reshape(lead + (table.n,)).astype(jnp.float32)
    if isinstance(table, QRQuantTable):
        # The QR product head is not a single matmul over codes; the virtual
        # rows are composed from the two fused gathers per step (transient
        # [n, d] — resident state stays int8).  A decomposed contraction
        # (einsum('bd,qd,rd->bqr') over the two small sub-tables) would avoid
        # the transient entirely but re-associates the product and breaks
        # bitwise parity with the fp-exported table — the parity contract
        # wins here; the decomposed head is a ROADMAP follow-up.
        w = rows(table, jnp.arange(table.n))
        return jnp.einsum("...d,vd->...v", h.astype(jnp.float32), w).astype(
            jnp.float32
        )
    if isinstance(table, MixedQuantTable):
        # Same trade-off as the QR head: compose the virtual rows through the
        # per-group fused gathers (transient [n, d]; resident state stays
        # packed integer) so the contraction is bitwise-parity with the
        # fp-exported table.
        w = rows(table, jnp.arange(table.n))
        return jnp.einsum("...d,vd->...v", h.astype(jnp.float32), w).astype(
            jnp.float32
        )
    w = table.table if isinstance(table, FloatTable) else table
    return jnp.einsum("...d,vd->...v", h.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(jnp.float32)
