"""Production mesh builders (functions, not constants — importing this module
never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds a leading 2-pod axis (512 chips).

    Axes: 'pod' (outer data parallel, DCN-ish), 'data' (in-pod data parallel),
    'model' (tensor parallel over ICI).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — used by tests."""
    return jax.make_mesh((data, model), ("data", "model"))
