"""Production training driver: preemption-safe, resumable, straggler-aware.

Usage (single host, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault-tolerance contract (DESIGN.md §6):
  * SIGTERM/SIGINT -> finish the in-flight step, checkpoint, exit(75) so the
    scheduler requeues the job.
  * Restart resumes from the latest committed checkpoint; the data pipeline
    is indexed by step, so the replay is exact (no data skew across restarts).
  * A per-step wall-time EWMA flags stragglers (> straggler_factor x EWMA);
    on a real pod this feeds the controller's replace-node decision — here it
    is logged and counted.
  * Elastic restart: --mesh-data/--mesh-model may differ from the run that
    wrote the checkpoint; restore re-shards (checkpoint/manager.py).
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import functools
import json
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, faults, methods
from repro.checkpoint import CheckpointManager
from repro.checkpoint.manager import (
    check_embedding_manifest,
    config_hash,
    embedding_manifest,
)
from repro.data.lm_synth import LMTokenStream
from repro.dist import context as dist_ctx
from repro.dist import sharding
from repro.kernels import ops as kernel_ops
from repro.launch.mesh import make_host_mesh
from repro.obs import counters as obs_counters
from repro.obs.stats import StreamingQuantiles
from repro.obs.trace import tracer
from repro.training import data_parallel, lm_trainer

# Per-host straggler accounting: ticked whenever the watchdog flags a step
# (> factor x EWMA); read back in end-of-run summaries and obs snapshots.
_MET_STRAGGLERS = obs_counters.registry().counter(
    "train.straggler_warnings", "steps flagged slow by the watchdog"
)


class GracefulShutdown:
    """Latches SIGTERM/SIGINT; the loop checkpoints and exits cleanly."""

    def __init__(self):
        self.requested = False
        for sig in (signal.SIGTERM, signal.SIGINT):
            signal.signal(sig, self._handler)

    def _handler(self, signum, frame):
        self.requested = True


class StragglerWatchdog:
    def __init__(self, factor: float = 2.5, warmup: int = 5):
        self.factor = factor
        self.warmup = warmup
        self.ewma = None
        self.n = 0
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        self.n += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = self.n > self.warmup and dt > self.factor * self.ewma
        if slow:
            self.flagged += 1
            _MET_STRAGGLERS.inc()
            tracer().instant("train.straggler", step=self.n, dt_ms=dt * 1e3)
        # Slow steps don't poison the EWMA.
        self.ewma = 0.9 * self.ewma + 0.1 * min(dt, 2 * self.ewma)
        return slow


def _run_ctr(args) -> int:
    """CTR training loop (sparse integer-table path) with optional tiered
    storage: ``--cache-rows`` wraps every cacheable storage slot in a device
    hot-row cache with dirty-row write-back — training metrics are
    bitwise-identical to the uncached run (tests/test_storage.py).

    The LM path below stays cache-free on purpose: its dense update touches
    every table row each step, so a hot-row cache would be permanently dirty.
    """
    from repro.launch.serve import CTR_DEMO_DATA, CTR_ZIPF_DATA
    from repro.data.ctr_synth import CTRSynthetic
    from repro.models.ctr import DCNConfig
    from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

    data_cfg = CTR_ZIPF_DATA if args.zipf else CTR_DEMO_DATA
    data = CTRSynthetic(data_cfg)
    spec = methods.EmbeddingSpec(
        method=args.embedding_method or "alpt", n=data_cfg.n_features, d=32,
        bits=8, init_scale=0.05, use_kernels=not args.no_kernels,
    )
    trainer = CTRTrainer(TrainerConfig(
        spec=spec, model="dcn", lr=args.lr,
        dcn=DCNConfig(n_fields=data_cfg.n_fields, emb_dim=32,
                      cross_depth=2, mlp_widths=(64, 32)),
        cache_rows=args.cache_rows,
        guard=args.guard,
    ))
    state = trainer.init_state(jax.random.PRNGKey(0))
    shutdown = GracefulShutdown()

    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(
            args.ckpt_dir, keep=3, save_every=args.ckpt_every
        )
        if ckpt.latest_step() is not None:
            # Checkpoints hold the exported (cache-off-equivalent) state;
            # restore into that structure, then re-wrap the caches cold.
            restored, manifest = ckpt.restore(trainer.export_state(state))
            state = trainer.import_state(restored)
            start_step = manifest["step"]
            print(f"[train] ctr resumed from step {start_step}")

    def save(step: int, *, force: bool = False) -> None:
        if ckpt:
            ckpt.maybe_save(trainer.export_state(state), step, force=force)

    losses = []
    step_times = StreamingQuantiles()
    for step in range(start_step, args.steps):
        ids, labels = data.batch("train", step, args.batch)
        t0 = time.time()
        state, metrics = trainer.train_step(state, ids, labels)
        losses.append(float(metrics["loss"]))  # blocks; also the step barrier
        step_times.add((time.time() - t0) * 1e6)
        if (step + 1) % args.log_every == 0:
            print(f"[train] ctr step {step+1} loss {losses[-1]:.4f}")
        save(step + 1)
        if faults.fires("train.preempt", step + 1):
            print(f"[train] injected preemption at step {step+1}")
            shutdown.requested = True
        if shutdown.requested:
            save(step + 1, force=True)
            print(f"[train] preempted at step {step+1}; checkpointed; "
                  f"exiting 75 for requeue")
            return 75
    save(args.steps, force=True)
    summary = {
        "first_loss": losses[0] if losses else None,
        "final_loss": losses[-1] if losses else None,
        "steps": len(losses),
        "step_time_us": step_times.to_json(),
    }
    for stats in trainer.cache_stats():
        print(f"[train] hot tier '{stats['name']}': hit rate "
              f"{stats['hit_rate']:.3f}, {stats['evictions']} evictions, "
              f"{stats['writebacks']} write-backs, "
              f"{stats['writeback_retries']} write-back retries, "
              f"{stats['admission_oom']} admission refusals")
    if trainer.guard_stats is not None:
        trainer.guard_stats.publish()
        g = trainer.guard_stats.to_json()
        summary["guard"] = g
        print(f"[train] guard: {g['skipped']} skipped steps "
              f"({g['nonfinite_fired']} injected non-finite, "
              f"{g['delta_fired']} injected Delta blowups, "
              f"{g['delta_clamped']} Delta rows clamped)")
    if ckpt and ckpt.corrupt_steps:
        summary["corrupt_checkpoints"] = ckpt.corrupt_steps
        print(f"[train] WARNING: refused corrupted checkpoint step(s) "
              f"{ckpt.corrupt_steps} on restore")
    if not args.no_kernels:
        stats = kernel_ops.fallback_stats()
        summary["kernel_fallbacks"] = stats["total_fallbacks"]
        for fb in stats["fallbacks"]:
            print(f"[train] kernel fallback: {fb['op']} {fb['shape']} "
                  f"({fb['reason']})")
    print("[train] done:", json.dumps(summary))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(configs.ARCHS) + ["ctr"],
                    required=True,
                    help="an LM arch, or 'ctr' for the sparse CTR trainer")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--embedding-method", default=None,
                    choices=sorted(methods.available()),
                    help="any registered repro.methods name")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument(
        "--dp-compress-bits", type=int, default=None, metavar="BITS",
        help="data-parallel mode: replicate the state over a --mesh-data-way "
        "'data' axis (shard_map) and sync gradients at this bit width "
        "(32 = exact fp32 mean, 8/4/2 = SR-compressed codes); requires "
        "--mesh-model 1",
    )
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument(
        "--no-kernels", action="store_true",
        help="disable the fused Pallas embedding hot paths "
        "(EmbeddingSpec.use_kernels; default on, auto-interpret off-TPU)",
    )
    ap.add_argument(
        "--pad-to-tiles", action="store_true",
        help="pad the vocab table to kernel-tile geometry so the fused paths "
        "run without shape fallbacks (EmbeddingSpec.pad_to_tiles)",
    )
    ap.add_argument(
        "--cache-rows", type=int, default=0,
        help="--arch ctr only: device hot-row cache capacity per storage "
        "slot (repro.storage); bitwise-equal to uncached training",
    )
    ap.add_argument(
        "--zipf", action="store_true",
        help="--arch ctr only: use the Zipf(1.1) skewed-traffic fixture",
    )
    ap.add_argument(
        "--fault-plan", default=None, metavar="JSON",
        help="install a repro.faults FaultPlan (JSON file) for this run; "
        "see the seam catalog in repro/faults/__init__.py",
    )
    ap.add_argument(
        "--guard", action="store_true",
        help="enable the non-finite skip-step guard (repro.faults.guards); "
        "auto-enabled when --fault-plan schedules a trainer seam",
    )
    ap.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="arm the obs span tracer and write a Chrome-trace JSON "
        "(chrome://tracing / ui.perfetto.dev) to PATH at exit",
    )
    args = ap.parse_args(argv)

    if args.trace_out:
        tracer().enable(args.trace_out)
        print(f"[train] tracing armed -> {args.trace_out}")
    try:
        return _main(ap, args)
    finally:
        if args.trace_out and tracer().export():
            print(f"[train] trace written: {args.trace_out} "
                  f"({len(tracer().events)} events)")


def _main(ap, args) -> int:

    if args.fault_plan:
        plan = faults.FaultPlan.load(args.fault_plan)
        faults.install(plan)
        print(f"[train] fault plan installed: sites {sorted(plan.sites())}")
        trainer_seams = {"trainer.nonfinite", "alpt.delta"} & set(plan.sites())
        if trainer_seams and not args.guard:
            print(f"[train] plan schedules {sorted(trainer_seams)}; "
                  f"enabling --guard")
            args.guard = True

    if args.arch == "ctr":
        return _run_ctr(args)
    if args.cache_rows:
        ap.error("--cache-rows is the sparse CTR trainer's tiered-storage "
                 "knob (--arch ctr); the LM dense update rewrites every row "
                 "each step, so a hot-row cache cannot stay coherent there")

    cfg = configs.smoke_config(args.arch) if args.smoke else configs.full_config(args.arch)
    if args.embedding_method:
        cfg = dataclasses.replace(cfg, embedding_method=args.embedding_method)
    dp_mode = args.dp_compress_bits is not None
    tcfg = lm_trainer.LMTrainerConfig(
        lr=args.lr,
        dp_sync_bits=args.dp_compress_bits if dp_mode else 32,
        use_kernels=not args.no_kernels,
        pad_to_tiles=args.pad_to_tiles,
        guard=args.guard,
    )

    if dp_mode and args.mesh_model != 1:
        ap.error("--dp-compress-bits is pure data parallelism; use --mesh-model 1")
    if dp_mode and args.guard:
        # Inside shard_map the guard would gate on the per-replica (pre-sync)
        # loss, so replicas could disagree on skip-vs-apply and diverge.
        ap.error("--guard is single-program only; drop --dp-compress-bits")
    if dp_mode and args.dp_compress_bits != 32 and not 2 <= args.dp_compress_bits <= 8:
        ap.error("--dp-compress-bits must be 32 (exact) or in [2, 8] "
                 f"(SR-compressed), got {args.dp_compress_bits}")
    if dp_mode and args.mesh_data == 1 and tcfg.dp_sync_bits != 32:
        print("[train] WARNING: --dp-compress-bits < 32 with --mesh-data 1 "
              "injects quantization noise with nothing to communicate")
    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    pol = sharding.Policy(name="tp", data_axes=("data",),
                          model_size=args.mesh_model)
    if dp_mode:
        # Replicated state, batch sharded over 'data', compressed sync.
        state_spec = jax.tree.map(
            lambda _: jax.sharding.PartitionSpec(),
            sharding.state_pspecs(cfg, pol, tcfg),
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
        )
    else:
        state_spec = sharding.state_pspecs(cfg, pol, tcfg)
    state_sh = sharding.to_named(state_spec, mesh)

    data = LMTokenStream(cfg.vocab_size, args.seq, seed=17)
    shutdown = GracefulShutdown()
    watchdog = StragglerWatchdog()
    # Checkpoint manifests carry the embedding method's name + schema so a
    # resume with a different --embedding-method fails loudly, not subtly.
    ckpt_meta = {
        "config_hash": config_hash(cfg),
        **embedding_manifest(lm_trainer.embedding_spec_of(cfg, tcfg)),
    }

    def make_batch(step: int) -> dict:
        full = data.batch(step, args.batch)
        batch = {
            "tokens": jnp.asarray(full[:, :-1]),
            "labels": jnp.asarray(full[:, 1:]),
        }
        if cfg.input_mode == "embeds":
            emb = np.random.RandomState(step).normal(
                0, 1, (args.batch, args.seq, cfg.d_model)
            )
            batch = {
                "embeds": jnp.asarray(emb, cfg.dtype),
                "labels": jnp.asarray(full[:, 1:] % cfg.vocab_size),
            }
        elif cfg.input_mode == "mixed":
            emb = np.random.RandomState(step).normal(
                0, 1, (args.batch, cfg.visual_prefix, cfg.d_model)
            )
            batch["prefix_embeds"] = jnp.asarray(emb, cfg.dtype)
            pos = jnp.arange(args.seq, dtype=jnp.int32)[None].repeat(args.batch, 0)
            batch["positions"] = jnp.stack([pos, pos, pos], 0)
        return batch

    # In DP mode the state is replicated and the step runs under shard_map,
    # where hint()'s with_sharding_constraint must not fire (the mesh axes are
    # manual there) — so the ambient dist context stays uninstalled.
    amb = contextlib.nullcontext() if dp_mode else dist_ctx.use(mesh, pol)
    with mesh, amb:
        init = jax.jit(
            functools.partial(lm_trainer.init_state, cfg=cfg, tcfg=tcfg),
            out_shardings=state_sh,
        )
        state = init(jax.random.PRNGKey(0))
        if dp_mode:
            if cfg.input_mode == "mixed":
                ap.error("--dp-compress-bits does not support mixed-input "
                         "(M-RoPE positions) archs")
            step_fn = data_parallel.make_lm_dp_step(cfg, tcfg, mesh)
            # Probe the wire bytes with the shapes of a real loop batch (one
            # throwaway host batch at startup — negligible next to init()).
            probe = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                make_batch(0),
            )
            grad_shapes = data_parallel.lm_grad_shapes(cfg, tcfg, state, probe)
            report = data_parallel.wire_report(grad_shapes, tcfg.dp_sync_bits)
            print(f"[train] dp sync_bits={tcfg.dp_sync_bits} "
                  f"wire_bytes/step={report['wire_bytes_per_step']} "
                  f"({report['compression_ratio']:.2f}x vs fp32)")
        else:
            step_fn = jax.jit(
                lm_trainer.make_train_step(cfg, tcfg),
                in_shardings=(state_sh, None),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            )
            # Host-side periodic refresh (prune mask); identity otherwise.
            step_fn = lm_trainer.wrap_host_refresh(step_fn, cfg, tcfg)

        start_step = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = CheckpointManager(
                args.ckpt_dir, keep=3, save_every=args.ckpt_every
            )
            latest = ckpt.latest_step()
            if latest is not None:
                # Surface method mismatches BEFORE the structural restore
                # errors out on leaf counts (clearer failure story).
                for problem in check_embedding_manifest(
                        ckpt.read_manifest(latest),
                        lm_trainer.embedding_spec_of(cfg, tcfg)):
                    print(f"[train] WARNING: {problem}")
                state, manifest = ckpt.restore(state, shardings=state_sh)
                if manifest.get("config_hash") != config_hash(cfg):
                    print("[train] WARNING: config hash mismatch on resume")
                start_step = manifest["step"]
                print(f"[train] resumed from step {start_step}")

        losses = []
        step_times = StreamingQuantiles()
        guard_stats = faults.GuardStats() if args.guard else None
        for step in range(start_step, args.steps):
            batch = make_batch(step)
            t0 = time.time()
            with tracer().span("train.step", step=step):
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])  # blocks; also the step barrier
            dt = time.time() - t0
            step_times.add(dt * 1e6)
            slow = watchdog.observe(dt)
            losses.append(loss)
            if guard_stats is not None:
                guard_stats.observe(metrics)
            if (step + 1) % args.log_every == 0:
                print(
                    f"[train] step {step+1} loss {loss:.4f} "
                    f"{dt*1e3:.0f}ms{' STRAGGLER' if slow else ''}"
                )
            if ckpt:
                ckpt.maybe_save(
                    state, step + 1,
                    extra_meta=ckpt_meta,
                )
            if faults.fires("train.preempt", step + 1):
                print(f"[train] injected preemption at step {step+1}")
                shutdown.requested = True
            if shutdown.requested:
                if ckpt:
                    ckpt.maybe_save(
                        state, step + 1, force=True,
                        extra_meta=ckpt_meta,
                    )
                print(f"[train] preempted at step {step+1}; checkpointed; "
                      f"exiting 75 for requeue")
                return 75
        if ckpt:
            ckpt.maybe_save(
                state, args.steps, force=True,
                extra_meta=ckpt_meta,
            )
        summary = {
            "final_loss": losses[-1] if losses else None,
            "first_loss": losses[0] if losses else None,
            "straggler_steps": watchdog.flagged,
            "steps": len(losses),
            "step_time_us": step_times.to_json(),
        }
        if guard_stats is not None:
            guard_stats.publish()
            g = guard_stats.to_json()
            summary["guard"] = g
            print(f"[train] guard: {g['skipped']} skipped steps "
                  f"({g['nonfinite_fired']} injected non-finite, "
                  f"{g['delta_fired']} injected Delta blowups)")
        if ckpt and ckpt.corrupt_steps:
            summary["corrupt_checkpoints"] = ckpt.corrupt_steps
            print(f"[train] WARNING: refused corrupted checkpoint step(s) "
                  f"{ckpt.corrupt_steps} on restore")
        if not args.no_kernels:
            # Explicit fallback accounting: surface any embedding op that
            # silently would have missed the fused path (never silent).
            stats = kernel_ops.fallback_stats()
            summary["kernel_fallbacks"] = stats["total_fallbacks"]
            for fb in stats["fallbacks"]:
                print(f"[train] kernel fallback: {fb['op']} {fb['shape']} "
                      f"({fb['reason']}) — consider --pad-to-tiles")
        print("[train] done:", json.dumps(summary))
        return 0


if __name__ == "__main__":
    sys.exit(main())
