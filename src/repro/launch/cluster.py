"""Multi-process (real pod) bootstrap for the production mesh.

On a real v5e pod slice each host runs this module, which initializes
jax.distributed from the standard TPU environment (or explicit flags), builds
the SAME production mesh as the dry-run, and enters launch.train's loop — the
dry-run (launch/dryrun.py) proves every (arch x shape) lowers and compiles on
exactly this mesh, so the only difference on hardware is real ICI instead of
fake host devices.

    # per host (GKE/GCE give COORDINATOR/NUM_PROCESSES/PROCESS_ID via env):
    python -m repro.launch.cluster --arch qwen3-1.7b --steps 10000 \
        --ckpt-dir gs://bucket/run1 [--multipod]

Elasticity contract: restart with a different number of pods/hosts and the
checkpoint manager re-shards state onto the new mesh (tests/test_checkpoint.py
::test_elastic_reshard_across_device_counts exercises the mechanism).
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--coordinator", default=os.environ.get("COORDINATOR_ADDRESS"))
    ap.add_argument("--num-processes", type=int,
                    default=int(os.environ.get("NUM_PROCESSES", "0")) or None)
    ap.add_argument("--process-id", type=int,
                    default=int(os.environ.get("PROCESS_ID", "-1")))
    ap.add_argument("--multipod", action="store_true")
    args, rest = ap.parse_known_args(argv)

    import jax

    if args.coordinator:
        jax.distributed.initialize(
            coordinator_address=args.coordinator,
            num_processes=args.num_processes,
            process_id=args.process_id if args.process_id >= 0 else None,
        )
    else:
        # TPU pods auto-discover via the metadata server.
        jax.distributed.initialize()

    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=args.multipod)
    if jax.process_index() == 0:
        print(f"[cluster] {jax.process_count()} processes, "
              f"{jax.device_count()} devices, mesh {dict(mesh.shape)}")

    # Hand off to the training driver with the production mesh dims.
    from repro.launch import train

    model_axis = mesh.shape["model"]
    data_axis = jax.device_count() // model_axis
    return train.main(
        rest + ["--mesh-data", str(data_axis), "--mesh-model", str(model_axis)]
    )


if __name__ == "__main__":
    sys.exit(main())
