"""Serving driver: a thin CLI over the `repro.serving` Engine API.

Two scenarios share one int8-resident Engine:

  LM decode (slot-based continuous batching):
    PYTHONPATH=src python -m repro.launch.serve lm --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 32 --gen 16 --requests 8

  CTR scoring (fixed-geometry batched admission):
    PYTHONPATH=src python -m repro.launch.serve ctr --method alpt \
        --batch 32 --requests 64

Everything interesting lives in :mod:`repro.serving` — the Engine builds the
method's ``serving_state`` (codes + scales for integer tables; the fp32
table is never materialized), steps the scheduler, and reports metrics
including resident embedding bytes and an accurate per-engine kernel
fallback tally (``ops.fallback_scope``).  This file only parses flags,
fabricates synthetic requests, and prints the report.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np

from repro import configs, faults, methods
from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic
from repro.models.ctr import DCNConfig
from repro.obs.trace import tracer
from repro.serving.ctr import CTREngine, CTRRequest
from repro.serving.lm import LMEngine, LMRequest
from repro.training import lm_trainer
from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

# One synthetic CTR fixture shared by this CLI and benchmarks/serve_bench.py,
# so the artifact's cells stay comparable with what the CLI demonstrates.
CTR_DEMO_DATA = CTRDatasetConfig(
    name="serve-synth", n_fields=8,
    cardinalities=(97, 41, 13, 211, 89, 53, 17, 149),
    teacher_rank=4, seed=0,
)
# d=64: wide enough that the per-row fp32 scale doesn't mask the packed
# sub-byte code savings (bits=4 resident <= 0.55x bits=8, asserted in
# benchmarks/serve_bench.py).
CTR_DEMO_DIM = 64

# Skewed-traffic fixture for the tiered-storage cells: Zipf(1.1) request ids
# over a 4092-row vocabulary, so a hot tier holding ~10% of the rows catches
# >=90% of lookups (asserted in benchmarks/serve_bench.py).
CTR_ZIPF_DATA = CTRDatasetConfig(
    name="serve-zipf", n_fields=8,
    cardinalities=(4, 8, 12, 24, 48, 96, 1400, 2500),
    teacher_rank=4, zipf_a=1.1, seed=0,
)


def build_ctr_demo_engine(method: str, *, bits: int = 8, batch: int,
                          train_steps: int, train_batch: int = 256,
                          data_cfg: CTRDatasetConfig = CTR_DEMO_DATA,
                          cache_rows: int = 0, cold_tier: bool = False,
                          device_budget_bytes: int | None = None):
    """Train a few steps on the demo fixture, return ``(engine, data)``."""
    data = CTRSynthetic(data_cfg)
    spec = methods.EmbeddingSpec(
        method=method, n=data_cfg.n_features, d=CTR_DEMO_DIM, bits=bits,
        init_scale=0.05,
    )
    trainer = CTRTrainer(TrainerConfig(
        spec=spec, model="dcn",
        dcn=DCNConfig(n_fields=data_cfg.n_fields, emb_dim=CTR_DEMO_DIM,
                      cross_depth=2, mlp_widths=(64, 32)),
    ))
    state = trainer.init_state()
    for i in range(train_steps):
        ids, labels = data.batch("train", i, train_batch)
        state, _ = trainer.train_step(state, ids, labels)
    engine = CTREngine.from_state(
        state, trainer.cfg, batch=batch, cache_rows=cache_rows,
        cold_tier=cold_tier, device_budget_bytes=device_budget_bytes,
    )
    return engine, data


def _print_report(engine) -> None:
    m = engine.metrics()
    per = (
        f"{m.get('us_per_token', 0.0):.0f} us/token"
        if engine.scenario == "lm" else f"{m.get('us_per_request', 0.0):.0f} us/request"
    )
    print(
        f"[serve] {m['scenario']}/{m['embedding_method']}: "
        f"{m['requests_completed']} requests in {m['wall_s']:.2f}s ({per}); "
        f"resident embedding bytes {m['resident_embedding_bytes']} "
        f"(codes {m['embedding_code_bytes']} + scales "
        f"{m['embedding_scale_bytes']}; int8_resident={m['int8_resident']})"
    )
    for c in m.caches:
        print(
            f"[serve] {c.tier} tier '{c.name}': {c.rows_cached}/{c.capacity} "
            f"rows, hit rate {c.hit_rate:.3f} ({c.hits} hits / {c.misses} "
            f"misses), {c.hot_bytes + c.metadata_bytes} device bytes "
            f"(rows {c.hot_bytes} + metadata {c.metadata_bytes})"
        )
        if c.admission_oom or c.prefetch_dropped or c.corruption_detected:
            print(
                f"[serve] {c.tier} tier '{c.name}' recovery: "
                f"{c.admission_oom} admission refusals, "
                f"{c.prefetch_dropped} prefetch losses, "
                f"{c.corruption_detected} corrupted prefetches re-fetched"
            )
    if m.caches:
        print(f"[serve] aggregate cache hit rate {m.cache_hit_rate:.3f}")
    if m.latency_us:
        for which, q in sorted(m.latency_us.items()):
            if q.get("count"):
                print(f"[serve] {which} latency: p50 {q['p50']:.0f}us "
                      f"p95 {q['p95']:.0f}us p99 {q['p99']:.0f}us "
                      f"(n={q['count']})")
    report = engine.fallback_report()
    for fb in report["fallbacks"]:
        print(f"[serve] kernel fallback: {fb['op']} {fb['shape']} "
              f"({fb['reason']})")
    if not report["fallbacks"]:
        print("[serve] kernel fallbacks: none")
    print(f"[serve] recovery: {m['served_degraded']} degraded waves, "
          f"{m['deadline_misses']} deadline misses, "
          f"{m['wave_retries']} wave retries, "
          f"{m['retry_failures']} retry exhaustions")
    for name, stats in engine._tier_retry_stats():
        print(f"[serve] {name} tier retries: {json.dumps(stats.to_json())}")
    h = engine.health()
    status = "READY" if h["ready"] else "NOT READY"
    failed = [k for k, ok in h["checks"].items() if not ok]
    print(f"[serve] health: {status}"
          + (f" (failing: {', '.join(failed)})" if failed else ""))


def _run_lm(args) -> int:
    cfg = (configs.smoke_config(args.arch) if args.smoke
           else configs.full_config(args.arch))
    if cfg.input_mode == "embeds":
        print("[serve] encoder-only arch has no decode; nothing to serve")
        return 0
    tcfg = lm_trainer.LMTrainerConfig()
    state = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    engine = LMEngine.from_state(
        state, cfg, tcfg, batch=args.batch,
        max_len=args.prompt_len + args.gen,
    )
    if args.deadline_ms is not None:
        engine.deadline_s = args.deadline_ms / 1e3
    rng = np.random.RandomState(0)
    for _ in range(args.requests):
        engine.submit(LMRequest(
            prompt=rng.randint(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
            max_new=args.gen,
        ))
    done = engine.run()
    _print_report(engine)
    for rid in sorted(done)[:2]:
        print(f"  rid={rid} tokens={done[rid][:8]}...")
    return 0


def _run_ctr(args) -> int:
    engine, data = build_ctr_demo_engine(
        args.method, bits=args.bits, batch=args.batch,
        train_steps=args.train_steps,
        data_cfg=CTR_ZIPF_DATA if args.zipf else CTR_DEMO_DATA,
        cache_rows=args.cache_rows, cold_tier=args.cold_tier,
        device_budget_bytes=args.device_budget_bytes,
    )
    if args.deadline_ms is not None:
        engine.deadline_s = args.deadline_ms / 1e3
    ids, _ = data.batch("test", 0, args.requests)
    rids = [engine.submit(CTRRequest(ids=row)) for row in ids]
    done = engine.run()
    _print_report(engine)
    probs = [done[r]["prob"] for r in rids[:4]]
    print(f"  first probs: {[round(p, 4) for p in probs]}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="scenario", required=True)

    lm = sub.add_parser("lm", help="continuous-batch LM decode")
    lm.add_argument("--arch", choices=sorted(configs.ARCHS), required=True)
    lm.add_argument("--smoke", action="store_true")
    lm.add_argument("--batch", type=int, default=4)
    lm.add_argument("--prompt-len", type=int, default=32)
    lm.add_argument("--gen", type=int, default=16)
    lm.add_argument("--requests", type=int, default=8)

    ctr = sub.add_parser("ctr", help="batched CTR request scoring")
    ctr.add_argument("--method", choices=methods.available(), default="alpt")
    ctr.add_argument("--bits", type=int, default=8)
    ctr.add_argument("--batch", type=int, default=32)
    ctr.add_argument("--requests", type=int, default=64)
    ctr.add_argument("--train-steps", type=int, default=5)
    ctr.add_argument("--zipf", action="store_true",
                     help="use the Zipf(1.1) skewed-traffic fixture")
    ctr.add_argument("--cache-rows", type=int, default=0,
                     help="device hot-row cache capacity per storage slot "
                          "(0 = off); bitwise-equal to uncached serving")
    ctr.add_argument("--cold-tier", action="store_true",
                     help="host-resident codes; device holds scales + hot "
                          "rows only (requires --cache-rows > 0)")
    ctr.add_argument("--device-budget-bytes", type=int, default=None,
                     help="assert hot-tier device bytes stay under this")

    for p in (lm, ctr):
        p.add_argument("--fault-plan", default=None, metavar="JSON",
                       help="install a repro.faults FaultPlan (JSON file); "
                       "see the seam catalog in repro/faults/__init__.py")
        p.add_argument("--deadline-ms", type=float, default=None,
                       help="per-wave deadline; waves over it tick the "
                       "deadline_misses counter (observed, not enforced)")
        p.add_argument("--trace-out", default=None, metavar="PATH",
                       help="arm the obs span tracer and write a Chrome-trace "
                       "JSON (chrome://tracing / ui.perfetto.dev) to PATH")

    args = ap.parse_args(argv)
    if args.fault_plan:
        plan = faults.FaultPlan.load(args.fault_plan)
        faults.install(plan)
        print(f"[serve] fault plan installed: sites {sorted(plan.sites())}")
    if args.trace_out:
        tracer().enable(args.trace_out)
        print(f"[serve] tracing armed -> {args.trace_out}")
    try:
        return _run_lm(args) if args.scenario == "lm" else _run_ctr(args)
    finally:
        if args.trace_out and tracer().export():
            print(f"[serve] trace written: {args.trace_out} "
                  f"({len(tracer().events)} events)")


if __name__ == "__main__":
    sys.exit(main())
