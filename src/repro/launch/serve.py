"""Batched serving driver: continuous-batch decode with int8 embedding tables.

Usage (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 32 --gen 16

Serving loop structure (the production shape):
  * one jitted ``prefill`` building the KV cache per admitted batch,
  * one jitted ``decode_step`` (single token, cache donated in/out),
  * slot-based continuous batching: finished sequences' slots are refilled
    from the request queue without recompiling (fixed batch geometry),
  * the embedding table stays int8 (LPT) — decode reads de-quantize rows on
    the fly; weights never exist in fp32.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, methods
from repro.kernels import ops as kernel_ops
from repro.models import transformer as tfm
from repro.training import lm_trainer


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [T] int32
    max_new: int


class ContinuousBatcher:
    """Fixed-geometry slot scheduler (the vLLM-style loop, minus paging)."""

    def __init__(self, params, table, cfg: tfm.ModelConfig, *, batch: int,
                 max_len: int):
        self.cfg = cfg
        self.params = params
        self.table = table
        self.batch = batch
        self.max_len = max_len
        # The registered method's serving export: int-code tables de-quantize
        # on the way out through the fused gather kernel; fp ships as-is
        # (weights never exist in fp32 for integer-table methods until this
        # point).  Any shape fallback off the kernel path is surfaced, never
        # silent.
        spec = lm_trainer.embedding_spec_of(cfg)
        method = methods.get(spec.method)
        if method.is_integer_table and spec.use_kernels:
            # Fallback counting happens at trace time, so this reflects the
            # export's dispatch when its shapes trace fresh (the serve CLI's
            # normal case: the batcher is the process's first jit user).  A
            # process that already traced these shapes under-reports here
            # rather than paying a process-wide cache flush to re-count.
            kernel_ops.reset_fallback_stats()
        self.table_fp = method.serving_table(table, spec)
        if method.is_integer_table and spec.use_kernels:
            for fb in kernel_ops.fallback_stats()["fallbacks"]:
                print(f"[serve] kernel fallback: {fb['op']} {fb['shape']} "
                      f"({fb['reason']})")
        self._decode = jax.jit(
            functools.partial(tfm.decode_step, cfg=cfg), donate_argnums=(3,)
        )
        self._prefill = jax.jit(
            functools.partial(tfm.prefill, cfg=cfg, max_len=max_len)
        )
        self.queue: list[Request] = []
        self.done: dict[int, list[int]] = {}

    def submit(self, req: Request):
        self.queue.append(req)

    def run(self):
        """Prefill-then-decode in admission waves; returns {rid: tokens}."""
        while self.queue:
            wave = [self.queue.pop(0) for _ in range(min(self.batch,
                                                         len(self.queue)))]
            # Left-align prompts to a common length (pad with 0, mask decode).
            plen = max(len(r.prompt) for r in wave)
            toks = np.zeros((self.batch, plen), np.int32)
            for i, r in enumerate(wave):
                toks[i, -len(r.prompt):] = r.prompt  # right-aligned
            logits, cache = self._prefill(
                self.params, self.table_fp, jnp.asarray(toks)
            )
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out = [[int(cur[i])] for i in range(len(wave))]
            max_new = max(r.max_new for r in wave)
            cache_len = jnp.asarray(plen, jnp.int32)
            for step in range(max_new - 1):
                logits, cache = self._decode(
                    self.params, self.table_fp, cur, cache, cache_len
                )
                cache_len = cache_len + 1
                cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                for i, r in enumerate(wave):
                    if len(out[i]) < r.max_new:
                        out[i].append(int(cur[i]))
            for i, r in enumerate(wave):
                self.done[r.rid] = out[i][: r.max_new]
        return self.done


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(configs.ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = configs.smoke_config(args.arch) if args.smoke else configs.full_config(args.arch)
    if cfg.input_mode == "embeds":
        print("[serve] encoder-only arch has no decode; nothing to serve")
        return 0
    tcfg = lm_trainer.LMTrainerConfig()
    state = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    srv = ContinuousBatcher(
        state.params, state.table, cfg, batch=args.batch,
        max_len=args.prompt_len + args.gen,
    )
    rng = np.random.RandomState(0)
    t0 = time.time()
    for rid in range(args.requests):
        srv.submit(Request(
            rid=rid,
            prompt=rng.randint(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
            max_new=args.gen,
        ))
    done = srv.run()
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in done.values())
    print(f"[serve] {len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s)")
    for rid in sorted(done)[:2]:
        print(f"  rid={rid} tokens={done[rid][:8]}...")
    return 0


if __name__ == "__main__":
    sys.exit(main())
