import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# The two lines above MUST run before any jax import (device count locks on
# first backend init); everything below is ordinary code.

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
mesh, prove memory fits, and dump the roofline inputs (EXPERIMENTS.md §Dry-run).

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --multipod
  python -m repro.launch.dryrun --all            # every cell, both meshes
  python -m repro.launch.dryrun --list           # show the cell matrix

Each cell writes JSON to benchmarks/dryrun_results/<cell>.json; re-runs skip
cells whose result file already exists (delete to force).
"""
import argparse
import functools
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs, methods
from repro.configs import common
from repro.dist import context as dist_ctx
from repro.dist import sharding
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tfm
from repro.training import lm_trainer

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "dryrun_results"

# TPU v5e hardware model for the roofline terms (per task spec).
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link


def arch_dry_config(arch: str, shape_name: str,
                    embedding: str | None = None) -> tfm.ModelConfig:
    """Full config tuned for the dry-run: bf16, TP head padding, remat."""
    over = dict(
        dtype=jnp.bfloat16,
        param_dtype=jnp.bfloat16,
        head_pad_multiple=16,
        remat=True,
    )
    if embedding:
        over["embedding_method"] = embedding
    cfg = configs.full_config(arch, **over)
    return cfg


def make_serve_step(cfg: tfm.ModelConfig, tcfg=None):
    spec = lm_trainer.embedding_spec_of(cfg, tcfg)
    method = methods.get(spec.method)

    def serve_step(params, table, token, cache, cache_len):
        table_fp = method.serving_table(table, spec)
        return tfm.decode_step(params, table_fp, token, cache, cache_len, cfg)

    return serve_step


def build_cell(arch: str, shape_name: str, mesh, policy_override=None,
               embedding=None):
    """Returns (jitted_fn, example_args_shapes) ready to .lower()."""
    shape = common.SHAPES[shape_name]
    cfg = arch_dry_config(arch, shape_name, embedding)
    # Lower the UNFUSED path: the interpret-mode Pallas lowering would turn
    # each kernel into a grid scan in the SPMD module, distorting the
    # trip-count-aware HLO analysis (and XLA:CPU cannot run the compiled
    # kernels anyway).  The kernel suite's data movement enters through the
    # roofline's fused_embedding_adjustment instead.
    tcfg = lm_trainer.LMTrainerConfig(use_kernels=False)
    multi_pod = "pod" in mesh.axis_names
    pol = sharding.default_policy(arch, multi_pod=multi_pod,
                                  override=policy_override,
                                  model_size=mesh.shape["model"])
    state_sds = jax.eval_shape(
        functools.partial(lm_trainer.init_state, cfg=cfg, tcfg=tcfg),
        jax.random.PRNGKey(0),
    )
    state_spec = sharding.state_pspecs(cfg, pol, tcfg, state_shapes=state_sds)
    state_sh = sharding.to_named(state_spec, mesh)

    if shape["kind"] in ("train", "prefill"):
        # prefill lowers the same full-sequence program as training but
        # without the optimizer; we lower train_step for 'train' and a
        # forward-only loss for 'prefill'.
        batch_sds = common.input_specs(cfg, shape_name)
        batch_spec = sharding.batch_pspecs(batch_sds, cfg, pol, mesh)
        batch_sh = sharding.to_named(batch_spec, mesh)
        if shape["kind"] == "train":
            fn = lm_trainer.make_train_step(cfg, tcfg)
            jitted = jax.jit(
                fn,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, NamedSharding(mesh, P())),
                donate_argnums=(0,),
            )
            args = (state_sds, batch_sds)
        else:
            eval_fn = lm_trainer.make_eval_step(cfg)
            jitted = jax.jit(
                eval_fn,
                in_shardings=(state_sh, batch_sh),
                out_shardings=NamedSharding(mesh, P()),
            )
            args = (state_sds, batch_sds)
    else:  # decode
        b, t = shape["global_batch"], shape["seq_len"]
        cache_sds = jax.eval_shape(
            lambda: tfm.init_cache(cfg, b, t)
        )
        cache_spec = sharding.cache_pspecs(cfg, pol, b, mesh)
        cache_sh = sharding.to_named(cache_spec, mesh)
        dp = sharding._dp_or_none(pol, b, mesh)
        tok_sh = NamedSharding(mesh, P(dp))
        scalar_sh = NamedSharding(mesh, P())
        table_sh = sharding.to_named(
            sharding.table_pspecs(cfg, pol, tcfg.row_optimizer), mesh
        )
        params_sh = sharding.to_named(sharding.param_pspecs(cfg, pol), mesh)
        serve = make_serve_step(cfg, tcfg)
        jitted = jax.jit(
            serve,
            in_shardings=(params_sh, table_sh, tok_sh, cache_sh, scalar_sh),
            out_shardings=(NamedSharding(mesh, P()), cache_sh),
            donate_argnums=(3,),
        )
        args = (
            state_sds.params,
            state_sds.table,
            jax.ShapeDtypeStruct((b,), jnp.int32),
            cache_sds,
            jax.ShapeDtypeStruct((), jnp.int32),
        )
    return cfg, pol, jitted, args


def model_flops(cfg: tfm.ModelConfig, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N_active*D for training, 2*N_active*D for one fwd/token."""
    shape = common.SHAPES[shape_name]
    n_active = _active_params(cfg)
    if shape["kind"] == "train":
        tokens = shape["seq_len"] * shape["global_batch"]
        return 6.0 * n_active * tokens
    if shape["kind"] == "prefill":
        tokens = shape["seq_len"] * shape["global_batch"]
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape["global_batch"]  # one token per sequence


def _active_params(cfg: tfm.ModelConfig) -> float:
    """Parameters touched per token (MoE counts top_k + shared experts only)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    hd = cfg.hd
    total = v * d if not cfg.tie_embeddings else v * d  # embed (+head if untied)
    if not cfg.tie_embeddings:
        total += v * d
    for layer in range(cfg.n_layers):
        pos = layer % cfg.period
        if cfg.layer_type(pos) == "attn":
            total += d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
            total += cfg.n_heads * hd * d
        else:
            s = cfg.ssm
            total += d * s.proj_width + s.conv_width * s.conv_dim + s.d_inner * d
        if cfg.is_moe(pos):
            m = cfg.moe
            total += m.top_k * 3 * d * m.d_ff + d * m.n_experts
            if m.n_shared_experts:
                total += 3 * d * m.shared_hidden
        elif f > 0:
            total += (3 if cfg.mlp_type == "swiglu" else 2) * d * f
    return float(total)


def _param_bytes(cfg: tfm.ModelConfig) -> float:
    """Total parameter bytes (bf16 dense + int8 codes + f32 Delta for LPT)."""
    d, v = cfg.d_model, cfg.vocab_size
    dense = 0.0
    for layer in range(cfg.n_layers):
        pos = layer % cfg.period
        if cfg.layer_type(pos) == "attn":
            h, kv = cfg.padded_heads
            dense += d * (h + 2 * kv) * cfg.hd + h * cfg.hd * d
        else:
            s = cfg.ssm
            dense += d * s.proj_width + s.conv_width * s.conv_dim + s.d_inner * d
        if cfg.is_moe(pos):
            m = cfg.moe
            dense += m.n_experts * 3 * d * m.d_ff + d * m.n_experts
            dense += 3 * d * m.shared_hidden if m.n_shared_experts else 0
        elif cfg.d_ff > 0:
            dense += (3 if cfg.mlp_type == "swiglu" else 2) * d * cfg.d_ff
    if not cfg.tie_embeddings:
        dense += v * d
    bytes_total = dense * 2  # bf16
    if methods.get(cfg.embedding_method).is_integer_table:
        bytes_total += v * d * 1 + v * 4  # int8 codes + f32 Delta
        bytes_total += v * d * 8  # row-adam mu/nu f32 (paper's Adam)
    else:
        bytes_total += v * d * 4
    bytes_total += dense * 8  # dense-param Adam mu/nu f32
    return bytes_total


def analytic_memory(cfg: tfm.ModelConfig, shape_name: str, n_chips: int,
                    pol) -> dict:
    """TPU-model HBM estimate per device: parameters/optimizer sharded per
    policy + scan-saved activations + decode cache.  The XLA:CPU
    memory_analysis is kept alongside but its buffer assignment (f32
    promotion, weak fusion, double-buffered wide loops) is not representative
    of TPU HBM (DESIGN.md §7)."""
    shape = common.SHAPES[shape_name]
    model_shards = 16  # 'model' axis
    data_shards = n_chips // model_shards
    p_bytes = _param_bytes(cfg)
    # tp: params+opt sharded over model only; fsdp_tp: over the whole mesh.
    shard = n_chips if pol.fsdp else model_shards
    per_dev_params = p_bytes / shard
    act = 0.0
    if shape["kind"] in ("train", "prefill"):
        if pol.pure_dp:
            data_shards = n_chips
        b_local = max(shape["global_batch"] // data_shards, 1)
        t = shape["seq_len"]
        # Remat: one carry per layer group + 2 passes live working set.
        carries = cfg.n_groups * b_local * t * cfg.d_model * 2
        if pol.seq_parallel:
            carries /= model_shards  # sequence-parallel saved activations
        act += carries
        act += 8 * b_local * t * cfg.d_model * 4  # live f32 working set
        if shape["kind"] == "train" and methods.get(
                cfg.embedding_method).has_learned_step:
            act *= 2  # ALPT Delta second pass conservatively not shared
    else:
        b = shape["global_batch"]
        b_local = max(b // data_shards, 1) if b >= data_shards else b
        kv_len = tfm.cache_len_for(cfg, shape["seq_len"])
        _, kv = cfg.padded_heads
        n_attn = sum(
            1 for l in range(cfg.n_layers) if cfg.layer_type(l % cfg.period) == "attn"
        )
        hd_shard = model_shards if cfg.hd % model_shards == 0 else 1
        act += n_attn * 2 * b_local * kv_len * kv * cfg.hd * 2 / hd_shard
        n_mamba = cfg.n_layers - n_attn
        if n_mamba and cfg.ssm:
            s = cfg.ssm
            act += n_mamba * b_local * s.n_heads * s.headdim * s.d_state * 4 / (
                model_shards if s.n_heads % model_shards == 0 else 1
            )
    total = per_dev_params + act
    return {
        "params_bytes_per_dev": per_dev_params,
        "activation_bytes_per_dev": act,
        "total_bytes_per_dev": total,
        "fits_16gb": bool(total < 16e9),
    }


def roofline(hlo_stats: dict, n_chips: int, cfg, shape_name: str,
             use_kernels: bool = True, embed_shards: int = 1) -> dict:
    """Three-term roofline from the trip-count-aware HLO analysis.

    All inputs are per-device per-step (the SPMD module's shapes are local):
      compute term    = device_FLOPs / peak_FLOP/s
      memory term     = device_HBM_bytes / HBM_bw
      collective term = device_wire_bytes / link_bw

    ``use_kernels`` applies the fused-embedding byte adjustment
    (hlo_analysis.fused_embedding_adjustment): the lowered HLO carries the
    unfused write-back, but the kernel path moves 1 B in / 1 B out per code
    element, so the memory term is corrected to the data movement training
    actually performs on TPU.
    """
    flops = hlo_stats["flops"]
    mem = hlo_stats["hbm_bytes"]
    interior = hlo_stats.get("attn_interior_bytes", 0.0)
    cbytes = float(hlo_stats["collectives"].get("total", 0))
    compute_s = flops / PEAK_FLOPS
    embed_delta = 0.0
    method = methods.get(cfg.embedding_method)
    if (use_kernels and method.is_integer_table
            and common.SHAPES[shape_name]["kind"] == "train"):
        # Every term here is per-device; the write-back delta divides by
        # however many ways the caller's mesh shards the vocab table
        # (run_cell passes the mesh's 'model' axis size; 1 = replicated).
        adj = hlo_analysis.fused_embedding_adjustment(
            cfg.vocab_size, cfg.d_model,
            learned_step=method.has_learned_step,
        )
        embed_delta = adj["delta_bytes"] / max(embed_shards, 1)
    # Fused-adjusted: attention/SSD interiors run in VMEM on TPU (Pallas),
    # and the embedding write-back runs through the fused kernel suite.
    memory_s = (mem - interior - embed_delta) / HBM_BW
    collective_s = cbytes / LINK_BW
    mf = model_flops(cfg, shape_name)
    hlo_total = flops * n_chips
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "memory_s_raw": mem / HBM_BW,
        "embed_fused_delta_bytes": embed_delta,
        "use_kernels": use_kernels,
        "collective_s": collective_s,
        "model_flops": mf,
        "hlo_flops_per_chip": flops,
        "hlo_flops_total": hlo_total,
        "useful_flops_ratio": (mf / hlo_total) if hlo_total else 0.0,
        "bottleneck": max(
            [("compute", compute_s), ("memory", memory_s),
             ("collective", collective_s)],
            key=lambda kv: kv[1],
        )[0],
    }
    dom = max(compute_s, memory_s, collective_s)
    terms["step_time_lower_bound_s"] = dom
    # Fraction of the chips' peak that the *useful* model FLOPs would reach if
    # the step ran exactly at the dominant-term bound (an MFU-style score).
    terms["roofline_fraction"] = (mf / n_chips / PEAK_FLOPS) / dom if dom else 0.0
    return terms


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, policy=None,
             embedding=None, save: bool = True, use_kernels: bool = True) -> dict:
    skip = configs.skip_shapes(arch)
    mesh_tag = "pod512" if multi_pod else "pod256"
    cell_id = f"{arch}__{shape_name}__{mesh_tag}" + (
        f"__{policy}" if policy else ""
    ) + (f"__emb-{embedding}" if embedding else "")
    out: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                 "policy": policy, "embedding": embedding}
    if shape_name in skip:
        out["status"] = "skipped"
        out["reason"] = skip[shape_name]
        _save(cell_id, out, save)
        return out
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        cfg, pol, jitted, args = build_cell(arch, shape_name, mesh, policy,
                                            embedding)
        out["policy"] = pol.name
        with mesh, dist_ctx.use(mesh, pol):
            lowered = jitted.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
        hlo = compiled.as_text()
        stats = hlo_analysis.analyze(hlo)
        cost = hlo_analysis.cost_summary(compiled)  # XLA's (not trip-aware)
        mem = hlo_analysis.memory_summary(compiled)
        n_chips = mesh.devices.size
        out.update(
            status="ok",
            lower_s=round(t_lower - t0, 1),
            compile_s=round(t_compile - t_lower, 1),
            n_chips=n_chips,
            hlo_stats=stats,
            xla_cost=cost,
            memory=mem,
            analytic_memory=analytic_memory(cfg, shape_name, n_chips, pol),
            collectives=stats["collectives"],
            roofline=roofline(stats, n_chips, cfg, shape_name,
                              use_kernels=use_kernels,
                              embed_shards=dict(mesh.shape).get("model", 1)),
        )
        out["fits_16gb_hbm"] = out["analytic_memory"]["fits_16gb"]
        mem_total = mem.get("total_bytes_per_device")
        if mem_total is not None:
            out["xla_cpu_bytes_per_device"] = mem_total
        print(f"[dryrun] {cell_id}: OK "
              f"(lower {out['lower_s']}s, compile {out['compile_s']}s, "
              f"bottleneck={out['roofline']['bottleneck']})")
    except Exception as e:  # noqa: BLE001 — a failing cell is a recorded bug
        out["status"] = "error"
        out["error"] = f"{type(e).__name__}: {e}"
        out["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {cell_id}: FAILED {out['error']}")
    _save(cell_id, out, save)
    return out


def _save(cell_id: str, out: dict, save: bool):
    if not save:
        return
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    (RESULTS_DIR / f"{cell_id}.json").write_text(json.dumps(out, indent=2))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(configs.ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(common.SHAPES), default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument(
        "--policy",
        choices=["tp", "fsdp_tp", "dp", "tp_sp", "fsdp_tp_sp", "fsdp_tp_ep",
                 "tp_ep"],
        default=None,
        help="sharding policy override (§Perf variants: dp = model axis as "
             "extra data parallelism + ZeRO-1; *_sp = sequence-parallel "
             "scan carries)",
    )
    ap.add_argument("--embedding", choices=sorted(methods.available()),
                    default=None,
                    help="override the embedding method (any registered "
                         "repro.methods name; amortized-ALPT §Perf "
                         "accounting pairs an alpt cell with an lpt cell)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true", help="re-run cached cells")
    ap.add_argument(
        "--no-kernels", action="store_true",
        help="roofline the unfused embedding write-back (default accounts "
        "the fused kernel suite's 1B-in/1B-out data movement)",
    )
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for arch in sorted(configs.ARCHS):
            for shape in common.SHAPES:
                for mp in (False, True):
                    cells.append((arch, shape, mp, None))
    elif args.arch and args.shape:
        cells.append((args.arch, args.shape, args.multipod, args.policy,
                      args.embedding))
    else:
        ap.error("need --arch and --shape, or --all / --list")

    if args.list:
        for c in cells:
            print(c)
        return 0

    failures = 0
    for cell in cells:
        arch, shape, mp, pol = cell[:4]
        emb = cell[4] if len(cell) > 4 else None
        mesh_tag = "pod512" if mp else "pod256"
        cell_id = (f"{arch}__{shape}__{mesh_tag}"
                   + (f"__{pol}" if pol else "")
                   + (f"__emb-{emb}" if emb else ""))
        path = RESULTS_DIR / f"{cell_id}.json"
        if path.exists() and not args.force:
            prev = json.loads(path.read_text())
            if prev.get("status") in ("ok", "skipped"):
                print(f"[dryrun] {cell_id}: cached ({prev['status']})")
                continue
        res = run_cell(arch, shape, multi_pod=mp, policy=pol, embedding=emb,
                       use_kernels=not args.no_kernels)
        if res["status"] == "error":
            failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
