"""Post-compilation HLO analysis for §Roofline: FLOPs, HBM bytes and
collective wire-bytes PER DEVICE PER STEP, with while-loop bodies multiplied
by their trip counts.

Why not compiled.cost_analysis()?  XLA:CPU's HloCostAnalysis visits a while
body once — a 95-layer scan would be undercounted 95x.  We parse the
optimized (SPMD-partitioned, post-fusion) HLO text instead:

  * FLOPs    — every ``dot`` (2 * output_elems * contraction_size), traversing
    fusion bodies, x trip count of enclosing whiles.
  * HBM bytes — per *kernel* (top-level op or fusion call): operand bytes +
    output bytes, skipping pure-metadata ops; fusion interiors are registers,
    not HBM traffic, so fusion bodies are NOT byte-counted.
  * Collective wire bytes — per-device send/receive volume with ring
    conventions: all-gather -> output, all-reduce -> 2x output,
    reduce-scatter/all-to-all/collective-permute -> operand bytes.

Shapes in the partitioned module are per-device, so every number is
per-device per-step.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_DEF_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\(?\s*(pred|[suf]\d+|bf16|f16|c64|c128)"
    r"\[([\d,]*)\]"
)
# The result type may be a tuple containing "/*index=N*/" comments, so the op
# is simply the FIRST "word(" token after the '=' (types never have parens
# directly after a word; operands are bare %names).
_OP_RE = re.compile(r"=\s.*?\s([a-z][a-z0-9\-]*)\(", re.DOTALL)
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "custom-call", "copy-start", "copy-done", "send", "recv",
    "send-done", "recv-done", "domain", "opt-barrier",
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


class _Comp:
    def __init__(self, name):
        self.name = name
        self.lines: list[str] = []
        self.symtab: dict[str, tuple[str, str]] = {}  # name -> (dtype, dims)


def _parse(hlo: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*[(\s].*\{\s*$", line)
            if m:
                cur = _Comp(m.group(1))
                comps[cur.name] = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
        if cur is None:
            continue
        s = line.strip()
        if s == "}" or s.startswith("//"):
            cur = None if s == "}" else cur
            continue
        cur.lines.append(s)
        dm = _DEF_RE.match(s)
        if dm:
            cur.symtab[dm.group(1)] = (dm.group(2), dm.group(3))
        else:
            # Tuple-typed results (while etc.): record name with no shape.
            tm = re.match(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=", s)
            if tm:
                cur.symtab.setdefault(tm.group(1), (None, None))
    return comps


def _op_of(line: str) -> str | None:
    # Strip metadata (it can contain op names in strings).
    body = line.split(", metadata=")[0]
    m = _OP_RE.search(body)
    return m.group(1) if m else None


# --------------------------------------------------------------------------
# Fused-interior attribution.  On a real TPU the flash-attention and SSD
# chunk interiors run as fused (Pallas) kernels whose probability / decay
# matrices never touch HBM; the XLA:CPU lowering materializes them.  The ops
# carry their einsum subscripts in op_name metadata, and those subscripts are
# unique to layers.py/ssm.py interiors — we classify on them and report the
# memory term both raw and fused-adjusted (EXPERIMENTS.md §Roofline).
# --------------------------------------------------------------------------

_INTERIOR_SIGS = (
    # flash attention (layers.py): scores / pv / backward dp, dk, dq
    "bqhgd,bkhd->bhgqk", "bhgqk,bkhd->bhgqd", "bhgqk,bqhgd->bkhd",
    "bhgqk,bkhd->bqhgd",
    # mamba2 SSD chunk interior (ssm.py): CB, decay-combine, state in/out
    "bin,bjn->bij", "bij,bijh,bjhp->bihp", "bin,bhpn,bih->bihp",
    "bjh,bjn,bjhp->bhpn",
)

_OPNAME_RE = re.compile(r'op_name="([^"]+)"')


def _interior_classifier(hlo: str):
    """Line classifier + the set of computations that are fully interior
    (e.g. the flash kv-scan while bodies, whose every dot is signature-
    matched — their elementwise fusions belong to the same fused kernel)."""

    def line_sig(line: str) -> bool:
        m = _OPNAME_RE.search(line)
        return bool(m) and any(sig in m.group(1) for sig in _INTERIOR_SIGS)

    return line_sig


def _interior_comps(comps) -> set:
    out = set()
    for name, comp in comps.items():
        dots = [l for l in comp.lines if _op_of(l) == "dot"]
        if not dots:
            continue
        sig_dots = [l for l in dots if _OPNAME_RE.search(l)
                    and any(s in _OPNAME_RE.search(l).group(1)
                            for s in _INTERIOR_SIGS)]
        if sig_dots and len(sig_dots) == len(dots):
            out.add(name)
    return out


def _operand_names(line: str) -> list[str]:
    try:
        inner = line[line.index("(") + 1 :]
    except ValueError:
        return []
    depth = 1
    end = 0
    for i, ch in enumerate(inner):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERANDS_RE.findall(inner[:end])


def _bytes_of(name: str, symtab) -> int:
    ent = symtab.get(name)
    if not ent or ent[0] is None:
        return 0
    return _shape_elems(ent[1]) * _DTYPE_BYTES.get(ent[0], 4)


def _out_bytes(line: str) -> int:
    m = _DEF_RE.match(line)
    if not m:
        return 0
    return _shape_elems(m.group(3)) * _DTYPE_BYTES.get(m.group(2), 4)


def _trip_count(comps, cond_name: str) -> int:
    """Loop bound heuristic: the largest s32 constant in the condition (or in
    computations it fuses into)."""
    best = 0
    seen = set()

    def visit(name):
        nonlocal best
        if name in seen or name not in comps:
            return
        seen.add(name)
        for line in comps[name].lines:
            for m in re.finditer(r"constant\((\d+)\)", line):
                best = max(best, int(m.group(1)))
            cm = re.search(r"calls=%?([\w.\-]+)", line)
            if cm:
                visit(cm.group(1))

    visit(cond_name)
    return max(best, 1)


def _control_calls(comps, comp: _Comp) -> list[tuple[str, int, bool]]:
    """(callee, multiplier, is_fusion) edges out of this computation."""
    out = []
    for line in comp.lines:
        op = _op_of(line)
        if op == "while":
            cm = re.search(r"condition=%?([\w.\-]+)", line)
            bm = re.search(r"body=%?([\w.\-]+)", line)
            if bm:
                trips = _trip_count(comps, cm.group(1)) if cm else 1
                out.append((bm.group(1), trips, False))
        elif op == "conditional":
            bm = re.search(r"branch_computations=\{([^}]*)\}", line)
            if bm:
                for c in bm.group(1).split(","):
                    out.append((c.strip().lstrip("%"), 1, False))
            tm = re.findall(r"(?:true|false)_computation=%?([\w.\-]+)", line)
            for c in tm:
                out.append((c, 1, False))
        elif op == "call":
            cm = re.search(r"to_apply=%?([\w.\-]+)", line)
            if cm:
                out.append((cm.group(1), 1, False))
        elif op == "fusion":
            cm = re.search(r"calls=%?([\w.\-]+)", line)
            if cm:
                out.append((cm.group(1), 1, True))
    return out


def _line_flops(line: str, symtab) -> float:
    op = _op_of(line)
    if op != "dot":
        return 0.0
    out_elems = 0
    m = _DEF_RE.match(line)
    if m:
        out_elems = _shape_elems(m.group(3))
    ops = _operand_names(line)
    if not ops:
        return 0.0
    lhs = symtab.get(ops[0])
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contraction = 1
    if lhs and lhs[1] is not None and cm and cm.group(1):
        dims = lhs[1].split(",") if lhs[1] else []
        for idx in cm.group(1).split(","):
            i = int(idx)
            if i < len(dims):
                contraction *= int(dims[i])
    return 2.0 * out_elems * contraction


def analyze(hlo: str) -> dict:
    """Returns {'flops', 'hbm_bytes', 'collectives': {...}} per device-step."""
    comps = _parse(hlo)
    entry = None
    for line in hlo.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w.\-]+)", line)
        if m:
            entry = m.group(1)
            break
    if entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0, "collectives": {"total": 0}}

    flops_memo: dict[str, float] = {}

    def flops_of(name, stack=()):
        if name in flops_memo:
            return flops_memo[name]
        if name in stack or name not in comps:
            return 0.0
        comp = comps[name]
        total = sum(_line_flops(l, comp.symtab) for l in comp.lines)
        for callee, mult, _ in _control_calls(comps, comp):
            total += mult * flops_of(callee, stack + (name,))
        flops_memo[name] = total
        return total

    is_interior = _interior_classifier(hlo)
    interior_comps = _interior_comps(comps)
    bytes_memo: dict[str, tuple] = {}

    def bytes_of(name, stack=()):
        if name in bytes_memo:
            return bytes_memo[name]
        if name in stack or name not in comps:
            return (0.0, 0.0)
        comp = comps[name]
        fully_interior = name in interior_comps
        total = 0.0
        interior = 0.0
        for line in comp.lines:
            op = _op_of(line)
            if op is None or op in _SKIP_BYTES_OPS:
                continue
            # Output-only x2 accounting: every kernel result is written once
            # and read ~once downstream.  Counting operands instead would
            # charge scan-carried buffers (stacked saved activations, full
            # weight stacks) wholesale to every loop iteration — reads of a
            # slice are already captured by the slice-fusion's own output.
            b = 2.0 * _out_bytes(line)
            total += b
            if fully_interior or is_interior(line):
                interior += b
        for callee, mult, is_fusion in _control_calls(comps, comp):
            if is_fusion:
                continue  # fusion interiors are registers, not HBM
            sub_total, sub_interior = bytes_of(callee, stack + (name,))
            total += mult * sub_total
            # A fully-interior callee (flash kv-scan body) is interior
            # wholesale: its elementwise fusions fuse into the same kernel.
            interior += mult * (sub_total if callee in interior_comps
                                else sub_interior)
        bytes_memo[name] = (total, min(interior, total))
        return bytes_memo[name]

    coll_memo: dict[str, dict] = {}

    def coll_of(name, stack=()):
        if name in coll_memo:
            return coll_memo[name]
        if name in stack or name not in comps:
            return {}
        comp = comps[name]
        out: dict[str, float] = defaultdict(float)
        for line in comp.lines:
            op = _op_of(line)
            if op is None:
                continue
            kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
            if kind is None:
                continue
            operand_b = sum(_bytes_of(o, comp.symtab)
                            for o in _operand_names(line))
            output_b = _out_bytes(line)
            if kind == "all-gather":
                wire = output_b
            elif kind == "all-reduce":
                wire = 2 * output_b
            else:  # reduce-scatter / all-to-all / collective-permute
                wire = operand_b
            out[kind] += wire
        for callee, mult, is_fusion in _control_calls(comps, comp):
            if is_fusion:
                continue
            for k, v in coll_of(callee, stack + (name,)).items():
                out[k] += mult * v
        coll_memo[name] = dict(out)
        return coll_memo[name]

    kinds = coll_of(entry)
    total_b, interior_b = bytes_of(entry)
    return {
        "flops": flops_of(entry),
        "hbm_bytes": total_b,
        "attn_interior_bytes": interior_b,
        "collectives": {"total": sum(kinds.values()), **kinds},
    }


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Back-compat wrapper returning just the collectives dict."""
    return analyze(hlo_text)["collectives"]


def fused_embedding_adjustment(
    vocab: int, d: int, *, learned_step: bool = False
) -> dict[str, float]:
    """HBM bytes the fused kernel path removes from one table write-back.

    The dry-run lowers the *unfused* jnp path (Pallas does not partition
    under the XLA:CPU SPMD lowering), so when ``use_kernels`` is on the
    roofline must re-account the embedding write-back with the kernel
    suite's data movement.  Per table element, under the analyzer's
    output-only x2 convention (each kernel result written once, read ~once):

      unfused (three fp32 round-trips through HBM):
        de-quantized table f32 out (4 B) + updated table f32 out (4 B)
        + re-quantized codes int8 out (1 B)                       -> 2 x 9 B
      fused ``ops.lpt_update`` (one VMEM pass):
        int8 codes out (1 B; the 1 B codes *in* are charged to their
        producer under output-only accounting)                    -> 2 x 1 B
      fused + learned step (ALPT): Algorithm 1 line 4 re-reads the updated
        float rows, so w_new still materializes (4 B out) and only the SR
        write-back fuses                                          -> 2 x 5 B

    Returns ``{'unfused_bytes', 'fused_bytes', 'delta_bytes'}`` for one
    full-table pass; the caller scales nothing (the write-back runs once per
    step) and subtracts ``delta_bytes`` from the HLO memory term.
    """
    elems = float(vocab * d)
    unfused = 2.0 * 9.0 * elems
    fused = 2.0 * (5.0 if learned_step else 1.0) * elems
    return {
        "unfused_bytes": unfused,
        "fused_bytes": fused,
        "delta_bytes": unfused - fused,
    }


def memory_summary(compiled) -> dict[str, float]:
    """Bytes-per-device from compiled.memory_analysis() (None-safe)."""
    ma = None
    try:
        ma = compiled.memory_analysis()
    except (AttributeError, NotImplementedError, RuntimeError):
        pass  # backend exposes no memory analysis for this artifact
    if ma is None:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    if out:
        out["total_bytes_per_device"] = (
            out.get("argument_size_in_bytes", 0.0)
            + out.get("output_size_in_bytes", 0.0)
            + out.get("temp_size_in_bytes", 0.0)
            - out.get("alias_size_in_bytes", 0.0)
        )
    return out


def cost_summary(compiled) -> dict[str, float]:
    """XLA's own cost analysis (NOT trip-count aware; kept for reference)."""
    try:
        ca = compiled.cost_analysis()
    except (AttributeError, NotImplementedError, RuntimeError):
        return {}  # backend exposes no cost analysis for this artifact
    if not ca:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    out = {}
    for k in ("flops", "bytes accessed", "optimal_seconds"):
        if k in ca:
            out[k.replace(" ", "_")] = float(ca[k])
    return out
