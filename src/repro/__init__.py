"""repro — ALPT (AAAI 2023) reproduction + mesh-parallel LM/CTR training.

Platform selection: containers in this project often carry a ``libtpu``
plugin whose TPU discovery retries a cloud metadata server for ~8 minutes
before giving up and falling back to CPU, which breaks every
subprocess-based test (they spawn with clean environments and 300 s
timeouts).  jax initializes its backend lazily — so pinning the platform
here, at package-import time, takes effect for any program that imports
``repro`` before its first jax operation (jax itself may already be
imported; ``jax.config.update`` still applies pre-initialization).  We only
pin ``cpu`` when the user has not chosen a platform explicitly and no TPU
device is visible on the host.
"""
import os as _os


def _tpu_plausible() -> bool:
    if _os.environ.get("TPU_NAME") or _os.environ.get("TPU_WORKER_ID"):
        return True
    for dev in ("/dev/accel0", "/dev/vfio/0"):
        if _os.path.exists(dev):
            return True
    return False


if "JAX_PLATFORMS" not in _os.environ and not _tpu_plausible():
    # For our own child processes (dry-run cells, serve workers).
    _os.environ["JAX_PLATFORMS"] = "cpu"
    try:  # For this process, even if jax was imported first.
        import jax as _jax

        _jax.config.update("jax_platforms", "cpu")
    except (ImportError, RuntimeError, ValueError):
        pass  # backend already initialized — leave it alone

del _os
