"""Pallas TPU kernel: fused clip + stochastic-round + int8 pack (LPT write-back).

Implements Eq. (1)/(4): codes = SR(clip(w / Delta, -2^{m-1}, 2^{m-1}-1)).

Two noise sources:
  * ``sr_round``      — uniform noise passed as an operand.  Bit-exact against
    the jnp oracle, used everywhere correctness matters (and in CPU tests).
  * ``sr_round_seeded`` — on-chip ``pltpu.prng_random_bits`` seeded per tile;
    saves the noise operand's HBM traffic (the production TPU path).

The op is elementwise -> pure bandwidth; tiles are (row_block, col_block)
VMEM blocks, (8, 128)-aligned on real shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro._compat.jax_shim import ensure_pallas_interpret_params

ensure_pallas_interpret_params()


def _kernel(w_ref, step_ref, noise_ref, out_ref, *, lo: int, hi: int):
    w = w_ref[...].astype(jnp.float32)
    step = step_ref[...].astype(jnp.float32)  # (rb, 1) broadcast over lanes
    scaled = jnp.clip(w / step, lo, hi)
    base = jnp.floor(scaled)
    up = (scaled - base > noise_ref[...]).astype(jnp.float32)
    out_ref[...] = jnp.clip(base + up, lo, hi).astype(jnp.int8)


def _kernel_seeded(seed_ref, w_ref, step_ref, out_ref, *, lo: int, hi: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    pltpu.prng_seed(seed_ref[0], i, j)
    w = w_ref[...].astype(jnp.float32)
    step = step_ref[...].astype(jnp.float32)
    scaled = jnp.clip(w / step, lo, hi)
    base = jnp.floor(scaled)
    bits = pltpu.prng_random_bits(w.shape)
    # uniform [0, 1) from the top 24 bits (exact float32 representation).
    u = (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    up = (scaled - base > u).astype(jnp.float32)
    out_ref[...] = jnp.clip(base + up, lo, hi).astype(jnp.int8)


def _blocks(rows: int, cols: int, row_block: int, col_block: int):
    rb = min(row_block, rows)
    cb = min(col_block, cols)
    if rows % rb or cols % cb:
        raise ValueError(f"shape ({rows},{cols}) not divisible by ({rb},{cb})")
    return rb, cb


def sr_round(
    w: jax.Array,  # f32 [r, c]
    step: jax.Array,  # f32 [r] per-row Delta
    noise: jax.Array,  # f32 [r, c] uniform [0,1)
    bits: int,
    *,
    row_block: int = 256,
    col_block: int = 512,
    interpret: bool = False,
) -> jax.Array:
    rows, cols = w.shape
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    rb, cb = _blocks(rows, cols, row_block, col_block)
    grid = (rows // rb, cols // cb)
    fn = pl.pallas_call(
        lambda a, b, c, o: _kernel(a, b, c, o, lo=lo, hi=hi),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
            pl.BlockSpec((rb, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.int8),
        interpret=interpret,
    )
    return fn(w, step.reshape(rows, 1), noise)


def sr_round_seeded(
    w: jax.Array,
    step: jax.Array,
    seed: jax.Array,  # int32 scalar
    bits: int,
    *,
    row_block: int = 256,
    col_block: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """On-chip PRNG variant (no noise operand -> 1/3 less input traffic)."""
    rows, cols = w.shape
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    rb, cb = _blocks(rows, cols, row_block, col_block)
    if getattr(type(interpret), "_compat_stub", False):
        # TPU-semantics interpretation requested on a jax without the TPU
        # interpreter: reproduce its documented behavior (prng_random_bits
        # stubbed to zeros -> u == 0) with the reference formula.
        scaled = jnp.clip(
            w.astype(jnp.float32) / step.astype(jnp.float32)[:, None], lo, hi
        )
        base = jnp.floor(scaled)
        up = (scaled - base > 0.0).astype(jnp.float32)
        return jnp.clip(base + up, lo, hi).astype(jnp.int8)
    grid = (rows // rb, cols // cb)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, cb), lambda i, j, s: (i, j)),
            pl.BlockSpec((rb, 1), lambda i, j, s: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rb, cb), lambda i, j, s: (i, j)),
    )
    fn = pl.pallas_call(
        lambda s, a, b, o: _kernel_seeded(s, a, b, o, lo=lo, hi=hi),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.int8),
        interpret=interpret,
    )
    return fn(seed.reshape(1).astype(jnp.int32), w, step.reshape(rows, 1))
