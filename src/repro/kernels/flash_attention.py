"""Pallas TPU kernel: fused flash-attention forward (GQA + causal + SWA).

This is the §Perf lever identified by the roofline analysis: the pure-JAX
flash formulation (models/layers.py) bounds live MEMORY but its probability
matrices still round-trip HBM in the XLA lowering; this kernel keeps the
entire online-softmax interior in VMEM — HBM traffic collapses to q, k, v in
and o out, which is what EXPERIMENTS.md §Roofline's fused-adjusted memory
term models.

Layout: q [BH, T, D], k/v [BKH, S, D] (batch*heads flattened so GQA group
mapping is a pure index computation).  Grid (BH, nq, nk), kv innermost; the
accumulator/max/denominator live in VMEM scratch across the kv sweep and the
output block is written once on the last visited kv block.  Causal/SWA blocks
outside the footprint are skipped with pl.when (no MXU work issued).

Blocks default to (128, head_dim) — (8,128)-lane aligned for the MXU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window, bq: int, bk: int, nk: int,
            s_true: int, t_true: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q0 = qi * bq
    k0 = ki * bk
    # Static-shape footprint test is done on traced ids via pl.when.
    in_footprint = jnp.asarray(True)
    if causal:
        in_footprint &= k0 <= q0 + bq - 1
    if window is not None:
        in_footprint &= k0 + bk - 1 >= q0 - window + 1

    @pl.when(in_footprint)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale  # [bq, D]
        k = k_ref[0].astype(jnp.float32)  # [bk, D]
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [bq, bk]
        q_ids = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_ids = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = (k_ids < s_true) & (q_ids < t_true)
        if causal:
            mask &= q_ids >= k_ids
        if window is not None:
            mask &= q_ids - k_ids < window
        scores = jnp.where(mask, scores, NEG_INF)
        m_prev = m_ref[...][:, 0]  # [bq]
        m_new = jnp.maximum(m_prev, scores.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = (l_ref[...][:, 0] * alpha + p.sum(axis=1))[:, None]
        v = v_ref[0].astype(jnp.float32)  # [bk, D]
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new[:, None]

    @pl.when(ki == nk - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...][:, 0], 1e-20)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,  # [B, T, H, D]
    k: jax.Array,  # [B, S, KH, D]
    v: jax.Array,  # [B, S, KH, D]
    *,
    causal: bool = True,
    window: int | None = None,
    q_block: int = 128,
    k_block: int = 128,
    softmax_scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused flash forward; returns [B, T, H, D]."""
    b, t, h, d = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    bq = min(q_block, t)
    bk = min(k_block, s)
    t_pad = -(-t // bq) * bq
    s_pad = -(-s // bk) * bk
    # [BH, T, D] / [BKH, S, D] layouts.
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, t, d)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * kh, s, d)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * kh, s, d)
    if t_pad != t:
        qf = jnp.pad(qf, ((0, 0), (0, t_pad - t), (0, 0)))
    if s_pad != s:
        kf = jnp.pad(kf, ((0, 0), (0, s_pad - s), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, s_pad - s), (0, 0)))
    nq = t_pad // bq
    nk = s_pad // bk

    def kv_index(bhi):
        return (bhi // h) * kh + (bhi % h) // g

    grid = (b * h, nq, nk)
    fn = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window, bq=bq, bk=bk,
            nk=nk, s_true=s, t_true=t,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bhi, qi, ki: (bhi, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bhi, qi, ki: (kv_index(bhi), ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bhi, qi, ki: (kv_index(bhi), ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bhi, qi, ki: (bhi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )
    of = fn(qf, kf, vf)[:, :t]
    return jnp.moveaxis(of.reshape(b, h, t, d), 1, 2)
