"""Pallas TPU kernel: fused CTR sparse embedding step (paper Eq. 8, row form).

One ``pallas_call`` over the batch's *unique* rows fuses the whole
``lpt.sparse_apply`` hot loop:

    gather int8 codes + Adam slots  ->  de-quantize  ->  Adam row step
    ->  SR re-quantize  ->  scatter codes/slots back in place

The scalar-prefetched unique ids drive both the input and the output
``BlockSpec`` index maps, so each grid step DMAs exactly one touched row in
and writes that row back (``input_output_aliases`` — the scatter is the
aliased write, not a separate XLA scatter).  Per touched element the HBM
traffic is: 1 B codes in, 1 B codes out, 4 B each for the grad / noise / mu /
nu operands — the de-quantized fp32 rows and the intermediate ``w``/``w_new``
never exist in HBM.  The updated float rows are emitted as a dense [K, d]
output because ALPT's Delta sub-step (Algorithm 1 line 4) re-reads them.

Sentinel handling: ``jnp.unique(size=)`` pads with an out-of-range sentinel.
The caller must point sentinels at a dedicated *scratch row* (the
``pad_to_tiles`` policy allocates one past the id space) — sentinel steps then
read/write only that dead row, so duplicate sentinel writes cannot corrupt
live state under the TPU DMA pipeline.

Adam bias corrections ``c1 = 1 - b1^t`` / ``c2 = 1 - b2^t`` are computed by
the caller (they are per-step scalars) and prefetched to SMEM with ``lr``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.codestore import pack_codes, unpack_codes


def _kernel(ids_ref, scal_ref, codes_ref, step_ref, mu_ref, nu_ref, g_ref,
            noise_ref, out_codes, out_mu, out_nu, out_w, *,
            lo: int, hi: int, weight_decay: float, b1: float, b2: float,
            eps: float, bits: int = 8, d: int = 0):
    lr = scal_ref[0]
    c1 = scal_ref[1]
    c2 = scal_ref[2]
    packed = d > 0  # packed container: codes blocks are uint8 [1, w]
    if packed:
        codes = unpack_codes(codes_ref[...], bits, d).astype(jnp.float32)
    else:
        codes = codes_ref[...].astype(jnp.float32)
    w = codes * step_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    mu = b1 * mu_ref[...] + (1.0 - b1) * g
    nu = b2 * nu_ref[...] + (1.0 - b2) * jnp.square(g)
    upd = (mu / c1) / (jnp.sqrt(nu / c2) + eps)
    if weight_decay:
        upd = upd + weight_decay * w
    w_new = w - lr * upd
    scaled = jnp.clip(w_new / step_ref[...].astype(jnp.float32), lo, hi)
    base = jnp.floor(scaled)
    up = (scaled - base > noise_ref[...]).astype(jnp.float32)
    codes_new = jnp.clip(base + up, lo, hi).astype(jnp.int8)
    # Re-pack on the aliased scatter: the updated row leaves VMEM as packed
    # bytes, so the HBM write stays at bits/8 bytes per code.
    out_codes[...] = pack_codes(codes_new, bits) if packed else codes_new
    out_mu[...] = mu
    out_nu[...] = nu
    out_w[...] = w_new


def sparse_row_update(
    codes: jax.Array,  # int8 [N, d] (N > every id in uniq, incl. sentinels)
    step: jax.Array,  # f32 [N]
    mu: jax.Array,  # f32 [N, d] Adam first moment
    nu: jax.Array,  # f32 [N, d] Adam second moment
    uniq: jax.Array,  # int32 [K] unique ids; sentinels mapped to a scratch row
    g_sum: jax.Array,  # f32 [K, d] summed per-unique-row gradients
    noise: jax.Array,  # f32 [K, d] uniform [0,1)
    lr: jax.Array,  # f32 scalar
    c1: jax.Array,  # f32 scalar 1 - b1^t
    c2: jax.Array,  # f32 scalar 1 - b2^t
    bits: int,
    *,
    weight_decay: float = 0.0,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    interpret: bool = False,
):
    """Returns ``(codes', mu', nu', w_new_rows)`` — table-shaped outputs are
    the aliased in-place scatters; ``w_new_rows`` is [K, d] f32."""
    n, d = codes.shape
    k = uniq.shape[0]
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # (uniq ids, [lr, c1, c2])
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, ids, s: (ids[i], 0)),
            pl.BlockSpec((1, 1), lambda i, ids, s: (ids[i], 0)),
            pl.BlockSpec((1, d), lambda i, ids, s: (ids[i], 0)),
            pl.BlockSpec((1, d), lambda i, ids, s: (ids[i], 0)),
            pl.BlockSpec((1, d), lambda i, ids, s: (i, 0)),
            pl.BlockSpec((1, d), lambda i, ids, s: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i, ids, s: (ids[i], 0)),
            pl.BlockSpec((1, d), lambda i, ids, s: (ids[i], 0)),
            pl.BlockSpec((1, d), lambda i, ids, s: (ids[i], 0)),
            pl.BlockSpec((1, d), lambda i, ids, s: (i, 0)),
        ],
    )
    fn = pl.pallas_call(
        functools.partial(
            _kernel, lo=lo, hi=hi, weight_decay=weight_decay, b1=b1, b2=b2,
            eps=eps,
        ),
        grid_spec=spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.int8),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
        ],
        # Operand indices count the scalar-prefetch args: 2=codes, 4=mu, 5=nu.
        input_output_aliases={2: 0, 4: 1, 5: 2},
        interpret=interpret,
    )
    scal = jnp.stack(
        [jnp.asarray(lr, jnp.float32), jnp.asarray(c1, jnp.float32),
         jnp.asarray(c2, jnp.float32)]
    )
    return fn(
        uniq.astype(jnp.int32), scal, codes, step.reshape(n, 1), mu, nu,
        g_sum, noise,
    )


def sparse_row_update_packed(
    packed: jax.Array,  # uint8 [N, w] packed container (w = ceil(d*bits/8))
    step: jax.Array,  # f32 [N]
    mu: jax.Array,  # f32 [N, d]
    nu: jax.Array,  # f32 [N, d]
    uniq: jax.Array,  # int32 [K]
    g_sum: jax.Array,  # f32 [K, d]
    noise: jax.Array,  # f32 [K, d]
    lr: jax.Array,
    c1: jax.Array,
    c2: jax.Array,
    bits: int,
    d: int,
    *,
    weight_decay: float = 0.0,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    interpret: bool = False,
):
    """Packed-container twin of :func:`sparse_row_update`.

    Each grid step DMAs one packed uint8 row (w bytes) in, unpacks in VMEM,
    runs the identical Adam + SR body on the int8 codes, re-packs, and writes
    the packed row back through the same ``input_output_aliases`` scatter —
    bits/8 bytes per code of HBM code traffic in each direction.  Returns
    ``(packed', mu', nu', w_new_rows)``.
    """
    n, w = packed.shape
    k = uniq.shape[0]
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # (uniq ids, [lr, c1, c2])
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, w), lambda i, ids, s: (ids[i], 0)),
            pl.BlockSpec((1, 1), lambda i, ids, s: (ids[i], 0)),
            pl.BlockSpec((1, d), lambda i, ids, s: (ids[i], 0)),
            pl.BlockSpec((1, d), lambda i, ids, s: (ids[i], 0)),
            pl.BlockSpec((1, d), lambda i, ids, s: (i, 0)),
            pl.BlockSpec((1, d), lambda i, ids, s: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, w), lambda i, ids, s: (ids[i], 0)),
            pl.BlockSpec((1, d), lambda i, ids, s: (ids[i], 0)),
            pl.BlockSpec((1, d), lambda i, ids, s: (ids[i], 0)),
            pl.BlockSpec((1, d), lambda i, ids, s: (i, 0)),
        ],
    )
    fn = pl.pallas_call(
        functools.partial(
            _kernel, lo=lo, hi=hi, weight_decay=weight_decay, b1=b1, b2=b2,
            eps=eps, bits=bits, d=d,
        ),
        grid_spec=spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, w), jnp.uint8),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
        ],
        input_output_aliases={2: 0, 4: 1, 5: 2},
        interpret=interpret,
    )
    scal = jnp.stack(
        [jnp.asarray(lr, jnp.float32), jnp.asarray(c1, jnp.float32),
         jnp.asarray(c2, jnp.float32)]
    )
    return fn(
        uniq.astype(jnp.int32), scal, packed, step.reshape(n, 1), mu, nu,
        g_sum, noise,
    )
