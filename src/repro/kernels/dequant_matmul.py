"""Pallas TPU kernel: fused de-quantize x int8-weight matmul (quantized LM head).

Computes  y[M, N] = x[M, K] @ (Delta[N] * W~[N, K])^T  without ever writing the
de-quantized table to HBM: each (bn, bk) int8 weight tile is scaled in VMEM
immediately before the MXU contraction.  Used for the tied quantized output
head (beyond-paper optimization; see DESIGN.md §2) where N = vocab.

Arithmetic intensity vs. the naive path: the naive path reads 4 bytes/weight
(fp32 dequant in HBM) or pays a separate dequant pass; this kernel reads
1 byte/weight once.  For M=tokens, the matmul FLOPs are unchanged, so the op
moves from memory-bound toward the compute roofline for small M (decode).

Grid (M/bm, N/bn, K/bk), K innermost for accumulation in an f32 VMEM scratch;
blocks default to 128x128x512 (MXU 128-lane aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.codestore import unpack_codes


def _kernel(x_ref, codes_ref, step_ref, out_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)  # (bm, bk)
    w = codes_ref[...].astype(jnp.float32) * step_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x,
        w,
        (((1,), (1,)), ((), ())),  # contract x's K with w's K -> (bm, bn)
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def dequant_matmul(
    x: jax.Array,  # [M, K] f32/bf16 activations
    codes: jax.Array,  # [N, K] int8 weight codes (row-major over output dim)
    step: jax.Array,  # [N] f32 per-row Delta
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    n, k2 = codes.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: x[{m},{k}] vs codes[{n},{k2}]")
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"({m},{n},{k}) not divisible by blocks ({bm},{bn},{bk})")
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    fn = pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, 1), lambda i, j, kk: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )
    return fn(x, codes, step.reshape(n, 1))


def _kernel_packed(x_ref, codes_ref, step_ref, out_ref, *, bits, k):
    # codes_ref: (bn, w) packed uint8 tile — whole-K (column tiling would
    # split codes mid-byte).  Unpack in VMEM, scale, contract on the MXU.
    x = x_ref[...].astype(jnp.float32)  # (bm, k)
    codes = unpack_codes(codes_ref[...], bits, k).astype(jnp.float32)
    w = codes * step_ref[...].astype(jnp.float32)
    out_ref[...] = jax.lax.dot_general(
        x,
        w,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)


def dequant_matmul_packed(
    x: jax.Array,  # [M, K] f32/bf16 activations
    packed: jax.Array,  # uint8 [N, W] packed codes (W = ceil(K*bits/8))
    step: jax.Array,  # [N] f32 per-row Delta
    *,
    bits: int,
    k: int,  # logical K (contraction length)
    block_m: int = 128,
    block_n: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Packed-container twin of :func:`dequant_matmul` (whole-K blocks).

    Reads bits/8 bytes per weight from HBM; the int8 codes and the fp32 tile
    both exist only in VMEM.  Bitwise equal to
    ``dequant_matmul(x, unpack_codes(packed), step)`` at whole-K blocking.
    """
    m, k2 = x.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: x[{m},{k2}] vs logical k={k}")
    n, w = packed.shape
    bm, bn = min(block_m, m), min(block_n, n)
    if m % bm or n % bn:
        raise ValueError(f"({m},{n}) not divisible by blocks ({bm},{bn})")
    grid = (m // bm, n // bn)
    fn = pl.pallas_call(
        functools.partial(_kernel_packed, bits=bits, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, w), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )
    return fn(x, packed, step.reshape(n, 1))
