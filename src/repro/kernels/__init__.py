"""Pallas TPU kernels for the paper's embedding hot spots.

Layout per kernel: <name>.py (pl.pallas_call + BlockSpec), ref.py (jnp oracle),
ops.py (jit'd wrappers with CPU interpret fallback).
"""
from repro.kernels import ops, ref  # noqa: F401
