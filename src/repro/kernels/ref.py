"""Pure-jnp oracles for every Pallas kernel (bit-exact where noise is shared).

The oracles are also the *fallback* implementations the ``ops`` wrappers run
on shape-misaligned inputs, so each one mirrors its kernel's exact operation
sequence (same association, no re-ordered reductions): kernels-on and
kernels-off must agree bitwise, not just to tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.codestore import pack_codes, unpack_codes


def dequant_gather_ref(codes: jax.Array, step: jax.Array, ids: jax.Array) -> jax.Array:
    rows = jnp.take(codes, ids, axis=0).astype(jnp.float32)
    return rows * jnp.take(step, ids)[:, None]


# Packed-container oracles: pack/unpack is exactly invertible on the valid
# code range and every arithmetic statement runs on the *unpacked* values in
# the same order as the unpacked oracle, so packed-on == packed-off bitwise.


def dequant_gather_packed_ref(packed, step, ids, *, bits: int, d: int):
    rows = unpack_codes(
        jnp.take(packed, ids, axis=0), bits, d
    ).astype(jnp.float32)
    return rows * jnp.take(step, ids)[:, None]


def dequant_matmul_packed_ref(x, packed, step, *, bits: int, k: int,
                              out_dtype=jnp.float32):
    return dequant_matmul_ref(
        x, unpack_codes(packed, bits, k), step, out_dtype
    )


def lpt_fused_update_packed_ref(packed, step, grad, noise, lr, bits: int,
                                d: int, new_step=None,
                                weight_decay: float = 0.0):
    codes_new = lpt_fused_update_ref(
        unpack_codes(packed, bits, d), step, grad, noise, lr, bits,
        new_step=new_step, weight_decay=weight_decay,
    )
    return pack_codes(codes_new, bits)


def sparse_row_update_packed_ref(packed, step, mu, nu, uniq, g_sum, noise,
                                 lr, c1, c2, bits: int, d: int, *,
                                 weight_decay: float = 0.0, b1: float = 0.9,
                                 b2: float = 0.999, eps: float = 1e-8):
    codes, mu_new, nu_new, w_new = sparse_row_update_ref(
        unpack_codes(packed, bits, d), step, mu, nu, uniq, g_sum, noise,
        lr, c1, c2, bits, weight_decay=weight_decay, b1=b1, b2=b2, eps=eps,
    )
    return pack_codes(codes, bits), mu_new, nu_new, w_new


def sr_round_ref(w: jax.Array, step: jax.Array, noise: jax.Array, bits: int) -> jax.Array:
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    scaled = jnp.clip(w.astype(jnp.float32) / step[:, None], lo, hi)
    base = jnp.floor(scaled)
    up = (scaled - base > noise).astype(jnp.float32)
    return jnp.clip(base + up, lo, hi).astype(jnp.int8)


def dequant_matmul_ref(
    x: jax.Array, codes: jax.Array, step: jax.Array, out_dtype=jnp.float32
) -> jax.Array:
    w = codes.astype(jnp.float32) * step[:, None]
    return jnp.dot(x.astype(jnp.float32), w.T).astype(out_dtype)


def lpt_fused_update_ref(
    codes: jax.Array, step: jax.Array, grad: jax.Array, noise: jax.Array,
    lr, bits: int, new_step: jax.Array | None = None,
    weight_decay: float = 0.0,
) -> jax.Array:
    """Eq. (8): dequantize -> (decayed) SGD step -> SR re-quantize.

    ``grad`` is the already-formed update *direction* (the raw gradient for
    SGD, the bias-corrected Adam direction for the row-Adam path);
    ``weight_decay`` adds the decoupled ``wd * w`` term against the
    de-quantized weights, matching ``lpt._row_update``'s sequence exactly.
    """
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    # Two statements (dequantize, then update) — the same association as the
    # unfused core path and the kernel body, so XLA's FMA formation cannot
    # diverge between them.
    w = codes.astype(jnp.float32) * step[:, None]
    upd = grad.astype(jnp.float32)
    if weight_decay:
        upd = upd + weight_decay * w
    w = w - lr * upd
    ns = (step if new_step is None else new_step)[:, None]
    scaled = jnp.clip(w / ns, lo, hi)
    base = jnp.floor(scaled)
    up = (scaled - base > noise).astype(jnp.float32)
    return jnp.clip(base + up, lo, hi).astype(jnp.int8)


def sparse_row_update_ref(
    codes: jax.Array,  # int8 [N, d]
    step: jax.Array,  # f32 [N]
    mu: jax.Array,  # f32 [N, d] Adam first moment
    nu: jax.Array,  # f32 [N, d] Adam second moment
    uniq: jax.Array,  # int32 [K] unique row ids (all < N)
    g_sum: jax.Array,  # f32 [K, d] summed per-row gradients
    noise: jax.Array,  # f32 [K, d] uniform [0,1)
    lr, c1, c2,  # f32 scalars: learning rate, 1-b1^t, 1-b2^t
    bits: int,
    *,
    weight_decay: float = 0.0,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
):
    """Oracle for the fused CTR sparse step: gather + Adam + SR + scatter.

    Returns ``(codes', mu', nu', w_new_rows)``.  ``uniq`` must hold distinct
    in-range ids (the wrapper maps jnp.unique's sentinel padding to the
    table's scratch row before calling either path).
    """
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    w = jnp.take(codes, uniq, axis=0).astype(jnp.float32) * jnp.take(step, uniq)[:, None]
    g = g_sum.astype(jnp.float32)
    mu_r = b1 * jnp.take(mu, uniq, axis=0) + (1.0 - b1) * g
    nu_r = b2 * jnp.take(nu, uniq, axis=0) + (1.0 - b2) * jnp.square(g)
    upd = (mu_r / c1) / (jnp.sqrt(nu_r / c2) + eps)
    if weight_decay:
        upd = upd + weight_decay * w
    w_new = w - lr * upd
    step_rows = jnp.take(step, uniq)[:, None]
    scaled = jnp.clip(w_new / step_rows, lo, hi)
    base = jnp.floor(scaled)
    up = (scaled - base > noise).astype(jnp.float32)
    codes_rows = jnp.clip(base + up, lo, hi).astype(jnp.int8)
    return (
        codes.at[uniq].set(codes_rows),
        mu.at[uniq].set(mu_r),
        nu.at[uniq].set(nu_r),
        w_new,
    )
