"""Pure-jnp oracles for every Pallas kernel (bit-exact where noise is shared)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dequant_gather_ref(codes: jax.Array, step: jax.Array, ids: jax.Array) -> jax.Array:
    rows = jnp.take(codes, ids, axis=0).astype(jnp.float32)
    return rows * jnp.take(step, ids)[:, None]


def sr_round_ref(w: jax.Array, step: jax.Array, noise: jax.Array, bits: int) -> jax.Array:
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    scaled = jnp.clip(w.astype(jnp.float32) / step[:, None], lo, hi)
    base = jnp.floor(scaled)
    up = (scaled - base > noise).astype(jnp.float32)
    return jnp.clip(base + up, lo, hi).astype(jnp.int8)


def dequant_matmul_ref(
    x: jax.Array, codes: jax.Array, step: jax.Array, out_dtype=jnp.float32
) -> jax.Array:
    w = codes.astype(jnp.float32) * step[:, None]
    return jnp.dot(x.astype(jnp.float32), w.T).astype(out_dtype)


def lpt_fused_update_ref(
    codes: jax.Array, step: jax.Array, grad: jax.Array, noise: jax.Array,
    lr, bits: int, new_step: jax.Array | None = None,
) -> jax.Array:
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    w = codes.astype(jnp.float32) * step[:, None] - lr * grad.astype(jnp.float32)
    ns = (step if new_step is None else new_step)[:, None]
    scaled = jnp.clip(w / ns, lo, hi)
    base = jnp.floor(scaled)
    up = (scaled - base > noise).astype(jnp.float32)
    return jnp.clip(base + up, lo, hi).astype(jnp.int8)
