"""jit'd public wrappers over the Pallas kernels — the embedding hot-path API.

``interpret`` defaults to True off-TPU so the same call sites run everywhere;
on TPU the compiled kernels are used.  Off-TPU the elementwise kernels run
with whole-array blocks (one grid step): the tiled decomposition is a TPU
bandwidth concern, and per-tile interpretation on CPU would only add loop
overhead without changing a single bit of the result.

Alignment contract: a shape is kernel-eligible when every blocked dimension
is a multiple of 8 (the fp32 sublane granularity; lane padding to 128 happens
in VMEM).  Non-eligible shapes fall back to the bitwise-identical jnp
reference in :mod:`repro.kernels.ref` — *never silently*: every distinct
(op, shape, reason) fallback is counted and logged once, and
:func:`fallback_stats` exposes the tally so benchmarks and trainers can
assert the hot path actually runs fused (``EmbeddingSpec.pad_to_tiles`` is
the knob that makes real table geometries eligible).

Counting happens at trace time (shapes are static under jit), so the tally
reflects distinct traced shapes, not per-step call counts.
"""
from __future__ import annotations

import collections
import functools
import logging

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dequant_gather import dequant_gather as _dequant_gather
from repro.kernels.dequant_matmul import dequant_matmul as _dequant_matmul
from repro.kernels.lpt_update import lpt_fused_update as _lpt_fused_update
from repro.kernels.sparse_row_update import sparse_row_update as _sparse_row_update
from repro.kernels.sr_round import sr_round as _sr_round
from repro.kernels.sr_round import sr_round_seeded as sr_round_seeded  # re-export

logger = logging.getLogger("repro.kernels")

#: fp32 sublane granularity — every blocked dimension must divide into it.
SUBLANE = 8
#: Preferred (row, col) tile targets on TPU; interpret mode uses whole arrays.
ROW_BLOCK = 256
COL_BLOCK = 512

# ---------------------------------------------------------------- accounting

_KERNEL_CALLS: collections.Counter = collections.Counter()
_FALLBACKS: collections.Counter = collections.Counter()


def _note_kernel(op: str) -> None:
    _KERNEL_CALLS[op] += 1


def _note_fallback(op: str, shape, reason: str) -> None:
    key = (op, str(tuple(shape)), reason)
    if key not in _FALLBACKS:
        logger.warning(
            "kernels.%s: shape %s falls back to the jnp reference (%s)",
            op, tuple(shape), reason,
        )
    _FALLBACKS[key] += 1


def note_fallback(op: str, shape, reason: str) -> None:
    """Public hook for callers that bypass a kernel *before* reaching its
    wrapper (e.g. lpt.sparse_apply's eligibility gate: no scratch row, non-
    Adam row optimizer, DR rounding).  Keeps the 'never silent' contract:
    every kernels-on dispatch that lands on the jnp path is counted."""
    _note_fallback(op, shape, reason)


def fallback_stats() -> dict:
    """Snapshot of kernel-vs-fallback dispatch since the last reset.

    ``kernel_calls``/``fallbacks`` count distinct *traces* (shapes are static
    under jit); ``total_fallbacks`` is the number a kernels-on benchmark
    config asserts to be zero.
    """
    return {
        "kernel_calls": dict(_KERNEL_CALLS),
        "fallbacks": [
            {"op": op, "shape": shape, "reason": reason, "count": int(c)}
            for (op, shape, reason), c in sorted(_FALLBACKS.items())
        ],
        "total_fallbacks": int(sum(_FALLBACKS.values())),
    }


def reset_fallback_stats() -> None:
    _KERNEL_CALLS.clear()
    _FALLBACKS.clear()


# ------------------------------------------------------------------ dispatch


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pick_block(n: int, target: int) -> int | None:
    """Largest kernel-legal block for a dimension of size ``n`` (None if the
    dimension is not sublane-aligned)."""
    if n % SUBLANE:
        return None
    if n <= target:
        return n
    for b in (target, 512, 256, 128, 64, 32, 16, 8):
        if b <= target and n % b == 0:
            return b
    return None  # unreachable: SUBLANE divides n


def _blocks_2d(rows: int, cols: int):
    if _default_interpret():
        # Whole-array blocks off-TPU: tiling is a VMEM concern, and per-tile
        # interpretation only adds loop overhead on CPU.
        if rows % SUBLANE == 0 and cols % SUBLANE == 0:
            return rows, cols
        return None
    rb = _pick_block(rows, ROW_BLOCK)
    cb = _pick_block(cols, COL_BLOCK)
    if rb is None or cb is None:
        return None
    return rb, cb


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def dequant_gather(codes, step, ids, *, use_kernel: bool = True):
    """Fused int8-row gather + de-quantize: f32 [b, d] rows for flat ids."""
    n, d = codes.shape
    if not use_kernel:
        return ref.dequant_gather_ref(codes, step, ids)
    db = d if _default_interpret() else _pick_block(d, COL_BLOCK)
    if d % SUBLANE or db is None:
        _note_fallback("dequant_gather", (n, d), "dim not sublane-aligned")
        return ref.dequant_gather_ref(codes, step, ids)
    _note_kernel("dequant_gather")
    return _dequant_gather(
        codes, step, ids, d_block=db, interpret=_default_interpret()
    )


@functools.partial(jax.jit, static_argnames=("bits", "use_kernel"))
def sr_round(w, step, noise, bits: int = 8, *, use_kernel: bool = True):
    """Fused clip + stochastic-round + int8 pack (Eq. 1/4)."""
    rows, cols = w.shape
    if not use_kernel:
        return ref.sr_round_ref(w, step, noise, bits)
    blocks = _blocks_2d(rows, cols)
    if blocks is None:
        _note_fallback("sr_round", (rows, cols), "shape not sublane-aligned")
        return ref.sr_round_ref(w, step, noise, bits)
    _note_kernel("sr_round")
    return _sr_round(
        w, step, noise, bits, row_block=blocks[0], col_block=blocks[1],
        interpret=_default_interpret(),
    )


@functools.partial(
    jax.jit, static_argnames=("bits", "weight_decay", "use_kernel")
)
def lpt_update(codes, step, grad, noise, lr, bits: int, *, new_step=None,
               weight_decay: float = 0.0, use_kernel: bool = True):
    """Fused Eq. (8) write-back: dequantize -> decayed step -> SR requantize.

    ``grad`` is the formed update direction (raw gradient for SGD, the Adam /
    Adagrad direction otherwise); ``new_step`` requantizes with ALPT's
    freshly learned Delta in the same pass.
    """
    rows, cols = codes.shape
    if not use_kernel:
        return ref.lpt_fused_update_ref(
            codes, step, grad, noise, lr, bits, new_step=new_step,
            weight_decay=weight_decay,
        )
    blocks = _blocks_2d(rows, cols)
    if blocks is None:
        _note_fallback("lpt_update", (rows, cols), "shape not sublane-aligned")
        return ref.lpt_fused_update_ref(
            codes, step, grad, noise, lr, bits, new_step=new_step,
            weight_decay=weight_decay,
        )
    _note_kernel("lpt_update")
    return _lpt_fused_update(
        codes, step, grad, noise, lr, bits, new_step=new_step,
        weight_decay=weight_decay, row_block=blocks[0], col_block=blocks[1],
        interpret=_default_interpret(),
    )


@functools.partial(
    jax.jit, static_argnames=("bits", "weight_decay", "use_kernel")
)
def sparse_row_update(codes, step, mu, nu, uniq, g_sum, noise, lr, c1, c2,
                      bits: int, *, weight_decay: float = 0.0,
                      use_kernel: bool = True):
    """Fused CTR sparse step over unique rows (gather+Adam+SR+scatter).

    ``uniq`` must contain only in-range ids — the caller maps jnp.unique's
    sentinel padding to the table's scratch row (``pad_to_tiles`` allocates
    it).  Adam slots must be [N, d] (row-Adam); other row optimizers use the
    jnp path upstream.  Returns ``(codes', mu', nu', w_new_rows)``.
    """
    n, d = codes.shape
    if not use_kernel:
        return ref.sparse_row_update_ref(
            codes, step, mu, nu, uniq, g_sum, noise, lr, c1, c2, bits,
            weight_decay=weight_decay,
        )
    if d % SUBLANE or d > COL_BLOCK:
        _note_fallback(
            "sparse_row_update", (n, d),
            "dim not sublane-aligned" if d % SUBLANE else "dim exceeds one block",
        )
        return ref.sparse_row_update_ref(
            codes, step, mu, nu, uniq, g_sum, noise, lr, c1, c2, bits,
            weight_decay=weight_decay,
        )
    _note_kernel("sparse_row_update")
    return _sparse_row_update(
        codes, step, mu, nu, uniq, g_sum, noise, lr, c1, c2, bits,
        weight_decay=weight_decay, interpret=_default_interpret(),
    )


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "use_kernel")
)
def dequant_matmul(
    x, codes, step, *, block_m=128, block_n=128, block_k=512, use_kernel=True
):
    m, k = x.shape
    n, _ = codes.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    if not use_kernel:
        return ref.dequant_matmul_ref(x, codes, step)
    if m % bm or n % bn or k % bk:
        _note_fallback("dequant_matmul", (m, n, k), "blocks not divisible")
        return ref.dequant_matmul_ref(x, codes, step)
    _note_kernel("dequant_matmul")
    return _dequant_matmul(
        x, codes, step, block_m=bm, block_n=bn, block_k=bk,
        interpret=_default_interpret(),
    )
