"""Public wrappers over the Pallas kernels — the embedding hot-path API.

``interpret`` defaults to True off-TPU so the same call sites run everywhere;
on TPU the compiled kernels are used.  Off-TPU the elementwise kernels run
with whole-array blocks (one grid step): the tiled decomposition is a TPU
bandwidth concern, and per-tile interpretation on CPU would only add loop
overhead without changing a single bit of the result.

Alignment contract: a shape is kernel-eligible when every blocked dimension
is a multiple of 8 (the fp32 sublane granularity; lane padding to 128 happens
in VMEM).  Non-eligible shapes fall back to the bitwise-identical jnp
reference in :mod:`repro.kernels.ref` — *never silently*: every fallback is
counted and logged once per distinct (op, shape, reason), and
:func:`fallback_stats` exposes the tally so benchmarks and trainers can
assert the hot path actually runs fused (``EmbeddingSpec.pad_to_tiles`` is
the knob that makes real table geometries eligible).

Dispatch accounting happens when the *wrapper* runs: eagerly per call, or
once per trace when the call site sits inside an enclosing ``jit``.  The
wrappers themselves are plain Python over jitted inner implementations, so a
fresh consumer (a new jitted step function, a serving engine warming up) sees
its dispatch decisions counted even when the inner kernels were already
compiled earlier in the process — the old trace-time scheme silently skipped
those on jit-cache hits.  :func:`fallback_scope` scopes the same tally to a
``with`` block for consumers that need an accurate local report (the serving
Engine) without resetting the process-wide counters.
"""
from __future__ import annotations

import collections
import contextlib
import functools
import logging

import jax
import jax.numpy as jnp

from repro.core.codestore import CodeStore
from repro.kernels import ref
from repro.obs import counters as obs_counters
from repro.storage import base as rowstore
from repro.storage.tiered import TieredCodes
from repro.kernels.dequant_gather import dequant_gather as _dequant_gather
from repro.kernels.dequant_gather import (
    dequant_gather_packed as _dequant_gather_packed,
)
from repro.kernels.dequant_matmul import dequant_matmul as _dequant_matmul
from repro.kernels.dequant_matmul import (
    dequant_matmul_packed as _dequant_matmul_packed,
)
from repro.kernels.lpt_update import lpt_fused_update as _lpt_fused_update
from repro.kernels.lpt_update import (
    lpt_fused_update_packed as _lpt_fused_update_packed,
)
from repro.kernels.sparse_row_update import sparse_row_update as _sparse_row_update
from repro.kernels.sparse_row_update import (
    sparse_row_update_packed as _sparse_row_update_packed,
)
from repro.kernels.sr_round import sr_round as _sr_round
from repro.kernels.sr_round import sr_round_seeded as sr_round_seeded  # re-export

logger = logging.getLogger("repro.kernels")

#: fp32 sublane granularity — every blocked dimension must divide into it.
SUBLANE = 8
#: Preferred (row, col) tile targets on TPU; interpret mode uses whole arrays.
ROW_BLOCK = 256
COL_BLOCK = 512

# ---------------------------------------------------------------- accounting


class FallbackScope:
    """One scoped tally of kernel-vs-fallback dispatch decisions.

    Created by :func:`fallback_scope`; while active it receives every
    dispatch note alongside the process-wide counters, so a consumer can
    report exactly the fallbacks *its* calls hit — independent of what the
    rest of the process traced before or since.
    """

    def __init__(self) -> None:
        self.kernel_calls: collections.Counter = collections.Counter()
        self.fallbacks: collections.Counter = collections.Counter()

    def stats(self) -> dict:
        return _stats_of(self.kernel_calls, self.fallbacks)


# Process-wide tallies live in the repro.obs registry (the single schema
# every surface reports through); the legacy ``fallback_stats()`` dict is
# reconstructed from it below.  Scoped tallies stay plain Counters.
_MET_KERNEL_CALLS = obs_counters.registry().counter(
    "kernels.kernel_calls", "fused kernel dispatches", labels=("op",)
)
_MET_FALLBACKS = obs_counters.registry().counter(
    "kernels.fallbacks", "jnp-reference fallbacks",
    labels=("op", "shape", "reason"),
)
_SCOPES: list[FallbackScope] = []


@contextlib.contextmanager
def fallback_scope(scope: FallbackScope | None = None):
    """Collect dispatch accounting for the duration of a ``with`` block.

    Yields a :class:`FallbackScope` whose counters see only the dispatch
    decisions made while the scope is active.  Pass an existing scope to
    re-enter it (the serving Engine accumulates one scope across its
    lifetime's call sites).  Unlike ``reset_fallback_stats()`` +
    ``fallback_stats()``, a scope neither clears nor double-reads the
    process-wide tally, and it observes decisions even when the inner jitted
    kernels were already compiled earlier in the process.
    """
    scope = FallbackScope() if scope is None else scope
    _SCOPES.append(scope)
    try:
        yield scope
    finally:
        _SCOPES.remove(scope)


def _note_kernel(op: str) -> None:
    _MET_KERNEL_CALLS.inc(1, op)
    for scope in _SCOPES:
        scope.kernel_calls[op] += 1


def _note_fallback(op: str, shape, reason: str) -> None:
    key = (op, str(tuple(shape)), reason)
    if _MET_FALLBACKS.value(*key) == 0:
        logger.warning(
            "kernels.%s: shape %s falls back to the jnp reference (%s)",
            op, tuple(shape), reason,
        )
    _MET_FALLBACKS.inc(1, *key)
    for scope in _SCOPES:
        scope.fallbacks[key] += 1


def note_fallback(op: str, shape, reason: str) -> None:
    """Public hook for callers that bypass a kernel *before* reaching its
    wrapper (e.g. lpt.sparse_apply's eligibility gate: no scratch row, non-
    Adam row optimizer, DR rounding).  Keeps the 'never silent' contract:
    every kernels-on dispatch that lands on the jnp path is counted."""
    _note_fallback(op, shape, reason)


def _fault_forced(op: str) -> bool:
    """True when an installed FaultPlan forces ``op`` onto the jnp reference
    path (site ``kernels.force_fallback``).  Consulted at *trace* time — the
    wrappers run inside jit, so a per-step schedule cannot apply here; the
    seam fires for every dispatch while the plan is installed, optionally
    narrowed to a subset via the spec's ``ops`` param.  Bitwise-safe by the
    kernel contract (the references are the kernels' oracles); every forced
    dispatch is counted with reason ``fault-injected``."""
    from repro.faults import plan as faultplan

    spec = faultplan.lookup("kernels.force_fallback")
    if spec is None:
        return False
    ops_sel = spec.param("ops")
    return ops_sel is None or op in ops_sel


def _stats_of(kernel_calls: collections.Counter,
              fallbacks: collections.Counter) -> dict:
    return {
        "kernel_calls": dict(kernel_calls),
        "fallbacks": [
            {"op": op, "shape": shape, "reason": reason, "count": int(c)}
            for (op, shape, reason), c in sorted(fallbacks.items())
        ],
        "total_fallbacks": int(sum(fallbacks.values())),
    }


def fallback_stats() -> dict:
    """Snapshot of kernel-vs-fallback dispatch since the last reset.

    ``kernel_calls``/``fallbacks`` count wrapper dispatches (per call when
    eager, per trace under an enclosing jit); ``total_fallbacks`` is the
    number a kernels-on benchmark config asserts to be zero.

    Backward-compatible shim: the tallies live in the ``repro.obs``
    registry (``kernels.kernel_calls`` / ``kernels.fallbacks``); this
    rebuilds the pre-registry dict schema from its cells, keys unchanged
    (pinned by tests/test_obs.py).
    """
    kc = collections.Counter(
        {op: int(c) for (op,), c in _MET_KERNEL_CALLS.cells().items()}
    )
    fb = collections.Counter(
        {key: int(c) for key, c in _MET_FALLBACKS.cells().items()}
    )
    return _stats_of(kc, fb)


def reset_fallback_stats() -> None:
    _MET_KERNEL_CALLS.reset()
    _MET_FALLBACKS.reset()


# ------------------------------------------------------------------ dispatch


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pick_block(n: int, target: int) -> int | None:
    """Largest kernel-legal block for a dimension of size ``n`` (None if the
    dimension is not sublane-aligned)."""
    if n % SUBLANE:
        return None
    if n <= target:
        return n
    for b in (target, 512, 256, 128, 64, 32, 16, 8):
        if b <= target and n % b == 0:
            return b
    return None  # unreachable: SUBLANE divides n


def _blocks_2d(rows: int, cols: int):
    if _default_interpret():
        # Whole-array blocks off-TPU: tiling is a VMEM concern, and per-tile
        # interpretation only adds loop overhead on CPU.
        if rows % SUBLANE == 0 and cols % SUBLANE == 0:
            return rows, cols
        return None
    rb = _pick_block(rows, ROW_BLOCK)
    cb = _pick_block(cols, COL_BLOCK)
    if rb is None or cb is None:
        return None
    return rb, cb


# Inner jitted implementations: the public wrappers stay plain Python so the
# dispatch decision (and its accounting) runs on every call / enclosing
# trace, while the arithmetic still compiles once per shape here.

_ref_dequant_gather = jax.jit(ref.dequant_gather_ref)
_ref_sr_round = jax.jit(ref.sr_round_ref, static_argnums=(3,))
_ref_dequant_matmul = jax.jit(ref.dequant_matmul_ref)
_ref_dequant_gather_packed = jax.jit(
    ref.dequant_gather_packed_ref, static_argnames=("bits", "d")
)
_ref_dequant_matmul_packed = jax.jit(
    ref.dequant_matmul_packed_ref, static_argnames=("bits", "k")
)


@functools.partial(jax.jit, static_argnames=("d_block", "interpret"))
def _dequant_gather_jit(codes, step, ids, *, d_block, interpret):
    return _dequant_gather(codes, step, ids, d_block=d_block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bits", "d", "interpret"))
def _dequant_gather_packed_jit(packed, step, ids, *, bits, d, interpret):
    return _dequant_gather_packed(
        packed, step, ids, bits=bits, d=d, interpret=interpret
    )


@functools.partial(
    jax.jit, static_argnames=("bits", "row_block", "col_block", "interpret")
)
def _sr_round_jit(w, step, noise, bits, *, row_block, col_block, interpret):
    return _sr_round(
        w, step, noise, bits, row_block=row_block, col_block=col_block,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("bits", "weight_decay", "row_block", "col_block",
                     "interpret", "has_new_step"),
)
def _lpt_update_jit(codes, step, grad, noise, lr, new_step, bits, *,
                    weight_decay, row_block, col_block, interpret,
                    has_new_step):
    return _lpt_fused_update(
        codes, step, grad, noise, lr, bits,
        new_step=new_step if has_new_step else None,
        weight_decay=weight_decay, row_block=row_block, col_block=col_block,
        interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("bits", "weight_decay", "has_new_step")
)
def _ref_lpt_update_jit(codes, step, grad, noise, lr, new_step, bits, *,
                        weight_decay, has_new_step):
    return ref.lpt_fused_update_ref(
        codes, step, grad, noise, lr, bits,
        new_step=new_step if has_new_step else None,
        weight_decay=weight_decay,
    )


@functools.partial(
    jax.jit,
    static_argnames=("bits", "d", "weight_decay", "row_block", "interpret",
                     "has_new_step"),
)
def _lpt_update_packed_jit(packed, step, grad, noise, lr, new_step, *, bits,
                           d, weight_decay, row_block, interpret,
                           has_new_step):
    return _lpt_fused_update_packed(
        packed, step, grad, noise, lr, bits, d,
        new_step=new_step if has_new_step else None,
        weight_decay=weight_decay, row_block=row_block, interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("bits", "d", "weight_decay", "has_new_step")
)
def _ref_lpt_update_packed_jit(packed, step, grad, noise, lr, new_step, *,
                               bits, d, weight_decay, has_new_step):
    return ref.lpt_fused_update_packed_ref(
        packed, step, grad, noise, lr, bits, d,
        new_step=new_step if has_new_step else None,
        weight_decay=weight_decay,
    )


@functools.partial(
    jax.jit, static_argnames=("bits", "weight_decay", "interpret")
)
def _sparse_row_update_jit(codes, step, mu, nu, uniq, g_sum, noise, lr, c1,
                           c2, bits, *, weight_decay, interpret):
    return _sparse_row_update(
        codes, step, mu, nu, uniq, g_sum, noise, lr, c1, c2, bits,
        weight_decay=weight_decay, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("bits", "weight_decay"))
def _ref_sparse_row_update_jit(codes, step, mu, nu, uniq, g_sum, noise, lr,
                               c1, c2, bits, *, weight_decay):
    return ref.sparse_row_update_ref(
        codes, step, mu, nu, uniq, g_sum, noise, lr, c1, c2, bits,
        weight_decay=weight_decay,
    )


@functools.partial(
    jax.jit, static_argnames=("bits", "d", "weight_decay", "interpret")
)
def _sparse_row_update_packed_jit(packed, step, mu, nu, uniq, g_sum, noise,
                                  lr, c1, c2, *, bits, d, weight_decay,
                                  interpret):
    return _sparse_row_update_packed(
        packed, step, mu, nu, uniq, g_sum, noise, lr, c1, c2, bits, d,
        weight_decay=weight_decay, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("bits", "d", "weight_decay"))
def _ref_sparse_row_update_packed_jit(packed, step, mu, nu, uniq, g_sum,
                                      noise, lr, c1, c2, *, bits, d,
                                      weight_decay):
    return ref.sparse_row_update_packed_ref(
        packed, step, mu, nu, uniq, g_sum, noise, lr, c1, c2, bits, d,
        weight_decay=weight_decay,
    )


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def _dequant_matmul_jit(x, codes, step, *, block_m, block_n, block_k,
                        interpret):
    return _dequant_matmul(
        x, codes, step, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("bits", "k", "block_m", "block_n", "interpret")
)
def _dequant_matmul_packed_jit(x, packed, step, *, bits, k, block_m, block_n,
                               interpret):
    return _dequant_matmul_packed(
        x, packed, step, bits=bits, k=k, block_m=block_m, block_n=block_n,
        interpret=interpret,
    )


# ------------------------------------------------------------------- wrappers


def dequant_gather(codes, step, ids, *, use_kernel: bool = True):
    """Fused int8-row gather + de-quantize: f32 [b, d] rows for flat ids.

    ``codes`` may be a raw int8 array or a :class:`CodeStore`; a packed store
    dispatches to the packed-container kernel (packed bytes move HBM->VMEM,
    the unpack happens in VMEM) — bitwise equal to the unpacked path.  A
    :class:`~repro.storage.tiered.TieredCodes` routes: the backing gather
    keeps its kernel path, and cached rows overlay through the identical
    de-quantize formula (``codes[id] * step[id]``), so the where-merge is
    bitwise-equal to an uncached gather of the same logical table.

    The gather itself is bitwise-stable across storages; consumers that need
    the *surrounding* model computation to compile identically (the cache-on
    == cache-off training contract) fence it with
    :func:`repro.core.fence.fence_call` — an ``optimization_barrier`` here is
    not enough, XLA:CPU fuses across barriers late in its pipeline.
    """
    return _dequant_gather_impl(codes, step, ids, use_kernel=use_kernel)


def _dequant_gather_impl(codes, step, ids, *, use_kernel: bool = True):
    if isinstance(codes, TieredCodes):
        base = _dequant_gather_impl(
            codes.backing, step, ids, use_kernel=use_kernel
        )
        slot = codes.slots_for(ids)
        hot_codes = rowstore.take_rows(
            codes.hot, jnp.clip(slot, 0, codes.capacity - 1)
        )
        hot = hot_codes.astype(jnp.float32) * jnp.take(step, ids)[:, None]
        return jnp.where((slot >= 0)[:, None], hot, base)
    if isinstance(codes, CodeStore) and codes.packed:
        n, d = codes.shape
        if not use_kernel:
            return _ref_dequant_gather_packed(
                codes.data, step, ids, bits=codes.bits, d=d
            )
        if _fault_forced("dequant_gather"):
            _note_fallback("dequant_gather", (n, d), "fault-injected")
            return _ref_dequant_gather_packed(
                codes.data, step, ids, bits=codes.bits, d=d
            )
        if d % SUBLANE or (not _default_interpret() and d > COL_BLOCK):
            _note_fallback(
                "dequant_gather", (n, d),
                "dim not sublane-aligned" if d % SUBLANE
                else "dim exceeds one block",
            )
            return _ref_dequant_gather_packed(
                codes.data, step, ids, bits=codes.bits, d=d
            )
        _note_kernel("dequant_gather")
        return _dequant_gather_packed_jit(
            codes.data, step, ids, bits=codes.bits, d=d,
            interpret=_default_interpret(),
        )
    if isinstance(codes, CodeStore):
        codes = codes.data
    n, d = codes.shape
    if not use_kernel:
        return _ref_dequant_gather(codes, step, ids)
    if _fault_forced("dequant_gather"):
        _note_fallback("dequant_gather", (n, d), "fault-injected")
        return _ref_dequant_gather(codes, step, ids)
    db = d if _default_interpret() else _pick_block(d, COL_BLOCK)
    if d % SUBLANE or db is None:
        _note_fallback("dequant_gather", (n, d), "dim not sublane-aligned")
        return _ref_dequant_gather(codes, step, ids)
    _note_kernel("dequant_gather")
    return _dequant_gather_jit(
        codes, step, ids, d_block=db, interpret=_default_interpret()
    )


def sr_round(w, step, noise, bits: int = 8, *, use_kernel: bool = True):
    """Fused clip + stochastic-round + int8 pack (Eq. 1/4)."""
    rows, cols = w.shape
    if not use_kernel:
        return _ref_sr_round(w, step, noise, bits)
    if _fault_forced("sr_round"):
        _note_fallback("sr_round", (rows, cols), "fault-injected")
        return _ref_sr_round(w, step, noise, bits)
    blocks = _blocks_2d(rows, cols)
    if blocks is None:
        _note_fallback("sr_round", (rows, cols), "shape not sublane-aligned")
        return _ref_sr_round(w, step, noise, bits)
    _note_kernel("sr_round")
    return _sr_round_jit(
        w, step, noise, bits, row_block=blocks[0], col_block=blocks[1],
        interpret=_default_interpret(),
    )


def lpt_update(codes, step, grad, noise, lr, bits: int, *, new_step=None,
               weight_decay: float = 0.0, use_kernel: bool = True):
    """Fused Eq. (8) write-back: dequantize -> decayed step -> SR requantize.

    ``grad`` is the formed update direction (raw gradient for SGD, the Adam /
    Adagrad direction otherwise); ``new_step`` requantizes with ALPT's
    freshly learned Delta in the same pass.

    A :class:`CodeStore` input returns a CodeStore with the same layout; a
    packed store runs the packed kernel (unpack -> identical body -> re-pack,
    all in VMEM) or its packed jnp oracle on ineligible shapes.
    """
    if isinstance(codes, CodeStore) and codes.packed:
        store = codes
        rows, cols = store.shape
        has_new_step = new_step is not None
        ns = step if new_step is None else new_step
        if not use_kernel:
            out = _ref_lpt_update_packed_jit(
                store.data, step, grad, noise, lr, ns, bits=bits, d=cols,
                weight_decay=weight_decay, has_new_step=has_new_step,
            )
            return store.with_data(out)
        if _fault_forced("lpt_update"):
            _note_fallback("lpt_update", (rows, cols), "fault-injected")
            out = _ref_lpt_update_packed_jit(
                store.data, step, grad, noise, lr, ns, bits=bits, d=cols,
                weight_decay=weight_decay, has_new_step=has_new_step,
            )
            return store.with_data(out)
        rb = rows if _default_interpret() else _pick_block(rows, ROW_BLOCK)
        if rows % SUBLANE or cols % SUBLANE or rb is None:
            _note_fallback(
                "lpt_update", (rows, cols), "shape not sublane-aligned"
            )
            out = _ref_lpt_update_packed_jit(
                store.data, step, grad, noise, lr, ns, bits=bits, d=cols,
                weight_decay=weight_decay, has_new_step=has_new_step,
            )
            return store.with_data(out)
        _note_kernel("lpt_update")
        out = _lpt_update_packed_jit(
            store.data, step, grad, noise, lr, ns, bits=bits, d=cols,
            weight_decay=weight_decay, row_block=rb,
            interpret=_default_interpret(), has_new_step=has_new_step,
        )
        return store.with_data(out)
    store = codes if isinstance(codes, CodeStore) else None
    if store is not None:
        codes = store.data
    rows, cols = codes.shape
    has_new_step = new_step is not None
    ns = step if new_step is None else new_step  # placeholder keeps jit arity
    if store is not None:
        out = lpt_update(
            codes, step, grad, noise, lr, bits, new_step=new_step,
            weight_decay=weight_decay, use_kernel=use_kernel,
        )
        return store.with_data(out)
    if not use_kernel:
        return _ref_lpt_update_jit(
            codes, step, grad, noise, lr, ns, bits,
            weight_decay=weight_decay, has_new_step=has_new_step,
        )
    if _fault_forced("lpt_update"):
        _note_fallback("lpt_update", (rows, cols), "fault-injected")
        return _ref_lpt_update_jit(
            codes, step, grad, noise, lr, ns, bits,
            weight_decay=weight_decay, has_new_step=has_new_step,
        )
    blocks = _blocks_2d(rows, cols)
    if blocks is None:
        _note_fallback("lpt_update", (rows, cols), "shape not sublane-aligned")
        return _ref_lpt_update_jit(
            codes, step, grad, noise, lr, ns, bits,
            weight_decay=weight_decay, has_new_step=has_new_step,
        )
    _note_kernel("lpt_update")
    return _lpt_update_jit(
        codes, step, grad, noise, lr, ns, bits,
        weight_decay=weight_decay, row_block=blocks[0], col_block=blocks[1],
        interpret=_default_interpret(), has_new_step=has_new_step,
    )


def sparse_row_update(codes, step, mu, nu, uniq, g_sum, noise, lr, c1, c2,
                      bits: int, *, weight_decay: float = 0.0,
                      use_kernel: bool = True):
    """Fused CTR sparse step over unique rows (gather+Adam+SR+scatter).

    ``uniq`` must contain only in-range ids — the caller maps jnp.unique's
    sentinel padding to the table's scratch row (``pad_to_tiles`` allocates
    it).  Adam slots must be [N, d] (row-Adam); other row optimizers use the
    jnp path upstream.  Returns ``(codes', mu', nu', w_new_rows)``.

    A :class:`CodeStore` input returns a CodeStore ``codes'`` with the same
    layout; a packed store keeps the aliased scatter on packed bytes
    (re-packed in VMEM before the write-back).
    """
    if isinstance(codes, CodeStore) and codes.packed:
        store = codes
        n, d = store.shape
        if not use_kernel:
            out, mu2, nu2, w_new = _ref_sparse_row_update_packed_jit(
                store.data, step, mu, nu, uniq, g_sum, noise, lr, c1, c2,
                bits=bits, d=d, weight_decay=weight_decay,
            )
            return store.with_data(out), mu2, nu2, w_new
        if _fault_forced("sparse_row_update"):
            _note_fallback("sparse_row_update", (n, d), "fault-injected")
            out, mu2, nu2, w_new = _ref_sparse_row_update_packed_jit(
                store.data, step, mu, nu, uniq, g_sum, noise, lr, c1, c2,
                bits=bits, d=d, weight_decay=weight_decay,
            )
            return store.with_data(out), mu2, nu2, w_new
        if d % SUBLANE or d > COL_BLOCK:
            _note_fallback(
                "sparse_row_update", (n, d),
                "dim not sublane-aligned" if d % SUBLANE
                else "dim exceeds one block",
            )
            out, mu2, nu2, w_new = _ref_sparse_row_update_packed_jit(
                store.data, step, mu, nu, uniq, g_sum, noise, lr, c1, c2,
                bits=bits, d=d, weight_decay=weight_decay,
            )
            return store.with_data(out), mu2, nu2, w_new
        _note_kernel("sparse_row_update")
        out, mu2, nu2, w_new = _sparse_row_update_packed_jit(
            store.data, step, mu, nu, uniq, g_sum, noise, lr, c1, c2,
            bits=bits, d=d, weight_decay=weight_decay,
            interpret=_default_interpret(),
        )
        return store.with_data(out), mu2, nu2, w_new
    if isinstance(codes, CodeStore):
        store = codes
        out, mu2, nu2, w_new = sparse_row_update(
            store.data, step, mu, nu, uniq, g_sum, noise, lr, c1, c2, bits,
            weight_decay=weight_decay, use_kernel=use_kernel,
        )
        return store.with_data(out), mu2, nu2, w_new
    n, d = codes.shape
    if not use_kernel:
        return _ref_sparse_row_update_jit(
            codes, step, mu, nu, uniq, g_sum, noise, lr, c1, c2, bits,
            weight_decay=weight_decay,
        )
    if _fault_forced("sparse_row_update"):
        _note_fallback("sparse_row_update", (n, d), "fault-injected")
        return _ref_sparse_row_update_jit(
            codes, step, mu, nu, uniq, g_sum, noise, lr, c1, c2, bits,
            weight_decay=weight_decay,
        )
    if d % SUBLANE or d > COL_BLOCK:
        _note_fallback(
            "sparse_row_update", (n, d),
            "dim not sublane-aligned" if d % SUBLANE else "dim exceeds one block",
        )
        return _ref_sparse_row_update_jit(
            codes, step, mu, nu, uniq, g_sum, noise, lr, c1, c2, bits,
            weight_decay=weight_decay,
        )
    _note_kernel("sparse_row_update")
    return _sparse_row_update_jit(
        codes, step, mu, nu, uniq, g_sum, noise, lr, c1, c2, bits,
        weight_decay=weight_decay, interpret=_default_interpret(),
    )


def dequant_matmul(
    x, codes, step, *, block_m=128, block_n=128, block_k=512, use_kernel=True
):
    """Fused de-quantize x int8-weight matmul: ``x @ (step * codes).T``.

    The serving LM head: the int8 vocab table is scaled tile-by-tile in VMEM
    immediately before the MXU contraction — the fp32 table never exists in
    HBM.  Off-TPU any geometry runs as one whole-array interpreted block; on
    TPU the (m, n, k) dims must divide the (128, 128, 512) tiles or the call
    falls back (counted) to the jnp reference.

    ``codes`` may be a :class:`CodeStore`; a packed store dispatches to the
    whole-K packed kernel (bits/8 bytes per weight off HBM).
    """
    if isinstance(codes, CodeStore) and codes.packed:
        m, k = x.shape
        n, d = codes.shape
        if not use_kernel:
            return _ref_dequant_matmul_packed(
                x, codes.data, step, bits=codes.bits, k=d
            )
        if _fault_forced("dequant_matmul"):
            _note_fallback("dequant_matmul", (m, n, k), "fault-injected")
            return _ref_dequant_matmul_packed(
                x, codes.data, step, bits=codes.bits, k=d
            )
        bm, bn = min(block_m, m), min(block_n, n)
        if m % bm or n % bn:
            if _default_interpret():
                bm, bn = m, n
            else:
                _note_fallback(
                    "dequant_matmul", (m, n, k), "blocks not divisible"
                )
                return _ref_dequant_matmul_packed(
                    x, codes.data, step, bits=codes.bits, k=d
                )
        _note_kernel("dequant_matmul")
        return _dequant_matmul_packed_jit(
            x, codes.data, step, bits=codes.bits, k=d, block_m=bm,
            block_n=bn, interpret=_default_interpret(),
        )
    if isinstance(codes, CodeStore):
        codes = codes.data
    m, k = x.shape
    n, _ = codes.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    if not use_kernel:
        return _ref_dequant_matmul(x, codes, step)
    if _fault_forced("dequant_matmul"):
        _note_fallback("dequant_matmul", (m, n, k), "fault-injected")
        return _ref_dequant_matmul(x, codes, step)
    if m % bm or n % bn or k % bk:
        if _default_interpret():
            # Whole-array blocks: tiling is a TPU bandwidth concern only.
            bm, bn, bk = m, n, k
        else:
            _note_fallback("dequant_matmul", (m, n, k), "blocks not divisible")
            return _ref_dequant_matmul(x, codes, step)
    _note_kernel("dequant_matmul")
    return _dequant_matmul_jit(
        x, codes, step, block_m=bm, block_n=bn, block_k=bk,
        interpret=_default_interpret(),
    )
