"""jit'd public wrappers over the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites run everywhere;
on TPU the compiled kernels are used.  Non-aligned shapes fall back to the
jnp reference (the kernels demand divisible blocks by design — padding embeds
the alignment decision in the caller's config, not silently in the op).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import ref
from repro.kernels.dequant_gather import dequant_gather as _dequant_gather
from repro.kernels.dequant_matmul import dequant_matmul as _dequant_matmul
from repro.kernels.sr_round import sr_round as _sr_round
from repro.kernels.sr_round import sr_round_seeded as sr_round_seeded  # re-export


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("d_block", "use_kernel"))
def dequant_gather(codes, step, ids, *, d_block: int = 512, use_kernel: bool = True):
    n, d = codes.shape
    db = min(d_block, d)
    if not use_kernel or d % db != 0:
        return ref.dequant_gather_ref(codes, step, ids)
    return _dequant_gather(
        codes, step, ids, d_block=db, interpret=_default_interpret()
    )


@functools.partial(jax.jit, static_argnames=("bits", "use_kernel"))
def sr_round(w, step, noise, bits: int = 8, *, use_kernel: bool = True):
    rows, cols = w.shape
    rb, cb = min(256, rows), min(512, cols)
    if not use_kernel or rows % rb or cols % cb:
        return ref.sr_round_ref(w, step, noise, bits)
    return _sr_round(
        w, step, noise, bits, row_block=rb, col_block=cb,
        interpret=_default_interpret(),
    )


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "use_kernel")
)
def dequant_matmul(
    x, codes, step, *, block_m=128, block_n=128, block_k=512, use_kernel=True
):
    m, k = x.shape
    n, _ = codes.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    if not use_kernel or m % bm or n % bn or k % bk:
        return ref.dequant_matmul_ref(x, codes, step)
    return _dequant_matmul(
        x, codes, step, block_m=bm, block_n=bn, block_k=bk,
        interpret=_default_interpret(),
    )
