"""Pallas TPU kernel: fused int8-row gather + per-row de-quantize.

This is the LPT forward (paper §2.3): only the rows a batch touches leave the
integer table.  On TPU the ids are *scalar-prefetched* into SMEM so they can
drive the BlockSpec index map — each grid step DMAs exactly one (row_block, d)
tile of int8 codes HBM->VMEM, multiplies by the row's step size in VMEM, and
writes the f32 rows out.  The fp table never materializes in HBM.

Roofline: the op moves 1 byte/elem instead of 4 — it is pure memory traffic,
so int8 codes put it 4x below the fp32 gather on the HBM roofline.

Block shape: (1, d_block) per grid step, d_block = min(d, 512) lanes
(multiple of 128 on real shapes); rows are independent so the grid is
(num_ids, d_blocks) with ids prefetched.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.codestore import unpack_codes


def _kernel(ids_ref, codes_ref, step_ref, out_ref):
    # codes_ref: (1, d_block) int8 tile of the row selected by the index map.
    # step_ref:  (1, 1) f32 step of that row.
    codes = codes_ref[...].astype(jnp.float32)
    out_ref[...] = codes * step_ref[0, 0]


def _kernel_packed(ids_ref, codes_ref, step_ref, out_ref, *, bits, d):
    # codes_ref: (1, w) packed uint8 row — the HBM->VMEM DMA moved bits/8
    # bytes per code; the sub-byte codes only exist unpacked here in VMEM.
    codes = unpack_codes(codes_ref[...], bits, d).astype(jnp.float32)
    out_ref[...] = codes * step_ref[0, 0]


def dequant_gather(
    codes: jax.Array,  # int8 [n, d]
    step: jax.Array,  # f32  [n]
    ids: jax.Array,  # int32 [b]
    *,
    d_block: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Returns f32 [b, d] de-quantized rows."""
    n, d = codes.shape
    (b,) = ids.shape
    d_block = min(d_block, d)
    if d % d_block != 0:
        raise ValueError(f"d={d} must be a multiple of d_block={d_block}")
    step2d = step.reshape(n, 1)

    grid = (b, d // d_block)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            # One int8 row-tile per step; the prefetched ids pick the row.
            pl.BlockSpec((1, d_block), lambda i, j, ids_ref: (ids_ref[i], j)),
            pl.BlockSpec((1, 1), lambda i, j, ids_ref: (ids_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d_block), lambda i, j, ids_ref: (i, j)),
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )
    return fn(ids.astype(jnp.int32), codes, step2d)


def dequant_gather_packed(
    packed: jax.Array,  # uint8 [n, w] packed container (w = ceil(d*bits/8))
    step: jax.Array,  # f32  [n]
    ids: jax.Array,  # int32 [b]
    *,
    bits: int,
    d: int,
    interpret: bool = False,
) -> jax.Array:
    """Packed-container gather: moves w bytes/row from HBM, unpacks in VMEM.

    Returns f32 [b, d] de-quantized rows, bitwise equal to
    ``dequant_gather(unpack_codes(packed), ...)`` — the unpack is exact and
    the de-quantize runs in the same operation order.  Rows stay whole (one
    grid step per id): sub-byte column tiling would split mid-byte.
    """
    n, w = packed.shape
    (b,) = ids.shape
    step2d = step.reshape(n, 1)

    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, w), lambda i, ids_ref: (ids_ref[i], 0)),
            pl.BlockSpec((1, 1), lambda i, ids_ref: (ids_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, ids_ref: (i, 0)),
    )
    fn = pl.pallas_call(
        functools.partial(_kernel_packed, bits=bits, d=d),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )
    return fn(ids.astype(jnp.int32), packed, step2d)
