"""Pallas TPU kernel: fused LPT update — Eq. (8) in a single VMEM pass.

    codes' = SR( clip( (Delta*codes - lr*grad) / Delta' ) )

De-quantize, SGD-update and re-quantize never materialize the fp32 table in
HBM: per (row_block, col_block) tile the traffic is 1 byte/elem of codes in,
grad + noise in, and 1 byte/elem of codes out — vs the unfused path's three
extra fp32 round-trips (dequantized table out, updated table out, quantize
read).  ``new_step`` lets ALPT requantize with the freshly learned Delta
(Algorithm 1 line 5) in the same pass.

This is the LPT write-back hot loop for the dense (LM vocab-table) path;
tiles are (8,128)-aligned VMEM blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.codestore import pack_codes, unpack_codes


def _kernel(codes_ref, step_ref, grad_ref, noise_ref, new_step_ref, lr_ref,
            out_ref, *, lo: int, hi: int, weight_decay: float,
            bits: int = 8, d: int = 0):
    packed = d > 0  # packed container: codes blocks are uint8 [rb, w]
    if packed:
        codes = unpack_codes(codes_ref[...], bits, d).astype(jnp.float32)
    else:
        codes = codes_ref[...].astype(jnp.float32)
    step = step_ref[...].astype(jnp.float32)  # [rb, 1]
    w = codes * step
    upd = grad_ref[...].astype(jnp.float32)
    if weight_decay:
        # Decoupled weight decay against the de-quantized weights, in the
        # same operation order as lpt._row_update (bitwise-parity contract).
        upd = upd + weight_decay * w
    w = w - lr_ref[0, 0] * upd
    ns = new_step_ref[...].astype(jnp.float32)
    scaled = jnp.clip(w / ns, lo, hi)
    base = jnp.floor(scaled)
    up = (scaled - base > noise_ref[...]).astype(jnp.float32)
    codes_new = jnp.clip(base + up, lo, hi).astype(jnp.int8)
    out_ref[...] = pack_codes(codes_new, bits) if packed else codes_new


def lpt_fused_update(
    codes: jax.Array,  # int8 [R, C]
    step: jax.Array,  # f32 [R] current Delta
    grad: jax.Array,  # [R, C] gradient (any float dtype)
    noise: jax.Array,  # f32 [R, C] uniform [0,1)
    lr: jax.Array,  # f32 scalar
    bits: int,
    *,
    new_step: jax.Array | None = None,  # f32 [R] (ALPT's Delta'); default step
    weight_decay: float = 0.0,  # decoupled decay vs the de-quantized weights
    row_block: int = 256,
    col_block: int = 512,
    interpret: bool = False,
) -> jax.Array:
    rows, cols = codes.shape
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    rb, cb = min(row_block, rows), min(col_block, cols)
    if rows % rb or cols % cb:
        raise ValueError(f"({rows},{cols}) not divisible by ({rb},{cb})")
    if new_step is None:
        new_step = step
    grid = (rows // rb, cols // cb)
    fn = pl.pallas_call(
        functools.partial(_kernel, lo=lo, hi=hi, weight_decay=weight_decay),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
            pl.BlockSpec((rb, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
            pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
            pl.BlockSpec((rb, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.int8),
        interpret=interpret,
    )
    return fn(
        codes, step.reshape(rows, 1), grad, noise, new_step.reshape(rows, 1),
        jnp.asarray(lr, jnp.float32).reshape(1, 1),
    )


def lpt_fused_update_packed(
    packed: jax.Array,  # uint8 [R, W] packed container (W = ceil(C*bits/8))
    step: jax.Array,  # f32 [R]
    grad: jax.Array,  # [R, C]
    noise: jax.Array,  # f32 [R, C]
    lr: jax.Array,
    bits: int,
    d: int,  # logical C
    *,
    new_step: jax.Array | None = None,
    weight_decay: float = 0.0,
    row_block: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Packed-container twin of :func:`lpt_fused_update`.

    Tiles over rows only (full-width blocks): column tiling would split codes
    mid-byte.  Per tile the code traffic is W bytes/row in and out — the
    unpack/update/re-pack all happen in VMEM, and the body between them is
    statement-for-statement the unpacked kernel's, so the result is bitwise
    equal to ``pack(lpt_fused_update(unpack(packed), ...))``.
    """
    rows, w = packed.shape
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    rb = min(row_block, rows)
    if rows % rb:
        raise ValueError(f"rows={rows} not divisible by row_block={rb}")
    if new_step is None:
        new_step = step
    grid = (rows // rb,)
    fn = pl.pallas_call(
        functools.partial(
            _kernel, lo=lo, hi=hi, weight_decay=weight_decay, bits=bits, d=d
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, w), lambda i: (i, 0)),
            pl.BlockSpec((rb, 1), lambda i: (i, 0)),
            pl.BlockSpec((rb, d), lambda i: (i, 0)),
            pl.BlockSpec((rb, d), lambda i: (i, 0)),
            pl.BlockSpec((rb, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, w), jnp.uint8),
        interpret=interpret,
    )
    return fn(
        packed, step.reshape(rows, 1), grad, noise,
        new_step.reshape(rows, 1), jnp.asarray(lr, jnp.float32).reshape(1, 1),
    )
