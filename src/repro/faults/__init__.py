"""Deterministic fault injection + the recovery machinery it proves out.

The production claim (ROADMAP north star: "serves heavy traffic from
millions of users") needs more than happy-path bitwise parity: host tiers
stall, packed bytes flip, gradients blow up, jobs get preempted.  This
package makes those failures *reproducible* so the recovery paths are
testable, not aspirational:

* :mod:`repro.faults.plan` — a seeded :class:`FaultPlan`: named injection
  sites fire on scheduled steps/waves with per-site parameters.  One plan,
  installed process-wide, drives every seam; the same plan JSON replays the
  same faults.
* :mod:`repro.faults.recovery` — bounded retry with deterministic
  exponential backoff (:func:`retry_with_backoff`) and the typed counters
  (:class:`RetryStats`) every retried seam reports through.
* :mod:`repro.faults.guards` — jit-compatible trainer guardrails: the
  non-finite-update detector wraps a jitted step and skips poisoned updates
  via ``lax.cond`` (state rolls back, step/rng advance — documented
  skip-step semantics), with host-side :class:`GuardStats` accumulation.

Seam catalog (the site names a :class:`FaultPlan` can schedule):

=========================  =================================================
site                       seam / recovery
=========================  =================================================
``trainer.nonfinite``      poisons a dense-param leaf at step entry (NaN
                           forward -> NaN grads -> NaN update); recovered by
                           the non-finite guard's skip-step.
``alpt.delta``             scales the ALPT tables' learned Delta by
                           ``scale`` (default inf) at step entry; non-finite
                           blowups recovered by the guard's skip-step,
                           finite ones bounded by the absolute Delta clamp
                           (``ALPTConfig.step_clamp``).
``codestore.corrupt``      flips packed code bytes in the cold tier's
                           staged prefetch buffer; recovered by checksum
                           verification against the host ground truth +
                           demand re-fetch (counted, bitwise-equal).
``cold.fetch``             cold-tier host gather raises ``TransientFault``
                           (``fails`` times per fired wave) or stalls
                           ``stall_s``; recovered by bounded retry+backoff.
``cold.prefetch_loss``     drops the staged prefetch; recovered by the
                           demand-load path (counted, bitwise-equal).
``cache.admission``        hot-row cache admission reports OOM for the
                           wave; recovered by serving/training straight off
                           the warm tier (degraded counters tick).
``tiered.writeback``       dirty hot-row write-back raises
                           ``TransientFault`` (``fails`` times per fired
                           flush); recovered by bounded retry+backoff (the
                           jitted write-back is pure, retries are bitwise-
                           identical).
``checkpoint.corrupt``     flips a byte in a committed leaf artifact;
                           recovered by checksum verification + fall back
                           to the last good checkpoint.
``kernels.force_fallback`` forces the jnp reference path at trace time
                           (reason ``fault-injected``, counted, never
                           silent); bitwise-equal by the kernel contract.
``train.preempt``          requests a graceful shutdown at the scheduled
                           step (checkpoint + exit 75); recovered by
                           exact-resume restart.
=========================  =================================================
"""
from repro.faults.guards import GuardStats, wrap_ctr_step, wrap_lm_step
from repro.faults.plan import (
    FaultPlan,
    FaultSpec,
    InjectedFault,
    TransientFault,
    active_plan,
    corrupt_checkpoint_leaf,
    fires,
    install,
    lookup,
    step_mask,
    uninstall,
)
from repro.faults.recovery import RetryError, RetryStats, retry_with_backoff

__all__ = [
    "FaultPlan",
    "FaultSpec",
    "GuardStats",
    "InjectedFault",
    "RetryError",
    "RetryStats",
    "TransientFault",
    "active_plan",
    "corrupt_checkpoint_leaf",
    "fires",
    "install",
    "lookup",
    "retry_with_backoff",
    "step_mask",
    "uninstall",
    "wrap_ctr_step",
    "wrap_lm_step",
]
