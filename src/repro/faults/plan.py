"""The fault plan: named injection sites firing on a deterministic schedule.

A :class:`FaultSpec` schedules one seam: ``site`` names the injection point
(see the catalog in :mod:`repro.faults`), ``steps`` lists the step/wave
indices it fires on, and ``params`` carries site-specific knobs (``fails``
for transient-error counts, ``stall_s`` for stalls, ``ops`` for kernel
sites, ...).  A :class:`FaultPlan` is a seeded collection of specs with a
JSON round-trip, so a chaos run is replayable from one artifact:

    plan = FaultPlan(seed=0, specs=(
        FaultSpec(site="trainer.nonfinite", steps=(3, 7)),
        FaultSpec(site="cold.fetch", steps=(2,), params={"fails": 2}),
    ))
    faults.install(plan)

Installation is process-global (one chaos experiment per process — the
seams live inside trainers, stores and engines that have no plan argument);
:func:`uninstall` or ``install(None)`` clears it.  Sites consult the plan
at *host* level (per wave / per call); jitted schedules are built from the
static ``steps`` tuple (:func:`step_mask`) so kernels-on stays fused.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import zlib
from typing import Any


class InjectedFault(Exception):
    """Base class for every error this package raises on purpose."""


class TransientFault(InjectedFault):
    """An injected failure the seam is expected to retry through."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled seam: fire ``site`` on each step/wave in ``steps``."""

    site: str
    steps: tuple[int, ...] = ()
    #: Fire on every step/wave (schedules with unknown horizons).
    always: bool = False
    params: dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "steps", tuple(int(s) for s in self.steps))

    def fires(self, step: int) -> bool:
        return self.always or int(step) in self.steps

    def param(self, name: str, default=None):
        return self.params.get(name, default)

    def to_json(self) -> dict:
        out: dict[str, Any] = {"site": self.site, "steps": list(self.steps)}
        if self.always:
            out["always"] = True
        if self.params:
            out["params"] = dict(self.params)
        return out

    @classmethod
    def from_json(cls, obj: dict) -> "FaultSpec":
        return cls(
            site=obj["site"],
            steps=tuple(obj.get("steps", ())),
            always=bool(obj.get("always", False)),
            params=dict(obj.get("params", {})),
        )


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, replayable set of scheduled faults."""

    specs: tuple[FaultSpec, ...] = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))
        sites = [s.site for s in self.specs]
        dup = {s for s in sites if sites.count(s) > 1}
        if dup:
            raise ValueError(f"duplicate fault sites in plan: {sorted(dup)}")

    def lookup(self, site: str) -> FaultSpec | None:
        for spec in self.specs:
            if spec.site == site:
                return spec
        return None

    def fires(self, site: str, step: int) -> bool:
        spec = self.lookup(site)
        return spec is not None and spec.fires(step)

    def sites(self) -> tuple[str, ...]:
        return tuple(s.site for s in self.specs)

    # ------------------------------------------------------------ json

    def to_json(self) -> dict:
        return {"seed": self.seed, "specs": [s.to_json() for s in self.specs]}

    @classmethod
    def from_json(cls, obj: dict) -> "FaultPlan":
        return cls(
            seed=int(obj.get("seed", 0)),
            specs=tuple(FaultSpec.from_json(s) for s in obj.get("specs", ())),
        )

    def save(self, path: str | os.PathLike) -> None:
        pathlib.Path(path).write_text(json.dumps(self.to_json(), indent=2))

    @classmethod
    def load(cls, path: str | os.PathLike) -> "FaultPlan":
        return cls.from_json(json.loads(pathlib.Path(path).read_text()))


# ------------------------------------------------------------------ install

_ACTIVE: FaultPlan | None = None


def install(plan: FaultPlan | None) -> None:
    """Make ``plan`` the process-wide active plan (None clears it)."""
    global _ACTIVE
    _ACTIVE = plan


def uninstall() -> None:
    install(None)


def active_plan() -> FaultPlan | None:
    return _ACTIVE


def lookup(site: str) -> FaultSpec | None:
    """The active plan's spec for ``site`` (None when no plan / no spec)."""
    return None if _ACTIVE is None else _ACTIVE.lookup(site)


def fires(site: str, step: int) -> bool:
    """Host-side schedule check against the active plan."""
    return _ACTIVE is not None and _ACTIVE.fires(site, step)


def step_mask(spec: FaultSpec | None):
    """A jit-safe ``fire(step) -> bool[]`` from the spec's static schedule.

    The schedule tuple is baked into the trace (it is plan-static), so the
    compiled step stays a single fused program — the fault is one
    ``jnp.any(step == steps)`` comparison feeding a ``lax.cond``.
    """
    import jax.numpy as jnp

    if spec is None:
        return lambda step: jnp.zeros((), bool)
    if spec.always:
        return lambda step: jnp.ones((), bool)
    if not spec.steps:
        return lambda step: jnp.zeros((), bool)
    sched = jnp.asarray(spec.steps)
    return lambda step: jnp.any(step == sched)


# ------------------------------------------------------------------ helpers


def corrupt_checkpoint_leaf(directory: str | os.PathLike, step: int,
                            *, leaf: int = 0, seed: int = 0) -> pathlib.Path:
    """Flip one byte of a committed checkpoint's leaf artifact (in the data
    region, past the .npy header) — the ``checkpoint.corrupt`` seam.

    Deterministic under ``seed``; returns the corrupted path.  Detection and
    recovery belong to :mod:`repro.checkpoint.manager` (per-leaf checksums,
    fall back to last good).
    """
    d = pathlib.Path(directory) / f"step_{step:09d}"
    path = d / f"leaf_{leaf:05d}.npy"
    raw = bytearray(path.read_bytes())
    header = 128  # .npy v1 header is 64-byte aligned; 128 clears any dict
    if len(raw) <= header:
        header = max(0, len(raw) - 1)
    span = len(raw) - header
    pos = header + (zlib.crc32(f"{step}:{leaf}:{seed}".encode()) % span)
    raw[pos] ^= 0xFF
    path.write_bytes(bytes(raw))
    return path
