"""Bounded retry with deterministic exponential backoff.

Every retried seam in the stack (cold-tier fetch/prefetch, dirty
write-back, serving waves) goes through :func:`retry_with_backoff` so the
retry discipline is uniform: bounded attempts, exponential backoff with a
deterministic schedule (no wall-clock jitter — chaos runs must replay
bit-for-bit), typed counters, and a *loud* final failure
(:class:`RetryError` chains the last cause; nothing is swallowed).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, TypeVar

from repro.faults.plan import InjectedFault
from repro.obs import counters as obs_counters

T = TypeVar("T")

# Every retried seam reports into the unified registry, keyed by the seam's
# ``op`` name — one place to read retry pressure across tiers and engines.
_MET_RETRIES = obs_counters.registry().counter(
    "faults.retries", "retry attempts across all retried seams",
    labels=("op",),
)
_MET_RETRY_FAILURES = obs_counters.registry().counter(
    "faults.retry_failures", "calls that exhausted all attempts",
    labels=("op",),
)


class RetryError(RuntimeError):
    """All attempts exhausted — raised loudly, chaining the last cause."""

    def __init__(self, op: str, attempts: int, last: BaseException):
        super().__init__(f"{op}: failed after {attempts} attempts: {last!r}")
        self.op = op
        self.attempts = attempts


@dataclasses.dataclass
class RetryStats:
    """Per-seam retry counters, reported in end-of-run summaries."""

    calls: int = 0
    retries: int = 0
    failures: int = 0  # calls that exhausted all attempts
    backoff_s: float = 0.0  # total deterministic backoff slept

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def merge(self, other: "RetryStats") -> "RetryStats":
        return RetryStats(
            calls=self.calls + other.calls,
            retries=self.retries + other.retries,
            failures=self.failures + other.failures,
            backoff_s=self.backoff_s + other.backoff_s,
        )


def backoff_schedule(attempts: int, base_s: float, factor: float = 2.0,
                     max_s: float = 1.0) -> tuple[float, ...]:
    """The deterministic sleep before each retry: base * factor**k, capped."""
    return tuple(min(base_s * factor**k, max_s) for k in range(max(0, attempts - 1)))


def retry_with_backoff(
    fn: Callable[[], T],
    *,
    op: str,
    attempts: int = 3,
    base_s: float = 0.005,
    factor: float = 2.0,
    max_s: float = 1.0,
    stats: RetryStats | None = None,
    retry_on: tuple[type[BaseException], ...] = (InjectedFault, OSError, TimeoutError),
    sleep: Callable[[float], None] = time.sleep,
) -> T:
    """Call ``fn`` with up to ``attempts`` tries and exponential backoff.

    Only exceptions in ``retry_on`` are retried — anything else (a real
    bug) propagates immediately.  On exhaustion raises :class:`RetryError`
    from the last cause.  ``stats`` (if given) ticks calls/retries/failures
    and accumulates the backoff actually applied.
    """
    if attempts < 1:
        raise ValueError(f"attempts must be >= 1, got {attempts}")
    if stats is not None:
        stats.calls += 1
    sched = backoff_schedule(attempts, base_s, factor, max_s)
    last: BaseException | None = None
    for k in range(attempts):
        try:
            return fn()
        except retry_on as e:  # noqa: PERF203 - retry loop, not hot path
            last = e
            if k == attempts - 1:
                break
            if stats is not None:
                stats.retries += 1
                stats.backoff_s += sched[k]
            _MET_RETRIES.inc(1, op)
            sleep(sched[k])
    if stats is not None:
        stats.failures += 1
    _MET_RETRY_FAILURES.inc(1, op)
    assert last is not None
    raise RetryError(op, attempts, last) from last
