"""Trainer guardrails: non-finite update detection with skip-step recovery.

The guard wraps a train step *inside* jit: it checks the step's loss and the
updated dense parameters for non-finite values and, on detection, rolls the
whole state back to the pre-step value via ``lax.cond`` — only ``step`` and
``rng`` advance (skip-step semantics: the poisoned batch is dropped, the
data/rng streams stay aligned with an unguarded run).  Everything is traced,
so kernels-on stays one fused program; there is no host sync in the step.

The same wrapper hosts the two trainer-side injection seams, because they
must poison a *copy* of the input state (rollback restores the clean one):

* ``trainer.nonfinite`` — multiplies the first float leaf of the dense
  params by NaN on scheduled steps (NaN forward -> NaN grads -> NaN update).
* ``alpt.delta`` — scales every ALPT table's learned Delta by ``scale``
  (default inf) on scheduled steps; a non-finite scale is recovered by this
  guard's skip-step, a finite blowup by the absolute Delta clamp in
  :mod:`repro.core.alpt` (``ALPTConfig.step_clamp``).

Skip counters ride the metrics dict (``guard_skipped``, ``fault_*_fired``)
as lazy device scalars; :class:`GuardStats` accumulates them host-side
without forcing a sync per step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.faults import plan as _plan

#: Metrics keys the guard adds to every wrapped step's output.
GUARD_METRIC_KEYS = ("guard_skipped", "fault_nonfinite_fired", "fault_delta_fired")


def poison_first_float_leaf(tree, fire):
    """NaN-poison the first float leaf of ``tree`` when ``fire`` is set."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    for i, x in enumerate(leaves):
        if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
            leaves[i] = x * jnp.where(fire, jnp.nan, 1.0).astype(x.dtype)
            break
    return jax.tree_util.tree_unflatten(treedef, leaves)


def scale_alpt_delta(emb_state, fire, scale):
    """Scale the learned Delta of every LPT/ALPT table in ``emb_state``."""
    # Imported here, not at module top: core.lpt reaches storage.tiered,
    # which imports this package for the cache.admission seam.
    from repro.core.lpt import LPTTable

    def on_node(x):
        if isinstance(x, LPTTable):
            f = jnp.where(fire, jnp.asarray(scale, x.step.dtype), 1.0)
            return x._replace(step=x.step * f.astype(x.step.dtype))
        return x

    return jax.tree_util.tree_map(
        on_node, emb_state, is_leaf=lambda x: isinstance(x, LPTTable)
    )


def _all_finite(tree):
    ok = jnp.ones((), bool)
    for x in jax.tree_util.tree_leaves(tree):
        if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
            ok = ok & jnp.all(jnp.isfinite(x))
    return ok


def _wrap(step_fn, *, dense_of, with_dense, emb_of, with_emb, jit):
    nf_spec = _plan.lookup("trainer.nonfinite")
    dl_spec = _plan.lookup("alpt.delta")
    fire_nf = _plan.step_mask(nf_spec)
    fire_dl = _plan.step_mask(dl_spec)
    dl_scale = dl_spec.param("scale", float("inf")) if dl_spec else 1.0

    def guarded(state, *args):
        st = state
        nf = fire_nf(state.step)
        dl = fire_dl(state.step)
        if nf_spec is not None:
            st = with_dense(st, poison_first_float_leaf(dense_of(st), nf))
        if dl_spec is not None:
            st = with_emb(st, scale_alpt_delta(emb_of(st), dl, dl_scale))
        new_state, m = step_fn(st, *args)
        ok = jnp.isfinite(m["loss"]) & _all_finite(dense_of(new_state))
        out = jax.lax.cond(
            ok,
            lambda: new_state,
            lambda: state._replace(step=new_state.step, rng=new_state.rng),
        )
        m = {
            **m,
            "guard_skipped": jnp.where(ok, 0, 1).astype(jnp.int32),
            "fault_nonfinite_fired": nf.astype(jnp.int32),
            "fault_delta_fired": dl.astype(jnp.int32),
        }
        return out, m

    return jax.jit(guarded) if jit else guarded


def wrap_ctr_step(step_fn):
    """Guard a (jitted) CTR step ``(state, ids, labels) -> (state, m)``.

    Returns a re-jitted step with identical signature; the injection seams
    are baked in from the plan active at wrap time (trace-time constants).
    """
    return _wrap(
        step_fn,
        dense_of=lambda s: s.dense_params,
        with_dense=lambda s, p: s._replace(dense_params=p),
        emb_of=lambda s: s.emb_state,
        with_emb=lambda s, e: s._replace(emb_state=e),
        jit=True,
    )


def wrap_lm_step(step_fn):
    """Guard an LM step ``(state, batch) -> (state, m)``.

    Like the step from :func:`repro.training.lm_trainer.make_train_step`,
    the result is jit/pjit-ready but not jitted — callers jit it.
    """
    return _wrap(
        step_fn,
        dense_of=lambda s: s.params,
        with_dense=lambda s, p: s._replace(params=p),
        emb_of=lambda s: s.table,
        with_emb=lambda s, t: s._replace(table=t),
        jit=False,
    )


class GuardStats:
    """Host-side accumulation of guard/fault counters without per-step sync.

    ``observe(metrics)`` adds the device scalars lazily; reading any
    property (or :meth:`to_json`) materialises the totals once.
    """

    def __init__(self):
        self.steps = 0
        self._skipped = 0
        self._nonfinite_fired = 0
        self._delta_fired = 0
        self._delta_clamped = 0

    def observe(self, metrics) -> None:
        self.steps += 1
        self._skipped = self._skipped + metrics.get("guard_skipped", 0)
        self._nonfinite_fired = (
            self._nonfinite_fired + metrics.get("fault_nonfinite_fired", 0)
        )
        self._delta_fired = self._delta_fired + metrics.get("fault_delta_fired", 0)
        self._delta_clamped = self._delta_clamped + metrics.get("delta_clamped", 0)

    @property
    def skipped(self) -> int:
        return int(self._skipped)

    @property
    def nonfinite_fired(self) -> int:
        return int(self._nonfinite_fired)

    @property
    def delta_fired(self) -> int:
        return int(self._delta_fired)

    @property
    def delta_clamped(self) -> int:
        return int(self._delta_clamped)

    def publish(self) -> None:
        """Mirror the materialized totals into ``faults.guard.*`` registry
        gauges.  Gauges (not counters): the totals here are already
        cumulative, and publishing happens at report time — never per step,
        preserving the no-sync-per-step property."""
        from repro.obs import counters as obs_counters

        reg = obs_counters.registry()
        for name, val in self.to_json().items():
            reg.gauge(f"faults.guard.{name}").set(val)

    def to_json(self) -> dict:
        return {
            "steps": self.steps,
            "skipped": self.skipped,
            "nonfinite_fired": self.nonfinite_fired,
            "delta_fired": self.delta_fired,
            "delta_clamped": self.delta_clamped,
        }
