"""End-to-end CTR training for every embedding method in paper Table 1.

One trainer, one DCN/DeepFM backbone, seven embedding methods — the only
thing that changes per method is how the table is looked up and updated:

  fp/lsq/pact/hash/prune : joint Adam over (embedding leaves, dense params)
  lpt                    : Eq. 8 — rows de-quantized, row-Adam, requantize
  alpt                   : Algorithm 1 — + learned Delta via second forward

This mirrors the paper's experimental protocol (§4.1): Adam lr 1e-3, tenfold
decay boundaries, decoupled weight decay on embeddings, Delta lr 2e-5.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import metrics
from repro.core import alpt as alpt_mod
from repro.core import lpt as lpt_mod
from repro.core import pruning, quant
from repro.models import ctr as ctr_models
from repro.models import embedding as emb_mod
from repro.optim import adam_init, adam_update


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    spec: emb_mod.EmbeddingSpec
    model: str = "dcn"  # 'dcn' | 'deepfm'
    dcn: ctr_models.DCNConfig | None = None
    deepfm: ctr_models.DeepFMConfig | None = None
    lr: float = 1e-3
    emb_weight_decay: float = 5e-8
    lr_boundaries: tuple[int, ...] = ()  # steps at which lr /= 10
    seed: int = 0
    # Gradient-sync bit width for data-parallel training
    # (repro.training.data_parallel): 32 = exact fp32, 2..8 = SR-compressed.
    dp_sync_bits: int = 32


class TrainState(NamedTuple):
    emb_state: Any
    dense_params: Any
    dense_opt: Any
    emb_opt: Any  # Adam state over float embedding leaves (None for int tables)
    step: jax.Array
    rng: jax.Array


class CTRTrainer:
    def __init__(self, cfg: TrainerConfig):
        self.cfg = cfg
        self.spec = cfg.spec
        if cfg.model == "dcn":
            assert cfg.dcn is not None
            self.model_cfg = cfg.dcn
            self._forward = ctr_models.dcn_forward
            self._init_model = ctr_models.init_dcn
        else:
            assert cfg.deepfm is not None
            self.model_cfg = cfg.deepfm
            self._forward = ctr_models.deepfm_forward
            self._init_model = ctr_models.init_deepfm
        self._train_step = self._build_train_step()
        self._eval_logits = jax.jit(self._logits_fn)

    # ------------------------------------------------------------ init

    def init_state(self, key: jax.Array | None = None) -> TrainState:
        key = jax.random.PRNGKey(self.cfg.seed) if key is None else key
        k_emb, k_dense, k_rng = jax.random.split(key, 3)
        emb_state = emb_mod.init_embedding(k_emb, self.spec)
        dense_params = self._init_model(k_dense, self.model_cfg)
        dense_opt = adam_init(dense_params)
        emb_params = emb_mod.trainable_params(emb_state, self.spec)
        emb_opt = adam_init(emb_params) if emb_params is not None else None
        return TrainState(
            emb_state=emb_state,
            dense_params=dense_params,
            dense_opt=dense_opt,
            emb_opt=emb_opt,
            step=jnp.zeros((), jnp.int32),
            rng=k_rng,
        )

    # ------------------------------------------------------------ lr

    def _lr_at(self, step: jax.Array) -> jax.Array:
        lr = jnp.asarray(self.cfg.lr, jnp.float32)
        for b in self.cfg.lr_boundaries:
            lr = lr * jnp.where(step >= b, 0.1, 1.0)
        return lr

    # ------------------------------------------------------------ forward

    def _logits_fn(self, emb_state, dense_params, ids, *, dropout_key=None):
        if self.cfg.model == "deepfm":
            rows_all = emb_mod.lookup(emb_state, ids, self.spec)
            rows, first = rows_all[..., :-1], rows_all[..., -1]
            return self._forward(
                dense_params, rows, first, self.model_cfg, dropout_key=dropout_key
            )
        rows = emb_mod.lookup(emb_state, ids, self.spec)
        return self._forward(dense_params, rows, self.model_cfg, dropout_key=dropout_key)

    def _logits_from_rows(self, rows, dense_params, dropout_key=None):
        if self.cfg.model == "deepfm":
            r, first = rows[..., :-1], rows[..., -1]
            return self._forward(
                dense_params, r, first, self.model_cfg, dropout_key=dropout_key
            )
        return self._forward(dense_params, rows, self.model_cfg, dropout_key=dropout_key)

    # ------------------------------------------------------------ train step

    def _build_train_step(self):
        spec = self.spec
        method = spec.method

        if method in emb_mod.FLOAT_METHODS:

            @jax.jit
            def step_fn(state: TrainState, ids, labels):
                lr = self._lr_at(state.step)
                rng, kd = jax.random.split(state.rng)
                emb_params = emb_mod.trainable_params(state.emb_state, spec)

                def loss_fn(emb_params, dense_params):
                    emb_state = emb_mod.with_params(state.emb_state, emb_params, spec)
                    logits = self._logits_fn(
                        emb_state, dense_params, ids, dropout_key=kd
                    )
                    return ctr_models.bce_loss(logits, labels)

                loss, (g_emb, g_dense) = jax.value_and_grad(loss_fn, (0, 1))(
                    emb_params, state.dense_params
                )
                new_dense, dense_opt = adam_update(
                    g_dense, state.dense_opt, state.dense_params, lr
                )
                new_emb_params, emb_opt = adam_update(
                    g_emb, state.emb_opt, emb_params, lr,
                    weight_decay=self.cfg.emb_weight_decay,
                )
                emb_state = emb_mod.with_params(state.emb_state, new_emb_params, spec)
                return (
                    TrainState(emb_state, new_dense, dense_opt, emb_opt,
                               state.step + 1, rng),
                    {"loss": loss, "lr": lr},
                )

            if method == "prune":
                return self.wrap_prune_mask_update(step_fn)
            return step_fn

        if method == "lpt":

            @jax.jit
            def step_fn(state: TrainState, ids, labels):
                lr = self._lr_at(state.step)
                rng, kd, kn = jax.random.split(state.rng, 3)
                rows0 = lpt_mod.lookup(state.emb_state, ids)

                def loss_fn(rows, dense_params):
                    logits = self._logits_from_rows(rows, dense_params, kd)
                    return ctr_models.bce_loss(logits, labels)

                loss, (g_rows, g_dense) = jax.value_and_grad(loss_fn, (0, 1))(
                    rows0, state.dense_params
                )
                new_dense, dense_opt = adam_update(
                    g_dense, state.dense_opt, state.dense_params, lr
                )
                emb_state = lpt_mod.sparse_apply(
                    state.emb_state, ids, g_rows,
                    lr=lr, bits=spec.bits, rounding=spec.alpt.rounding,
                    noise_key=kn, optimizer=spec.row_optimizer,
                    weight_decay=self.cfg.emb_weight_decay,
                )
                return (
                    TrainState(emb_state, new_dense, dense_opt, None,
                               state.step + 1, rng),
                    {"loss": loss, "lr": lr},
                )

            return step_fn

        if method == "alpt":

            @jax.jit
            def step_fn(state: TrainState, ids, labels):
                lr = self._lr_at(state.step)
                rng, kd, kn = jax.random.split(state.rng, 3)
                rows0 = lpt_mod.lookup(state.emb_state, ids)

                def loss_rows_dense(rows, dense_params):
                    logits = self._logits_from_rows(rows, dense_params, kd)
                    return ctr_models.bce_loss(logits, labels)

                # Dense update (Algorithm 1 line 3) shares step 1's backward.
                loss, g_dense = jax.value_and_grad(
                    lambda dp: loss_rows_dense(rows0, dp)
                )(state.dense_params)
                new_dense, dense_opt = adam_update(
                    g_dense, state.dense_opt, state.dense_params, lr
                )
                emb_state, loss2, aux = alpt_mod.alpt_step(
                    state.emb_state,
                    ids,
                    lambda rows: loss_rows_dense(rows, state.dense_params),
                    cfg=spec.alpt._replace(
                        weight_decay=self.cfg.emb_weight_decay,
                        optimizer=spec.row_optimizer,
                    ),
                    lr=lr,
                    noise_key=kn,
                    loss_fn_step2=lambda rows: loss_rows_dense(rows, new_dense),
                )
                return (
                    TrainState(emb_state, new_dense, dense_opt, None,
                               state.step + 1, rng),
                    {"loss": loss2, "lr": lr, **aux},
                )

            return step_fn

        raise ValueError(f"unknown method {method!r}")

    # ------------------------------------------- grad/apply split (DP hooks)
    #
    # The fused step above is the paper-faithful single-device path (sparse
    # row updates for lpt/alpt).  The data-parallel wrapper
    # (repro.training.data_parallel) needs to all-reduce gradients *between*
    # backward and update, so the same math is also exposed as a
    # (grad_fn, apply_fn) pair.  Integer-table methods switch to the dense
    # formulation there (dense table gradient + lpt.dense_apply /
    # alpt dense pieces): it is the only shape that is rank-invariant — every
    # replica sees the same [n, d] gradient tensor — and the dense/sparse
    # update parity is regression-tested in tests/test_lpt_alpt.py.

    def build_grad_fn(self):
        """Per-(micro)batch backward: (state, ids, labels, kd) -> (loss, grads).

        ``grads`` is ``(g_emb, g_dense)`` where ``g_emb`` is the trainable
        embedding-params pytree for float methods or the dense [n, d]
        de-quantized-table gradient for lpt/alpt.
        """
        spec = self.spec

        if spec.method in emb_mod.FLOAT_METHODS:

            def grad_fn(state: TrainState, ids, labels, kd):
                emb_params = emb_mod.trainable_params(state.emb_state, spec)

                def loss_fn(emb_params, dense_params):
                    emb_state = emb_mod.with_params(state.emb_state, emb_params, spec)
                    logits = self._logits_fn(
                        emb_state, dense_params, ids, dropout_key=kd
                    )
                    return ctr_models.bce_loss(logits, labels)

                return jax.value_and_grad(loss_fn, (0, 1))(
                    emb_params, state.dense_params
                )

            return grad_fn

        def grad_fn(state: TrainState, ids, labels, kd):
            table_fp = lpt_mod.dense_table(state.emb_state)

            def loss_fn(table_fp, dense_params):
                rows = jnp.take(table_fp, ids, axis=0)
                logits = self._logits_from_rows(rows, dense_params, kd)
                return ctr_models.bce_loss(logits, labels)

            return jax.value_and_grad(loss_fn, (0, 1))(
                table_fp, state.dense_params
            )

        return grad_fn

    def build_apply_fn(self):
        """Post-sync update: consumes the (synced) gradients from
        :meth:`build_grad_fn` and returns ``(new_state, metrics)``.

        Signature: ``apply_fn(state, loss, grads, *, lr, rng, kn,
        delta_grad=None, batch_rows=None)``.  ``kn`` keys the SR write-back
        noise (int tables); ``delta_grad(w_new, step_vec, dense_params,
        gscale) -> g_step`` supplies the (synced) ALPT Delta gradient;
        ``batch_rows`` is the paper's b for the Delta gradient scale — the
        GLOBAL batch's table-row lookups, so the scale is independent of how
        the batch is sharded over replicas.
        """
        spec = self.spec
        method = spec.method

        if method in emb_mod.FLOAT_METHODS:

            def apply_fn(state, loss, grads, *, lr, rng, kn=None,
                         delta_grad=None, batch_rows=None):
                g_emb, g_dense = grads
                new_dense, dense_opt = adam_update(
                    g_dense, state.dense_opt, state.dense_params, lr
                )
                emb_params = emb_mod.trainable_params(state.emb_state, spec)
                new_emb_params, emb_opt = adam_update(
                    g_emb, state.emb_opt, emb_params, lr,
                    weight_decay=self.cfg.emb_weight_decay,
                )
                emb_state = emb_mod.with_params(
                    state.emb_state, new_emb_params, spec
                )
                return (
                    TrainState(emb_state, new_dense, dense_opt, emb_opt,
                               state.step + 1, rng),
                    {"loss": loss, "lr": lr},
                )

            return apply_fn

        if method == "lpt":

            def apply_fn(state, loss, grads, *, lr, rng, kn,
                         delta_grad=None, batch_rows=None):
                g_table, g_dense = grads
                new_dense, dense_opt = adam_update(
                    g_dense, state.dense_opt, state.dense_params, lr
                )
                emb_state = lpt_mod.dense_apply(
                    state.emb_state, g_table,
                    lr=lr, bits=spec.bits, rounding=spec.alpt.rounding,
                    noise_key=kn, optimizer=spec.row_optimizer,
                    weight_decay=self.cfg.emb_weight_decay,
                )
                return (
                    TrainState(emb_state, new_dense, dense_opt, None,
                               state.step + 1, rng),
                    {"loss": loss, "lr": lr},
                )

            return apply_fn

        if method == "alpt":

            def apply_fn(state, loss, grads, *, lr, rng, kn,
                         delta_grad, batch_rows):
                g_table, g_dense = grads
                new_dense, dense_opt = adam_update(
                    g_dense, state.dense_opt, state.dense_params, lr
                )
                table = state.emb_state
                acfg = spec.alpt._replace(
                    weight_decay=self.cfg.emb_weight_decay,
                    optimizer=spec.row_optimizer,
                )
                upd = alpt_mod.dense_weight_update(table, g_table, cfg=acfg, lr=lr)
                gscale = alpt_mod.grad_scale_factor(
                    acfg, batch_rows=int(batch_rows), dim=table.dim
                )
                # Algorithm 1 line 4 at the UPDATED dense params.
                g_step = delta_grad(upd.w_new, table.step, new_dense, gscale)
                new_table = alpt_mod.dense_finish(
                    table, upd, g_step, cfg=acfg, noise_key=kn
                )
                aux = {
                    "step_grad_norm": jnp.linalg.norm(g_step),
                    "mean_step": jnp.mean(new_table.step),
                }
                return (
                    TrainState(new_table, new_dense, dense_opt, None,
                               state.step + 1, rng),
                    {"loss": loss, "lr": lr, **aux},
                )

            return apply_fn

        raise ValueError(f"unknown method {method!r}")

    def build_delta_grad_fn(self):
        """Per-(micro)batch ALPT Delta gradient (dense formulation):
        ``(w_new, step_vec, dense_params, ids, labels, kd, gscale) -> g_step``.
        """
        spec = self.spec

        def delta_fn(w_new, step_vec, dense_params, ids, labels, kd, gscale):
            def loss_wrt_step(step_vec):
                table_q = quant.fake_quant_lsq(
                    jax.lax.stop_gradient(w_new), step_vec, spec.bits, gscale
                )
                rows = jnp.take(table_q, ids, axis=0)
                logits = self._logits_from_rows(rows, dense_params, kd)
                return ctr_models.bce_loss(logits, labels)

            return jax.grad(loss_wrt_step)(step_vec)

        return delta_fn

    def wrap_prune_mask_update(self, step_fn):
        """Host-side DeepLight mask refresh around a jitted step function —
        the same wrapper the fused path installs for method='prune'."""
        spec = self.spec
        update_mask = jax.jit(lambda s: pruning.update_mask(s, spec.prune))

        def step_with_mask(state, ids, labels):
            state, m = step_fn(state, ids, labels)
            step = int(state.step)
            emb = state.emb_state._replace(step=jnp.asarray(step, jnp.int32))
            if step % spec.prune.update_every == 0:
                emb = update_mask(emb)
            return state._replace(emb_state=emb), m

        return step_with_mask

    # ------------------------------------------------------------ api

    def train_step(self, state: TrainState, ids: np.ndarray, labels: np.ndarray):
        return self._train_step(state, jnp.asarray(ids), jnp.asarray(labels))

    def evaluate(self, state: TrainState, batches) -> dict[str, float]:
        all_labels, all_probs = [], []
        for ids, labels in batches:
            logits = self._eval_logits(
                state.emb_state, state.dense_params, jnp.asarray(ids)
            )
            all_probs.append(np.asarray(jax.nn.sigmoid(logits)))
            all_labels.append(labels)
        labels = np.concatenate(all_labels)
        probs = np.concatenate(all_probs)
        return {
            "auc": metrics.auc(labels, probs),
            "logloss": metrics.logloss(labels, probs),
        }

    def fit(self, data, *, steps: int, batch_size: int, eval_every: int = 0,
            eval_batches: int = 20, log=None):
        state = self.init_state()
        history = []
        for i in range(steps):
            ids, labels = data.batch("train", i, batch_size)
            state, m = self.train_step(state, ids, labels)
            if eval_every and (i + 1) % eval_every == 0:
                ev = self.evaluate(
                    state, data.batches("valid", batch_size, eval_batches)
                )
                history.append({"step": i + 1, **ev, "loss": float(m["loss"])})
                if log:
                    log(history[-1])
        return state, history
