"""End-to-end CTR training for every registered embedding method.

One trainer, one DCN/DeepFM backbone, any method in ``repro.methods`` — the
trainer never names a method.  It keys off two capability surfaces:

  float-leaf methods    : joint Adam over (embedding leaves, dense params)
  integer-table methods : the method's ``fused_row_step`` (Eq. 8 for LPT,
                          Algorithm 1 for ALPT, product-rule row updates for
                          composed tables like qr_lpt)

This mirrors the paper's experimental protocol (§4.1): Adam lr 1e-3, tenfold
decay boundaries, decoupled weight decay on embeddings, Delta lr 2e-5.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults, methods, metrics
from repro.models import ctr as ctr_models
from repro.models import embedding as emb_mod
from repro.obs.trace import tracer
from repro.optim import adam_init, adam_update
from repro.storage.tiered import HotRowCache


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    spec: emb_mod.EmbeddingSpec
    model: str = "dcn"  # 'dcn' | 'deepfm'
    dcn: ctr_models.DCNConfig | None = None
    deepfm: ctr_models.DeepFMConfig | None = None
    lr: float = 1e-3
    emb_weight_decay: float = 5e-8
    lr_boundaries: tuple[int, ...] = ()  # steps at which lr /= 10
    seed: int = 0
    # Gradient-sync bit width for data-parallel training
    # (repro.training.data_parallel): 32 = exact fp32, 2..8 = SR-compressed.
    dp_sync_bits: int = 32
    # Tiered storage (repro.storage): > 0 composes a device hot-row cache of
    # this many rows over every cacheable sub-table of the embedding state.
    # Training reads/writes route through the cache (dirty rows write back
    # before eviction); cache-on is bitwise-equal to cache-off.  Integer-
    # table methods only.
    cache_rows: int = 0
    # Opt-in non-finite guard (repro.faults.guards): detects NaN/Inf in the
    # step's loss or updated dense params inside the jitted step and skips
    # the update (state rolls back; step/rng advance).  Off by default so
    # the default compiled graph — and its bitwise parity contracts — is
    # untouched.  Also hosts the trainer.nonfinite / alpt.delta injection
    # seams when a FaultPlan is installed.
    guard: bool = False


class TrainState(NamedTuple):
    emb_state: Any
    dense_params: Any
    dense_opt: Any
    emb_opt: Any  # Adam state over float embedding leaves (None for int tables)
    step: jax.Array
    rng: jax.Array


class CTRTrainer:
    def __init__(self, cfg: TrainerConfig):
        self.cfg = cfg
        self.spec = cfg.spec
        self.method = methods.get(cfg.spec.method)
        if cfg.model == "dcn":
            assert cfg.dcn is not None
            self.model_cfg = cfg.dcn
            self._init_model = ctr_models.init_dcn
        else:
            assert cfg.deepfm is not None
            self.model_cfg = cfg.deepfm
            self._init_model = ctr_models.init_deepfm
        self._caches: list = []  # [(CacheSlot, HotRowCache)]
        if cfg.cache_rows:
            self._storage_slots = self.method.storage_spec(self.spec)
            if not self._storage_slots:
                raise ValueError(
                    f"cache_rows > 0 but method {self.spec.method!r} exposes "
                    "no cacheable storage slots (integer-table methods only)"
                )
        self.guard_stats = faults.GuardStats() if cfg.guard else None
        self._train_step = self._build_train_step()
        self._eval_logits = jax.jit(self._logits_fn)

    # ------------------------------------------------------------ init

    def init_state(self, key: jax.Array | None = None) -> TrainState:
        key = jax.random.PRNGKey(self.cfg.seed) if key is None else key
        k_emb, k_dense, k_rng = jax.random.split(key, 3)
        emb_state = self._install_caches(self.method.init(k_emb, self.spec))
        dense_params = self._init_model(k_dense, self.model_cfg)
        dense_opt = adam_init(dense_params)
        emb_params = self.method.trainable_params(emb_state, self.spec)
        emb_opt = adam_init(emb_params) if emb_params is not None else None
        return TrainState(
            emb_state=emb_state,
            dense_params=dense_params,
            dense_opt=dense_opt,
            emb_opt=emb_opt,
            step=jnp.zeros((), jnp.int32),
            rng=k_rng,
        )

    # ------------------------------------------------------------ cache

    def _install_caches(self, emb_state):
        """Compose a hot-row cache over each cacheable slot of the state."""
        if not self.cfg.cache_rows:
            return emb_state
        self._caches = []
        for slot in self._storage_slots:
            sub = slot.get(emb_state)
            cap = max(1, min(int(self.cfg.cache_rows), slot.rows))
            cache = HotRowCache(cap, int(sub.codes.shape[0]), name=slot.name)
            emb_state = slot.put(
                emb_state, sub._replace(codes=cache.wrap(sub.codes))
            )
            self._caches.append((slot, cache))
        return emb_state

    def _maintain_caches(self, state: "TrainState", ids) -> "TrainState":
        """Post-step cache maintenance: the policy observes the batch's ids
        (write=True — the routed sparse update put cached rows' new codes in
        the hot tier only) and applies admissions/evictions in one jitted
        transaction per slot."""
        if not self._caches:
            return state
        flat = np.asarray(ids).reshape(-1)
        emb_state = state.emb_state
        for slot, cache in self._caches:
            moves = cache.observe(slot.local_ids(flat), write=True)
            if moves is None:
                continue
            sub = slot.get(emb_state)
            emb_state = slot.put(
                emb_state, sub._replace(codes=cache.apply(sub.codes, moves))
            )
        return state._replace(emb_state=emb_state)

    def export_state(self, state: "TrainState") -> "TrainState":
        """The cache-off-equivalent state: every dirty hot row folded back
        into its backing container (bitwise-equal to an uncached run) —
        what checkpoints, serving exports, and parity tests consume.  The
        live ``state`` stays valid for continued training."""
        if not self._caches:
            return state
        emb_state = state.emb_state
        for slot, cache in self._caches:
            sub = slot.get(emb_state)
            emb_state = slot.put(
                emb_state, sub._replace(codes=cache.unwrap(sub.codes))
            )
        return state._replace(emb_state=emb_state)

    def import_state(self, state: "TrainState") -> "TrainState":
        """Re-install the hot-row caches over a restored (exported) state.

        Checkpoints hold the cache-off-equivalent containers from
        :meth:`export_state`, so a restore re-wraps them with *cold* caches
        (fresh policy state).  That is bitwise-harmless for the training
        math — cache-on == cache-off per row — so exact-resume parity of
        losses and of the exported final state survives a restart even
        though cache membership does not."""
        if not self.cfg.cache_rows:
            return state
        return state._replace(
            emb_state=self._install_caches(state.emb_state)
        )

    def cache_stats(self) -> list[dict]:
        return [cache.stats() for _, cache in self._caches]

    # ------------------------------------------------------------ lr

    def _lr_at(self, step: jax.Array) -> jax.Array:
        lr = jnp.asarray(self.cfg.lr, jnp.float32)
        for b in self.cfg.lr_boundaries:
            lr = lr * jnp.where(step >= b, 0.1, 1.0)
        return lr

    # ------------------------------------------------------------ forward

    def _logits_fn(self, emb_state, dense_params, ids, *, dropout_key=None):
        rows = self.method.lookup(emb_state, ids, self.spec)
        return self._logits_from_rows(rows, dense_params, dropout_key)

    def _logits_from_rows(self, rows, dense_params, dropout_key=None):
        return ctr_models.logits_from_rows(
            dense_params, rows, self.model_cfg, model=self.cfg.model,
            dropout_key=dropout_key,
        )

    # ------------------------------------------------------------ train step

    def _build_train_step(self):
        spec = self.spec
        method = self.method

        if not method.is_integer_table:

            @jax.jit
            def step_fn(state: TrainState, ids, labels):
                lr = self._lr_at(state.step)
                rng, kd = jax.random.split(state.rng)
                emb_params = method.trainable_params(state.emb_state, spec)

                def loss_fn(emb_params, dense_params):
                    emb_state = method.with_params(state.emb_state, emb_params, spec)
                    logits = self._logits_fn(
                        emb_state, dense_params, ids, dropout_key=kd
                    )
                    return ctr_models.bce_loss(logits, labels)

                loss, (g_emb, g_dense) = jax.value_and_grad(loss_fn, (0, 1))(
                    emb_params, state.dense_params
                )
                new_dense, dense_opt = adam_update(
                    g_dense, state.dense_opt, state.dense_params, lr
                )
                new_emb_params, emb_opt = adam_update(
                    g_emb, state.emb_opt, emb_params, lr,
                    weight_decay=self.cfg.emb_weight_decay,
                )
                emb_state = method.with_params(state.emb_state, new_emb_params, spec)
                return (
                    TrainState(emb_state, new_dense, dense_opt, emb_opt,
                               state.step + 1, rng),
                    {"loss": loss, "lr": lr},
                )

            if self.cfg.guard:
                step_fn = faults.wrap_ctr_step(step_fn)
            if method.has_host_refresh:
                return self.wrap_host_refresh(step_fn)
            return step_fn

        @jax.jit
        def step_fn(state: TrainState, ids, labels):
            lr = self._lr_at(state.step)
            rng, kd, kn = jax.random.split(state.rng, 3)

            def loss_from_rows(rows, dense_params):
                logits = self._logits_from_rows(rows, dense_params, kd)
                return ctr_models.bce_loss(logits, labels)

            def update_dense(g, opt, params):
                return adam_update(g, opt, params, lr)

            emb_state, new_dense, dense_opt, m = method.fused_row_step(
                state.emb_state, ids, spec=spec,
                loss_from_rows=loss_from_rows,
                dense_params=state.dense_params, dense_opt=state.dense_opt,
                update_dense=update_dense, lr=lr,
                weight_decay=self.cfg.emb_weight_decay, noise_key=kn,
            )
            return (
                TrainState(emb_state, new_dense, dense_opt, None,
                           state.step + 1, rng),
                {"lr": lr, **m},
            )

        if self.cfg.guard:
            return faults.wrap_ctr_step(step_fn)
        return step_fn

    # ------------------------------------------- grad/apply split (DP hooks)
    #
    # The fused step above is the paper-faithful single-device path (sparse
    # row updates for integer tables).  The data-parallel wrapper
    # (repro.training.data_parallel) needs to all-reduce gradients *between*
    # backward and update, so the same math is also exposed as a
    # (grad_fn, apply_fn) pair built on the method's *dense* formulation
    # (``dense_params`` / ``dense_update``): it is the only shape that is
    # rank-invariant — every replica sees the same gradient pytree — and the
    # dense/sparse update parity is regression-tested in tests/test_lpt_alpt.py.

    def build_grad_fn(self):
        """Per-(micro)batch backward: (state, ids, labels, kd) -> (loss, grads).

        ``grads`` is ``(g_emb, g_dense)`` where ``g_emb`` mirrors the
        method's ``dense_params`` — the trainable-params pytree for float
        methods, the dense [n, d] de-quantized-table gradient for integer
        tables.
        """
        spec = self.spec
        method = self.method

        def grad_fn(state: TrainState, ids, labels, kd):
            emb_params = method.dense_params(state.emb_state, spec)

            def loss_fn(emb_params, dense_params):
                rows = method.dense_lookup(state.emb_state, emb_params, ids, spec)
                logits = self._logits_from_rows(rows, dense_params, kd)
                return ctr_models.bce_loss(logits, labels)

            return jax.value_and_grad(loss_fn, (0, 1))(
                emb_params, state.dense_params
            )

        return grad_fn

    def build_apply_fn(self):
        """Post-sync update: consumes the (synced) gradients from
        :meth:`build_grad_fn` and returns ``(new_state, metrics)``.

        Signature: ``apply_fn(state, loss, grads, *, lr, rng, kn,
        delta_grad=None, batch_rows=None)``.  ``kn`` keys the SR write-back
        noise (int tables); ``delta_grad(w_new, step_vec, dense_params,
        gscale) -> g_step`` supplies the (synced) ALPT Delta gradient;
        ``batch_rows`` is the paper's b for the Delta gradient scale — the
        GLOBAL batch's table-row lookups, so the scale is independent of how
        the batch is sharded over replicas.
        """
        spec = self.spec
        method = self.method
        wd = self.cfg.emb_weight_decay

        def apply_fn(state, loss, grads, *, lr, rng, kn=None,
                     delta_grad=None, batch_rows=None):
            g_emb, g_dense = grads
            new_dense, dense_opt = adam_update(
                g_dense, state.dense_opt, state.dense_params, lr
            )
            wrapped = None
            if delta_grad is not None:
                # Algorithm 1 line 4 evaluates at the UPDATED dense params.
                def wrapped(w_new, step_vec, gscale):
                    return delta_grad(w_new, step_vec, new_dense, gscale)

            emb_state, emb_opt, aux = method.dense_update(
                state.emb_state, state.emb_opt, g_emb, spec=spec, lr=lr,
                weight_decay=wd, noise_key=kn, delta_grad=wrapped,
                batch_rows=batch_rows,
            )
            return (
                TrainState(emb_state, new_dense, dense_opt, emb_opt,
                           state.step + 1, rng),
                {"loss": loss, "lr": lr, **aux},
            )

        return apply_fn

    def build_delta_grad_fn(self):
        """Per-(micro)batch ALPT Delta gradient (dense formulation):
        ``(w_new, step_vec, dense_params, ids, labels, kd, gscale) -> g_step``.
        """
        spec = self.spec
        method = self.method
        wd = self.cfg.emb_weight_decay

        def delta_fn(w_new, step_vec, dense_params, ids, labels, kd, gscale):
            def loss_fn_q(table_q):
                rows = jnp.take(table_q, ids, axis=0)
                logits = self._logits_from_rows(rows, dense_params, kd)
                return ctr_models.bce_loss(logits, labels)

            return method.dense_delta_grad(
                w_new, step_vec, loss_fn_q, spec=spec, weight_decay=wd,
                gscale=gscale,
            )

        return delta_fn

    def wrap_host_refresh(self, step_fn):
        """Host-side periodic state refresh around a jitted step function
        (DeepLight mask recomputation for method='prune') — installed by the
        fused path and the DP wrapper whenever ``method.has_host_refresh``."""
        spec = self.spec
        method = self.method
        refresh = jax.jit(lambda s: method.host_refresh(s, spec))
        every = method.refresh_every(spec)

        def step_with_refresh(state, ids, labels):
            state, m = step_fn(state, ids, labels)
            step = int(state.step)
            with tracer().span("train.refresh", step=step):
                emb = method.host_sync(state.emb_state, step, spec)
                if step % every == 0:
                    emb = refresh(emb)
            return state._replace(emb_state=emb), m

        return step_with_refresh

    # Historical name, kept for callers of the pre-registry API.
    wrap_prune_mask_update = wrap_host_refresh

    # ------------------------------------------------------------ api

    def train_step(self, state: TrainState, ids: np.ndarray, labels: np.ndarray):
        # Span edges sit at the host boundaries only: the fused step is ONE
        # jitted function by design (its lookup/grad/update phases are not
        # host-separable), so the span fences its edge and the write-back
        # phase gets its own span.  With tracing off both spans are shared
        # null context managers and the fence is a no-op — the jitted
        # computation is identical either way (tests/test_obs.py holds the
        # instrumented run bitwise-equal).
        tr = tracer()
        with tr.span("train.step", step=int(state.step)):
            state, m = self._train_step(
                state, jnp.asarray(ids), jnp.asarray(labels)
            )
            tr.fence(m)
        if self.guard_stats is not None:
            self.guard_stats.observe(m)
        with tr.span("train.writeback"):
            state = self._maintain_caches(state, ids)
        return state, m

    def evaluate(self, state: TrainState, batches) -> dict[str, float]:
        all_labels, all_probs = [], []
        for ids, labels in batches:
            logits = self._eval_logits(
                state.emb_state, state.dense_params, jnp.asarray(ids)
            )
            all_probs.append(np.asarray(jax.nn.sigmoid(logits)))
            all_labels.append(labels)
        labels = np.concatenate(all_labels)
        probs = np.concatenate(all_probs)
        return {
            "auc": metrics.auc(labels, probs),
            "logloss": metrics.logloss(labels, probs),
        }

    def fit(self, data, *, steps: int, batch_size: int, eval_every: int = 0,
            eval_batches: int = 20, log=None):
        state = self.init_state()
        history = []
        for i in range(steps):
            ids, labels = data.batch("train", i, batch_size)
            state, m = self.train_step(state, ids, labels)
            if eval_every and (i + 1) % eval_every == 0:
                ev = self.evaluate(
                    state, data.batches("valid", batch_size, eval_batches)
                )
                history.append({"step": i + 1, **ev, "loss": float(m["loss"])})
                if log:
                    log(history[-1])
        return state, history
