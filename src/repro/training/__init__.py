from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

__all__ = ["CTRTrainer", "TrainerConfig"]
