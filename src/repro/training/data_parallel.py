"""Data-parallel training with compressed gradient synchronization.

This is the layer that finally makes ``repro.dist.collectives`` carry a real
training loop: a ``jax.shard_map`` wrapper around either trainer's step
(CTR and LM) that

  * replicates the train state over the mesh's ``data`` axis,
  * shards the batch's leading dimension across it,
  * all-reduces the dense + embedding gradients between backward and update,
    through a configurable ``sync_bits`` knob:

      - ``sync_bits=32`` — exact fp32 mean (``collectives.exact_pmean_local``,
        a rank-ordered all-gather + one deterministic reduction);
      - ``sync_bits=2..8`` — the paper's SR quantizer applied to
        communication (``collectives.compressed_pmean_local``): codes against
        a shared pmax step size, int32 psum, one dequantize.  Stochastic
        rounding keeps the reduction unbiased, so compression noise averages
        out across replicas instead of accumulating (Li et al., ALPT).

Exactness contract (held by tests/test_data_parallel.py):

  The n-device ``make_*_dp_step`` is **bitwise step-for-step equal** to the
  single-device microbatched trainer ``make_*_microbatch_step`` with
  ``n_shards == n`` — at *every* supported bit width.  At 32 bits both sides
  reduce the identical rank-ordered stack with the identical ``jnp.mean``; at
  2..8 bits the int32 code sum is associative and the SR noise is keyed by
  ``fold_in(sync key, rank)`` on both sides.  (A full-batch single-device step
  is the n=1 special case; against n>1 it agrees only up to float summation
  order, which is exactly why the microbatched reference exists.)

SR noise keying: one base key per wrapper (``sync_seed``), folded with the
step counter every step, then with the gradient-leaf index, then (inside the
collective) with the replica rank — so no two (step, tensor, rank) triples
share noise.

Embedding methods: every registered method (repro.methods) exposes a *dense*
formulation — float-leaf methods sync the trainable-params gradient pytree;
integer-table methods the dense [n, d] de-quantized-table gradient (plus the
all-reduced ALPT Delta gradient when ``has_learned_step``) — because it is
the only rank-invariant shape; the dense/sparse update parity is
regression-tested in tests/test_lpt_alpt.py.  This wrapper never names a
method: it keys off the method's capability flags.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import methods
from repro.dist import collectives
from repro.training import lm_trainer

# Key salt separating the ALPT Delta-gradient sync from the per-leaf main
# gradient syncs (leaf indices are small integers).
_DELTA_SALT = 0x0D317A

# 32 = exact fp32; any width quant.code_bounds supports is a valid code sync.
_VALID_BITS = (32,) + tuple(range(2, 9))


@dataclasses.dataclass(frozen=True)
class DPConfig:
    """Data-parallel sync policy.

    ``sync_bits``: 32 = exact fp32 mean; 2..8 = SR-compressed codes.
    ``axis``: mesh axis name the batch is sharded over.
    ``sync_seed``: base PRNG seed for the SR compression noise.
    ``use_kernels``: run the compressed collectives' SR quantize through the
    fused Pallas pass (bitwise-identical to the jnp path, so the stacked
    single-device twins stay exact at every width).
    """

    sync_bits: int = 32
    axis: str = "data"
    sync_seed: int = 0
    use_kernels: bool = True

    def __post_init__(self):
        if self.sync_bits not in _VALID_BITS:
            raise ValueError(
                f"sync_bits must be one of {_VALID_BITS}, got {self.sync_bits}"
            )


def _base_key(dp: DPConfig) -> jax.Array:
    return jax.random.PRNGKey(dp.sync_seed)


# --------------------------------------------------------------------- syncs


def _sync_leaf_mesh(leaf, key, dp: DPConfig):
    if dp.sync_bits == 32:
        return collectives.exact_pmean_local(leaf, dp.axis)
    return collectives.compressed_pmean_local(
        leaf, dp.axis, key, bits=dp.sync_bits, use_kernels=dp.use_kernels
    )


def _sync_tree_mesh(grads, key, dp: DPConfig):
    """All-reduce-mean every gradient leaf over ``dp.axis`` (inside shard_map)."""
    leaves, treedef = jax.tree.flatten(grads)
    out = [
        _sync_leaf_mesh(leaf, jax.random.fold_in(key, i), dp)
        for i, leaf in enumerate(leaves)
    ]
    return treedef.unflatten(out)


def _combine_leaf_stacked(stack, key, dp: DPConfig):
    if dp.sync_bits == 32:
        return collectives.exact_pmean_stacked(stack)
    return collectives.compressed_pmean_stacked(stack, key, bits=dp.sync_bits)


def _combine_tree_stacked(grad_stacks, key, dp: DPConfig):
    """Single-device twin of :func:`_sync_tree_mesh` over [n_shards, ...] stacks."""
    leaves, treedef = jax.tree.flatten(grad_stacks)
    out = [
        _combine_leaf_stacked(leaf, jax.random.fold_in(key, i), dp)
        for i, leaf in enumerate(leaves)
    ]
    return treedef.unflatten(out)


# The ALPT Delta gradient is one array for alpt (key used directly — the
# historical noise stream) but a pytree of per-sub-table vectors for composed
# learned-step methods (qr_alpt); multi-leaf trees fold the key per leaf.


def _sync_delta_mesh(g_step, key, dp: DPConfig):
    leaves, treedef = jax.tree.flatten(g_step)
    if len(leaves) == 1:
        return treedef.unflatten([_sync_leaf_mesh(leaves[0], key, dp)])
    return treedef.unflatten([
        _sync_leaf_mesh(leaf, jax.random.fold_in(key, i), dp)
        for i, leaf in enumerate(leaves)
    ])


def _combine_delta_stacked(g_stack, key, dp: DPConfig):
    leaves, treedef = jax.tree.flatten(g_stack)
    if len(leaves) == 1:
        return treedef.unflatten([_combine_leaf_stacked(leaves[0], key, dp)])
    return treedef.unflatten([
        _combine_leaf_stacked(leaf, jax.random.fold_in(key, i), dp)
        for i, leaf in enumerate(leaves)
    ])


def _reshape_shards(leaf, n_shards: int):
    if leaf.shape[0] % n_shards:
        raise ValueError(
            f"batch dim {leaf.shape[0]} not divisible by n_shards={n_shards}"
        )
    return leaf.reshape(n_shards, leaf.shape[0] // n_shards, *leaf.shape[1:])


def _resolve(dp: DPConfig | None, sync_bits_default: int) -> DPConfig:
    return DPConfig(sync_bits=sync_bits_default) if dp is None else dp


# ------------------------------------------------------------- CTR trainers


def make_ctr_dp_step(trainer, mesh, dp: DPConfig | None = None, *, jit: bool = True):
    """Data-parallel CTR train step on ``mesh``: ``step(state, ids, labels)``.

    State is replicated over ``dp.axis``; ``ids``/``labels`` are globally
    shaped and sharded on their leading (batch) dimension.  Returns the same
    ``(state, metrics)`` as ``trainer.train_step``; the loss metric is the
    exact mean over replicas regardless of ``sync_bits``.
    """
    dp = _resolve(dp, trainer.cfg.dp_sync_bits)
    if dp.axis not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {dp.axis!r}: {mesh.axis_names}")
    n_ranks = int(dict(mesh.shape)[dp.axis])
    grad_fn = trainer.build_grad_fn()
    apply_fn = trainer.build_apply_fn()
    delta_fn = (
        trainer.build_delta_grad_fn() if trainer.method.has_learned_step
        else None
    )
    base = _base_key(dp)

    def inner(state, ids, labels):
        lr = trainer._lr_at(state.step)
        rng, kd, kn = jax.random.split(state.rng, 3)
        loss, grads = grad_fn(state, ids, labels, kd)
        key = jax.random.fold_in(base, state.step)
        grads = _sync_tree_mesh(grads, key, dp)
        loss = collectives.exact_pmean_local(loss, dp.axis)

        delta_grad = None
        if delta_fn is not None:
            def delta_grad(w_new, step_vec, new_dense, gscale):
                g_step = delta_fn(
                    w_new, step_vec, new_dense, ids, labels, kd, gscale
                )
                return _sync_delta_mesh(
                    g_step, jax.random.fold_in(key, _DELTA_SALT), dp
                )

        return apply_fn(
            state, loss, grads, lr=lr, rng=rng, kn=kn, delta_grad=delta_grad,
            # Paper's b = the GLOBAL batch's row lookups (ids here is the
            # local shard), so turning on DP does not rescale the ALPT
            # Delta gradient (g = 1/sqrt(b*d*q)) with the device count.
            batch_rows=ids.size * n_ranks,
        )

    step = jax.shard_map(
        inner, mesh=mesh,
        in_specs=(P(), P(dp.axis), P(dp.axis)),
        out_specs=(P(), P()),
        check_vma=False,
    )
    if jit:
        # Donate the state so its replicated buffers are reused in place
        # (same contract as the non-DP train driver's jit).
        step = jax.jit(step, donate_argnums=(0,))
    if trainer.method.has_host_refresh:
        step = trainer.wrap_host_refresh(step)
    return step


def make_ctr_microbatch_step(
    trainer, n_shards: int, dp: DPConfig | None = None, *, jit: bool = True
):
    """Single-device microbatched (gradient-accumulation) CTR step.

    Scans ``n_shards`` microbatches through the same per-shard backward and
    combines the gradient stack with the same arithmetic as the mesh
    collectives — bitwise-equal to :func:`make_ctr_dp_step` on an
    ``n_shards``-device mesh, at every ``sync_bits``.
    """
    dp = _resolve(dp, trainer.cfg.dp_sync_bits)
    grad_fn = trainer.build_grad_fn()
    apply_fn = trainer.build_apply_fn()
    delta_fn = (
        trainer.build_delta_grad_fn() if trainer.method.has_learned_step
        else None
    )
    base = _base_key(dp)

    def step(state, ids, labels):
        lr = trainer._lr_at(state.step)
        rng, kd, kn = jax.random.split(state.rng, 3)
        ids_s = _reshape_shards(ids, n_shards)
        labels_s = _reshape_shards(labels, n_shards)

        def body(carry, shard):
            loss, grads = grad_fn(state, shard[0], shard[1], kd)
            return carry, (loss, grads)

        _, (losses, grad_stacks) = jax.lax.scan(body, None, (ids_s, labels_s))
        key = jax.random.fold_in(base, state.step)
        grads = _combine_tree_stacked(grad_stacks, key, dp)
        loss = collectives.exact_pmean_stacked(losses)

        delta_grad = None
        if delta_fn is not None:
            def delta_grad(w_new, step_vec, new_dense, gscale):
                def body2(carry, shard):
                    g = delta_fn(
                        w_new, step_vec, new_dense, shard[0], shard[1], kd,
                        gscale,
                    )
                    return carry, g

                _, g_stack = jax.lax.scan(body2, None, (ids_s, labels_s))
                return _combine_delta_stacked(
                    g_stack, jax.random.fold_in(key, _DELTA_SALT), dp
                )

        return apply_fn(
            state, loss, grads, lr=lr, rng=rng, kn=kn,
            delta_grad=delta_grad, batch_rows=ids.size,
        )

    if jit:
        step = jax.jit(step, donate_argnums=(0,))
    if trainer.method.has_host_refresh:
        step = trainer.wrap_host_refresh(step)
    return step


# -------------------------------------------------------------- LM trainers


def _check_lm_batch(batch):
    if "positions" in batch:
        raise NotImplementedError(
            "DP wrapper shards the leading batch dim; [3, B, T] positions "
            "(M-RoPE) are not supported here"
        )


def make_lm_dp_step(
    cfg, tcfg, mesh, dp: DPConfig | None = None, *,
    lr_schedule=None, jit: bool = True,
):
    """Data-parallel LM train step on ``mesh``: ``step(state, batch)``.

    Every batch leaf must lead with the (global) batch dimension.  State is
    replicated; loss/aux metrics are exact means over replicas.
    """
    dp = _resolve(dp, tcfg.dp_sync_bits)
    if dp.axis not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {dp.axis!r}: {mesh.axis_names}")
    n_ranks = int(dict(mesh.shape)[dp.axis])
    base = _base_key(dp)

    def grad_sync(grads, step):
        return _sync_tree_mesh(grads, jax.random.fold_in(base, step), dp)

    def step_grad_sync(g_step, step):
        key = jax.random.fold_in(jax.random.fold_in(base, step), _DELTA_SALT)
        return _sync_delta_mesh(g_step, key, dp)

    # The LM trainer's own step, with its DP hooks filled in: the all-reduces
    # run between backward and update, and dp_size keeps the ALPT Delta
    # gradient scale counting the GLOBAL batch's token lookups.
    hooked = lm_trainer.make_train_step(
        cfg, tcfg, lr_schedule,
        grad_sync=grad_sync, step_grad_sync=step_grad_sync, dp_size=n_ranks,
    )

    def inner(state, batch):
        new_state, metrics = hooked(state, batch)
        # loss/aux_loss were computed per replica before the sync; replace
        # them with exact cross-replica means so every metric is replicated
        # (and matches the microbatched twin bitwise).
        metrics = dict(metrics)
        metrics["loss"] = collectives.exact_pmean_local(
            metrics["loss"], dp.axis
        )
        metrics["aux_loss"] = jax.tree.map(
            lambda a: collectives.exact_pmean_local(a, dp.axis),
            metrics["aux_loss"],
        )
        return new_state, metrics

    smapped = jax.shard_map(
        inner, mesh=mesh,
        in_specs=(P(), P(dp.axis)),
        out_specs=(P(), P()),
        check_vma=False,
    )

    def step(state, batch):
        _check_lm_batch(batch)
        return smapped(state, batch)

    step = jax.jit(step, donate_argnums=(0,)) if jit else step
    return lm_trainer.wrap_host_refresh(step, cfg, tcfg)


def make_lm_microbatch_step(
    cfg, tcfg, n_shards: int, dp: DPConfig | None = None, *,
    lr_schedule=None, jit: bool = True,
):
    """Single-device microbatched LM step — bitwise-equal to
    :func:`make_lm_dp_step` on an ``n_shards``-device mesh."""
    dp = _resolve(dp, tcfg.dp_sync_bits)
    lr_at = lm_trainer.make_lr_fn(tcfg, lr_schedule)
    grad_fn = lm_trainer.make_grad_fn(cfg, tcfg)
    apply_fn = lm_trainer.make_apply_fn(cfg, tcfg)
    delta_fn = (
        lm_trainer.make_delta_grad_fn(cfg, tcfg)
        if methods.get(cfg.embedding_method).has_learned_step else None
    )
    base = _base_key(dp)

    def step(state, batch):
        _check_lm_batch(batch)
        lr = lr_at(state.step)
        rng, kn = jax.random.split(state.rng)
        batch_s = jax.tree.map(
            functools.partial(_reshape_shards, n_shards=n_shards), batch
        )

        def body(carry, shard):
            return carry, grad_fn(state, shard)

        _, ((losses, auxes), grad_stacks) = jax.lax.scan(body, None, batch_s)
        key = jax.random.fold_in(base, state.step)
        grads = _combine_tree_stacked(grad_stacks, key, dp)
        loss = collectives.exact_pmean_stacked(losses)
        aux = jax.tree.map(collectives.exact_pmean_stacked, auxes)

        delta_grad = None
        if delta_fn is not None:
            def delta_grad(w_new, step_vec, new_params, gscale):
                def body2(carry, shard):
                    return carry, delta_fn(
                        w_new, step_vec, new_params, shard, gscale
                    )

                _, g_stack = jax.lax.scan(body2, None, batch_s)
                return _combine_delta_stacked(
                    g_stack, jax.random.fold_in(key, _DELTA_SALT), dp
                )

        return apply_fn(
            state, (loss, aux), grads, lr=lr, rng=rng, kn=kn,
            delta_grad=delta_grad, batch_rows=int(batch["labels"].size),
        )

    step = jax.jit(step, donate_argnums=(0,)) if jit else step
    return lm_trainer.wrap_host_refresh(step, cfg, tcfg)


# ------------------------------------------------------- wire-byte reporting


def wire_report(grads, dp: DPConfig | int) -> dict:
    """Per-step, per-replica gradient wire-byte accounting.

    ``grads`` is a pytree of arrays or ``ShapeDtypeStruct``s (use
    :func:`ctr_grad_shapes` / :func:`lm_grad_shapes`).  Returns wire bytes at
    ``sync_bits``, the fp32 baseline bytes, and their ratio.
    """
    bits = dp.sync_bits if isinstance(dp, DPConfig) else int(dp)
    return {
        "sync_bits": bits,
        "wire_bytes_per_step": collectives.sync_wire_bytes(grads, bits),
        "fp32_wire_bytes_per_step": collectives.sync_wire_bytes(grads, 32),
        "compression_ratio": collectives.sync_compression_ratio(grads, bits),
    }


def ctr_grad_shapes(trainer, state, batch_size: int, n_fields: int):
    """ShapeDtypeStruct pytree of the gradients one CTR replica syncs."""
    grad_fn = trainer.build_grad_fn()
    ids = jax.ShapeDtypeStruct((batch_size, n_fields), jnp.int32)
    labels = jax.ShapeDtypeStruct((batch_size,), jnp.float32)

    def grads_of(state, ids, labels):
        return grad_fn(state, ids, labels, jax.random.PRNGKey(0))[1]

    return jax.eval_shape(grads_of, state, ids, labels)


def lm_grad_shapes(cfg, tcfg, state, batch):
    """ShapeDtypeStruct pytree of the gradients one LM replica syncs."""
    grad_fn = lm_trainer.make_grad_fn(cfg, tcfg)
    return jax.eval_shape(lambda s, b: grad_fn(s, b)[1], state, batch)
