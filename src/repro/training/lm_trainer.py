"""LM training step with a registry-dispatched vocab embedding table.

The embedding method comes from ``repro.methods`` (``cfg.embedding_method``);
each step:

  1. materialize the method's dense differentiable params (for integer
     tables: the de-quantized [V, d] table, vocab-sharded under pjit),
  2. differentiate the LM loss w.r.t. (those params, dense params),
  3. AdamW the dense params; the method's ``dense_update`` consumes the
     table gradient (LPT/ALPT row-update + SR-requantize — untouched rows
     stay bit-identical; float-leaf methods get decoupled-decay Adam),
  4. (``has_learned_step`` only) learn Delta via the second fake-quant
     forward (Algorithm 1).

This is the paper's training paradigm transplanted onto an LM vocab table;
the same function lowers on the 512-device production mesh (launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro import faults, methods
from repro.core import alpt as alpt_mod
from repro.core import pruning as pruning_mod
from repro.models import transformer as tfm
from repro.optim import adam_init, adam_update, clip_by_global_norm


class LMTrainState(NamedTuple):
    params: Any  # transformer blocks (+ untied head)
    opt: Any  # Adam state for params
    table: Any  # embedding-method state (lpt.LPTTable | f32 [V, d] | ...)
    table_opt: Any  # Adam state over float embedding leaves, else None
    step: jax.Array
    rng: jax.Array


@dataclasses.dataclass(frozen=True)
class LMTrainerConfig:
    lr: float = 3e-4
    weight_decay: float = 0.01
    emb_weight_decay: float = 5e-8  # paper's embedding decay
    grad_clip: float = 1.0
    row_optimizer: str = "adam"
    alpt_step_lr: float = 2e-5
    # ALPT's Delta substep doubles the forward cost; 'every_k' amortizes it
    # (beyond-paper knob; k=1 == faithful Algorithm 1).
    alpt_every: int = 1
    # DeepLight schedule for method='prune' (host-side mask refresh).
    prune: pruning_mod.PruneConfig = pruning_mod.PruneConfig()
    # Gradient-sync bit width for data-parallel training
    # (repro.training.data_parallel): 32 = exact fp32, 2..8 = SR-compressed.
    dp_sync_bits: int = 32
    # Route integer-table hot paths through the Pallas kernel suite
    # (EmbeddingSpec.use_kernels; auto-interpret off-TPU, bitwise-identical).
    use_kernels: bool = True
    # Pad the vocab table to kernel tiles (EmbeddingSpec.pad_to_tiles).
    pad_to_tiles: bool = False
    # Opt-in non-finite guard (repro.faults.guards): skip-step on NaN/Inf
    # in the step's loss or updated params, inside the traced step.  Off by
    # default so the default graph (and its parity contracts) is untouched.
    guard: bool = False


def embedding_spec_of(
    cfg: tfm.ModelConfig, tcfg: LMTrainerConfig | None = None
) -> methods.EmbeddingSpec:
    """The vocab table as an :class:`~repro.methods.EmbeddingSpec`."""
    tcfg = LMTrainerConfig() if tcfg is None else tcfg
    return methods.EmbeddingSpec(
        method=cfg.embedding_method,
        n=cfg.vocab_size,
        d=cfg.d_model,
        bits=cfg.embedding_bits,
        init_scale=cfg.d_model**-0.5,
        row_optimizer=tcfg.row_optimizer,
        alpt=alpt_mod.ALPTConfig(
            bits=cfg.embedding_bits,
            rounding="sr",
            optimizer=tcfg.row_optimizer,
            weight_decay=tcfg.emb_weight_decay,
            step_lr=tcfg.alpt_step_lr,
        ),
        prune=tcfg.prune,
        use_kernels=tcfg.use_kernels,
        pad_to_tiles=tcfg.pad_to_tiles,
    )


def init_state(key: jax.Array, cfg: tfm.ModelConfig, tcfg: LMTrainerConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    params = tfm.init_params(k1, cfg)
    opt = adam_init(params)
    spec = embedding_spec_of(cfg, tcfg)
    method = methods.get(spec.method)
    table = method.init(k2, spec)
    emb_params = method.trainable_params(table, spec)
    table_opt = adam_init(emb_params) if emb_params is not None else None
    return LMTrainState(
        params=params, opt=opt, table=table, table_opt=table_opt,
        step=jnp.zeros((), jnp.int32), rng=k3,
    )


def table_fp_of(
    state: LMTrainState, cfg: tfm.ModelConfig,
    tcfg: LMTrainerConfig | None = None,
) -> jax.Array:
    """The [V, d] float table evaluation forwards read."""
    spec = embedding_spec_of(cfg, tcfg)
    return methods.get(spec.method).eval_table(state.table, spec)


def make_grad_fn(cfg: tfm.ModelConfig, tcfg: LMTrainerConfig):
    """Per-(micro)batch backward: (state, batch) -> ((loss, aux), grads) with
    ``grads = (g_emb, g_params)``; ``g_emb`` mirrors the method's
    ``dense_params`` (for integer tables: the de-quantized table, kept
    vocab-sharded via the method's ``hint_dense_params``)."""
    spec = embedding_spec_of(cfg, tcfg)
    method = methods.get(spec.method)

    def grad_fn(state: LMTrainState, batch: dict[str, jax.Array]):
        emb_params = method.hint_dense_params(
            method.dense_params(state.table, spec)
        )

        def loss_of(emb_params, params):
            table_fp = method.dense_table_from(state.table, emb_params, spec)
            loss, aux = tfm.loss_fn(params, table_fp, batch, cfg)
            return loss, aux

        (loss, aux), (g_emb, g_params) = jax.value_and_grad(
            loss_of, argnums=(0, 1), has_aux=True
        )(emb_params, state.params)
        g_emb = method.hint_dense_params(g_emb)
        return (loss, aux), (g_emb, g_params)

    return grad_fn


def make_delta_grad_fn(cfg: tfm.ModelConfig, tcfg: LMTrainerConfig):
    """Per-(micro)batch ALPT Delta gradient:
    ``(w_new, step_vec, params, batch, gscale) -> g_step``."""
    spec = embedding_spec_of(cfg, tcfg)
    method = methods.get(spec.method)

    def delta_fn(w_new, step_vec, params, batch, gscale):
        return method.dense_delta_grad(
            w_new, step_vec,
            lambda t: tfm.loss_fn(params, t, batch, cfg)[0],
            spec=spec, weight_decay=tcfg.emb_weight_decay, gscale=gscale,
        )

    return delta_fn


def make_apply_fn(cfg: tfm.ModelConfig, tcfg: LMTrainerConfig):
    """Post-sync update: ``apply_fn(state, loss_aux, grads, *, lr, rng, kn,
    delta_grad=None, batch_rows=None) -> (state, metrics)``.

    ``delta_grad(w_new, step_vec, new_params, gscale) -> g_step`` supplies the
    (possibly all-reduced) ALPT Delta gradient; ``batch_rows`` is the paper's
    b — the GLOBAL batch's token count, sharding-independent."""
    spec = embedding_spec_of(cfg, tcfg)
    method = methods.get(spec.method)

    def apply_fn(state: LMTrainState, loss_aux, grads, *, lr, rng, kn,
                 delta_grad=None, batch_rows=None):
        loss, aux = loss_aux
        g_table, g_params = grads
        g_params, gnorm = clip_by_global_norm(g_params, tcfg.grad_clip)
        new_params, new_opt = adam_update(
            g_params, state.opt, state.params, lr,
            weight_decay=tcfg.weight_decay,
        )
        wrapped = None
        if delta_grad is not None:
            # Algorithm 1 line 4: loss at the UPDATED dense params.
            def wrapped(w_new, step_vec, gscale):
                return delta_grad(w_new, step_vec, new_params, gscale)

        new_table, new_table_opt, emb_aux = method.dense_update(
            state.table, state.table_opt, g_table, spec=spec, lr=lr,
            weight_decay=tcfg.emb_weight_decay, noise_key=kn,
            delta_grad=wrapped, batch_rows=batch_rows,
        )

        metrics = {
            "loss": loss,
            "aux_loss": aux,
            "grad_norm": gnorm,
            "lr": lr,
            **emb_aux,
        }
        return (
            LMTrainState(
                params=new_params, opt=new_opt, table=new_table,
                table_opt=new_table_opt, step=state.step + 1, rng=rng,
            ),
            metrics,
        )

    return apply_fn


def make_lr_fn(
    tcfg: LMTrainerConfig,
    lr_schedule: Callable[[jax.Array], jax.Array] | None = None,
):
    def lr_at(step):
        if lr_schedule is None:
            return jnp.asarray(tcfg.lr, jnp.float32)
        return lr_schedule(step)

    return lr_at


def make_train_step(
    cfg: tfm.ModelConfig,
    tcfg: LMTrainerConfig,
    lr_schedule: Callable[[jax.Array], jax.Array] | None = None,
    *,
    grad_sync: Callable | None = None,
    step_grad_sync: Callable | None = None,
    dp_size: int = 1,
):
    """Returns train_step(state, batch) -> (state, metrics). jit/pjit-ready.

    ``grad_sync(grads, step) -> grads`` and ``step_grad_sync(g_step, step) ->
    g_step`` are the data-parallel all-reduce hooks (identity when None) —
    applied between backward and update, and to the ALPT Delta gradient,
    respectively.  They run inside whatever jit/shard_map wraps this step
    (repro.training.data_parallel.make_lm_dp_step assembles exactly this).
    ``dp_size`` is the replica count when the step runs under shard_map, so
    the paper's b (ALPT Delta gradient scale) counts the GLOBAL batch's
    token lookups, not one replica's shard.
    """
    method = methods.get(cfg.embedding_method)
    lr_at = make_lr_fn(tcfg, lr_schedule)
    grad_fn = make_grad_fn(cfg, tcfg)
    apply_fn = make_apply_fn(cfg, tcfg)
    delta_fn = (
        make_delta_grad_fn(cfg, tcfg) if method.has_learned_step else None
    )

    def train_step(state: LMTrainState, batch: dict[str, jax.Array]):
        lr = lr_at(state.step)
        rng, kn = jax.random.split(state.rng)
        loss_aux, grads = grad_fn(state, batch)
        if grad_sync is not None:
            grads = grad_sync(grads, state.step)

        delta_grad = None
        if delta_fn is not None:
            def delta_grad(w_new, step_vec, new_params, gscale):
                g_step = delta_fn(w_new, step_vec, new_params, batch, gscale)
                if step_grad_sync is not None:
                    g_step = step_grad_sync(g_step, state.step)
                return g_step

        return apply_fn(
            state, loss_aux, grads, lr=lr, rng=rng, kn=kn,
            delta_grad=delta_grad,
            # Paper's b: table-row lookups in the global batch (token count).
            batch_rows=int(batch["labels"].size) * dp_size,
        )

    if tcfg.guard:
        return faults.wrap_lm_step(train_step)
    return train_step


def wrap_host_refresh(step_fn, cfg: tfm.ModelConfig, tcfg: LMTrainerConfig):
    """Host-side periodic table refresh around a (jitted) LM step — the
    DeepLight mask recomputation for ``method.has_host_refresh`` (prune).
    Identity for every other method, so drivers can apply it unconditionally
    AFTER jit (the refresh clock is host-driven, like the CTR trainer's
    ``wrap_host_refresh``)."""
    spec = embedding_spec_of(cfg, tcfg)
    method = methods.get(spec.method)
    if not method.has_host_refresh:
        return step_fn
    refresh = jax.jit(lambda t: method.host_refresh(t, spec))
    every = method.refresh_every(spec)

    def step_with_refresh(state, batch):
        state, m = step_fn(state, batch)
        step = int(state.step)
        table = method.host_sync(state.table, step, spec)
        if step % every == 0:
            table = refresh(table)
        return state._replace(table=table), m

    return step_with_refresh


def make_eval_step(cfg: tfm.ModelConfig):
    def eval_step(state: LMTrainState, batch):
        table_fp = table_fp_of(state, cfg)
        loss, aux = tfm.loss_fn(state.params, table_fp, batch, cfg)
        return {"loss": loss, "aux_loss": aux}

    return eval_step
