"""LM training step with quantized (LPT/ALPT) vocab embeddings.

The embedding table is integer state (codes + per-row Delta); each step:

  1. de-quantize the table (dense, vocab-sharded under pjit),
  2. differentiate the LM loss w.r.t. (table_fp, dense params),
  3. AdamW the dense params; LPT/ALPT row-update + SR-requantize the table
     (untouched rows stay bit-identical — lpt.dense_apply semantics),
  4. (ALPT only) learn Delta via the second fake-quant forward (Algorithm 1).

This is the paper's training paradigm transplanted onto an LM vocab table;
the same function lowers on the 512-device production mesh (launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import alpt as alpt_mod
from repro.core import lpt as lpt_mod
from repro.dist.context import hint
from repro.models import transformer as tfm
from repro.optim import adam_init, adam_update, clip_by_global_norm


class LMTrainState(NamedTuple):
    params: Any  # transformer blocks (+ untied head)
    opt: Any  # Adam state for params
    table: Any  # lpt.LPTTable (int methods) | f32 [V, d] (fp)
    table_opt: Any  # Adam state when table is fp, else None
    step: jax.Array
    rng: jax.Array


@dataclasses.dataclass(frozen=True)
class LMTrainerConfig:
    lr: float = 3e-4
    weight_decay: float = 0.01
    emb_weight_decay: float = 5e-8  # paper's embedding decay
    grad_clip: float = 1.0
    row_optimizer: str = "adam"
    alpt_step_lr: float = 2e-5
    # ALPT's Delta substep doubles the forward cost; 'every_k' amortizes it
    # (beyond-paper knob; k=1 == faithful Algorithm 1).
    alpt_every: int = 1


def init_state(key: jax.Array, cfg: tfm.ModelConfig, tcfg: LMTrainerConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    params = tfm.init_params(k1, cfg)
    opt = adam_init(params)
    if cfg.embedding_method in ("lpt", "alpt"):
        table = lpt_mod.init_table(
            k2, cfg.vocab_size, cfg.d_model, cfg.embedding_bits,
            init_scale=cfg.d_model**-0.5, optimizer=tcfg.row_optimizer,
        )
        table_opt = None
    else:
        table = (
            jax.random.normal(k2, (cfg.vocab_size, cfg.d_model), jnp.float32)
            * cfg.d_model**-0.5
        )
        table_opt = adam_init(table)
    return LMTrainState(
        params=params, opt=opt, table=table, table_opt=table_opt,
        step=jnp.zeros((), jnp.int32), rng=k3,
    )


def table_fp_of(state: LMTrainState, cfg: tfm.ModelConfig) -> jax.Array:
    if cfg.embedding_method in ("lpt", "alpt"):
        return lpt_mod.dense_table(state.table)
    return state.table


def make_train_step(
    cfg: tfm.ModelConfig,
    tcfg: LMTrainerConfig,
    lr_schedule: Callable[[jax.Array], jax.Array] | None = None,
):
    """Returns train_step(state, batch) -> (state, metrics). jit/pjit-ready."""

    def lr_at(step):
        if lr_schedule is None:
            return jnp.asarray(tcfg.lr, jnp.float32)
        return lr_schedule(step)

    def train_step(state: LMTrainState, batch: dict[str, jax.Array]):
        lr = lr_at(state.step)
        rng, kn = jax.random.split(state.rng)

        # Keep the de-quantized table and its gradient vocab-sharded through
        # the whole update (hint is the identity off-mesh).
        table_fp = hint(table_fp_of(state, cfg), "embed_table")

        def loss_of(table_fp, params):
            loss, aux = tfm.loss_fn(params, table_fp, batch, cfg)
            return loss, aux

        (loss, aux), (g_table, g_params) = jax.value_and_grad(
            loss_of, argnums=(0, 1), has_aux=True
        )(table_fp, state.params)
        g_table = hint(g_table, "embed_table")

        g_params, gnorm = clip_by_global_norm(g_params, tcfg.grad_clip)
        new_params, new_opt = adam_update(
            g_params, state.opt, state.params, lr,
            weight_decay=tcfg.weight_decay,
        )

        method = cfg.embedding_method
        if method == "fp":
            new_table, new_table_opt = adam_update(
                g_table, state.table_opt, state.table, lr,
                weight_decay=tcfg.emb_weight_decay,
            )
        elif method == "lpt":
            new_table = lpt_mod.dense_apply(
                state.table, g_table, lr=lr, bits=cfg.embedding_bits,
                rounding="sr", noise_key=kn, optimizer=tcfg.row_optimizer,
                weight_decay=tcfg.emb_weight_decay,
            )
            new_table_opt = None
        else:  # alpt
            acfg = alpt_mod.ALPTConfig(
                bits=cfg.embedding_bits, rounding="sr",
                optimizer=tcfg.row_optimizer,
                weight_decay=tcfg.emb_weight_decay,
                step_lr=tcfg.alpt_step_lr,
            )
            new_table = alpt_mod.alpt_dense_step(
                state.table, g_table,
                # Algorithm 1 line 4: loss at the UPDATED dense params.
                lambda t: tfm.loss_fn(new_params, t, batch, cfg)[0],
                cfg=acfg, lr=lr, noise_key=kn,
                # Paper's b: table-row lookups in this batch (= token count).
                batch_rows=int(batch["labels"].size),
            )
            new_table_opt = None

        metrics = {
            "loss": loss,
            "aux_loss": aux,
            "grad_norm": gnorm,
            "lr": lr,
        }
        return (
            LMTrainState(
                params=new_params, opt=new_opt, table=new_table,
                table_opt=new_table_opt, step=state.step + 1, rng=rng,
            ),
            metrics,
        )

    return train_step


def make_eval_step(cfg: tfm.ModelConfig):
    def eval_step(state: LMTrainState, batch):
        table_fp = table_fp_of(state, cfg)
        loss, aux = tfm.loss_fn(state.params, table_fp, batch, cfg)
        return {"loss": loss, "aux_loss": aux}

    return eval_step
