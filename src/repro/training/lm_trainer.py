"""LM training step with quantized (LPT/ALPT) vocab embeddings.

The embedding table is integer state (codes + per-row Delta); each step:

  1. de-quantize the table (dense, vocab-sharded under pjit),
  2. differentiate the LM loss w.r.t. (table_fp, dense params),
  3. AdamW the dense params; LPT/ALPT row-update + SR-requantize the table
     (untouched rows stay bit-identical — lpt.dense_apply semantics),
  4. (ALPT only) learn Delta via the second fake-quant forward (Algorithm 1).

This is the paper's training paradigm transplanted onto an LM vocab table;
the same function lowers on the 512-device production mesh (launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import alpt as alpt_mod
from repro.core import lpt as lpt_mod
from repro.dist.context import hint
from repro.models import transformer as tfm
from repro.optim import adam_init, adam_update, clip_by_global_norm


class LMTrainState(NamedTuple):
    params: Any  # transformer blocks (+ untied head)
    opt: Any  # Adam state for params
    table: Any  # lpt.LPTTable (int methods) | f32 [V, d] (fp)
    table_opt: Any  # Adam state when table is fp, else None
    step: jax.Array
    rng: jax.Array


@dataclasses.dataclass(frozen=True)
class LMTrainerConfig:
    lr: float = 3e-4
    weight_decay: float = 0.01
    emb_weight_decay: float = 5e-8  # paper's embedding decay
    grad_clip: float = 1.0
    row_optimizer: str = "adam"
    alpt_step_lr: float = 2e-5
    # ALPT's Delta substep doubles the forward cost; 'every_k' amortizes it
    # (beyond-paper knob; k=1 == faithful Algorithm 1).
    alpt_every: int = 1
    # Gradient-sync bit width for data-parallel training
    # (repro.training.data_parallel): 32 = exact fp32, 2..8 = SR-compressed.
    dp_sync_bits: int = 32


def init_state(key: jax.Array, cfg: tfm.ModelConfig, tcfg: LMTrainerConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    params = tfm.init_params(k1, cfg)
    opt = adam_init(params)
    if cfg.embedding_method in ("lpt", "alpt"):
        table = lpt_mod.init_table(
            k2, cfg.vocab_size, cfg.d_model, cfg.embedding_bits,
            init_scale=cfg.d_model**-0.5, optimizer=tcfg.row_optimizer,
        )
        table_opt = None
    else:
        table = (
            jax.random.normal(k2, (cfg.vocab_size, cfg.d_model), jnp.float32)
            * cfg.d_model**-0.5
        )
        table_opt = adam_init(table)
    return LMTrainState(
        params=params, opt=opt, table=table, table_opt=table_opt,
        step=jnp.zeros((), jnp.int32), rng=k3,
    )


def table_fp_of(state: LMTrainState, cfg: tfm.ModelConfig) -> jax.Array:
    if cfg.embedding_method in ("lpt", "alpt"):
        return lpt_mod.dense_table(state.table)
    return state.table


def _alpt_config(cfg: tfm.ModelConfig, tcfg: LMTrainerConfig) -> alpt_mod.ALPTConfig:
    return alpt_mod.ALPTConfig(
        bits=cfg.embedding_bits, rounding="sr",
        optimizer=tcfg.row_optimizer,
        weight_decay=tcfg.emb_weight_decay,
        step_lr=tcfg.alpt_step_lr,
    )


def make_grad_fn(cfg: tfm.ModelConfig, tcfg: LMTrainerConfig):
    """Per-(micro)batch backward: (state, batch) -> ((loss, aux), grads) with
    ``grads = (g_table, g_params)``.  The de-quantized table and its gradient
    stay vocab-sharded via ``hint`` (identity off-mesh)."""

    def grad_fn(state: LMTrainState, batch: dict[str, jax.Array]):
        table_fp = hint(table_fp_of(state, cfg), "embed_table")

        def loss_of(table_fp, params):
            loss, aux = tfm.loss_fn(params, table_fp, batch, cfg)
            return loss, aux

        (loss, aux), (g_table, g_params) = jax.value_and_grad(
            loss_of, argnums=(0, 1), has_aux=True
        )(table_fp, state.params)
        g_table = hint(g_table, "embed_table")
        return (loss, aux), (g_table, g_params)

    return grad_fn


def make_delta_grad_fn(cfg: tfm.ModelConfig, tcfg: LMTrainerConfig):
    """Per-(micro)batch ALPT Delta gradient:
    ``(w_new, step_vec, params, batch, gscale) -> g_step``."""
    acfg = _alpt_config(cfg, tcfg)

    def delta_fn(w_new, step_vec, params, batch, gscale):
        return alpt_mod.dense_delta_grad(
            w_new, step_vec,
            lambda t: tfm.loss_fn(params, t, batch, cfg)[0],
            cfg=acfg, gscale=gscale,
        )

    return delta_fn


def make_apply_fn(cfg: tfm.ModelConfig, tcfg: LMTrainerConfig):
    """Post-sync update: ``apply_fn(state, loss_aux, grads, *, lr, rng, kn,
    delta_grad=None, batch_rows=None) -> (state, metrics)``.

    ``delta_grad(w_new, step_vec, new_params, gscale) -> g_step`` supplies the
    (possibly all-reduced) ALPT Delta gradient; ``batch_rows`` is the paper's
    b — the GLOBAL batch's token count, sharding-independent."""
    method = cfg.embedding_method

    def apply_fn(state: LMTrainState, loss_aux, grads, *, lr, rng, kn,
                 delta_grad=None, batch_rows=None):
        loss, aux = loss_aux
        g_table, g_params = grads
        g_params, gnorm = clip_by_global_norm(g_params, tcfg.grad_clip)
        new_params, new_opt = adam_update(
            g_params, state.opt, state.params, lr,
            weight_decay=tcfg.weight_decay,
        )

        if method == "fp":
            new_table, new_table_opt = adam_update(
                g_table, state.table_opt, state.table, lr,
                weight_decay=tcfg.emb_weight_decay,
            )
        elif method == "lpt":
            new_table = lpt_mod.dense_apply(
                state.table, g_table, lr=lr, bits=cfg.embedding_bits,
                rounding="sr", noise_key=kn, optimizer=tcfg.row_optimizer,
                weight_decay=tcfg.emb_weight_decay,
            )
            new_table_opt = None
        else:  # alpt
            acfg = _alpt_config(cfg, tcfg)
            table = state.table
            upd = alpt_mod.dense_weight_update(table, g_table, cfg=acfg, lr=lr)
            gscale = alpt_mod.grad_scale_factor(
                acfg, batch_rows=int(batch_rows), dim=table.dim
            )
            # Algorithm 1 line 4: loss at the UPDATED dense params.
            g_step = delta_grad(upd.w_new, table.step, new_params, gscale)
            new_table = alpt_mod.dense_finish(
                table, upd, g_step, cfg=acfg, noise_key=kn
            )
            new_table_opt = None

        metrics = {
            "loss": loss,
            "aux_loss": aux,
            "grad_norm": gnorm,
            "lr": lr,
        }
        return (
            LMTrainState(
                params=new_params, opt=new_opt, table=new_table,
                table_opt=new_table_opt, step=state.step + 1, rng=rng,
            ),
            metrics,
        )

    return apply_fn


def make_lr_fn(
    tcfg: LMTrainerConfig,
    lr_schedule: Callable[[jax.Array], jax.Array] | None = None,
):
    def lr_at(step):
        if lr_schedule is None:
            return jnp.asarray(tcfg.lr, jnp.float32)
        return lr_schedule(step)

    return lr_at


def make_train_step(
    cfg: tfm.ModelConfig,
    tcfg: LMTrainerConfig,
    lr_schedule: Callable[[jax.Array], jax.Array] | None = None,
    *,
    grad_sync: Callable | None = None,
    step_grad_sync: Callable | None = None,
    dp_size: int = 1,
):
    """Returns train_step(state, batch) -> (state, metrics). jit/pjit-ready.

    ``grad_sync(grads, step) -> grads`` and ``step_grad_sync(g_step, step) ->
    g_step`` are the data-parallel all-reduce hooks (identity when None) —
    applied between backward and update, and to the ALPT Delta gradient,
    respectively.  They run inside whatever jit/shard_map wraps this step
    (repro.training.data_parallel.make_lm_dp_step assembles exactly this).
    ``dp_size`` is the replica count when the step runs under shard_map, so
    the paper's b (ALPT Delta gradient scale) counts the GLOBAL batch's
    token lookups, not one replica's shard.
    """
    lr_at = make_lr_fn(tcfg, lr_schedule)
    grad_fn = make_grad_fn(cfg, tcfg)
    apply_fn = make_apply_fn(cfg, tcfg)
    delta_fn = (
        make_delta_grad_fn(cfg, tcfg)
        if cfg.embedding_method == "alpt" else None
    )

    def train_step(state: LMTrainState, batch: dict[str, jax.Array]):
        lr = lr_at(state.step)
        rng, kn = jax.random.split(state.rng)
        loss_aux, grads = grad_fn(state, batch)
        if grad_sync is not None:
            grads = grad_sync(grads, state.step)

        delta_grad = None
        if delta_fn is not None:
            def delta_grad(w_new, step_vec, new_params, gscale):
                g_step = delta_fn(w_new, step_vec, new_params, batch, gscale)
                if step_grad_sync is not None:
                    g_step = step_grad_sync(g_step, state.step)
                return g_step

        return apply_fn(
            state, loss_aux, grads, lr=lr, rng=rng, kn=kn,
            delta_grad=delta_grad,
            # Paper's b: table-row lookups in the global batch (token count).
            batch_rows=int(batch["labels"].size) * dp_size,
        )

    return train_step


def make_eval_step(cfg: tfm.ModelConfig):
    def eval_step(state: LMTrainState, batch):
        table_fp = table_fp_of(state, cfg)
        loss, aux = tfm.loss_fn(state.params, table_fp, batch, cfg)
        return {"loss": loss, "aux_loss": aux}

    return eval_step
