"""Minimal, dependency-free stand-in for the ``hypothesis`` package.

Installed by ``tests/conftest.py`` ONLY when the real package cannot be
imported (offline containers).  It covers exactly the API surface the test
suite uses — ``given``, ``settings``, ``assume`` and the ``integers`` /
``floats`` / ``sampled_from`` / ``booleans`` / ``lists`` strategies — by
running each property against a deterministic pseudo-random sample of the
strategy space (seeded from the test name, so failures reproduce).  It does
no shrinking and no coverage-guided search; it is a fallback, not a
replacement — ``requirements.txt`` declares the real dependency.
"""
from __future__ import annotations

import random
import sys
import types
import zlib


class _Unsatisfied(Exception):
    """Raised by ``assume(False)``; the example is skipped."""


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> Strategy:
    return Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(elements) -> Strategy:
    pool = list(elements)
    return Strategy(lambda rng: pool[rng.randrange(len(pool))])


def booleans() -> Strategy:
    return Strategy(lambda rng: rng.random() < 0.5)


def just(value) -> Strategy:
    return Strategy(lambda rng: value)


def lists(elements: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]

    return Strategy(draw)


_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Records ``max_examples`` on the decorated function; other knobs are
    accepted and ignored."""

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def assume(condition) -> None:
    if not condition:
        raise _Unsatisfied


def given(**strategies):
    def deco(fn):
        def wrapper():
            n = getattr(
                wrapper, "_stub_max_examples",
                getattr(fn, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES),
            )
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            ran = 0
            attempts = 0
            while ran < n and attempts < 10 * n:
                attempts += 1
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                try:
                    fn(**drawn)
                except _Unsatisfied:
                    continue
                except BaseException as e:
                    raise AssertionError(
                        f"property {fn.__name__} falsified by {drawn!r}"
                    ) from e
                ran += 1

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # Zero-arg signature so pytest doesn't mistake drawn params for
        # fixtures (real hypothesis does the same signature surgery).
        import inspect

        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco


def install() -> None:
    """Register stub ``hypothesis`` / ``hypothesis.strategies`` modules."""
    if "hypothesis" in sys.modules:
        return
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None
    )
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "booleans", "just",
                 "lists"):
        setattr(st, name, globals()[name])
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
