"""Compatibility shims for optional/aging dependencies.

Two concerns live here, both gated so that a fully provisioned environment
never sees them:

* ``jax_shim`` — backfills ``jax.shard_map`` (with the modern ``check_vma``
  keyword) onto jax versions that only ship
  ``jax.experimental.shard_map.shard_map(check_rep=...)``.
* ``hypothesis_stub`` — a minimal property-testing stand-in installed by
  ``tests/conftest.py`` only when the real ``hypothesis`` package is absent
  (offline CI containers).
"""
