"""Backfill modern jax surface on versions that predate it.

The repo targets the modern spellings ``jax.shard_map(f, mesh=...,
in_specs=..., out_specs=..., check_vma=False)`` and
``jax.lax.axis_size(name)``.  Older jax (<= 0.4.x) only exposes
``jax.experimental.shard_map.shard_map`` (replication-check keyword named
``check_rep``) and has no ``axis_size`` at all.  ``ensure_jax_compat()``
installs adapters so every caller (library code and tests alike) can use the
one modern spelling.
"""
from __future__ import annotations

import jax


def ensure_axis_size() -> None:
    if hasattr(jax.lax, "axis_size"):
        return

    def axis_size(axis_name):
        # psum of the literal 1 over a named axis constant-folds to the axis
        # size (a Python int) — exactly what modern axis_size returns.
        return jax.lax.psum(1, axis_name)

    jax.lax.axis_size = axis_size


def ensure_pallas_interpret_params() -> None:
    """Backfill ``pallas.tpu.InterpretParams`` (the TPU-semantics interpreter
    request) on jax versions without the TPU interpreter.  The class is just
    a marker here; kernels that accept ``interpret=InterpretParams()`` detect
    the stub (``_compat_stub``) and run an equivalent reference path that
    reproduces the interpreter's documented semantics (PRNG stubbed to
    zeros)."""
    from jax.experimental.pallas import tpu as pltpu

    if hasattr(pltpu, "InterpretParams"):
        return

    class InterpretParams:
        _compat_stub = True

        def __init__(self, **_kw):
            pass

    pltpu.InterpretParams = InterpretParams


def ensure_jax_compat() -> None:
    ensure_shard_map()
    ensure_axis_size()


def ensure_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return

    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, mesh=None, in_specs=None, out_specs=None, *,
                  check_vma=None, check_rep=None, auto=frozenset()):
        check = True
        if check_rep is not None:
            check = check_rep
        elif check_vma is not None:
            check = check_vma
        return _legacy_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check, auto=auto,
        )

    jax.shard_map = shard_map
