"""``python -m repro.analysis`` — the static contract gate.

Runs the AST lint rules over ``src/repro`` + ``benchmarks``, the jaxpr
invariant checkers over the trace-target registry, and the perf-regression
gate over the BENCH_*.json artifacts vs ``BENCH_BASELINE.json``; exits
nonzero on any unsuppressed finding.

    python -m repro.analysis                   # all layers, human output
    python -m repro.analysis --json            # machine findings (CI artifact)
    python -m repro.analysis --no-jaxpr        # skip the trace checkers
    python -m repro.analysis --no-perf         # skip the bench gate
    python -m repro.analysis --perf-report perf-gate-report.json
    python -m repro.analysis --suppressions analysis-suppressions.txt
    python -m repro.analysis --list-rules      # the catalog
"""
from __future__ import annotations

import argparse
import pathlib
import sys


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST lint + jaxpr invariant checks for the "
        "quantization contracts",
    )
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON on stdout")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the AST lint layer")
    ap.add_argument("--no-jaxpr", action="store_true",
                    help="skip the jaxpr trace checkers")
    ap.add_argument("--no-perf", action="store_true",
                    help="skip the BENCH perf-regression gate")
    ap.add_argument("--perf-report", type=pathlib.Path, default=None,
                    help="write raw perf-gate findings as JSON here when "
                    "any exist (the artifact CI uploads on failure)")
    ap.add_argument("--rule", action="append", default=None,
                    help="run only this lint rule (repeatable)")
    ap.add_argument("--target", action="append", default=None,
                    help="run only this jaxpr trace target (repeatable)")
    ap.add_argument("--suppressions", type=pathlib.Path, default=None,
                    help="explicit suppression file (rule path[:line] per "
                    "line); defaults to <repo>/analysis-suppressions.txt "
                    "when present")
    ap.add_argument("--no-suppressions", action="store_true",
                    help="ignore the suppression file entirely")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule/checker catalog and exit")
    ap.add_argument("--paths", nargs="*", default=None,
                    help="lint these files instead of src/ + benchmarks/")
    args = ap.parse_args(argv)

    from repro.analysis.findings import findings_to_json, load_suppressions
    from repro.analysis.lint import RULES, all_rules, run_lint

    if args.list_rules:
        from repro.analysis.jaxpr import CHECKS
        from repro.analysis.jaxpr.targets import all_targets
        print("lint rules:")
        for rule in all_rules():
            doc = (rule.__doc__ or "").strip().splitlines()[0]
            print(f"  {rule.name:28s} {doc}")
        print("jaxpr checkers:")
        for name in CHECKS:
            print(f"  jaxpr-{name}")
        print("trace targets:")
        for t in all_targets():
            print(f"  {t.name:28s} checks={','.join(t.checks)}")
        print("perf gate:")
        print("  perf-regression              BENCH_*.json artifacts vs "
              "BENCH_BASELINE.json (repro.obs.gate)")
        return 0

    findings = []
    if not args.no_lint:
        rules = None
        if args.rule:
            all_rules()  # populate the registry
            unknown = [r for r in args.rule if r not in RULES]
            if unknown:
                ap.error(f"unknown rule(s): {', '.join(unknown)}")
            rules = [RULES[r] for r in args.rule]
        paths = ([pathlib.Path(p) for p in args.paths]
                 if args.paths else None)
        findings.extend(run_lint(paths=paths, rules=rules))

    if not args.no_jaxpr:
        from repro.analysis.jaxpr.targets import all_targets, run_jaxpr_checks
        if args.target:
            known = {t.name for t in all_targets()}
            unknown = [t for t in args.target if t not in known]
            if unknown:
                ap.error(f"unknown target(s): {', '.join(unknown)}")
        findings.extend(run_jaxpr_checks(names=args.target))

    if not args.no_perf:
        from repro.analysis.perf import run_perf_checks
        findings.extend(run_perf_checks(report_path=args.perf_report))

    supp_path = args.suppressions
    if supp_path is None and not args.no_suppressions:
        from repro.analysis.lint import REPO_ROOT
        default = REPO_ROOT / "analysis-suppressions.txt"
        supp_path = default if default.exists() else None
    if args.no_suppressions:
        supp_path = None
    supp = load_suppressions(supp_path)
    findings = supp.apply(findings)

    if args.json:
        print(findings_to_json(findings))
    else:
        for f in findings:
            print(f.format())
        layers = [lyr for lyr, off in
                  (("lint", args.no_lint), ("jaxpr", args.no_jaxpr),
                   ("perf", args.no_perf))
                  if not off]
        print(f"repro.analysis [{'+'.join(layers)}]: "
              f"{len(findings)} finding(s)")
    for entry in supp.unused():
        print(f"warning: unused suppression: {entry.rule} "
              f"{entry.path_glob}"
              + (f":{entry.line}" if entry.line else ""),
              file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
