"""Static analysis for the repo's quantization contracts.

Two layers, one CLI (``python -m repro.analysis``):

* :mod:`repro.analysis.lint` — AST lint rules over ``src/`` and
  ``benchmarks/`` (no-string-dispatch, no-raw-code-casts,
  no-direct-storage-access, rng-key-discipline, no-silent-fallback,
  no-unfenced-model-grad).
* :mod:`repro.analysis.jaxpr` — jaxpr-level invariant checkers over the
  real jitted train/Engine steps (int8-resident serving, dequant-only
  code widening, packed sub-byte containment, packed collective wire).

Both layers emit :class:`~repro.analysis.findings.Finding` records with
``rule``, ``path:line`` and a fix hint; the CLI exits nonzero on any
unsuppressed finding.
"""
from repro.analysis.findings import Finding, Suppressions, load_suppressions

__all__ = ["Finding", "Suppressions", "load_suppressions"]
