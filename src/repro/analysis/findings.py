"""Structured findings + the suppression file shared by both layers.

A finding pins a contract violation to ``rule`` + ``path:line`` and carries
a one-line fix hint.  Jaxpr-layer findings use the trace-target name as the
path (``<target:engine-ctr/lpt>``) and line 0 — suppressions address them
the same way source findings are addressed.

Suppression file format (one entry per line, ``#`` comments)::

    rule-name path/glob            # whole file
    rule-name path/glob:123        # one line only

Paths are repo-relative posix and matched with :func:`fnmatch.fnmatch`, so
``no-raw-code-casts benchmarks/*`` silences a rule for a directory.  The
file is an *explicit* escape hatch: every entry is a reviewed decision, and
the CLI prints which entries actually matched so dead suppressions rot
visibly.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
import pathlib


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # repo-relative posix path, or "<target:...>" for jaxpr
    line: int          # 1-based source line; 0 for whole-target findings
    message: str
    hint: str = ""

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        out = f"{loc}: [{self.rule}] {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class SuppressionEntry:
    rule: str
    path_glob: str
    line: int | None   # None -> whole file

    def matches(self, f: Finding) -> bool:
        if self.rule != f.rule and self.rule != "*":
            return False
        if not fnmatch.fnmatch(f.path, self.path_glob):
            return False
        return self.line is None or self.line == f.line


class Suppressions:
    """Parsed suppression file; tracks which entries matched anything."""

    def __init__(self, entries: list[SuppressionEntry] = ()):  # type: ignore[assignment]
        self.entries = list(entries)
        self.used: set[SuppressionEntry] = set()

    def suppressed(self, f: Finding) -> bool:
        hit = False
        for e in self.entries:
            if e.matches(f):
                self.used.add(e)
                hit = True
        return hit

    def apply(self, findings: list[Finding]) -> list[Finding]:
        return [f for f in findings if not self.suppressed(f)]

    def unused(self) -> list[SuppressionEntry]:
        return [e for e in self.entries if e not in self.used]


def load_suppressions(path: str | pathlib.Path | None) -> Suppressions:
    if path is None:
        return Suppressions()
    entries = []
    for ln, raw in enumerate(
        pathlib.Path(path).read_text().splitlines(), start=1
    ):
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        parts = line.split()
        if len(parts) != 2:
            raise ValueError(
                f"{path}:{ln}: expected '<rule> <path-glob>[:line]', "
                f"got {raw!r}"
            )
        rule, target = parts
        lineno: int | None = None
        if ":" in target:
            target, _, tail = target.rpartition(":")
            if not tail.isdigit():
                raise ValueError(
                    f"{path}:{ln}: line suffix must be an integer: {raw!r}"
                )
            lineno = int(tail)
        entries.append(SuppressionEntry(rule, target, lineno))
    return Suppressions(entries)


def findings_to_json(findings: list[Finding]) -> str:
    return json.dumps(
        {"findings": [f.to_json() for f in findings],
         "count": len(findings)},
        indent=2,
    )
