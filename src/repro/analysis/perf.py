"""The ``perf-regression`` layer: BENCH artifacts vs the committed baseline.

Thin adapter over :mod:`repro.obs.gate` that turns gate findings into the
analysis layer's :class:`~repro.analysis.findings.Finding` shape, so a perf
regression fails ``python -m repro.analysis`` exactly the way a lint or
jaxpr contract violation does (and is addressable through the same
suppression file, rule name ``perf-regression``).

The baseline is ``BENCH_BASELINE.json`` at the repo root — seeded and
re-seeded deliberately via ``python -m repro.obs.gate seed``.  No baseline
file means the gate has nothing to hold and the layer passes (a fresh
clone without artifacts must not fail analysis); a *committed* baseline
whose artifacts have regressed or vanished fails it.
"""
from __future__ import annotations

import json
import pathlib

from repro.analysis.findings import Finding
from repro.obs import gate

BASELINE_NAME = "BENCH_BASELINE.json"

_HINT = (
    "re-run the benchmark to refresh the artifact; if the change is "
    "intended, re-seed the baseline: python -m repro.obs.gate seed "
    "BENCH_*.json --out BENCH_BASELINE.json"
)


def run_perf_checks(root: pathlib.Path | None = None,
                    baseline_path: pathlib.Path | None = None,
                    report_path: pathlib.Path | None = None) -> list[Finding]:
    """Compare the repo-root BENCH_*.json artifacts to the baseline.

    ``report_path`` (CI) gets the raw gate findings as JSON whenever any
    exist — the artifact a failing analysis job uploads for diffing.
    """
    if root is None:
        from repro.analysis.lint import REPO_ROOT

        root = REPO_ROOT
    baseline_path = baseline_path or root / BASELINE_NAME
    if not pathlib.Path(baseline_path).exists():
        return []
    baseline = gate.load_baseline(baseline_path)
    fresh = gate.load_fresh(root, baseline)
    raw = gate.compare(baseline, fresh)
    if report_path is not None and raw:
        pathlib.Path(report_path).write_text(json.dumps(
            [f.to_json() for f in raw], indent=2) + "\n")
    return [
        Finding(
            rule="perf-regression",
            path=f.bench,
            line=0,
            message=f"{f.cell} :: {f.metric}: {f.message}",
            hint=_HINT,
        )
        for f in raw
    ]
