"""Visitor-based AST lint framework.

A :class:`Rule` owns a name, a fix hint, and path ``include``/``exclude``
globs (matched against the repo-relative posix path — the per-rule
allowlist).  :func:`run_lint` parses each file once into a shared
:class:`Source` (AST + resolved import aliases + parent links) and hands it
to every applicable rule.

The framework resolves import aliases up front so rules match *semantics*,
not spellings: ``import jax.numpy as jnp`` and ``from jax import numpy as
xnp`` both make ``xnp.int8`` resolve to ``jax.numpy.int8``.  That kills the
aliased-import false-negative class the old regex guards had, and parsing
(rather than line-scanning) kills the false positives from strings,
comments and docstrings.

Adding a rule: subclass :class:`Rule`, implement ``check(source)``
returning :class:`~repro.analysis.findings.Finding` records, decorate with
:func:`register`.  See :mod:`repro.analysis.lint.rules` for the catalog.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import pathlib

from repro.analysis.findings import Finding

# repo root = parents[4] of .../src/repro/analysis/lint/__init__.py
REPO_ROOT = pathlib.Path(__file__).resolve().parents[4]
DEFAULT_LINT_ROOTS = ("src/repro", "benchmarks")


@dataclasses.dataclass
class Source:
    """One parsed file + the cross-rule derived indices."""

    path: pathlib.Path
    rel: str                       # repo-relative posix path
    text: str
    tree: ast.Module
    aliases: dict[str, str]        # local name -> dotted module/object path

    @classmethod
    def parse(cls, path: pathlib.Path, root: pathlib.Path = REPO_ROOT
              ) -> "Source":
        text = path.read_text()
        tree = ast.parse(text, filename=str(path))
        _link_parents(tree)
        rel = path.resolve().relative_to(root).as_posix()
        return cls(path=path, rel=rel, text=text, tree=tree,
                   aliases=_import_aliases(tree))

    @classmethod
    def from_text(cls, text: str, rel: str = "<snippet>.py") -> "Source":
        """Parse an in-memory snippet (fixture tests use this)."""
        tree = ast.parse(text)
        _link_parents(tree)
        return cls(path=pathlib.Path(rel), rel=rel, text=text, tree=tree,
                   aliases=_import_aliases(tree))

    # -- semantic helpers shared by rules ---------------------------------

    def dotted(self, node: ast.AST) -> str | None:
        """Resolve ``Name``/``Attribute`` chains through import aliases.

        ``jnp.int8`` -> ``jax.numpy.int8`` when the file did
        ``import jax.numpy as jnp``; unresolvable heads keep their local
        spelling (``self.foo`` -> ``self.foo``).
        """
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        head = self.aliases.get(node.id, node.id)
        return ".".join([head, *reversed(parts)]) if parts else head

    def is_module_alias(self, name: str) -> bool:
        """True when ``name`` is bound by a plain module import."""
        return name in self.aliases and name in self._module_names

    @property
    def _module_names(self) -> set[str]:
        names = getattr(self, "_module_names_cache", None)
        if names is None:
            names = set()
            for node in ast.walk(self.tree):
                if isinstance(node, ast.Import):
                    for a in node.names:
                        names.add(a.asname or a.name.split(".", 1)[0])
                elif isinstance(node, ast.ImportFrom) and node.module:
                    # `from jax import numpy as jnp` binds a module too;
                    # we cannot tell modules from objects without importing,
                    # so treat from-imports of known module tails as modules.
                    for a in node.names:
                        dotted = f"{node.module}.{a.name}"
                        if dotted in _KNOWN_MODULES or a.name in (
                                "numpy", "random", "lax", "linalg"):
                            names.add(a.asname or a.name)
            self._module_names_cache = names
        return names


_KNOWN_MODULES = {
    "jax.numpy", "jax.random", "jax.lax", "jax.nn", "numpy.random",
}


def _import_aliases(tree: ast.Module) -> dict[str, str]:
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    aliases[a.name.split(".", 1)[0]] = a.name.split(".", 1)[0]
        elif isinstance(node, ast.ImportFrom):
            if node.level or not node.module:
                continue  # relative imports stay local spellings
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _link_parents(tree: ast.AST) -> None:
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            child._lint_parent = parent  # type: ignore[attr-defined]


def parent(node: ast.AST) -> ast.AST | None:
    return getattr(node, "_lint_parent", None)


def ancestors(node: ast.AST):
    cur = parent(node)
    while cur is not None:
        yield cur
        cur = parent(cur)


class Rule:
    """Base class: one contract, one ``check``.

    ``include``/``exclude`` are fnmatch globs over the repo-relative posix
    path; an empty ``include`` means every linted file.  ``exclude`` is the
    per-rule allowlist — the modules that legitimately own the pattern the
    rule forbids elsewhere.
    """

    name: str = ""
    hint: str = ""
    include: tuple[str, ...] = ()
    exclude: tuple[str, ...] = ()

    def applies_to(self, rel: str) -> bool:
        if self.include and not any(
                fnmatch.fnmatch(rel, g) for g in self.include):
            return False
        return not any(fnmatch.fnmatch(rel, g) for g in self.exclude)

    def check(self, source: Source) -> list[Finding]:
        raise NotImplementedError

    def finding(self, source: Source, node: ast.AST, message: str,
                hint: str | None = None) -> Finding:
        return Finding(rule=self.name, path=source.rel,
                       line=getattr(node, "lineno", 0), message=message,
                       hint=self.hint if hint is None else hint)


RULES: dict[str, Rule] = {}


def register(cls: type[Rule]) -> type[Rule]:
    rule = cls()
    assert rule.name and rule.name not in RULES, rule.name
    RULES[rule.name] = rule
    return cls


def all_rules() -> list[Rule]:
    from repro.analysis.lint import rules as _rules  # noqa: F401 (registers)
    return list(RULES.values())


def lint_files(root: pathlib.Path = REPO_ROOT,
               roots: tuple[str, ...] = DEFAULT_LINT_ROOTS
               ) -> list[pathlib.Path]:
    files: list[pathlib.Path] = []
    for sub in roots:
        base = root / sub
        if base.exists():
            files.extend(sorted(base.rglob("*.py")))
    return files


def run_lint(paths: list[pathlib.Path] | None = None,
             rules: list[Rule] | None = None,
             root: pathlib.Path = REPO_ROOT) -> list[Finding]:
    rules = all_rules() if rules is None else rules
    paths = lint_files(root) if paths is None else paths
    findings: list[Finding] = []
    for path in paths:
        src = Source.parse(path, root)
        for rule in rules:
            if rule.applies_to(src.rel):
                findings.extend(rule.check(src))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def check_snippet(text: str, rule_name: str, rel: str = "src/repro/x.py"
                  ) -> list[Finding]:
    """Run one rule over an in-memory snippet (test/fixture entry point)."""
    from repro.analysis.lint import rules as _rules  # noqa: F401
    src = Source.from_text(text, rel)
    rule = RULES[rule_name]
    if not rule.applies_to(rel):
        return []
    return rule.check(src)
