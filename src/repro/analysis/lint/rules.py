"""The rule catalog: eight AST rules holding the repo's code contracts.

Each rule documents the contract it holds, the allowlist (modules that
legitimately own the forbidden pattern), and the regex-era failure modes it
fixes where it replaces one of the old line-scanning guards.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.lint import Rule, Source, ancestors, parent, register

# --------------------------------------------------------------------------
# no-string-dispatch
# --------------------------------------------------------------------------

_METHOD_ATTRS = {"method", "embedding_method"}


def _string_const(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) and isinstance(node.value, str)


def _string_collection(node: ast.AST) -> bool:
    return (isinstance(node, (ast.Tuple, ast.List, ast.Set))
            and node.elts
            and all(_string_const(e) for e in node.elts))


@register
class NoStringDispatch(Rule):
    """Method dispatch goes through the registry, not string compares.

    PR 3 replaced ``if spec.method == "alpt"`` chains with the
    ``EmbeddingMethod`` registry; this rule keeps them out everywhere but
    ``methods/`` (the registry layer itself).  AST-level wins over the old
    regex: comparisons inside strings/comments no longer false-positive,
    and ``match spec.method: case "lpt"`` no longer false-negatives.
    """

    name = "no-string-dispatch"
    hint = ("dispatch through the EmbeddingMethod registry "
            "(methods.get(spec.method)) or a capability flag on the method")
    exclude = ("src/repro/methods/*",)

    def check(self, source: Source) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(source.tree):
            if isinstance(node, ast.Compare):
                sides = [node.left, *node.comparators]
                if not any(self._is_method_attr(s) for s in sides):
                    continue
                for op, comp in zip(node.ops, node.comparators):
                    if isinstance(op, (ast.Eq, ast.NotEq)) and (
                            _string_const(comp) or _string_const(node.left)):
                        out.append(self.finding(
                            source, node, "string comparison against "
                            "`.method` dispatches on a name"))
                        break
                    if isinstance(op, (ast.In, ast.NotIn)) and (
                            _string_collection(comp) or _string_const(comp)):
                        out.append(self.finding(
                            source, node, "membership test of `.method` against "
                            "string literals dispatches on a name"))
                        break
            elif isinstance(node, ast.Match):
                if self._is_method_attr(node.subject) and any(
                        self._case_is_string(c) for c in node.cases):
                    out.append(self.finding(
                        source, node, "match over `.method` with string case "
                        "patterns dispatches on a name"))
            elif isinstance(node, ast.Call):
                f = node.func
                if (isinstance(f, ast.Attribute)
                        and f.attr in ("startswith", "endswith")
                        and self._is_method_attr(f.value)):
                    out.append(self.finding(
                        source, node, f"`.method.{f.attr}(...)` dispatches on a "
                        "name prefix"))
        return out

    @staticmethod
    def _is_method_attr(node: ast.AST) -> bool:
        return isinstance(node, ast.Attribute) and node.attr in _METHOD_ATTRS

    @staticmethod
    def _case_is_string(case: ast.match_case) -> bool:
        pat = case.pattern
        return (isinstance(pat, ast.MatchValue)
                and _string_const(pat.value))


# --------------------------------------------------------------------------
# no-raw-code-casts
# --------------------------------------------------------------------------

_CODE_DTYPES = {
    "jax.numpy.int8", "jax.numpy.uint8", "numpy.int8", "numpy.uint8",
}
_ARRAY_CTORS = {
    "jax.numpy.asarray", "jax.numpy.array", "numpy.asarray", "numpy.array",
}


def _is_code_dtype(source: Source, node: ast.AST | None) -> bool:
    if node is None:
        return False
    if isinstance(node, ast.Constant) and node.value in ("int8", "uint8"):
        return True
    return source.dotted(node) in _CODE_DTYPES


@register
class NoRawCodeCasts(Rule):
    """int8/uint8 casts happen only inside the quantization layers.

    A stray ``.astype(int8)`` outside ``core/quant.py`` /
    ``core/codestore.py`` / ``kernels/`` silently truncates without the
    SR/clip semantics of ``quant.quantize`` — the exact bug class ALPT's
    learned step sizes exist to prevent.  The AST version also catches the
    regex-era false negatives: ``jnp.asarray(x, dtype=jnp.int8)``,
    ``jnp.array(x, "int8")``, aliased imports, and
    ``lax.convert_element_type`` — and no longer fires on casts mentioned
    in strings or comments.
    """

    name = "no-raw-code-casts"
    hint = ("route through repro.core.quant (quantize/sr_round) or the "
            "CodeStore container; raw int8 casts skip SR/clip semantics")
    exclude = (
        "src/repro/core/codestore.py",
        "src/repro/core/quant.py",
        "src/repro/kernels/*",
    )

    def check(self, source: Source) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(source.tree):
            if not isinstance(node, ast.Call):
                continue
            bad = self._cast_dtype_node(source, node)
            if bad is not None:
                out.append(self.finding(
                    source, node, "raw cast of codes to "
                    f"{ast.unparse(bad)} outside the quantization layers"))
        return out

    def _cast_dtype_node(self, source: Source,
                         call: ast.Call) -> ast.AST | None:
        """The dtype argument node when ``call`` is a raw int8/uint8 cast."""
        f = call.func
        kw = {k.arg: k.value for k in call.keywords}
        # x.astype(int8) / x.astype(dtype=int8)
        if isinstance(f, ast.Attribute) and f.attr == "astype":
            cand = call.args[0] if call.args else kw.get("dtype")
            if _is_code_dtype(source, cand):
                return cand
            return None
        dotted = source.dotted(f)
        # jnp.asarray(x, jnp.int8) / jnp.array(x, dtype="int8")
        if dotted in _ARRAY_CTORS:
            cand = call.args[1] if len(call.args) > 1 else kw.get("dtype")
            if _is_code_dtype(source, cand):
                return cand
            return None
        # lax.convert_element_type(x, jnp.int8)
        if dotted == "jax.lax.convert_element_type":
            cand = call.args[1] if len(call.args) > 1 else kw.get("new_dtype")
            if _is_code_dtype(source, cand):
                return cand
            return None
        # x.view(jnp.int8): a reinterpret-cast is as raw as a value cast.
        if isinstance(f, ast.Attribute) and f.attr == "view":
            cand = call.args[0] if call.args else kw.get("dtype")
            if _is_code_dtype(source, cand):
                return cand
            return None
        # jax.random.randint(key, shape, lo, hi, jnp.int8): minting codes
        # without quantization semantics.  Synthetic-code benchmark setups
        # that want exactly this carry a reviewed suppression entry.
        if dotted == "jax.random.randint":
            cand = call.args[4] if len(call.args) > 4 else kw.get("dtype")
            if _is_code_dtype(source, cand):
                return cand
        return None


# --------------------------------------------------------------------------
# no-direct-storage-access
# --------------------------------------------------------------------------

_SEAM_METHODS = {"unpack", "take", "set_rows", "where_rows"}
_PACK_FUNCS = {"pack_codes", "unpack_codes"}


@register
class NoDirectStorageAccess(Rule):
    """Row access goes through the ``repro.storage.base`` seam helpers.

    Outside the storage layers, calling the :class:`RowStore` protocol
    methods directly (``container.take(ids)``, ``container.unpack()``) —
    or the byte-level ``pack_codes``/``unpack_codes`` — couples the call
    site to one container layout and skips the raw-array dispatch the
    module-level helpers (``take_rows``/``set_rows``/``where_rows``/
    ``logical_codes``) provide.  PR 7's tiered cache only slotted in with
    zero trainer edits because every access already ran through the seam;
    this rule keeps it that way.
    """

    name = "no-direct-storage-access"
    hint = ("use repro.storage.base helpers (take_rows/set_rows/where_rows/"
            "logical_codes) — they dispatch over every container layout")
    exclude = (
        "src/repro/core/codestore.py",
        "src/repro/core/quant.py",
        "src/repro/storage/*",
        "src/repro/kernels/*",
    )
    # byte-level (un)packing additionally belongs to the sync wire format
    _pack_exclude = ("src/repro/dist/collectives.py",)

    def check(self, source: Source) -> list[Finding]:
        import fnmatch as _fn
        out: list[Finding] = []
        pack_ok = any(_fn.fnmatch(source.rel, g) for g in self._pack_exclude)
        for node in ast.walk(source.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr in _SEAM_METHODS:
                recv = f.value
                # Any import-bound receiver is a module (rowstore.set_rows,
                # jnp.take) — containers are always locals/attributes.
                if isinstance(recv, ast.Name) and (
                        recv.id in source.aliases
                        or recv.id in ("self", "cls")):
                    continue
                # struct.unpack etc.: only flag zero/low-arity protocol
                # shapes — unpack() takes none, take(ids) exactly one.
                if f.attr == "unpack" and (node.args or node.keywords):
                    continue
                if f.attr == "take" and (len(node.args) != 1
                                         or node.keywords):
                    continue
                out.append(self.finding(
                    source, node, f"direct RowStore method `.{f.attr}(...)` "
                    "outside the storage layers"))
            else:
                dotted = source.dotted(f) or ""
                tail = dotted.rsplit(".", 1)[-1]
                if tail in _PACK_FUNCS and not pack_ok:
                    out.append(self.finding(
                        source, node, f"byte-level `{tail}` outside the storage "
                        "layers/sync wire"))
        return out


# --------------------------------------------------------------------------
# rng-key-discipline
# --------------------------------------------------------------------------

_KEY_PRODUCERS = {
    "jax.random.PRNGKey", "jax.random.key", "jax.random.split",
    "jax.random.fold_in", "jax.random.wrap_key_data", "jax.random.clone",
}
# Calls that read a key without consuming its entropy (fold_in *derives*;
# iter/next drive the split-iterator idiom).
_NONCONSUMING = {
    "jax.random.fold_in", "jax.random.key_data", "jax.random.clone",
    "iter", "next", "len", "print", "repr", "str", "id", "hash", "type",
    "isinstance", "list", "tuple",
}
_KEY_PARAM_SUFFIXES = ("key", "rng", "keys", "rngs")


def _is_key_param(name: str) -> bool:
    return name in ("key", "rng") or name.endswith(_KEY_PARAM_SUFFIXES)


@register
class RngKeyDiscipline(Rule):
    """A PRNGKey/split result is consumed at most once per scope.

    Reusing a key feeds *correlated* noise into two draws — for SR
    quantization that couples rounding noise across tensors and silently
    biases the very estimator LPT/ALPT's convergence argument (paper §3)
    rests on.  The sanctioned patterns stay legal: ``fold_in`` derivation,
    ``key, sub = split(key)`` reassignment, split-iterator ``next(keys)``,
    and per-branch single use.

    Abstract interpretation, one scope at a time: each tracked key has a
    consumption count; loop bodies are walked twice (a use per iteration
    without in-loop rederivation counts as reuse); ``if``/``try`` branches
    merge by max.  Nested ``def``/``lambda`` bodies are separate scopes.
    """

    name = "rng-key-discipline"
    hint = ("derive per-use subkeys: `key, sub = jax.random.split(key)` or "
            "`jax.random.fold_in(key, tag)` — never reuse a consumed key")
    # the vendored hypothesis stub threads a *stateful* stdlib Random named
    # `rng`; jax key discipline does not apply to it
    exclude = ("src/repro/_compat/*",)

    def check(self, source: Source) -> list[Finding]:
        out: list[Finding] = []
        scopes: list[tuple[str, list[ast.stmt], list[str]]] = [
            ("<module>", source.tree.body, [])
        ]
        for node in ast.walk(source.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                params = [
                    a.arg for a in (node.args.posonlyargs + node.args.args
                                    + node.args.kwonlyargs)
                    if _is_key_param(a.arg)
                ]
                scopes.append((node.name, node.body, params))
        for scope_name, body, key_params in scopes:
            walker = _KeyScopeWalker(source, self, scope_name)
            for p in key_params:
                walker.env[(p, None)] = 0
            walker.walk_block(body)
            out.extend(walker.findings)
        return out


class _KeyScopeWalker:
    """Linear consumption counting over one scope's statement list."""

    def __init__(self, source: Source, rule: Rule, scope: str):
        self.source = source
        self.rule = rule
        self.scope = scope
        self.env: dict[tuple[str, int | None], int] = {}
        self.findings: list[Finding] = []
        self.flagged: set[tuple[str, int | None]] = set()

    # ---- statements ----

    def walk_block(self, stmts: list[ast.stmt]) -> None:
        for st in stmts:
            self.walk_stmt(st)

    def walk_stmt(self, st: ast.stmt) -> None:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
            return  # separate scope
        if isinstance(st, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = st.value
            if value is not None:
                self.visit_expr(value)
            targets = (st.targets if isinstance(st, ast.Assign)
                       else [st.target])
            for t in targets:
                self._bind(t, value)
            return
        if isinstance(st, ast.If):
            self.visit_expr(st.test)
            self._branches([st.body, st.orelse])
            return
        if isinstance(st, (ast.For, ast.AsyncFor)):
            self.visit_expr(st.iter)
            self._bind(st.target, None)
            # two passes ~ two iterations: a use per iteration without
            # rederivation inside the body shows up as a double count.
            self.walk_block(st.body)
            self.walk_block(st.body)
            self.walk_block(st.orelse)
            return
        if isinstance(st, ast.While):
            self.visit_expr(st.test)
            self.walk_block(st.body)
            self.walk_block(st.body)
            self.walk_block(st.orelse)
            return
        if isinstance(st, (ast.With, ast.AsyncWith)):
            for item in st.items:
                self.visit_expr(item.context_expr)
                if item.optional_vars is not None:
                    self._bind(item.optional_vars, None)
            self.walk_block(st.body)
            return
        if isinstance(st, ast.Try):
            self._branches(
                [st.body] + [h.body for h in st.handlers] + [st.orelse])
            self.walk_block(st.finalbody)
            return
        for child in ast.iter_child_nodes(st):
            if isinstance(child, ast.expr):
                self.visit_expr(child)

    def _branches(self, blocks: list[list[ast.stmt]]) -> None:
        base = dict(self.env)
        merged = dict(self.env)
        for block in blocks:
            self.env = dict(base)
            self.walk_block(block)
            if any(isinstance(s, (ast.Return, ast.Raise, ast.Continue,
                                  ast.Break)) for s in block):
                continue  # terminated branch: never merges into fall-through
            for k, v in self.env.items():
                merged[k] = max(merged.get(k, 0), v) if k in base else v
            for k in set(base) - set(self.env):
                merged.pop(k, None)
        self.env = merged

    # ---- bindings ----

    def _bind(self, target: ast.AST, value: ast.expr | None) -> None:
        fresh = value is not None and self._produces_key(value)
        if isinstance(target, ast.Name):
            self._rebind(target.id, fresh)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                if isinstance(el, ast.Name):
                    self._rebind(el.id, fresh)
                elif isinstance(el, ast.Starred) and isinstance(
                        el.value, ast.Name):
                    self._rebind(el.value.id, fresh)

    def _rebind(self, name: str, fresh: bool) -> None:
        for k in [k for k in self.env if k[0] == name]:
            del self.env[k]
        self.flagged = {f for f in self.flagged if f[0] != name}
        if fresh:
            self.env[(name, None)] = 0

    def _produces_key(self, value: ast.expr) -> bool:
        if isinstance(value, ast.Subscript):
            return self._produces_key(value.value)
        if not isinstance(value, ast.Call):
            return False
        dotted = self.source.dotted(value.func)
        if dotted in _KEY_PRODUCERS:
            return True
        tail = (dotted or "").rsplit(".", 1)[-1]
        if tail in ("iter",) and value.args:
            return self._produces_key(value.args[0])
        return tail in ("PRNGKey", "split", "fold_in")

    # ---- uses ----

    def visit_expr(self, expr: ast.expr) -> None:
        for node in ast.walk(expr):
            if isinstance(node, (ast.Lambda, ast.ListComp, ast.SetComp,
                                 ast.DictComp, ast.GeneratorExp)):
                continue  # their bodies are handled below / skipped
            if isinstance(node, ast.Call):
                self._visit_call(node)
        # comprehensions: walk the element twice (loop semantics)
        for node in ast.walk(expr):
            if isinstance(node, (ast.ListComp, ast.SetComp,
                                 ast.GeneratorExp)):
                for sub in ast.walk(node.elt):
                    if isinstance(sub, ast.Call):
                        self._visit_call(sub)

    def _visit_call(self, call: ast.Call) -> None:
        dotted = self.source.dotted(call.func) or ""
        tail = dotted.rsplit(".", 1)[-1]
        if dotted in _NONCONSUMING or tail in ("fold_in", "iter", "next"):
            return
        in_lambda = any(isinstance(a, ast.Lambda) for a in ancestors(call))
        if in_lambda:
            return  # deferred bodies are not linear uses of this scope
        args = list(call.args) + [k.value for k in call.keywords]
        for a in args:
            ref = self._key_ref(a)
            if ref is not None:
                self._consume(ref, call)

    def _key_ref(self, node: ast.expr) -> tuple[str, int | None] | None:
        if isinstance(node, ast.Name) and (node.id, None) in self.env:
            return (node.id, None)
        if (isinstance(node, ast.Subscript)
                and isinstance(node.value, ast.Name)):
            name = node.value.id
            if (name, None) in self.env or any(
                    k[0] == name for k in self.env):
                idx = node.slice
                if isinstance(idx, ast.Constant) and isinstance(
                        idx.value, int):
                    if (name, None) in self.env:
                        # promote the array to per-index tracking
                        del self.env[(name, None)]
                    self.env.setdefault((name, idx.value), 0)
                    return (name, idx.value)
                return None  # dynamic index: cannot reason, do not count
        return None

    def _consume(self, ref: tuple[str, int | None], at: ast.Call) -> None:
        self.env[ref] = self.env.get(ref, 0) + 1
        if self.env[ref] >= 2 and ref not in self.flagged:
            self.flagged.add(ref)
            name = ref[0] if ref[1] is None else f"{ref[0]}[{ref[1]}]"
            self.findings.append(self.rule.finding(
                self.source, at,
                f"PRNG key `{name}` consumed more than once in scope "
                f"`{self.scope}` — draws are correlated"))


# --------------------------------------------------------------------------
# no-silent-fallback
# --------------------------------------------------------------------------

_NOTE_NAMES = {"_note_fallback", "note_fallback"}


@register
class NoSilentFallback(Rule):
    """Every branch that leaves the Pallas path ticks the fallback counter.

    PR 4's contract: "fallbacks counted and never silent".  A wrapper
    returning a ``_ref_*`` jnp reference path without a ``_note_fallback``
    call hides a perf cliff — benchmarks would report kernels-on numbers
    while silently running the reference.  The explicit ``use_kernel=False``
    gate is *not* a fallback (the caller asked for the reference) and is
    exempt when the return sits under a ``use_kernel`` test.
    """

    name = "no-silent-fallback"
    hint = ("call _note_fallback(name, shape, reason) before returning the "
            "_ref_* path (or gate the branch on the explicit use_kernel "
            "switch)")
    include = ("src/repro/kernels/*",)

    def check(self, source: Source) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(source.tree):
            if not isinstance(node, ast.Return) or node.value is None:
                continue
            ref = self._ref_call(node.value)
            if ref is None:
                continue
            fn = next((a for a in ancestors(node) if isinstance(
                a, (ast.FunctionDef, ast.AsyncFunctionDef))), None)
            if fn is None or fn.name.startswith(("_ref_", "ref_")):
                continue  # reference impls compose freely
            if self._under_use_kernel_gate(node):
                continue
            if self._noted_before(node, fn):
                continue
            out.append(self.finding(
                source, node, f"silent fallback: `{fn.name}` returns `{ref}` "
                "without ticking the fallback counter"))
        return out

    @staticmethod
    def _ref_call(expr: ast.expr) -> str | None:
        for sub in ast.walk(expr):
            if isinstance(sub, ast.Call):
                f = sub.func
                name = (f.id if isinstance(f, ast.Name)
                        else f.attr if isinstance(f, ast.Attribute) else "")
                if name.startswith(("_ref_", "ref_")):
                    return name
        return None

    @staticmethod
    def _under_use_kernel_gate(node: ast.AST) -> bool:
        for anc in ancestors(node):
            if isinstance(anc, ast.If):
                for sub in ast.walk(anc.test):
                    ident = (sub.id if isinstance(sub, ast.Name)
                             else sub.attr if isinstance(sub, ast.Attribute)
                             else "")
                    if "use_kernel" in ident:
                        return True
        return False

    @staticmethod
    def _noted_before(node: ast.Return,
                      fn: ast.FunctionDef | ast.AsyncFunctionDef) -> bool:
        """A note call in any statement lexically preceding the return on
        its ancestor path (same block or an enclosing one)."""
        path = {node} | set(ancestors(node))
        blocks: list[list[ast.stmt]] = [fn.body]
        for anc in ancestors(node):
            for field in ("body", "orelse", "finalbody"):
                block = getattr(anc, field, None)
                if isinstance(block, list) and any(
                        s in path for s in block):
                    blocks.append(block)
        for block in blocks:
            for st in block:
                if st in path:
                    break
                for sub in ast.walk(st):
                    if isinstance(sub, ast.Call):
                        f = sub.func
                        name = (f.id if isinstance(f, ast.Name)
                                else f.attr
                                if isinstance(f, ast.Attribute) else "")
                        if name in _NOTE_NAMES:
                            return True
        return False


# --------------------------------------------------------------------------
# no-unfenced-model-grad
# --------------------------------------------------------------------------

_GRAD_FUNCS = {"jax.grad", "jax.value_and_grad"}
# The dense formulation materializes the fake-quant table as a plain jit
# input (no storage graph to pin), so its delta-grad backward needs no
# fence; the function name marks the formulation.
_FENCE_EXEMPT_FUNCTIONS = {"dense_delta_grad"}


@register
class NoUnfencedModelGrad(Rule):
    """Fused-path model backwards compile behind ``fence.fence_call``.

    PR 7's cache-parity bar (cache-on bitwise == cache-off) holds because
    the model backward in every fused step compiles inside the
    ``core/fence.py`` opaque-trip-count loop — XLA cannot re-associate it
    against whatever storage graph surrounds it.  A direct
    ``jax.grad(f)(x)`` in a fused path reopens that seam.  Legal shapes:
    passing the grad callable *to* ``fence_call`` (unfenced construction,
    fenced invocation) and the dense formulation (``dense_delta_grad``).
    """

    name = "no-unfenced-model-grad"
    hint = ("wrap the call: fence.fence_call(jax.value_and_grad(f), args, "
            "tick=...) — see core/fence.py")
    include = ("src/repro/methods/*", "src/repro/core/*")
    exclude = ("src/repro/core/fence.py",)

    def check(self, source: Source) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(source.tree):
            if not isinstance(node, ast.Call):
                continue
            if source.dotted(node.func) not in _GRAD_FUNCS:
                continue
            par = parent(node)
            invoked = isinstance(par, ast.Call) and par.func is node
            if not invoked:
                continue  # constructed, not invoked (e.g. fence_call arg)
            fn = next((a for a in ancestors(node) if isinstance(
                a, (ast.FunctionDef, ast.AsyncFunctionDef))), None)
            if fn is not None and fn.name in _FENCE_EXEMPT_FUNCTIONS:
                continue
            out.append(self.finding(
                source, node, "model backward invoked outside fence_call in a "
                "fused path"))
        return out


# --------------------------------------------------------------------------
# no-silent-except


_BROAD_EXC = {"Exception", "BaseException"}
_LOG_ATTRS = {
    "debug", "info", "warning", "warn", "error", "exception", "critical",
    "log",
}
#: Calls that count as "ticking a counter": collection mutations the failure
#: accounting paths use (e.g. CheckpointManager.corrupt_steps.append).
_COUNTER_ATTRS = {"append", "add", "update", "merge"}


@register
class NoSilentExcept(Rule):
    """Broad exception handlers must re-raise, log, or tick a counter.

    The fault-injection harness (repro.faults) only proves recovery works
    if failures are *visible*: a bare ``except:`` or ``except Exception:
    pass`` swallows an injected fault and the chaos suite reads it as a
    pass.  Narrow handlers (``except CorruptCheckpointError:``) stay legal —
    catching a specific failure is a decision; catching everything silently
    is a hole.  AST-level wins over a regex guard: ``except`` mentioned in
    docstrings/comments never fires, and a handler that logs three
    statements down is recognized.
    """

    name = "no-silent-except"
    hint = ("re-raise, log through a logger/print, or tick a failure "
            "counter — a silently swallowed broad except hides faults "
            "from the recovery layer")

    def check(self, source: Source) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(source.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not self._broad(node.type):
                continue
            if self._handled(node):
                continue
            what = ("bare `except:`" if node.type is None
                    else f"`except {ast.unparse(node.type)}:`")
            out.append(self.finding(
                source, node,
                f"{what} swallows the error without re-raise, log, or "
                "counter"))
        return out

    @staticmethod
    def _broad(t: ast.AST | None) -> bool:
        if t is None:
            return True
        names = t.elts if isinstance(t, ast.Tuple) else [t]
        for n in names:
            if isinstance(n, ast.Name) and n.id in _BROAD_EXC:
                return True
            if isinstance(n, ast.Attribute) and n.attr in _BROAD_EXC:
                return True
        return False

    @staticmethod
    def _handled(handler: ast.ExceptHandler) -> bool:
        for stmt in handler.body:
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.Raise):
                    return True
                if isinstance(sub, ast.AugAssign):
                    return True  # counter tick: `self.failures += 1`
                if isinstance(sub, ast.Call):
                    f = sub.func
                    if isinstance(f, ast.Name) and f.id == "print":
                        return True
                    if isinstance(f, ast.Attribute) and f.attr in (
                            _LOG_ATTRS | _COUNTER_ATTRS):
                        return True
        return False


# --------------------------------------------------------------------------
# no-host-sync
# --------------------------------------------------------------------------

_JIT_WRAPPERS = {
    "jax.jit", "jax.pmap", "shard_map", "jax.experimental.shard_map.shard_map",
}
_HOST_PULL_FUNCS = {"numpy.asarray", "numpy.array", "jax.device_get"}


@register
class NoHostSync(Rule):
    """Library code never forces a device sync; step functions never pull
    values to the host.

    The obs contract (PR 10) keeps dispatch fully async when tracing is
    off: the only sanctioned ``block_until_ready`` in ``src/repro`` is the
    tracer's span-edge fence (a reviewed ``analysis-suppressions.txt``
    entry — it runs only while tracing is armed, at host span boundaries).
    Anywhere else a ``.block_until_ready()`` stalls the pipeline for every
    caller, traced or not.

    Inside *jit scopes* — functions decorated with / passed to
    ``jax.jit``/``pmap``/``shard_map``, and anything lexically nested in
    one — ``np.asarray``/``np.array``/``jax.device_get`` additionally
    force a device->host transfer at trace time (a hidden sync and a
    constant-folded copy baked into the compiled program).  Host-side
    policy code may convert freely; traced step functions may not.
    Benchmarks are excluded: min-of-N timing *requires* explicit syncs.
    """

    name = "no-host-sync"
    hint = ("keep device values on device: drop the block_until_ready (the "
            "obs tracer fences span edges when armed), and inside jitted "
            "step functions use jnp.* — np.asarray/device_get force a "
            "device->host pull at trace time")
    exclude = ("benchmarks/*",)

    def check(self, source: Source) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(source.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if ((isinstance(f, ast.Attribute)
                 and f.attr == "block_until_ready")
                    or source.dotted(f) == "jax.block_until_ready"):
                out.append(self.finding(
                    source, node, "host sync: `block_until_ready` outside "
                    "the tracer's reviewed span-edge fence"))
        scopes = {id(fn): fn for fn in self._jit_scopes(source)}
        for node in ast.walk(source.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = source.dotted(node.func)
            if dotted not in _HOST_PULL_FUNCS:
                continue
            # attribute to the innermost enclosing function only
            fn = next((a for a in ancestors(node) if isinstance(
                a, (ast.FunctionDef, ast.AsyncFunctionDef))), None)
            if fn is not None and id(fn) in scopes:
                out.append(self.finding(
                    source, node, f"`{dotted}` inside jit scope "
                    f"`{fn.name}` pulls a device value to the host at "
                    "trace time"))
        return out

    def _jit_scopes(self, source: Source):
        """FunctionDefs compiled by jax: jit-decorated, passed to a jit
        wrapper by name, or lexically nested in either."""
        defs: dict[str, list[ast.AST]] = {}
        roots: list[ast.AST] = []
        for node in ast.walk(source.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, []).append(node)
                if any(self._is_jit_expr(source, d)
                       for d in node.decorator_list):
                    roots.append(node)
        for node in ast.walk(source.tree):
            if (isinstance(node, ast.Call)
                    and source.dotted(node.func) in _JIT_WRAPPERS
                    and node.args and isinstance(node.args[0], ast.Name)):
                roots.extend(defs.get(node.args[0].id, []))
        seen: set[int] = set()
        for root in roots:
            for sub in ast.walk(root):
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)
                              ) and id(sub) not in seen:
                    seen.add(id(sub))
                    yield sub

    @staticmethod
    def _is_jit_expr(source: Source, node: ast.AST) -> bool:
        """`@jax.jit`, `@partial(jax.jit, ...)`, `@jax.jit(...)` shapes."""
        if source.dotted(node) in _JIT_WRAPPERS:
            return True
        if isinstance(node, ast.Call):
            if source.dotted(node.func) in _JIT_WRAPPERS:
                return True
            if (source.dotted(node.func) or "").endswith("partial"):
                return any(source.dotted(a) in _JIT_WRAPPERS
                           for a in node.args)
        return False
