"""Jaxpr-level invariant checkers.

Source scanning cannot see what XLA will actually materialize; these
checkers trace the *real* jitted steps (both trainers, both Engines, the
compressed collectives) with :func:`jax.make_jaxpr` and walk every
equation — recursing into ``pjit``/``while``/``cond``/``scan``/
``shard_map`` sub-jaxprs — asserting the contracts the runtime parity
tests hold numerically:

* :func:`check_no_f32_table` — the int8-resident serving contract: no
  float intermediate of any full-table ``[vocab, dim]`` geometry.
* :func:`check_codes_reach_float_via_dequant` — every int8→float widen is
  a dequant (its product feeds a scale multiply); a uint8→float widen is
  categorically wrong (packed bytes are not codes).
* :func:`check_packed_stays_packed` — packed sub-byte tables never
  round-trip through a full-table logical-int8 intermediate outside the
  container (per-row unpacks are the contract; whole-table unpacks are
  the leak).
* :func:`check_wire_stays_packed` — collective payloads at sync_bits<=4
  cross the wire as packed uint8, never as widened logical codes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator

import jax
from jax import core as jcore

from repro.analysis.findings import Finding

__all__ = [
    "walk_eqns",
    "check_no_f32_table",
    "check_codes_reach_float_via_dequant",
    "check_packed_stays_packed",
    "check_wire_stays_packed",
    "CHECKS",
]


def _subjaxprs(eqn) -> Iterator[jcore.Jaxpr]:
    for val in eqn.params.values():
        stack = [val]
        while stack:
            v = stack.pop()
            if isinstance(v, jcore.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jcore.Jaxpr):
                yield v
            elif isinstance(v, (tuple, list)):
                stack.extend(v)


def walk_eqns(jaxpr) -> Iterator:
    """Every eqn in ``jaxpr`` and all nested sub-jaxprs, depth-first."""
    if isinstance(jaxpr, jcore.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _subjaxprs(eqn):
            yield from walk_eqns(sub)


def _aval(var):
    return getattr(var, "aval", None)


def _shape_dtype(var):
    aval = _aval(var)
    if aval is None or not hasattr(aval, "shape"):
        return None, None
    return tuple(aval.shape), getattr(aval, "dtype", None)


def trace(fn: Callable, *args, **kwargs) -> jax.core.ClosedJaxpr:
    return jax.make_jaxpr(fn)(*args, **kwargs)


# --------------------------------------------------------------------------
# checker 1: int8-resident serving — no f32 full-table intermediate
# --------------------------------------------------------------------------

def check_no_f32_table(closed, forbidden_shapes, target: str
                       ) -> list[Finding]:
    """No float32/float16/bfloat16 intermediate of a full-table shape.

    ``forbidden_shapes`` is the set of table geometries for the traced
    spec: the logical ``(n, d)``, the padded ``(n_padded, d_padded)``, and
    each sub-table's allocation for composed (qr/mixed) methods.
    """
    import numpy as np
    forbidden = {tuple(s) for s in forbidden_shapes}
    out = []
    seen = set()
    for eqn in walk_eqns(closed):
        for var in eqn.outvars:
            shape, dtype = _shape_dtype(var)
            if shape is None or shape not in forbidden:
                continue
            if dtype is None or not np.issubdtype(dtype, np.floating):
                continue
            key = (shape, str(dtype), eqn.primitive.name)
            if key in seen:
                continue
            seen.add(key)
            out.append(Finding(
                rule="jaxpr-no-f32-table", path=f"<target:{target}>", line=0,
                message=f"`{eqn.primitive.name}` materializes a {dtype} "
                f"intermediate of full-table shape {shape}",
                hint="the Engine is int8-resident: gather rows first, "
                "dequantize per-row (ops.dequant_gather), never the table",
            ))
    return out


# --------------------------------------------------------------------------
# checker 2: codes reach float only through dequant
# --------------------------------------------------------------------------

_PASS_THROUGH = {
    "reshape", "broadcast_in_dim", "transpose", "squeeze", "slice",
    "dynamic_slice", "gather", "expand_dims", "copy", "convert_element_type",
    "stop_gradient", "optimization_barrier",
}


def check_codes_reach_float_via_dequant(closed, target: str
                                        ) -> list[Finding]:
    """Every int8→float convert feeds a scale multiply (a dequant).

    Dequantization is ``codes * step`` — so the float image of a code
    array must (possibly through shape-only ops) be consumed by ``mul``.
    An int8→float convert whose result reaches anything else widened raw
    codes without a scale: exactly the silent-dequant bug class.  uint8
    (packed bytes) must never convert to float at all.
    """
    import numpy as np
    out: list[Finding] = []
    # var -> producing eqn, and var -> consuming eqns
    consumers: dict = {}
    for eqn in walk_eqns(closed):
        for var in eqn.invars:
            if not isinstance(var, jcore.Literal):
                consumers.setdefault(var, []).append(eqn)
    for eqn in walk_eqns(closed):
        if eqn.primitive.name != "convert_element_type":
            continue
        (shape, src_dtype) = _shape_dtype(eqn.invars[0])
        (_, dst_dtype) = _shape_dtype(eqn.outvars[0])
        if src_dtype is None or dst_dtype is None:
            continue
        if not np.issubdtype(dst_dtype, np.floating):
            continue
        if src_dtype == np.uint8:
            out.append(Finding(
                rule="jaxpr-codes-dequant-only", path=f"<target:{target}>",
                line=0,
                message=f"packed uint8 bytes of shape {shape} converted "
                f"directly to {dst_dtype}",
                hint="packed bytes are containers, not codes: unpack to "
                "logical int8 inside CodeStore/kernels, then dequant",
            ))
            continue
        if src_dtype != np.int8:
            continue
        if not _feeds_mul(eqn.outvars[0], consumers):
            out.append(Finding(
                rule="jaxpr-codes-dequant-only", path=f"<target:{target}>",
                line=0,
                message=f"int8 codes of shape {shape} widened to "
                f"{dst_dtype} without a scale multiply (raw dequant-less "
                "widen)",
                hint="float images of codes must be `codes * step` — "
                "route through ops.dequant_gather / quant dequantize",
            ))
    return out


def _feeds_mul(var, consumers, depth: int = 0) -> bool:
    if depth > 8:
        return True  # deep chains: give the benefit of the doubt
    eqns = consumers.get(var, [])
    if not eqns:
        # unused inside this (sub)jaxpr: it is an output threaded onward —
        # cross-jaxpr dataflow is out of scope, assume the consumer scales.
        return True
    for eqn in eqns:
        name = eqn.primitive.name
        if name in ("mul", "div", "dot_general", "integer_pow"):
            continue
        if name in _PASS_THROUGH or name.startswith(("pjit", "custom_")):
            if name in _PASS_THROUGH and eqn.outvars:
                if all(_feeds_mul(o, consumers, depth + 1)
                       for o in eqn.outvars):
                    continue
            else:
                continue
            return False
        if name in ("while", "scan", "cond"):
            continue  # loop-carried: checked inside the sub-jaxpr walk
        return False
    return True


# --------------------------------------------------------------------------
# checker 3: packed leaves never round-trip through full-table int8
# --------------------------------------------------------------------------

def check_packed_stays_packed(closed, forbidden_shapes, target: str
                              ) -> list[Finding]:
    """No full-table logical-int8 intermediate when the store is packed.

    Packed sub-byte tables unpack *rows* at the point of use (in-VMEM for
    kernels, per-gather for the reference paths).  A whole-table int8
    intermediate is the container leaking: 2x-4x the resident bytes the
    packing bought, in the middle of a jitted step.
    """
    import numpy as np
    forbidden = {tuple(s) for s in forbidden_shapes}
    out = []
    seen = set()
    for eqn in walk_eqns(closed):
        for var in eqn.outvars:
            shape, dtype = _shape_dtype(var)
            if shape is None or shape not in forbidden:
                continue
            if dtype != np.int8:
                continue
            key = (shape, eqn.primitive.name)
            if key in seen:
                continue
            seen.add(key)
            out.append(Finding(
                rule="jaxpr-packed-containment", path=f"<target:{target}>",
                line=0,
                message=f"`{eqn.primitive.name}` materializes a full-table "
                f"logical int8 intermediate {shape} from a packed store",
                hint="unpack rows at the point of use (take_rows / in-VMEM "
                "kernel unpack), never the whole container",
            ))
    return out


# --------------------------------------------------------------------------
# checker 4: collective wire stays packed at sync_bits<=4
# --------------------------------------------------------------------------

_COLLECTIVES = {
    "psum", "all_gather", "all_to_all", "ppermute", "reduce_scatter",
    "psum_scatter", "all_reduce",
}


def check_wire_stays_packed(closed, target: str, *,
                            min_payload: int = 2) -> list[Finding]:
    """Every non-scalar collective payload is uint8 (the packed wire).

    At sync_bits<=4 the compressed all-reduce ships packed bytes and sums
    after unpack; a widened (int32/f32) payload of more than
    ``min_payload`` elements is the wire silently un-compressing.  Scalar
    reductions (the shared absmax pmax) are exempt.
    """
    import math
    import numpy as np
    out = []
    seen = set()
    for eqn in walk_eqns(closed):
        if eqn.primitive.name not in _COLLECTIVES:
            continue
        for var in eqn.invars:
            shape, dtype = _shape_dtype(var)
            if shape is None or dtype is None:
                continue
            if math.prod(shape) < min_payload:
                continue  # scalar absmax / step share
            if dtype == np.uint8:
                continue
            key = (eqn.primitive.name, shape, str(dtype))
            if key in seen:
                continue
            seen.add(key)
            out.append(Finding(
                rule="jaxpr-packed-wire", path=f"<target:{target}>", line=0,
                message=f"collective `{eqn.primitive.name}` ships a "
                f"{dtype} payload of shape {shape} at packable sync_bits",
                hint="pack codes to the uint8 wire before the collective "
                "(dist.collectives._packed_psum_codes)",
            ))
    return out


CHECKS = {
    "no-f32-table": check_no_f32_table,
    "codes-dequant-only": check_codes_reach_float_via_dequant,
    "packed-containment": check_packed_stays_packed,
    "packed-wire": check_wire_stays_packed,
}
