"""The trace-target registry: the real jitted steps the checkers walk.

Each target lazily builds a tiny-geometry instance of a production step —
the CTR Engine scorer for every registered integer-table method, the LM
Engine decode for the flat-table methods, both trainers' fused/dense
steps, and the compressed collective at each packable width — then runs
the checks named in its ``checks`` tuple.

Geometries are chosen collision-proof: batch=3 so no activation shares a
leading dim with any (sub-)table allocation, and the forbidden-shape sets
are *introspected* from the built state (every ``CodeStore``/raw-code
allocation plus the logical ``(n, d)``), not hand-maintained.

The qr/mixed LM head is deliberately absent: ``QRQuantTable.head_logits``
materializes a transient ``[n, d]`` product by design (see
serving/table.py; the decomposed einsum head is a carried ROADMAP item),
so only the flat-table methods carry the LM no-f32-table contract today.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.analysis.findings import Finding

CTR_CARDS = (23, 37, 11, 53)
ENGINE_METHODS = ("lpt", "alpt", "qr_lpt", "qr_alpt", "mixed")
LM_ENGINE_METHODS = ("lpt", "alpt")
TRAINER_METHODS = ("lpt", "alpt", "qr_lpt", "mixed")


@dataclasses.dataclass(frozen=True)
class TraceTarget:
    name: str
    build: Callable[[], "Traced"]
    checks: tuple[str, ...]


@dataclasses.dataclass
class Traced:
    closed: object                     # jax ClosedJaxpr
    forbidden: frozenset = frozenset()        # full-table float geometries
    packed_forbidden: frozenset = frozenset()  # packed alloc geometries


# ---------------------------------------------------------------- fixtures


def _spec_kwargs(method: str) -> dict:
    kw: dict = {}
    if method.startswith("qr"):
        kw["hash_compression"] = 4.0
    if method == "mixed":
        kw["field_cards"] = CTR_CARDS
        kw["field_bits"] = (8, 4, 8, 2)
    return kw


def _ctr_trainer(method: str, *, bits=8, packed=False, use_kernels=False):
    from repro import methods
    from repro.models.ctr import DCNConfig
    from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

    spec = methods.EmbeddingSpec(
        method=method, n=sum(CTR_CARDS), d=8, bits=bits, init_scale=0.05,
        packed=packed, use_kernels=use_kernels, **_spec_kwargs(method),
    )
    dcn = DCNConfig(n_fields=len(CTR_CARDS), emb_dim=8, cross_depth=1,
                    mlp_widths=(16,))
    trainer = CTRTrainer(TrainerConfig(spec=spec, model="dcn", dcn=dcn))
    return trainer, trainer.init_state(), spec


def _table_shapes(state_or_table) -> tuple[frozenset, frozenset]:
    """(float-forbidden, packed-forbidden) geometries, introspected.

    Walks the pytree for every code container: each contributes its
    *logical* allocation shape to the float-forbidden set; packed
    (sub-byte) containers additionally contribute it to the
    packed-forbidden set (a full-table logical-int8 image of a packed
    store is the containment leak).
    """
    import jax
    import numpy as np
    from repro.core import codestore

    forbidden: set = set()
    packed: set = set()

    def visit(x):
        if isinstance(x, codestore.CodeStore):
            forbidden.add(tuple(x.shape))
            if x.packed:
                packed.add(tuple(x.shape))
        elif hasattr(x, "dtype") and hasattr(x, "shape"):
            if getattr(x, "dtype", None) == np.int8 and len(x.shape) == 2:
                forbidden.add(tuple(x.shape))
        return x

    jax.tree_util.tree_map(
        visit, state_or_table,
        is_leaf=lambda x: isinstance(x, codestore.CodeStore),
    )
    return frozenset(forbidden), frozenset(packed)


def _with_logical(shapes: frozenset, n: int, d: int) -> frozenset:
    return shapes | {(n, d)}


# ---------------------------------------------------------------- builders


def _build_engine_ctr(method: str) -> Traced:
    import jax
    import jax.numpy as jnp
    from repro.serving.ctr import CTREngine

    # mixed serves genuinely packed sub-byte groups (4/2-bit fields), so its
    # Engine trace also carries the packed-containment contract.
    trainer, state, spec = _ctr_trainer(method, packed=(method == "mixed"))
    engine = CTREngine.from_state(state, trainer.cfg, batch=3)
    ids = jnp.zeros((3, len(CTR_CARDS)), jnp.int32)
    closed = jax.make_jaxpr(engine._score)(
        engine.table, engine.dense_params, ids
    )
    forbidden, packed = _table_shapes(engine.table)
    return Traced(closed, _with_logical(forbidden, spec.n, spec.d), packed)


def _build_engine_lm(method: str) -> Traced:
    import dataclasses as dc

    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.serving.lm import LMEngine
    from repro.training import lm_trainer

    cfg = dc.replace(configs.smoke_config("smollm-135m"),
                     embedding_method=method)
    tcfg = lm_trainer.LMTrainerConfig()
    state = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    engine = LMEngine.from_state(state, cfg, tcfg, batch=2, max_len=8)
    tok = jnp.zeros((2,), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    closed = jax.make_jaxpr(
        lambda p, t, tk, c, ps: engine._decode(p, t, tk, c, ps)
    )(engine.params, engine.table, tok, engine._cache, pos)
    spec = lm_trainer.embedding_spec_of(cfg, tcfg)
    forbidden, packed = _table_shapes(engine.table)
    return Traced(closed, _with_logical(forbidden, spec.n, spec.d), packed)


def _build_train_ctr(method: str) -> Traced:
    import jax
    import jax.numpy as jnp

    sub_byte = method in ("lpt", "alpt")
    trainer, state, spec = _ctr_trainer(
        method, bits=4 if sub_byte else 8,
        packed=sub_byte or method == "mixed",
    )
    ids = jnp.zeros((16, len(CTR_CARDS)), jnp.int32)
    labels = jnp.zeros((16,), jnp.float32)
    closed = jax.make_jaxpr(lambda s, i, y: trainer._train_step(s, i, y))(
        state, ids, labels
    )
    _, packed_shapes = _table_shapes(state)
    return Traced(closed, frozenset(), packed_shapes)


def _build_train_lm_dense() -> Traced:
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.training import lm_trainer

    cfg = configs.smoke_config("smollm-135m")
    tcfg = lm_trainer.LMTrainerConfig()
    state = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = lm_trainer.make_train_step(cfg, tcfg)
    tokens = jnp.zeros((2, 8), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    closed = jax.make_jaxpr(lambda s, b: step(s, b))(state, batch)
    _, packed_shapes = _table_shapes(state)
    return Traced(closed, frozenset(), packed_shapes)


def _build_train_ctr_dp(method: str, *, sync_bits: int = 8) -> Traced:
    """DP-wrapped CTR trainer step on a 1-device mesh at ``sync_bits``.

    The compressed gradient sync runs *between* backward and update inside
    the same traced program, so the codes-dequant-only contract must hold
    through the collective too — the wire codes and the table codes share
    dequant machinery.  Storage stays byte-width/unpacked here: the DP
    wrapper syncs the *dense* dequantized-table gradient (the only
    rank-invariant shape), so a packed store would legitimately unpack
    whole — packed containment belongs to the fused sparse targets above.
    """
    import jax
    import jax.numpy as jnp

    import repro.dist  # noqa: F401  (installs the shard_map compat adapter)
    from repro.training import data_parallel

    trainer, state, spec = _ctr_trainer(method, bits=8, packed=False)
    mesh = jax.make_mesh((1,), ("data",))
    dp = data_parallel.DPConfig(sync_bits=sync_bits)
    step = data_parallel.make_ctr_dp_step(trainer, mesh, dp, jit=False)
    ids = jnp.zeros((16, len(CTR_CARDS)), jnp.int32)
    labels = jnp.zeros((16,), jnp.float32)
    closed = jax.make_jaxpr(lambda s, i, y: step(s, i, y))(state, ids, labels)
    _, packed_shapes = _table_shapes(state)
    return Traced(closed, frozenset(), packed_shapes)


def _build_train_lm_dp(method: str, *, sync_bits: int = 8) -> Traced:
    import dataclasses as dc

    import jax
    import jax.numpy as jnp
    from repro import configs

    import repro.dist  # noqa: F401  (installs the shard_map compat adapter)
    from repro.training import data_parallel, lm_trainer

    cfg = dc.replace(configs.smoke_config("smollm-135m"),
                     embedding_method=method)
    tcfg = lm_trainer.LMTrainerConfig(dp_sync_bits=sync_bits)
    state = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    mesh = jax.make_mesh((1,), ("data",))
    step = data_parallel.make_lm_dp_step(cfg, tcfg, mesh, jit=False)
    tokens = jnp.zeros((2, 8), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    closed = jax.make_jaxpr(lambda s, b: step(s, b))(state, batch)
    _, packed_shapes = _table_shapes(state)
    return Traced(closed, frozenset(), packed_shapes)


def _build_collective(bits: int) -> Traced:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    import repro.dist  # noqa: F401  (installs the shard_map compat adapter)
    from repro.dist import collectives

    mesh = jax.make_mesh((1,), ("data",))

    def sync(g, key):
        return collectives.compressed_psum_local(g, "data", key, bits=bits)

    fn = jax.shard_map(sync, mesh=mesh, in_specs=(P(), P()),
                       out_specs=P(), check_vma=False)
    closed = jax.make_jaxpr(fn)(
        jnp.zeros((64,), jnp.float32), jax.random.PRNGKey(0)
    )
    return Traced(closed)


# ---------------------------------------------------------------- registry


def all_targets() -> list[TraceTarget]:
    targets: list[TraceTarget] = []
    for m in ENGINE_METHODS:
        targets.append(TraceTarget(
            name=f"engine-ctr/{m}",
            build=lambda m=m: _build_engine_ctr(m),
            checks=("no-f32-table", "codes-dequant-only",
                    "packed-containment"),
        ))
    for m in LM_ENGINE_METHODS:
        targets.append(TraceTarget(
            name=f"engine-lm/{m}",
            build=lambda m=m: _build_engine_lm(m),
            checks=("no-f32-table", "codes-dequant-only",
                    "packed-containment"),
        ))
    for m in TRAINER_METHODS:
        targets.append(TraceTarget(
            name=f"train-ctr-fused/{m}",
            build=lambda m=m: _build_train_ctr(m),
            checks=("codes-dequant-only", "packed-containment"),
        ))
    targets.append(TraceTarget(
        name="train-lm-dense/lpt",
        build=_build_train_lm_dense,
        checks=("codes-dequant-only", "packed-containment"),
    ))
    targets.append(TraceTarget(
        name="train-ctr-dp8/alpt",
        build=lambda: _build_train_ctr_dp("alpt", sync_bits=8),
        checks=("codes-dequant-only", "packed-containment"),
    ))
    targets.append(TraceTarget(
        name="train-lm-dp8/lpt",
        build=lambda: _build_train_lm_dp("lpt", sync_bits=8),
        checks=("codes-dequant-only", "packed-containment"),
    ))
    for bits in (4, 2):
        targets.append(TraceTarget(
            name=f"collective-sync/bits{bits}",
            build=lambda bits=bits: _build_collective(bits),
            checks=("packed-wire",),
        ))
    return targets


def run_jaxpr_checks(names: list[str] | None = None) -> list[Finding]:
    """Build every (selected) target and run its checks.

    A target that fails to *build* is itself a finding — the analysis gate
    must not silently skip a contract because a fixture broke.
    """
    from repro.analysis import jaxpr as jx

    out: list[Finding] = []
    for target in all_targets():
        if names is not None and target.name not in names:
            continue
        try:
            traced = target.build()
        except Exception as e:  # noqa: BLE001 — converted to a finding
            out.append(Finding(
                rule="jaxpr-trace-error", path=f"<target:{target.name}>",
                line=0,
                message=f"trace target failed to build: {type(e).__name__}: "
                f"{e}",
                hint="the analysis gate cannot skip a broken fixture — fix "
                "the target in analysis/jaxpr/targets.py",
            ))
            continue
        for check in target.checks:
            if check == "no-f32-table":
                out.extend(jx.check_no_f32_table(
                    traced.closed, traced.forbidden, target.name))
            elif check == "codes-dequant-only":
                out.extend(jx.check_codes_reach_float_via_dequant(
                    traced.closed, target.name))
            elif check == "packed-containment":
                out.extend(jx.check_packed_stays_packed(
                    traced.closed, traced.packed_forbidden, target.name))
            elif check == "packed-wire":
                out.extend(jx.check_wire_stays_packed(
                    traced.closed, target.name))
    return out
