"""Mixed per-field precision: one LPT sub-table per bit-width group.

CTR tables are concatenations of per-field vocabularies, and the fields are
wildly asymmetric: a handful of small fields (site category, device type)
whose rows are hit on almost every example, and a few huge ones (user id,
item id) that dominate memory but whose rows are each touched rarely.  A
single global bit width over-spends on the big fields or under-serves the
hot ones.  This method assigns a bit width *per field* — from
``spec.field_bits`` when given, otherwise from the mean per-row hit rate of
the synthetic CTR stream (:func:`assign_field_bits`) — and composes the
table from one packed LPT sub-table per distinct width via the registry's
existing pieces: ``repro.core.lpt`` does the math, ``repro.core.codestore``
packs the sub-byte groups, and no trainer learns anything new.

Geometry: fields occupy contiguous global id ranges (``offsets[f]`` fence-
posts, exactly the layout :mod:`repro.data.ctr_synth` emits).  Group ``g``
stacks the rows of every field assigned to it; global id ``i`` of field
``f`` lives at row ``i - offsets[f] + field_local[f]`` of sub-table
``field_group[f]``.  The field maps are static tuples (one entry per field,
never per row), so the id arithmetic constant-folds under jit.

Without ``field_cards`` the plan degenerates to a single group at
``spec.bits`` — ordinary LPT semantics — which is what generic consumers
(the LM trainer, the conformance suite's default spec) get.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import lpt as lpt_core
from repro.kernels import ops as kernel_ops
from repro.methods.base import IntegerTableMethod, register
from repro.serving import table as serving_tbl
from repro.storage import base as rowstore


class MixedTable(NamedTuple):
    """One LPT sub-table per bit-width group (field maps live in the spec)."""

    subs: tuple[lpt_core.LPTTable, ...]


def assign_field_bits(
    cards: tuple[int, ...],
    *,
    hot_rate: float = 1.0 / 64.0,
    cold_rate: float = 1.0 / 4096.0,
) -> tuple[int, ...]:
    """Bit width per field from the synthetic stream's row-hit statistics.

    Every example looks up exactly one id per field (the
    :mod:`repro.data.ctr_synth` contract), so a field of cardinality ``c``
    hits each of its rows at mean rate ``1/c`` per example — the Zipf skew
    moves mass to head rows but cannot raise the mean.  Hot rows see many
    SR updates between reads and keep full 8-bit codes; mid fields take
    4 bits; huge vocabularies, where residency is actually won, drop to
    2 bits (both sub-byte widths store packed, 8//bits codes per byte).
    """
    out = []
    for c in cards:
        rate = 1.0 / max(int(c), 1)
        out.append(8 if rate >= hot_rate else (4 if rate >= cold_rate else 2))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class MixedPlan:
    """Static field→(group, local row) layout derived from one spec."""

    field_offsets: tuple[int, ...]  # [F] global start row per field
    field_bits: tuple[int, ...]  # [F] resolved bit width per field
    field_group: tuple[int, ...]  # [F] sub-table index per field
    field_local: tuple[int, ...]  # [F] local start row inside the sub
    group_bits: tuple[int, ...]  # [G] bit width per sub-table
    group_rows: tuple[int, ...]  # [G] live rows per sub-table
    group_fields: tuple[tuple[int, ...], ...]  # [G] field ids per sub-table


def plan_of(spec) -> MixedPlan:
    """Resolve ``spec.field_cards`` / ``field_bits`` into a static layout."""
    cards = spec.field_cards if spec.field_cards is not None else (spec.n,)
    if sum(cards) != spec.n:
        raise ValueError(
            f"field_cards sum {sum(cards)} != table rows {spec.n}"
        )
    if spec.field_bits is not None:
        fbits = tuple(int(b) for b in spec.field_bits)
        if len(fbits) != len(cards):
            raise ValueError(
                f"{len(fbits)} field_bits for {len(cards)} fields"
            )
    elif spec.field_cards is None:
        fbits = (spec.bits,)
    else:
        fbits = assign_field_bits(cards)
    for b in fbits:
        if not 2 <= b <= 8:
            raise ValueError(f"field bit width {b} outside [2, 8]")

    group_bits = tuple(sorted(set(fbits), reverse=True))
    field_group = tuple(group_bits.index(b) for b in fbits)
    offsets, acc = [], 0
    for c in cards:
        offsets.append(acc)
        acc += int(c)
    local_acc = [0] * len(group_bits)
    field_local = []
    for f, c in enumerate(cards):
        g = field_group[f]
        field_local.append(local_acc[g])
        local_acc[g] += int(c)
    return MixedPlan(
        field_offsets=tuple(offsets),
        field_bits=fbits,
        field_group=field_group,
        field_local=tuple(field_local),
        group_bits=group_bits,
        group_rows=tuple(local_acc),
        group_fields=tuple(
            tuple(f for f in range(len(cards)) if field_group[f] == g)
            for g in range(len(group_bits))
        ),
    )


def _map_ids(plan: MixedPlan, ids: jax.Array):
    """Global ids -> (group index, local row) via the static field maps."""
    offs = jnp.asarray(plan.field_offsets, jnp.int32)
    fid = jnp.searchsorted(offs, ids.astype(jnp.int32), side="right") - 1
    local = (
        ids.astype(jnp.int32)
        - jnp.take(offs, fid)
        + jnp.take(jnp.asarray(plan.field_local, jnp.int32), fid)
    )
    gid = jnp.take(jnp.asarray(plan.field_group, jnp.int32), fid)
    return gid, local


@register("mixed")
class MixedMethod(IntegerTableMethod):
    @staticmethod
    def _pad_rows(rows: int, spec) -> int:
        """Sub-table allocation: id space + scratch row, tile-rounded."""
        if not spec.pad_to_tiles:
            return rows
        return -(-(rows + 1) // kernel_ops.SUBLANE) * kernel_ops.SUBLANE

    def init(self, key, spec):
        plan = plan_of(spec)
        subs = []
        for g, bits_g in enumerate(plan.group_bits):
            subs.append(
                lpt_core.init_table(
                    jax.random.fold_in(key, g),
                    self._pad_rows(plan.group_rows[g], spec),
                    spec.d_padded,
                    bits_g,
                    init_scale=spec.init_scale,
                    clip_value=spec.clip_value,
                    optimizer=spec.row_optimizer,
                    use_kernels=spec.use_kernels,
                    packed=spec.packed,
                )
            )
        return MixedTable(subs=tuple(subs))

    def lookup(self, state, ids, spec, grad_scale=1.0):
        plan = plan_of(spec)
        gid, local = _map_ids(plan, ids)
        # Masked sum over the groups — the identical composition (group
        # order, where/sum placement) serving's MixedQuantTable.rows uses,
        # so training reads and Engine reads stay bitwise-parity.
        out = jnp.zeros(ids.shape + (spec.d,), jnp.float32)
        for g, sub in enumerate(state.subs):
            mask = gid == g
            vals = lpt_core.lookup(
                sub, jnp.where(mask, local, 0),
                use_kernels=spec.use_kernels, out_dim=spec.d,
            )
            out = out + jnp.where(mask[..., None], vals, 0.0)
        return out

    def dense_table(self, state, spec):
        return self.lookup(state, jnp.arange(spec.n), spec)

    def memory_bytes(self, state, spec, *, training):
        # Storage-actual per group: the packed containers of the sub-byte
        # groups really hold ceil(d*bits/8) bytes per row.
        return sum(
            rowstore.resident_bytes_of(sub.codes) + sub.n_rows * 4
            for sub in state.subs
        )

    def sparse_apply(self, state, ids, g_rows, *, spec, lr, weight_decay,
                     noise_key):
        plan = plan_of(spec)
        gid, local = _map_ids(plan, ids)
        subs = []
        for g, sub in enumerate(state.subs):
            rows_g = plan.group_rows[g]
            # Non-member occurrences map to the dedup sentinel: they collapse
            # into one unique entry whose scatter lands on the scratch row
            # (padded tables) or drops (mode='drop'), never on live rows.
            sub_ids = jnp.where(gid == g, local, rows_g)
            subs.append(
                lpt_core.sparse_apply(
                    sub, sub_ids, g_rows,
                    lr=lr, bits=plan.group_bits[g],
                    rounding=spec.alpt.rounding,
                    noise_key=jax.random.fold_in(noise_key, g),
                    optimizer=spec.row_optimizer,
                    weight_decay=weight_decay, id_space=rows_g,
                    use_kernels=spec.use_kernels,
                )
            )
        return MixedTable(subs=tuple(subs))

    def dense_update(self, state, opt, grads, *, spec, lr, weight_decay,
                     noise_key=None, delta_grad=None, batch_rows=None):
        plan = plan_of(spec)
        cards = spec.field_cards if spec.field_cards is not None else (spec.n,)
        subs = []
        for g, sub in enumerate(state.subs):
            # Re-lay the global [n, d] gradient into this group's row order:
            # fields are contiguous global slices, statically bounded.
            parts = [
                grads[plan.field_offsets[f]: plan.field_offsets[f] + cards[f]]
                for f in plan.group_fields[g]
            ]
            gg = parts[0] if len(parts) == 1 else jnp.concatenate(parts, 0)
            n_alloc, d_alloc = sub.codes.shape
            gg = jnp.pad(
                gg,
                ((0, n_alloc - gg.shape[0]), (0, d_alloc - gg.shape[1])),
            )
            subs.append(
                lpt_core.dense_apply(
                    sub, gg,
                    lr=lr, bits=plan.group_bits[g],
                    rounding=spec.alpt.rounding,
                    noise_key=(
                        None if noise_key is None
                        else jax.random.fold_in(noise_key, g)
                    ),
                    optimizer=spec.row_optimizer,
                    weight_decay=weight_decay,
                    use_kernels=spec.use_kernels,
                )
            )
        return MixedTable(subs=tuple(subs)), None, {}

    def serving_state(self, state, spec):
        """Integer-resident export: every group ships its packed codes +
        per-row Delta, plus the static field maps the Engine needs to route
        ids — the fp32 table never materializes."""
        plan = plan_of(spec)
        return serving_tbl.MixedQuantTable(
            subs=tuple(
                serving_tbl.QuantTable(
                    codes=sub.codes, step=sub.step,
                    n=plan.group_rows[g], d=spec.d,
                    use_kernels=spec.use_kernels,
                )
                for g, sub in enumerate(state.subs)
            ),
            field_offsets=plan.field_offsets,
            field_group=plan.field_group,
            field_local=plan.field_local,
            n=spec.n, d=spec.d,
        )

    def storage_spec(self, spec):
        """One slot per bit-width group; global ids resolve to a group's
        local row space through the same static field maps the lookups use
        (non-member ids -> -1, ignored by the cache policy)."""
        plan = plan_of(spec)
        starts = np.asarray(plan.field_offsets, np.int64)
        group = np.asarray(plan.field_group, np.int64)
        local = np.asarray(plan.field_local, np.int64)

        def make_local(g):
            def f(ids):
                ids = np.asarray(ids, np.int64)
                fid = np.searchsorted(starts, ids, side="right") - 1
                loc = ids - starts[fid] + local[fid]
                return np.where(group[fid] == g, loc, -1)

            return f

        def make_put(g):
            def put(s, t):
                return MixedTable(subs=s.subs[:g] + (t,) + s.subs[g + 1:])

            return put

        return tuple(
            rowstore.CacheSlot(
                name=f"group{g}", rows=plan.group_rows[g],
                get=(lambda g: lambda s: s.subs[g])(g),
                put=make_put(g),
                local_ids=make_local(g),
            )
            for g in range(len(plan.group_bits))
        )

    def table_pspec(self, row, col, *, row_optimizer="adam"):
        # Group row counts rarely divide mesh axes; stay replicated.  The
        # pspec mirrors the *degenerate* single-group layout — the only one
        # generic specs (no field_cards) produce; per-field CTR configs run
        # data-parallel, not pjit-sharded.
        sub = lpt_core.LPTTable(codes=P(), step=P(), mu=P(), nu=P(), count=P())
        return MixedTable(subs=(sub,))
