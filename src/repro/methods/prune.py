"""DeepLight-style magnitude pruning baseline (Deng et al. 2021; §4.1/B.2):
dense fp32 weights + a periodically recomputed magnitude mask."""
from __future__ import annotations

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import pruning
from repro.methods.base import EmbeddingMethod, register


@register("prune")
class PruneMethod(EmbeddingMethod):
    has_host_refresh = True

    def init(self, key, spec):
        return pruning.init_prune(
            key, spec.n, spec.d, init_scale=spec.init_scale
        )

    def lookup(self, state, ids, spec, grad_scale=1.0):
        return pruning.prune_lookup(state, ids)

    def trainable_params(self, state, spec):
        return {"weights": state.weights}

    def with_params(self, state, params, spec):
        return state._replace(weights=params["weights"])

    def memory_bytes(self, state, spec, *, training):
        fp = spec.n * spec.d * 4
        if training:
            # Unstructured sparsity: dense weights + 1-bit mask.
            return fp + spec.n * spec.d // 8
        keep = float(jnp.mean(state.mask.astype(jnp.float32)))
        return int(fp * keep)

    # -------------------------------------------------- host-side refresh

    def host_sync(self, state, step, spec):
        # The pruning-ratio schedule reads a host-driven step clock.
        return state._replace(step=jnp.asarray(step, jnp.int32))

    def host_refresh(self, state, spec):
        return pruning.update_mask(state, spec.prune)

    def refresh_every(self, spec):
        return spec.prune.update_every

    def table_pspec(self, row, col, *, row_optimizer="adam"):
        return pruning.PruneState(
            weights=P(row, col), mask=P(row, col), step=P()
        )

    def param_pspec(self, row, col):
        return {"weights": P(row, col)}
