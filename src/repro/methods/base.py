"""The `EmbeddingMethod` protocol + registry.

The paper's thesis is that the embedding *method* (fp32, LPT, ALPT, QAT,
hashing, pruning, ...) is the swappable axis of a CTR/LM system.  This module
makes that axis a first-class object: every method is a registered
:class:`EmbeddingMethod` instance, and every consumer — both trainers, the
data-parallel wrapper, sharding specs, serving, checkpointing, launchers —
dispatches through :func:`get` instead of string chains.

A method bundles three things:

* **state** — ``init`` / ``lookup`` / ``memory_bytes`` / ``serving_table`` /
  ``checkpoint_schema`` / ``table_pspec`` sharding hints;
* **float-leaf training** — ``trainable_params`` / ``with_params`` expose the
  differentiable leaves for methods whose table is ordinary float state
  (fp, lsq, pact, hash, prune); the trainers run a generic joint-Adam step;
* **integer-table training** — methods whose table is integer codes
  (lpt, alpt, qr_lpt) instead implement the row/sparse formulation
  (``fused_row_step`` / ``sparse_apply``: paper Eq. 8 / Algorithm 1) and the
  dense formulation (``dense_params`` / ``dense_update``: rank-invariant
  [n, d] gradients for the data-parallel and pjit paths).

Capability flags (``is_integer_table``, ``has_learned_step``,
``has_host_refresh``) replace the old ``FLOAT_METHODS``/``INT_METHODS``
tuple-membership checks everywhere.

Adding a method touches exactly one new file: subclass, decorate with
``@register("name")``, import it from ``repro/methods/__init__.py`` (or any
plugin module).  ``repro/methods/qr_lpt.py`` is the worked example — a
composed method (QR hashing over int8 LPT tables) the old two-bucket split
could not express, registered without touching any trainer.
"""
from __future__ import annotations

import abc
import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import alpt as alpt_core
from repro.core import fence
from repro.core import pruning as pruning_core
from repro.dist.context import hint
from repro.kernels import ops as kernel_ops
from repro.optim import adam_update
from repro.serving import table as serving_tbl
from repro.storage import base as rowstore


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@dataclasses.dataclass(frozen=True)
class EmbeddingSpec:
    """Declarative description of one embedding table (method + geometry).

    ``method`` names a registered :class:`EmbeddingMethod`; the remaining
    fields are the union of every method's hyper-parameters (each method
    reads only the ones it understands).
    """

    method: str  # any name in repro.methods.available()
    n: int
    d: int
    bits: int = 8
    init_scale: float = 1e-2
    # LPT (Xu et al. 2021) fixes Delta via a tuned clip value:
    clip_value: float | None = None
    # ALPT hyper-parameters (paper §4.1):
    alpt: alpt_core.ALPTConfig = alpt_core.ALPTConfig()
    row_optimizer: str = "adam"
    hash_compression: float = 2.0
    prune: pruning_core.PruneConfig = pruning_core.PruneConfig()
    # Route integer-table hot paths (lookup / write-back / sparse step)
    # through the Pallas kernel suite (repro.kernels.ops).  Default on; the
    # wrappers auto-interpret off-TPU and fall back — counted, never silently
    # — on kernel-ineligible shapes.
    use_kernels: bool = True
    # Pad the table geometry up to kernel tiles at init: rows round up to the
    # sublane multiple *past* the id space (the extra row is the scratch row
    # the fused sparse scatter parks dedup sentinels in), dim rounds up to
    # the sublane multiple.  Lookups/dense tables are sliced back to (n, d),
    # so padding is invisible to the model — it exists so real geometries hit
    # the kernel path instead of the shape fallback.
    pad_to_tiles: bool = False
    # Code-container layout (repro.core.codestore): True packs sub-byte code
    # widths (bits in {2, 4}) into uint8 at 8//bits codes per byte; False
    # keeps one byte per code.  Pure storage choice — training and serving
    # are bitwise identical either way (the packed-parity test bar).
    packed: bool = True
    # Per-field composition (the 'mixed' method): cardinalities of the CTR
    # fields this table spans (sum == n), and optionally an explicit bit
    # width per field.  None leaves the table a single group at `bits`.
    field_cards: tuple[int, ...] | None = None
    field_bits: tuple[int, ...] | None = None

    @property
    def is_integer_table(self) -> bool:
        return get(self.method).is_integer_table

    @property
    def n_padded(self) -> int:
        """Allocated rows: id space (+ scratch row, sublane-rounded) if padded."""
        if not self.pad_to_tiles:
            return self.n
        return _round_up(self.n + 1, kernel_ops.SUBLANE)

    @property
    def d_padded(self) -> int:
        """Allocated embedding width (sublane-rounded if padded)."""
        if not self.pad_to_tiles:
            return self.d
        return _round_up(self.d, kernel_ops.SUBLANE)


class EmbeddingMethod(abc.ABC):
    """One embedding method: state, lookup, training formulations, metadata.

    Defaults implement the float-leaf family generically; integer-table
    methods subclass :class:`IntegerTableMethod` instead.
    """

    name: str = "?"  # set by @register

    # ---------------------------------------------------------- capabilities
    #: Table is integer codes (no differentiable float leaves); trainers use
    #: the row/sparse + dense formulations instead of joint Adam.
    is_integer_table: bool = False
    #: Method learns its step size Delta via a second fake-quant forward
    #: (ALPT Algorithm 1 line 4); trainers must supply a delta-grad closure.
    has_learned_step: bool = False
    #: Method needs a host-side state refresh between steps (DeepLight mask
    #: recomputation); trainers wrap the jitted step with ``host_refresh``.
    has_host_refresh: bool = False

    def capabilities(self) -> dict[str, bool]:
        return {
            "is_integer_table": self.is_integer_table,
            "has_learned_step": self.has_learned_step,
            "has_host_refresh": self.has_host_refresh,
        }

    # ---------------------------------------------------------------- state

    @abc.abstractmethod
    def init(self, key: jax.Array, spec: EmbeddingSpec) -> Any:
        """Initialize the table state pytree."""

    @abc.abstractmethod
    def lookup(self, state: Any, ids: jax.Array, spec: EmbeddingSpec,
               grad_scale: float = 1.0) -> jax.Array:
        """De-quantized / fake-quantized / masked rows [..., d]."""

    @abc.abstractmethod
    def memory_bytes(self, state: Any, spec: EmbeddingSpec, *,
                     training: bool) -> int:
        """Embedding-memory accounting (paper Table 1 compression columns).

        Storage-actual: integer-table methods report their container's
        resident bytes (``repro.storage.base.resident_bytes_of`` — packed
        sub-byte widths count ceil(d*bits/8) per row, not one byte per
        code)."""

    # ------------------------------------------------- float-leaf formulation

    @abc.abstractmethod
    def trainable_params(self, state: Any, spec: EmbeddingSpec) -> Any:
        """Differentiable leaves (None for integer tables)."""

    @abc.abstractmethod
    def with_params(self, state: Any, params: Any, spec: EmbeddingSpec) -> Any:
        """Rebuild state from updated differentiable leaves."""

    # ------------------------------------------------------ dense formulation
    #
    # The shape every distributed consumer wants: a differentiable pytree
    # whose gradient is identical on every replica.  Float-leaf methods use
    # their trainable params; integer tables use the de-quantized [n, d]
    # table (the only rank-invariant shape — see training/data_parallel.py).

    def dense_params(self, state: Any, spec: EmbeddingSpec) -> Any:
        """The pytree the dense/DP backward differentiates w.r.t."""
        return self.trainable_params(state, spec)

    def dense_lookup(self, state: Any, params: Any, ids: jax.Array,
                     spec: EmbeddingSpec) -> jax.Array:
        """Rows for ``ids``, differentiable in ``params``."""
        return self.lookup(self.with_params(state, params, spec), ids, spec)

    def dense_table_from(self, state: Any, params: Any,
                         spec: EmbeddingSpec) -> jax.Array:
        """Full [n, d] float table, differentiable in ``params`` (LM path)."""
        return self.dense_lookup(state, params, jnp.arange(spec.n), spec)

    def hint_dense_params(self, params: Any) -> Any:
        """Sharding hint for the dense params / their gradient (identity by
        default; [n, d]-table-shaped methods constrain to 'embed_table')."""
        return params

    def dense_update(self, state: Any, opt: Any, grads: Any, *,
                     spec: EmbeddingSpec, lr: jax.Array, weight_decay: float,
                     noise_key: jax.Array | None = None,
                     delta_grad: Callable | None = None,
                     batch_rows: int | None = None):
        """Consume (synced) dense-formulation gradients.

        Returns ``(new_state, new_opt, aux_metrics)``.  The default is the
        float-leaf rule: Adam over ``trainable_params`` with decoupled weight
        decay (``opt`` is the caller-held Adam state over those leaves).
        ``delta_grad(w_new, step_vec, gscale) -> g_step`` supplies the synced
        ALPT Delta gradient; ``batch_rows`` is the paper's b (global batch's
        table-row lookups) — both ignored unless ``has_learned_step``.
        """
        params = self.trainable_params(state, spec)
        new_params, new_opt = adam_update(
            grads, opt, params, lr, weight_decay=weight_decay
        )
        return self.with_params(state, new_params, spec), new_opt, {}

    # -------------------------------------------------- row/sparse (fused)

    def fused_row_step(self, state: Any, ids: jax.Array, *,
                       spec: EmbeddingSpec, loss_from_rows: Callable,
                       dense_params: Any, dense_opt: Any,
                       update_dense: Callable, lr: jax.Array,
                       weight_decay: float, noise_key: jax.Array):
        """Single-device fused train step (integer-table methods only).

        ``loss_from_rows(rows, dense_params) -> scalar`` closes over the
        batch; ``update_dense(g, opt, params) -> (new_params, new_opt)`` is
        the caller's dense-parameter optimizer.  Returns
        ``(new_state, new_dense_params, new_dense_opt, metrics)``.
        """
        raise NotImplementedError(
            f"{self.name!r} has no row formulation; use the float-leaf path"
        )

    def dense_delta_grad(self, w_new, step_vec, loss_fn_q, *,
                         spec: EmbeddingSpec, weight_decay: float,
                         gscale: float) -> jax.Array:
        raise NotImplementedError(f"{self.name!r} has no learned step size")

    # ---------------------------------------------------- host-side refresh

    def host_sync(self, state: Any, step: int, spec: EmbeddingSpec) -> Any:
        """Cheap host-side per-step state sync (e.g. schedule clocks)."""
        return state

    def host_refresh(self, state: Any, spec: EmbeddingSpec) -> Any:
        """Jittable periodic refresh (e.g. DeepLight mask recomputation)."""
        raise NotImplementedError(f"{self.name!r} has no host refresh")

    def refresh_every(self, spec: EmbeddingSpec) -> int:
        raise NotImplementedError(f"{self.name!r} has no host refresh")

    # ------------------------------------------------------- serving / eval

    def eval_table(self, state: Any, spec: EmbeddingSpec) -> jax.Array:
        """The [n, d] table evaluation forwards read (training semantics)."""
        return self.dense_table_from(state, self.dense_params(state, spec), spec)

    def serving_table(self, state: Any, spec: EmbeddingSpec) -> jax.Array:
        """The [n, d] table a serving process ships (post-training export)."""
        return self.eval_table(state, spec)

    def serving_state(self, state: Any, spec: EmbeddingSpec):
        """What a serving Engine keeps *resident* (repro.serving).

        Integer-table methods return their codes + scales
        (:class:`repro.serving.table.QuantTable` — the fp32 table is never
        materialized); the float-leaf default wraps the fp export.  Optimizer
        slots (Adam moments, masks' training state) are always dropped here:
        serving residency is inference state only.
        """
        return serving_tbl.FloatTable(self.serving_table(state, spec))

    def storage_spec(self, spec: EmbeddingSpec) -> tuple:
        """Cacheable sub-tables of the training state (the tiered hot-row
        cache hook, :mod:`repro.storage`).

        Returns a tuple of :class:`repro.storage.base.CacheSlot`, one per
        int-code table inside the state: ``get``/``put`` project the slot's
        ``LPTTable`` out of / back into the state, ``local_ids`` maps global
        feature ids to the slot's local row space (non-members -> -1).
        Float-leaf methods have nothing to cache -> ``()``.
        """
        return ()

    # -------------------------------------------------- sharding / metadata

    def table_pspec(self, row, col, *, row_optimizer: str = "adam"):
        """PartitionSpec pytree mirroring the state; ``row``/``col`` are the
        mesh-axis entries chosen (divisibility-guarded) by the caller."""
        return P(row, col)

    def param_pspec(self, row, col):
        """PartitionSpec pytree mirroring ``trainable_params`` (None for
        integer tables — they carry no float-leaf optimizer state)."""
        return P(row, col)

    def checkpoint_schema(self, spec: EmbeddingSpec) -> dict:
        """Leaf path -> {shape, dtype} of the state pytree, for checkpoint
        manifests (int8 codes must survive save/restore as int8)."""
        sds = jax.eval_shape(
            functools.partial(self.init, spec=spec),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
        flat, _ = jax.tree_util.tree_flatten_with_path(sds)
        return {
            jax.tree_util.keystr(path): {
                "shape": [int(s) for s in leaf.shape],
                "dtype": str(leaf.dtype),
            }
            for path, leaf in flat
        }


class IntegerTableMethod(EmbeddingMethod):
    """Base for methods whose table is integer state (no float leaves).

    Subclasses supply ``dense_table`` (de-quantize everything), a paper-Eq.-8
    style ``sparse_apply``/``dense_update`` pair, and inherit a generic fused
    row step: joint backward w.r.t. (looked-up rows, dense params), then the
    sparse row update.
    """

    is_integer_table = True

    def trainable_params(self, state, spec):
        return None

    def with_params(self, state, params, spec):
        return state

    @abc.abstractmethod
    def dense_table(self, state: Any, spec: EmbeddingSpec) -> jax.Array:
        """Materialize the full de-quantized [n, d] table."""

    @abc.abstractmethod
    def sparse_apply(self, state: Any, ids: jax.Array, g_rows: jax.Array, *,
                     spec: EmbeddingSpec, lr: jax.Array, weight_decay: float,
                     noise_key: jax.Array) -> Any:
        """Row update from per-occurrence cotangents (paper Eq. 8)."""

    def dense_params(self, state, spec):
        return self.dense_table(state, spec)

    def dense_lookup(self, state, params, ids, spec):
        """Rows for ``ids``, differentiable in the dense [n, d] ``params``.

        Kernels-on, the *forward* reads the int8 codes through the fused
        ``ops.dequant_gather`` (1 byte/elem instead of gathering the
        materialized fp32 table), while the *backward* stays the exact
        transpose of ``jnp.take`` — ``params`` always equals the de-quantized
        table at call time, so the two forwards are bitwise identical and
        autodiff sees the same function either way.
        """
        if not spec.use_kernels:
            return jnp.take(params, ids, axis=0)
        method = self

        @jax.custom_vjp
        def kernel_gather(p):
            return method.lookup(state, ids, spec)

        def fwd(p):
            return kernel_gather(p), p

        def bwd(p, g):
            _, pull = jax.vjp(lambda q: jnp.take(q, ids, axis=0), p)
            return pull(g)

        kernel_gather.defvjp(fwd, bwd)
        return kernel_gather(params)

    def dense_table_from(self, state, params, spec):
        return params

    def hint_dense_params(self, params):
        return hint(params, "embed_table")

    def serving_table(self, state, spec):
        """Serving export: de-quantize through the fused gather kernel, so
        the fp32 table first exists in the serving process's output buffer —
        the int8 codes are the only table read from HBM (bitwise-identical
        to the jnp export)."""
        if not spec.use_kernels:
            return self.eval_table(state, spec)
        return self.lookup(state, jnp.arange(spec.n), spec)

    def serving_state(self, state, spec):
        """int8-resident serving export: the codes + per-row Delta as-is.

        No de-quantization happens here at all — the Engine's jitted steps
        read rows through ``ops.dequant_gather`` and the tied LM head through
        ``ops.dequant_matmul``, so the fp32 table is deleted from the serving
        story entirely (the PR-5 redesign).  Works for any state whose table
        is a single ``LPTTable`` (lpt, alpt); composed tables override.
        """
        return serving_tbl.QuantTable(
            codes=state.codes, step=state.step, n=spec.n, d=spec.d,
            use_kernels=spec.use_kernels,
        )

    def storage_spec(self, spec):
        """Single-table identity slot — works for any state that *is* one
        ``LPTTable`` (lpt, alpt).  Composed methods override."""
        return (rowstore.CacheSlot(
            name="table", rows=spec.n,
            get=lambda s: s,
            put=lambda s, t: t,
            local_ids=lambda ids: np.asarray(ids),
        ),)

    def fused_row_step(self, state, ids, *, spec, loss_from_rows, dense_params,
                       dense_opt, update_dense, lr, weight_decay, noise_key):
        rows0 = self.lookup(state, ids, spec)
        # Fence the model forward/backward so it compiles identically whatever
        # storage backs the codes (plain, packed, tiered) — the cache-on ==
        # cache-off bitwise contract.  Feature ids are non-negative, so any
        # id doubles as the fence's runtime tick.
        loss, (g_rows, g_dense) = fence.fence_call(
            jax.value_and_grad(loss_from_rows, (0, 1)),
            (rows0, dense_params),
            tick=ids.reshape(-1)[0],
        )
        new_dense, new_opt = update_dense(g_dense, dense_opt, dense_params)
        new_state = self.sparse_apply(
            state, ids, g_rows, spec=spec, lr=lr, weight_decay=weight_decay,
            noise_key=noise_key,
        )
        return new_state, new_dense, new_opt, {"loss": loss}

    def param_pspec(self, row, col):
        return None


# ------------------------------------------------------------------ registry

_REGISTRY: dict[str, EmbeddingMethod] = {}


def register(name: str):
    """Class decorator: instantiate and register under ``name``."""

    def deco(cls):
        if name in _REGISTRY:
            raise ValueError(f"embedding method {name!r} already registered")
        cls.name = name
        _REGISTRY[name] = cls()
        return cls

    return deco


def get(name: str) -> EmbeddingMethod:
    """The registered method instance for ``name`` (ValueError if unknown)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown embedding method {name!r}; registered: {available()}"
        ) from None


def available() -> tuple[str, ...]:
    """Sorted names of every registered method."""
    return tuple(sorted(_REGISTRY))
