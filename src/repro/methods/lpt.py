"""LPT: int8 codes + per-row Delta, no fp32 master copy (paper §2.3, Eq. 8).

Thin adapter over :mod:`repro.core.lpt` — the paper-faithful math stays there.
``spec.use_kernels`` routes every hot path through the fused Pallas kernels
(``repro.kernels.ops``): lookups via ``dequant_gather``, the CTR sparse step
via ``sparse_row_update``, the dense write-back via ``lpt_update``;
``spec.pad_to_tiles`` allocates the table at kernel-tile geometry (live
``(n, d)`` is sliced back out everywhere the model looks).

Serving ships the table as-is: ``serving_state`` (inherited from
:class:`~repro.methods.base.IntegerTableMethod`) hands the codes + per-row
Delta to the ``repro.serving`` Engine, which reads rows through
``ops.dequant_gather`` inside its jitted steps — no fp32 export.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import lpt as lpt_core
from repro.methods.base import IntegerTableMethod, register
from repro.storage import base as rowstore


def _pad_grads(grads, state, spec):
    """Zero-pad live-geometry dense gradients up to the allocated table."""
    n_alloc, d_alloc = state.codes.shape
    n, d = grads.shape
    if (n, d) == (n_alloc, d_alloc):
        return grads
    return jnp.pad(grads, ((0, n_alloc - n), (0, d_alloc - d)))


@register("lpt")
class LPTMethod(IntegerTableMethod):
    # Vanilla LPT fixes Delta from the tuned clip value; ALPT overrides this.
    _clip_value_of = staticmethod(lambda spec: spec.clip_value)

    def init(self, key, spec):
        return lpt_core.init_table(
            key,
            spec.n_padded,
            spec.d_padded,
            spec.bits,
            init_scale=spec.init_scale,
            clip_value=self._clip_value_of(spec),
            optimizer=spec.row_optimizer,
            use_kernels=spec.use_kernels,
            packed=spec.packed,
        )

    def lookup(self, state, ids, spec, grad_scale=1.0):
        return lpt_core.lookup(
            state, ids, use_kernels=spec.use_kernels, out_dim=spec.d
        )

    def dense_table(self, state, spec):
        return lpt_core.dense_table(state)[: spec.n, : spec.d]

    def memory_bytes(self, state, spec, *, training):
        # Storage-actual: the container's resident bytes (packed sub-byte
        # widths really are ceil(d*bits/8) per row) + the per-row fp32 Delta.
        return (
            rowstore.resident_bytes_of(state.codes) + spec.n_padded * 4
        )

    def sparse_apply(self, state, ids, g_rows, *, spec, lr, weight_decay,
                     noise_key):
        return lpt_core.sparse_apply(
            state, ids, g_rows,
            lr=lr, bits=spec.bits, rounding=spec.alpt.rounding,
            noise_key=noise_key, optimizer=spec.row_optimizer,
            weight_decay=weight_decay, id_space=spec.n,
            use_kernels=spec.use_kernels,
        )

    def dense_update(self, state, opt, grads, *, spec, lr, weight_decay,
                     noise_key=None, delta_grad=None, batch_rows=None):
        new_state = lpt_core.dense_apply(
            state, _pad_grads(grads, state, spec),
            lr=lr, bits=spec.bits, rounding=spec.alpt.rounding,
            noise_key=noise_key, optimizer=spec.row_optimizer,
            weight_decay=weight_decay, use_kernels=spec.use_kernels,
        )
        return new_state, None, {}

    def table_pspec(self, row, col, *, row_optimizer="adam"):
        slot = P(row, col) if row_optimizer == "adam" else P(row)
        return lpt_core.LPTTable(
            codes=P(row, col), step=P(row), mu=slot, nu=slot, count=P()
        )
