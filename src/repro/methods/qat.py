"""QAT baselines LSQ / PACT (paper §2.2, §4.1): fp32 master copy, fake-quant
forward — compresses inference (int8 export) but not training memory."""
from __future__ import annotations

from jax.sharding import PartitionSpec as P

from repro.core import qat as qat_core
from repro.core import quant
from repro.methods.base import EmbeddingMethod, register
from repro.serving import table as serving_tbl


class _QATMethod(EmbeddingMethod):
    variant: str  # 'lsq' | 'pact'

    def init(self, key, spec):
        return qat_core.init_qat(
            key, spec.n, spec.d, spec.bits, method=self.variant,
            init_scale=spec.init_scale,
        )

    def lookup(self, state, ids, spec, grad_scale=1.0):
        return qat_core.qat_lookup(
            state, ids, spec.bits, method=self.variant, grad_scale=grad_scale
        )

    def trainable_params(self, state, spec):
        return {"weights": state.weights, "scale": state.scale}

    def with_params(self, state, params, spec):
        return qat_core.QATTable(
            weights=params["weights"], scale=params["scale"]
        )

    def memory_bytes(self, state, spec, *, training):
        # Training keeps the fp master copy; inference ships codes + step.
        fp = spec.n * spec.d * 4
        if training:
            return fp + spec.n * 4
        return int(spec.n * spec.d * spec.bits / 8) + spec.n * 4

    def serving_table(self, state, spec):
        codes, step = qat_core.export_int8(state, spec.bits, method=self.variant)
        return quant.dequantize(codes, step)

    def serving_state(self, state, spec):
        """QAT's whole deployment story is the int8 export — serve it
        int8-resident (codes + step), not re-inflated to fp32."""
        codes, step = qat_core.export_int8(state, spec.bits, method=self.variant)
        return serving_tbl.QuantTable(
            codes=codes, step=step, n=spec.n, d=spec.d,
            use_kernels=spec.use_kernels,
        )

    def table_pspec(self, row, col, *, row_optimizer="adam"):
        return qat_core.QATTable(weights=P(row, col), scale=P(row))

    def param_pspec(self, row, col):
        return {"weights": P(row, col), "scale": P(row)}


@register("lsq")
class LSQMethod(_QATMethod):
    variant = "lsq"


@register("pact")
class PACTMethod(_QATMethod):
    variant = "pact"
