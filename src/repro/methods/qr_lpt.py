"""qr_lpt: quotient-remainder hashing composed with int8 LPT tables.

The composed compressor the old two-bucket ``FLOAT_METHODS``/``INT_METHODS``
split could not express: both QR sub-tables (Shi et al. 2020) live as int8
codes + per-row Delta with NO fp32 master copy (paper Eq. 8 semantics per
sub-table), so the compression ratios multiply — ~2x from hashing times ~4x
from 8-bit codes.  Row gradients reach each sub-table through the product
rule: d(rem * quo)/drem = quo and vice versa.

This file is the registry's existence proof: a brand-new method wired into
both trainers, the DP wrapper, serving, sharding, and checkpointing without
touching any of them — everything below is registered state + formulations.
The kernel path composes for free: each sub-table routes its lookups and row
updates through the same ``repro.kernels.ops`` hot paths as plain LPT
(``spec.use_kernels``), each with its own dedup sentinel / scratch row under
``spec.pad_to_tiles``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import hashing
from repro.core import lpt as lpt_core
from repro.kernels import ops as kernel_ops
from repro.methods.base import IntegerTableMethod, _round_up, register


class QRLPTTable(NamedTuple):
    remainder: lpt_core.LPTTable  # int8 [r, d] sub-table
    quotient: lpt_core.LPTTable  # int8 [ceil(n/r), d] sub-table
    r: jax.Array  # int32 scalar — remainder modulus


@register("qr_lpt")
class QRLPTMethod(IntegerTableMethod):
    @staticmethod
    def _pad_rows(rows: int, spec) -> int:
        """Sub-table allocation: id space + scratch row, tile-rounded."""
        if not spec.pad_to_tiles:
            return rows
        return _round_up(rows + 1, kernel_ops.SUBLANE)

    def init(self, key, spec):
        r, q_rows = hashing.qr_rows(spec.n, spec.hash_compression)
        k1, k2 = jax.random.split(key)
        return QRLPTTable(
            remainder=lpt_core.init_table(
                k1, self._pad_rows(r, spec), spec.d_padded, spec.bits,
                init_scale=spec.init_scale, optimizer=spec.row_optimizer,
                use_kernels=spec.use_kernels,
            ),
            # The quotient factor starts near 1 so the product starts ~= the
            # remainder rows (Shi et al. 2020 composition).
            quotient=lpt_core.init_table(
                k2, self._pad_rows(q_rows, spec), spec.d_padded, spec.bits,
                init_scale=spec.init_scale, mean=1.0,
                optimizer=spec.row_optimizer, use_kernels=spec.use_kernels,
            ),
            r=jnp.asarray(r, jnp.int32),
        )

    def lookup(self, state, ids, spec, grad_scale=1.0):
        rem = lpt_core.lookup(
            state.remainder, ids % state.r,
            use_kernels=spec.use_kernels, out_dim=spec.d,
        )
        quo = lpt_core.lookup(
            state.quotient, ids // state.r,
            use_kernels=spec.use_kernels, out_dim=spec.d,
        )
        return rem * quo

    def dense_table(self, state, spec):
        return self.lookup(state, jnp.arange(spec.n), spec)

    def memory_bytes(self, state, spec, *, training):
        rows = state.remainder.n_rows + state.quotient.n_rows
        return int(rows * spec.d_padded * spec.bits / 8) + rows * 4

    def _sub_apply(self, table, ids, g_rows, *, spec, lr, weight_decay, key,
                   id_space):
        return lpt_core.sparse_apply(
            table, ids, g_rows,
            lr=lr, bits=spec.bits, rounding=spec.alpt.rounding,
            noise_key=key, optimizer=spec.row_optimizer,
            weight_decay=weight_decay, id_space=id_space,
            use_kernels=spec.use_kernels,
        )

    def sparse_apply(self, state, ids, g_rows, *, spec, lr, weight_decay,
                     noise_key):
        r, q_rows = hashing.qr_rows(spec.n, spec.hash_compression)
        rid, qid = ids % state.r, ids // state.r
        rem = lpt_core.lookup(
            state.remainder, rid, use_kernels=spec.use_kernels, out_dim=spec.d
        )
        quo = lpt_core.lookup(
            state.quotient, qid, use_kernels=spec.use_kernels, out_dim=spec.d
        )
        # Product rule: each sub-table's row cotangent is g * (other factor).
        new_rem = self._sub_apply(
            state.remainder, rid, g_rows * quo, spec=spec, lr=lr,
            weight_decay=weight_decay, key=jax.random.fold_in(noise_key, 0),
            id_space=r,
        )
        new_quo = self._sub_apply(
            state.quotient, qid, g_rows * rem, spec=spec, lr=lr,
            weight_decay=weight_decay, key=jax.random.fold_in(noise_key, 1),
            id_space=q_rows,
        )
        return QRLPTTable(remainder=new_rem, quotient=new_quo, r=state.r)

    def dense_update(self, state, opt, grads, *, spec, lr, weight_decay,
                     noise_key=None, delta_grad=None, batch_rows=None):
        """Rank-invariant formulation: ``grads`` is the dense [n, d] gradient
        of the *virtual* product table; segment-sum it into each sub-table."""
        ids = jnp.arange(spec.n)
        rid, qid = ids % state.r, ids // state.r
        rem = lpt_core.lookup(
            state.remainder, rid, use_kernels=spec.use_kernels, out_dim=spec.d
        )
        quo = lpt_core.lookup(
            state.quotient, qid, use_kernels=spec.use_kernels, out_dim=spec.d
        )
        d_pad = state.remainder.dim - spec.d
        g_rem = jax.ops.segment_sum(
            grads * quo, rid, num_segments=state.remainder.n_rows
        )
        g_quo = jax.ops.segment_sum(
            grads * rem, qid, num_segments=state.quotient.n_rows
        )
        if d_pad:
            g_rem = jnp.pad(g_rem, ((0, 0), (0, d_pad)))
            g_quo = jnp.pad(g_quo, ((0, 0), (0, d_pad)))
        kw = dict(lr=lr, bits=spec.bits, rounding=spec.alpt.rounding,
                  optimizer=spec.row_optimizer, weight_decay=weight_decay,
                  use_kernels=spec.use_kernels)
        new_rem = lpt_core.dense_apply(
            state.remainder, g_rem,
            noise_key=jax.random.fold_in(noise_key, 0), **kw,
        )
        new_quo = lpt_core.dense_apply(
            state.quotient, g_quo,
            noise_key=jax.random.fold_in(noise_key, 1), **kw,
        )
        return QRLPTTable(remainder=new_rem, quotient=new_quo, r=state.r), None, {}

    def table_pspec(self, row, col, *, row_optimizer="adam"):
        # Sub-table row counts rarely divide the mesh axes; stay replicated.
        sub = lpt_core.LPTTable(codes=P(), step=P(), mu=P(), nu=P(), count=P())
        return QRLPTTable(remainder=sub, quotient=sub, r=P())
