"""qr_lpt / qr_alpt: quotient-remainder hashing composed with int8 LPT tables.

The composed compressor the old two-bucket ``FLOAT_METHODS``/``INT_METHODS``
split could not express: both QR sub-tables (Shi et al. 2020) live as int8
codes + per-row Delta with NO fp32 master copy (paper Eq. 8 semantics per
sub-table), so the compression ratios multiply — ~2x from hashing times ~4x
from 8-bit codes.  Row gradients reach each sub-table through the product
rule: d(rem * quo)/drem = quo and vice versa.

This file is the registry's existence proof: a brand-new method wired into
both trainers, the DP wrapper, serving, sharding, and checkpointing without
touching any of them — everything below is registered state + formulations.
The kernel path composes for free: each sub-table routes its lookups and row
updates through the same ``repro.kernels.ops`` hot paths as plain LPT
(``spec.use_kernels``), each with its own dedup sentinel / scratch row under
``spec.pad_to_tiles``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import alpt as alpt_core
from repro.core import fence
from repro.core import hashing
from repro.core import lpt as lpt_core
from repro.core import quant
from repro.kernels import ops as kernel_ops
from repro.methods.base import IntegerTableMethod, _round_up, register
from repro.serving import table as serving_tbl
from repro.storage import base as rowstore


class QRLPTTable(NamedTuple):
    remainder: lpt_core.LPTTable  # int8 [r, d] sub-table
    quotient: lpt_core.LPTTable  # int8 [ceil(n/r), d] sub-table
    r: jax.Array  # int32 scalar — remainder modulus


@register("qr_lpt")
class QRLPTMethod(IntegerTableMethod):
    @staticmethod
    def _pad_rows(rows: int, spec) -> int:
        """Sub-table allocation: id space + scratch row, tile-rounded."""
        if not spec.pad_to_tiles:
            return rows
        return _round_up(rows + 1, kernel_ops.SUBLANE)

    def init(self, key, spec):
        r, q_rows = hashing.qr_rows(spec.n, spec.hash_compression)
        k1, k2 = jax.random.split(key)
        return QRLPTTable(
            remainder=lpt_core.init_table(
                k1, self._pad_rows(r, spec), spec.d_padded, spec.bits,
                init_scale=spec.init_scale, optimizer=spec.row_optimizer,
                use_kernels=spec.use_kernels, packed=spec.packed,
            ),
            # The quotient factor starts near 1 so the product starts ~= the
            # remainder rows (Shi et al. 2020 composition).
            quotient=lpt_core.init_table(
                k2, self._pad_rows(q_rows, spec), spec.d_padded, spec.bits,
                init_scale=spec.init_scale, mean=1.0,
                optimizer=spec.row_optimizer, use_kernels=spec.use_kernels,
                packed=spec.packed,
            ),
            r=jnp.asarray(r, jnp.int32),
        )

    def lookup(self, state, ids, spec, grad_scale=1.0):
        rem = lpt_core.lookup(
            state.remainder, ids % state.r,
            use_kernels=spec.use_kernels, out_dim=spec.d,
        )
        quo = lpt_core.lookup(
            state.quotient, ids // state.r,
            use_kernels=spec.use_kernels, out_dim=spec.d,
        )
        return rem * quo

    def dense_table(self, state, spec):
        return self.lookup(state, jnp.arange(spec.n), spec)

    def memory_bytes(self, state, spec, *, training):
        # Storage-actual: packed sub-byte containers really hold
        # ceil(d*bits/8) bytes per row; the per-row fp32 Delta rides along.
        rows = state.remainder.n_rows + state.quotient.n_rows
        return (
            rowstore.resident_bytes_of(state.remainder.codes)
            + rowstore.resident_bytes_of(state.quotient.codes)
            + rows * 4
        )

    def _sub_apply(self, table, ids, g_rows, *, spec, lr, weight_decay, key,
                   id_space):
        return lpt_core.sparse_apply(
            table, ids, g_rows,
            lr=lr, bits=spec.bits, rounding=spec.alpt.rounding,
            noise_key=key, optimizer=spec.row_optimizer,
            weight_decay=weight_decay, id_space=id_space,
            use_kernels=spec.use_kernels,
        )

    def sparse_apply(self, state, ids, g_rows, *, spec, lr, weight_decay,
                     noise_key):
        r, q_rows = hashing.qr_rows(spec.n, spec.hash_compression)
        rid, qid = ids % state.r, ids // state.r
        rem = lpt_core.lookup(
            state.remainder, rid, use_kernels=spec.use_kernels, out_dim=spec.d
        )
        quo = lpt_core.lookup(
            state.quotient, qid, use_kernels=spec.use_kernels, out_dim=spec.d
        )
        # Product rule: each sub-table's row cotangent is g * (other factor).
        new_rem = self._sub_apply(
            state.remainder, rid, g_rows * quo, spec=spec, lr=lr,
            weight_decay=weight_decay, key=jax.random.fold_in(noise_key, 0),
            id_space=r,
        )
        new_quo = self._sub_apply(
            state.quotient, qid, g_rows * rem, spec=spec, lr=lr,
            weight_decay=weight_decay, key=jax.random.fold_in(noise_key, 1),
            id_space=q_rows,
        )
        return QRLPTTable(remainder=new_rem, quotient=new_quo, r=state.r)

    def dense_update(self, state, opt, grads, *, spec, lr, weight_decay,
                     noise_key=None, delta_grad=None, batch_rows=None):
        """Rank-invariant formulation: ``grads`` is the dense [n, d] gradient
        of the *virtual* product table; segment-sum it into each sub-table."""
        ids = jnp.arange(spec.n)
        rid, qid = ids % state.r, ids // state.r
        rem = lpt_core.lookup(
            state.remainder, rid, use_kernels=spec.use_kernels, out_dim=spec.d
        )
        quo = lpt_core.lookup(
            state.quotient, qid, use_kernels=spec.use_kernels, out_dim=spec.d
        )
        d_pad = state.remainder.dim - spec.d
        g_rem = jax.ops.segment_sum(
            grads * quo, rid, num_segments=state.remainder.n_rows
        )
        g_quo = jax.ops.segment_sum(
            grads * rem, qid, num_segments=state.quotient.n_rows
        )
        if d_pad:
            g_rem = jnp.pad(g_rem, ((0, 0), (0, d_pad)))
            g_quo = jnp.pad(g_quo, ((0, 0), (0, d_pad)))
        kw = dict(lr=lr, bits=spec.bits, rounding=spec.alpt.rounding,
                  optimizer=spec.row_optimizer, weight_decay=weight_decay,
                  use_kernels=spec.use_kernels)
        new_rem = lpt_core.dense_apply(
            state.remainder, g_rem,
            noise_key=jax.random.fold_in(noise_key, 0), **kw,
        )
        new_quo = lpt_core.dense_apply(
            state.quotient, g_quo,
            noise_key=jax.random.fold_in(noise_key, 1), **kw,
        )
        return QRLPTTable(remainder=new_rem, quotient=new_quo, r=state.r), None, {}

    def storage_spec(self, spec):
        """Two slots — each QR sub-table caches independently; global ids
        map into a sub-table via the same ``% r`` / ``// r`` arithmetic the
        lookups use."""
        r, q_rows = hashing.qr_rows(spec.n, spec.hash_compression)
        return (
            rowstore.CacheSlot(
                name="remainder", rows=r,
                get=lambda s: s.remainder,
                put=lambda s, t: s._replace(remainder=t),
                local_ids=lambda ids: np.asarray(ids) % r,
            ),
            rowstore.CacheSlot(
                name="quotient", rows=q_rows,
                get=lambda s: s.quotient,
                put=lambda s, t: s._replace(quotient=t),
                local_ids=lambda ids: np.asarray(ids) // r,
            ),
        )

    def table_pspec(self, row, col, *, row_optimizer="adam"):
        # Sub-table row counts rarely divide the mesh axes; stay replicated.
        sub = lpt_core.LPTTable(codes=P(), step=P(), mu=P(), nu=P(), count=P())
        return QRLPTTable(remainder=sub, quotient=sub, r=P())

    def serving_state(self, state, spec):
        """int8-resident composition: both sub-tables ship codes + their own
        per-row scale vector (qr_alpt *learns* both; serving honors each)."""
        r, q_rows = hashing.qr_rows(spec.n, spec.hash_compression)

        def sub(table, live_rows):
            return serving_tbl.QuantTable(
                codes=table.codes, step=table.step, n=live_rows, d=spec.d,
                use_kernels=spec.use_kernels,
            )

        # The modulus comes from the spec (qr_rows is deterministic), not
        # int(state.r): serving templates build this under jax.eval_shape,
        # where the state is abstract.
        return serving_tbl.QRQuantTable(
            remainder=sub(state.remainder, r),
            quotient=sub(state.quotient, q_rows),
            r=r, n=spec.n, d=spec.d,
        )


@register("qr_alpt")
class QRALPTMethod(QRLPTMethod):
    """qr_lpt with ALPT's learned step size on BOTH sub-tables.

    The ROADMAP follow-up ("ALPT-ize qr_lpt"): each sub-table keeps its own
    per-row Delta and learns it via the LSQ-style second forward (paper
    Algorithm 1 line 4) evaluated *through the composed product table*, so
    the two scale vectors co-adapt — d(loss)/d(Delta_rem) sees the quotient
    factor and vice versa, exactly like the weight gradients do.  The weight
    sub-step is qr_lpt's product-rule update unchanged; serving inherits the
    per-sub-table-scale :class:`~repro.serving.table.QRQuantTable` export.
    """

    has_learned_step = True

    @staticmethod
    def _acfg(spec, weight_decay) -> alpt_core.ALPTConfig:
        return spec.alpt._replace(
            weight_decay=weight_decay, optimizer=spec.row_optimizer,
            use_kernels=spec.use_kernels,
        )

    def _delta_writeback(self, table, uniq, w_new, step_b, g_step, *, cfg,
                         noise_key):
        """Algorithm 1 line 5 for one sub-table: Delta update + SR
        re-quantize of the already-float-updated unique rows (mirrors
        ``alpt_core.alpt_step``'s tail).  ``noise_key`` must be a key
        derived for this draw alone — the caller folds, so the key flow is
        auditable at the call site (rng-key-discipline)."""
        new_step_b = step_b - cfg.step_lr * (
            g_step + cfg.step_weight_decay * step_b
        )
        new_step_b = jnp.maximum(new_step_b, 1e-8)
        noise = quant.sr_noise(noise_key, w_new.shape)
        if cfg.use_kernels and cfg.rounding == "sr":
            codes_rows = kernel_ops.sr_round(w_new, new_step_b, noise, cfg.bits)
        else:
            if cfg.use_kernels:
                kernel_ops.note_fallback("sr_round", w_new.shape, "dr rounding")
            codes_rows = quant.quantize_codes(
                w_new, new_step_b, cfg.bits, cfg.rounding, noise
            )
        return table._replace(
            codes=rowstore.set_rows(table.codes, uniq, codes_rows, mode="drop"),
            step=table.step.at[uniq].set(new_step_b, mode="drop"),
        )

    def fused_row_step(self, state, ids, *, spec, loss_from_rows, dense_params,
                       dense_opt, update_dense, lr, weight_decay, noise_key):
        cfg = self._acfg(spec, weight_decay)
        r, q_rows = hashing.qr_rows(spec.n, spec.hash_compression)
        rid, qid = ids % state.r, ids // state.r
        rem = lpt_core.lookup(
            state.remainder, rid, use_kernels=spec.use_kernels, out_dim=spec.d
        )
        quo = lpt_core.lookup(
            state.quotient, qid, use_kernels=spec.use_kernels, out_dim=spec.d
        )

        # Step 1 (weights): one joint backward, product-rule row cotangents,
        # each sub-table's sparse update keeps its updated float rows around
        # for the Delta sub-step.
        # Fenced (see repro.core.fence): the joint backward must compile the
        # same whatever storage backs the two sub-tables.
        tick = ids.reshape(-1)[0]
        loss, (g_rows, g_dense) = fence.fence_call(
            jax.value_and_grad(loss_from_rows, (0, 1)),
            (rem * quo, dense_params),
            tick=tick,
        )
        new_dense, new_opt = update_dense(g_dense, dense_opt, dense_params)
        k_rem = jax.random.fold_in(noise_key, 0)
        k_quo = jax.random.fold_in(noise_key, 1)
        kw = dict(lr=lr, bits=spec.bits, rounding=spec.alpt.rounding,
                  optimizer=spec.row_optimizer, weight_decay=weight_decay,
                  return_updated_rows=True, use_kernels=spec.use_kernels)
        rem1, (uniq_r, w_new_r) = lpt_core.sparse_apply(
            state.remainder, rid, g_rows * quo, noise_key=k_rem, id_space=r,
            **kw,
        )
        quo1, (uniq_q, w_new_q) = lpt_core.sparse_apply(
            state.quotient, qid, g_rows * rem, noise_key=k_quo,
            id_space=q_rows, **kw,
        )

        # Step 2 (Delta, Algorithm 1 line 4): both step vectors jointly, at
        # the UPDATED dense params, through the fake-quantized product of the
        # updated sub-table rows.
        d = state.remainder.dim
        step_r = jnp.take(
            state.remainder.step, jnp.minimum(uniq_r, state.remainder.n_rows - 1)
        )
        step_q = jnp.take(
            state.quotient.step, jnp.minimum(uniq_q, state.quotient.n_rows - 1)
        )
        inv_r = lpt_core.dedup_ids(rid, r)[1]
        inv_q = lpt_core.dedup_ids(qid, q_rows)[1]
        gscale = alpt_core.grad_scale_factor(
            cfg, batch_rows=int(ids.size), dim=spec.d
        )

        def loss_wrt_steps(steps):
            s_r, s_q = steps
            rq = quant.fake_quant_lsq(
                jax.lax.stop_gradient(w_new_r), s_r, cfg.bits, gscale
            )
            qq = quant.fake_quant_lsq(
                jax.lax.stop_gradient(w_new_q), s_q, cfg.bits, gscale
            )
            occ = (
                jnp.take(rq, inv_r, axis=0) * jnp.take(qq, inv_q, axis=0)
            ).reshape(ids.shape + (d,))
            if spec.d != d:
                occ = occ[..., : spec.d]
            return loss_from_rows(occ, new_dense)

        g_sr, g_sq = fence.fence_call(
            jax.grad(loss_wrt_steps), ((step_r, step_q),), tick=tick
        )
        # Same keys as before the rng-key-discipline refactor: the fold that
        # used to live inside _delta_writeback now happens here, so each
        # k_rem/k_quo visibly feeds one draw (sparse_apply) and one derived
        # subkey (the Delta writeback) — bitwise-identical key material.
        new_rem = self._delta_writeback(
            rem1, uniq_r, w_new_r, step_r, g_sr, cfg=cfg,
            noise_key=jax.random.fold_in(k_rem, 1),
        )
        new_quo = self._delta_writeback(
            quo1, uniq_q, w_new_q, step_q, g_sq, cfg=cfg,
            noise_key=jax.random.fold_in(k_quo, 1),
        )
        aux = {
            "step_grad_norm": jnp.sqrt(
                jnp.sum(jnp.square(g_sr)) + jnp.sum(jnp.square(g_sq))
            ),
            "mean_step": 0.5 * (jnp.mean(new_rem.step) + jnp.mean(new_quo.step)),
        }
        return (
            QRLPTTable(remainder=new_rem, quotient=new_quo, r=state.r),
            new_dense, new_opt, {"loss": loss, **aux},
        )

    def dense_update(self, state, opt, grads, *, spec, lr, weight_decay,
                     noise_key=None, delta_grad=None, batch_rows=None):
        """Rank-invariant formulation: segment-summed sub-table gradients,
        then the joint two-sub-table Delta sub-step (``delta_grad`` receives
        pytrees of both sub-tables' updated rows / step vectors)."""
        cfg = self._acfg(spec, weight_decay)
        r, q_rows = hashing.qr_rows(spec.n, spec.hash_compression)
        ids = jnp.arange(spec.n)
        rid, qid = ids % state.r, ids // state.r
        rem = lpt_core.lookup(
            state.remainder, rid, use_kernels=spec.use_kernels, out_dim=spec.d
        )
        quo = lpt_core.lookup(
            state.quotient, qid, use_kernels=spec.use_kernels, out_dim=spec.d
        )
        d_pad = state.remainder.dim - spec.d
        g_rem = jax.ops.segment_sum(
            grads * quo, rid, num_segments=state.remainder.n_rows
        )
        g_quo = jax.ops.segment_sum(
            grads * rem, qid, num_segments=state.quotient.n_rows
        )
        if d_pad:
            g_rem = jnp.pad(g_rem, ((0, 0), (0, d_pad)))
            g_quo = jnp.pad(g_quo, ((0, 0), (0, d_pad)))
        upd_r = alpt_core.dense_weight_update(state.remainder, g_rem, cfg=cfg, lr=lr)
        upd_q = alpt_core.dense_weight_update(state.quotient, g_quo, cfg=cfg, lr=lr)
        gscale = alpt_core.grad_scale_factor(
            cfg, batch_rows=int(batch_rows), dim=spec.d
        )
        # Algorithm 1 line 4 at the caller's UPDATED dense params; live
        # geometry only (pad rows/cols never looked up), gradients padded back.
        g_sr, g_sq = delta_grad(
            (upd_r.w_new[:r, : spec.d], upd_q.w_new[:q_rows, : spec.d]),
            (state.remainder.step[:r], state.quotient.step[:q_rows]),
            gscale,
        )
        if g_sr.shape != state.remainder.step.shape:
            g_sr = jnp.pad(g_sr, (0, state.remainder.step.shape[0] - g_sr.shape[0]))
        if g_sq.shape != state.quotient.step.shape:
            g_sq = jnp.pad(g_sq, (0, state.quotient.step.shape[0] - g_sq.shape[0]))
        new_rem = alpt_core.dense_finish(
            state.remainder, upd_r, g_sr, cfg=cfg,
            noise_key=jax.random.fold_in(noise_key, 0),
        )
        new_quo = alpt_core.dense_finish(
            state.quotient, upd_q, g_sq, cfg=cfg,
            noise_key=jax.random.fold_in(noise_key, 1),
        )
        aux = {
            "step_grad_norm": jnp.sqrt(
                jnp.sum(jnp.square(g_sr)) + jnp.sum(jnp.square(g_sq))
            ),
            "mean_step": 0.5 * (jnp.mean(new_rem.step) + jnp.mean(new_quo.step)),
        }
        return QRLPTTable(remainder=new_rem, quotient=new_quo, r=state.r), None, aux

    def dense_delta_grad(self, w_new, step_vec, loss_fn_q, *, spec,
                         weight_decay, gscale):
        """Joint Delta gradient through the composed table: ``w_new`` /
        ``step_vec`` are (remainder, quotient) pytrees; the fake-quantized
        product is what ``loss_fn_q`` scores (Eq. 6/7 routes each gradient to
        its own scale vector)."""
        cfg = self._acfg(spec, weight_decay)
        r, _ = hashing.qr_rows(spec.n, spec.hash_compression)
        w_r, w_q = w_new
        ids = jnp.arange(spec.n)
        rid, qid = ids % r, ids // r

        def loss_wrt_steps(steps):
            s_r, s_q = steps
            rq = quant.fake_quant_lsq(
                jax.lax.stop_gradient(w_r), s_r, cfg.bits, gscale
            )
            qq = quant.fake_quant_lsq(
                jax.lax.stop_gradient(w_q), s_q, cfg.bits, gscale
            )
            return loss_fn_q(jnp.take(rq, rid, axis=0) * jnp.take(qq, qid, axis=0))

        return jax.grad(loss_wrt_steps)((step_vec[0], step_vec[1]))
