"""Full-precision fp32 table — the paper's accuracy reference (Table 1 row 1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.context import hint
from repro.methods.base import EmbeddingMethod, register


@register("fp")
class FPMethod(EmbeddingMethod):
    def init(self, key, spec):
        return (
            jax.random.normal(key, (spec.n, spec.d), jnp.float32)
            * spec.init_scale
        )

    def lookup(self, state, ids, spec, grad_scale=1.0):
        return jnp.take(state, ids, axis=0)

    def trainable_params(self, state, spec):
        return state

    def with_params(self, state, params, spec):
        return params

    def dense_table_from(self, state, params, spec):
        return params  # the params ARE the table

    def hint_dense_params(self, params):
        return hint(params, "embed_table")

    def memory_bytes(self, state, spec, *, training):
        return spec.n * spec.d * 4

    def table_pspec(self, row, col, *, row_optimizer="adam"):
        return P(row, col)
