"""QR compositional-embedding baseline (Shi et al. 2020; paper §4.1):
remainder/quotient fp32 tables composed by element-wise product."""
from __future__ import annotations

from jax.sharding import PartitionSpec as P

from repro.core import hashing
from repro.methods.base import EmbeddingMethod, register


@register("hash")
class QRHashMethod(EmbeddingMethod):
    def init(self, key, spec):
        return hashing.init_qr(
            key, spec.n, spec.d, compression=spec.hash_compression,
            init_scale=spec.init_scale,
        )

    def lookup(self, state, ids, spec, grad_scale=1.0):
        return hashing.qr_lookup(state, ids)

    def trainable_params(self, state, spec):
        return {"remainder": state.remainder, "quotient": state.quotient}

    def with_params(self, state, params, spec):
        return hashing.QRTable(
            remainder=params["remainder"], quotient=params["quotient"],
            r=state.r,
        )

    def memory_bytes(self, state, spec, *, training):
        return hashing.qr_memory_bytes(state)

    def table_pspec(self, row, col, *, row_optimizer="adam"):
        # Sub-table row counts rarely divide the mesh axes; stay replicated.
        return hashing.QRTable(remainder=P(), quotient=P(), r=P())

    def param_pspec(self, row, col):
        return {"remainder": P(), "quotient": P()}
