"""Embedding-method protocol + registry (see :mod:`repro.methods.base`).

Importing this package registers every built-in method; consumers dispatch
with ``repro.methods.get(name)`` and discover names with ``available()``.
"""
from repro.methods.base import (  # noqa: F401
    EmbeddingMethod,
    EmbeddingSpec,
    IntegerTableMethod,
    available,
    get,
    register,
)

# Importing an implementation module registers its method(s).
from repro.methods import (  # noqa: E402,F401
    alpt,
    fp,
    lpt,
    mixed,
    prune,
    qat,
    qr_hash,
    qr_lpt,
)

__all__ = [
    "EmbeddingMethod",
    "EmbeddingSpec",
    "IntegerTableMethod",
    "available",
    "get",
    "register",
]
