"""ALPT: LPT + learned per-row step size Delta (paper §3.2, Algorithm 1).

Inherits the LPT table/state handling; overrides the train-step pieces with
the two-substep schedule (weight update, then Delta learned via a second
fake-quant forward at the *updated* dense params).  ``spec.use_kernels``
flows into :class:`~repro.core.alpt.ALPTConfig` so both sub-steps run fused:
the weight step through ``ops.sparse_row_update``/``ops.lpt_update`` and the
line-5 requantize-with-learned-Delta through ``ops.sr_round``.

The learned Delta is exactly what serving keeps: ``serving_state`` (inherited
int8-resident export) ships codes + the *learned* per-row scales straight
into the ``repro.serving`` Engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import alpt as alpt_core
from repro.core import fence
from repro.methods.base import register
from repro.methods.lpt import LPTMethod, _pad_grads


@register("alpt")
class ALPTMethod(LPTMethod):
    has_learned_step = True
    # ALPT learns Delta from the LSQ-style init; the clip knob is LPT-only.
    _clip_value_of = staticmethod(lambda spec: None)

    @staticmethod
    def _acfg(spec, weight_decay) -> alpt_core.ALPTConfig:
        # spec.bits is the table's storage width (it sized the code container
        # at init); a stale ALPTConfig.bits default must not write wider
        # codes into a narrower (possibly packed) container.
        return spec.alpt._replace(
            bits=spec.bits, weight_decay=weight_decay,
            optimizer=spec.row_optimizer, use_kernels=spec.use_kernels,
        )

    def fused_row_step(self, state, ids, *, spec, loss_from_rows, dense_params,
                       dense_opt, update_dense, lr, weight_decay, noise_key):
        rows0 = self.lookup(state, ids, spec)

        # Dense update (Algorithm 1 line 3) shares step 1's backward.
        # Fenced (see repro.core.fence): g_dense feeds the persistent dense
        # params, so this backward too must compile independently of the
        # storage graph around it.
        loss, g_dense = fence.fence_call(
            jax.value_and_grad(lambda dp: loss_from_rows(rows0, dp)),
            (dense_params,),
            tick=ids.reshape(-1)[0],
        )
        new_dense, new_opt = update_dense(g_dense, dense_opt, dense_params)
        new_state, loss2, aux = alpt_core.alpt_step(
            state,
            ids,
            lambda rows: loss_from_rows(rows, dense_params),
            cfg=self._acfg(spec, weight_decay),
            lr=lr,
            noise_key=noise_key,
            loss_fn_step2=lambda rows: loss_from_rows(rows, new_dense),
            id_space=spec.n,
            out_dim=spec.d,
        )
        return new_state, new_dense, new_opt, {"loss": loss2, **aux}

    def dense_update(self, state, opt, grads, *, spec, lr, weight_decay,
                     noise_key=None, delta_grad=None, batch_rows=None):
        acfg = self._acfg(spec, weight_decay)
        grads = _pad_grads(grads, state, spec)
        upd = alpt_core.dense_weight_update(state, grads, cfg=acfg, lr=lr)
        gscale = alpt_core.grad_scale_factor(
            acfg, batch_rows=int(batch_rows), dim=spec.d
        )
        # Algorithm 1 line 4 at the caller's UPDATED dense params; the caller
        # sees the live (n, d) table, so padded geometry is sliced away and
        # the resulting Delta gradient zero-padded back (pad rows untouched).
        g_step = delta_grad(
            upd.w_new[: spec.n, : spec.d], state.step[: spec.n], gscale
        )
        if g_step.shape != state.step.shape:
            g_step = jnp.pad(g_step, (0, state.step.shape[0] - g_step.shape[0]))
        new_state = alpt_core.dense_finish(
            state, upd, g_step, cfg=acfg, noise_key=noise_key
        )
        aux = {
            "step_grad_norm": jnp.linalg.norm(g_step),
            "mean_step": jnp.mean(new_state.step),
        }
        return new_state, None, aux

    def dense_delta_grad(self, w_new, step_vec, loss_fn_q, *, spec,
                         weight_decay, gscale):
        return alpt_core.dense_delta_grad(
            w_new, step_vec, loss_fn_q,
            cfg=self._acfg(spec, weight_decay), gscale=gscale,
        )
