"""Distribution layer: mesh sharding policies + compressed collectives.

Three modules, split by concern:

* ``context``     — ambient (mesh, policy) context; models annotate tensors
                    with logical kind names via ``hint(x, kind)`` and the
                    active policy decides the physical ``PartitionSpec``.
* ``sharding``    — ``Policy`` + per-pytree PartitionSpec builders for params,
                    optimizer state, quantized embedding tables, batches and
                    decode caches.
* ``collectives`` — SR-quantized (int8) gradient all-reduce built on
                    ``repro.core.quant`` — the paper's stochastic-rounding
                    quantizer applied to communication.

Importing this package also installs the ``jax.shard_map`` compat adapter so
the explicit expert-parallel dispatch works on older jax, and switches jax to
*partitionable* threefry: with the legacy (non-partitionable) PRNG the random
bits depend on the output sharding, so a mesh-sharded ``init_state`` would not
reproduce the single-device initialization.  Partitionable threefry makes
every ``jax.random`` draw sharding-invariant — the foundation of the
``sharded loss == single-device loss`` contract (tests/test_distribution.py).
"""
import jax as _jax

from repro._compat.jax_shim import ensure_jax_compat as _ensure_jax_compat

_ensure_jax_compat()
_jax.config.update("jax_threefry_partitionable", True)

from repro.dist import collectives, context, sharding  # noqa: E402,F401
