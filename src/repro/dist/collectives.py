"""Compressed cross-replica collectives built on the paper's SR quantizer.

``compressed_psum_local`` is an int8 all-reduce for gradients: every rank
SR-quantizes its local shard against a *shared* step size (a pmax of the
per-rank absmax, so codes are comparable across ranks), the integer codes are
psum'd in int32 (no overflow for <= 2^24 ranks), and the sum is de-quantized
once.  Stochastic rounding keeps the reduction unbiased —
E[Q_sr(g)] = g (quant.round_stochastic) — so compression noise averages out
across ranks instead of accumulating as bias; this is Li et al.'s embedding
quantizer applied to communication, in the spirit of Guan et al.'s 4-bit
embedding tables.

Runs INSIDE ``jax.shard_map`` (it uses named-axis collectives).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import codestore, quant
from repro.kernels import ops as kernel_ops


def _as_2d(x: jax.Array) -> jax.Array:
    """Collapse a gradient leaf to the [rows, lanes] layout the SR kernel
    tiles over (1-D / scalar leaves become a single row)."""
    if x.ndim >= 2:
        return x.reshape(-1, x.shape[-1])
    return x.reshape(1, -1) if x.ndim == 1 else x.reshape(1, 1)


def _sr_codes(grad, step, noise, bits: int, use_kernels: bool) -> jax.Array:
    """SR-quantize ``grad`` against the shared scalar ``step``.

    Kernels-on this is the fused clip+round+pack pass (``ops.sr_round``,
    bitwise-identical to ``quant.quantize_codes``).  Leaves whose 2-D view
    has fewer rows than a sublane (biases, norm scales) are *structurally*
    untileable — no padding knob can fix a (1, L) gradient — so they take
    the jnp path directly rather than being counted as actionable
    fallbacks; genuinely misaligned table-shaped leaves still fall back
    inside the wrapper, counted and logged.
    """
    if not use_kernels:
        return quant.quantize_codes(grad, step, bits, "sr", noise)
    g2 = _as_2d(grad.astype(jnp.float32))
    if g2.shape[0] < kernel_ops.SUBLANE:
        return quant.quantize_codes(grad, step, bits, "sr", noise)
    step_rows = jnp.broadcast_to(step, (g2.shape[0],))
    codes = kernel_ops.sr_round(g2, step_rows, _as_2d(noise), bits)
    return codes.reshape(grad.shape)


def _linear_rank(axis) -> jax.Array:
    """This rank's linear index over (possibly multiple) named axes."""
    axes = axis if isinstance(axis, (tuple, list)) else (axis,)
    rank = jnp.zeros((), jnp.int32)
    for a in axes:
        rank = rank * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    return rank


def compressed_psum_local(
    grad: jax.Array,
    axis,
    key: jax.Array,
    bits: int = 8,
    use_kernels: bool = False,
) -> jax.Array:
    """SR-quantized psum of ``grad`` over the named mesh axis ``axis``.

    Returns the (approximate) sum in float32.  Per-element error is bounded by
    ``n_ranks * step`` with ``step = pmax(|grad|) / (2^{bits-1} - 1)`` — under
    2% relative for int8 — and is mean-zero because each rank folds its rank
    index into ``key`` (decorrelated SR noise).  ``use_kernels`` runs the SR
    quantize through the fused Pallas pass (bitwise-identical either way, so
    the single-device stacked twins hold at every setting).
    """
    _, p = quant.code_bounds(bits)
    # One shared step size per reduction: pmax so every rank scales alike.
    absmax = jax.lax.pmax(jnp.max(jnp.abs(grad.astype(jnp.float32))), axis)
    step = jnp.maximum(absmax / p, jnp.float32(1e-30))
    noise = quant.sr_noise(
        jax.random.fold_in(key, _linear_rank(axis)), grad.shape
    )
    codes = _sr_codes(grad, step, noise, bits, use_kernels)
    if codestore.is_packable(bits):
        total = _packed_psum_codes(codes, axis, bits)
    else:
        total = jax.lax.psum(codes.astype(jnp.int32), axis)
    return total.astype(jnp.float32) * step


def _packed_psum_codes(codes: jax.Array, axis, bits: int) -> jax.Array:
    """Sum sub-byte codes over ``axis`` shipping the *packed* wire format.

    Each rank packs its codes 8//bits per byte, the uint8 payload is
    all-gathered (that's what crosses the wire — ``sync_wire_bytes`` charges
    exactly these bytes), and every rank unpacks the stack and sums in int32.
    Integer addition is associative, so this is bitwise-identical to a direct
    ``psum`` of the codes — the compressed-sync twins contract is unchanged.
    """
    axes = axis if isinstance(axis, (tuple, list)) else (axis,)
    wire = codestore.pack_codes(codes.reshape(1, -1), bits)
    for a in reversed(axes):
        wire = jax.lax.all_gather(wire, a, axis=0, tiled=False)
    for _ in axes[1:]:
        wire = wire.reshape((-1,) + wire.shape[2:])
    stack = codestore.unpack_codes(wire, bits, codes.size)
    total = jnp.sum(stack.astype(jnp.int32), axis=0)
    return total.reshape(codes.shape)


def compressed_pmean_local(
    grad: jax.Array,
    axis,
    key: jax.Array,
    bits: int = 8,
    use_kernels: bool = False,
) -> jax.Array:
    """Mean-reducing variant of :func:`compressed_psum_local`."""
    axes = axis if isinstance(axis, (tuple, list)) else (axis,)
    total = compressed_psum_local(grad, axis, key, bits=bits,
                                  use_kernels=use_kernels)
    size = 1
    for a in axes:
        size = size * jax.lax.axis_size(a)
    return total / jnp.float32(size)


def exact_pmean_local(grad: jax.Array, axis) -> jax.Array:
    """Uncompressed fp32 mean over ``axis`` with a *deterministic* reduction.

    ``lax.pmean`` leaves the cross-replica summation order to the backend's
    all-reduce schedule, so its low-order bits can differ from any
    single-device emulation.  Here every rank all-gathers the shards into a
    rank-ordered stack and applies one ordinary ``jnp.mean`` over the leading
    axis — the identical reduction a single device performs on the same stack
    (:func:`exact_pmean_stacked`).  This is what makes the ``sync_bits=32``
    data-parallel path bitwise-reproducible against the single-device
    microbatched trainer.  Runs inside ``jax.shard_map``.
    """
    axes = axis if isinstance(axis, (tuple, list)) else (axis,)
    stack = grad.astype(jnp.float32)
    for a in reversed(axes):
        stack = jax.lax.all_gather(stack, a, axis=0, tiled=False)
    for _ in axes[1:]:
        stack = stack.reshape((-1,) + stack.shape[2:])
    return jnp.mean(stack, axis=0)


# ---------------------------------------------------------------------------
# Single-device emulations over a stacked rank axis.
#
# These mirror the collectives above *arithmetic-for-arithmetic* on a
# ``[n_ranks, ...]`` stack, so a one-device microbatched trainer reproduces
# the n-device shard_map trainer bit-for-bit:
#   * the compressed path psums int32 codes — integer addition is associative,
#     so any summation order gives the same total, and pmax == jnp.max;
#   * the exact path reduces the same rank-ordered stack with the same
#     ``jnp.mean``.
# tests/test_data_parallel.py holds this contract at 32, 8 and 4 bits.
# ---------------------------------------------------------------------------


def exact_pmean_stacked(grad_stack: jax.Array) -> jax.Array:
    """Single-device twin of :func:`exact_pmean_local` on a [n, ...] stack."""
    return jnp.mean(grad_stack.astype(jnp.float32), axis=0)


def compressed_psum_stacked(
    grad_stack: jax.Array,
    key: jax.Array,
    bits: int = 8,
) -> jax.Array:
    """Single-device twin of :func:`compressed_psum_local`.

    ``grad_stack[r]`` plays the role of rank ``r``'s local shard; the SR noise
    is keyed by ``fold_in(key, r)`` exactly as ``_linear_rank`` does on the
    mesh, and the int32 code sum is order-independent by construction.
    """
    _, p = quant.code_bounds(bits)
    n = grad_stack.shape[0]
    absmax = jnp.max(jnp.abs(grad_stack.astype(jnp.float32)))
    step = jnp.maximum(absmax / p, jnp.float32(1e-30))
    ranks = jnp.arange(n, dtype=jnp.int32)
    keys = jax.vmap(lambda r: jax.random.fold_in(key, r))(ranks)
    noise = jax.vmap(lambda k, g: quant.sr_noise(k, g.shape))(keys, grad_stack)
    codes = quant.quantize_codes(grad_stack, step, bits, "sr", noise)
    total = jnp.sum(codes.astype(jnp.int32), axis=0)
    return total.astype(jnp.float32) * step


def compressed_pmean_stacked(
    grad_stack: jax.Array,
    key: jax.Array,
    bits: int = 8,
) -> jax.Array:
    """Single-device twin of :func:`compressed_pmean_local`."""
    total = compressed_psum_stacked(grad_stack, key, bits=bits)
    return total / jnp.float32(grad_stack.shape[0])


# ---------------------------------------------------------------------------
# Wire-byte accounting.
# ---------------------------------------------------------------------------


def sync_wire_bytes(grads, bits: int) -> int:
    """Per-rank gradient payload (bytes) put on the wire for one sync.

    ``grads`` is a pytree of arrays or ``ShapeDtypeStruct``s.  The fp32
    baseline ships 4 bytes per element; the compressed path ships the codes
    in their actual wire format — sub-byte widths (bits in {2, 4}) travel
    packed by ``codestore.pack_codes`` at ``8 // bits`` codes per byte
    (that's the payload ``_packed_psum_codes`` all-gathers), every other
    integer width ships one byte per code — plus one fp32 step scalar per
    tensor for the shared-absmax (pmax) exchange.  Ring-schedule constant
    factors (2(n-1)/n hops) multiply both paths equally and cancel in the
    ratio, so they are left out.
    """
    if not 2 <= bits <= 8 and bits != 32:
        raise ValueError(f"sync_bits must be 32 or in [2, 8], got {bits}")
    total = 0
    for leaf in jax.tree.leaves(grads):
        size = 1
        for dim in leaf.shape:
            size *= int(dim)
        if bits == 32:
            total += size * 4
        elif codestore.is_packable(bits):
            # Packed codes round up to whole bytes per tensor.
            total += -(-size // codestore.codes_per_byte(bits)) + 4
        else:
            # Non-byte-divisor widths ship one byte per code.
            total += size + 4
    return total


def sync_compression_ratio(grads, bits: int) -> float:
    """fp32 wire bytes / compressed wire bytes for one gradient sync."""
    return sync_wire_bytes(grads, 32) / max(sync_wire_bytes(grads, bits), 1)
