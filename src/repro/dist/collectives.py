"""Compressed cross-replica collectives built on the paper's SR quantizer.

``compressed_psum_local`` is an int8 all-reduce for gradients: every rank
SR-quantizes its local shard against a *shared* step size (a pmax of the
per-rank absmax, so codes are comparable across ranks), the integer codes are
psum'd in int32 (no overflow for <= 2^24 ranks), and the sum is de-quantized
once.  Stochastic rounding keeps the reduction unbiased —
E[Q_sr(g)] = g (quant.round_stochastic) — so compression noise averages out
across ranks instead of accumulating as bias; this is Li et al.'s embedding
quantizer applied to communication, in the spirit of Guan et al.'s 4-bit
embedding tables.

Runs INSIDE ``jax.shard_map`` (it uses named-axis collectives).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant


def _linear_rank(axis) -> jax.Array:
    """This rank's linear index over (possibly multiple) named axes."""
    axes = axis if isinstance(axis, (tuple, list)) else (axis,)
    rank = jnp.zeros((), jnp.int32)
    for a in axes:
        rank = rank * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    return rank


def compressed_psum_local(
    grad: jax.Array,
    axis,
    key: jax.Array,
    bits: int = 8,
) -> jax.Array:
    """SR-quantized psum of ``grad`` over the named mesh axis ``axis``.

    Returns the (approximate) sum in float32.  Per-element error is bounded by
    ``n_ranks * step`` with ``step = pmax(|grad|) / (2^{bits-1} - 1)`` — under
    2% relative for int8 — and is mean-zero because each rank folds its rank
    index into ``key`` (decorrelated SR noise).
    """
    _, p = quant.code_bounds(bits)
    # One shared step size per reduction: pmax so every rank scales alike.
    absmax = jax.lax.pmax(jnp.max(jnp.abs(grad.astype(jnp.float32))), axis)
    step = jnp.maximum(absmax / p, jnp.float32(1e-30))
    noise = quant.sr_noise(
        jax.random.fold_in(key, _linear_rank(axis)), grad.shape
    )
    codes = quant.quantize_codes(grad, step, bits, "sr", noise)
    total = jax.lax.psum(codes.astype(jnp.int32), axis)
    return total.astype(jnp.float32) * step


def compressed_pmean_local(
    grad: jax.Array,
    axis,
    key: jax.Array,
    bits: int = 8,
) -> jax.Array:
    """Mean-reducing variant of :func:`compressed_psum_local`."""
    axes = axis if isinstance(axis, (tuple, list)) else (axis,)
    total = compressed_psum_local(grad, axis, key, bits=bits)
    size = 1
    for a in axes:
        size = size * jax.lax.axis_size(a)
    return total / jnp.float32(size)
