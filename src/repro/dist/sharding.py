"""Sharding policies and PartitionSpec builders for every trainer pytree.

A ``Policy`` names the parallelism style (tp / fsdp_tp / dp / *_sp / *_ep) and
carries the mesh-shape facts the spec builders need.  Builders return
PartitionSpec pytrees that mirror the runtime pytrees exactly (params,
optimizer state, quantized LPT/ALPT tables, batches, decode caches), with
divisibility-guarded placement: an axis that does not evenly divide a
dimension is dropped rather than erroring, so degenerate shapes (hubert's
vocab=504 head on a 16-way model axis, odd head counts, tiny smoke configs)
degrade to replication instead of failing to lower.

Layout rules (DESIGN.md §5, Megatron-style):

* attention/MLP in-projections are column-parallel (output dim over 'model'),
  out-projections row-parallel (input dim over 'model');
* MoE expert stacks shard the expert dim over 'model' (expert parallelism);
* the quantized vocab table (codes, Delta, row-Adam slots) shards vocab over
  'model', falling back to the feature dim when vocab doesn't divide;
* fsdp_* additionally shards the non-model matrix dim over the data axes;
* dp replicates parameters and uses the model axis as extra data parallelism,
  while still sharding optimizer moments over 'model' (ZeRO-1-style).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.optim.adam import OptState

# ------------------------------------------------------------------- policy


@dataclasses.dataclass(frozen=True)
class Policy:
    """Parallelism policy: axis names + shape facts + feature flags."""

    name: str
    data_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    model_size: int = 1
    # Total data-parallel way-count (product over data_axes); None = unknown,
    # which disables fsdp placement (it can't be divisibility-checked).
    data_size: int | None = None
    fsdp: bool = False
    seq_parallel: bool = False
    ep: bool = False  # explicit shard_map expert-parallel MoE dispatch
    pure_dp: bool = False  # model axis reused as extra data parallelism

    @property
    def dp_spec(self):
        """PartitionSpec entry for a batch dimension."""
        axes = tuple(self.data_axes)
        if self.pure_dp:
            axes = axes + (self.model_axis,)
        return axes[0] if len(axes) == 1 else axes


def policy_from_name(
    name: str,
    *,
    data_axes: tuple[str, ...] = ("data",),
    model_size: int = 1,
    data_size: int | None = None,
) -> Policy:
    parts = name.split("_")
    return Policy(
        name=name,
        data_axes=data_axes,
        model_size=model_size,
        data_size=data_size,
        fsdp="fsdp" in parts,
        seq_parallel="sp" in parts,
        ep="ep" in parts,
        pure_dp=name == "dp",
    )


# MoE archs get explicit EP dispatch (EXPERIMENTS.md §Perf: GSPMD-only EP
# triggers involuntary remat); other multi-billion-param archs get fsdp_tp.
_EP_ARCHS = frozenset({"mixtral-8x7b", "deepseek-moe-16b", "jamba-v0.1-52b"})
_FSDP_ARCHS = frozenset({"deepseek-67b", "qwen2-vl-7b"})


def default_policy(
    arch: str,
    *,
    multi_pod: bool = False,
    model_size: int = 16,
    override: str | None = None,
    data_size: int | None = None,
) -> Policy:
    name = override
    if name is None:
        if arch in _EP_ARCHS:
            name = "fsdp_tp_ep"
        elif arch in _FSDP_ARCHS:
            name = "fsdp_tp"
        else:
            name = "tp"
    data_axes = ("pod", "data") if multi_pod else ("data",)
    if data_size is None:
        # Production meshes are 16-way data per pod (launch/mesh.py).
        data_size = 32 if multi_pod else 16
    return policy_from_name(
        name, data_axes=data_axes, model_size=model_size, data_size=data_size
    )


# ------------------------------------------------------------- leaf placing


def _leaf_spec(shape, placements: dict[int, str], pol: Policy) -> P:
    """Build a spec from wanted ``{dim (may be negative): 'model'|'fsdp'}``.

    Drops any placement whose axis size doesn't divide the dimension (or is
    unknown / 1).
    """
    entries: list[Any] = [None] * len(shape)
    for idx, which in placements.items():
        i = idx % len(shape) if shape else 0
        if which == "model":
            names: Any = pol.model_axis
            size = pol.model_size
        else:  # fsdp over the data axes
            if not pol.fsdp or not pol.data_size:
                continue
            axes = tuple(pol.data_axes)
            names = axes[0] if len(axes) == 1 else axes
            size = pol.data_size
        if size and size > 1 and shape[i] % size == 0:
            entries[i] = names
    return P(*entries)


# Column-parallel (output dim over 'model', optional fsdp on the input dim).
_COL_PARALLEL = frozenset({"wq", "wk", "wv", "w_gate", "w_up", "w_in",
                           "wz", "wx", "wdt"})
# Row-parallel (input dim over 'model', optional fsdp on the output dim).
_ROW_PARALLEL = frozenset({"wo", "w_down", "w_out", "out_proj"})
# Vectors / conv stacks living in the model-sharded inner dimension.
_MODEL_LAST = frozenset({"bq", "bk", "bv", "b_in", "conv_x", "conv_bx",
                         "norm_w", "dt_bias", "A_log", "D"})


def _param_placements(path_names: tuple[str, ...]) -> dict[int, str]:
    name = path_names[-1]
    if "moe" in path_names:
        if "shared" in path_names or name == "router":
            return {}
        if name in ("w_gate", "w_up", "w_down"):
            return {-3: "model"}  # [..., E, d, f] / [..., E, f, d]: expert dim
        return {}
    if name in _COL_PARALLEL:
        return {-1: "model", -2: "fsdp"}
    if name in _ROW_PARALLEL:
        return {-2: "model", -1: "fsdp"}
    if name in _MODEL_LAST:
        return {-1: "model"}
    return {}  # norms, router, B/C streams, biases on d_model


def _head_spec(shape, pol: Policy) -> P:
    """Untied LM head [V, d]: vocab over 'model'; replicate the vocab dim and
    shard d instead when V doesn't divide (hubert's 504-way head on 16)."""
    v, d = shape
    m = pol.model_axis
    if pol.model_size > 1 and v % pol.model_size == 0:
        return P(m, None)
    if pol.model_size > 1 and d % pol.model_size == 0:
        return P(None, m)
    return P(None, None)


def _key_name(entry) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


def _param_spec_tree(params_shapes, pol: Policy):
    def one(path, leaf):
        names = tuple(_key_name(e) for e in path)
        if names and names[-1] == "head":
            return _head_spec(leaf.shape, pol)
        if pol.pure_dp:
            return P()
        return _leaf_spec(leaf.shape, _param_placements(names), pol)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


# --------------------------------------------------------------- public API


def _eval_param_shapes(cfg):
    from repro.models import transformer as tfm

    return jax.eval_shape(
        functools.partial(tfm.init_params, cfg=cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )


def param_pspecs(cfg, pol: Policy, param_shapes=None):
    """PartitionSpec tree mirroring ``transformer.init_params(cfg)``."""
    if param_shapes is None:
        param_shapes = _eval_param_shapes(cfg)
    return _param_spec_tree(param_shapes, pol)


def _table_axes(cfg, pol: Policy):
    """(row_entry, col_entry) for the [V, d] embedding table family."""
    m = pol.model_axis
    if pol.model_size > 1 and cfg.vocab_size % pol.model_size == 0:
        return m, None
    if pol.model_size > 1 and cfg.d_model % pol.model_size == 0:
        return None, m
    return None, None


def table_pspecs(cfg, pol: Policy, row_optimizer: str = "adam"):
    """Specs for the embedding table state, mirrored from the registered
    method's ``table_pspec`` (e.g. ``LPTTable`` codes + Delta + row-optimizer
    slots for integer tables, a plain [V, d] spec for fp)."""
    from repro import methods  # local import: methods.base imports dist.context

    row, col = _table_axes(cfg, pol)
    return methods.get(cfg.embedding_method).table_pspec(
        row, col, row_optimizer=row_optimizer
    )


def state_pspecs(cfg, pol: Policy, tcfg, state_shapes=None):
    """Spec tree mirroring ``lm_trainer.LMTrainState`` exactly."""
    from repro.training import lm_trainer

    if state_shapes is None:
        state_shapes = jax.eval_shape(
            functools.partial(lm_trainer.init_state, cfg=cfg, tcfg=tcfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
    params_spec = _param_spec_tree(state_shapes.params, pol)
    # Optimizer moments mirror the params; under pure dp they still shard over
    # the model axis (ZeRO-1-style optimizer-state sharding).
    opt_pol = dataclasses.replace(pol, pure_dp=False) if pol.pure_dp else pol
    moment_spec = _param_spec_tree(state_shapes.params, opt_pol)
    opt_spec = OptState(step=P(), mu=moment_spec, nu=moment_spec)
    table_spec = table_pspecs(cfg, pol, tcfg.row_optimizer)
    from repro import methods  # local import: methods.base imports dist.context

    method = methods.get(cfg.embedding_method)
    param_spec = method.param_pspec(*_table_axes(cfg, pol))
    if param_spec is None:  # integer tables carry no float-leaf Adam state
        table_opt_spec = None
    else:
        table_opt_spec = OptState(step=P(), mu=param_spec, nu=param_spec)
    return lm_trainer.LMTrainState(
        params=params_spec,
        opt=opt_spec,
        table=table_spec,
        table_opt=table_opt_spec,
        step=P(),
        rng=P(),
    )


def mesh_axes_size(mesh, axes) -> int:
    shape = dict(mesh.shape)
    size = 1
    for a in axes:
        size *= int(shape.get(a, 1))
    return size


def _dp_or_none(pol: Policy, batch_dim: int, mesh):
    """The data-parallel spec entry for a concrete batch dim on ``mesh``,
    or None when the dp way-count doesn't divide it."""
    spec = pol.dp_spec
    axes = spec if isinstance(spec, tuple) else (spec,)
    size = mesh_axes_size(mesh, axes)
    if size <= 1 or batch_dim % size:
        return None
    return spec


def model_or_none(pol: Policy, dim: int, mesh):
    """The model-axis spec entry for ``dim`` on ``mesh``, or None when the
    axis is absent/trivial or doesn't divide it."""
    size = mesh_axes_size(mesh, (pol.model_axis,))
    if size <= 1 or dim % size:
        return None
    return pol.model_axis


def batch_pspecs(batch_shapes, cfg, pol: Policy, mesh):
    """Specs for a model-input batch dict: batch dim over the data axes.

    ``positions`` may be [3, B, T] (M-RoPE streams lead) — its batch dim is
    axis 1; every other input leads with batch.
    """
    specs = {}
    for name, sds in batch_shapes.items():
        shape = sds.shape
        if name == "positions" and len(shape) == 3:
            specs[name] = P(None, _dp_or_none(pol, shape[1], mesh), None)
        else:
            dp = _dp_or_none(pol, shape[0], mesh) if shape else None
            specs[name] = P(dp, *([None] * (len(shape) - 1)))
    return specs


def cache_pspecs(cfg, pol: Policy, batch: int, mesh):
    """Specs mirroring ``transformer.init_cache``: one entry per period
    position, each stacked [n_groups, batch, ...]."""
    dp = _dp_or_none(pol, batch, mesh)

    def model_if(dim: int):
        if pol.model_size > 1 and dim % pol.model_size == 0:
            return pol.model_axis
        return None

    _, kv = cfg.padded_heads
    caches = []
    for pos in range(cfg.period):
        if cfg.layer_type(pos) == "attn":
            kv_spec = P(None, dp, None, model_if(kv), None)
            caches.append({"k": kv_spec, "v": kv_spec})
        else:
            s = cfg.ssm
            caches.append({
                "conv_x": P(None, dp, None, model_if(s.d_inner)),
                "conv_B": P(None, dp, None, None),
                "conv_C": P(None, dp, None, None),
                "ssm": P(None, dp, model_if(s.n_heads), None, None),
            })
    return caches


def to_named(spec_tree, mesh):
    """Map a PartitionSpec tree to NamedShardings on ``mesh``."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
