"""Ambient distribution context: logical-name sharding constraints.

Models never mention mesh axes.  They call ``hint(x, kind)`` with a *logical*
kind (``q_heads``, ``carry``, ``logits``, ...) and the active
``(mesh, policy)`` context — installed by ``use(mesh, policy)`` around the
jit trace — decides the physical ``PartitionSpec``.  With no active context
``hint`` is the identity, so the exact same model code runs single-device.

Kinds and their canonical layouts:

  q_heads     [B, T, H, hd]   heads over 'model', batch over data axes
  kv_heads    [B, T, KV, hd]  (same, KV may be smaller than H under GQA)
  carry       [B, T, d]       scan carry; T over 'model' iff seq-parallel
  activation  [B, T, d]       block input/output
  head_weight [V, d]          vocab over 'model' (fallback: d over 'model')
  embed_table [V, d]          de-quantized LPT/ALPT table + its gradient
  logits      [B, C, V]       vocab over 'model', batch over data axes
  moe_buf     [B, E, C, d]    experts over 'model' (GSPMD MoE dispatch)

Every placement is divisibility-guarded: an axis that does not evenly divide
the corresponding dimension is dropped (e.g. hubert's vocab=504 head on a
16-way model axis stays replicated) — degenerate shapes degrade to coarser
sharding instead of erroring.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: Any  # jax.sharding.Mesh
    policy: Any  # repro.dist.sharding.Policy


_STACK: list[DistContext] = []


@contextlib.contextmanager
def use(mesh, policy):
    """Install ``(mesh, policy)`` as the ambient distribution context.

    Wrap the jit *trace* (the ``jax.jit(...)`` call), not just execution —
    ``hint`` reads the stack at trace time.  Contexts nest; the innermost
    wins.
    """
    _STACK.append(DistContext(mesh=mesh, policy=policy))
    try:
        yield _STACK[-1]
    finally:
        _STACK.pop()


def current() -> DistContext | None:
    return _STACK[-1] if _STACK else None


def moe_ep_context() -> DistContext | None:
    """The active context iff the policy requests explicit expert-parallel
    dispatch (shard_map all-to-all instead of GSPMD MoE)."""
    ctx = current()
    if ctx is None or not getattr(ctx.policy, "ep", False):
        return None
    return ctx


# --------------------------------------------------------------------- hints

# One divisibility guard shared with the pspec builders, so hint() and
# batch/state specs can never disagree about what fits an axis.
from repro.dist.sharding import _dp_or_none as _dp_entry  # noqa: E402
from repro.dist.sharding import model_or_none as _model_entry  # noqa: E402


def _spec_for(kind: str, shape, pol, mesh) -> P | None:
    nd = len(shape)
    if kind in ("q_heads", "kv_heads"):
        if nd != 4:
            return None
        return P(_dp_entry(pol, shape[0], mesh), None,
                 _model_entry(pol, shape[2], mesh), None)
    if kind in ("carry", "activation"):
        if nd != 3:
            return None
        seq = _model_entry(pol, shape[1], mesh) if pol.seq_parallel else None
        return P(_dp_entry(pol, shape[0], mesh), seq, None)
    if kind in ("head_weight", "embed_table"):
        if nd != 2:
            return None
        vocab = _model_entry(pol, shape[0], mesh)
        if vocab is not None:
            return P(vocab, None)
        return P(None, _model_entry(pol, shape[1], mesh))
    if kind == "logits":
        if nd < 2:
            return None
        mid = [None] * (nd - 2)
        return P(_dp_entry(pol, shape[0], mesh), *mid,
                 _model_entry(pol, shape[-1], mesh))
    if kind == "moe_buf":
        if nd != 4:
            return None
        return P(_dp_entry(pol, shape[0], mesh),
                 _model_entry(pol, shape[1], mesh), None, None)
    raise ValueError(f"unknown sharding hint kind {kind!r}")


def hint(x, kind: str):
    """Constrain ``x`` to the active policy's layout for ``kind``.

    Identity when no context is active or no mesh axis fits the shape.
    """
    ctx = current()
    if ctx is None:
        return x
    spec = _spec_for(kind, x.shape, ctx.policy, ctx.mesh)
    if spec is None or all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
