"""Evaluation metrics for CTR prediction: AUC and Logloss (paper §4.1)."""
from __future__ import annotations

import numpy as np


def auc(labels: np.ndarray, scores: np.ndarray) -> float:
    """Mann-Whitney AUC with tie handling (average ranks)."""
    labels = np.asarray(labels).astype(np.float64).ravel()
    scores = np.asarray(scores).astype(np.float64).ravel()
    pos = labels > 0.5
    n_pos = int(pos.sum())
    n_neg = labels.size - n_pos
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    sorted_scores = scores[order]
    # Average ranks over ties.
    i = 0
    while i < len(sorted_scores):
        j = i
        while j + 1 < len(sorted_scores) and sorted_scores[j + 1] == sorted_scores[i]:
            j += 1
        ranks[order[i : j + 1]] = 0.5 * (i + j) + 1.0
        i = j + 1
    sum_pos_ranks = ranks[pos].sum()
    return float((sum_pos_ranks - n_pos * (n_pos + 1) / 2.0) / (n_pos * n_neg))


def logloss(labels: np.ndarray, probs: np.ndarray, eps: float = 1e-7) -> float:
    labels = np.asarray(labels).astype(np.float64).ravel()
    p = np.clip(np.asarray(probs).astype(np.float64).ravel(), eps, 1.0 - eps)
    return float(-np.mean(labels * np.log(p) + (1.0 - labels) * np.log(1.0 - p)))
