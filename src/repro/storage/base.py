"""`RowStore`: the one row-access protocol every code container implements.

Before this module, the row-access surface was scattered: `core/codestore.py`
carried "either-type" helpers that dispatched on `isinstance(x, CodeStore)`,
and `serving/table.py` carried its own isinstance chains for row reads.
Every new container type (the tiered hot-row cache, the host-memory cold
tier) would have grown every one of those chains.

Now there is exactly one boundary: a container either *is* a raw
``jax.Array``/numpy array (the historical int8 codes layout) or it implements
the :class:`RowStore` protocol — ``unpack`` / ``take`` / ``set_rows`` /
``where_rows`` / ``resident_bytes``.  The module-level functions below are
the only dispatch sites; call sites never type-switch again.

Implementations in-tree:

* :class:`repro.core.codestore.CodeStore` — the HBM-resident (possibly
  packed sub-byte) warm tier;
* :class:`repro.storage.tiered.TieredCodes` — a device-resident hot-row
  cache composed over any other RowStore backing;
* raw int8 arrays — hand-built tables in tests, float exports.

Bitwise contract: for containers holding the same logical codes, every
function here returns bitwise-identical values whichever implementation
backs it — the cache-parity tests in tests/test_storage.py hold each
implementation to that bar.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "RowStore",
    "CacheSlot",
    "is_row_store",
    "logical_codes",
    "take_rows",
    "set_rows",
    "where_rows",
    "resident_bytes_of",
]


@runtime_checkable
class RowStore(Protocol):
    """A table of ``n x d`` logical int8 codes behind a storage layout.

    ``shape`` reports the *logical* geometry; the container may hold packed
    bytes, tiers, or host memory underneath.  All five operations are
    functional (writes return a new container).
    """

    @property
    def shape(self) -> tuple[int, int]: ...

    def unpack(self) -> jax.Array: ...

    def take(self, ids: jax.Array) -> jax.Array: ...

    def set_rows(self, rows_idx: jax.Array, codes_rows: jax.Array, *,
                 mode: str = "drop") -> "RowStore": ...

    def where_rows(self, row_mask: jax.Array,
                   codes_new: "RowStore | jax.Array") -> "RowStore": ...

    @property
    def resident_bytes(self) -> int: ...


def is_row_store(codes) -> bool:
    """True for protocol containers; False for raw jax/numpy code arrays.

    Duck-typed on ``where_rows`` (raw arrays have ``take`` but none of the
    functional write surface), so this module never imports the container
    classes — new RowStore implementations need no registration here.
    """
    return hasattr(codes, "where_rows")


def logical_codes(codes) -> jax.Array:
    """The unpacked int8 [n, d] view of any container."""
    return codes.unpack() if is_row_store(codes) else codes


def take_rows(codes, ids: jax.Array) -> jax.Array:
    """Row gather -> int8 codes ``ids.shape + (d,)``."""
    if is_row_store(codes):
        return codes.take(ids)
    return jnp.take(codes, ids, axis=0)


def set_rows(codes, rows_idx: jax.Array, codes_rows: jax.Array, *,
             mode: str = "drop"):
    """Functional row scatter of int8 ``[k, d]`` rows -> new container."""
    if is_row_store(codes):
        return codes.set_rows(rows_idx, codes_rows, mode=mode)
    return codes.at[rows_idx].set(codes_rows, mode=mode)


def where_rows(codes, row_mask: jax.Array, codes_new):
    """Row-wise select: where ``row_mask`` take ``codes_new`` else ``codes``."""
    if is_row_store(codes):
        return codes.where_rows(row_mask, codes_new)
    mask = row_mask if row_mask.ndim == 2 else row_mask[:, None]
    return jnp.where(mask, logical_codes(codes_new), codes)


def resident_bytes_of(codes) -> int:
    """Container-actual resident bytes of any representation."""
    if is_row_store(codes):
        return int(codes.resident_bytes)
    return int(math.prod(codes.shape) * np.dtype(codes.dtype).itemsize)


@dataclasses.dataclass(frozen=True)
class CacheSlot:
    """One cacheable sub-table of a composed state (training or serving).

    The tiered cache operates per *slot* — a single-table method has one
    identity slot; qr methods have remainder/quotient slots; the mixed
    method has one slot per bit-width group.  ``get``/``put`` project the
    slot's table out of / back into the enclosing state; ``local_ids`` maps
    global feature ids to the slot's local row space (entries outside the
    slot map to -1 and are ignored by the cache policy).
    """

    name: str
    rows: int  # live local id space of the slot's table
    get: Callable[[Any], Any]
    put: Callable[[Any, Any], Any]
    local_ids: Callable[[np.ndarray], np.ndarray]
