"""Device-resident hot-row cache composed over any :class:`RowStore` backing.

Two halves, split by where the work runs:

* :class:`TieredCodes` — the in-jit container.  A registered pytree holding
  the ``backing`` tier (CodeStore or raw codes), a fixed-capacity ``hot``
  tier in the same layout, and two int32 id<->slot maps.  All four RowStore
  operations route per-row: reads overlay cached rows on the backing gather
  (one batched gather + a where-merge, static shapes, stable jit geometry);
  writes land in the hot tier for cached rows and in the backing for
  everything else.  Cache-on is bitwise-equal to cache-off for every
  operation — the hot tier always holds the row's *current* value.

* :class:`HotRowCache` — the host-side policy manager.  LRU eviction with
  frequency admission (a miss only displaces a victim with a strictly lower
  access count), per-slot dirty flags for write-back-before-eviction, and
  hit/miss/eviction/write-back counters.  ``observe`` consumes a batch's
  ids and returns padded-to-capacity move arrays; ``apply`` executes them
  in one jitted step (dirty write-back -> map update -> admission gather),
  so membership churn never retraces the training step.

The cache layers *codes only*.  Scale vectors and optimizer slots stay
full-size device arrays — they are dense [n]-indexed state the routed paths
already read by id, and the de-quantize multiply commutes with the row
routing, which is what keeps the parity bitwise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codestore
from repro.faults import plan as faultplan
from repro.faults.recovery import RetryStats, retry_with_backoff
from repro.obs import counters as obs_counters
from repro.obs.trace import tracer
from repro.storage import base as rowstore

__all__ = ["TieredCodes", "HotRowCache", "wrap_codes"]

_MET_WRITEBACK_ROWS = obs_counters.registry().counter(
    "storage.writeback_rows", "dirty hot rows flushed to the backing tier"
)


@dataclasses.dataclass(frozen=True)
class TieredCodes:
    """Hot tier + backing tier behind the RowStore protocol.

    ``slot_of_id`` is int32 ``[n_alloc]`` (-1 = not cached); ``ids_of_slot``
    is int32 ``[capacity]`` (-1 = free slot).  Both are device-resident so
    lookups route *inside* jit; the host-side policy mirror lives in
    :class:`HotRowCache`.
    """

    backing: "codestore.CodeStore | jax.Array"
    hot: "codestore.CodeStore | jax.Array"  # [capacity, d], same layout
    slot_of_id: jax.Array
    ids_of_slot: jax.Array

    # ------------------------------------------------------------ facade

    @property
    def shape(self) -> tuple[int, int]:
        return tuple(self.backing.shape)

    @property
    def dtype(self):
        return jnp.int8

    @property
    def size(self) -> int:
        return int(self.shape[0]) * int(self.shape[1])

    @property
    def ndim(self) -> int:
        return 2

    @property
    def capacity(self) -> int:
        return int(self.ids_of_slot.shape[0])

    @property
    def hot_bytes(self) -> int:
        return rowstore.resident_bytes_of(self.hot)

    @property
    def metadata_bytes(self) -> int:
        """Device bytes of the id<->slot maps (part of the cache budget)."""
        return int(self.slot_of_id.size + self.ids_of_slot.size) * 4

    @property
    def resident_bytes(self) -> int:
        """Backing + hot tier + cache metadata — the honest device footprint."""
        return (
            rowstore.resident_bytes_of(self.backing)
            + self.hot_bytes
            + self.metadata_bytes
        )

    # ------------------------------------------------------------ routing

    def slots_for(self, ids: jax.Array) -> jax.Array:
        """Hot-tier slot per id (-1 = uncached / out of range)."""
        n = self.shape[0]
        safe = jnp.clip(ids, 0, n - 1)
        slot = jnp.take(self.slot_of_id, safe)
        ok = (ids >= 0) & (ids < n)
        return jnp.where(ok, slot, -1)

    # ------------------------------------------------------------ reads

    def unpack(self) -> jax.Array:
        """Full logical [n, d] view: backing overlaid with cached rows."""
        base = rowstore.logical_codes(self.backing)
        n = base.shape[0]
        hot = rowstore.logical_codes(self.hot)
        idx = jnp.where(self.ids_of_slot >= 0, self.ids_of_slot, n)
        return base.at[idx].set(hot, mode="drop")

    def take(self, ids: jax.Array) -> jax.Array:
        """Routed gather: one backing gather + one hot gather, where-merged.

        Static shapes whatever the hit pattern — the partition is a mask,
        not a compaction, so jit geometry never depends on cache contents.
        """
        base = rowstore.take_rows(self.backing, ids)
        slot = self.slots_for(ids)
        hot = rowstore.take_rows(self.hot, jnp.clip(slot, 0, self.capacity - 1))
        return jnp.where((slot >= 0)[..., None], hot, base)

    # ------------------------------------------------------------ writes

    def set_rows(self, rows_idx: jax.Array, codes_rows: jax.Array, *,
                 mode: str = "drop") -> "TieredCodes":
        """Row scatter routed per id: cached rows write the hot tier only
        (the host manager marks them dirty); uncached rows write the backing.
        Out-of-range ids (dedup sentinels) behave exactly as the backing
        would: real scratch rows are written, true OOB indices drop.
        """
        n = self.shape[0]
        slot = self.slots_for(rows_idx)
        hot_idx = jnp.where(slot >= 0, slot, self.capacity)
        back_idx = jnp.where(slot >= 0, n, rows_idx)
        hot = rowstore.set_rows(self.hot, hot_idx, codes_rows, mode="drop")
        backing = rowstore.set_rows(self.backing, back_idx, codes_rows, mode=mode)
        return dataclasses.replace(self, hot=hot, backing=backing)

    def where_rows(self, row_mask: jax.Array, codes_new) -> "TieredCodes":
        """Dense masked write: selected rows take the new value in *both*
        tiers (so no dirtiness is introduced — the dense/pjit path stays
        write-back-free); unselected cached rows keep their hot value.
        """
        new_logical = rowstore.logical_codes(codes_new)
        backing = rowstore.where_rows(self.backing, row_mask, new_logical)
        n = self.shape[0]
        ids = self.ids_of_slot
        safe = jnp.clip(ids, 0, n - 1)
        mask1 = row_mask.reshape(-1)
        m_slot = (ids >= 0) & jnp.take(mask1, safe)
        new_rows = jnp.take(new_logical, safe, axis=0)
        sel = jnp.where(m_slot, jnp.arange(self.capacity), self.capacity)
        hot = rowstore.set_rows(self.hot, sel, new_rows, mode="drop")
        return dataclasses.replace(self, backing=backing, hot=hot)


def _flatten_with_keys(t: TieredCodes):
    g = jax.tree_util.GetAttrKey
    return (
        (g("backing"), t.backing), (g("hot"), t.hot),
        (g("slot_of_id"), t.slot_of_id), (g("ids_of_slot"), t.ids_of_slot),
    ), None


def _flatten(t: TieredCodes):
    return (t.backing, t.hot, t.slot_of_id, t.ids_of_slot), None


def _unflatten(aux, children) -> TieredCodes:
    return TieredCodes(*children)


jax.tree_util.register_pytree_with_keys(
    TieredCodes, _flatten_with_keys, _unflatten, _flatten
)


def wrap_codes(codes, capacity: int) -> TieredCodes:
    """Compose an (empty) hot tier over ``codes`` in the same layout."""
    n_alloc, d = codes.shape
    if isinstance(codes, codestore.CodeStore):
        hot = codestore.CodeStore.from_codes(
            jnp.zeros((capacity, d), jnp.int8), codes.bits, packed=codes.packed
        )
    else:
        hot = jnp.zeros((capacity, d), codes.dtype)
    return TieredCodes(
        backing=codes,
        hot=hot,
        slot_of_id=jnp.full((int(n_alloc),), -1, jnp.int32),
        ids_of_slot=jnp.full((int(capacity),), -1, jnp.int32),
    )


@jax.jit
def _apply_moves(tiered: TieredCodes, ev_slots, ev_ids, ev_dirty,
                 adm_slots, adm_ids) -> TieredCodes:
    """One jitted membership transaction, padded to capacity:

    1. write back the *dirty* evicted hot rows into the backing,
    2. clear the evicted ids from both maps,
    3. gather admitted rows from the post-write-back backing into the hot
       tier and set their map entries.

    Evicted and admitted id sets are disjoint by construction (the host
    policy never readmits what it just evicted in the same transaction), so
    the scatter order above is the only one that matters.
    """
    n = tiered.shape[0]
    cap = tiered.capacity
    # 1. dirty write-back (clean evictions already match the backing).
    ev_rows = rowstore.take_rows(tiered.hot, jnp.clip(ev_slots, 0, cap - 1))
    wb_idx = jnp.where((ev_ids >= 0) & ev_dirty, ev_ids, n)
    backing = rowstore.set_rows(tiered.backing, wb_idx, ev_rows, mode="drop")
    # 2. map clears.
    slot_of = tiered.slot_of_id.at[
        jnp.where(ev_ids >= 0, ev_ids, n)
    ].set(-1, mode="drop")
    ids_of = tiered.ids_of_slot.at[
        jnp.where(ev_ids >= 0, ev_slots, cap)
    ].set(-1, mode="drop")
    # 3. admissions from the post-write-back backing.
    adm_rows = rowstore.take_rows(backing, jnp.clip(adm_ids, 0, n - 1))
    hot = rowstore.set_rows(
        tiered.hot, jnp.where(adm_ids >= 0, adm_slots, cap), adm_rows,
        mode="drop",
    )
    slot_of = slot_of.at[
        jnp.where(adm_ids >= 0, adm_ids, n)
    ].set(adm_slots, mode="drop")
    ids_of = ids_of.at[
        jnp.where(adm_ids >= 0, adm_slots, cap)
    ].set(adm_ids, mode="drop")
    return TieredCodes(
        backing=backing, hot=hot, slot_of_id=slot_of, ids_of_slot=ids_of
    )


@jax.jit
def _write_back(tiered: TieredCodes, slots, ids) -> TieredCodes:
    """Flush listed hot rows into the backing (membership unchanged)."""
    n = tiered.shape[0]
    rows = rowstore.take_rows(
        tiered.hot, jnp.clip(slots, 0, tiered.capacity - 1)
    )
    backing = rowstore.set_rows(
        tiered.backing, jnp.where(ids >= 0, ids, n), rows, mode="drop"
    )
    return dataclasses.replace(tiered, backing=backing)


class HotRowCache:
    """Host-side cache policy for one :class:`TieredCodes` slot.

    LRU victim selection with frequency admission: a miss is admitted into a
    free slot unconditionally, but only displaces the least-recently-used
    victim when its lifetime access count strictly exceeds the victim's —
    the classic guard against scan traffic flushing the hot set.
    """

    def __init__(self, capacity: int, n_alloc: int, *, name: str = "codes"):
        capacity = int(min(capacity, n_alloc))
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.name = name
        self.capacity = capacity
        self.n_alloc = int(n_alloc)
        self.slot_of_arr = np.full(self.n_alloc, -1, np.int32)
        self.slot_ids = np.full(capacity, -1, np.int64)
        self.freq = np.zeros(self.n_alloc, np.int64)
        self.last_used = np.zeros(capacity, np.int64)
        self.dirty = np.zeros(capacity, bool)
        self._free = list(range(capacity))[::-1]  # pop() fills slot 0 first
        self.clock = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.writebacks = 0
        # Waves this cache refused on (injected) admission memory pressure;
        # the wave is then served straight off the backing tier (degraded
        # but bitwise-equal — cache-on == cache-off holds per row).
        self.admission_oom = 0
        self.observe_calls = 0  # wave index for the cache.admission seam
        self.flush_calls = 0  # flush index, the tiered.writeback seam basis
        self.retry_stats = RetryStats()  # dirty write-back retry accounting

    # ------------------------------------------------------------ wrap

    def wrap(self, codes) -> TieredCodes:
        """Compose an empty hot tier over ``codes`` at this cache's capacity."""
        if codes.shape[0] != self.n_alloc:
            raise ValueError(
                f"codes rows {codes.shape[0]} != cache n_alloc {self.n_alloc}"
            )
        return wrap_codes(codes, self.capacity)

    # ------------------------------------------------------------ policy

    def observe(self, ids, *, write: bool = False):
        """Account one batch of (local) ids; returns move arrays or None.

        ``write=True`` marks touched cached rows dirty (the routed
        ``set_rows`` put their new codes in the hot tier only).  Hits and
        misses are counted per occurrence against pre-admission membership.
        Negative / out-of-range ids (other slots' traffic, sentinels) are
        ignored.
        """
        wave = self.observe_calls
        self.observe_calls += 1
        spec = faultplan.lookup("cache.admission")
        if spec is not None and spec.fires(wave):
            # Injected admission OOM: refuse BEFORE any policy state mutates
            # (a half-observed wave would desync the host maps from the
            # device overlay).  The caller serves the wave off the backing
            # tier — degraded, counted, bitwise-equal.
            self.admission_oom += 1
            return None
        ids = np.asarray(ids).reshape(-1).astype(np.int64)
        ids = ids[(ids >= 0) & (ids < self.n_alloc)]
        self.clock += 1
        if ids.size == 0:
            return None
        uniq, counts = np.unique(ids, return_counts=True)
        self.freq[uniq] += counts
        slots = self.slot_of_arr[uniq]
        hit = slots >= 0
        self.hits += int(counts[hit].sum())
        self.misses += int(counts[~hit].sum())
        hot_slots = slots[hit]
        self.last_used[hot_slots] = self.clock
        if write:
            self.dirty[hot_slots] = True
        miss_ids = uniq[~hit]
        if miss_ids.size == 0:
            return None
        ev_slots: list[int] = []
        ev_ids: list[int] = []
        ev_dirty: list[bool] = []
        adm_slots: list[int] = []
        adm_ids: list[int] = []
        # Admit hottest misses first so the frequency guard sees them before
        # colder ones contend for the same victims.
        for i in miss_ids[np.argsort(-self.freq[miss_ids], kind="stable")]:
            i = int(i)
            if self._free:
                slot = self._free.pop()
            else:
                victim = int(np.argmin(self.last_used))
                vid = int(self.slot_ids[victim])
                if self.freq[i] <= self.freq[vid]:
                    continue  # frequency admission: keep the hotter row
                ev_slots.append(victim)
                ev_ids.append(vid)
                ev_dirty.append(bool(self.dirty[victim]))
                self.evictions += 1
                if self.dirty[victim]:
                    self.writebacks += 1
                self.slot_of_arr[vid] = -1
                slot = victim
            self.slot_of_arr[i] = slot
            self.slot_ids[slot] = i
            self.last_used[slot] = self.clock
            self.dirty[slot] = False
            adm_slots.append(slot)
            adm_ids.append(i)
        if not adm_ids:
            return None
        return self._pad_moves(ev_slots, ev_ids, ev_dirty, adm_slots, adm_ids)

    def _pad_moves(self, ev_slots, ev_ids, ev_dirty, adm_slots, adm_ids):
        """Pad move lists to capacity so `apply` traces exactly once."""
        cap = self.capacity

        def pad_i32(vals):
            out = np.full(cap, -1, np.int32)
            out[: len(vals)] = vals
            return out

        dirty = np.zeros(cap, bool)
        dirty[: len(ev_dirty)] = ev_dirty
        return (
            pad_i32(ev_slots), pad_i32(ev_ids), dirty,
            pad_i32(adm_slots), pad_i32(adm_ids),
        )

    # ------------------------------------------------------------ device

    def apply(self, tiered: TieredCodes, moves) -> TieredCodes:
        """Execute ``observe``'s moves on the device container (jitted)."""
        ev_s, ev_i, ev_d, ad_s, ad_i = (jnp.asarray(m) for m in moves)
        return _apply_moves(tiered, ev_s, ev_i, ev_d, ad_s, ad_i)

    def observe_apply(self, tiered: TieredCodes, ids, *,
                      write: bool = False) -> TieredCodes:
        moves = self.observe(ids, write=write)
        return tiered if moves is None else self.apply(tiered, moves)

    def _dirty_moves(self):
        idx = np.nonzero(self.dirty)[0]
        if idx.size == 0:
            return None
        slots = np.full(self.capacity, -1, np.int32)
        ids = np.full(self.capacity, -1, np.int32)
        slots[: idx.size] = idx
        ids[: idx.size] = self.slot_ids[idx]
        return jnp.asarray(slots), jnp.asarray(ids), int(idx.size)

    def flush(self, tiered: TieredCodes) -> TieredCodes:
        """Write every dirty hot row back to the backing; membership and the
        hot tier stay intact (training can continue through the cache).

        The write-back runs behind bounded retry+backoff (the
        ``tiered.writeback`` seam: an installed plan can fail it ``fails``
        times per fired flush).  ``_write_back`` is a pure jitted function,
        so a retried attempt is bitwise-identical; exhaustion raises
        ``RetryError`` loudly with the dirty rows still flagged."""
        moves = self._dirty_moves()
        flush_idx = self.flush_calls
        self.flush_calls += 1
        if moves is None:
            return tiered
        slots, ids, k = moves
        spec = faultplan.lookup("tiered.writeback")
        armed = spec is not None and spec.fires(flush_idx)
        fails = [int(spec.param("fails", 1)) if armed else 0]

        def write():
            if fails[0] > 0:
                fails[0] -= 1
                raise faultplan.TransientFault(
                    f"tiered.writeback injected failure (flush {flush_idx})"
                )
            return _write_back(tiered, slots, ids)

        attempts = int(spec.param("attempts", 4)) if spec is not None else 4
        with tracer().span("storage.writeback", rows=k, store=self.name):
            tiered = retry_with_backoff(
                write, op="tiered.writeback", attempts=attempts, base_s=0.002,
                stats=self.retry_stats,
            )
        self.dirty[:] = False
        self.writebacks += k
        _MET_WRITEBACK_ROWS.inc(k)
        return tiered

    def unwrap(self, tiered: TieredCodes):
        """The backing with all cached writes folded in — bitwise-equal to
        the container a cache-off run would hold.  Non-destructive: dirty
        flags are left set, so the live tiered state stays consistent."""
        moves = self._dirty_moves()
        if moves is None:
            return tiered.backing
        slots, ids, _ = moves
        return _write_back(tiered, slots, ids).backing

    def warm_start(self, tiered: TieredCodes, freqs) -> TieredCodes:
        """Admit the top-capacity rows by the given frequency counts (e.g.
        training-time id statistics shipped with a serving checkpoint).
        Requires an empty cache."""
        if int((self.slot_of_arr >= 0).sum()):
            raise ValueError("warm_start requires an empty cache")
        f = np.asarray(freqs, np.int64).reshape(-1)
        full = np.zeros(self.n_alloc, np.int64)
        full[: min(f.size, self.n_alloc)] = f[: self.n_alloc]
        self.freq += full
        order = np.argsort(-full, kind="stable")
        order = order[full[order] > 0][: self.capacity]
        if order.size == 0:
            return tiered
        adm_slots, adm_ids = [], []
        self.clock += 1
        for i in order:
            i = int(i)
            slot = self._free.pop()
            self.slot_of_arr[i] = slot
            self.slot_ids[slot] = i
            self.last_used[slot] = self.clock
            adm_slots.append(slot)
            adm_ids.append(i)
        return self.apply(tiered, self._pad_moves([], [], [], adm_slots, adm_ids))

    # ------------------------------------------------------------ metrics

    @property
    def rows_cached(self) -> int:
        return int((self.slot_of_arr >= 0).sum())

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def host_metadata_bytes(self) -> int:
        """Host bytes of the policy state (id map, recency/freq counters)."""
        return int(
            self.slot_of_arr.nbytes + self.slot_ids.nbytes + self.freq.nbytes
            + self.last_used.nbytes + self.dirty.nbytes
        )

    def reset_counters(self) -> None:
        """Zero the traffic counters; membership and policy state persist."""
        self.hits = self.misses = self.evictions = self.writebacks = 0
        self.admission_oom = 0
        self.retry_stats = RetryStats()

    def stats(self) -> dict:
        return {
            "name": self.name,
            "capacity": self.capacity,
            "rows_cached": self.rows_cached,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "writebacks": self.writebacks,
            "admission_oom": self.admission_oom,
            "writeback_retries": self.retry_stats.retries,
            "hit_rate": self.hit_rate,
        }
