"""Host-memory cold tier for serving: tables larger than the device budget.

A :class:`ColdStore` keeps the *container bytes* of a quantized table in
host numpy memory; the device holds only the per-row scale vector, a
fixed-capacity hot tier of the hottest rows, and nothing else.  Per scoring
wave:

1. the wave's rows are gathered on host at fixed ``[batch * fields, width]``
   geometry and ``jax.device_put`` (misses travel; hits are overridden),
2. one jitted merge overlays the device hot tier where the host-side id map
   says a row is cached, unpacks the container bytes, and de-quantizes with
   exactly the warm path's formula — so cold serving is bitwise-equal to
   HBM-resident serving,
3. the *next* wave's host gather is staged ahead of time (one-deep async
   prefetch keyed on the pending queue), hiding the host->device copy
   behind the current wave's compute.

Routing happens host-side (the policy's id map), so the device carries no
map arrays in cold mode; admissions copy rows host->device into the hot
tier.  The store is read-only — dirty write-back never arises (training
uses :mod:`repro.storage.tiered` instead).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codestore
from repro.storage.tiered import HotRowCache

__all__ = ["ColdStore"]


@jax.jit
def _scatter_rows(hot, slots, rows):
    return hot.at[slots].set(rows, mode="drop")


@functools.partial(jax.jit, static_argnames=("bits", "d", "packed"))
def _cold_dequant(hot, step, host_rows, slot, ids, *, bits, d, packed):
    """Merge + unpack + de-quantize, mirroring the warm reference path
    (``codes.astype(f32) * step[ids][:, None]``) for bitwise parity."""
    cap = hot.shape[0]
    hot_rows = jnp.take(hot, jnp.clip(slot, 0, cap - 1), axis=0)
    container = jnp.where((slot >= 0)[:, None], hot_rows, host_rows)
    codes = (
        codestore.unpack_codes(container, bits, d) if packed else container
    )
    return codes.astype(jnp.float32) * jnp.take(step, ids)[:, None]


class ColdStore:
    """Host-resident quantized table + device hot tier + prefetch staging."""

    def __init__(self, codes, step, *, cache_rows: int, name: str = "cold"):
        if isinstance(codes, codestore.CodeStore):
            self.host = np.asarray(jax.device_get(codes.data))
            self.bits = codes.bits
            self.packed = codes.packed
            self.d_alloc = codes.d
        else:
            self.host = np.asarray(jax.device_get(codes))
            self.bits = 8
            self.packed = False
            self.d_alloc = int(codes.shape[1])
        self.n_alloc = int(self.host.shape[0])
        self.step = jnp.asarray(step)
        self.cache = HotRowCache(max(1, cache_rows), self.n_alloc, name=name)
        self.hot = jnp.zeros(
            (self.cache.capacity, self.host.shape[1]), self.host.dtype
        )
        self._staged: tuple[bytes, jax.Array] | None = None
        self.prefetch_hits = 0
        self.demand_puts = 0

    # ------------------------------------------------------------ bytes

    @property
    def host_bytes(self) -> int:
        """The cold tier's host footprint (what exceeds the device budget)."""
        return int(self.host.nbytes)

    @property
    def device_bytes(self) -> int:
        """Everything this store keeps device-resident: hot rows + scales."""
        hot = int(self.hot.size) * self.hot.dtype.itemsize
        return hot + int(self.step.size) * self.step.dtype.itemsize

    @property
    def hot_device_bytes(self) -> int:
        return int(self.hot.size) * self.hot.dtype.itemsize

    # ------------------------------------------------------------ prefetch

    def _host_gather(self, flat_ids: np.ndarray) -> np.ndarray:
        return self.host[np.clip(flat_ids, 0, self.n_alloc - 1)]

    def stage(self, flat_ids: np.ndarray) -> None:
        """Start the host->device copy for a future wave's ids."""
        flat_ids = np.asarray(flat_ids, np.int64).reshape(-1)
        key = flat_ids.tobytes()
        if self._staged is not None and self._staged[0] == key:
            return
        self._staged = (key, jax.device_put(self._host_gather(flat_ids)))

    # ------------------------------------------------------------ serving

    def admit(self, flat_ids: np.ndarray) -> None:
        """Run the cache policy over a wave's ids; copy admissions to the
        device hot tier (rows come from host memory, not a backing tier)."""
        moves = self.cache.observe(np.asarray(flat_ids, np.int64))
        if moves is None:
            return
        _, _, _, adm_slots, adm_ids = moves
        rows = jax.device_put(self._host_gather(adm_ids))
        slots = jnp.asarray(
            np.where(adm_ids >= 0, adm_slots, self.cache.capacity)
        )
        self.hot = _scatter_rows(self.hot, slots, rows)

    def rows(self, flat_ids: np.ndarray) -> jax.Array:
        """De-quantized f32 rows ``[k, d_alloc]`` for one wave of ids.

        Consumes the staged prefetch when it matches; otherwise demand-loads
        the host gather.  Bitwise-equal to a warm ``QuantTable`` read.
        """
        flat_ids = np.asarray(flat_ids, np.int64).reshape(-1)
        key = flat_ids.tobytes()
        if self._staged is not None and self._staged[0] == key:
            host_rows = self._staged[1]
            self.prefetch_hits += 1
        else:
            host_rows = jax.device_put(self._host_gather(flat_ids))
            self.demand_puts += 1
        self._staged = None
        slot = jnp.asarray(self.cache.slot_of_arr[np.clip(flat_ids, 0, self.n_alloc - 1)])
        ids_dev = jnp.asarray(flat_ids.astype(np.int32))
        return _cold_dequant(
            self.hot, self.step, host_rows, slot, ids_dev,
            bits=self.bits, d=self.d_alloc, packed=self.packed,
        )

    def warm_start(self, freqs) -> None:
        """Admit the top rows by frequency (checkpoint-restart warm cache)."""
        f = np.asarray(freqs, np.int64).reshape(-1)
        full = np.zeros(self.n_alloc, np.int64)
        full[: min(f.size, self.n_alloc)] = f[: self.n_alloc]
        order = np.argsort(-full, kind="stable")
        order = order[full[order] > 0][: self.cache.capacity]
        if order.size == 0:
            return
        self.cache.freq += full
        self.cache.clock += 1
        adm_slots, adm_ids = [], []
        for i in order:
            i = int(i)
            slot = self.cache._free.pop()
            self.cache.slot_of_arr[i] = slot
            self.cache.slot_ids[slot] = i
            self.cache.last_used[slot] = self.cache.clock
            adm_slots.append(slot)
            adm_ids.append(i)
        moves = self.cache._pad_moves([], [], [], adm_slots, adm_ids)
        _, _, _, adm_slots_p, adm_ids_p = moves
        rows = jax.device_put(self._host_gather(adm_ids_p))
        slots = jnp.asarray(
            np.where(adm_ids_p >= 0, adm_slots_p, self.cache.capacity)
        )
        self.hot = _scatter_rows(self.hot, slots, rows)

    def reset_counters(self) -> None:
        self.cache.reset_counters()
        self.prefetch_hits = 0
        self.demand_puts = 0
