"""Host-memory cold tier for serving: tables larger than the device budget.

A :class:`ColdStore` keeps the *container bytes* of a quantized table in
host numpy memory; the device holds only the per-row scale vector, a
fixed-capacity hot tier of the hottest rows, and nothing else.  Per scoring
wave:

1. the wave's rows are gathered on host at fixed ``[batch * fields, width]``
   geometry and ``jax.device_put`` (misses travel; hits are overridden),
2. one jitted merge overlays the device hot tier where the host-side id map
   says a row is cached, unpacks the container bytes, and de-quantizes with
   exactly the warm path's formula — so cold serving is bitwise-equal to
   HBM-resident serving,
3. the *next* wave's host gather is staged ahead of time (one-deep async
   prefetch keyed on the pending queue), hiding the host->device copy
   behind the current wave's compute.

Routing happens host-side (the policy's id map), so the device carries no
map arrays in cold mode; admissions copy rows host->device into the hot
tier.  The store is read-only — dirty write-back never arises (training
uses :mod:`repro.storage.tiered` instead).
"""
from __future__ import annotations

import functools
import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codestore
from repro.faults import plan as faultplan
from repro.faults.recovery import RetryStats, retry_with_backoff
from repro.obs import counters as obs_counters
from repro.obs.trace import tracer
from repro.storage.tiered import HotRowCache

__all__ = ["ColdStore"]

# Cold-tier traffic in the unified registry (process-wide across stores;
# per-store counts stay on the ColdStore attributes the engines report).
_REG = obs_counters.registry()
_MET_PREFETCH_HITS = _REG.counter(
    "storage.cold.prefetch_hits", "waves served from the staged prefetch"
)
_MET_DEMAND_PUTS = _REG.counter(
    "storage.cold.demand_puts", "waves demand-fetched host->device"
)
_MET_PREFETCH_DROPPED = _REG.counter(
    "storage.cold.prefetch_dropped", "staged prefetches lost (re-fetched)"
)
_MET_CORRUPTION = _REG.counter(
    "storage.cold.corruption_detected", "staged bytes failing crc"
)


@jax.jit
def _scatter_rows(hot, slots, rows):
    return hot.at[slots].set(rows, mode="drop")


@functools.partial(jax.jit, static_argnames=("bits", "d", "packed"))
def _cold_dequant(hot, step, host_rows, slot, ids, *, bits, d, packed):
    """Merge + unpack + de-quantize, mirroring the warm reference path
    (``codes.astype(f32) * step[ids][:, None]``) for bitwise parity."""
    cap = hot.shape[0]
    hot_rows = jnp.take(hot, jnp.clip(slot, 0, cap - 1), axis=0)
    container = jnp.where((slot >= 0)[:, None], hot_rows, host_rows)
    codes = (
        codestore.unpack_codes(container, bits, d) if packed else container
    )
    return codes.astype(jnp.float32) * jnp.take(step, ids)[:, None]


class ColdStore:
    """Host-resident quantized table + device hot tier + prefetch staging."""

    def __init__(self, codes, step, *, cache_rows: int, name: str = "cold"):
        if isinstance(codes, codestore.CodeStore):
            self.host = np.asarray(jax.device_get(codes.data))
            self.bits = codes.bits
            self.packed = codes.packed
            self.d_alloc = codes.d
        else:
            self.host = np.asarray(jax.device_get(codes))
            self.bits = 8
            self.packed = False
            self.d_alloc = int(codes.shape[1])
        self.n_alloc = int(self.host.shape[0])
        self.step = jnp.asarray(step)
        self.cache = HotRowCache(max(1, cache_rows), self.n_alloc, name=name)
        self.hot = jnp.zeros(
            (self.cache.capacity, self.host.shape[1]), self.host.dtype
        )
        self._staged: tuple[bytes, jax.Array] | None = None
        self._staged_crc: int | None = None
        self.prefetch_hits = 0
        self.demand_puts = 0
        # Recovery accounting: every host fetch goes through bounded
        # retry+backoff (repro.faults.recovery); these are the per-store
        # counters the engines surface in their end-of-run reports.
        self.retry_stats = RetryStats()
        self.prefetch_dropped = 0  # injected prefetch losses (re-fetched)
        self.corruption_detected = 0  # staged bytes failing crc verification
        self.wave = 0  # fetch-wave index, the cold.* fault schedule basis
        self._fails_armed = 0  # remaining injected failures this wave
        self._armed_wave = -1

    # ------------------------------------------------------------ bytes

    @property
    def host_bytes(self) -> int:
        """The cold tier's host footprint (what exceeds the device budget)."""
        return int(self.host.nbytes)

    @property
    def device_bytes(self) -> int:
        """Everything this store keeps device-resident: hot rows + scales."""
        hot = int(self.hot.size) * self.hot.dtype.itemsize
        return hot + int(self.step.size) * self.step.dtype.itemsize

    @property
    def hot_device_bytes(self) -> int:
        return int(self.hot.size) * self.hot.dtype.itemsize

    # ------------------------------------------------------------ prefetch

    def _host_gather(self, flat_ids: np.ndarray) -> np.ndarray:
        return self.host[np.clip(flat_ids, 0, self.n_alloc - 1)]

    def _fetch(self, flat_ids: np.ndarray) -> np.ndarray:
        """Host gather behind bounded retry+backoff (the ``cold.fetch`` seam:
        an installed plan can stall the gather or fail it ``fails`` times per
        fired wave; exhaustion raises ``RetryError`` loudly)."""
        spec = faultplan.lookup("cold.fetch")
        armed = spec is not None and spec.fires(self.wave)
        if armed and self._armed_wave != self.wave:
            self._armed_wave = self.wave
            self._fails_armed = int(spec.param("fails", 1))

        def gather():
            if armed:
                stall = float(spec.param("stall_s", 0.0))
                if stall:
                    time.sleep(stall)
                if self._fails_armed > 0:
                    self._fails_armed -= 1
                    raise faultplan.TransientFault(
                        f"cold.fetch injected failure (wave {self.wave})"
                    )
            return self._host_gather(flat_ids)

        attempts = int(spec.param("attempts", 4)) if spec is not None else 4
        return retry_with_backoff(
            gather, op="cold.fetch", attempts=attempts, base_s=0.002,
            stats=self.retry_stats,
        )

    def stage(self, flat_ids: np.ndarray) -> None:
        """Start the host->device copy for a future wave's ids."""
        flat_ids = np.asarray(flat_ids, np.int64).reshape(-1)
        key = flat_ids.tobytes()
        if self._staged is not None and self._staged[0] == key:
            return
        with tracer().span("storage.cold.prefetch", rows=int(flat_ids.size)):
            rows = self._fetch(flat_ids)
        crc = None
        spec = faultplan.lookup("codestore.corrupt")
        if spec is not None:
            # Record the ground-truth checksum of the staged bytes so the
            # consumer can verify the device copy before trusting it.
            crc = zlib.crc32(rows.tobytes())
            if spec.fires(self.wave):
                buf = bytearray(rows.tobytes())
                seed = int(spec.param("seed", 0))
                pos = zlib.crc32(f"{seed}:{self.wave}".encode()) % len(buf)
                buf[pos] ^= 0xFF
                rows = np.frombuffer(
                    bytes(buf), dtype=rows.dtype
                ).reshape(rows.shape)
        self._staged = (key, jax.device_put(rows))
        self._staged_crc = crc

    # ------------------------------------------------------------ serving

    def admit(self, flat_ids: np.ndarray) -> None:
        """Run the cache policy over a wave's ids; copy admissions to the
        device hot tier (rows come from host memory, not a backing tier)."""
        moves = self.cache.observe(np.asarray(flat_ids, np.int64))
        if moves is None:
            return
        _, _, _, adm_slots, adm_ids = moves
        rows = jax.device_put(self._fetch(adm_ids))
        slots = jnp.asarray(
            np.where(adm_ids >= 0, adm_slots, self.cache.capacity)
        )
        self.hot = _scatter_rows(self.hot, slots, rows)

    def rows(self, flat_ids: np.ndarray) -> jax.Array:
        """De-quantized f32 rows ``[k, d_alloc]`` for one wave of ids.

        Consumes the staged prefetch when it matches; otherwise demand-loads
        the host gather.  Bitwise-equal to a warm ``QuantTable`` read.
        """
        flat_ids = np.asarray(flat_ids, np.int64).reshape(-1)
        key = flat_ids.tobytes()
        spec = faultplan.lookup("cold.prefetch_loss")
        if (
            spec is not None
            and spec.fires(self.wave)
            and self._staged is not None
        ):
            # Injected prefetch loss: the staged copy vanishes; the demand
            # path below re-fetches from host ground truth (bitwise-equal).
            self._staged = None
            self._staged_crc = None
            self.prefetch_dropped += 1
            _MET_PREFETCH_DROPPED.inc()
        if self._staged is not None and self._staged[0] == key:
            host_rows = self._staged[1]
            if self._staged_crc is not None:
                got = zlib.crc32(
                    np.asarray(jax.device_get(host_rows)).tobytes()
                )
                if got != self._staged_crc:
                    # Corrupted staged bytes: drop them, demand re-fetch.
                    self.corruption_detected += 1
                    _MET_CORRUPTION.inc()
                    with tracer().span("storage.cold.fetch",
                                       rows=int(flat_ids.size),
                                       reason="corrupt-staged"):
                        host_rows = jax.device_put(self._fetch(flat_ids))
                    self.demand_puts += 1
                    _MET_DEMAND_PUTS.inc()
                else:
                    self.prefetch_hits += 1
                    _MET_PREFETCH_HITS.inc()
            else:
                self.prefetch_hits += 1
                _MET_PREFETCH_HITS.inc()
        else:
            with tracer().span("storage.cold.fetch",
                               rows=int(flat_ids.size)):
                host_rows = jax.device_put(self._fetch(flat_ids))
            self.demand_puts += 1
            _MET_DEMAND_PUTS.inc()
        self._staged = None
        self._staged_crc = None
        slot = jnp.asarray(self.cache.slot_of_arr[np.clip(flat_ids, 0, self.n_alloc - 1)])
        ids_dev = jnp.asarray(flat_ids.astype(np.int32))
        out = _cold_dequant(
            self.hot, self.step, host_rows, slot, ids_dev,
            bits=self.bits, d=self.d_alloc, packed=self.packed,
        )
        self.wave += 1
        return out

    def warm_start(self, freqs) -> None:
        """Admit the top rows by frequency (checkpoint-restart warm cache)."""
        f = np.asarray(freqs, np.int64).reshape(-1)
        full = np.zeros(self.n_alloc, np.int64)
        full[: min(f.size, self.n_alloc)] = f[: self.n_alloc]
        order = np.argsort(-full, kind="stable")
        order = order[full[order] > 0][: self.cache.capacity]
        if order.size == 0:
            return
        self.cache.freq += full
        self.cache.clock += 1
        adm_slots, adm_ids = [], []
        for i in order:
            i = int(i)
            slot = self.cache._free.pop()
            self.cache.slot_of_arr[i] = slot
            self.cache.slot_ids[slot] = i
            self.cache.last_used[slot] = self.cache.clock
            adm_slots.append(slot)
            adm_ids.append(i)
        moves = self.cache._pad_moves([], [], [], adm_slots, adm_ids)
        _, _, _, adm_slots_p, adm_ids_p = moves
        rows = jax.device_put(self._host_gather(adm_ids_p))
        slots = jnp.asarray(
            np.where(adm_ids_p >= 0, adm_slots_p, self.cache.capacity)
        )
        self.hot = _scatter_rows(self.hot, slots, rows)

    def reset_counters(self) -> None:
        self.cache.reset_counters()
        self.prefetch_hits = 0
        self.demand_puts = 0
        self.retry_stats = RetryStats()
        self.prefetch_dropped = 0
        self.corruption_detected = 0
