"""Tiered row storage for quantized embedding tables.

Three tiers behind one :class:`~repro.storage.base.RowStore` protocol:

* **hot** — :mod:`repro.storage.tiered`: a device-resident cache of the
  top-K hottest rows (LRU + frequency admission, dirty write-back), shared
  by training and serving;
* **warm** — :class:`repro.core.codestore.CodeStore` / raw int8 arrays: the
  HBM-resident (possibly packed sub-byte) container;
* **cold** — :mod:`repro.storage.cold`: host numpy memory with per-wave
  ``device_put`` and one-deep prefetch, for tables larger than the device
  budget.
"""
from repro.storage import base, cold, tiered
from repro.storage.base import (
    CacheSlot,
    RowStore,
    is_row_store,
    logical_codes,
    resident_bytes_of,
    set_rows,
    take_rows,
    where_rows,
)
from repro.storage.cold import ColdStore
from repro.storage.tiered import HotRowCache, TieredCodes, wrap_codes

__all__ = [
    "base",
    "cold",
    "tiered",
    "CacheSlot",
    "RowStore",
    "is_row_store",
    "logical_codes",
    "take_rows",
    "set_rows",
    "where_rows",
    "resident_bytes_of",
    "ColdStore",
    "HotRowCache",
    "TieredCodes",
    "wrap_codes",
]
