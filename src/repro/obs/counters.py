"""One typed, namespaced counter/gauge registry for the whole stack.

Every telemetry surface in the repo re-registers into the process-global
:func:`registry` under a dotted namespace::

    kernels.*   kernel-vs-fallback dispatch (repro.kernels.ops)
    engine.*    serving Engine request/step/latency counters
    cache.*     per-tier hot/cold cache traffic
    faults.*    guard skip/fire counters, retry/backoff outcomes
    train.*     trainer step counters, straggler warnings
    ckpt.*      checkpoint save/restore events

Metrics are **typed**: a :class:`Counter` only increments, a :class:`Gauge`
holds the last value set.  Both support label tuples (declared up front) so
structured tallies — e.g. the kernels' per-``(op, shape, reason)`` fallback
detail — live in the registry without flattening into name soup.

The registry is observational only: nothing in a jitted computation reads
or writes it, so enabling every surface changes no traced program (the
bitwise-parity contract in tests/test_obs.py).

``snapshot()`` returns an immutable :class:`Snapshot`; ``diff`` between two
snapshots isolates one window's activity (benchmarks snapshot around their
measurement loop).  ``to_json()`` is the stable wire schema, version-tagged
``repro/obs/v1``.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Iterable, Mapping

SCHEMA = "repro/obs/v1"

_KINDS = ("counter", "gauge")


class Metric:
    """Base metric: a named family of (label-tuple -> value) cells."""

    kind = "?"

    def __init__(self, name: str, help: str = "",
                 labels: tuple[str, ...] = ()) -> None:
        self.name = name
        self.help = help
        self.labels = tuple(labels)
        self._values: dict[tuple, int | float] = {}
        self._lock = threading.Lock()

    def _key(self, label_values: tuple) -> tuple:
        if len(label_values) != len(self.labels):
            raise ValueError(
                f"{self.kind} '{self.name}' takes labels {self.labels}; "
                f"got {label_values!r}"
            )
        return tuple(str(v) for v in label_values)

    def value(self, *label_values) -> int | float:
        return self._values.get(self._key(label_values), 0)

    def cells(self) -> dict[tuple, int | float]:
        return dict(self._values)

    def reset(self) -> None:
        with self._lock:
            self._values.clear()


class Counter(Metric):
    """Monotonically increasing tally."""

    kind = "counter"

    def inc(self, amount: int | float = 1, *label_values) -> None:
        if amount < 0:
            raise ValueError(
                f"counter '{self.name}' cannot decrease (amount={amount})"
            )
        key = self._key(label_values)
        with self._lock:
            self._values[key] = self._values.get(key, 0) + amount


class Gauge(Metric):
    """Last-value-wins measurement (bytes resident, hit rate, queue depth)."""

    kind = "gauge"

    def set(self, value: int | float, *label_values) -> None:
        key = self._key(label_values)
        with self._lock:
            self._values[key] = value

    def inc(self, amount: int | float = 1, *label_values) -> None:
        key = self._key(label_values)
        with self._lock:
            self._values[key] = self._values.get(key, 0) + amount


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """Immutable point-in-time view: {name: {label_tuple: value}}."""

    values: Mapping[str, Mapping[tuple, int | float]]
    kinds: Mapping[str, str]
    label_names: Mapping[str, tuple[str, ...]]

    def value(self, name: str, *label_values) -> int | float:
        cells = self.values.get(name, {})
        return cells.get(tuple(str(v) for v in label_values), 0)

    def diff(self, earlier: "Snapshot") -> "Snapshot":
        """This snapshot minus an earlier one — one window's activity.

        Counters subtract cell-wise (missing-earlier cells count from 0);
        gauges keep their later value (a gauge *is* its last observation).
        """
        out: dict[str, dict[tuple, int | float]] = {}
        for name, cells in self.values.items():
            if self.kinds.get(name) == "gauge":
                out[name] = dict(cells)
                continue
            prev = earlier.values.get(name, {})
            d = {
                k: v - prev.get(k, 0)
                for k, v in cells.items()
                if v - prev.get(k, 0)
            }
            if d:
                out[name] = d
        return Snapshot(values=out, kinds=dict(self.kinds),
                        label_names=dict(self.label_names))

    def to_json(self) -> dict:
        """Stable wire schema (``repro/obs/v1``).

        Unlabelled metrics serialize as scalars; labelled ones as a sorted
        list of ``{"labels": {...}, "value": n}`` cells.
        """
        counters: dict = {}
        gauges: dict = {}
        for name in sorted(self.values):
            cells = self.values[name]
            names = self.label_names.get(name, ())
            if not names:
                val = cells.get((), 0)
                dst = gauges if self.kinds.get(name) == "gauge" else counters
                dst[name] = val
                continue
            rows = [
                {"labels": dict(zip(names, key)), "value": val}
                for key, val in sorted(cells.items())
            ]
            dst = gauges if self.kinds.get(name) == "gauge" else counters
            dst[name] = rows
        return {"schema": SCHEMA, "counters": counters, "gauges": gauges}


class Registry:
    """Get-or-create home for every metric, keyed by dotted name."""

    def __init__(self) -> None:
        self._metrics: dict[str, Metric] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, labels: Iterable[str]):
        labels = tuple(labels)
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help=help, labels=labels)
                self._metrics[name] = m
                return m
        if not isinstance(m, cls):
            raise TypeError(
                f"metric '{name}' already registered as {m.kind}, "
                f"not {cls.kind}"
            )
        if m.labels != labels:
            raise ValueError(
                f"metric '{name}' already registered with labels "
                f"{m.labels}, not {labels}"
            )
        return m

    def counter(self, name: str, help: str = "",
                labels: Iterable[str] = ()) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Iterable[str] = ()) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Snapshot:
        with self._lock:
            return Snapshot(
                values={n: m.cells() for n, m in self._metrics.items()},
                kinds={n: m.kind for n, m in self._metrics.items()},
                label_names={n: m.labels for n, m in self._metrics.items()},
            )

    def reset(self) -> None:
        """Zero every metric's cells (registrations survive)."""
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            m.reset()

    def to_json(self) -> dict:
        return self.snapshot().to_json()


_REGISTRY = Registry()


def registry() -> Registry:
    """The process-global registry every surface re-registers into."""
    return _REGISTRY
