"""Perf-regression gate: BENCH_*.json artifacts vs a committed baseline.

The repo's BENCH artifacts were write-only until now — numbers got measured
once and never defended.  This module turns them into a gate the same way
bitwise parity is held: ``BENCH_BASELINE.json`` (committed at the repo root)
records per-cell metric values with per-kind tolerances, and the
``perf-regression`` layer of ``python -m repro.analysis`` fails when a
fresh artifact regresses past them or a baselined cell disappears.

Metric kinds and their default tolerances:

* ``time``  — wall-clock (``us_per_step``, ``us_per_request``,
  ``us_per_token``, ``wall_s``, latency ``p50/p95/p99``).  Generous relative
  tolerance (default 1.5, i.e. fresh ≤ 2.5× baseline): CI machines vary
  wildly, but a 10× step-time regression still fails loudly.
* ``bytes`` — resident/transferred bytes.  Deterministic, so exact by
  default: any growth is a finding.
* ``count`` — fallback/retry/corruption tallies.  Exact: going from 0
  fallbacks to any is a finding.
* ``rate``  — hit rates (higher is better).  Absolute slack (default 0.05).
* ``frac``  — overhead fractions (guard/obs ≤3% bars).  Absolute slack
  (default 0.02) on top of the baseline value.

A cell or metric present in the baseline but missing from the fresh
artifact is itself a finding — silently dropping a measured cell is how
perf coverage rots.  Fresh cells *not* in the baseline pass (baseline
updates are deliberate commits).

Seeding: ``python -m repro.obs.gate seed --out BENCH_BASELINE.json
BENCH_PR4.json BENCH_PR7.json ...`` reads the artifacts and classifies
every gated metric.  Checking: ``python -m repro.obs.gate check`` compares
the repo-root artifacts against the committed baseline (what the analysis
job runs in CI).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
from typing import Mapping

SCHEMA = "repro/obs/bench-baseline/v1"

#: Default per-kind tolerances (overridable per metric in the baseline).
DEFAULT_TOLERANCES = {
    "time": 1.5,   # relative: fresh <= base * (1 + tol)
    "bytes": 0.0,  # relative: exact by default
    "count": 0.0,  # absolute: exact by default
    "rate": 0.05,  # absolute slack below the baseline (higher is better)
    "frac": 0.02,  # absolute slack above the baseline (lower is better)
}

_TIME_KEYS = {"us_per_step", "us_per_request", "us_per_token", "wall_s",
              "p50", "p95", "p99"}
_BYTES_KEYS = {"embed_bytes_per_step", "packed_bytes",
               "resident_embedding_bytes", "embedding_code_bytes",
               "embedding_scale_bytes"}
_COUNT_KEYS = {"shape_fallbacks", "kernel_fallbacks", "retry_failures",
               "corruption_detected"}
_RATE_KEYS = {"cache_hit_rate"}
_FRAC_KEYS = {"overhead_frac"}


def classify(key: str) -> str | None:
    """Gate kind for a (possibly dotted) metric key; None = not gated."""
    leaf = key.rsplit(".", 1)[-1]
    if leaf in _TIME_KEYS:
        return "time"
    if leaf in _BYTES_KEYS:
        return "bytes"
    if leaf in _COUNT_KEYS:
        return "count"
    if leaf in _RATE_KEYS:
        return "rate"
    if leaf in _FRAC_KEYS:
        return "frac"
    return None


@dataclasses.dataclass(frozen=True)
class GateFinding:
    """One regression (or coverage hole) the gate found."""

    bench: str
    cell: str
    metric: str
    message: str
    baseline: float | None = None
    fresh: float | None = None

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


# ------------------------------------------------------------------ cells


def _flatten(cell: Mapping, prefix: str = "") -> dict[str, float]:
    """One level of nesting (``latency_us.p95``) flattened to dotted keys."""
    out: dict[str, float] = {}
    for k, v in cell.items():
        key = f"{prefix}{k}"
        if isinstance(v, Mapping):
            out.update(_flatten(v, prefix=f"{key}."))
        elif isinstance(v, bool) or not isinstance(v, (int, float)):
            continue
        else:
            out[key] = float(v)
    return out


def extract_cells(doc: Mapping) -> dict[str, dict[str, float]]:
    """Named cells with their numeric metrics, from any BENCH_* schema.

    Handles the repo's three artifact shapes: the e2e bench's named-cell
    mapping, the serving benches' cell lists (named by scenario/method and
    tier), and the chaos bench's section dict.
    """
    cells: dict[str, dict[str, float]] = {}

    def _name_listed(c: Mapping) -> str:
        scenario = c.get("scenario", "?")
        who = c.get("arch") or c.get("embedding_method", "?")
        name = f"{scenario}/{who}"
        if "bits" in c and c["bits"] != 8:
            name += f"/bits{c['bits']}"
        if c.get("cold_tier"):
            name += "/cold"
        elif c.get("cache_rows"):
            name += f"/hot{c['cache_rows']}"
        elif "cache_rows" in c:
            name += "/uncached"
        return name

    raw = doc.get("cells")
    if isinstance(raw, Mapping):
        for name, cell in raw.items():
            cells[name] = _flatten(cell)
    elif isinstance(raw, list):
        for cell in raw:
            cells[_name_listed(cell)] = _flatten(cell)
    for section in ("lm", "ctr"):
        for cell in doc.get(section, []) or []:
            cells[_name_listed(cell)] = _flatten(cell)
    for section in ("guard_overhead", "obs_overhead", "chaos_serving"):
        cell = doc.get(section)
        if isinstance(cell, Mapping):
            cells[section] = _flatten(cell)
    return cells


# ------------------------------------------------------------------ seed


def seed_baseline(bench_docs: Mapping[str, Mapping],
                  tolerances: Mapping[str, float] | None = None) -> dict:
    """Build a baseline document from {artifact filename: parsed json}."""
    benches: dict = {}
    for fname in sorted(bench_docs):
        cells_out: dict = {}
        for cname, metrics in sorted(extract_cells(bench_docs[fname]).items()):
            gated = {}
            for key, val in sorted(metrics.items()):
                kind = classify(key)
                if kind is None:
                    continue
                gated[key] = {"value": val, "kind": kind}
            if gated:
                cells_out[cname] = gated
        if cells_out:
            benches[fname] = {"cells": cells_out}
    return {
        "schema": SCHEMA,
        "tolerances": dict(tolerances or DEFAULT_TOLERANCES),
        "benches": benches,
    }


# ------------------------------------------------------------------ check


def _allowed(kind: str, base: float, tol: float) -> tuple[float, bool]:
    """(threshold, higher_is_better) for one baselined metric."""
    if kind == "rate":
        return base - tol, True
    if kind in ("count", "frac"):
        return base + tol, False
    return base * (1.0 + tol), False  # time / bytes: relative


def compare(baseline: Mapping,
            fresh_docs: Mapping[str, Mapping]) -> list[GateFinding]:
    """Every way the fresh artifacts regress from (or fail to cover) the
    baseline.  Empty list = gate passes."""
    findings: list[GateFinding] = []
    tols = {**DEFAULT_TOLERANCES, **baseline.get("tolerances", {})}
    for fname, bench in baseline.get("benches", {}).items():
        doc = fresh_docs.get(fname)
        if doc is None:
            findings.append(GateFinding(
                bench=fname, cell="*", metric="*",
                message=f"baselined artifact {fname} is missing",
            ))
            continue
        fresh_cells = extract_cells(doc)
        for cname, metrics in bench.get("cells", {}).items():
            fresh = fresh_cells.get(cname)
            if fresh is None:
                findings.append(GateFinding(
                    bench=fname, cell=cname, metric="*",
                    message="baselined cell is missing from the artifact",
                ))
                continue
            for key, spec in metrics.items():
                base = float(spec["value"])
                kind = spec.get("kind") or classify(key) or "time"
                tol = spec.get("tol", tols.get(kind, 0.0))
                if key not in fresh:
                    findings.append(GateFinding(
                        bench=fname, cell=cname, metric=key, baseline=base,
                        message="baselined metric is missing from the cell",
                    ))
                    continue
                val = fresh[key]
                thresh, higher_better = _allowed(kind, base, tol)
                bad = val < thresh if higher_better else val > thresh
                if bad:
                    direction = "below" if higher_better else "above"
                    findings.append(GateFinding(
                        bench=fname, cell=cname, metric=key,
                        baseline=base, fresh=val,
                        message=(
                            f"{kind} metric regressed: {val:g} is "
                            f"{direction} the allowed {thresh:g} "
                            f"(baseline {base:g}, tol {tol:g})"
                        ),
                    ))
    return findings


def load_baseline(path: str | pathlib.Path) -> dict:
    doc = json.loads(pathlib.Path(path).read_text())
    if doc.get("schema") != SCHEMA:
        raise ValueError(
            f"{path}: schema {doc.get('schema')!r} != {SCHEMA!r}"
        )
    return doc


def load_fresh(root: str | pathlib.Path,
               baseline: Mapping) -> dict[str, dict]:
    """The baselined artifacts found under ``root`` ({filename: doc})."""
    root = pathlib.Path(root)
    out = {}
    for fname in baseline.get("benches", {}):
        p = root / fname
        if p.exists():
            out[fname] = json.loads(p.read_text())
    return out


# ------------------------------------------------------------------ CLI


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs.gate",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    seed = sub.add_parser("seed", help="build a baseline from artifacts")
    seed.add_argument("artifacts", nargs="+",
                      help="BENCH_*.json files to baseline")
    seed.add_argument("--out", default="BENCH_BASELINE.json")
    check = sub.add_parser("check", help="compare artifacts to the baseline")
    check.add_argument("--baseline", default="BENCH_BASELINE.json")
    check.add_argument("--root", default=".",
                       help="directory holding the fresh BENCH_*.json files")
    check.add_argument("--report", default=None,
                       help="write the findings as JSON here (CI artifact)")
    args = ap.parse_args(argv)

    if args.cmd == "seed":
        docs = {
            pathlib.Path(p).name: json.loads(pathlib.Path(p).read_text())
            for p in args.artifacts
        }
        doc = seed_baseline(docs)
        pathlib.Path(args.out).write_text(json.dumps(doc, indent=2) + "\n")
        n = sum(len(b["cells"]) for b in doc["benches"].values())
        print(f"[obs.gate] seeded {args.out}: {n} cells "
              f"from {len(doc['benches'])} artifacts")
        return 0

    baseline = load_baseline(args.baseline)
    fresh = load_fresh(args.root, baseline)
    findings = compare(baseline, fresh)
    if args.report:
        pathlib.Path(args.report).write_text(json.dumps(
            [f.to_json() for f in findings], indent=2) + "\n")
    for f in findings:
        print(f"[obs.gate] {f.bench} :: {f.cell} :: {f.metric}: {f.message}")
    print(f"[obs.gate] {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
