"""Streaming quantile estimation — P² (Jain & Chlamtac, 1985).

One :class:`P2Quantile` tracks a single quantile in O(1) memory with five
markers; :class:`StreamingQuantiles` bundles the p50/p95/p99 set (plus
count/min/max/mean) that serving latency reports and per-host step-time
summaries carry into the BENCH json.

Pure host-side Python over floats: the estimators never see device values
(callers time with ``time.perf_counter`` and feed seconds or µs), so
instrumenting a loop with one changes nothing about the traced computation.

Accuracy: exact through the first five observations, then the classic P²
parabolic-marker approximation — tests/test_obs.py holds it against
``numpy.percentile`` on large samples.
"""
from __future__ import annotations

import math


class P2Quantile:
    """Streaming estimate of one quantile ``q`` in (0, 1), O(1) memory."""

    def __init__(self, q: float) -> None:
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1); got {q}")
        self.q = q
        self.count = 0
        self._heights: list[float] = []  # marker heights (first 5: buffer)
        # Marker positions (1-based, as in the paper), desired positions,
        # and their per-observation increments — set once 5 samples exist.
        self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
        self._want = [1.0, 1 + 2 * q, 1 + 4 * q, 3 + 2 * q, 5.0]
        self._dwant = [0.0, q / 2, q, (1 + q) / 2, 1.0]

    def add(self, x: float) -> None:
        x = float(x)
        self.count += 1
        h = self._heights
        if self.count <= 5:
            h.append(x)
            h.sort()
            return

        # Locate the cell k (0..3) holding x, extending extremes in place.
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and not (h[k] <= x < h[k + 1]):
                k += 1

        for i in range(k + 1, 5):
            self._pos[i] += 1.0
        for i in range(5):
            self._want[i] += self._dwant[i]

        # Adjust the three interior markers toward their desired positions.
        for i in (1, 2, 3):
            d = self._want[i] - self._pos[i]
            if (d >= 1.0 and self._pos[i + 1] - self._pos[i] > 1.0) or (
                d <= -1.0 and self._pos[i - 1] - self._pos[i] < -1.0
            ):
                d = math.copysign(1.0, d)
                cand = self._parabolic(i, d)
                if not (h[i - 1] < cand < h[i + 1]):
                    cand = self._linear(i, d)
                h[i] = cand
                self._pos[i] += d

    def _parabolic(self, i: int, d: float) -> float:
        h, n = self._heights, self._pos
        return h[i] + d / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + d) * (h[i + 1] - h[i]) / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - d) * (h[i] - h[i - 1]) / (n[i] - n[i - 1])
        )

    def _linear(self, i: int, d: float) -> float:
        h, n = self._heights, self._pos
        j = i + int(d)
        return h[i] + d * (h[j] - h[i]) / (n[j] - n[i])

    def value(self) -> float:
        """Current estimate (exact while count <= 5; nan when empty)."""
        if self.count == 0:
            return math.nan
        h = self._heights
        if self.count <= 5:
            # Exact linear-interpolated percentile of the buffered sample.
            rank = self.q * (len(h) - 1)
            lo = int(rank)
            hi = min(lo + 1, len(h) - 1)
            return h[lo] + (rank - lo) * (h[hi] - h[lo])
        return h[2]


class StreamingQuantiles:
    """The p50/p95/p99 bundle plus count/min/max/mean, streamed in O(1)."""

    DEFAULT_QS = (0.5, 0.95, 0.99)

    def __init__(self, qs: tuple[float, ...] = DEFAULT_QS) -> None:
        self._est = {q: P2Quantile(q) for q in qs}
        self.count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def add(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self._sum += x
        self._min = min(self._min, x)
        self._max = max(self._max, x)
        for est in self._est.values():
            est.add(x)

    def quantile(self, q: float) -> float:
        return self._est[q].value()

    def to_json(self) -> dict:
        """Stable summary schema: {count, mean, min, max, p50, p95, p99}.

        Empty estimators report ``count: 0`` and omit the moments — a bench
        cell with no samples must not serialize NaN into its artifact.
        """
        if self.count == 0:
            return {"count": 0}
        out = {
            "count": self.count,
            "mean": self._sum / self.count,
            "min": self._min,
            "max": self._max,
        }
        for q, est in sorted(self._est.items()):
            out[f"p{round(q * 100)}"] = est.value()
        return out
