"""`repro.obs` — the single observability layer for the whole stack.

Four pieces, one contract:

* :mod:`repro.obs.counters` — a typed, namespaced counter/gauge registry
  every existing telemetry surface re-registers into (kernel fallback
  tallies, Engine/cache counters, fault-guard and retry counters), so CLI
  reports and bench artifacts read one ``to_json()`` schema instead of four.
* :mod:`repro.obs.trace` — host-side span tracing with Chrome-trace JSON
  export (``--trace-out`` on both launch CLIs).  Spans wrap *host*
  boundaries only (trainer step edge, cache write-back, Engine waves,
  storage tier events, checkpoint save/restore); device-sync fences run
  only at span edges and only while tracing is enabled.
* :mod:`repro.obs.stats` — streaming quantile estimation (P²) behind the
  per-host step-time quantiles and serving p50/p95/p99 that the BENCH
  artifacts carry.
* :mod:`repro.obs.gate` — the perf-regression gate: compares BENCH_*.json
  artifacts against the committed ``BENCH_BASELINE.json`` and fails
  ``python -m repro.analysis`` the way a jaxpr contract violation does.

The hard contract: obs-on changes no jitted computation.  Spans never enter
traced code, instrumented runs are bitwise-equal to uninstrumented
(tests/test_obs.py), and measured overhead is asserted ≤3% in the e2e bench.
"""
from __future__ import annotations

from repro.obs.counters import (  # noqa: F401
    Counter,
    Gauge,
    Registry,
    Snapshot,
    registry,
)
from repro.obs.trace import tracer  # noqa: F401
