"""Host-side span tracing with Chrome-trace JSON export.

Spans wrap **host** boundaries only — they never enter traced code, so an
instrumented run executes the exact same jitted programs as an
uninstrumented one (the bitwise-parity contract in tests/test_obs.py).  The
trainer's fused step is one jitted function by design (splitting it would
change the compiled program); its in-jit phases — lookup/grad/sync/update —
are therefore indivisible from the host, and the span catalog instruments
the real host seams around them:

    train.step        one fused trainer step (fenced at the span edge)
    train.writeback   host cache-policy write-back after the step
    train.refresh     host-refresh phase (methods that rebuild host state)
    ckpt.save         checkpoint write (atomic rename included)
    ckpt.restore      checkpoint read + verify
    engine.wave       one Engine scheduler step (prefill/score/decode
                      children where the frontend separates them)
    engine.prefill    LM prefill of one admitted request
    engine.decode     LM decode step across active slots
    engine.score      CTR wave scoring
    storage.cold.fetch     demand host->device row fetch
    storage.cold.prefetch  staging of the next wave's gather
    storage.writeback      dirty hot-row write-back to the backing tier

plus per-request async spans (``request/<rid>``) from submit to finish.

Device-sync fences run **only at span edges and only while tracing is
enabled** (:meth:`Tracer.fence`): with tracing off the fence is a no-op and
dispatch stays fully async.  The fence call is the repo's single reviewed
exception to the ``no-host-sync`` lint rule (analysis-suppressions.txt).

Disabled-path cost: ``tracer().span(...)`` returns a shared null context
manager — no allocation, no clock read.  Overhead of the *enabled* path is
measured and asserted ≤3% in benchmarks/e2e_step_bench.py.

Export is the Chrome trace-event JSON format: load the file in
``chrome://tracing`` or https://ui.perfetto.dev.
"""
from __future__ import annotations

import contextlib
import json
import os
import time
from typing import Any

_NULL_CM = contextlib.nullcontext()


class _Span:
    """Context manager for one complete ('X') trace event."""

    __slots__ = ("_tracer", "_name", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict) -> None:
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter_ns()
        self._tracer._depth += 1
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter_ns()
        tr = self._tracer
        tr._depth -= 1
        tr._events.append({
            "ph": "X",
            "name": self._name,
            "cat": self._name.split(".", 1)[0],
            "ts": (self._t0 - tr._epoch_ns) / 1e3,
            "dur": (t1 - self._t0) / 1e3,
            "pid": tr._pid,
            "tid": 0,
            **({"args": self._args} if self._args else {}),
        })


class Tracer:
    """Span collector; a process-global instance lives behind :func:`tracer`.

    Disabled by default.  ``enable(path)`` arms it and records the export
    path; ``export()`` writes the Chrome-trace JSON (called by the launch
    CLIs at end of run, or explicitly).
    """

    def __init__(self) -> None:
        self.enabled = False
        self.out_path: str | None = None
        self._events: list[dict] = []
        self._epoch_ns = time.perf_counter_ns()
        self._pid = os.getpid()
        self._depth = 0

    # ------------------------------------------------------------ control

    def enable(self, out_path: str | None = None) -> None:
        self.enabled = True
        self.out_path = out_path
        self._epoch_ns = time.perf_counter_ns()

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        self._events.clear()
        self._epoch_ns = time.perf_counter_ns()

    @property
    def events(self) -> list[dict]:
        return list(self._events)

    # ------------------------------------------------------------ spans

    def span(self, name: str, **args: Any):
        """Context manager timing one host-side phase (nesting = call nesting
        in the exported trace).  Near-zero cost while disabled."""
        if not self.enabled:
            return _NULL_CM
        return _Span(self, name, args)

    def instant(self, name: str, **args: Any) -> None:
        """Zero-duration marker ('i') — fault injections, straggler flags."""
        if not self.enabled:
            return
        self._events.append({
            "ph": "i", "s": "t",
            "name": name,
            "cat": name.split(".", 1)[0],
            "ts": (time.perf_counter_ns() - self._epoch_ns) / 1e3,
            "pid": self._pid, "tid": 0,
            **({"args": args} if args else {}),
        })

    def async_begin(self, name: str, aid: int, **args: Any) -> None:
        """Open one async span ('b') — e.g. a request entering the queue."""
        if not self.enabled:
            return
        self._events.append({
            "ph": "b", "cat": name.split(".", 1)[0],
            "name": name, "id": aid,
            "ts": (time.perf_counter_ns() - self._epoch_ns) / 1e3,
            "pid": self._pid, "tid": 0,
            **({"args": args} if args else {}),
        })

    def async_end(self, name: str, aid: int, **args: Any) -> None:
        if not self.enabled:
            return
        self._events.append({
            "ph": "e", "cat": name.split(".", 1)[0],
            "name": name, "id": aid,
            "ts": (time.perf_counter_ns() - self._epoch_ns) / 1e3,
            "pid": self._pid, "tid": 0,
            **({"args": args} if args else {}),
        })

    # ------------------------------------------------------------ fences

    def fence(self, value: Any) -> Any:
        """Device-sync fence at a span *edge*.

        While tracing, block until ``value``'s device work is done so the
        enclosing span measures compute, not dispatch.  While disabled this
        is a pure pass-through — no sync, dispatch stays async.  This is the
        one reviewed ``no-host-sync`` exception (analysis-suppressions.txt):
        it is host code at a span boundary, never inside a step function.
        """
        if self.enabled and value is not None:
            import jax

            jax.block_until_ready(value)
        return value

    # ------------------------------------------------------------ export

    def to_chrome_trace(self) -> dict:
        return {"traceEvents": list(self._events), "displayTimeUnit": "ms"}

    def export(self, path: str | None = None) -> str | None:
        """Write the Chrome-trace JSON; returns the path written (or None
        when there is nowhere to write)."""
        path = path or self.out_path
        if path is None:
            return None
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path


_TRACER = Tracer()


def tracer() -> Tracer:
    """The process-global tracer every instrumented surface shares."""
    return _TRACER
