"""Synthetic LM token pipeline: deterministic, shardable, restart-replayable.

Sequences come from a tiny order-2 Markov chain over the vocab so there is
real signal for a LM to learn (loss decreases measurably within a few hundred
steps on the ~100M-class examples), unlike uniform random tokens.
"""
from __future__ import annotations

import numpy as np


class LMTokenStream:
    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0,
                 n_states: int = 64):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.seed = seed
        rng = np.random.RandomState(seed)
        self.n_states = n_states
        # Hidden Markov structure: state -> state transitions + state -> token
        # emission concentrated on a small token subset per state.
        self.trans = rng.dirichlet(np.ones(n_states) * 0.1, size=n_states)
        emit_support = rng.randint(0, vocab_size, size=(n_states, 32))
        self.emit_support = emit_support
        self.emit_probs = rng.dirichlet(np.ones(32) * 0.5, size=n_states)

    def batch(self, index: int, batch_size: int) -> np.ndarray:
        """Deterministic int32 [batch, seq_len+1] (inputs + next-token labels)."""
        rng = np.random.RandomState((self.seed * 7_368_787 + index) % (2**31 - 1))
        out = np.zeros((batch_size, self.seq_len + 1), np.int32)
        state = rng.randint(0, self.n_states, size=batch_size)
        for t in range(self.seq_len + 1):
            # Vectorized emission + transition.
            u = rng.uniform(size=batch_size)
            cum = np.cumsum(self.emit_probs[state], axis=1)
            pick = (u[:, None] < cum).argmax(axis=1)
            out[:, t] = self.emit_support[state, pick]
            u2 = rng.uniform(size=batch_size)
            cumt = np.cumsum(self.trans[state], axis=1)
            state = (u2[:, None] < cumt).argmax(axis=1)
        return out

    def batches(self, batch_size: int, num_batches: int, start: int = 0):
        for i in range(start, start + num_batches):
            b = self.batch(i, batch_size)
            yield b[:, :-1], b[:, 1:]
