from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic, criteo_like, avazu_like
from repro.data.lm_synth import LMTokenStream

__all__ = [
    "CTRDatasetConfig",
    "CTRSynthetic",
    "criteo_like",
    "avazu_like",
    "LMTokenStream",
]
