"""Synthetic CTR datasets shaped like Criteo / Avazu (paper §4.1).

Criteo/Avazu cannot be downloaded in this environment, so we generate a
dataset with the same *structure*: F categorical fields with power-law
(Zipf) value frequencies, and labels from a planted factorization-machine
teacher — first-order weights + pairwise latent interactions — so that a model
which learns good embeddings gets high AUC and a broken one does not.
Reproduction claims are therefore relative orderings (see DESIGN.md §7).

Feature ids are global: field f's values occupy [offset_f, offset_f + card_f),
matching the single-embedding-table layout CTR systems use.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CTRDatasetConfig:
    name: str
    n_fields: int
    cardinalities: tuple[int, ...]  # per-field number of distinct values
    teacher_rank: int = 8  # latent dim of the planted FM teacher
    zipf_a: float = 1.2  # power-law exponent for value frequencies
    label_noise: float = 0.1  # fraction of teacher logit replaced by noise
    seed: int = 0

    @property
    def n_features(self) -> int:
        return int(sum(self.cardinalities))

    @property
    def offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.cardinalities)[:-1]]).astype(
            np.int64
        )


def _powerlaw_cards(n_fields: int, total: int, seed: int) -> tuple[int, ...]:
    """Field cardinalities spanning 4 orders of magnitude, like real CTR data."""
    rng = np.random.RandomState(seed)
    raw = np.exp(rng.uniform(np.log(4), np.log(total / 4), n_fields))
    raw = raw / raw.sum() * total
    return tuple(int(max(c, 4)) for c in raw)


def criteo_like(scale: float = 1.0, seed: int = 0) -> CTRDatasetConfig:
    """39 fields (26 categorical + 13 discretized numeric), ~1.1M features."""
    total = int(1_086_895 * scale)
    return CTRDatasetConfig(
        name="criteo-synth",
        n_fields=39,
        cardinalities=_powerlaw_cards(39, total, seed),
        seed=seed,
    )


def avazu_like(scale: float = 1.0, seed: int = 1) -> CTRDatasetConfig:
    """24 fields (21 categorical + hour/weekday/is_weekend), ~4.4M features."""
    total = int(4_428_293 * scale)
    return CTRDatasetConfig(
        name="avazu-synth",
        n_fields=24,
        cardinalities=_powerlaw_cards(24, total, seed),
        seed=seed,
    )


class CTRSynthetic:
    """Deterministic batch generator with train/valid/test splits.

    Batches are (ids int32 [B, F], labels float32 [B]); the generator is
    stateless in the sample index so any worker can reproduce any batch —
    this is what makes restart-replay (launch/train.py) exact.
    """

    def __init__(self, cfg: CTRDatasetConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        self.offsets = cfg.offsets
        # Planted teacher: first-order weight + rank-r latent per feature.
        n = cfg.n_features
        self.teacher_w = rng.normal(0.0, 1.0, n).astype(np.float32)
        self.teacher_v = rng.normal(
            0.0, 1.0 / np.sqrt(cfg.teacher_rank), (n, cfg.teacher_rank)
        ).astype(np.float32)
        self.bias = -0.7  # CTR datasets are imbalanced (~25% positive)
        # Zipf sampling tables per field (truncated, renormalized).
        self._field_probs = []
        for card in cfg.cardinalities:
            ranks = np.arange(1, card + 1, dtype=np.float64)
            p = ranks ** (-cfg.zipf_a)
            self._field_probs.append((p / p.sum()).astype(np.float64))

    def _sample_ids(self, rng: np.random.RandomState, batch: int) -> np.ndarray:
        cols = []
        for f, card in enumerate(self.cfg.cardinalities):
            vals = rng.choice(card, size=batch, p=self._field_probs[f])
            cols.append(vals + self.offsets[f])
        return np.stack(cols, axis=1).astype(np.int32)

    def _teacher_logit(self, ids: np.ndarray) -> np.ndarray:
        w = self.teacher_w[ids].sum(axis=1)
        v = self.teacher_v[ids]  # [B, F, r]
        s = v.sum(axis=1)
        pair = 0.5 * ((s * s).sum(axis=1) - (v * v).sum(axis=(1, 2)))
        # Normalize pair term so neither term dominates.
        return self.bias + 0.3 * w + 0.1 * pair

    def batch(self, split: str, index: int, batch_size: int):
        """Deterministic (ids, labels) for (split, index)."""
        salt = {"train": 0, "valid": 1_000_003, "test": 2_000_003}[split]
        rng = np.random.RandomState(
            (self.cfg.seed * 9_176_161 + salt + index) % (2**31 - 1)
        )
        ids = self._sample_ids(rng, batch_size)
        logit = self._teacher_logit(ids)
        noise = rng.normal(0.0, 1.0, batch_size)
        z = (1 - self.cfg.label_noise) * logit + self.cfg.label_noise * noise
        p = 1.0 / (1.0 + np.exp(-z))
        labels = (rng.uniform(size=batch_size) < p).astype(np.float32)
        return ids, labels

    def batches(self, split: str, batch_size: int, num_batches: int):
        for i in range(num_batches):
            yield self.batch(split, i, batch_size)
