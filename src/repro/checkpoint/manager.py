"""Fault-tolerant checkpointing: atomic step directories, keep-k GC, integer
tables (codes + Delta) saved as-is, elastic restore onto a different mesh.

Layout:
  <dir>/step_000120/
    manifest.json       # step, config hash, rng, leaf index, tree structure
    leaf_00000.npy ...  # one .npy per pytree leaf (int8 codes stay int8)
  <dir>/step_000120.COMMITTED   # empty marker written LAST (atomic rename)

Multi-host note: in a real cluster each process writes only its addressable
shards and process 0 writes the manifest; on this single-process container
every array is fully addressable so the save path is the degenerate case of
the same protocol.  Restore re-shards with jax.device_put against the current
mesh, which is what makes 256 -> 512 chip elasticity work (the dry-run proves
both meshes lower the same step function).
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import tempfile
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import counters as obs_counters
from repro.obs.trace import tracer

_REG = obs_counters.registry()
_MET_SAVES = _REG.counter("ckpt.saves", "checkpoints written")
_MET_RESTORES = _REG.counter("ckpt.restores", "checkpoints restored")
_MET_CORRUPT = _REG.counter(
    "ckpt.corrupt_refused", "restores refused on verification failure"
)


class CorruptCheckpointError(RuntimeError):
    """A committed artifact failed checksum/parse verification on restore."""


def _fsync(path: pathlib.Path) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _tree_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def config_hash(cfg: Any) -> str:
    return hashlib.sha256(repr(cfg).encode()).hexdigest()[:16]


def embedding_storage(spec: Any) -> dict:
    """Code-container layout for a manifest: whether codes are stored packed
    (sub-byte widths share bytes), how many codes ride per resident byte, and
    the bit layout — so a restore can refuse a packed artifact loaded under
    an unpacked config (same logical shapes, different bytes) and vice
    versa."""
    from repro.core import codestore

    packed = bool(getattr(spec, "packed", True)) and codestore.is_packable(
        spec.bits
    )
    return {
        "bits": spec.bits,
        "packed": packed,
        "codes_per_byte": codestore.codes_per_byte(spec.bits) if packed else 1,
        "layout": "low-bits-first",
    }


def embedding_manifest(spec: Any) -> dict:
    """Embedding-method checkpoint metadata for a manifest's ``extra_meta``:
    the registered method's name, capability flags, leaf schema, and code
    container layout — so a restore can detect a method mismatch (e.g. int8
    codes restored into an fp template) before shapes happen to collide."""
    from repro import methods

    method = methods.get(spec.method)
    return {
        "embedding_method": spec.method,
        "embedding_capabilities": method.capabilities(),
        "embedding_schema": method.checkpoint_schema(spec),
        "embedding_storage": embedding_storage(spec),
    }


def check_embedding_manifest(manifest: dict, spec: Any) -> list[str]:
    """Mismatches between a loaded manifest and the expected ``spec``
    (empty list == compatible, or no embedding metadata recorded)."""
    saved = manifest.get("embedding_method")
    if saved is None:
        return []
    problems = []
    if saved != spec.method:
        problems.append(
            f"checkpoint embedding method {saved!r} != configured {spec.method!r}"
        )
    from repro import methods

    schema = methods.get(spec.method).checkpoint_schema(spec)
    if manifest.get("embedding_schema", schema) != schema:
        problems.append("embedding table schema differs (shape/dtype/leaves)")
    storage = embedding_storage(spec)
    if manifest.get("embedding_storage", storage) != storage:
        problems.append(
            "embedding storage layout differs (bits/packing/container)"
        )
    return problems


def serving_template(spec: Any):
    """ShapeDtypeStruct pytree of the *serving-resident* state for ``spec``
    (the method's ``serving_state`` export: codes + scales for integer
    tables) — the restore template a serving process builds without ever
    initializing or materializing a training table."""
    from repro import methods

    method = methods.get(spec.method)

    def resident(key):
        return method.serving_state(method.init(key, spec), spec)

    return jax.eval_shape(resident, jax.ShapeDtypeStruct((2,), jnp.uint32))


def save_serving_checkpoint(directory: str | os.PathLike, *, step: int,
                            params: Any, table: Any, spec: Any) -> pathlib.Path:
    """Serving export: model/dense params + the serving-*resident* table.

    ``table`` may be the training-time method state (converted through
    ``serving_state`` here) or an already-built serving table.  Either way
    the artifact holds inference state only — int8 codes + scale vectors for
    integer-table methods, never the fp32 table and never training-only
    leaves (Adam moments, schedule clocks).  The manifest carries
    :func:`embedding_manifest` so a restore detects a method/geometry
    mismatch before any array is loaded.
    """
    from repro import methods
    from repro.serving import table as serving_tbl

    if not serving_tbl.is_serving_table(table):
        table = methods.get(spec.method).serving_state(table, spec)
    return save_pytree(
        {"params": params, "table": table}, directory, step=step,
        extra_meta=embedding_manifest(spec),
    )


def restore_serving_checkpoint(directory: str | os.PathLike, spec: Any,
                               params_template: Any, *,
                               step: int | None = None):
    """Restore a serving checkpoint: ``(params, serving_table, manifest)``.

    The table template comes from the method registry
    (:func:`serving_template`), so int8 codes restore as int8 and go
    straight into residency — the fp32 table never exists on the restore
    path.  A manifest whose embedding method or schema disagrees with
    ``spec`` raises before loading arrays.
    """
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    manifest = json.loads(
        (directory / f"step_{step:09d}" / "manifest.json").read_text()
    )
    problems = check_embedding_manifest(manifest, spec)
    if problems:
        raise ValueError(
            "serving restore refused — checkpoint/config mismatch: "
            + "; ".join(problems)
        )
    template = {"params": params_template, "table": serving_template(spec)}
    tree, manifest = load_pytree(template, directory, step=step)
    return tree["params"], tree["table"], manifest


def save_pytree(tree, directory: str | os.PathLike, *, step: int,
                extra_meta: dict | None = None) -> pathlib.Path:
    """Atomic save: write to a temp dir, fsync, rename, then commit-marker."""
    with tracer().span("ckpt.save", step=step):
        out = _save_pytree(tree, directory, step=step, extra_meta=extra_meta)
    _MET_SAVES.inc()
    return out


def _save_pytree(tree, directory: str | os.PathLike, *, step: int,
                 extra_meta: dict | None = None) -> pathlib.Path:
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:09d}"
    tmp = pathlib.Path(
        tempfile.mkdtemp(prefix=f".tmp_step_{step:09d}_", dir=directory)
    )
    flat = _tree_paths(tree)
    index = []
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        # Checksum the artifact bytes as written (header included), so any
        # flipped bit on disk — data or header — fails restore verification.
        crc = zlib.crc32((tmp / fname).read_bytes())
        _fsync(tmp / fname)
        index.append({"path": path, "file": fname, "dtype": str(arr.dtype),
                      "shape": list(arr.shape), "crc32": crc})
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "leaves": index,
        "treedef": str(treedef),
        **(extra_meta or {}),
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    _fsync(tmp / "manifest.json")
    _fsync(tmp)
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic on POSIX
    _fsync(directory)
    marker = directory / f"step_{step:09d}.COMMITTED"
    marker.touch()
    _fsync(directory)
    return final


def load_pytree(template, directory: str | os.PathLike, *, step: int | None = None,
                shardings=None, verify: bool = True):
    """Restore into the structure of ``template``; optionally re-shard.

    Observability: the whole restore is one ``ckpt.restore`` span;
    ``ckpt.restores`` counts successes, ``ckpt.corrupt_refused`` counts
    restores refused by verification.

    ``template`` provides the pytree structure (arrays or ShapeDtypeStructs);
    ``shardings`` (same structure, NamedSharding leaves) re-shards each leaf
    onto the current mesh — different device counts are fine because the save
    format is host-side full arrays.

    ``verify`` checks each leaf artifact against the per-leaf crc32 the save
    recorded and raises :class:`CorruptCheckpointError` on any mismatch or
    unparseable artifact — a corrupted checkpoint is *refused*, never
    half-loaded.  Manifests from before checksums simply skip verification.
    """
    with tracer().span("ckpt.restore", step=-1 if step is None else step):
        try:
            out = _load_pytree(template, directory, step=step,
                               shardings=shardings, verify=verify)
        except CorruptCheckpointError:
            _MET_CORRUPT.inc()
            raise
    _MET_RESTORES.inc()
    return out


def _load_pytree(template, directory: str | os.PathLike, *, step: int | None = None,
                 shardings=None, verify: bool = True):
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    d = directory / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat_t, treedef = jax.tree_util.tree_flatten(template)
    if len(manifest["leaves"]) != len(flat_t):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, template "
            f"{len(flat_t)} — config mismatch?"
        )
    arrays = []
    for e in manifest["leaves"]:
        path = d / e["file"]
        if verify and "crc32" in e:
            crc = zlib.crc32(path.read_bytes())
            if crc != e["crc32"]:
                raise CorruptCheckpointError(
                    f"{path}: crc32 {crc:#010x} != manifest {e['crc32']:#010x}"
                )
        try:
            arrays.append(np.load(path))
        except (ValueError, OSError, EOFError) as err:
            raise CorruptCheckpointError(f"{path}: unreadable leaf: {err}") from err
    for arr, t in zip(arrays, flat_t):
        # np.shape handles scalar pytree leaves (e.g. a python-int modulus).
        if tuple(arr.shape) != tuple(getattr(t, "shape", np.shape(t))):
            raise ValueError(f"shape mismatch {arr.shape} vs {np.shape(t)}")
    if shardings is not None:
        # jit-style prefix broadcast: a sharding sitting at an internal
        # template node (e.g. a CodeStore code container, whose single leaf
        # is the packed data array) applies to every leaf underneath it.
        is_shard = lambda x: isinstance(x, jax.sharding.Sharding)
        expanded = jax.tree_util.tree_map(
            lambda s, sub: jax.tree_util.tree_map(lambda _: s, sub),
            shardings, template, is_leaf=is_shard,
        )
        flat_s = treedef.flatten_up_to(expanded)
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, flat_s)]
    else:
        arrays = [jax.numpy.asarray(a) for a in arrays]
    return treedef.unflatten(arrays), manifest


def latest_step(directory: str | os.PathLike) -> int | None:
    directory = pathlib.Path(directory)
    steps = []
    for marker in directory.glob("step_*.COMMITTED"):
        s = int(marker.stem.split("_")[1])
        if (directory / f"step_{s:09d}" / "manifest.json").exists():
            steps.append(s)
    return max(steps) if steps else None


class CheckpointManager:
    """Keep-k checkpoint rotation + resume + preemption save."""

    def __init__(self, directory: str | os.PathLike, *, keep: int = 3,
                 save_every: int = 100):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self.save_every = save_every
        # Steps refused by restore verification (newest-first fallback walk).
        self.corrupt_steps: list[int] = []

    def maybe_save(self, tree, step: int, *, force: bool = False,
                   extra_meta: dict | None = None) -> bool:
        if not force and (step == 0 or step % self.save_every != 0):
            return False
        save_pytree(tree, self.directory, step=step, extra_meta=extra_meta)
        self._gc()
        return True

    def restore(self, template, shardings=None, step: int | None = None):
        """Restore ``step`` (refusing a corrupted artifact loudly) or, with
        ``step=None``, the newest committed checkpoint that passes
        verification — corrupted ones are skipped (recorded in
        ``self.corrupt_steps``) and the walk falls back to the last good."""
        if step is not None:
            return load_pytree(template, self.directory, step=step,
                               shardings=shardings)
        steps = sorted(
            (
                int(m.stem.split("_")[1])
                for m in self.directory.glob("step_*.COMMITTED")
                if (self.directory / m.stem / "manifest.json").exists()
            ),
            reverse=True,
        )
        if not steps:
            raise FileNotFoundError(
                f"no committed checkpoint in {self.directory}"
            )
        last_err: CorruptCheckpointError | None = None
        for s in steps:
            try:
                return load_pytree(template, self.directory, step=s,
                                   shardings=shardings)
            except CorruptCheckpointError as err:
                last_err = err
                self.corrupt_steps.append(s)
                print(
                    f"[checkpoint] step {s} refused ({err}); "
                    "falling back to previous committed checkpoint"
                )
        assert last_err is not None
        raise CorruptCheckpointError(
            f"all {len(steps)} committed checkpoints in {self.directory} "
            "failed verification"
        ) from last_err

    def read_manifest(self, step: int) -> dict:
        """The manifest alone (no array loads) — for pre-restore checks."""
        path = self.directory / f"step_{step:09d}" / "manifest.json"
        return json.loads(path.read_text())

    def latest_step(self) -> int | None:
        return latest_step(self.directory)

    def _gc(self):
        steps = sorted(
            int(m.stem.split("_")[1])
            for m in self.directory.glob("step_*.COMMITTED")
        )
        for s in steps[: -self.keep] if self.keep else []:
            # Marker first: a crash between the two leaves an uncommitted
            # (invisible) directory, never a committed-but-missing one.
            (self.directory / f"step_{s:09d}.COMMITTED").unlink(missing_ok=True)
            shutil.rmtree(self.directory / f"step_{s:09d}", ignore_errors=True)
