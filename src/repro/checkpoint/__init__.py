from repro.checkpoint.manager import (
    CheckpointManager,
    check_embedding_manifest,
    embedding_manifest,
    load_pytree,
    restore_serving_checkpoint,
    save_pytree,
    save_serving_checkpoint,
    serving_template,
)

__all__ = [
    "CheckpointManager",
    "check_embedding_manifest",
    "embedding_manifest",
    "load_pytree",
    "restore_serving_checkpoint",
    "save_pytree",
    "save_serving_checkpoint",
    "serving_template",
]
