from repro.checkpoint.manager import (
    CheckpointManager,
    check_embedding_manifest,
    embedding_manifest,
    load_pytree,
    save_pytree,
)

__all__ = [
    "CheckpointManager",
    "check_embedding_manifest",
    "embedding_manifest",
    "load_pytree",
    "save_pytree",
]
