"""DeepLight-style magnitude pruning baseline (Deng et al. 2021; paper §4.1/B.2).

Train dense for a warmup, then prune-and-retrain with a schedule where the
pruning ratio grows as  R_x * (1 - D^{k/U})  (R_x target sparsity, k current
step, D/U damping).  Pruned weights may grow back: the mask is recomputed from
current magnitudes every ``update_every`` steps rather than frozen.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class PruneState(NamedTuple):
    weights: jax.Array  # f32 [n, d]
    mask: jax.Array  # bool [n, d]
    step: jax.Array  # int32 scalar (pruning-schedule clock)


class PruneConfig(NamedTuple):
    target_sparsity: float = 0.5  # R_x (paper: 0.5 -> 2x inference ratio)
    damping: float = 0.99  # D
    damping_steps: int = 3000  # U
    warmup_steps: int = 200
    update_every: int = 10


def init_prune(key: jax.Array, n: int, d: int, *, init_scale: float = 1e-2):
    w = jax.random.normal(key, (n, d), jnp.float32) * init_scale
    return PruneState(weights=w, mask=jnp.ones((n, d), bool), step=jnp.zeros((), jnp.int32))


def prune_ratio(cfg: PruneConfig, step: jax.Array) -> jax.Array:
    """R_x * (1 - D^{k/U}) after warmup, 0 before."""
    k = jnp.maximum(step.astype(jnp.float32) - cfg.warmup_steps, 0.0)
    return jnp.where(
        step < cfg.warmup_steps,
        0.0,
        cfg.target_sparsity * (1.0 - cfg.damping ** (k / cfg.damping_steps)),
    )


def update_mask(state: PruneState, cfg: PruneConfig) -> PruneState:
    """Recompute the magnitude mask at the scheduled ratio (regrowth allowed)."""
    ratio = prune_ratio(cfg, state.step)
    flat = jnp.abs(state.weights).reshape(-1)
    # Threshold = ratio-quantile of |w|; quantile of 0 keeps everything.
    thresh = jnp.quantile(flat, ratio)
    mask = jnp.abs(state.weights) > thresh
    # Never prune everything: keep mask unchanged if ratio == 0.
    mask = jnp.where(ratio > 0.0, mask, state.weights == state.weights)
    return state._replace(mask=mask)


def prune_lookup(state: PruneState, ids: jax.Array) -> jax.Array:
    w = jnp.take(state.weights, ids, axis=0)
    m = jnp.take(state.mask, ids, axis=0)
    return w * m


def sparsity(state: PruneState) -> jax.Array:
    return 1.0 - jnp.mean(state.mask.astype(jnp.float32))
