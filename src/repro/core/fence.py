"""Compilation fence: run a sub-computation in its own XLA loop body.

The tiered-storage bitwise contract (``repro.storage``: cache-on training is
bitwise-equal to cache-off) needs the model forward/backward to compile
identically whatever embedding-storage graph surrounds it — plain codes, a
packed container, or a hot-row-cache overlay.  XLA does not honor that by
default: its fusion pass freely duplicates producers into consumers across
``optimization_barrier`` (the barrier is expanded before late CPU fusion),
and re-fusing an elementwise neighborhood into a dot's loop nest shifts the
reduction's rounding by a ULP.  Two differently-shaped modules around one
identical backward can therefore disagree in the last bit.

The one boundary XLA never fuses across is a ``while`` body.  ``fence_call``
runs ``f`` inside a trip-count-1 loop built so the compiler cannot dissolve
it:

* the trip count derives from a runtime scalar (``tick``), so the
  trip-count-1 unroller cannot prove it is 1;
* the arguments ride in the loop carry and are re-tied to ``tick`` with a
  select inside the body, so neither the while-tuple simplifier nor
  loop-invariant code motion can hoist the computation out.

The body becomes a standalone HLO computation; identical bodies optimize
identically, so equal inputs give bitwise-equal outputs across modules.
Cost: one zero-initialized output buffer plus an elementwise select over the
arguments per call — noise next to a training step's matmuls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["fence_call"]


def fence_call(f, args: tuple, tick):
    """``f(*args)``, compiled as its own while-loop body.

    ``tick`` must be a *traced* scalar that is non-negative at runtime (a
    step counter, a feature id, ...).  A Python/concrete constant defeats
    the fence — XLA folds the trip count and inlines the body — so pass
    something that reaches the jitted computation as an input.  ``f`` must
    be shape-stable and is evaluated exactly once.
    """
    out0 = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), jax.eval_shape(f, *args)
    )
    tick = jnp.asarray(tick).astype(jnp.int32).reshape(())
    trip = jnp.where(tick >= 0, jnp.int32(1), jnp.int32(2))

    def body(i, carry):
        a, _ = carry
        a = jax.tree_util.tree_map(
            lambda x: jnp.where(tick >= 0, x, jnp.zeros_like(x)), a
        )
        return (a, f(*a))

    _, out = jax.lax.fori_loop(0, trip, body, (args, out0))
    return out
