"""Uniform symmetric quantization primitives (paper §2.1).

For m-bit quantization the code book is S = {b_0, ..., b_k}, k = 2^m - 1,
b_i = Delta * i with integer codes in [-2^{m-1}, 2^{m-1} - 1].

Two rounding functions (Eq. 3/4):
  * deterministic rounding (DR): round-to-nearest (ties to +inf, matching Eq. 3)
  * stochastic rounding (SR):   floor(x) + Bernoulli(frac(x))

All functions support a scalar step size or a per-row step size broadcast against
the trailing embedding dimension (feature-wise Delta, paper §3.2).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

Rounding = Literal["dr", "sr"]

# int8 container is used for every bit width m <= 8; the *code range* is what
# changes with m.  This matches deployment practice (sub-byte packing is a
# storage-format detail; see kernels/sr_round.py for the packed path).
CODE_DTYPE = jnp.int8


def code_bounds(bits: int) -> tuple[int, int]:
    """Inclusive integer code range [n, p] for m-bit symmetric quantization."""
    if not 2 <= bits <= 8:
        raise ValueError(f"bits must be in [2, 8], got {bits}")
    return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1


def _broadcast_step(w: jax.Array, step: jax.Array) -> jax.Array:
    """Broadcast per-row step sizes against the trailing dim of ``w``."""
    step = jnp.asarray(step, jnp.float32)
    if step.ndim == 0:
        return step
    if step.ndim == w.ndim:
        return step
    if step.ndim == w.ndim - 1:
        return step[..., None]
    raise ValueError(f"step shape {step.shape} incompatible with weights {w.shape}")


def round_deterministic(x: jax.Array) -> jax.Array:
    """Eq. 3: floor(x) if frac < 0.5 else floor(x)+1 (ties round up)."""
    return jnp.floor(x + 0.5)


def round_stochastic(x: jax.Array, noise: jax.Array) -> jax.Array:
    """Eq. 4 with explicit uniform noise in [0, 1): floor(x) + (frac(x) > u).

    P[round up] = frac(x) exactly, so E[round(x)] = x.  Passing the noise in
    (rather than a PRNG key) keeps the Pallas kernel and the oracle bit-exact.
    """
    lo = jnp.floor(x)
    return lo + (x - lo > noise).astype(x.dtype)


def quantize_codes(
    w: jax.Array,
    step: jax.Array,
    bits: int,
    rounding: Rounding = "sr",
    noise: jax.Array | None = None,
) -> jax.Array:
    """Eq. 1: integer codes  R(clip(w / Delta, -2^{m-1}, 2^{m-1}-1)).

    Returns int8 codes (valid range depends on ``bits``).
    """
    n, p = code_bounds(bits)
    step = _broadcast_step(w, step)
    scaled = jnp.clip(w.astype(jnp.float32) / step, n, p)
    if rounding == "dr":
        codes = round_deterministic(scaled)
    elif rounding == "sr":
        if noise is None:
            raise ValueError("stochastic rounding requires noise")
        codes = round_stochastic(scaled, noise)
    else:
        raise ValueError(f"unknown rounding {rounding!r}")
    # SR of a clipped value can round up to p+1 only if scaled == p exactly and
    # frac == 0 -> never; DR of clip <= p likewise. Clip defensively anyway.
    return jnp.clip(codes, n, p).astype(CODE_DTYPE)


def dequantize(codes: jax.Array, step: jax.Array) -> jax.Array:
    """Eq. 2: w_hat = Delta * w_tilde."""
    out = codes.astype(jnp.float32)
    step = _broadcast_step(out, step)
    return out * step


def quantize(
    w: jax.Array,
    step: jax.Array,
    bits: int,
    rounding: Rounding = "sr",
    noise: jax.Array | None = None,
) -> jax.Array:
    """Full quantizer Q(w) = Delta * codes (Eq. 2) — returns float values in S."""
    return dequantize(quantize_codes(w, step, bits, rounding, noise), step)


def sr_noise(key: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    """Uniform [0,1) noise for stochastic rounding."""
    return jax.random.uniform(key, shape, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Straight-through fake-quant + learned step size gradient (LSQ, Eq. 6/7).
# Used by QAT baselines and by ALPT's step-size sub-step (Algorithm 1, line 4).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def fake_quant_lsq(w: jax.Array, step: jax.Array, bits: int, grad_scale: float = 1.0):
    """Forward: Q_D(w, step).  Backward: STE for w, Eq. 7 for step.

    ``grad_scale`` multiplies the step-size gradient (paper §3.2: g = 1/sqrt(b*d*q)).
    """
    return quantize(w, step, bits, rounding="dr")


def _fake_quant_fwd(w, step, bits, grad_scale):
    return fake_quant_lsq(w, step, bits, grad_scale), (w, step)


def _fake_quant_bwd(bits, grad_scale, res, g):
    w, step = res
    n, p = code_bounds(bits)
    stepb = _broadcast_step(w, step)
    scaled = w.astype(jnp.float32) / stepb
    in_range = (scaled > n) & (scaled < p)
    # dQ/dw: straight-through inside the clip range, 0 outside.
    dw = (g * in_range).astype(w.dtype)
    # dQ/dstep (Eq. 7): -2^{m-1} below, 2^{m-1}-1 above, R(w/D) - w/D inside.
    dstep_elem = jnp.where(
        scaled <= n,
        float(n),
        jnp.where(scaled >= p, float(p), round_deterministic(scaled) - scaled),
    )
    dstep_full = g.astype(jnp.float32) * dstep_elem * grad_scale
    # Reduce to the shape of ``step`` (scalar or per-row).
    step_arr = jnp.asarray(step)
    if step_arr.ndim == 0:
        dstep = jnp.sum(dstep_full)
    elif step_arr.ndim == w.ndim - 1:
        dstep = jnp.sum(dstep_full, axis=-1)
    else:
        dstep = dstep_full
    return dw, dstep.astype(step_arr.dtype)


fake_quant_lsq.defvjp(_fake_quant_fwd, _fake_quant_bwd)


# ---------------------------------------------------------------------------
# PACT-style clipping (Choi et al. 2018): learnable clip value alpha,
# uniform quantization of clip(w, -alpha, alpha) with step = alpha / (2^{m-1}-1).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def fake_quant_pact(w: jax.Array, alpha: jax.Array, bits: int):
    p = 2 ** (bits - 1) - 1
    alpha_b = _broadcast_step(w, alpha)
    step = alpha_b / p
    return quantize(w, step, bits, rounding="dr")


def _pact_fwd(w, alpha, bits):
    return fake_quant_pact(w, alpha, bits), (w, alpha)


def _pact_bwd(bits, res, g):
    w, alpha = res
    alpha_b = _broadcast_step(w, alpha)
    inside = jnp.abs(w) < alpha_b
    dw = (g * inside).astype(w.dtype)
    # Outside the clip: d/dalpha clip(w,-a,a) = sign(w). Inside: 0 (PACT).
    dalpha_full = g.astype(jnp.float32) * jnp.where(inside, 0.0, jnp.sign(w)).astype(
        jnp.float32
    )
    alpha_arr = jnp.asarray(alpha)
    if alpha_arr.ndim == 0:
        dalpha = jnp.sum(dalpha_full)
    elif alpha_arr.ndim == w.ndim - 1:
        dalpha = jnp.sum(dalpha_full, axis=-1)
    else:
        dalpha = dalpha_full
    return dw, dalpha.astype(alpha_arr.dtype)


fake_quant_pact.defvjp(_pact_fwd, _pact_bwd)


# ---------------------------------------------------------------------------
# Sub-byte storage: the int8 container is the compute format; for bits <= 4
# the *storage* format packs 8//bits codes per byte.  The container itself
# lives in repro.core.codestore (packed pack4/unpack4 generalized into
# pack_codes/unpack_codes); re-exported here for the quantization API surface.
# ---------------------------------------------------------------------------

from repro.core.codestore import pack_codes, unpack_codes  # noqa: E402


def pack4(codes: jax.Array) -> jax.Array:
    """int8 codes in [-8, 7] -> packed uint8 [n, d//2] (low nibble first).

    Thin wrapper over :func:`repro.core.codestore.pack_codes` at bits=4,
    kept for the historical even-width contract (byte-identical layout).
    """
    if codes.shape[-1] % 2:
        raise ValueError("last dim must be even to pack")
    return pack_codes(codes, 4)


def unpack4(packed: jax.Array) -> jax.Array:
    """Inverse of pack4 -> int8 codes in [-8, 7]."""
    return unpack_codes(packed, 4, packed.shape[-1] * 2)


def init_step_size(w: jax.Array, bits: int, per_row: bool = True) -> jax.Array:
    """LSQ-style init: 2*mean(|w|)/sqrt(p) per row (or globally)."""
    p = 2 ** (bits - 1) - 1
    if per_row:
        mean_abs = jnp.mean(jnp.abs(w), axis=-1)
    else:
        mean_abs = jnp.mean(jnp.abs(w))
    return jnp.maximum(2.0 * mean_abs / jnp.sqrt(float(p)), 1e-8).astype(jnp.float32)
