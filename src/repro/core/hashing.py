"""QR compositional-embedding baseline (Shi et al. 2020; paper §4.1).

The n x d table is replaced by E1 in R^{r x d} (indexed by id % r... paper
text: remainder table is R^{r x d}, quotient table R^{n/r x d}) whose rows are
element-wise multiplied.  Compression ratio ~= n / (r + n/r) per dimension; the
paper uses r such that the ratio is 2x.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class QRTable(NamedTuple):
    remainder: jax.Array  # f32 [r, d]
    quotient: jax.Array  # f32 [ceil(n/r), d]
    r: int


def qr_rows(n: int, compression: float = 2.0) -> tuple[int, int]:
    """(remainder_rows r, quotient_rows ceil(n/r)) such that
    (r + n/r) ~= n / compression (quadratic formula)."""
    target = n / compression
    # r + n/r = target  ->  r^2 - target*r + n = 0
    disc = target * target - 4.0 * n
    if disc <= 0:
        # Static (trace-time) computation — stay in Python math so callers
        # inside jit don't see a tracer.
        r = max(int(n ** 0.5), 2)
    else:
        r = int((target - disc**0.5) / 2.0)
        r = max(r, 2)
    return r, -(-n // r)  # ceil


def init_qr(
    key: jax.Array, n: int, d: int, *, compression: float = 2.0,
    init_scale: float = 1e-2,
) -> QRTable:
    """Pick r so that (r + n/r) ~= n / compression (quadratic formula)."""
    r, q_rows = qr_rows(n, compression)
    k1, k2 = jax.random.split(key)
    return QRTable(
        remainder=jax.random.normal(k1, (r, d), jnp.float32) * init_scale,
        # Quotient table initialized near 1 so the product starts ~= remainder.
        quotient=1.0 + jax.random.normal(k2, (q_rows, d), jnp.float32) * init_scale,
        r=r,
    )


def qr_lookup(table: QRTable, ids: jax.Array) -> jax.Array:
    rem = jnp.take(table.remainder, ids % table.r, axis=0)
    quo = jnp.take(table.quotient, ids // table.r, axis=0)
    return rem * quo


def qr_params(table: QRTable):
    """The trainable leaves (r is static)."""
    return {"remainder": table.remainder, "quotient": table.quotient}


def qr_memory_bytes(table: QRTable) -> int:
    return int((table.remainder.size + table.quotient.size) * 4)
