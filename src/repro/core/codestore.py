"""First-class packed storage for quantized embedding codes.

Every integer-table method in this repo stores its table as low-bit signed
codes plus per-row scales.  Historically the codes lived in an int8 array —
one full byte per code — so bits=4 and bits=2 saved *nothing* in resident or
moved bytes.  :class:`CodeStore` makes the container explicit:

    bits in {2, 4}   ->  packed uint8, ``8 // bits`` codes per byte
    bits in {5..8}   ->  one int8 byte per code (unchanged layout)

Packed layout (low-bits-first, matching the original ``quant.pack4``): logical
code ``j`` of a row lives in byte ``j // cpb`` at bit offset
``(j % cpb) * bits`` where ``cpb = 8 // bits``.  Rows whose logical width is
not a multiple of ``cpb`` are zero-padded to the next byte boundary; the pad
codes are never observable through :func:`unpack_codes` (it slices back to the
logical width).

The class is a registered pytree (one array child, static ``bits``/shape/
``packed`` aux), so it flows through ``jax.jit``, ``jax.eval_shape``, the
checkpoint manager's leaf-per-file layout, and ``jax.tree`` size accounting
without special cases.  The facade (``shape``/``dtype``/``size``/indexing)
reports the *logical* int8 view so shape-level consumers keep working, while
mutation goes through the explicit ``take`` / ``set_rows`` / ``where_rows``
API — there is deliberately no ``.at`` or ``.astype`` on a CodeStore, so a
call site that tries to mutate raw bytes fails loudly instead of silently
corrupting the packed container.

Bitwise-parity contract: ``pack_codes`` / ``unpack_codes`` are exact inverses
on the valid signed code range for their bit width, and every consumer does
its arithmetic on the *unpacked* values in the same operation order as the
unpacked path.  Packed-on therefore equals packed-off bit for bit — the
parity tests in tests/test_codestore.py hold every method to that bar.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

_PACKABLE_BITS = (2, 4)

__all__ = [
    "CodeStore",
    "is_packable",
    "codes_per_byte",
    "packed_width",
    "pack_codes",
    "unpack_codes",
]


def is_packable(bits: int) -> bool:
    """True when ``bits`` codes can share bytes (exact byte divisors only)."""
    return bits in _PACKABLE_BITS


def codes_per_byte(bits: int) -> int:
    if not is_packable(bits):
        raise ValueError(f"bits={bits} is not packable (need one of {_PACKABLE_BITS})")
    return 8 // bits


def packed_width(d: int, bits: int) -> int:
    """Bytes per row when packing ``d`` logical codes at ``bits`` bits."""
    cpb = codes_per_byte(bits)
    return -(-d // cpb)


def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """Pack signed ``bits``-bit codes into uint8, ``8 // bits`` per byte.

    Operates over the last axis; any leading shape is preserved.  Odd lengths
    are zero-padded up to the next byte boundary.  Low-bits-first layout:
    logical code ``j`` lands in byte ``j // cpb`` at shift ``(j % cpb) * bits``
    (for bits=4 this is byte-for-byte the historical ``quant.pack4`` layout).
    """
    cpb = codes_per_byte(bits)
    mask = (1 << bits) - 1
    d = codes.shape[-1]
    w = packed_width(d, bits)
    u = codes.astype(jnp.int32) & mask
    pad = w * cpb - d
    if pad:
        u = jnp.pad(u, [(0, 0)] * (u.ndim - 1) + [(0, pad)])
    u = u.reshape(u.shape[:-1] + (w, cpb))
    shifts = (jnp.arange(cpb, dtype=jnp.int32) * bits)[(None,) * (u.ndim - 1)]
    return jnp.sum(u << shifts, axis=-1).astype(jnp.uint8)


def unpack_codes(packed: jax.Array, bits: int, d: int) -> jax.Array:
    """Inverse of :func:`pack_codes`: uint8 container back to int8 codes.

    ``d`` is the logical last-axis length (byte-boundary zero-pad is sliced
    off).  Values are sign-extended from ``bits`` bits, so the roundtrip is
    exact over the full signed code range ``[-2^(bits-1), 2^(bits-1))``.
    """
    cpb = codes_per_byte(bits)
    mask = (1 << bits) - 1
    shifts = jnp.arange(cpb, dtype=jnp.int32) * bits
    vals = (packed.astype(jnp.int32)[..., None] >> shifts) & mask
    flat = vals.reshape(vals.shape[:-2] + (vals.shape[-2] * cpb,))
    flat = flat[..., :d]
    half = 1 << (bits - 1)
    return jnp.where(flat >= half, flat - (1 << bits), flat).astype(jnp.int8)


@dataclasses.dataclass(frozen=True)
class CodeStore:
    """A table of ``n x d`` signed codes in an explicit byte container.

    ``data`` is ``uint8 [n, packed_width(d, bits)]`` when ``packed`` else the
    classic ``int8 [n, d]``.  ``bits``/``n``/``d``/``packed`` are static pytree
    aux, so two stores with different layouts never unify under ``jit``.
    """

    data: jax.Array
    bits: int
    n: int
    d: int
    packed: bool

    # ------------------------------------------------------------ build

    @classmethod
    def from_codes(cls, codes: jax.Array, bits: int,
                   packed: bool | None = None) -> "CodeStore":
        """Wrap raw int8 codes ``[n, d]``; packs when the width allows it.

        ``packed=None`` means "pack if possible"; asking for ``packed=True``
        at a non-packable width silently stores one byte per code (there is
        no denser layout for bits in {3, 5..8}).
        """
        n, d = codes.shape
        do_pack = is_packable(bits) if packed is None else (
            bool(packed) and is_packable(bits)
        )
        data = pack_codes(codes, bits) if do_pack else codes
        return cls(data=data, bits=int(bits), n=int(n), d=int(d),
                   packed=do_pack)

    def with_data(self, data: jax.Array) -> "CodeStore":
        return dataclasses.replace(self, data=data)

    # ------------------------------------------------------------ facade

    @property
    def shape(self) -> tuple[int, int]:
        """Logical (rows, codes-per-row) — not the byte container's shape."""
        return (self.n, self.d)

    @property
    def dtype(self):
        """Logical code dtype (the container dtype is ``self.data.dtype``)."""
        return jnp.int8

    @property
    def size(self) -> int:
        return self.n * self.d

    @property
    def ndim(self) -> int:
        return 2

    @property
    def resident_bytes(self) -> int:
        """Actual container bytes: ``ceil(d * bits / 8)`` per row if packed."""
        return int(
            math.prod(self.data.shape) * np.dtype(self.data.dtype).itemsize
        )

    # ------------------------------------------------------------ reads

    def unpack(self) -> jax.Array:
        """The full logical int8 ``[n, d]`` view (a copy when packed)."""
        if self.packed:
            return unpack_codes(self.data, self.bits, self.d)
        return self.data

    def take(self, ids: jax.Array) -> jax.Array:
        """Row gather -> int8 codes ``ids.shape + (d,)`` (out-of-range rows
        follow ``jnp.take``'s clamping, matching the raw-array path)."""
        rows = jnp.take(self.data, ids, axis=0)
        if self.packed:
            return unpack_codes(rows, self.bits, self.d)
        return rows

    def min(self):
        return self.unpack().min()

    def max(self):
        return self.unpack().max()

    def __getitem__(self, idx):
        return self.unpack()[idx]

    def __array__(self, dtype=None):
        arr = np.asarray(jax.device_get(self.unpack()))
        return arr.astype(dtype) if dtype is not None else arr

    def __jax_array__(self):
        # Escape hatch: lets stray `jnp.take(store, ...)`-style reads see the
        # logical int8 view.  Writes have no such hatch on purpose.
        return self.unpack()

    # ------------------------------------------------------------ writes

    def set_rows(self, rows_idx: jax.Array, codes_rows: jax.Array, *,
                 mode: str = "drop") -> "CodeStore":
        """Functional row scatter: int8 ``[k, d]`` rows -> new CodeStore.

        Packs the incoming rows first when the container is packed, so the
        scatter moves container bytes (what the aliased Pallas path does
        in-kernel).
        """
        if self.packed:
            rows = pack_codes(codes_rows, self.bits)
        else:
            rows = codes_rows.astype(self.data.dtype)
        return self.with_data(self.data.at[rows_idx].set(rows, mode=mode))

    def where_rows(self, row_mask: jax.Array,
                   codes_new: "CodeStore | jax.Array") -> "CodeStore":
        """Row-wise select: where ``row_mask`` take ``codes_new`` else self.

        ``row_mask`` is ``[n]`` or ``[n, 1]``; ``codes_new`` is a CodeStore
        with the same layout or raw int8 ``[n, d]``.  Selection happens on
        container bytes — row-wise masks commute with packing exactly.
        """
        if isinstance(codes_new, CodeStore):
            if (codes_new.packed, codes_new.bits) != (self.packed, self.bits):
                raise ValueError(
                    f"layout mismatch in where_rows: "
                    f"{(codes_new.packed, codes_new.bits)} vs "
                    f"{(self.packed, self.bits)}"
                )
            new_data = codes_new.data
        elif self.packed:
            new_data = pack_codes(codes_new, self.bits)
        else:
            new_data = codes_new.astype(self.data.dtype)
        mask = row_mask if row_mask.ndim == 2 else row_mask[:, None]
        return self.with_data(jnp.where(mask, new_data, self.data))


# The either-type row-access helpers that used to live here (logical_codes /
# take_rows / set_rows / where_rows / resident_bytes_of) are now the
# :mod:`repro.storage.base` RowStore protocol surface — one dispatch boundary
# shared by every container (CodeStore, TieredCodes, raw arrays).


def _flatten_with_keys(s: CodeStore):
    return ((jax.tree_util.GetAttrKey("data"), s.data),), (
        s.bits, s.n, s.d, s.packed,
    )


def _flatten(s: CodeStore):
    return (s.data,), (s.bits, s.n, s.d, s.packed)


def _unflatten(aux, children) -> CodeStore:
    bits, n, d, packed = aux
    return CodeStore(data=children[0], bits=bits, n=n, d=d, packed=packed)


jax.tree_util.register_pytree_with_keys(
    CodeStore, _flatten_with_keys, _unflatten, _flatten
)
