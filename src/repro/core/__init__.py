"""Core of the paper: quantization, LPT, ALPT, QAT/hash/prune baselines, theory."""
from repro.core import alpt, hashing, lpt, pruning, qat, quant, theory  # noqa: F401
