"""Adaptive low-precision training (ALPT) — paper §3.2, Algorithm 1.

Per batch, two alternating sub-steps:

  Step 1 (weights):   w_hat_b = Delta_b * w_tilde_b          (de-quantize)
                      w_b'    = w_hat_b - eta * df/dw_hat    (+ dense params)
  Step 2 (step size): Delta_b' = Delta_b - eta_D * df(Q_D(w_b', Delta_b))/dDelta
                      w_tilde_b' = SR-quantize(w_b', Delta_b')

The Delta gradient comes from an LSQ-style second forward pass over the
*updated float rows* (quant.fake_quant_lsq, Eq. 6/7), scaled by
g = 1/sqrt(b * d * q) with q = 2^{m-1} - 1 (paper §3.2; Fig. 4 shows the
scale matters less than the Delta learning rate, both are exposed).

The weight sub-step reuses lpt.sparse_apply / lpt.dense_apply, so ALPT == LPT
plus the learned Delta — exactly the paper's framing.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import fence, lpt, quant
from repro.kernels import ops
from repro.storage import base as rowstore


class ALPTConfig(NamedTuple):
    bits: int = 8
    rounding: str = "sr"  # rounding for the write-back (paper: SR)
    optimizer: str = "adam"  # row optimizer for the embeddings
    weight_decay: float = 5e-8  # paper: 5e-8 Avazu / 1e-5 Criteo
    step_lr: float = 2e-5  # paper: Delta learning rate 2e-5
    step_weight_decay: float = 5e-8  # paper: same decay as embeddings (8-bit)
    grad_scale: str = "bdq"  # '1' | 'dq' | 'bdq'  (Fig. 4 sweep)
    # Route the lookup / write-back hot loops through repro.kernels.ops
    # (methods copy EmbeddingSpec.use_kernels in here; bitwise-identical).
    use_kernels: bool = False
    # Absolute upper bound on the learned Delta (guardrail against step-size
    # blowup: one huge Delta poisons the whole row's quantization grid).
    # None (default) leaves the update graph byte-identical to the paper's;
    # when set, clamped rows are counted in aux["delta_clamped"].
    step_clamp: float | None = None


def grad_scale_factor(cfg: ALPTConfig, batch_rows: int, dim: int) -> float:
    q = 2 ** (cfg.bits - 1) - 1
    if cfg.grad_scale == "1":
        return 1.0
    if cfg.grad_scale == "dq":
        return 1.0 / math.sqrt(dim * q)
    if cfg.grad_scale == "bdq":
        return 1.0 / math.sqrt(batch_rows * dim * q)
    raise ValueError(f"unknown grad_scale {cfg.grad_scale!r}")


def alpt_step(
    table: lpt.LPTTable,
    ids: jax.Array,
    loss_fn: Callable[[jax.Array], jax.Array],
    *,
    cfg: ALPTConfig,
    lr: jax.Array,
    noise_key: jax.Array,
    loss_fn_step2: Callable[[jax.Array], jax.Array] | None = None,
    id_space: int | None = None,
    out_dim: int | None = None,
):
    """One ALPT update of a table against ``loss_fn(rows) -> scalar``.

    ``loss_fn`` closes over the batch and any dense parameters; it receives the
    de-quantized rows for ``ids`` (same leading shape as ids, trailing dim d).
    Returns (new_table, loss, aux) where aux carries diagnostics.

    Dense-parameter updates happen outside (the caller differentiates the same
    loss w.r.t. its own params); this function owns lines 1-2 and 4-5 of
    Algorithm 1 for the embedding table.  Algorithm 1 line 4 evaluates the
    step-size loss at the *updated* dense params w_o^{t+1}; pass that closure
    as ``loss_fn_step2`` (defaults to ``loss_fn``).

    ``id_space``/``out_dim`` carry the live geometry of ``pad_to_tiles``
    tables (dedup sentinel and model-facing row width); the paper's b and d
    count live lookups, not padding.
    """
    if loss_fn_step2 is None:
        loss_fn_step2 = loss_fn
    d = table.dim
    d_live = d if out_dim is None else out_dim
    n = table.n_rows
    sentinel = n if id_space is None else id_space

    # ---- Step 1: de-quantize, get row gradients, float update. ----
    rows = lpt.lookup(
        table, ids, use_kernels=cfg.use_kernels, out_dim=out_dim
    )  # w_hat_b^t
    # Fenced (see repro.core.fence): the model backward compiles as its own
    # unit whatever storage backs the codes, keeping cache-on bitwise-equal
    # to cache-off.  Ids are non-negative, so one doubles as the tick.
    tick = ids.reshape(-1)[0]
    loss, g_rows = fence.fence_call(
        jax.value_and_grad(loss_fn), (rows,), tick=tick
    )
    table1, (uniq, w_new) = lpt.sparse_apply(
        table,
        ids,
        g_rows,
        lr=lr,
        bits=cfg.bits,
        rounding=cfg.rounding,
        noise_key=noise_key,
        optimizer=cfg.optimizer,
        weight_decay=cfg.weight_decay,
        return_updated_rows=True,
        id_space=id_space,
        use_kernels=cfg.use_kernels,
    )
    # ---- Step 2: learn Delta on the *updated* float rows (line 4). ----
    # Re-run the forward with fake-quantized updated rows; the LSQ custom-vjp
    # routes the gradient to Delta via Eq. 7.
    safe = jnp.minimum(uniq, n - 1)
    step_b = jnp.take(table.step, safe)  # Delta_b^t
    gscale = grad_scale_factor(cfg, batch_rows=int(ids.size), dim=d_live)
    inv = lpt.dedup_ids(ids, sentinel)[1]

    def loss_wrt_step(step_vec):
        rows_q = quant.fake_quant_lsq(
            jax.lax.stop_gradient(w_new), step_vec, cfg.bits, gscale
        )
        # Re-broadcast unique rows back to per-occurrence layout for the loss.
        occ = jnp.take(rows_q, inv, axis=0).reshape(ids.shape + (d,))
        if d_live != d:
            occ = occ[..., :d_live]
        return loss_fn_step2(occ)

    g_step = fence.fence_call(jax.grad(loss_wrt_step), (step_b,), tick=tick)
    new_step_b = step_b - cfg.step_lr * (
        g_step + cfg.step_weight_decay * step_b
    )
    new_step_b = jnp.maximum(new_step_b, 1e-8)  # Delta must stay positive
    delta_clamped = None
    if cfg.step_clamp is not None:
        delta_clamped = jnp.sum(new_step_b > cfg.step_clamp).astype(jnp.int32)
        new_step_b = jnp.minimum(new_step_b, cfg.step_clamp)

    # ---- Line 5: re-quantize w^{t+1} with the NEW Delta (SR). ----
    k2 = jax.random.fold_in(noise_key, 1)
    noise = quant.sr_noise(k2, w_new.shape)
    if cfg.use_kernels and cfg.rounding == "sr":
        codes_rows = ops.sr_round(w_new, new_step_b, noise, cfg.bits)
    else:
        if cfg.use_kernels:
            ops.note_fallback("sr_round", w_new.shape, "dr rounding")
        codes_rows = quant.quantize_codes(
            w_new, new_step_b, cfg.bits, cfg.rounding, noise
        )
    codes = rowstore.set_rows(table1.codes, uniq, codes_rows, mode="drop")
    step = table1.step.at[uniq].set(new_step_b, mode="drop")
    new_table = table1._replace(codes=codes, step=step)
    aux = {
        "step_grad_norm": jnp.linalg.norm(g_step),
        "mean_step": jnp.mean(new_step_b),
    }
    if delta_clamped is not None:
        aux["delta_clamped"] = delta_clamped
    return new_table, loss, aux


class DenseWeightUpdate(NamedTuple):
    """Intermediate of the dense ALPT weight sub-step (Algorithm 1 lines 1-3),
    handed between :func:`dense_weight_update` and :func:`dense_finish` so a
    data-parallel caller can interleave gradient synchronization."""

    w_new: jax.Array  # f32 [n, d] float-updated rows
    mu_new: jax.Array
    nu_new: jax.Array
    touched: jax.Array  # bool [n]
    count: jax.Array  # int32 scalar


def dense_weight_update(
    table: lpt.LPTTable,
    grad_table: jax.Array,
    *,
    cfg: ALPTConfig,
    lr: jax.Array,
) -> DenseWeightUpdate:
    """Dense float weight update (Algorithm 1 line 2) without the write-back."""
    touched = jnp.any(grad_table != 0.0, axis=-1)
    w = lpt.dense_table(table)
    count = table.count + 1
    t = count.astype(jnp.float32)
    w_new, mu_new, nu_new = lpt._row_update(
        w, grad_table, table.mu, table.nu, t, lr, cfg.optimizer, cfg.weight_decay
    )
    return DenseWeightUpdate(
        w_new=w_new, mu_new=mu_new, nu_new=nu_new, touched=touched, count=count
    )


def dense_delta_grad(
    w_new: jax.Array,
    step_vec: jax.Array,
    loss_fn_q: Callable[[jax.Array], jax.Array],
    *,
    cfg: ALPTConfig,
    gscale: float,
) -> jax.Array:
    """Delta gradient (Algorithm 1 line 4): differentiate the fake-quant
    forward of the *updated* rows w.r.t. the step vector."""

    def loss_wrt_step(step_vec):
        table_q = quant.fake_quant_lsq(
            jax.lax.stop_gradient(w_new), step_vec, cfg.bits, gscale
        )
        return loss_fn_q(table_q)

    return jax.grad(loss_wrt_step)(step_vec)


def dense_finish(
    table: lpt.LPTTable,
    upd: DenseWeightUpdate,
    g_step: jax.Array,
    *,
    cfg: ALPTConfig,
    noise_key: jax.Array,
) -> lpt.LPTTable:
    """Delta update + SR re-quantization (Algorithm 1 line 5), touched-row
    masked so untouched rows keep codes and Delta bit-identical."""
    new_step = table.step - cfg.step_lr * (g_step + cfg.step_weight_decay * table.step)
    new_step = jnp.maximum(new_step, 1e-8)
    if cfg.step_clamp is not None:
        new_step = jnp.minimum(new_step, cfg.step_clamp)
    new_step = jnp.where(upd.touched, new_step, table.step)

    noise = quant.sr_noise(jax.random.fold_in(noise_key, 1), upd.w_new.shape)
    if cfg.use_kernels and cfg.rounding == "sr":
        # Algorithm 1 line 5 already materialized w_new for the Delta
        # gradient, so the fused piece here is the SR write-back itself
        # (fp32 in, int8 out — no intermediate rounding buffers).
        codes_new = ops.sr_round(upd.w_new, new_step, noise, cfg.bits)
    else:
        if cfg.use_kernels:
            ops.note_fallback("sr_round", upd.w_new.shape, "dr rounding")
        codes_new = quant.quantize_codes(
            upd.w_new, new_step, cfg.bits, cfg.rounding, noise
        )
    mask = upd.touched[:, None]
    codes = rowstore.where_rows(table.codes, upd.touched, codes_new)
    if table.mu.ndim == 2:
        mu = jnp.where(mask, upd.mu_new, table.mu)
        nu = jnp.where(mask, upd.nu_new, table.nu)
    else:
        mu = jnp.where(upd.touched, upd.mu_new, table.mu)
        nu = jnp.where(upd.touched, upd.nu_new, table.nu)
    return table._replace(codes=codes, step=new_step, mu=mu, nu=nu, count=upd.count)


def alpt_dense_step(
    table: lpt.LPTTable,
    grad_table: jax.Array,
    loss_fn_q: Callable[[jax.Array], jax.Array],
    *,
    cfg: ALPTConfig,
    lr: jax.Array,
    noise_key: jax.Array,
    batch_rows: int,
):
    """pjit-friendly ALPT: dense gradients + dense Delta learning.

    ``grad_table`` is the dense df/dtable from the caller's backward pass.
    ``loss_fn_q(table_fp) -> scalar`` re-evaluates the loss from a dense float
    table (used for the Delta gradient via fake-quant).  Untouched rows keep
    codes and Delta bit-identical.

    ``batch_rows`` is the paper's b — the number of table-row lookups the
    batch performed (token count for an LM) — feeding the Delta gradient
    scale g = 1/sqrt(b*d*q).  It matches the sparse path's ``ids.size``; the
    table's total row count is NOT a substitute (it over-damps the Delta
    learning rate by sqrt(V/b)).

    Composed from :func:`dense_weight_update` / :func:`dense_delta_grad` /
    :func:`dense_finish`; the data-parallel trainer calls the pieces directly
    so it can all-reduce ``grad_table`` and the Delta gradient in between.
    """
    upd = dense_weight_update(table, grad_table, cfg=cfg, lr=lr)
    gscale = grad_scale_factor(cfg, batch_rows=int(batch_rows), dim=table.dim)
    g_step = dense_delta_grad(
        upd.w_new, table.step, loss_fn_q, cfg=cfg, gscale=gscale
    )
    return dense_finish(table, upd, g_step, cfg=cfg, noise_key=noise_key)
