"""QAT baselines (paper §2.2 / §4.1): LSQ and PACT.

Unlike LPT these keep a *full-precision master copy* of the embedding table —
so they compress inference (4x at int8) but not training memory (1x), exactly
the distinction Table 1's "Compression ratio" columns draw.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quant


class QATTable(NamedTuple):
    weights: jax.Array  # f32 [n, d] — master copy (the thing LPT removes)
    scale: jax.Array  # f32 [n] — LSQ step size or PACT clip alpha


def init_qat(
    key: jax.Array, n: int, d: int, bits: int, *, method: str = "lsq",
    init_scale: float = 1e-2,
) -> QATTable:
    w = jax.random.normal(key, (n, d), jnp.float32) * init_scale
    if method == "lsq":
        scale = quant.init_step_size(w, bits, per_row=True)
    elif method == "pact":
        p = 2 ** (bits - 1) - 1
        scale = quant.init_step_size(w, bits, per_row=True) * p  # alpha = step*p
    else:
        raise ValueError(f"unknown QAT method {method!r}")
    return QATTable(weights=w, scale=scale)


def qat_lookup(
    table: QATTable, ids: jax.Array, bits: int, *, method: str = "lsq",
    grad_scale: float = 1.0,
) -> jax.Array:
    """Fake-quantized lookup: forward sees Q_D(w), backward flows STE to the
    master weights and (Eq. 7 / PACT rule) to the scale."""
    w_rows = jnp.take(table.weights, ids, axis=0)
    s_rows = jnp.take(table.scale, ids, axis=0)
    if method == "lsq":
        return quant.fake_quant_lsq(w_rows, s_rows, bits, grad_scale)
    return quant.fake_quant_pact(w_rows, s_rows, bits)


def export_int8(table: QATTable, bits: int, *, method: str = "lsq"):
    """Post-training export: integer codes + per-row step (the 4x inference win)."""
    if method == "pact":
        p = 2 ** (bits - 1) - 1
        step = table.scale / p
    else:
        step = table.scale
    codes = quant.quantize_codes(table.weights, step, bits, "dr")
    return codes, step
