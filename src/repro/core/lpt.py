"""Low-precision training (LPT) of embedding tables (paper §2.3, Eq. 8).

The table lives as int8 codes plus a per-row (feature-wise) step size; there is
NO full-precision master copy.  Each step de-quantizes only the rows a batch
touches, applies the optimizer update in float, and re-quantizes with SR or DR:

    w_hat^{t+1} = Q( w_hat^t - eta * grad f(w_hat^t) )            (Eq. 8)

Two execution paths share identical semantics:

* ``sparse`` — CTR-style: ids are de-duplicated under jit (`jnp.unique(size=)`),
  per-unique-row gradients are segment-summed, and only those rows are updated
  and re-quantized.  This is the paper-faithful path: the de-quantized floats
  for a batch are "negligible compared to the embedding tables" (§2.3).
* ``dense`` — LM/pjit-style: the table gradient arrives dense (XLA scatter-add
  from the token gather); rows whose gradient is exactly zero keep their old
  codes bit-for-bit, so untouched rows never drift.  This path shards cleanly
  over a vocab-partitioned mesh axis.

Row optimizers: 'sgd' (Eq. 8 literally), 'adam' (paper §4.1: Adam with
decoupled weight decay), 'adagrad' (industry-standard per-row accumulator,
cheapest state).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quant


class LPTTable(NamedTuple):
    """Quantized embedding table + per-row step + row optimizer state."""

    codes: jax.Array  # int8 [n, d]
    step: jax.Array  # f32  [n]   (feature-wise Delta; ALPT learns this)
    # Row-optimizer slots (zeros-shaped () when unused):
    mu: jax.Array  # f32 [n, d] (adam) | [n] zeros (adagrad/sgd)
    nu: jax.Array  # f32 [n, d] (adam) | [n] (adagrad accumulator) | [n] zeros
    count: jax.Array  # int32 scalar — global step for Adam bias correction

    @property
    def n_rows(self) -> int:
        return self.codes.shape[0]

    @property
    def dim(self) -> int:
        return self.codes.shape[1]


def init_table(
    key: jax.Array,
    n: int,
    d: int,
    bits: int,
    *,
    init_scale: float = 1e-2,
    mean: float = 0.0,
    step_size: float | None = None,
    clip_value: float | None = None,
    optimizer: str = "adam",
) -> LPTTable:
    """Initialize weights ~ N(mean, init_scale^2), choose Delta, quantize.

    Vanilla LPT (Xu et al. 2021) fixes Delta from a tuned clip value:
    Delta = clip / 2^{m-1}.  If neither ``step_size`` nor ``clip_value`` is
    given, Delta is set per-row LSQ-style from the init (the ALPT default).
    ``mean`` shifts the init (composed tables start multiplicative factors
    near 1); the paper's tables use the zero-mean default.
    """
    kw, kn = jax.random.split(key)
    w = jax.random.normal(kw, (n, d), jnp.float32) * init_scale
    if mean:
        w = mean + w
    if step_size is not None:
        step = jnp.full((n,), step_size, jnp.float32)
    elif clip_value is not None:
        step = jnp.full((n,), clip_value / (2 ** (bits - 1)), jnp.float32)
    else:
        step = quant.init_step_size(w, bits, per_row=True)
    noise = quant.sr_noise(kn, w.shape)
    codes = quant.quantize_codes(w, step, bits, "sr", noise)
    if optimizer == "adam":
        mu = jnp.zeros((n, d), jnp.float32)
        nu = jnp.zeros((n, d), jnp.float32)
    elif optimizer == "adagrad":
        mu = jnp.zeros((n,), jnp.float32)
        nu = jnp.zeros((n,), jnp.float32)
    elif optimizer == "sgd":
        mu = jnp.zeros((n,), jnp.float32)
        nu = jnp.zeros((n,), jnp.float32)
    else:
        raise ValueError(f"unknown row optimizer {optimizer!r}")
    return LPTTable(codes=codes, step=step, mu=mu, nu=nu, count=jnp.zeros((), jnp.int32))


def lookup(table: LPTTable, ids: jax.Array) -> jax.Array:
    """De-quantize the rows for ``ids`` (any leading shape) -> f32 [..., d]."""
    codes = jnp.take(table.codes, ids, axis=0)
    step = jnp.take(table.step, ids, axis=0)
    return quant.dequantize(codes, step)


def dense_table(table: LPTTable) -> jax.Array:
    """Materialize the full de-quantized table (dense/pjit path)."""
    return quant.dequantize(table.codes, table.step)


# ---------------------------------------------------------------------------
# Row-update rules (shared by the sparse and dense paths).
# ---------------------------------------------------------------------------


def _row_update(
    w: jax.Array,  # f32 [k, d] current de-quantized rows
    g: jax.Array,  # f32 [k, d] summed row gradients
    mu: jax.Array,
    nu: jax.Array,
    t: jax.Array,  # scalar f32, 1-indexed adam step
    lr: jax.Array,
    optimizer: str,
    weight_decay: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
):
    """Returns (w_new, mu_new, nu_new)."""
    g = g.astype(jnp.float32)
    if optimizer == "adam":
        mu = b1 * mu + (1.0 - b1) * g
        nu = b2 * nu + (1.0 - b2) * jnp.square(g)
        upd = (mu / (1.0 - b1**t)) / (jnp.sqrt(nu / (1.0 - b2**t)) + eps)
    elif optimizer == "adagrad":
        nu = nu + jnp.mean(jnp.square(g), axis=-1)
        upd = g / (jnp.sqrt(nu)[..., None] + eps)
    else:  # sgd
        upd = g
    if weight_decay:
        upd = upd + weight_decay * w
    return w - lr * upd, mu, nu


def dedup_ids(ids: jax.Array, n_rows: int):
    """jit-stable de-duplication: returns (unique_ids [K], inverse [K_in]).

    ``unique_ids`` is padded with ``n_rows`` (an out-of-range sentinel row);
    scatters use mode='drop' so padding is inert.
    """
    flat = ids.reshape(-1)
    uniq, inv = jnp.unique(
        flat, return_inverse=True, size=flat.shape[0], fill_value=n_rows
    )
    return uniq, inv.reshape(-1)


def sparse_apply(
    table: LPTTable,
    ids: jax.Array,  # int32 [...], the ids that were looked up
    grad_rows: jax.Array,  # f32 [..., d], cotangent per lookup occurrence
    *,
    lr: jax.Array,
    bits: int,
    rounding: str = "sr",
    noise_key: jax.Array | None = None,
    optimizer: str = "adam",
    weight_decay: float = 0.0,
    new_step: jax.Array | None = None,  # ALPT passes the freshly learned Delta_b
    return_updated_rows: bool = False,
):
    """Paper-faithful LPT update: only rows present in ``ids`` change.

    Duplicate ids in the batch have their gradients summed (the same semantics
    autodiff would give a dense table scatter-add).
    """
    n = table.n_rows
    d = table.dim
    flat_ids = ids.reshape(-1)
    flat_g = grad_rows.reshape(-1, d)
    uniq, inv = dedup_ids(flat_ids, n)
    k = uniq.shape[0]
    # Sum gradients per unique row.
    g_sum = jnp.zeros((k, d), jnp.float32).at[inv].add(flat_g.astype(jnp.float32))
    # Gather current rows + optimizer slots (sentinel gathers row 0 harmlessly;
    # its scatter is dropped).
    safe = jnp.minimum(uniq, n - 1)
    w = quant.dequantize(jnp.take(table.codes, safe, axis=0), jnp.take(table.step, safe))
    count = table.count + 1
    t = count.astype(jnp.float32)
    # Slot layout is optimizer-dependent ([k, d] adam / [k] otherwise) but the
    # gather is row-indexed either way.
    mu = jnp.take(table.mu, safe, axis=0)
    nu = jnp.take(table.nu, safe, axis=0)
    w_new, mu_new, nu_new = _row_update(
        w, g_sum, mu, nu, t, lr, optimizer, weight_decay
    )
    step_rows = jnp.take(table.step, safe) if new_step is None else new_step
    if rounding == "sr":
        if noise_key is None:
            raise ValueError("SR requires noise_key")
        noise = quant.sr_noise(noise_key, w_new.shape)
    else:
        noise = None
    new_codes_rows = quant.quantize_codes(w_new, step_rows, bits, rounding, noise)
    codes = table.codes.at[uniq].set(new_codes_rows, mode="drop")
    step = table.step.at[uniq].set(step_rows, mode="drop")
    mu_t = table.mu.at[uniq].set(mu_new, mode="drop")
    nu_t = table.nu.at[uniq].set(nu_new, mode="drop")
    new_table = LPTTable(codes=codes, step=step, mu=mu_t, nu=nu_t, count=count)
    if return_updated_rows:
        return new_table, (uniq, w_new)
    return new_table


def dense_apply(
    table: LPTTable,
    grad_table: jax.Array,  # f32 [n, d] dense gradient (zero on untouched rows)
    *,
    lr: jax.Array,
    bits: int,
    rounding: str = "sr",
    noise_key: jax.Array | None = None,
    optimizer: str = "adam",
    weight_decay: float = 0.0,
    new_step: jax.Array | None = None,
) -> LPTTable:
    """pjit-friendly LPT update: dense compute, touched-row masking.

    A row is "touched" iff any element of its gradient is nonzero; untouched
    rows keep their codes/slots bit-identical (exact sparse semantics, but the
    computation is dense and therefore shards trivially over the vocab axis).
    """
    touched = jnp.any(grad_table != 0.0, axis=-1)  # [n]
    w = dense_table(table)
    count = table.count + 1
    t = count.astype(jnp.float32)
    w_new, mu_new, nu_new = _row_update(
        w, grad_table, table.mu, table.nu, t, lr, optimizer, weight_decay
    )
    step = table.step if new_step is None else new_step
    if rounding == "sr":
        if noise_key is None:
            raise ValueError("SR requires noise_key")
        noise = quant.sr_noise(noise_key, w_new.shape)
    else:
        noise = None
    codes_new = quant.quantize_codes(w_new, step, bits, rounding, noise)
    mask = touched[:, None]
    codes = jnp.where(mask, codes_new, table.codes)
    if table.mu.ndim == 2:
        mu = jnp.where(mask, mu_new, table.mu)
        nu = jnp.where(mask, nu_new, table.nu)
    else:
        mu = jnp.where(touched, mu_new, table.mu)
        nu = jnp.where(touched, nu_new, table.nu)
    step_out = jnp.where(touched, step, table.step) if new_step is not None else table.step
    return LPTTable(codes=codes, step=step_out, mu=mu, nu=nu, count=count)


def memory_bytes(table: LPTTable, bits: int, count_optimizer: bool = False) -> int:
    """Training-memory accounting as in paper Table 1 (codes + Delta)."""
    n, d = table.codes.shape
    code_bytes = n * d * bits / 8.0
    step_bytes = n * 4
    total = code_bytes + step_bytes
    if count_optimizer:
        total += table.mu.size * 4 + table.nu.size * 4
    return int(total)
