"""Low-precision training (LPT) of embedding tables (paper §2.3, Eq. 8).

The table lives as int8 codes plus a per-row (feature-wise) step size; there is
NO full-precision master copy.  Each step de-quantizes only the rows a batch
touches, applies the optimizer update in float, and re-quantizes with SR or DR:

    w_hat^{t+1} = Q( w_hat^t - eta * grad f(w_hat^t) )            (Eq. 8)

Two execution paths share identical semantics:

* ``sparse`` — CTR-style: ids are de-duplicated under jit (`jnp.unique(size=)`),
  per-unique-row gradients are segment-summed, and only those rows are updated
  and re-quantized.  This is the paper-faithful path: the de-quantized floats
  for a batch are "negligible compared to the embedding tables" (§2.3).
* ``dense`` — LM/pjit-style: the table gradient arrives dense (XLA scatter-add
  from the token gather); rows whose gradient is exactly zero keep their old
  codes bit-for-bit, so untouched rows never drift.  This path shards cleanly
  over a vocab-partitioned mesh axis.

Row optimizers: 'sgd' (Eq. 8 literally), 'adam' (paper §4.1: Adam with
decoupled weight decay), 'adagrad' (industry-standard per-row accumulator,
cheapest state).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import codestore, quant
from repro.kernels import ops
from repro.storage import base as rowstore
from repro.storage.tiered import TieredCodes


class LPTTable(NamedTuple):
    """Quantized embedding table + per-row step + row optimizer state."""

    # A CodeStore (packed uint8 at bits<=4, int8 otherwise) or a raw int8
    # array for hand-built tables; `.shape` is the logical [n, d] either way.
    codes: "codestore.CodeStore | jax.Array"
    step: jax.Array  # f32  [n]   (feature-wise Delta; ALPT learns this)
    # Row-optimizer slots (zeros-shaped () when unused):
    mu: jax.Array  # f32 [n, d] (adam) | [n] zeros (adagrad/sgd)
    nu: jax.Array  # f32 [n, d] (adam) | [n] (adagrad accumulator) | [n] zeros
    count: jax.Array  # int32 scalar — global step for Adam bias correction

    @property
    def n_rows(self) -> int:
        return self.codes.shape[0]

    @property
    def dim(self) -> int:
        return self.codes.shape[1]


def init_table(
    key: jax.Array,
    n: int,
    d: int,
    bits: int,
    *,
    init_scale: float = 1e-2,
    mean: float = 0.0,
    step_size: float | None = None,
    clip_value: float | None = None,
    optimizer: str = "adam",
    use_kernels: bool = False,
    packed: bool | None = None,
) -> LPTTable:
    """Initialize weights ~ N(mean, init_scale^2), choose Delta, quantize.

    Vanilla LPT (Xu et al. 2021) fixes Delta from a tuned clip value:
    Delta = clip / 2^{m-1}.  If neither ``step_size`` nor ``clip_value`` is
    given, Delta is set per-row LSQ-style from the init (the ALPT default).
    ``mean`` shifts the init (composed tables start multiplicative factors
    near 1); the paper's tables use the zero-mean default.

    ``packed`` selects the code container (see :mod:`repro.core.codestore`):
    None/True packs sub-byte widths (bits in {2, 4}) into uint8; False keeps
    one byte per code.  Packing is a storage-layout choice only — training is
    bitwise identical either way.
    """
    kw, kn = jax.random.split(key)
    w = jax.random.normal(kw, (n, d), jnp.float32) * init_scale
    if mean:
        w = mean + w
    if step_size is not None:
        step = jnp.full((n,), step_size, jnp.float32)
    elif clip_value is not None:
        step = jnp.full((n,), clip_value / (2 ** (bits - 1)), jnp.float32)
    else:
        step = quant.init_step_size(w, bits, per_row=True)
    noise = quant.sr_noise(kn, w.shape)
    if use_kernels:
        codes = ops.sr_round(w, step, noise, bits)
    else:
        codes = quant.quantize_codes(w, step, bits, "sr", noise)
    codes = codestore.CodeStore.from_codes(codes, bits, packed=packed)
    if optimizer == "adam":
        mu = jnp.zeros((n, d), jnp.float32)
        nu = jnp.zeros((n, d), jnp.float32)
    elif optimizer == "adagrad":
        mu = jnp.zeros((n,), jnp.float32)
        nu = jnp.zeros((n,), jnp.float32)
    elif optimizer == "sgd":
        mu = jnp.zeros((n,), jnp.float32)
        nu = jnp.zeros((n,), jnp.float32)
    else:
        raise ValueError(f"unknown row optimizer {optimizer!r}")
    return LPTTable(codes=codes, step=step, mu=mu, nu=nu, count=jnp.zeros((), jnp.int32))


def lookup(
    table: LPTTable,
    ids: jax.Array,
    *,
    use_kernels: bool = False,
    out_dim: int | None = None,
) -> jax.Array:
    """De-quantize the rows for ``ids`` (any leading shape) -> f32 [..., d].

    ``use_kernels`` routes through the fused gather+dequantize Pallas kernel
    (``ops.dequant_gather``: int8 rows leave HBM, the fp table never
    materializes); the jnp path is bitwise-identical.  ``out_dim`` slices
    padded tables back to the live embedding width (``pad_to_tiles``).
    """
    if use_kernels:
        flat = ids.reshape(-1)
        rows = ops.dequant_gather(table.codes, table.step, flat)
        rows = rows.reshape(ids.shape + (table.dim,))
    else:
        codes = rowstore.take_rows(table.codes, ids)
        step = jnp.take(table.step, ids, axis=0)
        rows = quant.dequantize(codes, step)
    if out_dim is not None and out_dim != rows.shape[-1]:
        rows = rows[..., :out_dim]
    return rows


def dense_table(table: LPTTable) -> jax.Array:
    """Materialize the full de-quantized table (dense/pjit path)."""
    return quant.dequantize(rowstore.logical_codes(table.codes), table.step)


# ---------------------------------------------------------------------------
# Row-update rules (shared by the sparse and dense paths).
# ---------------------------------------------------------------------------


def _opt_direction(
    g: jax.Array,  # f32 [k, d] summed row gradients
    mu: jax.Array,
    nu: jax.Array,
    t: jax.Array,  # scalar f32, 1-indexed adam step
    optimizer: str,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
):
    """Weight-independent part of the row update: (direction, mu_new, nu_new).

    The fused kernels consume the direction and fold the decoupled weight
    decay + subtraction + re-quantization into one VMEM pass.
    """
    g = g.astype(jnp.float32)
    if optimizer == "adam":
        mu = b1 * mu + (1.0 - b1) * g
        nu = b2 * nu + (1.0 - b2) * jnp.square(g)
        upd = (mu / (1.0 - b1**t)) / (jnp.sqrt(nu / (1.0 - b2**t)) + eps)
    elif optimizer == "adagrad":
        nu = nu + jnp.mean(jnp.square(g), axis=-1)
        upd = g / (jnp.sqrt(nu)[..., None] + eps)
    else:  # sgd
        upd = g
    return upd, mu, nu


def _row_update(
    w: jax.Array,  # f32 [k, d] current de-quantized rows
    g: jax.Array,  # f32 [k, d] summed row gradients
    mu: jax.Array,
    nu: jax.Array,
    t: jax.Array,  # scalar f32, 1-indexed adam step
    lr: jax.Array,
    optimizer: str,
    weight_decay: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
):
    """Returns (w_new, mu_new, nu_new)."""
    upd, mu, nu = _opt_direction(g, mu, nu, t, optimizer, b1, b2, eps)
    if weight_decay:
        upd = upd + weight_decay * w
    return w - lr * upd, mu, nu


def dedup_ids(ids: jax.Array, n_rows: int):
    """jit-stable de-duplication: returns (unique_ids [K], inverse [K_in]).

    ``unique_ids`` is padded with ``n_rows`` (an out-of-range sentinel row);
    scatters use mode='drop' so padding is inert.
    """
    flat = ids.reshape(-1)
    uniq, inv = jnp.unique(
        flat, return_inverse=True, size=flat.shape[0], fill_value=n_rows
    )
    return uniq, inv.reshape(-1)


def sparse_apply(
    table: LPTTable,
    ids: jax.Array,  # int32 [...], the ids that were looked up
    grad_rows: jax.Array,  # f32 [..., d], cotangent per lookup occurrence
    *,
    lr: jax.Array,
    bits: int,
    rounding: str = "sr",
    noise_key: jax.Array | None = None,
    optimizer: str = "adam",
    weight_decay: float = 0.0,
    new_step: jax.Array | None = None,  # ALPT passes the freshly learned Delta_b
    return_updated_rows: bool = False,
    id_space: int | None = None,  # sentinel for dedup (< n_rows on padded tables)
    use_kernels: bool = False,
):
    """Paper-faithful LPT update: only rows present in ``ids`` change.

    Duplicate ids in the batch have their gradients summed (the same semantics
    autodiff would give a dense table scatter-add).

    ``id_space`` is the logical id range (``spec.n``); on ``pad_to_tiles``
    tables it is smaller than ``n_rows``, which turns the dedup sentinel into
    a real-but-dead *scratch row* — the precondition for the fused
    ``ops.sparse_row_update`` kernel, whose ids-driven aliased scatter must
    never point outside the table.  ``use_kernels`` routes the
    gather+Adam+SR+scatter loop through that kernel when eligible (SR
    rounding, row-Adam, no ALPT ``new_step``, scratch row present); anything
    else falls back to the jnp path below, which is bitwise-compatible on
    every live row (scratch-row bytes are unspecified scratch on both paths).
    """
    n = table.n_rows
    d = table.dim
    sentinel = n if id_space is None else id_space
    flat_ids = ids.reshape(-1)
    flat_g = grad_rows.reshape(-1, grad_rows.shape[-1]).astype(jnp.float32)
    if flat_g.shape[-1] != d:
        # Live-width cotangents against a pad_to_tiles table: the tail
        # columns were never looked up, so their gradient is exactly zero.
        flat_g = jnp.pad(flat_g, ((0, 0), (0, d - flat_g.shape[-1])))
    uniq, inv = dedup_ids(flat_ids, sentinel)
    k = uniq.shape[0]
    g_sum = jnp.zeros((k, d), jnp.float32).at[inv].add(flat_g)
    count = table.count + 1
    t = count.astype(jnp.float32)

    kernel_ok = False
    if use_kernels:
        # Eligibility gate for the fused kernel; an ineligible kernels-on
        # dispatch is a counted fallback, never a silent one.
        if isinstance(table.codes, TieredCodes):
            # The fused kernel's aliased scatter writes the backing container
            # directly; cached rows must route through the hot tier instead.
            ops.note_fallback(
                "sparse_row_update", (n, d), "tiered hot-row cache"
            )
        elif rounding != "sr":
            ops.note_fallback("sparse_row_update", (n, d), "dr rounding")
        elif optimizer != "adam":
            ops.note_fallback(
                "sparse_row_update", (n, d), f"row optimizer {optimizer!r}"
            )
        elif new_step is not None:
            ops.note_fallback(
                "sparse_row_update", (n, d), "caller-supplied new_step"
            )
        elif sentinel >= n:  # no scratch row for the aliased scatter
            ops.note_fallback(
                "sparse_row_update", (n, d),
                "no scratch row past the id space (pad_to_tiles off)",
            )
        else:
            kernel_ok = True
    if kernel_ok:
        if noise_key is None:
            raise ValueError("SR requires noise_key")
        noise = quant.sr_noise(noise_key, (k, d))
        c1 = 1.0 - 0.9**t
        c2 = 1.0 - 0.999**t
        codes2, mu2, nu2, w_new = ops.sparse_row_update(
            table.codes, table.step, table.mu, table.nu, uniq, g_sum, noise,
            lr, c1, c2, bits, weight_decay=weight_decay,
        )
        new_table = LPTTable(
            codes=codes2, step=table.step, mu=mu2, nu=nu2, count=count
        )
        if return_updated_rows:
            return new_table, (uniq, w_new)
        return new_table

    # Gather current rows + optimizer slots (sentinel gathers row 0 harmlessly;
    # its scatter is dropped).
    safe = jnp.minimum(uniq, n - 1)
    w = quant.dequantize(
        rowstore.take_rows(table.codes, safe), jnp.take(table.step, safe)
    )
    # Slot layout is optimizer-dependent ([k, d] adam / [k] otherwise) but the
    # gather is row-indexed either way.
    mu = jnp.take(table.mu, safe, axis=0)
    nu = jnp.take(table.nu, safe, axis=0)
    w_new, mu_new, nu_new = _row_update(
        w, g_sum, mu, nu, t, lr, optimizer, weight_decay
    )
    step_rows = jnp.take(table.step, safe) if new_step is None else new_step
    if rounding == "sr":
        if noise_key is None:
            raise ValueError("SR requires noise_key")
        noise = quant.sr_noise(noise_key, w_new.shape)
    else:
        noise = None
    new_codes_rows = quant.quantize_codes(w_new, step_rows, bits, rounding, noise)
    codes = rowstore.set_rows(table.codes, uniq, new_codes_rows, mode="drop")
    step = table.step.at[uniq].set(step_rows, mode="drop")
    mu_t = table.mu.at[uniq].set(mu_new, mode="drop")
    nu_t = table.nu.at[uniq].set(nu_new, mode="drop")
    new_table = LPTTable(codes=codes, step=step, mu=mu_t, nu=nu_t, count=count)
    if return_updated_rows:
        return new_table, (uniq, w_new)
    return new_table


def dense_apply(
    table: LPTTable,
    grad_table: jax.Array,  # f32 [n, d] dense gradient (zero on untouched rows)
    *,
    lr: jax.Array,
    bits: int,
    rounding: str = "sr",
    noise_key: jax.Array | None = None,
    optimizer: str = "adam",
    weight_decay: float = 0.0,
    new_step: jax.Array | None = None,
    use_kernels: bool = False,
) -> LPTTable:
    """pjit-friendly LPT update: dense compute, touched-row masking.

    A row is "touched" iff any element of its gradient is nonzero; untouched
    rows keep their codes/slots bit-identical (exact sparse semantics, but the
    computation is dense and therefore shards trivially over the vocab axis).

    ``use_kernels`` routes the write-back through the fused
    ``ops.lpt_update`` kernel — the optimizer *direction* is formed in jnp
    (it needs only the gradient and the Adam/Adagrad slots), then one VMEM
    pass de-quantizes, applies the decayed step and SR-requantizes without
    ever materializing the fp32 table in HBM (Eq. 8 in one kernel, including
    ALPT's ``new_step`` requantize-with-learned-Delta).
    """
    touched = jnp.any(grad_table != 0.0, axis=-1)  # [n]
    count = table.count + 1
    t = count.astype(jnp.float32)
    step = table.step if new_step is None else new_step
    kernel_ok = use_kernels and rounding == "sr"
    if use_kernels and rounding != "sr":
        ops.note_fallback("lpt_update", table.codes.shape, "dr rounding")
    if kernel_ok and isinstance(table.codes, TieredCodes):
        # The fused write-back targets the backing container; cached rows
        # must take their new codes through the hot tier's where-merge.
        ops.note_fallback(
            "lpt_update", table.codes.shape, "tiered hot-row cache"
        )
        kernel_ok = False
    if kernel_ok:
        if noise_key is None:
            raise ValueError("SR requires noise_key")
        upd, mu_new, nu_new = _opt_direction(
            grad_table, table.mu, table.nu, t, optimizer
        )
        noise = quant.sr_noise(noise_key, grad_table.shape)
        codes_new = ops.lpt_update(
            table.codes, table.step, upd, noise, lr, bits,
            new_step=None if new_step is None else step,
            weight_decay=weight_decay,
        )
    else:
        w = dense_table(table)
        w_new, mu_new, nu_new = _row_update(
            w, grad_table, table.mu, table.nu, t, lr, optimizer, weight_decay
        )
        if rounding == "sr":
            if noise_key is None:
                raise ValueError("SR requires noise_key")
            noise = quant.sr_noise(noise_key, w_new.shape)
        else:
            noise = None
        codes_new = quant.quantize_codes(w_new, step, bits, rounding, noise)
    mask = touched[:, None]
    codes = rowstore.where_rows(table.codes, touched, codes_new)
    if table.mu.ndim == 2:
        mu = jnp.where(mask, mu_new, table.mu)
        nu = jnp.where(mask, nu_new, table.nu)
    else:
        mu = jnp.where(touched, mu_new, table.mu)
        nu = jnp.where(touched, nu_new, table.nu)
    step_out = jnp.where(touched, step, table.step) if new_step is not None else table.step
    return LPTTable(codes=codes, step=step_out, mu=mu, nu=nu, count=count)


def memory_bytes(table: LPTTable, bits: int, count_optimizer: bool = False) -> int:
    """Training-memory accounting (codes + Delta), storage-actual.

    Reports the *container's* resident bytes — ``ceil(d * bits / 8)`` per row
    for a packed CodeStore, one byte per code otherwise — so the paper Table 1
    compression figures reflect what is actually allocated, not an idealized
    bits/8 that an int8-per-code layout never achieved.
    """
    n, _ = table.codes.shape
    code_bytes = rowstore.resident_bytes_of(table.codes)
    step_bytes = n * 4
    total = code_bytes + step_bytes
    if count_optimizer:
        total += table.mu.size * 4 + table.nu.size * 4
    return int(total)
