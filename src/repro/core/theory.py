"""Convergence theory (paper §3.1, Theorems 1-2) + the Fig. 3 synthetic experiment.

Theorem 1 (SR, from Li et al. 2017):
    E[F(wbar_T) - F(w*)] <= D^2/(2 eta sqrt(T)) + eta G^2/sqrt(T) + sqrt(d) Delta G / 2

Theorem 2 (DR, this paper), with T0 = floor(2 eta G / (sqrt(d) Delta)):
    ... + 3 eta G^2/sqrt(T) + sqrt(d) Delta G / 2
        + sqrt(d) D Delta sum_{t<=T0} sqrt(t) / (2 eta T) + (T - T0) D G / T

The synthetic experiment minimizes f(w) = (w - 0.5)^2 for 1000 parameters with
eta_t = eta/sqrt(t), Delta = 0.01, m = 8 — reproducing the paper's Fig. 3:
SR tracks full-precision, DR stalls once |eta_t f'(w)| < Delta/2 (Remark 1).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quant


def sr_bound(D: float, G: float, eta: float, d: int, delta: float, T: int) -> float:
    """RHS of Theorem 1 (Eq. 11)."""
    return (
        D * D / (2.0 * eta * math.sqrt(T))
        + eta * G * G / math.sqrt(T)
        + math.sqrt(d) * delta * G / 2.0
    )


def dr_bound(D: float, G: float, eta: float, d: int, delta: float, T: int) -> float:
    """RHS of Theorem 2 (Eq. 12)."""
    T0 = min(int(2.0 * eta * G / (math.sqrt(d) * delta)), T)
    sum_sqrt = sum(math.sqrt(t) for t in range(1, T0 + 1))
    return (
        D * D / (2.0 * eta * math.sqrt(T))
        + 3.0 * eta * G * G / math.sqrt(T)
        + math.sqrt(d) * delta * G / 2.0
        + math.sqrt(d) * D * delta * sum_sqrt / (2.0 * eta * T)
        + (T - T0) * D * G / T
    )


class SyntheticResult(NamedTuple):
    w_final: jax.Array  # [n] parameters after T iterations
    mean_abs_err: jax.Array  # [T] mean |w - 0.5| trajectory
    stalled_frac: jax.Array  # [T] fraction with |eta_t f'(w)| < Delta/2 (Remark 1)


def synthetic_experiment(
    method: str,  # 'fp' | 'dr' | 'sr'
    *,
    iters: int = 1000,
    n: int = 1000,
    eta: float = 0.3,
    delta: float = 0.01,
    bits: int = 8,
    seed: int = 0,
) -> SyntheticResult:
    """min_w (w - 0.5)^2, n params init U[0,1], eta_t = eta/sqrt(t).

    Deviation note: the paper states eta = 1, but with f'(w) = 2(w - 0.5) and
    eta_t = eta/sqrt(t) the multiplier (1 - 2 eta_t) hits exactly 0 at t = 4,
    so EVERY method (FP, DR, SR) lands on w* in four steps — degenerate and
    clearly not what Fig. 3 shows.  eta = 0.3 keeps the contraction strictly
    inside (0, 1) and reproduces the figure's qualitative structure: FP -> 0,
    SR -> quantization floor at FP-like rate, DR stalls per Remark 1.
    """
    key = jax.random.PRNGKey(seed)
    k0, kloop = jax.random.split(key)
    w0 = jax.random.uniform(k0, (n,), jnp.float32)
    if method in ("dr", "sr"):
        w0 = quant.quantize(w0, delta, bits, "dr")

    def grad(w):
        return 2.0 * (w - 0.5)

    def body(carry, t):
        w, k = carry
        eta_t = eta / jnp.sqrt(t.astype(jnp.float32))
        g = grad(w)
        upd = w - eta_t * g
        if method == "fp":
            w_new = upd
        elif method == "dr":
            w_new = quant.quantize(upd, delta, bits, "dr")
        else:
            k, kn = jax.random.split(k)
            noise = quant.sr_noise(kn, upd.shape)
            w_new = quant.quantize(upd, delta, bits, "sr", noise)
        stalled = jnp.mean((jnp.abs(eta_t * g) < delta / 2.0).astype(jnp.float32))
        return (w_new, k), (jnp.mean(jnp.abs(w_new - 0.5)), stalled)

    (w_final, _), (traj, stalled) = jax.lax.scan(
        body, (w0, kloop), jnp.arange(1, iters + 1)
    )
    return SyntheticResult(w_final=w_final, mean_abs_err=traj, stalled_frac=stalled)
