from repro.optim.adam import (
    OptState,
    adam_init,
    adam_update,
    clip_by_global_norm,
    sgd_init,
    sgd_update,
    make_optimizer,
)
from repro.optim.schedule import (
    constant_schedule,
    step_decay_schedule,
    cosine_schedule,
    warmup_cosine_schedule,
)

__all__ = [
    "OptState",
    "adam_init",
    "adam_update",
    "clip_by_global_norm",
    "sgd_init",
    "sgd_update",
    "make_optimizer",
    "constant_schedule",
    "step_decay_schedule",
    "cosine_schedule",
    "warmup_cosine_schedule",
]
