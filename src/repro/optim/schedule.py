"""Learning-rate schedules, including the paper's step decay.

Paper §4.1: lr 1e-3, reduce tenfold after epochs 6 and 9; the theory (§3.1)
assumes eta_t = eta / sqrt(t), which ``inv_sqrt_schedule`` provides for the
synthetic experiment.
"""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    def fn(step):
        return jnp.asarray(lr, jnp.float32)

    return fn


def step_decay_schedule(lr: float, boundaries: tuple[int, ...], factor: float = 0.1):
    """Paper's schedule: multiply by ``factor`` at each boundary step."""

    def fn(step):
        mult = jnp.asarray(1.0, jnp.float32)
        for b in boundaries:
            mult = mult * jnp.where(step >= b, factor, 1.0)
        return lr * mult

    return fn


def inv_sqrt_schedule(lr: float):
    """eta_t = eta / sqrt(t) (t is 1-indexed) — the theory's schedule."""

    def fn(step):
        return lr / jnp.sqrt(jnp.maximum(step.astype(jnp.float32), 1.0))

    return fn


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1.0 - final_frac) * cos)

    return fn


def warmup_cosine_schedule(lr: float, warmup: int, total_steps: int, final_frac=0.1):
    cos = cosine_schedule(lr, max(total_steps - warmup, 1), final_frac)

    def fn(step):
        s = step.astype(jnp.float32)
        warm = lr * s / jnp.maximum(warmup, 1)
        return jnp.where(step < warmup, warm, cos(step - warmup))

    return fn
