"""Minimal pytree optimizers (Adam/AdamW/SGD) — no external deps.

The paper trains with Adam (lr 1e-3) and decoupled weight decay on embeddings;
we reproduce that and reuse the same machinery for the LM substrates.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array  # scalar int32
    mu: Any  # first moment pytree (Adam) or None-like empty tuple (SGD)
    nu: Any  # second moment pytree


def _zeros_like_f32(p):
    return jnp.zeros(p.shape, jnp.float32)


def adam_init(params) -> OptState:
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(_zeros_like_f32, params),
        nu=jax.tree.map(_zeros_like_f32, params),
    )


def adam_update(
    grads,
    state: OptState,
    params,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
):
    """One AdamW step. Returns (new_params, new_state)."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g32
        v = b2 * v + (1.0 - b2) * jnp.square(g32)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay:
            update = update + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, mu=new_m, nu=new_v)


def sgd_init(params) -> OptState:
    return OptState(step=jnp.zeros((), jnp.int32), mu=(), nu=())


def sgd_update(grads, state: OptState, params, lr, *, weight_decay: float = 0.0):
    def upd(p, g):
        g32 = g.astype(jnp.float32)
        if weight_decay:
            g32 = g32 + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * g32).astype(p.dtype)

    return jax.tree.map(upd, params, grads), OptState(
        step=state.step + 1, mu=(), nu=()
    )


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def make_optimizer(name: str):
    """Returns (init_fn, update_fn) for 'adam' | 'adamw' | 'sgd'."""
    if name in ("adam", "adamw"):
        return adam_init, adam_update
    if name == "sgd":
        return sgd_init, sgd_update
    raise ValueError(f"unknown optimizer {name!r}")
