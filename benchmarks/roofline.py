"""§Roofline report: reads the dry-run JSON cells and prints the per-cell
three-term roofline table (compute / memory / collective seconds, bottleneck,
MODEL_FLOPS/HLO ratio, roofline fraction).
"""
import json
import pathlib

from benchmarks.common import emit

RESULTS = pathlib.Path(__file__).resolve().parent / "dryrun_results"


def load_cells():
    cells = {}
    for p in sorted(RESULTS.glob("*.json")):
        d = json.loads(p.read_text())
        cells[p.stem] = d
    return cells


def run():
    cells = load_cells()
    if not cells:
        emit("roofline/none", 0.0,
             "no dry-run results; run python -m repro.launch.dryrun --all")
        return {}
    n_ok = n_skip = n_err = 0
    for name, d in cells.items():
        if d["status"] == "skipped":
            n_skip += 1
            emit(f"roofline/{name}", 0.0, f"SKIP: {d['reason'][:60]}")
            continue
        if d["status"] != "ok":
            n_err += 1
            emit(f"roofline/{name}", 0.0, f"ERROR: {d.get('error','?')[:80]}")
            continue
        n_ok += 1
        r = d["roofline"]
        emit(
            f"roofline/{name}",
            r["step_time_lower_bound_s"] * 1e6,
            f"bottleneck={r['bottleneck']} compute_s={r['compute_s']:.3f} "
            f"memory_s={r['memory_s']:.3f} collective_s={r['collective_s']:.3f} "
            f"useful_ratio={r['useful_flops_ratio']:.3f} "
            f"roofline_frac={r['roofline_fraction']:.3f} "
            f"fits16gb={d.get('fits_16gb_hbm')}",
        )
    emit("roofline/summary", 0.0, f"ok={n_ok} skipped={n_skip} errors={n_err}")
    return cells


if __name__ == "__main__":
    run()
