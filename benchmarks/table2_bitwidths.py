"""Paper Table 2: quantization methods at smaller bit widths (2/4-bit).

Claim under test: ALPT(SR) > LPT(SR) at every width, gap widening as bits
shrink; QAT (LSQ) degrades more gracefully than LPT-family (it keeps fp
master weights).
"""
from benchmarks.common import AVAZU_MINI, emit, run_method

METHODS = ["pact", "lsq", "lpt", "alpt"]


def run(steps=None):
    results = {}
    for bits in (4, 2):
        for m in METHODS:
            kw = {"bits": bits}
            if m == "lpt":
                kw["clip_value"] = 0.1  # paper: tuned clip 0.1 for 2/4-bit
            if steps:
                kw["steps"] = steps
            r = run_method(AVAZU_MINI, m, **kw)
            results[(bits, m)] = r
            emit(
                f"table2/avazu/{bits}bit/{m}",
                r["us_per_step"],
                f"auc={r['auc']:.4f} logloss={r['logloss']:.4f}",
            )
    return results


if __name__ == "__main__":
    run()
