"""Paper Table 3: scalability — larger embedding dim (d=32) and more features.

Claims: with d=32 ALPT matches-or-beats FP; with a larger feature vocabulary
(threshold lowered -> more rows) ALPT stays lossless.
"""
import dataclasses

from benchmarks.common import AVAZU_MINI, emit, run_method


def run(steps=None):
    results = {}
    kw = {"steps": steps} if steps else {}
    # d = 32.
    for m in ("fp", "lpt", "alpt"):
        r = run_method(AVAZU_MINI, m, d=32, **kw)
        results[("d32", m)] = r
        emit(f"table3/avazu_d32/{m}", r["us_per_step"],
             f"auc={r['auc']:.4f} logloss={r['logloss']:.4f}")
    # More features: double every field's cardinality (threshold 2 -> 1).
    bigger = dataclasses.replace(
        AVAZU_MINI,
        cardinalities=tuple(2 * c for c in AVAZU_MINI.cardinalities),
        name="avazu-mini-thr1",
    )
    for m in ("fp", "lpt", "alpt"):
        r = run_method(bigger, m, **kw)
        results[("thr1", m)] = r
        emit(f"table3/avazu_thr1/{m}", r["us_per_step"],
             f"auc={r['auc']:.4f} logloss={r['logloss']:.4f}")
    return results


if __name__ == "__main__":
    run()
