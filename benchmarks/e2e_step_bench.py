"""End-to-end train-step benchmark: kernels-on vs kernels-off, CTR and LM.

Measures us/step and models the embedding-path HBM bytes for
{ctr, lm} x {kernels on, off} x bits {2, 4, 8}, asserting the kernels-on path
runs with ZERO shape fallbacks (the configs are pad_to_tiles-aligned), and
writes ``BENCH_PR4.json`` at the repo root — the first entry in the repo's
perf trajectory; later PRs append cells to the same schema.

Each cell also records ``packed_bytes`` — the measured resident bytes of the
live code container (sub-byte widths live packed at ``8 // bits`` codes per
byte) — and the run asserts the packed-storage acceptance bar: the 4-bit
table is at most 0.55x the 8-bit table's resident bytes.

Two caveats the numbers carry explicitly:

* off-TPU the kernels run under the Pallas *interpreter*, so the CPU
  ``us_per_step`` of the kernels-on cells measures interpreter overhead, not
  TPU speed (``backend``/``interpret`` are recorded per run).  The number
  that transfers to TPU is ``embed_bytes_per_step`` — the kernels are
  memory-bound, so bytes moved is the roofline.
* ``embed_bytes_per_step`` is an analytic model of the embedding hot path
  (documented per formula below), not an HLO measurement: it counts operand +
  result bytes of each op the step runs, which is what the fused kernels
  change.

Usage:
  PYTHONPATH=src python -m benchmarks.e2e_step_bench            # full
  PYTHONPATH=src python -m benchmarks.e2e_step_bench --smoke    # CI artifact
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro import configs, methods
from repro.configs.common import concrete_batch
from repro.storage import base as rowstore
from repro.core import codestore
from repro.core.alpt import ALPTConfig
from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic
from repro.kernels import ops
from repro.models.ctr import DCNConfig
from repro.obs.stats import StreamingQuantiles
from repro.obs.trace import tracer
from repro.training import lm_trainer
from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

OUT_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_PR4.json"

CTR_DATA = CTRDatasetConfig(
    name="bench-ctr", n_fields=24,
    cardinalities=tuple([97, 41, 13, 211, 89, 53, 17, 149, 61, 29, 103, 43,
                         19, 157, 71, 31, 11, 223, 83, 37, 23, 131, 59, 47]),
    teacher_rank=6, seed=1,
)
CTR_D = 16
CTR_BATCH = 256


def _code_b(bits: int) -> float:
    """Bytes per code as stored: packed sub-byte widths move bits/8 B."""
    return bits / 8 if codestore.is_packable(bits) else 1.0


def ctr_embed_bytes(n_ids: int, d: int, bits: int, on: bool) -> int:
    """Embedding bytes per CTR sparse step (operand + result accounting).

    Shared by both paths (K = n_ids unique-row slots, c = stored bytes per
    code — 1 for 8-bit, bits/8 for the packed sub-byte widths):
      lookup: K*d codes in (cB) + K*d f32 rows out (4B)
      update: K*d each of grad/noise/mu/nu in (4B), codes in (cB),
              codes out (cB) + mu/nu out (4B each) + w_new out (4B)
    The unfused path additionally materializes the gathered codes, the
    de-quantized f32 rows and the pre-requantize f32 rows in HBM
    (+c+8 B/elem) — exactly the intermediates the fused kernels keep in VMEM.
    """
    c = _code_b(bits)
    per_elem = (c + 4) + (4 + 4 + 4 + 4 + c) + (c + 4 + 4 + 4)
    if not on:
        per_elem += c + 4 + 4
    return int(n_ids * d * per_elem)


def lm_embed_bytes(vocab: int, d: int, bits: int, on: bool) -> int:
    """Embedding bytes per LM dense step (write-back only; the forward's
    dense-table materialization is identical on both paths).

    Unfused: de-quantized table f32 out+in (8B) + updated table f32 out+in
    (8B) + requantized codes out (cB) + codes in (cB) = 16+2c B/elem.
    Fused ``ops.lpt_update``: codes in (cB) + direction in (4B) + noise in
    (4B) + codes out (cB) = 8+2c B/elem — the fp32 table never round-trips.
    """
    c = _code_b(bits)
    per_elem = (8 + 2 * c) if on else (16 + 2 * c)
    return int(vocab * d * per_elem)


def _bench_loop(step_fn, state, batches, warmup: int = 1):
    """Returns (mean us/step, per-step quantile summary in us).

    Per-step times block on the step's own loss, so the quantiles measure
    real step latency (the mean over the whole loop stays the headline
    number for baseline comparability).
    """
    for i in range(warmup):
        state, m = step_fn(state, *batches[i % len(batches)])
    jax.block_until_ready(m["loss"])
    q = StreamingQuantiles()
    t0 = time.perf_counter()
    for i in range(len(batches)):
        t1 = time.perf_counter()
        state, m = step_fn(state, *batches[i])
        jax.block_until_ready(m["loss"])
        q.add((time.perf_counter() - t1) * 1e6)
    mean_us = (time.perf_counter() - t0) / len(batches) * 1e6
    return mean_us, q.to_json()


def run_ctr(bits: int, use_kernels: bool, steps: int) -> dict:
    # Fresh traces per cell: dispatch (and therefore fallback/kernel-call
    # accounting, which counts distinct traces) must not leak across cells.
    jax.clear_caches()
    data = CTRSynthetic(CTR_DATA)
    spec = methods.EmbeddingSpec(
        method="lpt", n=CTR_DATA.n_features, d=CTR_D, bits=bits,
        init_scale=0.05, alpt=ALPTConfig(bits=bits),
        use_kernels=use_kernels, pad_to_tiles=True,
    )
    dcn = DCNConfig(n_fields=CTR_DATA.n_fields, emb_dim=CTR_D, cross_depth=2,
                    mlp_widths=(128, 64))
    tr = CTRTrainer(TrainerConfig(spec=spec, model="dcn", dcn=dcn, lr=1e-3))
    state = tr.init_state()
    batches = [data.batch("train", i, CTR_BATCH) for i in range(steps)]
    ops.reset_fallback_stats()
    us, step_q = _bench_loop(tr.train_step, state, batches)
    stats = ops.fallback_stats()
    return {
        "us_per_step": round(us, 1),
        "step_time_us": step_q,
        "embed_bytes_per_step": ctr_embed_bytes(
            CTR_BATCH * CTR_DATA.n_fields, spec.d_padded, bits, use_kernels
        ),
        # Measured resident bytes of the live code container (not a model).
        "packed_bytes": rowstore.resident_bytes_of(state.emb_state.codes),
        "shape_fallbacks": stats["total_fallbacks"],
        "kernel_calls": stats["kernel_calls"],
        "table_rows": spec.n_padded,
        "ids_per_step": CTR_BATCH * CTR_DATA.n_fields,
    }


def run_lm(bits: int, use_kernels: bool, steps: int) -> dict:
    jax.clear_caches()
    cfg = dataclasses.replace(
        configs.smoke_config("smollm-135m"),
        embedding_method="lpt", embedding_bits=bits,
    )
    tcfg = lm_trainer.LMTrainerConfig(
        lr=1e-3, use_kernels=use_kernels, pad_to_tiles=True
    )
    step = jax.jit(lm_trainer.make_train_step(cfg, tcfg))
    state = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    batch = concrete_batch(cfg, batch=4, seq=64)
    spec = lm_trainer.embedding_spec_of(cfg, tcfg)
    ops.reset_fallback_stats()

    def step2(state, batch):
        return step(state, batch)

    us, step_q = _bench_loop(step2, state, [(batch,)] * steps)
    stats = ops.fallback_stats()
    return {
        "us_per_step": round(us, 1),
        "step_time_us": step_q,
        "embed_bytes_per_step": lm_embed_bytes(
            spec.n_padded, spec.d_padded, bits, use_kernels
        ),
        "packed_bytes": rowstore.resident_bytes_of(state.table.codes),
        "shape_fallbacks": stats["total_fallbacks"],
        "kernel_calls": stats["kernel_calls"],
        "vocab_rows": spec.n_padded,
    }


def run(steps_ctr: int = 20, steps_lm: int = 8) -> dict:
    cells = {}
    for workload, runner, steps in (
        ("ctr", run_ctr, steps_ctr), ("lm", run_lm, steps_lm)
    ):
        for bits in (2, 4, 8):
            for on in (True, False):
                cell = runner(bits, on, steps)
                name = f"{workload}/bits{bits}/kernels_{'on' if on else 'off'}"
                cells[name] = cell
                emit(f"e2e/{name}", cell["us_per_step"],
                     f"embed_bytes={cell['embed_bytes_per_step']} "
                     f"packed_bytes={cell['packed_bytes']} "
                     f"fallbacks={cell['shape_fallbacks']}")
                if on and cell["shape_fallbacks"]:
                    raise SystemExit(
                        f"{name}: kernels-on hit {cell['shape_fallbacks']} "
                        f"shape fallbacks — the benchmark configs must be "
                        f"tile-aligned: {ops.fallback_stats()['fallbacks']}"
                    )
        # Packed-storage acceptance bar: sub-byte containers actually shrink
        # the resident table (4-bit <= 0.55x 8-bit, 2-bit <= 0.30x 8-bit).
        for on in ("on", "off"):
            b8 = cells[f"{workload}/bits8/kernels_{on}"]["packed_bytes"]
            b4 = cells[f"{workload}/bits4/kernels_{on}"]["packed_bytes"]
            b2 = cells[f"{workload}/bits2/kernels_{on}"]["packed_bytes"]
            if b4 > 0.55 * b8 or b2 > 0.30 * b8:
                raise SystemExit(
                    f"{workload}/kernels_{on}: packed_bytes ratio regressed "
                    f"(bits2={b2}, bits4={b4}, bits8={b8}) — sub-byte codes "
                    f"must stay packed"
                )
    return cells


def bench_obs_overhead(smoke: bool) -> dict:
    """Armed-tracer overhead on the CTR training step (PR 10 bar).

    With tracing armed every step records two spans (train.step +
    train.writeback) and one span-edge fence; the jitted computation is
    unchanged (bitwise parity is asserted in tests/test_obs.py).  Asserts
    the instrumented step's best-case time stays within 3% of the
    uninstrumented step (min-of-N: scheduler noise only ever adds time).
    """
    steps = 30 if smoke else 80
    data = CTRSynthetic(CTR_DATA)

    def min_step_s(traced: bool) -> float:
        spec = methods.EmbeddingSpec(
            method="alpt", n=CTR_DATA.n_features, d=CTR_D, bits=8,
            init_scale=0.05,
        )
        trainer = CTRTrainer(TrainerConfig(
            spec=spec, model="dcn",
            dcn=DCNConfig(n_fields=CTR_DATA.n_fields, emb_dim=CTR_D,
                          cross_depth=2, mlp_widths=(64, 32)),
        ))
        state = trainer.init_state()
        if traced:
            tracer().enable()
        best = float("inf")
        try:
            for i in range(steps):
                ids, labels = data.batch("train", i, 256)
                t0 = time.perf_counter()
                state, m = trainer.train_step(state, ids, labels)
                float(m["loss"])  # block on the device work
                if i >= 3:  # skip compile + cache-warm steps
                    best = min(best, time.perf_counter() - t0)
        finally:
            tracer().disable()
            tracer().clear()
        return best

    base = min_step_s(False)
    on = min_step_s(True)
    overhead = on / base - 1.0
    assert overhead <= 0.03, (
        f"tracing-armed step {on*1e6:.0f}us exceeds tracing-off "
        f"{base*1e6:.0f}us by {overhead:.1%} (> 3%)"
    )
    emit("e2e/obs-overhead", overhead * 100,
         f"off={base*1e6:.0f}us on={on*1e6:.0f}us")
    return {"step_us_obs_off": base * 1e6, "step_us_obs_on": on * 1e6,
            "overhead_frac": overhead}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short loops (CI artifact)")
    ap.add_argument("--out", default=str(OUT_PATH))
    args = ap.parse_args(argv)
    cells = run(steps_ctr=5 if args.smoke else 20,
                steps_lm=3 if args.smoke else 8)
    obs_overhead = bench_obs_overhead(args.smoke)
    doc = {
        "schema": "repro/e2e_step_bench/v1",
        "pr": 4,
        "backend": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
        "note": (
            "us_per_step on CPU measures the Pallas interpreter for the "
            "kernels-on cells; embed_bytes_per_step is the number that "
            "transfers to TPU (memory-bound ops)"
        ),
        "cells": cells,
        "obs_overhead": obs_overhead,
    }
    pathlib.Path(args.out).write_text(json.dumps(doc, indent=2) + "\n")
    print(f"[e2e_step_bench] wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
