"""Benchmark harness — one module per paper table/figure + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks training steps
(CI mode); the full run reproduces the paper's orderings at reduced scale.
"""
import argparse
import os
import time

# Before any benchmark imports jax: the dp_sync suite needs a multi-device
# (fake CPU) mesh, and the flag must be set before the backend initializes.
# Single-device benchmarks are unaffected (they run on device 0).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer train steps")
    ap.add_argument(
        "--only",
        default=None,
        help="comma list from: table1,table2,table3,fig3,fig4,kernels,serve,"
             "roofline,dp_sync",
    )
    args = ap.parse_args(argv)
    steps = 120 if args.quick else None
    want = set(args.only.split(",")) if args.only else None

    def on(name):
        return want is None or name in want

    t0 = time.time()
    print("name,us_per_call,derived")
    if on("fig3"):
        from benchmarks import fig3_synthetic

        fig3_synthetic.run()
    if on("kernels"):
        from benchmarks import kernel_bench

        kernel_bench.run()
    if on("table1"):
        from benchmarks import table1_overall

        table1_overall.run(steps=steps)
    if on("table2"):
        from benchmarks import table2_bitwidths

        table2_bitwidths.run(steps=steps)
    if on("table3"):
        from benchmarks import table3_scalability

        table3_scalability.run(steps=steps)
    if on("fig4"):
        from benchmarks import fig4_stepsize

        fig4_stepsize.run(steps=steps)
    if on("serve"):
        from benchmarks import serve_bench

        serve_bench.run()
    if on("roofline"):
        from benchmarks import roofline

        roofline.run()
    if on("dp_sync"):
        from benchmarks import dp_sync_bench

        dp_sync_bench.run(steps=steps)
    print(f"# total_wall_s={time.time()-t0:.1f}")


if __name__ == "__main__":
    main()
