"""Paper Fig. 3: the synthetic convex problem — SR tracks FP, DR stalls.

Prints the mean |w - 0.5| at t in {10, 100, 1000} per method, plus the DR
stalled-update fraction (Fig. 3d / Remark 1).
"""
import time

from repro.core import theory
from benchmarks.common import emit


def run():
    results = {}
    for method in ("fp", "sr", "dr"):
        t0 = time.time()
        res = theory.synthetic_experiment(method, iters=1000)
        us = (time.time() - t0) * 1e6
        tr = res.mean_abs_err
        results[method] = res
        emit(
            f"fig3/{method}",
            us,
            f"err@10={float(tr[9]):.4f} err@100={float(tr[99]):.4f} "
            f"err@1000={float(tr[999]):.5f}"
            + (f" stalled@50={float(res.stalled_frac[49]):.2f}"
               if method == "dr" else ""),
        )
    # Theorem bound check (Thm 1 vs Thm 2 RHS at matching constants).
    b_sr = theory.sr_bound(D=1.0, G=1.0, eta=0.3, d=1, delta=0.01, T=1000)
    b_dr = theory.dr_bound(D=1.0, G=1.0, eta=0.3, d=1, delta=0.01, T=1000)
    emit("fig3/theorem_bounds", 0.0, f"sr_rhs={b_sr:.4f} dr_rhs={b_dr:.4f}")
    return results


if __name__ == "__main__":
    run()
