"""Paper Table 1: overall performance of ALPT vs baselines at 8-bit.

Columns reproduced: AUC / Logloss / per-step time / train & inference
compression, on synthetic Avazu- and Criteo-shaped data.  The claims under
test (paper §4.2): ALPT(SR) ~ FP >= {LSQ, PACT} > LPT(SR) > {hash, prune-ish}
>> LPT(DR), and LPT/ALPT alone compress *training* memory ~4x.
"""
from benchmarks.common import AVAZU_MINI, CRITEO_MINI, emit, run_method

METHODS = [
    ("fp", {}),
    ("hash", {}),
    ("prune", {}),
    ("pact", {}),
    ("lsq", {}),
    ("lpt_dr", {"rounding": "dr"}),
    ("lpt_sr", {}),
    # DR cannot undo a bad Delta move (Remark 1), so its Delta needs the
    # paper's conservative lr (2e-5); SR tolerates 10x larger (Fig. 4).
    ("alpt_dr", {"rounding": "dr", "step_lr": 2e-5}),
    ("alpt_sr", {}),
]


def run(steps=None):
    results = {}
    for ds_name, ds in (("avazu", AVAZU_MINI), ("criteo", CRITEO_MINI)):
        for label, kw in METHODS:
            method = label.split("_")[0]
            r = run_method(ds, method, **({"steps": steps} if steps else {}),
                           **kw)
            results[(ds_name, label)] = r
            emit(
                f"table1/{ds_name}/{label}",
                r["us_per_step"],
                f"auc={r['auc']:.4f} logloss={r['logloss']:.4f} "
                f"train_comp={r['train_compression']:.1f}x "
                f"inf_comp={r['inference_compression']:.1f}x",
            )
    return results


if __name__ == "__main__":
    run()
