"""Paper Fig. 4: AUC vs step-size learning rate x gradient scaling factor.

Claim: the Delta learning rate matters; the gradient scaling factor barely
does (all three scalings track each other at a given lr).
"""
from benchmarks.common import AVAZU_MINI, emit, run_method


def run(steps=None):
    results = {}
    kw = {"steps": steps} if steps else {}
    for lr in (2e-3, 2e-4, 2e-5):
        for scale in ("1", "dq", "bdq"):
            r = run_method(AVAZU_MINI, "alpt", step_lr=lr, grad_scale=scale,
                           **kw)
            results[(lr, scale)] = r
            emit(f"fig4/alpt_lr{lr:g}_g{scale}", r["us_per_step"],
                 f"auc={r['auc']:.4f}")
    return results


if __name__ == "__main__":
    run()
