"""Serving benchmark for the int8-resident Engine (PR 5 artifact).

Per cell it reports what the redesign promises:

* **us/token** (LM continuous-batch decode) and **us/request** (CTR batched
  scoring) through the same `repro.serving` Engine API — absolute numbers
  are CPU-bound; the trajectory and the derived bytes transfer to TPU;
* **resident embedding bytes** — asserted to equal the int8 code bytes plus
  the scale vectors for every integer-table method, i.e. the Engine never
  re-inflated the table to fp32 (the acceptance criterion);
* the per-engine kernel fallback tally (zero on the aligned geometries).

Usage:
    PYTHONPATH=src python -m benchmarks.serve_bench --smoke --out BENCH_PR5.json
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from benchmarks.common import emit
from repro import configs, methods
from repro.core import codestore
from repro.launch.serve import (
    CTR_DEMO_DATA,
    CTR_DEMO_DIM,
    CTR_ZIPF_DATA,
    build_ctr_demo_engine,
)
from repro.serving import table as serving_tbl
from repro.serving.ctr import CTRRequest
from repro.serving.lm import LMEngine, LMRequest
from repro.training import lm_trainer

LM_ARCHS = ["smollm-135m", "mamba2-370m", "mixtral-8x7b"]
CTR_METHODS = ["lpt", "alpt", "qr_lpt", "qr_alpt", "fp"]


def _assert_int8_resident(engine, fp32_bytes: int) -> None:
    """The acceptance criterion: resident bytes == codes + scales, not fp32."""
    m = engine.metrics()
    resident = m["resident_embedding_bytes"]
    expect = m["embedding_code_bytes"] + m["embedding_scale_bytes"]
    assert engine.int8_resident, "integer-table method not int8-resident"
    assert resident == expect, (resident, expect)
    assert resident < fp32_bytes, (resident, fp32_bytes)
    codes = serving_tbl.code_bytes(engine.table)
    assert codes * 4 <= fp32_bytes, (codes, fp32_bytes)  # int8 vs f32 elems


def bench_lm(arch: str, *, requests: int, gen: int) -> dict:
    cfg = configs.smoke_config(arch)
    tcfg = lm_trainer.LMTrainerConfig()
    state = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    engine = LMEngine.from_state(state, cfg, tcfg, batch=4, max_len=32 + gen)
    rng = np.random.RandomState(0)

    def submit(n):
        for _ in range(n):
            engine.submit(LMRequest(
                prompt=rng.randint(0, cfg.vocab_size, 32).astype(np.int32),
                max_new=gen,
            ))

    submit(2)  # warm the prefill/decode traces
    engine.run()
    engine.reset_metrics()
    submit(requests)
    engine.run()
    m = engine.metrics()
    fp32_bytes = cfg.vocab_size * cfg.d_model * 4
    _assert_int8_resident(engine, fp32_bytes)
    assert m["kernel_fallbacks"] == 0, engine.fallback_report()
    emit(
        f"serve/lm/{arch}", m["us_per_token"],
        f"tok_s={m['tokens_generated'] / m['wall_s']:.1f} "
        f"resident_B={m['resident_embedding_bytes']} fp32_B={fp32_bytes}",
    )
    return {**m, "arch": arch, "fp32_bytes": fp32_bytes}


def bench_ctr(method: str, *, requests: int, bits: int = 8) -> dict:
    engine, data = build_ctr_demo_engine(
        method, bits=bits, batch=32, train_steps=3, train_batch=128,
    )
    warm, _ = data.batch("valid", 0, 32)
    for row in warm:
        engine.submit(CTRRequest(ids=row))
    engine.run()
    engine.reset_metrics()
    ids, _ = data.batch("test", 0, requests)
    for row in ids:
        engine.submit(CTRRequest(ids=row))
    engine.run()
    m = engine.metrics()
    fp32_bytes = CTR_DEMO_DATA.n_features * CTR_DEMO_DIM * 4
    if methods.get(method).is_integer_table:
        _assert_int8_resident(engine, fp32_bytes)
        assert m["kernel_fallbacks"] == 0, engine.fallback_report()
    if codestore.is_packable(bits):
        # Sub-byte cells serve straight off the PACKED container: every code
        # leaf is a packed CodeStore and the reported code bytes are the
        # container's actual (sub-byte) footprint, not one-byte-per-code.
        stores = [
            leaf for leaf in jax.tree.leaves(
                engine.table,
                is_leaf=lambda x: isinstance(x, codestore.CodeStore),
            )
            if isinstance(leaf, codestore.CodeStore)
        ]
        assert stores and all(s.packed for s in stores), "codes not packed"
        assert m["embedding_code_bytes"] == sum(
            s.resident_bytes for s in stores
        )
    emit(
        f"serve/ctr/{method}" + (f"/bits{bits}" if bits != 8 else ""),
        m["us_per_request"],
        f"resident_B={m['resident_embedding_bytes']} fp32_B={fp32_bytes} "
        f"int8={m['int8_resident']}",
    )
    return {**m, "bits": bits, "fp32_bytes": fp32_bytes}


def bench_tiered(method: str, *, requests: int, cache_rows: int,
                 cold_tier: bool = False,
                 device_budget_bytes: int | None = None) -> dict:
    """One Zipf(1.1) cell of the tiered-storage grid (PR 7 artifact).

    Returns the metrics dict plus the scored probabilities, so the caller
    can assert every cell is bitwise-equal to the cache-off baseline."""
    engine, data = build_ctr_demo_engine(
        method, batch=32, train_steps=3, train_batch=128,
        data_cfg=CTR_ZIPF_DATA, cache_rows=cache_rows, cold_tier=cold_tier,
        device_budget_bytes=device_budget_bytes,
    )
    # Warm the jit traces AND let the frequency-admission policy converge on
    # the Zipf head before measuring (8 waves of held-out traffic).
    for i in range(8):
        warm, _ = data.batch("valid", i, 64)
        for row in warm:
            engine.submit(CTRRequest(ids=row))
        engine.run()
    engine.reset_metrics()
    probs = {}
    for i in range(requests // 32):
        ids, _ = data.batch("test", i, 32)
        rids = [engine.submit(CTRRequest(ids=row)) for row in ids]
        done = engine.run()
        probs.update({32 * i + j: done[r]["prob"] for j, r in enumerate(rids)})
    m = engine.metrics()
    frac = cache_rows / CTR_ZIPF_DATA.n_features
    tier = "cold" if cold_tier else ("hot" if cache_rows else "off")
    hit = m.get("cache_hit_rate")
    emit(
        f"serve/tiered/{method}/{tier}-{frac:.2f}",
        m["us_per_request"],
        f"hit={hit if hit is None else round(hit, 3)} "
        f"resident_B={m['resident_embedding_bytes']}",
    )
    return {**m, "cache_rows": cache_rows, "cold_tier": cold_tier,
            "cache_fraction": frac, "probs": probs}


def run_tiered(smoke: bool = False, out: str | None = None) -> dict:
    """The Zipf(1.1) tiered-storage grid: cache {0, 1%, 10%} of the vocab,
    plus a cold-tier cell served under a device budget the full table
    exceeds.  Asserts the PR-7 acceptance bars:

    * every cached cell scores bitwise-equal to the cache-off baseline;
    * the 10% hot tier catches >= 0.9 of Zipf(1.1) lookups;
    * hot-tier device bytes stay inside the declared budget;
    * the cold tier stays under a budget smaller than the full code bytes.
    """
    requests = 64 if smoke else 256
    vocab = CTR_ZIPF_DATA.n_features
    method = "alpt"

    base = bench_tiered(method, requests=requests, cache_rows=0)
    full_code_bytes = base["embedding_code_bytes"]
    cells = [base]
    for frac in (0.01, 0.10):
        rows = max(1, int(vocab * frac))
        # Budget: the declared hot rows + scales + id maps, with headroom
        # for the per-slot bookkeeping — NOT enough for the whole table.
        budget = int(full_code_bytes * frac * 4) + 64 * 1024
        cell = bench_tiered(
            method, requests=requests, cache_rows=rows,
            device_budget_bytes=budget,
        )
        assert cell["probs"] == base["probs"], (
            f"cache_rows={rows} broke bitwise serving parity"
        )
        hot = cell["caches"][0]
        assert hot["hot_bytes"] + hot["metadata_bytes"] <= budget, (
            hot, budget,
        )
        cells.append(cell)
    ten = cells[-1]
    assert ten["cache_hit_rate"] >= 0.9, (
        f"Zipf(1.1) hit rate {ten['cache_hit_rate']:.3f} < 0.9 with a "
        f"10%-of-vocab hot tier"
    )

    cold_budget = full_code_bytes - 1  # the full table must NOT fit
    cold = bench_tiered(
        method, requests=requests, cache_rows=max(1, vocab // 10),
        cold_tier=True, device_budget_bytes=cold_budget,
    )
    assert cold["probs"] == base["probs"], "cold tier broke serving parity"
    assert cold["resident_embedding_bytes"] <= cold_budget
    cells.append(cold)

    results = {
        "data": {"name": CTR_ZIPF_DATA.name, "vocab": vocab,
                 "zipf_a": CTR_ZIPF_DATA.zipf_a},
        "cells": [{k: v for k, v in c.items() if k != "probs"}
                  for c in cells],
    }
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"[serve_bench] wrote {out}")
    return results


def _bench_guard_overhead(smoke: bool) -> dict:
    """Zero-fault guardrail overhead on the CTR training step (PR 9 bar).

    The guard adds an in-jit finiteness reduction plus one ``lax.cond`` to
    every step; with no plan installed the injection seams compile away.
    Asserts the guarded step's best-case time stays within 3% of the
    unguarded step (min-of-N: the robust estimator for a fused jitted step —
    scheduler noise only ever adds time).
    """
    import time

    from repro.data.ctr_synth import CTRSynthetic
    from repro.models.ctr import DCNConfig
    from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

    steps = 30 if smoke else 80
    data = CTRSynthetic(CTR_DEMO_DATA)

    def min_step_s(guard: bool) -> float:
        spec = methods.EmbeddingSpec(
            method="alpt", n=CTR_DEMO_DATA.n_features, d=CTR_DEMO_DIM,
            bits=8, init_scale=0.05,
        )
        trainer = CTRTrainer(TrainerConfig(
            spec=spec, model="dcn",
            dcn=DCNConfig(n_fields=CTR_DEMO_DATA.n_fields,
                          emb_dim=CTR_DEMO_DIM, cross_depth=2,
                          mlp_widths=(64, 32)),
            guard=guard,
        ))
        state = trainer.init_state()
        best = float("inf")
        for i in range(steps):
            ids, labels = data.batch("train", i, 256)
            t0 = time.perf_counter()
            state, m = trainer.train_step(state, ids, labels)
            float(m["loss"])  # block on the device work
            if i >= 3:  # skip compile + cache-warm steps
                best = min(best, time.perf_counter() - t0)
        return best

    base = min_step_s(False)
    guarded = min_step_s(True)
    overhead = guarded / base - 1.0
    assert overhead <= 0.03, (
        f"guardrail-on zero-fault step {guarded*1e6:.0f}us exceeds "
        f"guardrail-off {base*1e6:.0f}us by {overhead:.1%} (> 3%)"
    )
    emit("serve/chaos/guard-overhead", overhead * 100,
         f"off={base*1e6:.0f}us on={guarded*1e6:.0f}us")
    return {"step_us_guard_off": base * 1e6, "step_us_guard_on": guarded * 1e6,
            "overhead_frac": overhead}


def _bench_chaos_serving(smoke: bool) -> dict:
    """Cold-tier Zipf serving with a fault at every serving seam: the
    recovered run must score bitwise-equal to the fault-free run."""
    from repro import faults

    requests = 128 if smoke else 256
    kwargs = dict(
        batch=32, train_steps=3, train_batch=128, data_cfg=CTR_ZIPF_DATA,
        cache_rows=max(1, CTR_ZIPF_DATA.n_features // 10), cold_tier=True,
    )

    def score(engine, data):
        # Enqueue everything up front so the engine drains multiple waves in
        # one run — that keeps the one-deep prefetch staging live, which is
        # where the prefetch-loss and corruption seams sit.
        ids, _ = data.batch("test", 0, requests)
        rids = [engine.submit(CTRRequest(ids=row)) for row in ids]
        done = engine.run()
        return [done[r]["prob"] for r in rids]

    base_engine, data = build_ctr_demo_engine("alpt", **kwargs)
    base_probs = score(base_engine, data)

    faults.install(faults.FaultPlan(specs=(
        faults.FaultSpec(site="cache.admission", steps=(1,)),
        faults.FaultSpec(site="cold.fetch", steps=(1,), params={"fails": 2}),
        faults.FaultSpec(site="cold.prefetch_loss", steps=(2,)),
        faults.FaultSpec(site="codestore.corrupt", steps=(3,)),
        faults.FaultSpec(site="kernels.force_fallback", always=True),
    )))
    try:
        engine, data = build_ctr_demo_engine("alpt", **kwargs)
        probs = score(engine, data)
        assert probs == base_probs, (
            "chaos serving broke bitwise parity with the fault-free run"
        )
        m = engine.metrics()
        health = engine.health()
        assert health["ready"], health  # recovered faults keep it READY
        cold = m["caches"][0]
        tallies = {
            "served_degraded": m["served_degraded"],
            "wave_retries": m["wave_retries"],
            "retry_failures": m["retry_failures"],
            "admission_oom": cold["admission_oom"],
            "prefetch_dropped": cold["prefetch_dropped"],
            "corruption_detected": cold["corruption_detected"],
            "tier_retries": {
                name: s.to_json() for name, s in engine._tier_retry_stats()
            },
        }
        fired = (
            tallies["served_degraded"] and tallies["prefetch_dropped"]
            and tallies["corruption_detected"]
            and tallies["tier_retries"]["cold"]["retries"]
        )
        assert fired, f"a scheduled serving seam never fired: {tallies}"
        assert tallies["retry_failures"] == 0
    finally:
        faults.uninstall()
    emit("serve/chaos/full-plan", m["us_per_request"],
         f"degraded={tallies['served_degraded']} "
         f"retries={tallies['tier_retries']['cold']['retries']} bitwise=ok")
    return {**{k: v for k, v in m.to_json().items() if k != "caches"},
            **tallies, "requests": requests, "bitwise_equal": True}


def run_chaos(smoke: bool = False, out: str | None = None) -> dict:
    """The PR-9 chaos grid: guardrail overhead + full-plan degraded serving.

    * guardrail-on zero-fault CTR step time within 3% of guardrail-off;
    * a cold-tier engine with faults injected at every serving seam
      (admission OOM, fetch failures, prefetch loss, corrupted staged bytes,
      forced kernel fallbacks) scores bitwise-equal to the fault-free run
      and finishes READY with zero retry exhaustions.
    """
    results = {
        "guard_overhead": _bench_guard_overhead(smoke),
        "chaos_serving": _bench_chaos_serving(smoke),
    }
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"[serve_bench] wrote {out}")
    return results


def run(smoke: bool = False, out: str | None = None) -> dict:
    requests = 8 if smoke else 32
    gen = 8 if smoke else 16
    archs = LM_ARCHS[:2] if smoke else LM_ARCHS
    ctr_methods = CTR_METHODS[:4] if smoke else CTR_METHODS
    results = {
        "lm": [bench_lm(a, requests=requests, gen=gen) for a in archs],
        "ctr": [bench_ctr(m, requests=requests * 8) for m in ctr_methods],
    }
    # Packed sub-byte cell: same engine, 4-bit codes resident at 2/byte.
    packed4 = bench_ctr("lpt", requests=requests * 8, bits=4)
    results["ctr"].append(packed4)
    lpt8 = next(
        c for c in results["ctr"]
        if c["embedding_method"] == "lpt" and c["bits"] == 8
    )
    assert (packed4["resident_embedding_bytes"]
            <= 0.55 * lpt8["resident_embedding_bytes"]), (
        "bits=4 serving table not packed: "
        f"{packed4['resident_embedding_bytes']} vs "
        f"{lpt8['resident_embedding_bytes']} (bits=8)"
    )
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"[serve_bench] wrote {out}")
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--tiered", action="store_true",
                    help="run the Zipf(1.1) tiered-storage grid instead "
                         "(cache {0, 1%%, 10%%} of vocab + cold tier); "
                         "--out typically BENCH_PR7.json")
    ap.add_argument("--chaos", action="store_true",
                    help="run the fault-injection grid instead (guardrail "
                         "overhead + full-plan degraded serving parity); "
                         "--out typically BENCH_PR9.json")
    args = ap.parse_args(argv)
    if args.tiered:
        run_tiered(args.smoke, args.out)
    elif args.chaos:
        run_chaos(args.smoke, args.out)
    else:
        run(args.smoke, args.out)
    return 0


if __name__ == "__main__":
    main()
