"""Serving throughput on reduced configs (paper Table 1 reports inference
time; here: prefill latency + decode tok/s for three arch families on CPU —
absolute numbers are CPU-bound, the derived column carries the per-token
cache/table bytes that transfer to TPU).
"""
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro import configs
from repro.launch.serve import ContinuousBatcher, Request
from repro.training import lm_trainer

ARCHS = ["smollm-135m", "mixtral-8x7b", "mamba2-370m"]


def _cache_bytes_per_token(cfg) -> float:
    _, kv = cfg.padded_heads
    per = 0.0
    for layer in range(cfg.n_layers):
        if cfg.layer_type(layer % cfg.period) == "attn":
            per += 2 * kv * cfg.hd * 2  # bf16-ish K+V
    return per


def run():
    for arch in ARCHS:
        cfg = configs.smoke_config(arch)
        tcfg = lm_trainer.LMTrainerConfig()
        state = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
        srv = ContinuousBatcher(state.params, state.table, cfg, batch=4,
                                max_len=48)
        rng = np.random.RandomState(0)
        reqs = [Request(rid=i, prompt=rng.randint(
            0, cfg.vocab_size, 32).astype(np.int32), max_new=8)
            for i in range(4)]
        for r in reqs:
            srv.submit(r)
        t0 = time.time()
        done = srv.run()
        dt = time.time() - t0
        total = sum(len(v) for v in done.values())
        emit(
            f"serve/{arch}",
            dt / max(total, 1) * 1e6,
            f"tok_s={total/dt:.1f} cache_B_per_tok={_cache_bytes_per_token(cfg):.0f} "
            f"int8_table=yes",
        )


if __name__ == "__main__":
    run()
