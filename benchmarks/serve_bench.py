"""Serving benchmark for the int8-resident Engine (PR 5 artifact).

Per cell it reports what the redesign promises:

* **us/token** (LM continuous-batch decode) and **us/request** (CTR batched
  scoring) through the same `repro.serving` Engine API — absolute numbers
  are CPU-bound; the trajectory and the derived bytes transfer to TPU;
* **resident embedding bytes** — asserted to equal the int8 code bytes plus
  the scale vectors for every integer-table method, i.e. the Engine never
  re-inflated the table to fp32 (the acceptance criterion);
* the per-engine kernel fallback tally (zero on the aligned geometries).

Usage:
    PYTHONPATH=src python -m benchmarks.serve_bench --smoke --out BENCH_PR5.json
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from benchmarks.common import emit
from repro import configs, methods
from repro.core import codestore
from repro.launch.serve import (
    CTR_DEMO_DATA,
    CTR_DEMO_DIM,
    CTR_ZIPF_DATA,
    build_ctr_demo_engine,
)
from repro.serving import table as serving_tbl
from repro.serving.ctr import CTRRequest
from repro.serving.lm import LMEngine, LMRequest
from repro.training import lm_trainer

LM_ARCHS = ["smollm-135m", "mamba2-370m", "mixtral-8x7b"]
CTR_METHODS = ["lpt", "alpt", "qr_lpt", "qr_alpt", "fp"]


def _assert_int8_resident(engine, fp32_bytes: int) -> None:
    """The acceptance criterion: resident bytes == codes + scales, not fp32."""
    m = engine.metrics()
    resident = m["resident_embedding_bytes"]
    expect = m["embedding_code_bytes"] + m["embedding_scale_bytes"]
    assert engine.int8_resident, "integer-table method not int8-resident"
    assert resident == expect, (resident, expect)
    assert resident < fp32_bytes, (resident, fp32_bytes)
    codes = serving_tbl.code_bytes(engine.table)
    assert codes * 4 <= fp32_bytes, (codes, fp32_bytes)  # int8 vs f32 elems


def bench_lm(arch: str, *, requests: int, gen: int) -> dict:
    cfg = configs.smoke_config(arch)
    tcfg = lm_trainer.LMTrainerConfig()
    state = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    engine = LMEngine.from_state(state, cfg, tcfg, batch=4, max_len=32 + gen)
    rng = np.random.RandomState(0)

    def submit(n):
        for _ in range(n):
            engine.submit(LMRequest(
                prompt=rng.randint(0, cfg.vocab_size, 32).astype(np.int32),
                max_new=gen,
            ))

    submit(2)  # warm the prefill/decode traces
    engine.run()
    engine.reset_metrics()
    submit(requests)
    engine.run()
    m = engine.metrics()
    fp32_bytes = cfg.vocab_size * cfg.d_model * 4
    _assert_int8_resident(engine, fp32_bytes)
    assert m["kernel_fallbacks"] == 0, engine.fallback_report()
    emit(
        f"serve/lm/{arch}", m["us_per_token"],
        f"tok_s={m['tokens_generated'] / m['wall_s']:.1f} "
        f"resident_B={m['resident_embedding_bytes']} fp32_B={fp32_bytes}",
    )
    return {**m, "arch": arch, "fp32_bytes": fp32_bytes}


def bench_ctr(method: str, *, requests: int, bits: int = 8) -> dict:
    engine, data = build_ctr_demo_engine(
        method, bits=bits, batch=32, train_steps=3, train_batch=128,
    )
    warm, _ = data.batch("valid", 0, 32)
    for row in warm:
        engine.submit(CTRRequest(ids=row))
    engine.run()
    engine.reset_metrics()
    ids, _ = data.batch("test", 0, requests)
    for row in ids:
        engine.submit(CTRRequest(ids=row))
    engine.run()
    m = engine.metrics()
    fp32_bytes = CTR_DEMO_DATA.n_features * CTR_DEMO_DIM * 4
    if methods.get(method).is_integer_table:
        _assert_int8_resident(engine, fp32_bytes)
        assert m["kernel_fallbacks"] == 0, engine.fallback_report()
    if codestore.is_packable(bits):
        # Sub-byte cells serve straight off the PACKED container: every code
        # leaf is a packed CodeStore and the reported code bytes are the
        # container's actual (sub-byte) footprint, not one-byte-per-code.
        stores = [
            leaf for leaf in jax.tree.leaves(
                engine.table,
                is_leaf=lambda x: isinstance(x, codestore.CodeStore),
            )
            if isinstance(leaf, codestore.CodeStore)
        ]
        assert stores and all(s.packed for s in stores), "codes not packed"
        assert m["embedding_code_bytes"] == sum(
            s.resident_bytes for s in stores
        )
    emit(
        f"serve/ctr/{method}" + (f"/bits{bits}" if bits != 8 else ""),
        m["us_per_request"],
        f"resident_B={m['resident_embedding_bytes']} fp32_B={fp32_bytes} "
        f"int8={m['int8_resident']}",
    )
    return {**m, "bits": bits, "fp32_bytes": fp32_bytes}


def bench_tiered(method: str, *, requests: int, cache_rows: int,
                 cold_tier: bool = False,
                 device_budget_bytes: int | None = None) -> dict:
    """One Zipf(1.1) cell of the tiered-storage grid (PR 7 artifact).

    Returns the metrics dict plus the scored probabilities, so the caller
    can assert every cell is bitwise-equal to the cache-off baseline."""
    engine, data = build_ctr_demo_engine(
        method, batch=32, train_steps=3, train_batch=128,
        data_cfg=CTR_ZIPF_DATA, cache_rows=cache_rows, cold_tier=cold_tier,
        device_budget_bytes=device_budget_bytes,
    )
    # Warm the jit traces AND let the frequency-admission policy converge on
    # the Zipf head before measuring (8 waves of held-out traffic).
    for i in range(8):
        warm, _ = data.batch("valid", i, 64)
        for row in warm:
            engine.submit(CTRRequest(ids=row))
        engine.run()
    engine.reset_metrics()
    probs = {}
    for i in range(requests // 32):
        ids, _ = data.batch("test", i, 32)
        rids = [engine.submit(CTRRequest(ids=row)) for row in ids]
        done = engine.run()
        probs.update({32 * i + j: done[r]["prob"] for j, r in enumerate(rids)})
    m = engine.metrics()
    frac = cache_rows / CTR_ZIPF_DATA.n_features
    tier = "cold" if cold_tier else ("hot" if cache_rows else "off")
    hit = m.get("cache_hit_rate")
    emit(
        f"serve/tiered/{method}/{tier}-{frac:.2f}",
        m["us_per_request"],
        f"hit={hit if hit is None else round(hit, 3)} "
        f"resident_B={m['resident_embedding_bytes']}",
    )
    return {**m, "cache_rows": cache_rows, "cold_tier": cold_tier,
            "cache_fraction": frac, "probs": probs}


def run_tiered(smoke: bool = False, out: str | None = None) -> dict:
    """The Zipf(1.1) tiered-storage grid: cache {0, 1%, 10%} of the vocab,
    plus a cold-tier cell served under a device budget the full table
    exceeds.  Asserts the PR-7 acceptance bars:

    * every cached cell scores bitwise-equal to the cache-off baseline;
    * the 10% hot tier catches >= 0.9 of Zipf(1.1) lookups;
    * hot-tier device bytes stay inside the declared budget;
    * the cold tier stays under a budget smaller than the full code bytes.
    """
    requests = 64 if smoke else 256
    vocab = CTR_ZIPF_DATA.n_features
    method = "alpt"

    base = bench_tiered(method, requests=requests, cache_rows=0)
    full_code_bytes = base["embedding_code_bytes"]
    cells = [base]
    for frac in (0.01, 0.10):
        rows = max(1, int(vocab * frac))
        # Budget: the declared hot rows + scales + id maps, with headroom
        # for the per-slot bookkeeping — NOT enough for the whole table.
        budget = int(full_code_bytes * frac * 4) + 64 * 1024
        cell = bench_tiered(
            method, requests=requests, cache_rows=rows,
            device_budget_bytes=budget,
        )
        assert cell["probs"] == base["probs"], (
            f"cache_rows={rows} broke bitwise serving parity"
        )
        hot = cell["caches"][0]
        assert hot["hot_bytes"] + hot["metadata_bytes"] <= budget, (
            hot, budget,
        )
        cells.append(cell)
    ten = cells[-1]
    assert ten["cache_hit_rate"] >= 0.9, (
        f"Zipf(1.1) hit rate {ten['cache_hit_rate']:.3f} < 0.9 with a "
        f"10%-of-vocab hot tier"
    )

    cold_budget = full_code_bytes - 1  # the full table must NOT fit
    cold = bench_tiered(
        method, requests=requests, cache_rows=max(1, vocab // 10),
        cold_tier=True, device_budget_bytes=cold_budget,
    )
    assert cold["probs"] == base["probs"], "cold tier broke serving parity"
    assert cold["resident_embedding_bytes"] <= cold_budget
    cells.append(cold)

    results = {
        "data": {"name": CTR_ZIPF_DATA.name, "vocab": vocab,
                 "zipf_a": CTR_ZIPF_DATA.zipf_a},
        "cells": [{k: v for k, v in c.items() if k != "probs"}
                  for c in cells],
    }
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"[serve_bench] wrote {out}")
    return results


def run(smoke: bool = False, out: str | None = None) -> dict:
    requests = 8 if smoke else 32
    gen = 8 if smoke else 16
    archs = LM_ARCHS[:2] if smoke else LM_ARCHS
    ctr_methods = CTR_METHODS[:4] if smoke else CTR_METHODS
    results = {
        "lm": [bench_lm(a, requests=requests, gen=gen) for a in archs],
        "ctr": [bench_ctr(m, requests=requests * 8) for m in ctr_methods],
    }
    # Packed sub-byte cell: same engine, 4-bit codes resident at 2/byte.
    packed4 = bench_ctr("lpt", requests=requests * 8, bits=4)
    results["ctr"].append(packed4)
    lpt8 = next(
        c for c in results["ctr"]
        if c["embedding_method"] == "lpt" and c["bits"] == 8
    )
    assert (packed4["resident_embedding_bytes"]
            <= 0.55 * lpt8["resident_embedding_bytes"]), (
        "bits=4 serving table not packed: "
        f"{packed4['resident_embedding_bytes']} vs "
        f"{lpt8['resident_embedding_bytes']} (bits=8)"
    )
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"[serve_bench] wrote {out}")
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--tiered", action="store_true",
                    help="run the Zipf(1.1) tiered-storage grid instead "
                         "(cache {0, 1%%, 10%%} of vocab + cold tier); "
                         "--out typically BENCH_PR7.json")
    args = ap.parse_args(argv)
    if args.tiered:
        run_tiered(args.smoke, args.out)
    else:
        run(args.smoke, args.out)
    return 0


if __name__ == "__main__":
    main()
