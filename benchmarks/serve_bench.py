"""Serving benchmark for the int8-resident Engine (PR 5 artifact).

Per cell it reports what the redesign promises:

* **us/token** (LM continuous-batch decode) and **us/request** (CTR batched
  scoring) through the same `repro.serving` Engine API — absolute numbers
  are CPU-bound; the trajectory and the derived bytes transfer to TPU;
* **resident embedding bytes** — asserted to equal the int8 code bytes plus
  the scale vectors for every integer-table method, i.e. the Engine never
  re-inflated the table to fp32 (the acceptance criterion);
* the per-engine kernel fallback tally (zero on the aligned geometries).

Usage:
    PYTHONPATH=src python -m benchmarks.serve_bench --smoke --out BENCH_PR5.json
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from benchmarks.common import emit
from repro import configs, methods
from repro.core import codestore
from repro.launch.serve import CTR_DEMO_DATA, CTR_DEMO_DIM, build_ctr_demo_engine
from repro.serving import table as serving_tbl
from repro.serving.ctr import CTRRequest
from repro.serving.lm import LMEngine, LMRequest
from repro.training import lm_trainer

LM_ARCHS = ["smollm-135m", "mamba2-370m", "mixtral-8x7b"]
CTR_METHODS = ["lpt", "alpt", "qr_lpt", "qr_alpt", "fp"]


def _assert_int8_resident(engine, fp32_bytes: int) -> None:
    """The acceptance criterion: resident bytes == codes + scales, not fp32."""
    m = engine.metrics()
    resident = m["resident_embedding_bytes"]
    expect = m["embedding_code_bytes"] + m["embedding_scale_bytes"]
    assert engine.int8_resident, "integer-table method not int8-resident"
    assert resident == expect, (resident, expect)
    assert resident < fp32_bytes, (resident, fp32_bytes)
    codes = serving_tbl.code_bytes(engine.table)
    assert codes * 4 <= fp32_bytes, (codes, fp32_bytes)  # int8 vs f32 elems


def bench_lm(arch: str, *, requests: int, gen: int) -> dict:
    cfg = configs.smoke_config(arch)
    tcfg = lm_trainer.LMTrainerConfig()
    state = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    engine = LMEngine.from_state(state, cfg, tcfg, batch=4, max_len=32 + gen)
    rng = np.random.RandomState(0)

    def submit(n):
        for _ in range(n):
            engine.submit(LMRequest(
                prompt=rng.randint(0, cfg.vocab_size, 32).astype(np.int32),
                max_new=gen,
            ))

    submit(2)  # warm the prefill/decode traces
    engine.run()
    engine.reset_metrics()
    submit(requests)
    engine.run()
    m = engine.metrics()
    fp32_bytes = cfg.vocab_size * cfg.d_model * 4
    _assert_int8_resident(engine, fp32_bytes)
    assert m["kernel_fallbacks"] == 0, engine.fallback_report()
    emit(
        f"serve/lm/{arch}", m["us_per_token"],
        f"tok_s={m['tokens_generated'] / m['wall_s']:.1f} "
        f"resident_B={m['resident_embedding_bytes']} fp32_B={fp32_bytes}",
    )
    return {**m, "arch": arch, "fp32_bytes": fp32_bytes}


def bench_ctr(method: str, *, requests: int, bits: int = 8) -> dict:
    engine, data = build_ctr_demo_engine(
        method, bits=bits, batch=32, train_steps=3, train_batch=128,
    )
    warm, _ = data.batch("valid", 0, 32)
    for row in warm:
        engine.submit(CTRRequest(ids=row))
    engine.run()
    engine.reset_metrics()
    ids, _ = data.batch("test", 0, requests)
    for row in ids:
        engine.submit(CTRRequest(ids=row))
    engine.run()
    m = engine.metrics()
    fp32_bytes = CTR_DEMO_DATA.n_features * CTR_DEMO_DIM * 4
    if methods.get(method).is_integer_table:
        _assert_int8_resident(engine, fp32_bytes)
        assert m["kernel_fallbacks"] == 0, engine.fallback_report()
    if codestore.is_packable(bits):
        # Sub-byte cells serve straight off the PACKED container: every code
        # leaf is a packed CodeStore and the reported code bytes are the
        # container's actual (sub-byte) footprint, not one-byte-per-code.
        stores = [
            leaf for leaf in jax.tree.leaves(
                engine.table,
                is_leaf=lambda x: isinstance(x, codestore.CodeStore),
            )
            if isinstance(leaf, codestore.CodeStore)
        ]
        assert stores and all(s.packed for s in stores), "codes not packed"
        assert m["embedding_code_bytes"] == sum(
            s.resident_bytes for s in stores
        )
    emit(
        f"serve/ctr/{method}" + (f"/bits{bits}" if bits != 8 else ""),
        m["us_per_request"],
        f"resident_B={m['resident_embedding_bytes']} fp32_B={fp32_bytes} "
        f"int8={m['int8_resident']}",
    )
    return {**m, "bits": bits, "fp32_bytes": fp32_bytes}


def run(smoke: bool = False, out: str | None = None) -> dict:
    requests = 8 if smoke else 32
    gen = 8 if smoke else 16
    archs = LM_ARCHS[:2] if smoke else LM_ARCHS
    ctr_methods = CTR_METHODS[:4] if smoke else CTR_METHODS
    results = {
        "lm": [bench_lm(a, requests=requests, gen=gen) for a in archs],
        "ctr": [bench_ctr(m, requests=requests * 8) for m in ctr_methods],
    }
    # Packed sub-byte cell: same engine, 4-bit codes resident at 2/byte.
    packed4 = bench_ctr("lpt", requests=requests * 8, bits=4)
    results["ctr"].append(packed4)
    lpt8 = next(
        c for c in results["ctr"]
        if c["embedding_method"] == "lpt" and c["bits"] == 8
    )
    assert (packed4["resident_embedding_bytes"]
            <= 0.55 * lpt8["resident_embedding_bytes"]), (
        "bits=4 serving table not packed: "
        f"{packed4['resident_embedding_bytes']} vs "
        f"{lpt8['resident_embedding_bytes']} (bits=8)"
    )
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"[serve_bench] wrote {out}")
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    run(args.smoke, args.out)
    return 0


if __name__ == "__main__":
    main()
