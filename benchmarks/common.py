"""Shared harness for the paper-table benchmarks.

The paper's datasets are synthesized at reduced scale (DESIGN.md §7), so the
benchmarks validate the paper's *orderings and gaps*, not absolute AUC.
Every benchmark emits ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import time

import jax

from repro.core.alpt import ALPTConfig
from repro.core.pruning import PruneConfig
from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic
from repro.models import embedding as emb_mod
from repro.models.ctr import DCNConfig
from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

# Scaled-down stand-ins for Avazu / Criteo (field counts match; cardinality
# total reduced so a benchmark run finishes in CPU-minutes).
AVAZU_MINI = CTRDatasetConfig(
    name="avazu-mini", n_fields=24,
    cardinalities=tuple([97, 41, 13, 211, 89, 53, 17, 149, 61, 29, 103, 43,
                         19, 157, 71, 31, 11, 223, 83, 37, 23, 131, 59, 47]),
    teacher_rank=6, seed=1,
)
CRITEO_MINI = CTRDatasetConfig(
    name="criteo-mini", n_fields=39,
    cardinalities=tuple([67, 31, 11, 127, 53, 23, 89, 41, 17, 101, 47, 19,
                         73, 37, 13, 113, 59, 29, 83, 43, 151, 61, 97, 71,
                         107, 79, 131, 103, 139, 109, 149, 121, 157, 127,
                         163, 137, 167, 141, 173]),
    teacher_rank=6, seed=2,
)

STEPS = 300
BATCH = 256
EVAL_BATCHES = 12


def dcn_for(data_cfg: CTRDatasetConfig, d: int = 16) -> DCNConfig:
    return DCNConfig(n_fields=data_cfg.n_fields, emb_dim=d, cross_depth=2,
                     mlp_widths=(128, 64))


def run_method(
    data_cfg: CTRDatasetConfig,
    method: str,
    *,
    bits: int = 8,
    d: int = 16,
    steps: int = STEPS,
    rounding: str = "sr",
    clip_value: float | None = 0.1,
    step_lr: float = 2e-4,
    grad_scale: str = "bdq",
    seed: int = 0,
) -> dict:
    """Train one method, return metrics + timing + memory accounting."""
    data = CTRSynthetic(data_cfg)
    alpt_cfg = ALPTConfig(bits=bits, rounding=rounding, step_lr=step_lr,
                          grad_scale=grad_scale)
    spec = emb_mod.EmbeddingSpec(
        method=method, n=data_cfg.n_features, d=d, bits=bits,
        init_scale=0.05,
        clip_value=clip_value if method == "lpt" else None,
        alpt=alpt_cfg,
        # DeepLight schedule rescaled to the benchmark's step budget (the
        # paper's D=0.99/U=3000 is tuned for epochs-long runs).
        prune=PruneConfig(target_sparsity=0.5, warmup_steps=50, damping=0.9,
                          damping_steps=20, update_every=10),
    )
    tr = CTRTrainer(TrainerConfig(spec=spec, model="dcn",
                                  dcn=dcn_for(data_cfg, d), lr=3e-3,
                                  seed=seed))
    state = tr.init_state()
    # Warm-up/compile outside the timed loop.
    ids, labels = data.batch("train", 0, BATCH)
    state, _ = tr.train_step(state, ids, labels)
    t0 = time.time()
    for i in range(1, steps):
        ids, labels = data.batch("train", i, BATCH)
        state, m = tr.train_step(state, ids, labels)
    jax.block_until_ready(m["loss"])
    dt = time.time() - t0
    ev = tr.evaluate(state, data.batches("test", BATCH, EVAL_BATCHES))
    mem_train = emb_mod.memory_bytes(state.emb_state, spec, training=True)
    mem_inf = emb_mod.memory_bytes(state.emb_state, spec, training=False)
    fp_bytes = data_cfg.n_features * d * 4
    return {
        "auc": ev["auc"],
        "logloss": ev["logloss"],
        "us_per_step": dt / max(steps - 1, 1) * 1e6,
        "train_compression": fp_bytes / mem_train,
        "inference_compression": fp_bytes / mem_inf,
    }


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
