"""Data-parallel gradient-sync benchmark: step time, wire bytes, final AUC.

Trains the paper's CTR setup (DCN on the Avazu-shaped synthetic set, LPT int8
embeddings) data-parallel on an 8-fake-device CPU mesh, sweeping the gradient
sync bit width ``sync_bits in {32, 8, 4}``:

  * 32 — exact fp32 mean (the baseline the compressed paths must track);
  * 8/4 — SR-compressed int codes (repro.dist.collectives), the paper's
    stochastic quantizer applied to communication.

Emits the usual ``name,us_per_call,derived`` CSV rows *and* writes a JSON
report (``--out``) so CI can upload the wire-byte / step-time / AUC
trajectory as an artifact.  ``--smoke`` shrinks steps for the per-PR CI run.

Run directly (sets the fake-device flag before jax initializes):

    PYTHONPATH=src python -m benchmarks.dp_sync_bench --smoke --out dp.json
"""
import argparse
import json
import os
import sys

if "jax" not in sys.modules:
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
    )

import jax

from benchmarks.common import AVAZU_MINI, BATCH, EVAL_BATCHES, dcn_for, emit
from repro.core.alpt import ALPTConfig
from repro.data.ctr_synth import CTRSynthetic
from repro.models import embedding as emb_mod
from repro.training import data_parallel as dp_mod
from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

SYNC_BITS = (32, 8, 4)


def _make_trainer(data_cfg, sync_bits: int) -> CTRTrainer:
    spec = emb_mod.EmbeddingSpec(
        method="lpt", n=data_cfg.n_features, d=16, bits=8, init_scale=0.05,
        clip_value=0.1, alpt=ALPTConfig(bits=8),
    )
    return CTRTrainer(TrainerConfig(
        spec=spec, model="dcn", dcn=dcn_for(data_cfg), lr=3e-3,
        dp_sync_bits=sync_bits,
    ))


def run(steps: int | None = None, out: str | None = None, batch: int = BATCH):
    import time

    steps = 200 if steps is None else steps
    n_dev = len(jax.devices())
    if n_dev < 2:
        emit("dp_sync/skip", 0.0, f"needs >=2 devices, have {n_dev}")
        return None
    mesh = jax.make_mesh((n_dev,), ("data",))
    data_cfg = AVAZU_MINI
    data = CTRSynthetic(data_cfg)
    rows = []
    fp32_bytes = None
    for bits in SYNC_BITS:
        tr = _make_trainer(data_cfg, bits)
        step_fn = dp_mod.make_ctr_dp_step(tr, mesh)
        state = tr.init_state()
        shapes = dp_mod.ctr_grad_shapes(tr, state, batch // n_dev,
                                        data_cfg.n_fields)
        report = dp_mod.wire_report(shapes, bits)
        fp32_bytes = report["fp32_wire_bytes_per_step"]
        ids, labels = data.batch("train", 0, batch)
        state, m = step_fn(state, ids, labels)  # compile + warm-up
        jax.block_until_ready(m["loss"])
        t0 = time.time()
        for i in range(1, steps):
            ids, labels = data.batch("train", i, batch)
            state, m = step_fn(state, ids, labels)
        jax.block_until_ready(m["loss"])
        us_per_step = (time.time() - t0) / max(steps - 1, 1) * 1e6
        # Evaluate on the host copy (the mesh state is replicated).
        ev = tr.evaluate(jax.device_get(state),
                         data.batches("test", batch, EVAL_BATCHES))
        row = {
            "sync_bits": bits,
            "us_per_step": us_per_step,
            "wire_bytes_per_step": report["wire_bytes_per_step"],
            "compression_ratio": report["compression_ratio"],
            "auc": ev["auc"],
            "logloss": ev["logloss"],
            "final_loss": float(m["loss"]),
        }
        rows.append(row)
        emit(
            f"dp_sync/bits{bits}",
            us_per_step,
            f"auc={ev['auc']:.4f} logloss={ev['logloss']:.4f} "
            f"wire_B={report['wire_bytes_per_step']} "
            f"ratio={report['compression_ratio']:.2f}x",
        )
    result = {
        "bench": "dp_sync",
        "mesh_devices": n_dev,
        "method": "lpt",
        "steps": steps,
        "batch": batch,
        "fp32_wire_bytes_per_step": fp32_bytes,
        "rows": rows,
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"# wrote {out}")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: fewer steps, smaller batch")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--out", default=None, help="write JSON report here")
    args = ap.parse_args(argv)
    steps = args.steps
    batch = BATCH
    if args.smoke and steps is None:
        steps, batch = 40, 128
    print("name,us_per_call,derived")
    run(steps=steps, out=args.out, batch=batch)


if __name__ == "__main__":
    main()
