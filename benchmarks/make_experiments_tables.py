"""Emit the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from the
dry-run JSON cells.  Run after `python -m repro.launch.dryrun --all`."""
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parent / "dryrun_results"


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.2f}GB"


def load():
    cells = {}
    for p in sorted(RESULTS.glob("*.json")):
        cells[p.stem] = json.loads(p.read_text())
    return cells


def baseline_table(cells, mesh="pod256"):
    print(f"\n### Roofline baselines — {mesh} (16x16), default policy\n")
    print("| arch | shape | policy | status | compute_s | memory_s | "
          "collective_s | bottleneck | useful FLOPs ratio | roofline frac | "
          "fits 16GB |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for name, d in cells.items():
        parts = name.split("__")
        if len(parts) != 3 or parts[2] != mesh:
            continue
        arch, shape = parts[0], parts[1]
        if d["status"] == "skipped":
            print(f"| {arch} | {shape} | - | SKIP ({d['reason'][:48]}...) "
                  f"| | | | | | | |")
            continue
        if d["status"] != "ok":
            print(f"| {arch} | {shape} | - | ERROR | | | | | | | |")
            continue
        r = d["roofline"]
        print(
            f"| {arch} | {shape} | {d['policy']} | ok "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.3f} | {r['roofline_fraction']:.3f} "
            f"| {'yes' if d.get('fits_16gb_hbm') else 'NO'} |"
        )


def multipod_table(cells):
    print("\n### Multi-pod (2x16x16 = 512 chips) — lower+compile status\n")
    print("| arch | shape | status | compile_s | collective total |")
    print("|---|---|---|---|---|")
    for name, d in cells.items():
        parts = name.split("__")
        if len(parts) != 3 or parts[2] != "pod512":
            continue
        arch, shape = parts[0], parts[1]
        if d["status"] == "skipped":
            print(f"| {arch} | {shape} | SKIP | | |")
        elif d["status"] == "ok":
            print(f"| {arch} | {shape} | ok | {d['compile_s']} | "
                  f"{fmt_bytes(d['collectives']['total'])} |")
        else:
            print(f"| {arch} | {shape} | ERROR | | |")


def variants_table(cells):
    print("\n### §Perf variant cells (hillclimb)\n")
    print("| cell | policy | embedding | compute_s | memory_s | "
          "collective_s | bottleneck | frac |")
    print("|---|---|---|---|---|---|---|---|")
    for name, d in cells.items():
        parts = name.split("__")
        if len(parts) <= 3 or d["status"] != "ok":
            continue
        r = d["roofline"]
        # The filename token keeps the _sp/_ep suffix; d['policy'] is the base.
        pol_label = next((p for p in parts[3:] if not p.startswith("emb-")),
                         d["policy"])
        print(
            f"| {parts[0]}/{parts[1]} | {pol_label} "
            f"| {d.get('embedding') or 'alpt'} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {r['bottleneck']} "
            f"| {r['roofline_fraction']:.3f} |"
        )


if __name__ == "__main__":
    cells = load()
    baseline_table(cells)
    multipod_table(cells)
    variants_table(cells)
