"""Kernel microbenchmarks: Pallas (interpret on CPU) vs jnp reference.

On CPU the pallas interpreter is NOT representative of TPU speed — the
derived column therefore reports bytes moved and the arithmetic intensity the
BlockSpec tiling claims, which is what transfers to TPU.  The jnp reference
is additionally timed for a same-machine sanity number.
"""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    # One fresh subkey per array: no two benchmark inputs share a stream.
    keys = iter(jax.random.split(jax.random.PRNGKey(0), 20))
    n, d, b = 100_000, 128, 4096
    codes = jax.random.randint(next(keys), (n, d), -128, 128, jnp.int8)
    step = jax.random.uniform(next(keys), (n,), minval=1e-3, maxval=0.1)
    ids = jax.random.randint(next(keys), (b,), 0, n, jnp.int32)
    us = _time(lambda *a: ops.dequant_gather(*a), codes, step, ids)
    us_ref = _time(lambda *a: ref.dequant_gather_ref(*a), codes, step, ids)
    moved = b * d * (1 + 4) + b * 4  # int8 in, f32 out
    emit("kernel/dequant_gather", us,
         f"ref_us={us_ref:.1f} bytes={moved} int8_vs_f32_read=4.0x")

    w = jax.random.normal(next(keys), (4096, 512)) * 0.05
    st = jax.random.uniform(next(keys), (4096,), minval=1e-3, maxval=0.05)
    noise = jax.random.uniform(next(keys), (4096, 512))
    us = _time(lambda *a: ops.sr_round(*a, 8), w, st, noise)
    us_ref = _time(lambda *a: ref.sr_round_ref(*a, 8), w, st, noise)
    emit("kernel/sr_round", us,
         f"ref_us={us_ref:.1f} bytes={4096*512*(4+4+1)} writeback_int8=4x_smaller")

    # Fused dense write-back (Eq. 8): codes in/out are the only table bytes.
    codes_sq = jax.random.randint(next(keys), (4096, 512), -128, 128, jnp.int8)
    grad = jax.random.normal(next(keys), (4096, 512)) * 0.1
    us = _time(
        lambda *a: ops.lpt_update(*a, 8), codes_sq, st, grad, noise,
        jnp.float32(0.01),
    )
    us_ref = _time(
        lambda *a: ref.lpt_fused_update_ref(*a, 0.01, 8), codes_sq, st, grad,
        noise,
    )
    fused_b = 4096 * 512 * (1 + 4 + 4 + 1)  # codes in, grad+noise in, codes out
    unfused_b = 4096 * 512 * (1 + 4 + 4 + 4 + 4 + 4 + 1)  # + 3 fp32 round-trips
    emit("kernel/lpt_update", us,
         f"ref_us={us_ref:.1f} bytes={fused_b} "
         f"unfused_bytes={unfused_b} traffic_saved={unfused_b/fused_b:.1f}x")

    # Fused CTR sparse step over unique rows (gather+Adam+SR+scatter).
    # Table scaled down vs the gather bench: the interpreter walks the grid
    # row by row, and the derived bytes column is size-linear anyway.
    ns, kk, dd = 20_000, 512, 128
    mu = jax.random.normal(next(keys), (ns, dd)) * 0.01
    nu = jax.random.uniform(next(keys), (ns, dd)) * 1e-3
    codes_k = jax.random.randint(next(keys), (ns, dd), -128, 128, jnp.int8)
    step_k = jax.random.uniform(next(keys), (ns,), minval=1e-3, maxval=0.1)
    uniq = jax.random.permutation(next(keys), ns)[:kk].astype(jnp.int32)
    g_rows = jax.random.normal(next(keys), (kk, dd)) * 0.1
    nz = jax.random.uniform(next(keys), (kk, dd))
    args = (codes_k, step_k, mu, nu, uniq, g_rows, nz,
            jnp.float32(0.01), jnp.float32(0.1), jnp.float32(1e-3), 8)
    us = _time(lambda *a: ops.sparse_row_update(*a), *args)
    us_ref = _time(
        lambda *a: ops.sparse_row_update(*a, use_kernel=False), *args
    )
    row_b = kk * dd * (1 + 4 + 4 + 4 + 4 + 1 + 4 + 4 + 4)
    emit("kernel/sparse_row_update", us,
         f"ref_us={us_ref:.1f} touched_row_bytes={row_b} "
         f"rows={kk} fp32_table_never_in_hbm=1")

    x = jax.random.normal(next(keys), (256, 2048), jnp.bfloat16)
    wc = jax.random.randint(next(keys), (2048, 2048), -128, 128, jnp.int8)
    ws = jax.random.uniform(next(keys), (2048,), minval=1e-3, maxval=0.02)
    us = _time(lambda *a: ops.dequant_matmul(*a), x, wc, ws)
    us_ref = _time(lambda *a: ref.dequant_matmul_ref(*a), x, wc, ws)
    flops = 2 * 256 * 2048 * 2048
    wbytes = 2048 * 2048
    emit("kernel/dequant_matmul", us,
         f"ref_us={us_ref:.1f} flops={flops} weight_bytes={wbytes} "
         f"intensity={flops/wbytes:.0f}flop_per_weight_byte")


if __name__ == "__main__":
    run()
