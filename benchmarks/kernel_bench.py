"""Kernel microbenchmarks: Pallas (interpret on CPU) vs jnp reference.

On CPU the pallas interpreter is NOT representative of TPU speed — the
derived column therefore reports bytes moved and the arithmetic intensity the
BlockSpec tiling claims, which is what transfers to TPU.  The jnp reference
is additionally timed for a same-machine sanity number.
"""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run():
    key = jax.random.PRNGKey(0)
    n, d, b = 100_000, 128, 4096
    codes = jax.random.randint(key, (n, d), -128, 128, jnp.int8)
    step = jax.random.uniform(key, (n,), minval=1e-3, maxval=0.1)
    ids = jax.random.randint(key, (b,), 0, n, jnp.int32)
    us = _time(lambda *a: ops.dequant_gather(*a), codes, step, ids)
    us_ref = _time(lambda *a: ref.dequant_gather_ref(*a), codes, step, ids)
    moved = b * d * (1 + 4) + b * 4  # int8 in, f32 out
    emit("kernel/dequant_gather", us,
         f"ref_us={us_ref:.1f} bytes={moved} int8_vs_f32_read=4.0x")

    w = jax.random.normal(key, (4096, 512)) * 0.05
    st = jax.random.uniform(key, (4096,), minval=1e-3, maxval=0.05)
    noise = jax.random.uniform(key, (4096, 512))
    us = _time(lambda *a: ops.sr_round(*a, 8), w, st, noise)
    us_ref = _time(lambda *a: ref.sr_round_ref(*a, 8), w, st, noise)
    emit("kernel/sr_round", us,
         f"ref_us={us_ref:.1f} bytes={4096*512*(4+4+1)} writeback_int8=4x_smaller")

    x = jax.random.normal(key, (256, 2048), jnp.bfloat16)
    wc = jax.random.randint(key, (2048, 2048), -128, 128, jnp.int8)
    ws = jax.random.uniform(key, (2048,), minval=1e-3, maxval=0.02)
    us = _time(lambda *a: ops.dequant_matmul(*a), x, wc, ws)
    us_ref = _time(lambda *a: ref.dequant_matmul_ref(*a), x, wc, ws)
    flops = 2 * 256 * 2048 * 2048
    wbytes = 2048 * 2048
    emit("kernel/dequant_matmul", us,
         f"ref_us={us_ref:.1f} flops={flops} weight_bytes={wbytes} "
         f"intensity={flops/wbytes:.0f}flop_per_weight_byte")


if __name__ == "__main__":
    run()
