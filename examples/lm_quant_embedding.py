"""The paper's technique on an LM: ALPT-quantized vocab embeddings.

Trains the reduced qwen3-family config (qk-norm GQA transformer, tied int8
embedding table with learned per-row Delta) on a synthetic Markov token
stream for a few hundred steps and compares against fp embeddings.

    PYTHONPATH=src python examples/lm_quant_embedding.py [--steps 200]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.lm_synth import LMTokenStream
from repro.training import lm_trainer


def run(method: str, steps: int, batch: int, seq: int):
    cfg = configs.smoke_config("qwen3-1.7b")
    cfg = dataclasses.replace(cfg, embedding_method=method)
    tcfg = lm_trainer.LMTrainerConfig(lr=1e-3)
    state = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    step_fn = jax.jit(lm_trainer.make_train_step(cfg, tcfg))
    data = LMTokenStream(cfg.vocab_size, seq, seed=3)
    first = last = None
    for i, (inp, lab) in enumerate(data.batches(batch, steps)):
        state, m = step_fn(state, {"tokens": jnp.asarray(inp),
                                   "labels": jnp.asarray(lab)})
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    table_bits = 8 if method == "alpt" else 32
    print(f"{method:5s} loss {first:.3f} -> {last:.3f}   "
          f"embedding storage: {table_bits}-bit")
    return last


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()
    fp = run("fp", args.steps, args.batch, args.seq)
    alpt = run("alpt", args.steps, args.batch, args.seq)
    gap = alpt - fp
    print(f"-> int8 ALPT table vs fp: final-loss gap {gap:+.4f} "
          f"(4x smaller table + learned Delta)")


if __name__ == "__main__":
    main()
