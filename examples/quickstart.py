"""Quickstart: train 8-bit ALPT embeddings on a tiny CTR problem in ~30s.

    PYTHONPATH=src python examples/quickstart.py

Shows the paper's core loop: the embedding table lives as int8 codes + a
learned per-row step size; accuracy matches full precision at 4x less
training memory for the table.
"""
import jax

from repro.core.alpt import ALPTConfig
from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic
from repro.models import embedding as emb_mod
from repro.models.ctr import DCNConfig
from repro.training.ctr_trainer import CTRTrainer, TrainerConfig


def main():
    data_cfg = CTRDatasetConfig(
        name="quickstart", n_fields=8,
        cardinalities=(37, 83, 11, 199, 61, 23, 131, 17), teacher_rank=4,
    )
    data = CTRSynthetic(data_cfg)
    dcn = DCNConfig(n_fields=8, emb_dim=8, cross_depth=2, mlp_widths=(64, 32))

    for method in ("fp", "alpt"):
        spec = emb_mod.EmbeddingSpec(
            method=method, n=data_cfg.n_features, d=8, bits=8, init_scale=0.05,
            alpt=ALPTConfig(bits=8, step_lr=2e-4),
        )
        trainer = CTRTrainer(
            TrainerConfig(spec=spec, model="dcn", dcn=dcn, lr=3e-3)
        )
        state, _ = trainer.fit(data, steps=200, batch_size=256)
        ev = trainer.evaluate(state, data.batches("test", 256, 8))
        mem = emb_mod.memory_bytes(state.emb_state, spec, training=True)
        print(
            f"{method:5s}  AUC={ev['auc']:.4f}  logloss={ev['logloss']:.4f}  "
            f"table={mem/1024:.0f}KiB"
        )
    print("-> 8-bit ALPT matches FP accuracy with ~4x smaller training table")


if __name__ == "__main__":
    main()
