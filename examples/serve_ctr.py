"""Batched CTR scoring on the int8-resident serving Engine.

    PYTHONPATH=src python examples/serve_ctr.py

Trains a few ALPT steps on the synthetic CTR data, builds a
`repro.serving.CTREngine` from the trainer state (the embedding table goes
into residency as int8 codes + learned per-row scales — no fp32 export), and
scores a stream of requests through the fixed-geometry jitted scorer.
"""
from repro.launch import serve


def main():
    serve.main([
        "ctr", "--method", "alpt", "--batch", "16", "--requests", "48",
        "--train-steps", "3",
    ])


if __name__ == "__main__":
    main()
