"""End-to-end driver: the paper's experiment at reduced scale.

Trains DCN on synthetic Avazu with the full method roster (FP / LPT(SR) /
ALPT(SR)) for a few hundred steps, with the paper's hyper-parameters
(Adam 1e-3-ish, Delta lr 2e-5-scaled, weight decay, SR write-back), prints a
Table-1-shaped comparison, and writes a checkpoint of the quantized table.

    PYTHONPATH=src python examples/train_ctr_alpt.py [--steps 400]
"""
import argparse
import tempfile

from repro.checkpoint import CheckpointManager
from repro.configs.dcn_ctr import avazu_setup
from repro.data.ctr_synth import CTRSynthetic
from repro.models import embedding as emb_mod
from repro.training.ctr_trainer import CTRTrainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--scale", type=float, default=0.002,
                    help="fraction of Avazu's 4.4M features to synthesize")
    args = ap.parse_args()

    rows = []
    for method in ("fp", "lpt", "alpt"):
        data_cfg, spec, dcn = avazu_setup(method=method, scale=args.scale)
        if method == "lpt":
            spec = emb_mod.EmbeddingSpec(
                **{**spec.__dict__, "clip_value": 0.1}
            )
        # Reduced MLP so the example runs in CPU-minutes.
        dcn = type(dcn)(n_fields=dcn.n_fields, emb_dim=dcn.emb_dim,
                        cross_depth=3, mlp_widths=(256, 128, 64))
        data = CTRSynthetic(data_cfg)
        trainer = CTRTrainer(
            TrainerConfig(spec=spec, model="dcn", dcn=dcn, lr=1e-3,
                          emb_weight_decay=5e-8)
        )
        state, hist = trainer.fit(
            data, steps=args.steps, batch_size=args.batch,
            eval_every=max(args.steps // 4, 1),
            log=lambda h: print(f"  [{method}] {h}"),
        )
        ev = trainer.evaluate(state, data.batches("test", args.batch, 10))
        mem = emb_mod.memory_bytes(state.emb_state, spec, training=True)
        rows.append((method, ev["auc"], ev["logloss"], mem))
        if method == "alpt":
            ckpt_dir = tempfile.mkdtemp(prefix="alpt_ckpt_")
            CheckpointManager(ckpt_dir, save_every=1).maybe_save(
                state.emb_state, args.steps, force=True
            )
            print(f"  quantized table checkpoint -> {ckpt_dir}")

    print(f"\n{'method':6s} {'AUC':>8s} {'logloss':>9s} {'table-mem':>10s}")
    fp_mem = rows[0][3]
    for m, auc, ll, mem in rows:
        print(f"{m:6s} {auc:8.4f} {ll:9.4f} {fp_mem/mem:9.1f}x")


if __name__ == "__main__":
    main()
