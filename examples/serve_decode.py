"""Batched serving with int8 embedding tables (continuous batcher).

    PYTHONPATH=src python examples/serve_decode.py

Wraps repro.launch.serve: prefill + decode steps are jitted once; finished
requests are replaced without recompilation; the vocab table stays int8.
"""
from repro.launch import serve


def main():
    serve.main([
        "--arch", "mixtral-8x7b", "--smoke",
        "--batch", "4", "--prompt-len", "24", "--gen", "12",
        "--requests", "8",
    ])


if __name__ == "__main__":
    main()
