"""Continuous-batch LM decode on the int8-resident serving Engine.

    PYTHONPATH=src python examples/serve_decode.py

Wraps the `repro.launch.serve lm` CLI: per-request prefill + slot-refill
decode are jitted once; the vocab table stays int8 codes + scales end-to-end
(embeds via the fused dequant-gather, tied head via the fused dequant-matmul).
"""
from repro.launch import serve


def main():
    serve.main([
        "lm", "--arch", "mixtral-8x7b", "--smoke",
        "--batch", "4", "--prompt-len", "24", "--gen", "12",
        "--requests", "8",
    ])


if __name__ == "__main__":
    main()
