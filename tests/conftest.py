"""Suite-wide conftest.

Provides a minimal ``hypothesis`` stand-in when the real package is absent
(offline CI containers can't pip install); see repro._compat.hypothesis_stub.

Also hosts the shared subprocess harness for the mesh/driver tests: they
spawn fresh interpreters (each sets its own fake-device count before jax
initializes), rooted at the repo checkout so ``PYTHONPATH=src`` resolves on
any machine, not just the original dev box.
"""
import pathlib
import subprocess
import sys

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro._compat import hypothesis_stub

    hypothesis_stub.install()


REPO_ROOT = str(pathlib.Path(__file__).resolve().parent.parent)
SUBPROC_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}


def run_prog(prog: str, timeout: int = 560) -> str:
    """Run ``python -c prog`` from the repo root; assert success."""
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        env=dict(SUBPROC_ENV), cwd=REPO_ROOT, timeout=timeout,
    )
    assert out.returncode == 0, (out.stderr[-3000:], out.stdout[-500:])
    return out.stdout
