"""Suite-wide conftest.

Provides a minimal ``hypothesis`` stand-in when the real package is absent
(offline CI containers can't pip install); see repro._compat.hypothesis_stub.
"""
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro._compat import hypothesis_stub

    hypothesis_stub.install()
