"""CI guard: no raw int8 code casts outside the code-container layers.

The packed-storage refactor made :mod:`repro.core.codestore` the single
owner of the code-container layout — every consumer reads/writes codes
through ``CodeStore`` / the either-type helpers (``logical_codes``,
``take_rows``, ``set_rows``, ``where_rows``) or through the kernel wrappers,
which unpack sub-byte tiles in VMEM.  A direct ``.astype(jnp.int8)`` on a
code array anywhere else is how the old implicit one-byte-per-code layout
creeps back in: it silently materializes an unpacked copy (4x the resident
bytes at 2-bit) and skips the sign-extension rules the container owns.

Allowed layers: ``core/codestore.py`` (the container itself),
``core/quant.py`` (the quantizer mints fresh codes), and ``kernels/``
(in-VMEM unpack/repack inside the fused ops and their oracles).
"""
import pathlib
import re

SRC = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"

# The container layers that legitimately cast to the logical code dtype.
EXEMPT = re.compile(r"^(core/codestore\.py|core/quant\.py|kernels/)")

CAST = re.compile(r"\.astype\(\s*jnp\.int8\s*\)")


def test_no_raw_int8_code_casts_outside_codestore():
    offenders = []
    for path in sorted(SRC.rglob("*.py")):
        rel = path.relative_to(SRC).as_posix()
        if EXEMPT.match(rel):
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if CAST.search(line):
                offenders.append(
                    f"{path.relative_to(SRC.parent.parent)}:{lineno}: "
                    f"{line.strip()}"
                )
    assert not offenders, (
        "raw .astype(jnp.int8) code cast found — go through "
        "repro.core.codestore (CodeStore / pack_codes / unpack_codes / the "
        "either-type helpers) so sub-byte tables stay packed:\n"
        + "\n".join(offenders)
    )
