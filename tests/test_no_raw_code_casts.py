"""CI guard: no raw int8/uint8 code casts outside the code-container layers.

The packed-storage refactor made :mod:`repro.core.codestore` the single
owner of the code-container layout — every consumer reads/writes codes
through ``CodeStore`` / the either-type helpers or through the kernel
wrappers, which unpack sub-byte tiles in VMEM.  A direct
``.astype(jnp.int8)`` on a code array anywhere else is how the old implicit
one-byte-per-code layout creeps back in.

This test is a thin wrapper over the ``no-raw-code-casts`` AST rule in
:mod:`repro.analysis.lint.rules`, which also catches the variants the old
regex missed (aliased imports, ``jnp.asarray(..., dtype=...)``,
``lax.convert_element_type``, ``.view``, uint8) without the regex's
false positives on comments and strings.
"""
from repro.analysis.findings import load_suppressions
from repro.analysis.lint import REPO_ROOT, all_rules, run_lint


def test_no_raw_int8_code_casts_outside_codestore():
    rule = next(r for r in all_rules() if r.name == "no-raw-code-casts")
    supp = load_suppressions(REPO_ROOT / "analysis-suppressions.txt")
    findings = supp.apply(run_lint(rules=[rule]))
    assert not findings, (
        "raw code-dtype cast found — go through repro.core.codestore "
        "(CodeStore / pack_codes / unpack_codes / the either-type helpers) "
        "so sub-byte tables stay packed:\n"
        + "\n".join(f.format() for f in findings)
    )
