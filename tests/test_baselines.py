"""Tests for the paper's baseline methods: QAT (LSQ/PACT), QR hashing, pruning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.core import hashing, pruning, qat, quant

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------- QAT


def test_qat_lookup_is_fake_quantized():
    t = qat.init_qat(jax.random.PRNGKey(0), 32, 8, 8, method="lsq")
    rows = qat.qat_lookup(t, jnp.array([0, 5]), 8, method="lsq")
    # Every value must sit on its row's lattice.
    steps = np.asarray(t.scale)[[0, 5]]
    codes = np.asarray(rows) / steps[:, None]
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-3)


def test_qat_master_weights_get_gradients():
    t = qat.init_qat(jax.random.PRNGKey(0), 16, 4, 8, method="lsq")
    ids = jnp.array([1, 2])

    def loss(w):
        rows = qat.qat_lookup(qat.QATTable(w, t.scale), ids, 8, method="lsq")
        return jnp.sum(rows**2)

    g = jax.grad(loss)(t.weights)
    assert float(jnp.abs(g[jnp.array([1, 2])]).sum()) > 0.0
    assert float(jnp.abs(g[jnp.array([0, 3])]).sum()) == 0.0


@pytest.mark.parametrize("method", ["lsq", "pact"])
def test_qat_trains_toward_target(method):
    t = qat.init_qat(jax.random.PRNGKey(1), 8, 4, 8, method=method)
    ids = jnp.arange(8)
    target = 0.11

    @jax.jit
    def step(t):
        def loss(tbl):
            rows = qat.qat_lookup(tbl, ids, 8, method=method)
            return jnp.sum((rows - target) ** 2)

        g = jax.grad(lambda w, s: loss(qat.QATTable(w, s)), argnums=(0, 1))(
            t.weights, t.scale
        )
        return qat.QATTable(t.weights - 0.05 * g[0], t.scale - 1e-3 * g[1])

    for _ in range(200):
        t = step(t)
    rows = qat.qat_lookup(t, ids, 8, method=method)
    assert float(jnp.mean(jnp.abs(rows - target))) < 0.01


def test_qat_export_roundtrip():
    t = qat.init_qat(jax.random.PRNGKey(2), 16, 8, 8, method="lsq")
    codes, step = qat.export_int8(t, 8, method="lsq")
    assert codes.dtype == jnp.int8
    recon = quant.dequantize(codes, step)
    fq = qat.qat_lookup(t, jnp.arange(16), 8, method="lsq")
    np.testing.assert_allclose(np.asarray(recon), np.asarray(fq), atol=1e-6)


# ---------------------------------------------------------------- QR hashing


def test_qr_compression_ratio():
    t = hashing.init_qr(jax.random.PRNGKey(0), n=100000, d=16, compression=2.0)
    total_rows = t.remainder.shape[0] + t.quotient.shape[0]
    ratio = 100000 / total_rows
    assert 1.8 < ratio < 2.6  # ~2x as in paper Table 1


@settings(max_examples=20, deadline=None)
@given(n=st.integers(100, 5000), ids=st.lists(st.integers(0, 99), min_size=1, max_size=8))
def test_qr_index_decomposition_unique(n, ids):
    """(id % r, id // r) is injective over [0, n) — no two features collide."""
    t = hashing.init_qr(jax.random.PRNGKey(0), n=n, d=4)
    seen = set()
    for i in range(min(n, 500)):
        pair = (i % t.r, i // t.r)
        assert pair not in seen
        seen.add(pair)


def test_qr_lookup_is_product():
    t = hashing.init_qr(jax.random.PRNGKey(0), n=64, d=4)
    ids = jnp.array([0, 7, 63])
    out = hashing.qr_lookup(t, ids)
    expect = np.asarray(t.remainder)[np.asarray(ids) % t.r] * np.asarray(t.quotient)[
        np.asarray(ids) // t.r
    ]
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-6)


# ---------------------------------------------------------------- pruning


def test_prune_ratio_schedule():
    cfg = pruning.PruneConfig(target_sparsity=0.5, warmup_steps=10, damping=0.9,
                              damping_steps=100)
    assert float(pruning.prune_ratio(cfg, jnp.asarray(0))) == 0.0
    r_mid = float(pruning.prune_ratio(cfg, jnp.asarray(200)))
    r_late = float(pruning.prune_ratio(cfg, jnp.asarray(5000)))
    assert 0.0 < r_mid < r_late <= 0.5 + 1e-6


def test_prune_mask_and_regrowth():
    cfg = pruning.PruneConfig(target_sparsity=0.5, warmup_steps=0, damping=0.5,
                              damping_steps=1)
    s = pruning.init_prune(jax.random.PRNGKey(0), 64, 8)
    s = s._replace(step=jnp.asarray(1000, jnp.int32))
    s = pruning.update_mask(s, cfg)
    sp = float(pruning.sparsity(s))
    assert 0.4 < sp < 0.6
    # Regrowth: boost pruned weights' magnitude; a fresh mask must re-admit them.
    big = jnp.where(s.mask, s.weights, 10.0)
    s2 = pruning.update_mask(s._replace(weights=big), cfg)
    regrown = jnp.mean((~s.mask & s2.mask).astype(jnp.float32))
    assert float(regrown) > 0.2


def test_prune_lookup_applies_mask():
    s = pruning.init_prune(jax.random.PRNGKey(0), 16, 4)
    mask = s.mask.at[3].set(False)
    s = s._replace(mask=mask)
    rows = pruning.prune_lookup(s, jnp.array([3]))
    np.testing.assert_array_equal(np.asarray(rows), np.zeros((1, 4)))
