"""Packed code-container tests: the CodeStore pytree, the end-to-end
packed-vs-unpacked-vs-kernels-off bitwise parity bar for every integer-table
method, the sub-byte memory-ratio acceptance (bits=4 <= 0.55x bits=8 for the
training table, the Engine's resident metric, and the checkpoint artifact),
the packed serving-checkpoint roundtrip, and the per-field mixed-precision
method.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import methods
from repro.core import codestore
from repro.core import lpt as lpt_core
from repro.storage import base as rowstore

jax.config.update("jax_platform_name", "cpu")

INT_TABLE_METHODS = [
    m for m in methods.available() if methods.get(m).is_integer_table
]


# ------------------------------------------------------------- container


def test_codestore_packs_sub_byte_widths_only():
    codes = jnp.zeros((8, 16), jnp.int8)
    for bits, cpb in ((2, 4), (4, 2)):
        s = codestore.CodeStore.from_codes(codes, bits)
        assert s.packed and s.data.dtype == jnp.uint8
        assert s.data.shape == (8, 16 // cpb)
        assert s.resident_bytes == 8 * 16 // cpb
    for bits in (3, 5, 6, 7, 8):
        s = codestore.CodeStore.from_codes(codes, bits)
        assert not s.packed
        assert s.resident_bytes == 8 * 16


def test_codestore_facade_is_logical():
    codes = jax.random.randint(jax.random.PRNGKey(0), (8, 12), -8, 8, jnp.int8)
    s = codestore.CodeStore.from_codes(codes, 4)
    assert s.shape == (8, 12) and s.dtype == jnp.int8
    assert s.size == 96 and s.ndim == 2
    np.testing.assert_array_equal(np.asarray(s), np.asarray(codes))
    np.testing.assert_array_equal(np.asarray(s.unpack()), np.asarray(codes))


def test_codestore_row_ops_roundtrip():
    codes = jax.random.randint(jax.random.PRNGKey(1), (16, 8), -2, 2, jnp.int8)
    s = codestore.CodeStore.from_codes(codes, 2)
    ids = jnp.array([3, 3, 0, 15], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(s.take(ids)), np.asarray(jnp.take(codes, ids, axis=0))
    )
    rows = jnp.full((2, 8), -2, jnp.int8)
    idx = jnp.array([1, 9], jnp.int32)
    updated = rowstore.set_rows(s, idx, rows, mode="drop")
    expect = codes.at[idx].set(rows, mode="drop")
    np.testing.assert_array_equal(np.asarray(updated), np.asarray(expect))
    # Out-of-range scatter drops, bit-identically to the raw .at path.
    dropped = rowstore.set_rows(s, jnp.array([99]), rows[:1], mode="drop")
    np.testing.assert_array_equal(np.asarray(dropped), np.asarray(codes))


def test_codestore_is_a_pytree_with_one_leaf():
    s = codestore.CodeStore.from_codes(jnp.zeros((4, 8), jnp.int8), 4)
    leaves, treedef = jax.tree_util.tree_flatten(s)
    assert len(leaves) == 1 and leaves[0].dtype == jnp.uint8
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.bits == 4 and rebuilt.shape == (4, 8) and rebuilt.packed
    # flows through jit as state
    out = jax.jit(lambda t: t.with_data(t.data))(s)
    assert isinstance(out, codestore.CodeStore) and out.packed


def test_wire_bytes_sub_byte_and_odd_widths():
    from repro.dist import collectives

    grads = {"t": jax.ShapeDtypeStruct((100, 10), jnp.float32)}
    assert collectives.sync_wire_bytes(grads, 2) == 250 + 4
    assert collectives.sync_wire_bytes(grads, 4) == 500 + 4
    # Non-byte-divisor widths ship whole bytes, not an idealized bits/8.
    assert collectives.sync_wire_bytes(grads, 5) == 1000 + 4
    assert collectives.sync_wire_bytes(grads, 8) == 1000 + 4


# ------------------------------------------- end-to-end packed parity bar


def _ctr_fixture(name, *, bits=4, packed=True, use_kernels=True, d=8,
                 field_bits=None, field_cards=None, cards=(23, 37, 11, 53)):
    from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic
    from repro.models.ctr import DCNConfig
    from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

    data_cfg = CTRDatasetConfig(
        name="pack", n_fields=len(cards), cardinalities=cards,
        teacher_rank=3, seed=11,
    )
    data = CTRSynthetic(data_cfg)
    spec = methods.EmbeddingSpec(
        method=name, n=data_cfg.n_features, d=d, bits=bits, init_scale=0.05,
        use_kernels=use_kernels, pad_to_tiles=True, packed=packed,
        field_cards=field_cards, field_bits=field_bits,
    )
    dcn = DCNConfig(n_fields=len(cards), emb_dim=d, cross_depth=1,
                    mlp_widths=(16,))
    tr = CTRTrainer(TrainerConfig(spec=spec, model="dcn", dcn=dcn, lr=1e-3))
    return tr, data, spec


def _train(tr, data, steps=2):
    state = tr.init_state()
    losses = []
    for i in range(steps):
        ids, labels = data.batch("train", i, 16)
        state, m = tr.train_step(state, ids, labels)
        losses.append(float(m["loss"]))
    return state, losses


@pytest.mark.parametrize("name", INT_TABLE_METHODS)
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_packed_parity_end_to_end(name, bits):
    """The tentpole bar: packed-on == packed-off == kernels-off, bitwise, on
    everything the model observes (de-quantized live table + losses), for
    every integer-table method at every bit width, same seeds."""
    results = []
    for packed, kernels in ((True, True), (False, True), (True, False)):
        tr, data, spec = _ctr_fixture(
            name, bits=bits, packed=packed, use_kernels=kernels
        )
        state, losses = _train(tr, data)
        table = methods.get(name).eval_table(state.emb_state, spec)
        results.append((np.asarray(table), losses))
    base_table, base_losses = results[0]
    for table, losses in results[1:]:
        np.testing.assert_array_equal(base_table, table)
        assert losses == base_losses


@pytest.mark.parametrize("name", INT_TABLE_METHODS)
def test_packed_state_is_actually_packed(name):
    tr, data, spec = _ctr_fixture(name, bits=4)
    state, _ = _train(tr, data, steps=1)
    stores = [
        leaf for leaf in jax.tree.leaves(
            state.emb_state,
            is_leaf=lambda x: isinstance(x, codestore.CodeStore),
        )
        if isinstance(leaf, codestore.CodeStore)
    ]
    assert stores, f"{name}: no CodeStore leaves in trained state"
    for s in stores:
        assert s.packed and s.data.dtype == jnp.uint8
        assert s.resident_bytes * 2 == s.size


# --------------------------------------------------- memory-ratio acceptance


def _table_and_engine_bytes(bits, tmp_path):
    from repro.checkpoint import manager
    from repro.serving.ctr import CTREngine

    tr, data, spec = _ctr_fixture("lpt", bits=bits, d=64)
    state, _ = _train(tr, data, steps=1)
    m = methods.get("lpt")
    train_bytes = m.memory_bytes(state.emb_state, spec, training=True)
    eng = CTREngine.from_state(state, tr.cfg, batch=4)
    eng_bytes = eng.resident_embedding_bytes
    ckpt_dir = tmp_path / f"bits{bits}"
    manager.save_serving_checkpoint(
        ckpt_dir, step=1, params={}, table=state.emb_state, spec=spec
    )
    step_dir = ckpt_dir / "step_000000001"
    ckpt_bytes = sum(
        f.stat().st_size for f in step_dir.glob("leaf_*.npy")
    )
    return train_bytes, eng_bytes, ckpt_bytes, eng


def test_bits4_resident_bytes_at_most_055x_bits8(tmp_path):
    """Acceptance bar: at d=64 the 4-bit table is <= 0.55x the 8-bit table's
    bytes for (a) the training state, (b) the serving Engine's resident
    metric, and (c) the serving checkpoint artifact."""
    t4, e4, c4, eng4 = _table_and_engine_bytes(4, tmp_path)
    t8, e8, c8, _ = _table_and_engine_bytes(8, tmp_path)
    assert t4 <= 0.55 * t8, (t4, t8)
    assert e4 <= 0.55 * e8, (e4, e8)
    assert c4 <= 0.55 * c8, (c4, c8)
    # The Engine metric reports the true packed code footprint.
    metrics = eng4.metrics()
    n_alloc, d_alloc = eng4.table.codes.shape
    assert metrics["embedding_code_bytes"] == n_alloc * d_alloc // 2
    assert metrics["int8_resident"]


# ------------------------------------------- packed serving checkpoint trip


@pytest.mark.parametrize("name", INT_TABLE_METHODS)
def test_packed_serving_checkpoint_roundtrip(name, tmp_path):
    """Train -> serving checkpoint -> Engine.from_checkpoint: the codes stay
    packed across the trip and the restored engine scores requests bitwise
    identically to the pre-save engine."""
    from repro.checkpoint import manager
    from repro.serving.ctr import CTREngine, CTRRequest

    tr, data, spec = _ctr_fixture(name, bits=4)
    state, _ = _train(tr, data, steps=1)
    live = CTREngine.from_state(state, tr.cfg, batch=4)
    manager.save_serving_checkpoint(
        tmp_path, step=1, params=state.dense_params, table=state.emb_state,
        spec=spec,
    )
    restored = CTREngine.from_checkpoint(
        tmp_path, tr.cfg, state.dense_params, batch=4
    )
    stores = [
        leaf for leaf in jax.tree.leaves(
            restored.table,
            is_leaf=lambda x: isinstance(x, codestore.CodeStore),
        )
        if isinstance(leaf, codestore.CodeStore)
    ]
    assert stores, f"{name}: restored serving table has no CodeStore"
    for s in stores:
        assert s.packed and s.data.dtype == jnp.uint8

    ids = np.asarray(data.batch("train", 3, 4)[0][0], np.int32)
    for eng in (live, restored):
        eng.submit(CTRRequest(ids=ids, rid=0))
        eng.step()
    a, b = live.poll(0), restored.poll(0)
    assert a["logit"] == b["logit"]


# ----------------------------------------------------- mixed-precision method


def test_mixed_plan_degenerates_without_field_cards():
    spec = methods.EmbeddingSpec(method="mixed", n=64, d=8, bits=4)
    from repro.methods.mixed import plan_of

    plan = plan_of(spec)
    assert plan.group_bits == (4,)
    assert plan.group_rows == (64,)
    assert plan.field_group == (0,)


def test_mixed_bit_assignment_from_stream_stats():
    from repro.methods.mixed import assign_field_bits

    # Hot small fields keep 8 bits, mid fields 4, huge vocabularies 2.
    assert assign_field_bits((17, 300, 11, 5000)) == (8, 4, 8, 2)


def test_mixed_plan_validates():
    from repro.methods.mixed import plan_of

    with pytest.raises(ValueError, match="field_cards sum"):
        plan_of(methods.EmbeddingSpec(
            method="mixed", n=10, d=8, field_cards=(4, 4)
        ))
    with pytest.raises(ValueError, match="field_bits"):
        plan_of(methods.EmbeddingSpec(
            method="mixed", n=8, d=8, field_cards=(4, 4), field_bits=(4,)
        ))


def test_mixed_multi_group_trains_and_serves_bitwise():
    """A real per-field assignment (three bit-width groups) trains through
    the unmodified CTRTrainer, packs its sub-byte groups, beats the uniform
    8-bit footprint, and serves bitwise-identically to training lookups."""
    from repro.serving.ctr import CTREngine, CTRRequest

    cards = (17, 300, 11, 600)
    fbits = (8, 4, 8, 2)
    tr, data, spec = _ctr_fixture(
        "mixed", bits=8, cards=cards, field_cards=cards, field_bits=fbits
    )
    state, losses = _train(tr, data)
    assert all(np.isfinite(losses))
    m = methods.get("mixed")

    # Three groups: 8-bit (one byte/code), 4-bit (2/byte), 2-bit (4/byte).
    subs = state.emb_state.subs
    assert len(subs) == 3
    assert [s.codes.bits for s in subs] == [8, 4, 2]
    assert subs[1].codes.packed and subs[2].codes.packed

    mixed_bytes = m.memory_bytes(state.emb_state, spec, training=True)
    tr8, data8, spec8 = _ctr_fixture("lpt", bits=8, cards=cards)
    st8, _ = _train(tr8, data8, steps=1)
    lpt8_bytes = methods.get("lpt").memory_bytes(
        st8.emb_state, spec8, training=True
    )
    assert mixed_bytes < lpt8_bytes

    # Serving reads compose the groups exactly like training lookups.
    eng = CTREngine.from_state(state, tr.cfg, batch=4)
    ids, _ = data.batch("train", 5, 4)
    ids = np.asarray(ids, np.int32)
    from repro.serving import table as serving_tbl

    got = serving_tbl.rows(eng.table, jnp.asarray(ids))
    expect = m.lookup(state.emb_state, jnp.asarray(ids), spec)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))

    eng.submit(CTRRequest(ids=ids[0], rid=7))
    eng.step()
    assert np.isfinite(eng.poll(7)["logit"])


def test_mixed_kernel_and_packed_parity():
    """Multi-group mixed holds the same parity bar as the single-group
    methods: packed-on == packed-off == kernels-off, bitwise."""
    cards = (17, 64, 11, 120)
    fbits = (8, 4, 8, 2)
    results = []
    for packed, kernels in ((True, True), (False, True), (True, False)):
        tr, data, spec = _ctr_fixture(
            "mixed", bits=8, cards=cards, field_cards=cards, field_bits=fbits,
            packed=packed, use_kernels=kernels,
        )
        state, losses = _train(tr, data)
        results.append(
            (np.asarray(methods.get("mixed").eval_table(state.emb_state, spec)),
             losses)
        )
    for table, losses in results[1:]:
        np.testing.assert_array_equal(results[0][0], table)
        assert losses == results[0][1]
