"""Per-method conformance suite: every registered embedding method honors the
``EmbeddingMethod`` protocol — init shapes/dtypes, lookup output, the
trainable_params/with_params roundtrip, memory accounting, sharding-spec
structure, checkpoint save/load through checkpoint/manager.py, and a
one-train-step smoke through both trainer formulations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import methods
from repro.checkpoint import load_pytree, save_pytree
from repro.checkpoint.manager import check_embedding_manifest, embedding_manifest

jax.config.update("jax_platform_name", "cpu")

N, D = 103, 8
ALL_METHODS = methods.available()


def spec_of(name):
    return methods.EmbeddingSpec(method=name, n=N, d=D, bits=8, init_scale=0.05)


def state_of(name, seed=0):
    spec = spec_of(name)
    return methods.get(name).init(jax.random.PRNGKey(seed), spec), spec


def test_registry_has_all_paper_methods_plus_composed():
    assert set(ALL_METHODS) >= {
        "fp", "lpt", "alpt", "lsq", "pact", "hash", "prune", "qr_lpt",
    }


def test_unknown_method_raises():
    with pytest.raises(ValueError, match="unknown embedding method"):
        methods.get("nope")
    with pytest.raises(ValueError, match="unknown embedding method"):
        methods.EmbeddingSpec(method="nope", n=4, d=2).is_integer_table


def test_double_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        @methods.register("fp")
        class Dup(methods.EmbeddingMethod):  # pragma: no cover - never built
            pass


@pytest.mark.parametrize("name", ALL_METHODS)
def test_lookup_shapes_and_dtypes(name):
    state, spec = state_of(name)
    m = methods.get(name)
    ids = jnp.array([[0, 5, 17], [N - 1, 2, 5]], jnp.int32)
    rows = m.lookup(state, ids, spec)
    assert rows.shape == (2, 3, D)
    assert rows.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(rows)))
    # Same id -> same row, regardless of position in the batch.
    np.testing.assert_array_equal(np.asarray(rows[0, 1]), np.asarray(rows[1, 2]))


@pytest.mark.parametrize("name", ALL_METHODS)
def test_trainable_params_roundtrip_and_capability_consistency(name):
    state, spec = state_of(name)
    m = methods.get(name)
    params = m.trainable_params(state, spec)
    # Integer tables expose no float leaves; float methods must roundtrip.
    assert (params is None) == m.is_integer_table
    rebuilt = m.with_params(state, params, spec)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(rebuilt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", ALL_METHODS)
def test_memory_bytes_positive_and_compressors_compress(name):
    state, spec = state_of(name)
    m = methods.get(name)
    train_b = m.memory_bytes(state, spec, training=True)
    inf_b = m.memory_bytes(state, spec, training=False)
    assert train_b > 0 and inf_b > 0
    fp_bytes = N * D * 4
    if m.is_integer_table:
        assert train_b < fp_bytes  # no fp32 master copy, ever
    if name in ("lsq", "pact"):
        assert train_b >= fp_bytes and inf_b < fp_bytes


@pytest.mark.parametrize("name", ALL_METHODS)
def test_dense_and_serving_tables_are_full_shape(name):
    state, spec = state_of(name)
    m = methods.get(name)
    for table in (m.eval_table(state, spec), m.serving_table(state, spec)):
        assert table.shape == (N, D) and table.dtype == jnp.float32


@pytest.mark.parametrize("name", ALL_METHODS)
def test_table_pspec_mirrors_state_structure(name):
    state, spec = state_of(name)
    m = methods.get(name)
    pspec = m.table_pspec("model", None, row_optimizer="adam")
    is_p = lambda x: isinstance(x, P)
    n_spec = len(jax.tree.flatten(pspec, is_leaf=is_p)[0])
    assert n_spec == len(jax.tree.leaves(state))


@pytest.mark.parametrize("name", ALL_METHODS)
def test_checkpoint_roundtrip_through_manager(name, tmp_path):
    state, spec = state_of(name)
    m = methods.get(name)
    meta = embedding_manifest(spec)
    assert meta["embedding_method"] == name
    assert len(meta["embedding_schema"]) == len(jax.tree.leaves(state))
    save_pytree(state, tmp_path, step=1, extra_meta=meta)
    restored, manifest = load_pytree(state, tmp_path, step=1)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        if hasattr(a, "dtype"):  # python-scalar leaves restore as 0-d arrays
            assert a.dtype == np.asarray(b).dtype  # int8 codes stay int8
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert check_embedding_manifest(manifest, spec) == []
    # A different method (or geometry) must be flagged, not silently loaded.
    other = "lpt" if name != "lpt" else "fp"
    assert check_embedding_manifest(manifest, spec_of(other))


@pytest.mark.parametrize("name", ALL_METHODS)
def test_one_train_step_both_formulations(name):
    """Every method takes one fused step and one dense-formulation
    (microbatched grad/apply) step through the unmodified CTRTrainer."""
    from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic
    from repro.models.ctr import DCNConfig
    from repro.training import data_parallel as dpm
    from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

    data_cfg = CTRDatasetConfig(
        name="conf", n_fields=4, cardinalities=(17, 29, 11, 41),
        teacher_rank=3, seed=7,
    )
    data = CTRSynthetic(data_cfg)
    spec = methods.EmbeddingSpec(
        method=name, n=data_cfg.n_features, d=8, bits=8, init_scale=0.05
    )
    dcn = DCNConfig(n_fields=4, emb_dim=8, cross_depth=1, mlp_widths=(16,))
    tr = CTRTrainer(TrainerConfig(spec=spec, model="dcn", dcn=dcn, lr=1e-3))
    ids, labels = data.batch("train", 0, 16)

    fused_state, m1 = tr.train_step(tr.init_state(), ids, labels)
    micro = dpm.make_ctr_microbatch_step(tr, 2, dpm.DPConfig(sync_bits=8))
    micro_state, m2 = micro(tr.init_state(), jnp.asarray(ids),
                            jnp.asarray(labels))
    for m in (m1, m2):
        assert np.isfinite(float(m["loss"]))
    # The step must actually move the state (lookup of a touched id changes).
    method = methods.get(name)
    before = method.lookup(tr.init_state().emb_state, ids[:1], spec)
    after = method.lookup(fused_state.emb_state, ids[:1], spec)
    assert not np.array_equal(np.asarray(before), np.asarray(after))


# ---------------------------------------------------------------------------
# Kernels-on vs kernels-off parity: the fused Pallas hot paths
# (repro.kernels.ops, interpret mode off-TPU) must be numerically IDENTICAL
# to the jnp path for every integer-table method, in both the sparse (CTR
# fused) and dense (LM / microbatched) formulations.  SR noise is seeded, so
# the comparison is exact — any new method registered with integer-table
# formulations is automatically held to the same contract.
# ---------------------------------------------------------------------------

INT_TABLE_METHODS = [m for m in ALL_METHODS if methods.get(m).is_integer_table]


def _ctr_fixture(name, use_kernels, pad):
    from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic
    from repro.models.ctr import DCNConfig
    from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

    data_cfg = CTRDatasetConfig(
        name="kparity", n_fields=4, cardinalities=(23, 37, 11, 53),
        teacher_rank=3, seed=11,
    )
    data = CTRSynthetic(data_cfg)
    spec = methods.EmbeddingSpec(
        method=name, n=data_cfg.n_features, d=8, bits=8, init_scale=0.05,
        use_kernels=use_kernels, pad_to_tiles=pad,
    )
    dcn = DCNConfig(n_fields=4, emb_dim=8, cross_depth=1, mlp_widths=(16,))
    tr = CTRTrainer(TrainerConfig(spec=spec, model="dcn", dcn=dcn, lr=1e-3))
    return tr, data, spec


def _assert_live_state_equal(m, spec_on, st_on, spec_off, st_off, ctx):
    """Bitwise equality on everything the model can observe: the live
    de-quantized table and the dense parameters.  (pad_to_tiles scratch rows
    are deliberately unspecified bytes on both paths.)"""
    t_on = m.eval_table(st_on.emb_state, spec_on)
    t_off = m.eval_table(st_off.emb_state, spec_off)
    np.testing.assert_array_equal(
        np.asarray(t_on), np.asarray(t_off), err_msg=f"{ctx}: table"
    )
    for a, b in zip(jax.tree.leaves(st_on.dense_params),
                    jax.tree.leaves(st_off.dense_params)):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"{ctx}: dense params"
        )


@pytest.mark.parametrize("pad", [False, True])
@pytest.mark.parametrize("name", INT_TABLE_METHODS)
def test_kernel_parity_ctr_sparse(name, pad):
    """Kernels-on == kernels-off, CTR fused (sparse row) formulation."""
    m = methods.get(name)
    tr_on, data, spec_on = _ctr_fixture(name, True, pad)
    tr_off, _, spec_off = _ctr_fixture(name, False, pad)
    st_on = tr_on.init_state()
    st_off = tr_off.init_state()
    for step in range(3):
        ids, labels = data.batch("train", step, 16)
        st_on, m_on = tr_on.train_step(st_on, ids, labels)
        st_off, m_off = tr_off.train_step(st_off, ids, labels)
        np.testing.assert_array_equal(
            np.asarray(m_on["loss"]), np.asarray(m_off["loss"]),
            err_msg=f"{name} pad={pad} step {step}: loss",
        )
        _assert_live_state_equal(
            m, spec_on, st_on, spec_off, st_off,
            f"{name} pad={pad} step {step}",
        )


@pytest.mark.parametrize("name", INT_TABLE_METHODS)
def test_kernel_parity_ctr_dense_microbatched(name):
    """Kernels-on == kernels-off through the dense formulation (the DP
    arithmetic: dense_lookup custom-vjp forward + dense_update write-back)."""
    from repro.training import data_parallel as dpm

    m = methods.get(name)
    tr_on, data, spec_on = _ctr_fixture(name, True, False)
    tr_off, _, spec_off = _ctr_fixture(name, False, False)
    step_on = dpm.make_ctr_microbatch_step(tr_on, 2, dpm.DPConfig(sync_bits=8))
    step_off = dpm.make_ctr_microbatch_step(tr_off, 2, dpm.DPConfig(sync_bits=8))
    st_on = tr_on.init_state()
    st_off = tr_off.init_state()
    for step in range(2):
        ids, labels = data.batch("train", step, 16)
        st_on, _ = step_on(st_on, jnp.asarray(ids), jnp.asarray(labels))
        st_off, _ = step_off(st_off, jnp.asarray(ids), jnp.asarray(labels))
        _assert_live_state_equal(
            m, spec_on, st_on, spec_off, st_off, f"{name} micro step {step}"
        )


@pytest.mark.parametrize("name", INT_TABLE_METHODS)
def test_kernel_parity_lm_dense(name):
    """Kernels-on == kernels-off, LM dense formulation (vocab-table
    write-back through ops.lpt_update / ops.sr_round)."""
    import dataclasses

    from repro import configs
    from repro.configs.common import concrete_batch
    from repro.training import lm_trainer

    cfg = dataclasses.replace(
        configs.smoke_config("smollm-135m"), embedding_method=name
    )
    batch = concrete_batch(cfg, batch=2, seq=16)
    tables = {}
    for use_kernels in (True, False):
        tcfg = lm_trainer.LMTrainerConfig(lr=1e-3, use_kernels=use_kernels)
        step = jax.jit(lm_trainer.make_train_step(cfg, tcfg))
        state = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
        losses = []
        for _ in range(2):
            state, metrics = step(state, batch)
            losses.append(np.asarray(metrics["loss"]))
        spec = lm_trainer.embedding_spec_of(cfg, tcfg)
        tables[use_kernels] = (
            np.asarray(methods.get(name).eval_table(state.table, spec)),
            losses,
        )
    np.testing.assert_array_equal(
        tables[True][0], tables[False][0], err_msg=f"{name}: vocab table"
    )
    for l_on, l_off in zip(tables[True][1], tables[False][1]):
        np.testing.assert_array_equal(l_on, l_off, err_msg=f"{name}: loss")


def test_kernel_parity_padded_spec_geometry():
    """pad_to_tiles allocates a scratch row past the id space and sublane-
    rounds, and the padding never leaks into model-visible shapes."""
    spec = methods.EmbeddingSpec(
        method="lpt", n=103, d=12, bits=8, pad_to_tiles=True
    )
    assert spec.n_padded % 8 == 0 and spec.n_padded > spec.n
    assert spec.d_padded % 8 == 0 and spec.d_padded >= spec.d
    m = methods.get("lpt")
    state = m.init(jax.random.PRNGKey(0), spec)
    assert state.codes.shape == (spec.n_padded, spec.d_padded)
    rows = m.lookup(state, jnp.array([0, spec.n - 1]), spec)
    assert rows.shape == (2, spec.d)
    assert m.eval_table(state, spec).shape == (spec.n, spec.d)
    assert m.serving_table(state, spec).shape == (spec.n, spec.d)


def test_lm_prune_mask_refresh_actually_prunes():
    """The LM path honors has_host_refresh: with an aggressive DeepLight
    schedule the vocab table's mask must leave the all-ones init (the
    schedule clock is host-driven, like the CTR trainer's wrapper)."""
    import dataclasses

    from repro import configs
    from repro.configs.common import concrete_batch
    from repro.core import pruning
    from repro.training import lm_trainer

    cfg = configs.smoke_config("smollm-135m")
    cfg = dataclasses.replace(cfg, embedding_method="prune")
    tcfg = lm_trainer.LMTrainerConfig(
        lr=1e-3,
        prune=pruning.PruneConfig(
            target_sparsity=0.5, warmup_steps=0, update_every=1,
            damping=0.5, damping_steps=1,
        ),
    )
    step = lm_trainer.wrap_host_refresh(
        jax.jit(lm_trainer.make_train_step(cfg, tcfg)), cfg, tcfg
    )
    state = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    batch = concrete_batch(cfg, batch=4, seq=16)
    for _ in range(3):
        state, _ = step(state, batch)
    assert int(state.table.step) == 3  # host_sync drives the schedule clock
    sparsity = float(pruning.sparsity(state.table))
    assert sparsity > 0.1, sparsity
