"""Behaviour tests for LPT (Eq. 8) and ALPT (Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alpt, lpt, quant, theory

jax.config.update("jax_platform_name", "cpu")


def make_table(n=32, d=8, bits=8, optimizer="sgd", **kw):
    return lpt.init_table(jax.random.PRNGKey(0), n, d, bits, optimizer=optimizer, **kw)


def test_lookup_shapes():
    t = make_table()
    ids = jnp.array([[0, 1], [2, 3]])
    rows = lpt.lookup(t, ids)
    assert rows.shape == (2, 2, 8)
    assert rows.dtype == jnp.float32


def test_untouched_rows_bit_stable():
    """LPT must not drift rows that a batch does not touch."""
    t = make_table(optimizer="adam")
    ids = jnp.array([1, 3])
    g = jnp.ones((2, 8), jnp.float32)
    t2 = lpt.sparse_apply(
        t, ids, g, lr=0.1, bits=8, rounding="sr",
        noise_key=jax.random.PRNGKey(1), optimizer="adam",
    )
    untouched = [i for i in range(32) if i not in (1, 3)]
    np.testing.assert_array_equal(
        np.asarray(t.codes)[untouched], np.asarray(t2.codes)[untouched]
    )
    # Touched rows did change.
    assert not np.array_equal(np.asarray(t.codes)[[1, 3]], np.asarray(t2.codes)[[1, 3]])


def test_duplicate_ids_sum_gradients():
    """Duplicate ids in a batch behave like a scatter-add (one summed update)."""
    t = make_table(optimizer="sgd", step_size=0.001)
    ids_dup = jnp.array([5, 5])
    g = jnp.ones((2, 8), jnp.float32) * 0.01
    t_dup = lpt.sparse_apply(
        t, ids_dup, g, lr=1.0, bits=8, rounding="dr", optimizer="sgd"
    )
    ids_one = jnp.array([5])
    t_one = lpt.sparse_apply(
        t, ids_one, jnp.ones((1, 8)) * 0.02, lr=1.0, bits=8, rounding="dr",
        optimizer="sgd",
    )
    np.testing.assert_array_equal(np.asarray(t_dup.codes[5]), np.asarray(t_one.codes[5]))


def test_sparse_vs_dense_equivalence():
    """The CTR (sparse) path and the LM (dense) path implement one update rule."""
    t = make_table(n=16, d=4, optimizer="adam")
    ids = jnp.array([2, 7, 11])
    g_rows = jax.random.normal(jax.random.PRNGKey(5), (3, 4))
    key = jax.random.PRNGKey(9)
    t_sparse = lpt.sparse_apply(
        t, ids, g_rows, lr=0.05, bits=8, rounding="dr", optimizer="adam",
        noise_key=key,
    )
    g_dense = jnp.zeros((16, 4)).at[ids].add(g_rows)
    t_dense = lpt.dense_apply(
        t, g_dense, lr=0.05, bits=8, rounding="dr", optimizer="adam", noise_key=key
    )
    np.testing.assert_array_equal(np.asarray(t_sparse.codes), np.asarray(t_dense.codes))
    np.testing.assert_allclose(
        np.asarray(t_sparse.mu), np.asarray(t_dense.mu), atol=1e-6
    )


def test_lpt_under_jit():
    t = make_table()

    @jax.jit
    def step(t, ids, g, key):
        return lpt.sparse_apply(
            t, ids, g, lr=0.1, bits=8, rounding="sr", noise_key=key, optimizer="sgd"
        )

    t2 = step(t, jnp.array([0, 1, 1]), jnp.ones((3, 8)), jax.random.PRNGKey(0))
    assert t2.codes.shape == t.codes.shape


def test_lpt_convergence_sr_beats_dr():
    """Remark 1 on a real table: small-gradient regime stalls DR, not SR.

    Target rows pulled toward 0.5 with decaying lr; SR keeps moving, DR freezes.
    """
    bits = 8
    delta = 0.01

    def run(rounding, iters=300):
        t = make_table(n=4, d=8, step_size=delta, optimizer="sgd")
        ids = jnp.arange(4)
        key = jax.random.PRNGKey(7)
        for i in range(1, iters + 1):
            rows = lpt.lookup(t, ids)
            g = 2.0 * (rows - 0.5)
            key, kn = jax.random.split(key)
            t = lpt.sparse_apply(
                t, ids, g, lr=0.3 / np.sqrt(i), bits=bits, rounding=rounding,
                noise_key=kn, optimizer="sgd",
            )
        return float(jnp.mean(jnp.abs(lpt.lookup(t, ids) - 0.5)))

    err_sr = run("sr")
    err_dr = run("dr")
    assert err_sr < 0.008  # SR converges to the quantization floor
    assert err_dr > 0.008  # DR stalls above it (Remark 1)
    assert err_dr > 2.0 * err_sr


def test_theorem_bounds_dr_geq_sr():
    for T in (10, 100, 10000):
        for delta in (0.1, 0.01, 0.001):
            b_sr = theory.sr_bound(D=1.0, G=1.0, eta=0.5, d=16, delta=delta, T=T)
            b_dr = theory.dr_bound(D=1.0, G=1.0, eta=0.5, d=16, delta=delta, T=T)
            assert b_dr >= b_sr - 1e-9


def test_synthetic_experiment_fig3():
    """Reproduce Fig 3: SR ~ FP convergence; DR stalls with ~100% small updates."""
    fp = theory.synthetic_experiment("fp", iters=1000)
    sr = theory.synthetic_experiment("sr", iters=1000)
    dr = theory.synthetic_experiment("dr", iters=1000)
    assert float(fp.mean_abs_err[-1]) < 0.02
    assert float(sr.mean_abs_err[-1]) < 0.02  # similar-or-faster than FP (paper)
    assert float(dr.mean_abs_err[-1]) > 5 * float(sr.mean_abs_err[-1])  # stalled
    # Fig 3(d): after ~10 iters all DR updates are below Delta/2.
    assert float(dr.stalled_frac[50]) > 0.95


def test_alpt_step_runs_and_learns_delta():
    cfg = alpt.ALPTConfig(bits=8, step_lr=1e-2, weight_decay=0.0, optimizer="sgd")
    t = make_table(n=16, d=8, step_size=0.01, optimizer="sgd")
    ids = jnp.array([1, 2, 3, 3])
    target = jnp.ones((4, 8)) * 0.3

    def loss_fn(rows):
        return jnp.sum((rows - target) ** 2)

    step_before = np.asarray(t.step).copy()
    losses = []
    key = jax.random.PRNGKey(0)
    for i in range(30):
        key, kn = jax.random.split(key)
        t, loss, aux = alpt_step_jitted(t, ids, loss_fn, cfg, kn)
        losses.append(float(loss))
    # Loss decreased and the touched step sizes moved.
    assert losses[-1] < losses[0] * 0.5
    touched = np.array([1, 2, 3])
    assert not np.allclose(np.asarray(t.step)[touched], step_before[touched])
    untouched = np.array([0, 5, 10])
    np.testing.assert_array_equal(np.asarray(t.step)[untouched], step_before[untouched])


def alpt_step_jitted(t, ids, loss_fn, cfg, key):
    @jax.jit
    def _step(t, key):
        return alpt.alpt_step(t, ids, loss_fn, cfg=cfg, lr=0.1, noise_key=key)

    return _step(t, key)


def test_alpt_grad_scale_factor():
    cfg = alpt.ALPTConfig(bits=8, grad_scale="bdq")
    g = alpt.grad_scale_factor(cfg, batch_rows=100, dim=16)
    assert abs(g - 1.0 / np.sqrt(100 * 16 * 127)) < 1e-9
    cfg1 = cfg._replace(grad_scale="1")
    assert alpt.grad_scale_factor(cfg1, 100, 16) == 1.0


def test_memory_accounting():
    t = make_table(n=1000, d=16, bits=8)
    fp_bytes = 1000 * 16 * 4
    lpt_bytes = lpt.memory_bytes(t, bits=8)
    # 4x on codes; the per-row Delta costs one extra f32 per row (paper §4.2).
    assert lpt_bytes == 1000 * 16 * 1 + 1000 * 4
    assert fp_bytes / lpt_bytes > 3.0


# ------------------------------------------------- dedup sentinel semantics


def test_dedup_ids_sentinel_padding_and_inverse():
    """dedup_ids pads with the out-of-range sentinel n_rows and maps every
    occurrence back to its unique slot."""
    uniq, inv = lpt.dedup_ids(jnp.array([3, 3, 5]), 16)
    assert uniq.shape == (3,)  # jit-stable: size == number of occurrences
    np.testing.assert_array_equal(np.asarray(uniq), [3, 5, 16])
    np.testing.assert_array_equal(np.asarray(inv), [0, 0, 1])


def test_sparse_apply_sentinel_inert_and_duplicates_sum_once():
    """Padding rows (sentinel id n_rows) must scatter inertly (mode='drop')
    and duplicated ids must receive their SUMMED gradient exactly once."""
    n, d, lr = 16, 4, 0.5
    t = make_table(n=n, d=d, optimizer="sgd", step_size=0.01)
    ids = jnp.array([3, 3, 5])
    g = jnp.ones((3, d), jnp.float32)
    t2 = lpt.sparse_apply(t, ids, g, lr=lr, bits=8, rounding="dr",
                          optimizer="sgd")
    w0 = np.asarray(lpt.dense_table(t))
    step = np.asarray(t.step)
    # Row 3: two occurrences -> one update with the summed gradient (2.0).
    want3 = quant.quantize_codes(jnp.asarray(w0[3] - lr * 2.0), step[3], 8, "dr")
    np.testing.assert_array_equal(np.asarray(t2.codes[3]), np.asarray(want3))
    # Row 5: single occurrence.
    want5 = quant.quantize_codes(jnp.asarray(w0[5] - lr * 1.0), step[5], 8, "dr")
    np.testing.assert_array_equal(np.asarray(t2.codes[5]), np.asarray(want5))
    # Everything else — including the rows the sentinel gather touched (0 and
    # n-1) — is bit-identical.
    untouched = [i for i in range(n) if i not in (3, 5)]
    np.testing.assert_array_equal(
        np.asarray(t.codes)[untouched], np.asarray(t2.codes)[untouched]
    )
    np.testing.assert_array_equal(np.asarray(t.step), np.asarray(t2.step))


# -------------------------------------- dense/sparse ALPT grad-scale parity


def test_alpt_dense_step_uses_batch_rows_not_table_rows():
    """Regression: both ALPT paths must scale the Delta gradient by the
    paper's b = rows-in-the-batch.  The dense path used to pass the table's
    total row count, damping Delta learning by sqrt(V/b) relative to the
    sparse path on identical data."""
    n, d = 32, 8
    key = jax.random.PRNGKey(0)
    table = make_table(n=n, d=d, optimizer="sgd")
    ids = jnp.array([1, 4, 9])
    c = jax.random.normal(jax.random.PRNGKey(1), (3, d))
    cfg = alpt.ALPTConfig(bits=8, rounding="dr", optimizer="sgd",
                          weight_decay=0.0, step_lr=1e-3, grad_scale="bdq")
    lr = jnp.asarray(0.01, jnp.float32)

    def loss_rows(rows):  # sparse path: per-occurrence rows [3, d]
        return jnp.sum(rows * c)

    t_sparse, _, _ = alpt.alpt_step(table, ids, loss_rows, cfg=cfg, lr=lr,
                                    noise_key=key)

    def loss_dense(tab):  # dense path: full de-quantized table [n, d]
        return jnp.sum(tab[ids] * c)

    g_dense = jax.grad(loss_dense)(lpt.dense_table(table))
    t_dense = alpt.alpt_dense_step(table, g_dense, loss_dense, cfg=cfg, lr=lr,
                                   noise_key=key, batch_rows=int(ids.size))

    sel = np.asarray(ids)
    np.testing.assert_allclose(np.asarray(t_sparse.step)[sel],
                               np.asarray(t_dense.step)[sel], rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(t_sparse.codes)[sel],
                                  np.asarray(t_dense.codes)[sel])
