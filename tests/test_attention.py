"""Flash attention (values + custom-VJP gradients) vs a naive reference."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import decode_attention, flash_attention

jax.config.update("jax_platform_name", "cpu")


def naive_attention(q, k, v, *, causal, window=None, q_offset=0):
    b, t, h, d = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = h // kh
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bthd,bshd->bhts", q, kr) / math.sqrt(d)
    q_ids = q_offset + jnp.arange(t)
    k_ids = jnp.arange(s)
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= q_ids[:, None] >= k_ids[None, :]
    if window is not None:
        mask &= q_ids[:, None] - k_ids[None, :] < window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhts,bshd->bthd", p, vr)


CASES = [
    # (t, s, h, kh, d, causal, window, q_block, k_block)
    (64, 64, 4, 4, 16, True, None, 16, 16),
    (64, 64, 4, 2, 16, True, None, 32, 16),
    (96, 96, 4, 1, 8, True, None, 32, 32),  # non-divisible t/s vs blocks
    (64, 64, 2, 2, 16, False, None, 16, 32),  # encoder
    (128, 128, 4, 2, 16, True, 32, 32, 32),  # sliding window
    (100, 100, 2, 2, 8, True, 24, 32, 16),  # SWA + ragged blocks
]


@pytest.mark.parametrize("t,s,h,kh,d,causal,window,qb,kb", CASES)
def test_flash_matches_naive_forward(t, s, h, kh, d, causal, window, qb, kb):
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (2, t, h, d))
    k = jax.random.normal(k2, (2, s, kh, d))
    v = jax.random.normal(k3, (2, s, kh, d))
    out = flash_attention(q, k, v, causal=causal, window=window, q_block=qb,
                          k_block=kb)
    ref = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=1e-4)


@pytest.mark.parametrize("t,s,h,kh,d,causal,window,qb,kb", CASES)
def test_flash_matches_naive_gradients(t, s, h, kh, d, causal, window, qb, kb):
    key = jax.random.PRNGKey(1)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    q = jax.random.normal(k1, (2, t, h, d))
    k = jax.random.normal(k2, (2, s, kh, d))
    v = jax.random.normal(k3, (2, s, kh, d))
    co = jax.random.normal(k4, (2, t, h, d))  # random cotangent

    def loss_flash(q, k, v):
        return jnp.sum(
            flash_attention(q, k, v, causal=causal, window=window, q_block=qb,
                            k_block=kb) * co
        )

    def loss_naive(q, k, v):
        return jnp.sum(naive_attention(q, k, v, causal=causal, window=window) * co)

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gn, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-4, rtol=1e-3,
            err_msg=f"d{name} mismatch",
        )


def test_flash_q_offset_continuation():
    """Chunked prefill: q_offset shifts the causal frontier correctly."""
    key = jax.random.PRNGKey(2)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (1, 32, 2, 8))
    k = jax.random.normal(k2, (1, 64, 2, 8))
    v = jax.random.normal(k3, (1, 64, 2, 8))
    out = flash_attention(q, k, v, causal=True, q_offset=32, q_block=16,
                          k_block=16)
    ref = naive_attention(q, k, v, causal=True, q_offset=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=1e-4)


def test_decode_matches_naive_last_row():
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    s = 40
    q = jax.random.normal(k1, (2, 1, 4, 16))
    kc = jax.random.normal(k2, (2, 64, 2, 16))
    vc = jax.random.normal(k3, (2, 64, 2, 16))
    out = decode_attention(q, kc, vc, jnp.asarray(s))
    # Naive: attend over the first s entries only.
    ref = naive_attention(
        q, kc[:, :s], vc[:, :s], causal=True, q_offset=s - 1
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=1e-4)
