"""Per-architecture smoke tests: reduced configs, one train step on CPU,
shape + finiteness assertions, and prefill/decode consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.common import concrete_batch
from repro.models import transformer as tfm
from repro.training import lm_trainer

# ~3 CPU-minutes across 10 archs: runs in the slow/dist CI shard.
pytestmark = pytest.mark.slow

jax.config.update("jax_platform_name", "cpu")

ALL_ARCHS = sorted(configs.ARCHS)
SEQ = 64
BATCH = 2


@pytest.fixture(scope="module")
def smoke_states():
    return {}


def _setup(arch):
    cfg = configs.smoke_config(arch)
    tcfg = lm_trainer.LMTrainerConfig(lr=1e-3)
    state = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(lm_trainer.make_train_step(cfg, tcfg))
    return cfg, state, step


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_runs_no_nans(arch):
    cfg, state, step = _setup(arch)
    batch = concrete_batch(cfg, batch=BATCH, seq=SEQ)
    state, m = step(state, batch)
    state, m2 = step(state, batch)
    assert np.isfinite(float(m["loss"])), f"{arch}: loss not finite"
    assert np.isfinite(float(m2["loss"]))
    # Same batch twice: loss should decrease (the model can overfit 2x64 tokens).
    for _ in range(6):
        state, m3 = step(state, batch)
    assert float(m3["loss"]) < float(m["loss"]), f"{arch}: no learning signal"
    # Parameters stayed finite.
    assert all(
        bool(jnp.all(jnp.isfinite(x)))
        for x in jax.tree.leaves(state.params)
        if jnp.issubdtype(x.dtype, jnp.floating)
    )


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes(arch):
    cfg = configs.smoke_config(arch)
    tcfg = lm_trainer.LMTrainerConfig()
    state = lm_trainer.init_state(jax.random.PRNGKey(1), cfg, tcfg)
    batch = concrete_batch(cfg, batch=BATCH, seq=SEQ)
    table_fp = lm_trainer.table_fp_of(state, cfg)
    embeds = tfm.assemble_embeds(table_fp, batch, cfg)
    assert embeds.shape == (BATCH, SEQ, cfg.d_model)
    pos = batch.get("positions", tfm.default_positions(BATCH, SEQ, cfg))
    h, aux = tfm.backbone(state.params, embeds, cfg, pos)
    assert h.shape == (BATCH, SEQ, cfg.d_model)
    assert np.isfinite(np.asarray(h)).all()
    logits = tfm.head_logits(state.params, table_fp, h, cfg)
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)


DECODE_ARCHS = [a for a in ALL_ARCHS if a != "hubert-xlarge"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_then_decode(arch):
    cfg = configs.smoke_config(arch)
    tcfg = lm_trainer.LMTrainerConfig()
    state = lm_trainer.init_state(jax.random.PRNGKey(2), cfg, tcfg)
    table_fp = lm_trainer.table_fp_of(state, cfg)
    t0 = 16
    tokens = jax.random.randint(
        jax.random.PRNGKey(3), (BATCH, t0), 0, cfg.vocab_size, jnp.int32
    )
    if cfg.input_mode == "mixed":
        # VLM decode operates on the text path; plain tokens are valid input.
        pass
    logits, cache = tfm.prefill(state.params, table_fp, tokens, cfg, max_len=t0 + 8)
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for i in range(3):
        logits, cache = tfm.decode_step(
            state.params, table_fp, tok, cache, jnp.asarray(t0 + i, jnp.int32), cfg
        )
        assert logits.shape == (BATCH, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "smollm-135m", "mamba2-370m"])
def test_decode_matches_full_forward(arch):
    """Prefill+decode must agree with the full-sequence forward (teacher forcing)."""
    cfg = configs.smoke_config(arch)
    tcfg = lm_trainer.LMTrainerConfig()
    state = lm_trainer.init_state(jax.random.PRNGKey(4), cfg, tcfg)
    table_fp = lm_trainer.table_fp_of(state, cfg)
    t = 12
    tokens = jax.random.randint(
        jax.random.PRNGKey(5), (1, t), 0, cfg.vocab_size, jnp.int32
    )
    # Full forward logits at every position.
    embeds = tfm.embed_tokens(table_fp, tokens, cfg)
    pos = tfm.default_positions(1, t, cfg)
    h, _ = tfm.backbone(state.params, embeds, cfg, pos)
    full_logits = tfm.head_logits(state.params, table_fp, h, cfg)  # [1, t, V]
    # Prefill on the first t-3 tokens, decode the rest teacher-forced.
    t0 = t - 3
    logits_p, cache = tfm.prefill(
        state.params, table_fp, tokens[:, :t0], cfg, max_len=t
    )
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, t0 - 1]), rtol=2e-2,
        atol=2e-3,
    )
    for i in range(3):
        logits_d, cache = tfm.decode_step(
            state.params, table_fp, tokens[:, t0 + i], cache,
            jnp.asarray(t0 + i, jnp.int32), cfg,
        )
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full_logits[:, t0 + i]), rtol=2e-2,
            atol=2e-3,
        )


def test_swa_ring_cache_bounded():
    """SWA decode cache is window-sized regardless of max_len (long_500k story)."""
    cfg = configs.smoke_config("mixtral-8x7b")
    cache = tfm.init_cache(cfg, batch=1, max_len=4096)
    # Layout: [groups, batch, kv_slots, kv_heads, head_dim].
    assert cache[0]["k"].shape[2] == cfg.sliding_window


def test_param_counts_full_configs():
    """Full configs match the published parameter scales (sanity on shapes)."""
    expected = {
        "smollm-135m": (0.10e9, 0.20e9),
        "qwen3-1.7b": (1.4e9, 2.4e9),
        "mixtral-8x7b": (40e9, 55e9),
        "deepseek-67b": (60e9, 75e9),
        "mamba2-370m": (0.3e9, 0.5e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "jamba-v0.1-52b": (45e9, 60e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = configs.full_config(arch)
        n = _count_params_analytic(cfg)
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9},{hi/1e9}]B"


def _count_params_analytic(cfg: tfm.ModelConfig) -> int:
    """Closed-form parameter count from the config (no allocation)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    h, kv = cfg.n_heads, cfg.n_kv_heads  # unpadded, published arch
    hd = cfg.hd
    total = v * d  # embedding
    if not cfg.tie_embeddings:
        total += v * d
    for layer in range(cfg.n_layers):
        pos = layer % cfg.period
        kind = cfg.layer_type(pos)
        if kind == "attn":
            total += d * h * hd + 2 * d * kv * hd + h * hd * d
        else:
            s = cfg.ssm
            total += d * s.proj_width + s.conv_width * s.conv_dim + s.d_inner * d
        if cfg.is_moe(pos):
            m = cfg.moe
            total += m.n_experts * 3 * d * m.d_ff + d * m.n_experts
            if m.n_shared_experts:
                total += 3 * d * m.shared_hidden
        elif f > 0:
            total += 3 * d * f if cfg.mlp_type == "swiglu" else 2 * d * f
    return total
