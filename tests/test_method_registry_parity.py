"""Bitwise step-parity: registry-dispatched trainers == pre-refactor steps.

The `repro.methods` redesign must change NOTHING numerically.  The reference
side is tests/_legacy_embed.py — frozen copies of the string-dispatch step
functions exactly as they existed before the registry — and every comparison
is bit-for-bit over the full train-state pytree at a fixed seed:

  * CTR fused single-device steps, methods {fp, lpt, alpt};
  * CTR grad/apply (DP arithmetic) via the microbatched twin, at
    sync_bits in {32, 8} — the same arithmetic the shard_map DP wrapper
    runs per rank (tests/test_data_parallel.py proves mesh == microbatch
    bitwise, so legacy == microbatch here closes legacy == DP mesh);
  * LM fused steps and microbatched twins, methods {lpt, alpt};
  * a direct 8-fake-device shard_map check (marker: dist).

Plus the registry's existence proof: qr_lpt — a method the old string chains
could not express — trains end-to-end through the unmodified CTRTrainer.
"""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _legacy_embed as legacy
from conftest import run_prog

from repro.core.alpt import ALPTConfig
from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic
from repro.models import embedding as emb_mod
from repro.models.ctr import DCNConfig
from repro.training import data_parallel as dpm
from repro.training import lm_trainer
from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

jax.config.update("jax_platform_name", "cpu")

DATA_CFG = CTRDatasetConfig(
    name="parity", n_fields=6, cardinalities=(17, 29, 11, 41, 13, 23),
    teacher_rank=4, seed=3,
)
DATA = CTRSynthetic(DATA_CFG)
DCN = DCNConfig(n_fields=6, emb_dim=8, cross_depth=2, mlp_widths=(32, 16))


def make_trainer(method, **spec_kw):
    spec = emb_mod.EmbeddingSpec(
        method=method, n=DATA_CFG.n_features, d=8, bits=8, init_scale=0.05,
        alpt=ALPTConfig(bits=8, step_lr=2e-4), **spec_kw,
    )
    return CTRTrainer(TrainerConfig(spec=spec, model="dcn", dcn=DCN, lr=1e-3))


def assert_states_equal(a, b, ctx):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(la)), np.asarray(jax.device_get(lb)),
            err_msg=str(ctx),
        )


# ------------------------------------------------------------- CTR parity


@pytest.mark.parametrize("method", ["fp", "lpt", "alpt"])
def test_ctr_fused_step_bitwise_matches_legacy(method):
    tr = make_trainer(method)
    legacy_step = legacy.legacy_ctr_train_step(tr)
    s_new, s_old = tr.init_state(), tr.init_state()
    for i in range(3):
        ids, labels = DATA.batch("train", i, 64)
        s_new, m_new = tr.train_step(s_new, ids, labels)
        s_old, m_old = legacy_step(s_old, jnp.asarray(ids), jnp.asarray(labels))
        assert_states_equal(s_new, s_old, (method, i))
        assert float(m_new["loss"]) == float(m_old["loss"]), (method, i)


@pytest.mark.parametrize("method", ["fp", "lpt", "alpt"])
@pytest.mark.parametrize("bits", [32, 8])
def test_ctr_dp_arithmetic_bitwise_matches_legacy(method, bits):
    """grad/apply split (what every DP rank runs) at exact + compressed sync."""
    tr = make_trainer(method)
    dp = dpm.DPConfig(sync_bits=bits)
    new_step = dpm.make_ctr_microbatch_step(tr, 4, dp)
    legacy_step = legacy.legacy_ctr_microbatch_step(tr, 4, dp)
    s_new, s_old = tr.init_state(), tr.init_state()
    for i in range(2):
        ids, labels = DATA.batch("train", i, 64)
        s_new, m_new = new_step(s_new, jnp.asarray(ids), jnp.asarray(labels))
        s_old, m_old = legacy_step(s_old, jnp.asarray(ids), jnp.asarray(labels))
        assert_states_equal(s_new, s_old, (method, bits, i))
        assert float(m_new["loss"]) == float(m_old["loss"]), (method, bits)


@pytest.mark.dist
def test_ctr_dp_mesh_bitwise_matches_legacy():
    """Direct check under the shard_map DP wrapper: 8-device registry step ==
    legacy single-device microbatched step, at sync_bits 32 and 8."""
    prog = textwrap.dedent(
        """
        import os, sys
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        sys.path.insert(0, "tests")
        import jax, jax.numpy as jnp, numpy as np
        import _legacy_embed as legacy
        from repro.core.alpt import ALPTConfig
        from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic
        from repro.models import embedding as emb_mod
        from repro.models.ctr import DCNConfig
        from repro.training.ctr_trainer import CTRTrainer, TrainerConfig
        from repro.training import data_parallel as dpm

        data_cfg = CTRDatasetConfig(
            name="parity", n_fields=6, cardinalities=(17, 29, 11, 41, 13, 23),
            teacher_rank=4, seed=3,
        )
        data = CTRSynthetic(data_cfg)
        mesh = jax.make_mesh((8,), ("data",))
        dcn = DCNConfig(n_fields=6, emb_dim=8, cross_depth=2,
                        mlp_widths=(32, 16))

        for method, bits in [("lpt", 32), ("lpt", 8), ("alpt", 8)]:
            spec = emb_mod.EmbeddingSpec(
                method=method, n=data_cfg.n_features, d=8, bits=8,
                init_scale=0.05, alpt=ALPTConfig(bits=8, step_lr=2e-4),
            )
            tr = CTRTrainer(TrainerConfig(spec=spec, model="dcn", dcn=dcn,
                                          lr=1e-3))
            dp = dpm.DPConfig(sync_bits=bits)
            mesh_step = dpm.make_ctr_dp_step(tr, mesh, dp)
            legacy_step = legacy.legacy_ctr_microbatch_step(tr, 8, dp)
            s_m, s_l = tr.init_state(), tr.init_state()
            for i in range(2):
                ids, labels = data.batch("train", i, 64)
                s_m, m_m = mesh_step(s_m, jnp.asarray(ids), jnp.asarray(labels))
                s_l, m_l = legacy_step(s_l, jnp.asarray(ids), jnp.asarray(labels))
                for a, b in zip(jax.tree.leaves(s_m), jax.tree.leaves(s_l)):
                    assert np.array_equal(np.asarray(jax.device_get(a)),
                                          np.asarray(jax.device_get(b))), (
                        method, bits, i)
                assert float(m_m["loss"]) == float(m_l["loss"])
            print(method, bits, "OK")
        print("DP_MESH_LEGACY_PARITY_OK")
        """
    )
    assert "DP_MESH_LEGACY_PARITY_OK" in run_prog(prog)


# -------------------------------------------------------------- LM parity


def lm_setup(method):
    import dataclasses

    from repro import configs
    from repro.configs.common import concrete_batch

    cfg = configs.smoke_config("smollm-135m")
    cfg = dataclasses.replace(cfg, embedding_method=method)
    tcfg = lm_trainer.LMTrainerConfig(lr=1e-3)
    batch = concrete_batch(cfg, batch=8, seq=32)
    return cfg, tcfg, batch


@pytest.mark.parametrize("method", ["lpt", "alpt"])
def test_lm_fused_step_bitwise_matches_legacy(method):
    cfg, tcfg, batch = lm_setup(method)
    new_step = jax.jit(lm_trainer.make_train_step(cfg, tcfg))
    legacy_step = jax.jit(legacy.legacy_lm_train_step(cfg, tcfg))
    s_new = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    s_old = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    for i in range(2):
        s_new, m_new = new_step(s_new, batch)
        s_old, m_old = legacy_step(s_old, batch)
        assert_states_equal(s_new, s_old, (method, i))
        assert float(m_new["loss"]) == float(m_old["loss"]), (method, i)


@pytest.mark.parametrize("method,bits", [("lpt", 32), ("lpt", 8), ("alpt", 8)])
def test_lm_dp_arithmetic_bitwise_matches_legacy(method, bits):
    cfg, tcfg, batch = lm_setup(method)
    dp = dpm.DPConfig(sync_bits=bits)
    new_step = dpm.make_lm_microbatch_step(cfg, tcfg, 4, dp)
    legacy_step = legacy.legacy_lm_microbatch_step(cfg, tcfg, 4, dp)
    s_new = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    s_old = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    for i in range(2):
        s_new, m_new = new_step(s_new, batch)
        s_old, m_old = legacy_step(s_old, batch)
        assert_states_equal(s_new, s_old, (method, bits, i))
        assert float(m_new["loss"]) == float(m_old["loss"]), (method, bits)


# ---------------------------------------------- registry existence proof


def test_qr_lpt_trains_end_to_end_through_unmodified_trainer():
    """The composed method (QR hashing x int8 LPT) — impossible under the old
    FLOAT_METHODS/INT_METHODS split — learns through CTRTrainer purely via
    its registry entry."""
    spec = emb_mod.EmbeddingSpec(
        method="qr_lpt", n=DATA_CFG.n_features, d=8, bits=8, init_scale=0.05,
        alpt=ALPTConfig(bits=8, step_lr=2e-4),
    )
    tr = CTRTrainer(TrainerConfig(spec=spec, model="dcn", dcn=DCN, lr=3e-3))
    state, _ = tr.fit(DATA, steps=250, batch_size=256)
    ev = tr.evaluate(state, DATA.batches("test", 256, 10))
    assert ev["auc"] > 0.63, ev
    # And its memory accounting reflects BOTH compressions (hashing ~2x
    # on rows, int8 ~4x on bytes) — well under half the fp32 table.
    from repro import methods

    spec = tr.spec
    fp_bytes = DATA_CFG.n_features * 8 * 4
    qr_bytes = methods.get("qr_lpt").memory_bytes(
        state.emb_state, spec, training=True
    )
    assert qr_bytes < fp_bytes / 4


def test_qr_lpt_dense_formulation_matches_sparse_semantics():
    """One microbatched (dense-formulation) step == one fused (sparse) step
    is NOT expected bitwise (different gradient layout), but both must leave
    untouched sub-table rows bit-identical — the LPT invariant."""
    tr = make_trainer("qr_lpt")
    step = dpm.make_ctr_microbatch_step(tr, 4, dpm.DPConfig(sync_bits=32))
    state0 = tr.init_state()
    # The jitted step donates the state; snapshot before stepping.
    r = int(state0.emb_state.r)
    codes0 = np.asarray(state0.emb_state.remainder.codes).copy()
    ids, labels = DATA.batch("train", 0, 32)
    state1, _ = step(state0, jnp.asarray(ids), jnp.asarray(labels))
    rid = np.asarray(ids).reshape(-1) % r
    untouched = np.setdiff1d(np.arange(codes0.shape[0]), rid)
    np.testing.assert_array_equal(
        codes0[untouched],
        np.asarray(state1.emb_state.remainder.codes)[untouched],
    )
