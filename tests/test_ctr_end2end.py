"""End-to-end CTR training: every Table-1 method learns; ALPT ~ FP (paper §4.2).

Uses a scaled-down synthetic Criteo-like dataset; claims are the paper's
*relative* orderings (DESIGN.md §7), at reduced scale for CI runtime.
"""
import jax
import pytest

from repro.core.alpt import ALPTConfig
from repro.data.ctr_synth import CTRDatasetConfig
from repro.data import ctr_synth
from repro.models import embedding as emb_mod
from repro.models.ctr import DCNConfig
from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

jax.config.update("jax_platform_name", "cpu")

SMALL = CTRDatasetConfig(
    name="tiny",
    n_fields=8,
    cardinalities=(37, 83, 11, 199, 61, 23, 131, 17),
    teacher_rank=4,
    seed=0,
)
DATA = ctr_synth.CTRSynthetic(SMALL)
DCN_SMALL = DCNConfig(n_fields=8, emb_dim=8, cross_depth=2, mlp_widths=(64, 32))
STEPS = 220
BATCH = 256


def run(method, **spec_kw):
    spec = emb_mod.EmbeddingSpec(
        method=method, n=SMALL.n_features, d=8, init_scale=0.05, **spec_kw
    )
    tr = CTRTrainer(TrainerConfig(spec=spec, model="dcn", dcn=DCN_SMALL, lr=3e-3))
    state, _ = tr.fit(DATA, steps=STEPS, batch_size=BATCH)
    ev = tr.evaluate(state, DATA.batches("test", BATCH, 10))
    return ev


@pytest.fixture(scope="module")
def fp_result():
    return run("fp")


def test_fp_learns(fp_result):
    # The planted teacher is learnable: clearly better than random.
    assert fp_result["auc"] > 0.65


def test_alpt_int8_close_to_fp(fp_result):
    """Paper's headline: 8-bit ALPT without accuracy loss."""
    ev = run("alpt", bits=8, alpt=ALPTConfig(bits=8, step_lr=2e-4))
    assert ev["auc"] > fp_result["auc"] - 0.01


def test_lpt_sr_learns_but_trails_alpt(fp_result):
    """LPT(SR) with a fixed tuned clip works but loses accuracy (Table 1)."""
    ev = run("lpt", bits=8, clip_value=0.1)
    assert ev["auc"] > 0.60  # learns
    # ALPT's learned step should not do worse (tolerance for SR noise).
    ev_alpt = run("alpt", bits=8, alpt=ALPTConfig(bits=8, step_lr=2e-4))
    assert ev_alpt["auc"] >= ev["auc"] - 0.01


def test_lpt_dr_worst_rounding():
    """LPT(DR) suffers the stall of Remark 1 -> clearly below LPT(SR)."""
    ev_dr = run("lpt", bits=8, clip_value=0.1,
                alpt=ALPTConfig(bits=8, rounding="dr"))
    ev_sr = run("lpt", bits=8, clip_value=0.1)
    assert ev_sr["auc"] >= ev_dr["auc"] - 0.005


@pytest.mark.parametrize("method", ["lsq", "pact", "hash", "prune"])
def test_baselines_learn(method):
    ev = run(method)
    assert ev["auc"] > 0.62, f"{method} failed to learn: {ev}"


def test_memory_ordering():
    """Training-memory: LPT/ALPT 4x < FP; QAT >= FP (Table 1 compression)."""
    key = jax.random.PRNGKey(0)
    n, d = SMALL.n_features, 8
    specs = {
        m: emb_mod.EmbeddingSpec(method=m, n=n, d=d, bits=8)
        for m in ("fp", "alpt", "lsq")
    }
    states = {m: emb_mod.init_embedding(key, s) for m, s in specs.items()}
    mem = {
        m: emb_mod.memory_bytes(states[m], specs[m], training=True) for m in specs
    }
    assert mem["alpt"] < mem["fp"] / 2.5
    assert mem["lsq"] >= mem["fp"]
    # Inference: QAT also ships int8.
    mem_inf_lsq = emb_mod.memory_bytes(states["lsq"], specs["lsq"], training=False)
    assert mem_inf_lsq < mem["fp"] / 2.5
