"""Extra coverage: 4-bit packed storage roundtrip, DeepFM end-to-end."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import quant
from repro.core.alpt import ALPTConfig
from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic
from repro.models import embedding as emb_mod
from repro.models.ctr import DeepFMConfig
from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_pack4_roundtrip_bit_exact(seed):
    key = jax.random.PRNGKey(seed)
    codes = jax.random.randint(key, (8, 16), -8, 8, jnp.int8)
    packed = quant.pack4(codes)
    assert packed.dtype == jnp.uint8
    assert packed.shape == (8, 8)  # exactly half the bytes
    np.testing.assert_array_equal(
        np.asarray(quant.unpack4(packed)), np.asarray(codes)
    )


def test_pack4_storage_is_half():
    codes = jnp.zeros((100, 32), jnp.int8)
    assert quant.pack4(codes).size * 2 == codes.size


def test_deepfm_end_to_end_with_alpt():
    """DeepFM backbone (FM 1st+2nd order + deep) trains with the int8 table.

    The trainer stores the FM first-order weight as the last embedding column
    (table d = emb_dim + 1)."""
    data_cfg = CTRDatasetConfig(
        name="dfm", n_fields=6, cardinalities=(29, 53, 11, 97, 41, 17),
        teacher_rank=4, seed=5,
    )
    data = CTRSynthetic(data_cfg)
    d = 8
    spec = emb_mod.EmbeddingSpec(
        method="alpt", n=data_cfg.n_features, d=d + 1, bits=8, init_scale=0.05,
        alpt=ALPTConfig(bits=8, step_lr=2e-4),
    )
    tr = CTRTrainer(
        TrainerConfig(
            spec=spec, model="deepfm",
            deepfm=DeepFMConfig(n_fields=6, emb_dim=d, mlp_widths=(32, 16)),
            lr=3e-3,
        )
    )
    state, _ = tr.fit(data, steps=300, batch_size=256)
    ev = tr.evaluate(state, data.batches("test", 256, 8))
    # DeepFM lacks DCN's cross layers and converges slower on this teacher;
    # the bar checks the quantized-table path learns, not parity with DCN.
    assert ev["auc"] > 0.60, ev
