"""Extra coverage: sub-byte packed storage roundtrip, DeepFM end-to-end."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import codestore, quant
from repro.core.alpt import ALPTConfig
from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic
from repro.models import embedding as emb_mod
from repro.models.ctr import DeepFMConfig
from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    bits=st.sampled_from([2, 4]),
    d=st.integers(1, 33),  # odd widths exercise the zero-padded last byte
)
def test_pack_unpack_identity_full_code_range(seed, bits, d):
    """pack∘unpack is the identity over the *entire* signed code range
    (negative codes included) for both packable widths, any last-dim
    length — the invariant every packed-vs-unpacked parity bar rests on."""
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1)
    key = jax.random.PRNGKey(seed)
    codes = jax.random.randint(key, (8, d), lo, hi, jnp.int8)
    packed = codestore.pack_codes(codes, bits)
    assert packed.dtype == jnp.uint8
    assert packed.shape == (8, codestore.packed_width(d, bits))
    np.testing.assert_array_equal(
        np.asarray(codestore.unpack_codes(packed, bits, d)), np.asarray(codes)
    )


def test_pack_exhaustive_code_values():
    """Every representable code value survives a roundtrip, both widths."""
    for bits in (2, 4):
        lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
        codes = jnp.arange(lo, hi + 1, dtype=jnp.int8).reshape(1, -1)
        got = codestore.unpack_codes(
            codestore.pack_codes(codes, bits), bits, codes.shape[-1]
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(codes))


def test_pack4_compat_is_pack_codes():
    """The legacy 4-bit helpers are thin aliases of the generalized pair —
    byte-identical layout (low nibble first)."""
    codes = jax.random.randint(jax.random.PRNGKey(3), (16, 32), -8, 8, jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(quant.pack4(codes)),
        np.asarray(codestore.pack_codes(codes, 4)),
    )
    np.testing.assert_array_equal(
        np.asarray(quant.unpack4(quant.pack4(codes))), np.asarray(codes)
    )


def test_pack_storage_ratio():
    codes = jnp.zeros((100, 32), jnp.int8)
    assert codestore.pack_codes(codes, 4).size * 2 == codes.size
    assert codestore.pack_codes(codes, 2).size * 4 == codes.size


def test_deepfm_end_to_end_with_alpt():
    """DeepFM backbone (FM 1st+2nd order + deep) trains with the int8 table.

    The trainer stores the FM first-order weight as the last embedding column
    (table d = emb_dim + 1)."""
    data_cfg = CTRDatasetConfig(
        name="dfm", n_fields=6, cardinalities=(29, 53, 11, 97, 41, 17),
        teacher_rank=4, seed=5,
    )
    data = CTRSynthetic(data_cfg)
    d = 8
    spec = emb_mod.EmbeddingSpec(
        method="alpt", n=data_cfg.n_features, d=d + 1, bits=8, init_scale=0.05,
        alpt=ALPTConfig(bits=8, step_lr=2e-4),
    )
    tr = CTRTrainer(
        TrainerConfig(
            spec=spec, model="deepfm",
            deepfm=DeepFMConfig(n_fields=6, emb_dim=d, mlp_widths=(32, 16)),
            lr=3e-3,
        )
    )
    state, _ = tr.fit(data, steps=300, batch_size=256)
    ev = tr.evaluate(state, data.batches("test", 256, 8))
    # DeepFM lacks DCN's cross layers and converges slower on this teacher;
    # the bar checks the quantized-table path learns, not parity with DCN.
    assert ev["auc"] > 0.60, ev
