"""Frozen PRE-REFACTOR trainer step implementations (string dispatch).

These are verbatim copies of the per-method ``if`` ladders that lived in
``CTRTrainer._build_train_step`` / ``build_grad_fn`` / ``build_apply_fn`` /
``build_delta_grad_fn`` and the LM trainer's ``make_grad_fn`` /
``make_apply_fn`` / ``make_train_step`` before the ``repro.methods`` registry
redesign, kept ONLY as the reference side of the bitwise step-parity tests
(tests/test_method_registry_parity.py).  Do not extend them — new methods go
in ``repro/methods/``.
"""
import functools

import jax
import jax.numpy as jnp

from repro.core import alpt as alpt_mod
from repro.core import lpt as lpt_mod
from repro.core import quant
from repro.dist import collectives
from repro.dist.context import hint
from repro.models import ctr as ctr_models
from repro.models import embedding as emb_mod
from repro.models import transformer as tfm
from repro.optim import adam_update, clip_by_global_norm
from repro.training.ctr_trainer import TrainState
from repro.training.data_parallel import (
    _DELTA_SALT,
    _base_key,
    _combine_leaf_stacked,
    _combine_tree_stacked,
    _reshape_shards,
    _resolve,
)
from repro.training.lm_trainer import LMTrainState

FLOAT_METHODS = ("fp", "lsq", "pact", "hash", "prune")


# ------------------------------------------------------------- CTR (fused)


def legacy_ctr_train_step(trainer):
    """The pre-registry ``CTRTrainer._build_train_step`` (fp/float, lpt, alpt)."""
    spec = trainer.spec
    method = spec.method
    self = trainer

    if method in FLOAT_METHODS:

        @jax.jit
        def step_fn(state, ids, labels):
            lr = self._lr_at(state.step)
            rng, kd = jax.random.split(state.rng)
            emb_params = emb_mod.trainable_params(state.emb_state, spec)

            def loss_fn(emb_params, dense_params):
                emb_state = emb_mod.with_params(state.emb_state, emb_params, spec)
                rows = emb_mod.lookup(emb_state, ids, spec)
                logits = self._logits_from_rows(rows, dense_params, kd)
                return ctr_models.bce_loss(logits, labels)

            loss, (g_emb, g_dense) = jax.value_and_grad(loss_fn, (0, 1))(
                emb_params, state.dense_params
            )
            new_dense, dense_opt = adam_update(
                g_dense, state.dense_opt, state.dense_params, lr
            )
            new_emb_params, emb_opt = adam_update(
                g_emb, state.emb_opt, emb_params, lr,
                weight_decay=self.cfg.emb_weight_decay,
            )
            emb_state = emb_mod.with_params(state.emb_state, new_emb_params, spec)
            return (
                TrainState(emb_state, new_dense, dense_opt, emb_opt,
                           state.step + 1, rng),
                {"loss": loss, "lr": lr},
            )

        return step_fn

    if method == "lpt":

        @jax.jit
        def step_fn(state, ids, labels):
            lr = self._lr_at(state.step)
            rng, kd, kn = jax.random.split(state.rng, 3)
            rows0 = lpt_mod.lookup(state.emb_state, ids)

            def loss_fn(rows, dense_params):
                logits = self._logits_from_rows(rows, dense_params, kd)
                return ctr_models.bce_loss(logits, labels)

            loss, (g_rows, g_dense) = jax.value_and_grad(loss_fn, (0, 1))(
                rows0, state.dense_params
            )
            new_dense, dense_opt = adam_update(
                g_dense, state.dense_opt, state.dense_params, lr
            )
            emb_state = lpt_mod.sparse_apply(
                state.emb_state, ids, g_rows,
                lr=lr, bits=spec.bits, rounding=spec.alpt.rounding,
                noise_key=kn, optimizer=spec.row_optimizer,
                weight_decay=self.cfg.emb_weight_decay,
            )
            return (
                TrainState(emb_state, new_dense, dense_opt, None,
                           state.step + 1, rng),
                {"loss": loss, "lr": lr},
            )

        return step_fn

    if method == "alpt":

        @jax.jit
        def step_fn(state, ids, labels):
            lr = self._lr_at(state.step)
            rng, kd, kn = jax.random.split(state.rng, 3)
            rows0 = lpt_mod.lookup(state.emb_state, ids)

            def loss_rows_dense(rows, dense_params):
                logits = self._logits_from_rows(rows, dense_params, kd)
                return ctr_models.bce_loss(logits, labels)

            loss, g_dense = jax.value_and_grad(
                lambda dp: loss_rows_dense(rows0, dp)
            )(state.dense_params)
            new_dense, dense_opt = adam_update(
                g_dense, state.dense_opt, state.dense_params, lr
            )
            emb_state, loss2, aux = alpt_mod.alpt_step(
                state.emb_state,
                ids,
                lambda rows: loss_rows_dense(rows, state.dense_params),
                cfg=spec.alpt._replace(
                    weight_decay=self.cfg.emb_weight_decay,
                    optimizer=spec.row_optimizer,
                ),
                lr=lr,
                noise_key=kn,
                loss_fn_step2=lambda rows: loss_rows_dense(rows, new_dense),
            )
            return (
                TrainState(emb_state, new_dense, dense_opt, None,
                           state.step + 1, rng),
                {"loss": loss2, "lr": lr, **aux},
            )

        return step_fn

    raise ValueError(f"unknown method {method!r}")


# ------------------------------------------------- CTR (grad/apply pieces)


def legacy_ctr_grad_fn(trainer):
    spec = trainer.spec
    self = trainer

    if spec.method in FLOAT_METHODS:

        def grad_fn(state, ids, labels, kd):
            emb_params = emb_mod.trainable_params(state.emb_state, spec)

            def loss_fn(emb_params, dense_params):
                emb_state = emb_mod.with_params(state.emb_state, emb_params, spec)
                rows = emb_mod.lookup(emb_state, ids, spec)
                logits = self._logits_from_rows(rows, dense_params, kd)
                return ctr_models.bce_loss(logits, labels)

            return jax.value_and_grad(loss_fn, (0, 1))(
                emb_params, state.dense_params
            )

        return grad_fn

    def grad_fn(state, ids, labels, kd):
        table_fp = lpt_mod.dense_table(state.emb_state)

        def loss_fn(table_fp, dense_params):
            rows = jnp.take(table_fp, ids, axis=0)
            logits = self._logits_from_rows(rows, dense_params, kd)
            return ctr_models.bce_loss(logits, labels)

        return jax.value_and_grad(loss_fn, (0, 1))(
            table_fp, state.dense_params
        )

    return grad_fn


def legacy_ctr_apply_fn(trainer):
    spec = trainer.spec
    self = trainer
    method = spec.method

    if method in FLOAT_METHODS:

        def apply_fn(state, loss, grads, *, lr, rng, kn=None,
                     delta_grad=None, batch_rows=None):
            g_emb, g_dense = grads
            new_dense, dense_opt = adam_update(
                g_dense, state.dense_opt, state.dense_params, lr
            )
            emb_params = emb_mod.trainable_params(state.emb_state, spec)
            new_emb_params, emb_opt = adam_update(
                g_emb, state.emb_opt, emb_params, lr,
                weight_decay=self.cfg.emb_weight_decay,
            )
            emb_state = emb_mod.with_params(
                state.emb_state, new_emb_params, spec
            )
            return (
                TrainState(emb_state, new_dense, dense_opt, emb_opt,
                           state.step + 1, rng),
                {"loss": loss, "lr": lr},
            )

        return apply_fn

    if method == "lpt":

        def apply_fn(state, loss, grads, *, lr, rng, kn,
                     delta_grad=None, batch_rows=None):
            g_table, g_dense = grads
            new_dense, dense_opt = adam_update(
                g_dense, state.dense_opt, state.dense_params, lr
            )
            emb_state = lpt_mod.dense_apply(
                state.emb_state, g_table,
                lr=lr, bits=spec.bits, rounding=spec.alpt.rounding,
                noise_key=kn, optimizer=spec.row_optimizer,
                weight_decay=self.cfg.emb_weight_decay,
            )
            return (
                TrainState(emb_state, new_dense, dense_opt, None,
                           state.step + 1, rng),
                {"loss": loss, "lr": lr},
            )

        return apply_fn

    if method == "alpt":

        def apply_fn(state, loss, grads, *, lr, rng, kn,
                     delta_grad, batch_rows):
            g_table, g_dense = grads
            new_dense, dense_opt = adam_update(
                g_dense, state.dense_opt, state.dense_params, lr
            )
            table = state.emb_state
            acfg = spec.alpt._replace(
                weight_decay=self.cfg.emb_weight_decay,
                optimizer=spec.row_optimizer,
            )
            upd = alpt_mod.dense_weight_update(table, g_table, cfg=acfg, lr=lr)
            gscale = alpt_mod.grad_scale_factor(
                acfg, batch_rows=int(batch_rows), dim=table.dim
            )
            g_step = delta_grad(upd.w_new, table.step, new_dense, gscale)
            new_table = alpt_mod.dense_finish(
                table, upd, g_step, cfg=acfg, noise_key=kn
            )
            aux = {
                "step_grad_norm": jnp.linalg.norm(g_step),
                "mean_step": jnp.mean(new_table.step),
            }
            return (
                TrainState(new_table, new_dense, dense_opt, None,
                           state.step + 1, rng),
                {"loss": loss, "lr": lr, **aux},
            )

        return apply_fn

    raise ValueError(f"unknown method {method!r}")


def legacy_ctr_delta_fn(trainer):
    spec = trainer.spec
    self = trainer

    def delta_fn(w_new, step_vec, dense_params, ids, labels, kd, gscale):
        def loss_wrt_step(step_vec):
            table_q = quant.fake_quant_lsq(
                jax.lax.stop_gradient(w_new), step_vec, spec.bits, gscale
            )
            rows = jnp.take(table_q, ids, axis=0)
            logits = self._logits_from_rows(rows, dense_params, kd)
            return ctr_models.bce_loss(logits, labels)

        return jax.grad(loss_wrt_step)(step_vec)

    return delta_fn


def legacy_ctr_microbatch_step(trainer, n_shards, dp=None):
    """Pre-registry ``make_ctr_microbatch_step`` wired to the legacy pieces."""
    dp = _resolve(dp, trainer.cfg.dp_sync_bits)
    grad_fn = legacy_ctr_grad_fn(trainer)
    apply_fn = legacy_ctr_apply_fn(trainer)
    delta_fn = (
        legacy_ctr_delta_fn(trainer) if trainer.spec.method == "alpt" else None
    )
    base = _base_key(dp)

    def step(state, ids, labels):
        lr = trainer._lr_at(state.step)
        rng, kd, kn = jax.random.split(state.rng, 3)
        ids_s = _reshape_shards(ids, n_shards)
        labels_s = _reshape_shards(labels, n_shards)

        def body(carry, shard):
            loss, grads = grad_fn(state, shard[0], shard[1], kd)
            return carry, (loss, grads)

        _, (losses, grad_stacks) = jax.lax.scan(body, None, (ids_s, labels_s))
        key = jax.random.fold_in(base, state.step)
        grads = _combine_tree_stacked(grad_stacks, key, dp)
        loss = collectives.exact_pmean_stacked(losses)

        delta_grad = None
        if delta_fn is not None:
            def delta_grad(w_new, step_vec, new_dense, gscale):
                def body2(carry, shard):
                    g = delta_fn(
                        w_new, step_vec, new_dense, shard[0], shard[1], kd,
                        gscale,
                    )
                    return carry, g

                _, g_stack = jax.lax.scan(body2, None, (ids_s, labels_s))
                return _combine_leaf_stacked(
                    g_stack, jax.random.fold_in(key, _DELTA_SALT), dp
                )

        return apply_fn(
            state, loss, grads, lr=lr, rng=rng, kn=kn,
            delta_grad=delta_grad, batch_rows=ids.size,
        )

    return jax.jit(step, donate_argnums=(0,))


# --------------------------------------------------------------------- LM


def _legacy_alpt_config(cfg, tcfg):
    return alpt_mod.ALPTConfig(
        bits=cfg.embedding_bits, rounding="sr",
        optimizer=tcfg.row_optimizer,
        weight_decay=tcfg.emb_weight_decay,
        step_lr=tcfg.alpt_step_lr,
    )


def legacy_table_fp_of(state, cfg):
    if cfg.embedding_method in ("lpt", "alpt"):
        return lpt_mod.dense_table(state.table)
    return state.table


def legacy_lm_grad_fn(cfg, tcfg):
    def grad_fn(state, batch):
        table_fp = hint(legacy_table_fp_of(state, cfg), "embed_table")

        def loss_of(table_fp, params):
            loss, aux = tfm.loss_fn(params, table_fp, batch, cfg)
            return loss, aux

        (loss, aux), (g_table, g_params) = jax.value_and_grad(
            loss_of, argnums=(0, 1), has_aux=True
        )(table_fp, state.params)
        g_table = hint(g_table, "embed_table")
        return (loss, aux), (g_table, g_params)

    return grad_fn


def legacy_lm_delta_grad_fn(cfg, tcfg):
    acfg = _legacy_alpt_config(cfg, tcfg)

    def delta_fn(w_new, step_vec, params, batch, gscale):
        return alpt_mod.dense_delta_grad(
            w_new, step_vec,
            lambda t: tfm.loss_fn(params, t, batch, cfg)[0],
            cfg=acfg, gscale=gscale,
        )

    return delta_fn


def legacy_lm_apply_fn(cfg, tcfg):
    method = cfg.embedding_method

    def apply_fn(state, loss_aux, grads, *, lr, rng, kn,
                 delta_grad=None, batch_rows=None):
        loss, aux = loss_aux
        g_table, g_params = grads
        g_params, gnorm = clip_by_global_norm(g_params, tcfg.grad_clip)
        new_params, new_opt = adam_update(
            g_params, state.opt, state.params, lr,
            weight_decay=tcfg.weight_decay,
        )

        if method == "fp":
            new_table, new_table_opt = adam_update(
                g_table, state.table_opt, state.table, lr,
                weight_decay=tcfg.emb_weight_decay,
            )
        elif method == "lpt":
            new_table = lpt_mod.dense_apply(
                state.table, g_table, lr=lr, bits=cfg.embedding_bits,
                rounding="sr", noise_key=kn, optimizer=tcfg.row_optimizer,
                weight_decay=tcfg.emb_weight_decay,
            )
            new_table_opt = None
        else:  # alpt
            acfg = _legacy_alpt_config(cfg, tcfg)
            table = state.table
            upd = alpt_mod.dense_weight_update(table, g_table, cfg=acfg, lr=lr)
            gscale = alpt_mod.grad_scale_factor(
                acfg, batch_rows=int(batch_rows), dim=table.dim
            )
            g_step = delta_grad(upd.w_new, table.step, new_params, gscale)
            new_table = alpt_mod.dense_finish(
                table, upd, g_step, cfg=acfg, noise_key=kn
            )
            new_table_opt = None

        metrics = {
            "loss": loss,
            "aux_loss": aux,
            "grad_norm": gnorm,
            "lr": lr,
        }
        return (
            LMTrainState(
                params=new_params, opt=new_opt, table=new_table,
                table_opt=new_table_opt, step=state.step + 1, rng=rng,
            ),
            metrics,
        )

    return apply_fn


def legacy_lm_train_step(cfg, tcfg, lr_schedule=None, *, grad_sync=None,
                         step_grad_sync=None, dp_size=1):
    grad_fn = legacy_lm_grad_fn(cfg, tcfg)
    apply_fn = legacy_lm_apply_fn(cfg, tcfg)
    delta_fn = (
        legacy_lm_delta_grad_fn(cfg, tcfg)
        if cfg.embedding_method == "alpt" else None
    )

    def lr_at(step):
        if lr_schedule is None:
            return jnp.asarray(tcfg.lr, jnp.float32)
        return lr_schedule(step)

    def train_step(state, batch):
        lr = lr_at(state.step)
        rng, kn = jax.random.split(state.rng)
        loss_aux, grads = grad_fn(state, batch)
        if grad_sync is not None:
            grads = grad_sync(grads, state.step)

        delta_grad = None
        if delta_fn is not None:
            def delta_grad(w_new, step_vec, new_params, gscale):
                g_step = delta_fn(w_new, step_vec, new_params, batch, gscale)
                if step_grad_sync is not None:
                    g_step = step_grad_sync(g_step, state.step)
                return g_step

        return apply_fn(
            state, loss_aux, grads, lr=lr, rng=rng, kn=kn,
            delta_grad=delta_grad,
            batch_rows=int(batch["labels"].size) * dp_size,
        )

    return train_step


def legacy_lm_microbatch_step(cfg, tcfg, n_shards, dp=None):
    """Pre-registry ``make_lm_microbatch_step`` wired to the legacy pieces."""
    dp = _resolve(dp, tcfg.dp_sync_bits)
    grad_fn = legacy_lm_grad_fn(cfg, tcfg)
    apply_fn = legacy_lm_apply_fn(cfg, tcfg)
    delta_fn = (
        legacy_lm_delta_grad_fn(cfg, tcfg)
        if cfg.embedding_method == "alpt" else None
    )
    base = _base_key(dp)

    def step(state, batch):
        lr = jnp.asarray(tcfg.lr, jnp.float32)
        rng, kn = jax.random.split(state.rng)
        batch_s = jax.tree.map(
            functools.partial(_reshape_shards, n_shards=n_shards), batch
        )

        def body(carry, shard):
            return carry, grad_fn(state, shard)

        _, ((losses, auxes), grad_stacks) = jax.lax.scan(body, None, batch_s)
        key = jax.random.fold_in(base, state.step)
        grads = _combine_tree_stacked(grad_stacks, key, dp)
        loss = collectives.exact_pmean_stacked(losses)
        aux = jax.tree.map(collectives.exact_pmean_stacked, auxes)

        delta_grad = None
        if delta_fn is not None:
            def delta_grad(w_new, step_vec, new_params, gscale):
                def body2(carry, shard):
                    return carry, delta_fn(
                        w_new, step_vec, new_params, shard, gscale
                    )

                _, g_stack = jax.lax.scan(body2, None, batch_s)
                return _combine_leaf_stacked(
                    g_stack, jax.random.fold_in(key, _DELTA_SALT), dp
                )

        return apply_fn(
            state, (loss, aux), grads, lr=lr, rng=rng, kn=kn,
            delta_grad=delta_grad, batch_rows=int(batch["labels"].size),
        )

    return jax.jit(step, donate_argnums=(0,))
